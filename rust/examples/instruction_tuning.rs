//! Instruction tuning scenario (paper §5.3): fine-tune on the synthetic
//! instruction corpus, then measure generalization across the eight
//! MT-Bench-like categories, comparing S²FT against LoRA and full FT head
//! to head — including far-OOD retention of pre-trained skills.
//!
//! Run: `cargo run --release --example instruction_tuning`

use anyhow::Result;

use repro::data::{finetune_examples, COMMONSENSE, INSTRUCT};
use repro::experiments::common::{evaluate_suite, finetune, pretrain};
use repro::runtime::open_backend;
use repro::train::GenModel;

fn main() -> Result<()> {
    let steps: usize = std::env::var("STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(150);
    let rt = open_backend("artifacts")?;
    println!("pre-training base model ({steps} steps)...");
    let base = pretrain(rt.as_ref(), "small", steps, 42, true)?;
    let examples = finetune_examples("instruct", 2000, 99);

    println!("\n{:<10} {:>10} {:>12} {:>14}", "method", "instruct%", "retention%", "train-loss");
    for method in ["fullft", "lora", "s2ft"] {
        let trainer = match finetune(rt.as_ref(), "small", method, &base, &examples, steps, 5) {
            Ok(t) => t,
            Err(e) => {
                println!("{method:<10} skipped ({e})");
                continue;
            }
        };
        let model = GenModel::new(rt.as_ref(), "small", trainer.merged_params(rt.as_ref())?)?;
        let (per_cat, avg) = evaluate_suite(&model, &INSTRUCT, 16, 3)?;
        // far-OOD retention: commonsense skills learned in pre-training
        let (_, retention) = evaluate_suite(&model, &COMMONSENSE, 16, 3)?;
        println!(
            "{:<10} {:>10.1} {:>12.1} {:>14.3}",
            method,
            avg,
            retention,
            trainer.metrics.tail_loss(10)
        );
        if method == "s2ft" {
            println!("  per category:");
            for (name, acc) in per_cat {
                println!("    {name:>12}: {acc:5.1}%");
            }
        }
    }
    println!("\nExpected (paper Tab 3): S2FT ≥ FullFT ≥ LoRA on generalization.");
    Ok(())
}
