"""Selection strategies (S2FT-R/W/A/S/G) unit tests."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import selection as sel


def test_topk_largest_and_smallest():
    scores = jnp.asarray([3.0, 1.0, 4.0, 1.5, 5.0])
    assert sel.topk_indices(scores, 2, smallest=False).tolist() == [2, 4]
    assert sel.topk_indices(scores, 2, smallest=True).tolist() == [1, 3]


@settings(max_examples=25, deadline=None)
@given(total=st.integers(1, 100), seed=st.integers(0, 10**6))
def test_random_indices_valid(total, seed):
    rng = np.random.default_rng(seed)
    s = int(rng.integers(1, total + 1))
    idx = sel.random_indices(rng, total, s)
    assert len(idx) == s
    assert len(set(idx.tolist())) == s
    assert idx.tolist() == sorted(idx.tolist())
    assert all(0 <= i < total for i in idx)


def test_weight_score_ffn_shape():
    d, k = 6, 10
    rng = np.random.default_rng(0)
    score = sel.weight_score_ffn(
        jnp.asarray(rng.standard_normal((d, k)), ),
        jnp.asarray(rng.standard_normal((d, k))),
        jnp.asarray(rng.standard_normal((k, d))),
    )
    assert score.shape == (k,)
    assert np.all(np.asarray(score) > 0)


def test_activation_score_identifies_hot_channel():
    acts = np.ones((4, 7, 5), np.float32) * 0.01
    acts[..., 3] = 10.0
    score = sel.activation_score(jnp.asarray(acts))
    assert int(np.argmax(np.asarray(score))) == 3
    # smallest-activation selection avoids the hot channel (paper Table 4)
    idx = sel.topk_indices(score, 4, smallest=True)
    assert 3 not in idx.tolist()


def test_head_score_from_channels():
    chan = jnp.asarray(np.array([1, 1, 5, 5, 0, 0], np.float32))
    hs = sel.head_score_from_channels(chan, 3)
    assert np.asarray(hs).tolist() == [2.0, 10.0, 0.0]


def test_gradient_score_axes():
    g = np.zeros((4, 3), np.float32)
    g[2, :] = 3.0
    s0 = sel.gradient_score(jnp.asarray(g), axis=0)  # per-row
    assert s0.shape == (4,)
    assert int(np.argmax(np.asarray(s0))) == 2


def test_select_ffn_channels_strategies():
    rng = np.random.default_rng(1)
    d, k = 8, 16
    wu = jnp.asarray(rng.standard_normal((d, k)).astype(np.float32))
    wg = jnp.asarray(rng.standard_normal((d, k)).astype(np.float32))
    wd = jnp.asarray(rng.standard_normal((k, d)).astype(np.float32))
    acts = jnp.asarray(rng.standard_normal((3, 5, k)).astype(np.float32))
    grad = jnp.asarray(rng.standard_normal((k, d)).astype(np.float32))
    for strat in "rwasg":
        idx = sel.select_ffn_channels(strat, True, 4, wu, wg, wd, acts=acts,
                                      grad_wd=grad, rng=rng)
        assert len(idx) == 4 and len(set(idx.tolist())) == 4

    with pytest.raises(ValueError):
        sel.select_ffn_channels("x", True, 4, wu, wg, wd, rng=rng)


def test_select_mha_heads_strategies():
    rng = np.random.default_rng(2)
    d, h = 16, 4
    wo = jnp.asarray(rng.standard_normal((d, d)).astype(np.float32))
    acts = jnp.asarray(rng.standard_normal((2, 3, d)).astype(np.float32))
    grad = jnp.asarray(rng.standard_normal((d, d)).astype(np.float32))
    for strat in "rwasg":
        idx = sel.select_mha_heads(strat, False, 2, wo, h, acts=acts,
                                   grad_wo=grad, rng=rng)
        assert len(idx) == 2 and all(0 <= i < h for i in idx.tolist())


def test_select_full_budget_returns_all():
    rng = np.random.default_rng(3)
    wd = jnp.zeros((5, 4))
    idx = sel.select_ffn_channels("r", True, 5, jnp.zeros((4, 5)), jnp.zeros((4, 5)),
                                  wd, rng=rng)
    assert idx.tolist() == [0, 1, 2, 3, 4]


def test_budget_to_counts():
    c = sel.budget_to_counts({"wo": 0.25, "wd": 0.1}, d_ff=100, n_heads=8)
    assert c == {"wo": 2, "wd": 10}
    c = sel.budget_to_counts({"wo": 0.01}, d_ff=100, n_heads=8)
    assert c["wo"] == 1  # nonzero fraction floors at one head
    with pytest.raises(ValueError):
        sel.budget_to_counts({"bogus": 0.5}, 10, 2)
