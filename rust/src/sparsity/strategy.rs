//! Pluggable unit-selection strategies: the "selecting sparsely" step of
//! S²FT (paper §3.1) as a first-class, swappable policy.
//!
//! The native `prepare` artifact and the [`crate::train::Trainer`] replan
//! path both route through the helpers here ([`select_units`],
//! [`head_unit_scores`], [`chan_unit_scores`], [`SELECTION_STREAM`]), so a
//! [`StaticS2ft`] strategy driven host-side reproduces the artifact's
//! selection bit-for-bit — the regression contract the refactor is pinned
//! by. Dynamic strategies ([`IterativeDropGrow`], [`GradNormWarmup`])
//! return a fresh [`LayerSelections`] mid-run; the trainer then rebuilds
//! the co-permuted pool, remaps optimizer moments by *original unit
//! index*, and bumps the plan epoch so every plan-derived cache downstream
//! is rebuilt (see `rust/docs/training.md`).

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Seed-stream tag for unit selection: `prepare` derives its selection
/// RNG as `Rng::seed(seed ^ SELECTION_STREAM)`, then folds `2*i` (heads)
/// and `2*i + 1` (channels) per layer `i`. Host-side strategies reuse the
/// identical stream so static selections match the artifact bitwise.
pub const SELECTION_STREAM: u64 = 0x52F7_1111;

/// The trainable units chosen for one transformer layer: head ids for the
/// coupled wq/wk/wv/wo structure and FFN channel ids for wu/wg/wd, both
/// keyed by *original* (unpermuted) unit index.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct LayerSelection {
    /// Selected attention heads (original head indices, selection order).
    pub heads: Vec<usize>,
    /// Selected FFN channels (original channel indices, selection order).
    pub channels: Vec<usize>,
}

/// Per-layer selections for the whole model (`len == n_layers`).
pub type LayerSelections = Vec<LayerSelection>;

/// Unit scores a strategy may consult. Magnitude scores are always
/// populated (recomputed from the current merged weights at each replan);
/// gradient scores are measured by the `gradnorm_M_BxT` probe artifact and
/// present only when the strategy declared it needs them
/// ([`SelectionStrategy::needs_grad_scores`]).
#[derive(Debug, Clone, Default)]
pub struct UnitScores {
    /// Per layer: weight-magnitude score per head (the wo row-block L2
    /// norm — same formula the static "w" selection uses).
    pub head_mag: Vec<Vec<f32>>,
    /// Per layer: weight-magnitude score per FFN channel (wu col + wg col
    /// + wd row L2 norms).
    pub chan_mag: Vec<Vec<f32>>,
    /// Per layer: gradient-magnitude score per head, from a probe batch.
    pub head_grad: Option<Vec<Vec<f32>>>,
    /// Per layer: gradient-magnitude score per FFN channel.
    pub chan_grad: Option<Vec<Vec<f32>>>,
}

/// Everything a strategy sees when (re)selecting: the step counter, the
/// model geometry, the per-structure unit budget, the current selection
/// (None before the first commit) and the scores.
#[derive(Debug)]
pub struct SelectionCtx<'a> {
    /// 0-based optimizer step the upcoming train step will run at.
    pub step: usize,
    /// Transformer depth.
    pub n_layers: usize,
    /// Total attention heads per layer.
    pub n_heads: usize,
    /// Total FFN channels per layer.
    pub d_ff: usize,
    /// Budgeted trainable heads per layer (0 = MHA structure unbudgeted;
    /// strategies must then select no heads).
    pub mha_count: usize,
    /// Budgeted trainable FFN channels per layer (0 = unbudgeted).
    pub ffn_count: usize,
    /// The run seed (same value `prepare` receives as its `seed` input).
    pub seed: u64,
    /// Unit scores (see [`UnitScores`]).
    pub scores: &'a UnitScores,
    /// The selection currently in effect, if any.
    pub current: Option<&'a LayerSelections>,
}

/// A pluggable selection policy. The trainer drives it as:
///
/// 1. at step 0, [`SelectionStrategy::select`] must commit an initial
///    [`LayerSelections`];
/// 2. before each later step it asks [`SelectionStrategy::replan_due`];
///    when due (and after measuring gradient scores if
///    [`SelectionStrategy::needs_grad_scores`] says so) it calls
///    [`SelectionStrategy::select`] again — `Some` commits the returned
///    selection (a *re*-commit of an identical selection still rebuilds
///    the pool/plans, which is exactly what the bit-identity proptest
///    exercises), `None` leaves the current plan untouched.
///
/// Replan semantics for optimizer state: AdamW moments are keyed by
/// original unit index — surviving units carry their moments over,
/// dropped units' moments are discarded, grown units start at zero.
pub trait SelectionStrategy: Send {
    /// Short identifier (CLI `--strategy` value, experiment row label).
    fn name(&self) -> &str;

    /// Whether the upcoming [`SelectionStrategy::select`] call at `step`
    /// needs measured gradient scores (`head_grad`/`chan_grad`). The probe
    /// costs a full forward/backward, so default is `false`.
    fn needs_grad_scores(&self, _step: usize) -> bool {
        false
    }

    /// Whether to re-run selection before `step`. The default honors the
    /// trainer's `--replan-every` cadence; strategies with an intrinsic
    /// schedule (e.g. a warmup commit point) override it.
    fn replan_due(&self, step: usize, replan_every: usize) -> bool {
        replan_every > 0 && step > 0 && step % replan_every == 0
    }

    /// (Re)select trainable units. `Some` commits (even if identical to
    /// the current selection); `None` keeps the current plan.
    fn select(&mut self, ctx: &SelectionCtx) -> Result<Option<LayerSelections>>;
}

// ---------------------------------------------------------------------------
// Shared selection math (used by the native prepare artifact too)
// ---------------------------------------------------------------------------

/// Unit selection for one coupled structure — the exact semantics of the
/// prepare artifact's selection strategies: `"r"` draws `count` distinct
/// units from the rng stream (ascending); `"w"` stably sorts units by
/// score (ascending when `select_small`, else descending), takes `count`,
/// and returns them ascending. `count >= total` selects every unit.
/// `scores` is lazy: `"r"` never evaluates it.
pub fn select_units(
    selection: &str,
    select_small: bool,
    total: usize,
    count: usize,
    scores: impl Fn() -> Vec<f32>,
    rng: &mut Rng,
) -> Result<Vec<usize>> {
    if count >= total {
        return Ok((0..total).collect());
    }
    match selection {
        "r" => Ok(rng.choose(total, count)),
        "w" => {
            let sc = scores();
            let mut idx: Vec<usize> = (0..total).collect();
            idx.sort_by(|&a, &b| sc[a].partial_cmp(&sc[b]).unwrap_or(std::cmp::Ordering::Equal));
            if !select_small {
                idx.reverse();
            }
            let mut sel = idx[..count].to_vec();
            sel.sort_unstable();
            Ok(sel)
        }
        other => bail!("unsupported selection strategy {other:?} (expected \"r\" or \"w\")"),
    }
}

/// Per-head weight score over a `(d_model, d_model)` wo matrix: the L2
/// norm of head `h`'s row block (`head_dim` rows). Also applied to the
/// wo *gradient* by the `gradnorm` probe — same formula, same bits.
pub fn head_unit_scores(wo: &[f32], d_model: usize, head_dim: usize, n_heads: usize) -> Vec<f32> {
    (0..n_heads)
        .map(|h| {
            wo[h * head_dim * d_model..(h + 1) * head_dim * d_model]
                .iter()
                .map(|v| v * v)
                .sum::<f32>()
                .sqrt()
        })
        .collect()
}

/// Per-channel weight score over the coupled FFN structure: L2 norm of
/// channel `c`'s wu column + wg column + wd row. wu/wg are
/// `(d_model, d_ff)`, wd is `(d_ff, d_model)`, all row-major.
pub fn chan_unit_scores(
    wu: &[f32],
    wg: &[f32],
    wd: &[f32],
    d_model: usize,
    d_ff: usize,
) -> Vec<f32> {
    (0..d_ff)
        .map(|c| {
            let col = |w: &[f32]| {
                (0..d_model)
                    .map(|r| w[r * d_ff + c] * w[r * d_ff + c])
                    .sum::<f32>()
                    .sqrt()
            };
            let wd_row = wd[c * d_model..(c + 1) * d_model]
                .iter()
                .map(|v| v * v)
                .sum::<f32>()
                .sqrt();
            col(wu) + col(wg) + wd_row
        })
        .collect()
}

/// The static S²FT selection for every layer: the prepare artifact's
/// per-layer rng folds (`2*i` heads, `2*i + 1` channels) over the
/// [`SELECTION_STREAM`] with weight-magnitude scores — bit-identical to
/// what `prepare_M_m_BxT` computes for the same seed and weights.
pub fn static_layer_selections(
    selection: &str,
    select_small: bool,
    ctx: &SelectionCtx,
) -> Result<LayerSelections> {
    let root = Rng::seed(ctx.seed ^ SELECTION_STREAM);
    let mut out = Vec::with_capacity(ctx.n_layers);
    for i in 0..ctx.n_layers {
        let mut sel = LayerSelection::default();
        if ctx.mha_count > 0 {
            sel.heads = select_units(
                selection,
                select_small,
                ctx.n_heads,
                ctx.mha_count,
                || ctx.scores.head_mag[i].clone(),
                &mut root.fold(2 * i as u64),
            )?;
        }
        if ctx.ffn_count > 0 {
            sel.channels = select_units(
                selection,
                select_small,
                ctx.d_ff,
                ctx.ffn_count,
                || ctx.scores.chan_mag[i].clone(),
                &mut root.fold(2 * i as u64 + 1),
            )?;
        }
        out.push(sel);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Strategies
// ---------------------------------------------------------------------------

/// The paper's one-shot static selection behind the pluggable trait:
/// selects once at step 0 (exactly as the prepare artifact would) and
/// re-commits the *stored* selection verbatim whenever a replan is forced
/// — so a forced replan provably changes nothing but the plan epoch.
#[derive(Debug, Clone)]
pub struct StaticS2ft {
    selection: String,
    select_small: bool,
    committed: Option<LayerSelections>,
}

impl StaticS2ft {
    /// `selection`/`select_small` as in the method meta (`"r"` or `"w"`).
    pub fn new(selection: &str, select_small: bool) -> Self {
        Self { selection: selection.to_string(), select_small, committed: None }
    }
}

impl SelectionStrategy for StaticS2ft {
    fn name(&self) -> &str {
        "static"
    }

    fn select(&mut self, ctx: &SelectionCtx) -> Result<Option<LayerSelections>> {
        if let Some(sel) = &self.committed {
            // Forced replan: re-commit the step-0 selection unchanged.
            return Ok(Some(sel.clone()));
        }
        let sel = static_layer_selections(&self.selection, self.select_small, ctx)?;
        self.committed = Some(sel.clone());
        Ok(Some(sel))
    }
}

/// Ansell-style iterative drop/regrow (PAPERS.md, arXiv 2401.16405):
/// starts from the static selection, then every replan drops the
/// `drop_frac` lowest weight-magnitude selected units per structure and
/// regrows the same number of currently-frozen units with the highest
/// measured gradient magnitude. The trainable budget never changes.
#[derive(Debug, Clone)]
pub struct IterativeDropGrow {
    selection: String,
    select_small: bool,
    drop_frac: f64,
    started: bool,
}

impl IterativeDropGrow {
    /// `drop_frac` is clamped into (0, 1]; the initial selection uses the
    /// method's static `selection`/`select_small` semantics.
    pub fn new(selection: &str, select_small: bool, drop_frac: f64) -> Self {
        Self {
            selection: selection.to_string(),
            select_small,
            drop_frac: drop_frac.clamp(1e-6, 1.0),
            started: false,
        }
    }
}

/// Drop the `k` lowest-`mag` members of `cur`, regrow the `k` highest
/// `grad` non-members; ties break toward the lower unit index, and the
/// result is sorted ascending. Pure and deterministic.
fn drop_grow_one(
    cur: &[usize],
    total: usize,
    k: usize,
    mag: &[f32],
    grad: &[f32],
) -> Vec<usize> {
    let mut selected = vec![false; total];
    for &u in cur {
        selected[u] = true;
    }
    let avail = total - cur.len();
    let k = k.min(cur.len()).min(avail);
    if k == 0 {
        let mut keep = cur.to_vec();
        keep.sort_unstable();
        return keep;
    }
    let mut members = cur.to_vec();
    members.sort_by(|&a, &b| {
        mag[a].partial_cmp(&mag[b]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    let mut frozen: Vec<usize> = (0..total).filter(|&u| !selected[u]).collect();
    frozen.sort_by(|&a, &b| {
        grad[b].partial_cmp(&grad[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    let mut next: Vec<usize> = members[k..].iter().chain(&frozen[..k]).copied().collect();
    debug_assert_eq!(next.len(), cur.len());
    next.sort_unstable();
    next
}

impl SelectionStrategy for IterativeDropGrow {
    fn name(&self) -> &str {
        "dropgrow"
    }

    fn needs_grad_scores(&self, step: usize) -> bool {
        step > 0
    }

    fn select(&mut self, ctx: &SelectionCtx) -> Result<Option<LayerSelections>> {
        if !self.started {
            self.started = true;
            let sel = static_layer_selections(&self.selection, self.select_small, ctx)?;
            return Ok(Some(sel));
        }
        let cur = match ctx.current {
            Some(c) => c,
            None => bail!("dropgrow: replan without a committed selection"),
        };
        let (Some(hg), Some(cg)) = (&ctx.scores.head_grad, &ctx.scores.chan_grad) else {
            bail!("dropgrow: replan requires measured gradient scores");
        };
        let mut next = Vec::with_capacity(ctx.n_layers);
        for i in 0..ctx.n_layers {
            let mut sel = LayerSelection::default();
            if ctx.mha_count > 0 {
                let k = (self.drop_frac * cur[i].heads.len() as f64).ceil() as usize;
                sel.heads = drop_grow_one(
                    &cur[i].heads,
                    ctx.n_heads,
                    k,
                    &ctx.scores.head_mag[i],
                    &hg[i],
                );
            }
            if ctx.ffn_count > 0 {
                let k = (self.drop_frac * cur[i].channels.len() as f64).ceil() as usize;
                sel.channels = drop_grow_one(
                    &cur[i].channels,
                    ctx.d_ff,
                    k,
                    &ctx.scores.chan_mag[i],
                    &cg[i],
                );
            }
            next.push(sel);
        }
        Ok(Some(next))
    }
}

/// Dense-ish warmup, then commit: trains *all but one* unit per structure
/// for `warmup` steps (the one left out keeps the frozen complement
/// non-empty — a zero-sized `_f` tensor is unrepresentable), then at step
/// `warmup` commits to the budgeted counts with the highest measured
/// gradient norms. A shape-changing replan: the trainer loads a layout
/// variant executable and shrinks the optimizer state, carrying moments
/// for the surviving units.
#[derive(Debug, Clone)]
pub struct GradNormWarmup {
    warmup: usize,
    committed: bool,
}

impl GradNormWarmup {
    /// Commit after `warmup` steps (minimum 1).
    pub fn new(warmup: usize) -> Self {
        Self { warmup: warmup.max(1), committed: false }
    }
}

/// The `count` highest-`score` unit ids, ties toward the lower index,
/// ascending.
fn top_by_score(score: &[f32], count: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..score.len()).collect();
    idx.sort_by(|&a, &b| {
        score[b]
            .partial_cmp(&score[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut sel = idx[..count.min(idx.len())].to_vec();
    sel.sort_unstable();
    sel
}

impl SelectionStrategy for GradNormWarmup {
    fn name(&self) -> &str {
        "warmup"
    }

    fn needs_grad_scores(&self, _step: usize) -> bool {
        !self.committed
    }

    fn replan_due(&self, step: usize, _replan_every: usize) -> bool {
        !self.committed && step == self.warmup
    }

    fn select(&mut self, ctx: &SelectionCtx) -> Result<Option<LayerSelections>> {
        if ctx.step == 0 {
            // Warmup phase: every unit but the last per structure.
            let sel = LayerSelection {
                heads: if ctx.mha_count > 0 { (0..ctx.n_heads - 1).collect() } else { vec![] },
                channels: if ctx.ffn_count > 0 { (0..ctx.d_ff - 1).collect() } else { vec![] },
            };
            return Ok(Some(vec![sel; ctx.n_layers]));
        }
        if self.committed {
            return Ok(None);
        }
        let (Some(hg), Some(cg)) = (&ctx.scores.head_grad, &ctx.scores.chan_grad) else {
            bail!("warmup: the commit step requires measured gradient scores");
        };
        let mut next = Vec::with_capacity(ctx.n_layers);
        for i in 0..ctx.n_layers {
            let heads =
                if ctx.mha_count > 0 { top_by_score(&hg[i], ctx.mha_count) } else { vec![] };
            let channels =
                if ctx.ffn_count > 0 { top_by_score(&cg[i], ctx.ffn_count) } else { vec![] };
            next.push(LayerSelection { heads, channels });
        }
        self.committed = true;
        Ok(Some(next))
    }
}

/// Build a strategy from its CLI/experiment name (`static`, `dropgrow`,
/// `warmup[:W]`), inheriting the static selection semantics from
/// `selection`/`select_small` (the method meta's fields).
pub fn for_name(
    name: &str,
    selection: &str,
    select_small: bool,
) -> Result<Box<dyn SelectionStrategy>> {
    if let Some(w) = name.strip_prefix("warmup:") {
        let w: usize = w.parse().map_err(|_| {
            anyhow::anyhow!("bad warmup step count in strategy {name:?} (expected warmup:<steps>)")
        })?;
        return Ok(Box::new(GradNormWarmup::new(w)));
    }
    match name {
        "static" => Ok(Box::new(StaticS2ft::new(selection, select_small))),
        "dropgrow" => Ok(Box::new(IterativeDropGrow::new(selection, select_small, 0.3))),
        "warmup" => Ok(Box::new(GradNormWarmup::new(8))),
        other => bail!("unknown selection strategy {other:?} (static|dropgrow|warmup[:W])"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(
        scores: &'a UnitScores,
        current: Option<&'a LayerSelections>,
        step: usize,
    ) -> SelectionCtx<'a> {
        SelectionCtx {
            step,
            n_layers: 2,
            n_heads: 4,
            d_ff: 8,
            mha_count: 2,
            ffn_count: 3,
            seed: 7,
            scores,
            current,
        }
    }

    fn mag_scores() -> UnitScores {
        UnitScores {
            head_mag: vec![vec![0.4, 0.1, 0.3, 0.2]; 2],
            chan_mag: vec![vec![0.8, 0.1, 0.7, 0.2, 0.6, 0.3, 0.5, 0.4]; 2],
            head_grad: None,
            chan_grad: None,
        }
    }

    #[test]
    fn static_matches_prepare_stream() {
        // same rng stream as prepare: seed ^ SELECTION_STREAM, fold(2i)/(2i+1)
        let scores = mag_scores();
        let c = ctx(&scores, None, 0);
        let mut s = StaticS2ft::new("r", true);
        let sel = s.select(&c).unwrap().unwrap();
        let root = Rng::seed(7 ^ SELECTION_STREAM);
        for (i, ls) in sel.iter().enumerate() {
            assert_eq!(ls.heads, root.fold(2 * i as u64).choose(4, 2));
            assert_eq!(ls.channels, root.fold(2 * i as u64 + 1).choose(8, 3));
        }
        // recommit returns the stored selection verbatim
        let again = s.select(&ctx(&scores, Some(&sel), 5)).unwrap().unwrap();
        assert_eq!(again, sel);
    }

    #[test]
    fn static_w_selects_small_scores() {
        let scores = mag_scores();
        let c = ctx(&scores, None, 0);
        let mut s = StaticS2ft::new("w", true);
        let sel = s.select(&c).unwrap().unwrap();
        // smallest head scores are units 1 (0.1) and 3 (0.2), ascending
        assert_eq!(sel[0].heads, vec![1, 3]);
        assert_eq!(sel[0].channels, vec![1, 3, 5]);
    }

    #[test]
    fn drop_grow_swaps_lowest_mag_for_highest_grad() {
        // cur = {1, 3}; mag: unit 1 = 0.1 (lowest) is dropped; frozen
        // units {0, 2} regrow by grad: unit 2 wins.
        let cur = vec![1, 3];
        let mag = vec![0.4, 0.1, 0.3, 0.2];
        let grad = vec![0.2, 0.0, 0.9, 0.0];
        assert_eq!(drop_grow_one(&cur, 4, 1, &mag, &grad), vec![2, 3]);
        // k = 0 keeps the selection
        assert_eq!(drop_grow_one(&cur, 4, 0, &mag, &grad), vec![1, 3]);
        // budget is preserved even when k exceeds the frozen pool
        assert_eq!(drop_grow_one(&[0, 1, 2], 4, 3, &mag, &grad).len(), 3);
    }

    #[test]
    fn warmup_commits_top_grad_units_once() {
        let mut scores = mag_scores();
        let mut s = GradNormWarmup::new(3);
        assert!(s.replan_due(3, 0));
        assert!(!s.replan_due(2, 0));
        let c = ctx(&scores, None, 0);
        let init = s.select(&c).unwrap().unwrap();
        // dense-ish: all but the last unit per structure
        assert_eq!(init[0].heads, vec![0, 1, 2]);
        assert_eq!(init[0].channels.len(), 7);
        scores.head_grad = Some(vec![vec![0.1, 0.9, 0.2, 0.8]; 2]);
        scores.chan_grad = Some(vec![vec![0.1, 0.2, 0.9, 0.8, 0.7, 0.0, 0.0, 0.0]; 2]);
        let c = ctx(&scores, Some(&init), 3);
        let committed = s.select(&c).unwrap().unwrap();
        assert_eq!(committed[0].heads, vec![1, 3]);
        assert_eq!(committed[0].channels, vec![2, 3, 4]);
        // after the commit the strategy never replans again
        assert!(!s.replan_due(6, 3));
        assert_eq!(s.select(&ctx(&scores, Some(&committed), 6)).unwrap(), None);
    }

    #[test]
    fn factory_resolves_names() {
        assert_eq!(for_name("static", "r", true).unwrap().name(), "static");
        assert_eq!(for_name("dropgrow", "r", true).unwrap().name(), "dropgrow");
        assert_eq!(for_name("warmup:5", "r", true).unwrap().name(), "warmup");
        assert!(for_name("nope", "r", true).is_err());
    }
}
