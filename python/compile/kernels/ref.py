"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its oracle to float32 tolerance under pytest (and under the
hypothesis shape/dtype sweep in ``python/tests/test_kernel.py``).
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    """Plain dense GEMM oracle: (M, K) @ (K, N) -> (M, N)."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32)


def s2ft_linear_ref(x, w_t, w_f):
    """Forward of an S2FT-partitioned linear layer.

    The coupled structure has been co-permuted so the ``s`` trainable
    channels are the leading rows of the weight: W = [w_t; w_f] with
    w_t: (s, N) trainable and w_f: (K - s, N) frozen. x: (M, K).
    """
    w = jnp.concatenate([w_t, w_f], axis=0)
    return matmul_ref(x, w)


def s2ft_linear_grads_ref(x, w_t, w_f, dy):
    """Reference partial back-propagation (paper Sec. 3.3).

    Returns (dx, dw_t): the input gradient needs the full weight, but the
    weight gradient is computed *only* for the trainable slice —
    dw_t = x[:, :s]^T @ dy. No gradient exists for w_f.
    """
    s = w_t.shape[0]
    w = jnp.concatenate([w_t, w_f], axis=0)
    dx = matmul_ref(dy, w.T)
    dw_t = matmul_ref(x[:, :s].T, dy)
    return dx, dw_t


def lora_linear_ref(x, w, a, b, scale):
    """LoRA-adapted linear: y = x @ (W + scale * A @ B)."""
    return matmul_ref(x, w) + scale * matmul_ref(matmul_ref(x, a), b)
