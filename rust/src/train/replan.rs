//! Host-side selection → pool pipeline for mid-run replanning.
//!
//! A dynamic [`crate::sparsity::strategy::SelectionStrategy`] commits a
//! new [`LayerSelections`] while optimizer state already exists in the
//! *old* method layout. This module supplies the pure, bit-exact pieces
//! the [`super::Trainer`] composes at a replan:
//!
//! 1. [`merge_pool_to_base`] — invert the current co-permutation (host
//!    mirror of the `merge_M_m` artifact; pure index gathers, so frozen
//!    weights round-trip bit-identically),
//! 2. [`unit_scores`] — weight-magnitude scores in base layout,
//! 3. [`build_pool`] — re-apply the trainable-first co-permutation at the
//!    *new* selection (host mirror of the `prepare_M_m_BxT` artifact's
//!    permute/split step, minus the selection itself),
//! 4. [`remap_unit_moments`] — carry AdamW moments across the change,
//!    keyed by original unit index: survivors copy their block, dropped
//!    units are discarded, grown units start at zero.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::runtime::native::builtin::{is_mha, is_row_split};
use crate::runtime::{ModelMeta, Tensor};
use crate::sparsity;
use crate::sparsity::strategy::{self, LayerSelections, UnitScores};

fn getf<'a>(pool: &'a HashMap<String, Tensor>, name: &str) -> Result<&'a [f32]> {
    pool.get(name)
        .ok_or_else(|| anyhow!("replan: missing tensor {name:?}"))?
        .as_f32()
}

/// The per-structure unit budget of a projection count map: trainable
/// heads (first MHA projection present) and FFN channels (first FFN
/// projection present); 0 = that structure is unbudgeted.
pub(super) fn structure_counts(counts: &HashMap<String, usize>) -> (usize, usize) {
    let pick = |projs: &[&str]| projs.iter().find_map(|p| counts.get(*p)).copied().unwrap_or(0);
    (pick(&["wq", "wk", "wv", "wo"]), pick(&["wu", "wg", "wd"]))
}

/// Weight-magnitude unit scores over base-layout params (the same
/// formulas the static "w" selection and the gradnorm probe use).
pub(super) fn unit_scores(mm: &ModelMeta, base: &HashMap<String, Tensor>) -> Result<UnitScores> {
    let d = mm.dims.d_model;
    let hd = mm.head_dim();
    let ff = mm.dims.d_ff;
    let mut head_mag = Vec::with_capacity(mm.dims.n_layers);
    let mut chan_mag = Vec::with_capacity(mm.dims.n_layers);
    for i in 0..mm.dims.n_layers {
        let wo = getf(base, &format!("L{i}.wo"))?;
        head_mag.push(strategy::head_unit_scores(wo, d, hd, mm.dims.n_heads));
        let wu = getf(base, &format!("L{i}.wu"))?;
        let wg = getf(base, &format!("L{i}.wg"))?;
        let wd = getf(base, &format!("L{i}.wd"))?;
        chan_mag.push(strategy::chan_unit_scores(wu, wg, wd, d, ff));
    }
    Ok(UnitScores { head_mag, chan_mag, head_grad: None, chan_grad: None })
}

/// Split an `[n_layers, units]` score tensor (gradnorm probe output) into
/// per-layer rows.
pub(super) fn score_rows(t: &Tensor) -> Result<Vec<Vec<f32>>> {
    if t.shape.len() != 2 {
        bail!("replan: score tensor must be 2-d, got {:?}", t.shape);
    }
    let (l, n) = (t.shape[0], t.shape[1]);
    let v = t.as_f32()?;
    Ok((0..l).map(|i| v[i * n..(i + 1) * n].to_vec()).collect())
}

/// Reject selections the method layout cannot represent: wrong layer
/// count, selections for an unbudgeted structure, or a trainable count of
/// 0 or the full unit total (either would make a `_t`/`_f` split tensor
/// zero-sized, which the tensor layer cannot represent).
pub(super) fn validate_selections(
    mm: &ModelMeta,
    mha_budgeted: bool,
    ffn_budgeted: bool,
    sels: &LayerSelections,
) -> Result<()> {
    if sels.len() != mm.dims.n_layers {
        bail!("replan: {} layer selections for {} layers", sels.len(), mm.dims.n_layers);
    }
    for (i, s) in sels.iter().enumerate() {
        if mha_budgeted {
            if s.heads.is_empty() || s.heads.len() >= mm.dims.n_heads {
                bail!(
                    "replan: layer {i} selects {} of {} heads; need 1..={} \
                     (an empty trainable or frozen split is unrepresentable)",
                    s.heads.len(),
                    mm.dims.n_heads,
                    mm.dims.n_heads - 1
                );
            }
        } else if !s.heads.is_empty() {
            bail!("replan: layer {i} selects heads but the method budgets no MHA units");
        }
        if ffn_budgeted {
            if s.channels.is_empty() || s.channels.len() >= mm.dims.d_ff {
                bail!(
                    "replan: layer {i} selects {} of {} channels; need 1..={}",
                    s.channels.len(),
                    mm.dims.d_ff,
                    mm.dims.d_ff - 1
                );
            }
        } else if !s.channels.is_empty() {
            bail!("replan: layer {i} selects channels but the method budgets no FFN units");
        }
    }
    Ok(())
}

/// Per-layer projection→unit-count maps for a selection (the shape of
/// budget `Executor::load_train_variant` consumes). `base_counts` names
/// the budgeted projections; the counts come from the selection.
pub(super) fn counts_per_layer(
    base_counts: &HashMap<String, usize>,
    sels: &LayerSelections,
) -> Vec<HashMap<String, usize>> {
    sels.iter()
        .map(|s| {
            base_counts
                .keys()
                .map(|p| {
                    let c = if is_mha(p) { s.heads.len() } else { s.channels.len() };
                    (p.clone(), c)
                })
                .collect()
        })
        .collect()
}

/// Invert the current co-permutation and reassemble base-layout weights
/// from a trainer pool — the host mirror of the `merge_M_m` artifact
/// (same pure gathers, bit-identical output), but driven off pool keys so
/// it works for any layout variant the replanner has committed.
pub(super) fn merge_pool_to_base(
    mm: &ModelMeta,
    pool: &HashMap<String, Tensor>,
    perms: &HashMap<String, Tensor>,
) -> Result<HashMap<String, Tensor>> {
    let hd = mm.head_dim();
    let mut out = HashMap::new();
    for s in &mm.base_params {
        if let Some(t) = pool.get(&s.name) {
            out.insert(s.name.clone(), t.clone());
        }
    }
    let unsplit = |name: &str, proj: &str| -> Result<Tensor> {
        let t_name = format!("{name}_t");
        if !pool.contains_key(&t_name) {
            return pool
                .get(name)
                .cloned()
                .ok_or_else(|| anyhow!("replan: missing tensor {name:?}"));
        }
        let tt = &pool[&t_name];
        let ft = pool
            .get(&format!("{name}_f"))
            .ok_or_else(|| anyhow!("replan: missing tensor {name}_f"))?;
        if is_row_split(proj) {
            let cols = tt.shape[1];
            let mut buf = tt.as_f32()?.to_vec();
            buf.extend_from_slice(ft.as_f32()?);
            Ok(Tensor::f32(vec![tt.shape[0] + ft.shape[0], cols], buf))
        } else {
            let rows = tt.shape[0];
            let (ct, cf) = (tt.shape[1], ft.shape[1]);
            let (tv, fv) = (tt.as_f32()?, ft.as_f32()?);
            let mut buf = Vec::with_capacity(rows * (ct + cf));
            for r in 0..rows {
                buf.extend_from_slice(&tv[r * ct..(r + 1) * ct]);
                buf.extend_from_slice(&fv[r * cf..(r + 1) * cf]);
            }
            Ok(Tensor::f32(vec![rows, ct + cf], buf))
        }
    };
    for i in 0..mm.dims.n_layers {
        if let Some(hp) = perms.get(&format!("L{i}.head_perm")) {
            let hperm: Vec<usize> = hp.as_i32()?.iter().map(|&x| x as usize).collect();
            let inv = sparsity::invert_permutation(&sparsity::expand_head_perm(&hperm, hd));
            for p in ["wq", "wk", "wv", "wo"] {
                let name = format!("L{i}.{p}");
                let w = unsplit(&name, p)?;
                let (rows, cols) = (w.shape[0], w.shape[1]);
                let data = if is_row_split(p) {
                    sparsity::gather_rows(w.as_f32()?, cols, &inv)
                } else {
                    sparsity::gather_cols(w.as_f32()?, rows, cols, &inv)
                };
                out.insert(name, Tensor::f32(vec![rows, cols], data));
            }
        }
        if let Some(cp) = perms.get(&format!("L{i}.chan_perm")) {
            let cperm: Vec<usize> = cp.as_i32()?.iter().map(|&x| x as usize).collect();
            let inv = sparsity::invert_permutation(&cperm);
            for p in ["wu", "wg", "wd"] {
                let name = format!("L{i}.{p}");
                let w = unsplit(&name, p)?;
                let (rows, cols) = (w.shape[0], w.shape[1]);
                let data = if is_row_split(p) {
                    sparsity::gather_rows(w.as_f32()?, cols, &inv)
                } else {
                    sparsity::gather_cols(w.as_f32()?, rows, cols, &inv)
                };
                out.insert(name, Tensor::f32(vec![rows, cols], data));
            }
        }
    }
    for s in &mm.base_params {
        if !out.contains_key(&s.name) {
            bail!("replan: could not reassemble {:?}", s.name);
        }
    }
    Ok(out)
}

/// Apply the trainable-first co-permutation at an explicit selection and
/// split the budgeted projections — the host mirror of the prepare
/// artifact's permute/split step (identical gathers and slicing, so for
/// the same selection the result is bit-identical to `prepare`'s).
/// Returns (weight pool with `_t`/`_f` splits, perm tensors).
pub(super) fn build_pool(
    mm: &ModelMeta,
    base_counts: &HashMap<String, usize>,
    sels: &LayerSelections,
    base: &HashMap<String, Tensor>,
) -> Result<(HashMap<String, Tensor>, HashMap<String, Tensor>)> {
    let d = mm.dims.d_model;
    let hd = mm.head_dim();
    let ff = mm.dims.d_ff;
    let mut staged: HashMap<String, Tensor> = HashMap::new();
    for s in &mm.base_params {
        staged.insert(
            s.name.clone(),
            base.get(&s.name)
                .ok_or_else(|| anyhow!("replan: missing base param {:?}", s.name))?
                .clone(),
        );
    }
    let mut perms = HashMap::new();
    for (i, sel) in sels.iter().enumerate().take(mm.dims.n_layers) {
        if !sel.heads.is_empty() {
            let hperm = sparsity::trainable_first_permutation(&sel.heads, mm.dims.n_heads)?;
            let eperm = sparsity::expand_head_perm(&hperm, hd);
            for p in ["wq", "wk", "wv"] {
                let w = getf(base, &format!("L{i}.{p}"))?;
                staged.insert(
                    format!("L{i}.{p}"),
                    Tensor::f32(vec![d, d], sparsity::gather_cols(w, d, d, &eperm)),
                );
            }
            let wo = getf(base, &format!("L{i}.wo"))?;
            staged.insert(
                format!("L{i}.wo"),
                Tensor::f32(vec![d, d], sparsity::gather_rows(wo, d, &eperm)),
            );
            perms.insert(
                format!("L{i}.head_perm"),
                Tensor::i32(vec![mm.dims.n_heads], hperm.iter().map(|&x| x as i32).collect()),
            );
        }
        if !sel.channels.is_empty() {
            let cperm = sparsity::trainable_first_permutation(&sel.channels, ff)?;
            let wu = getf(base, &format!("L{i}.wu"))?;
            let wg = getf(base, &format!("L{i}.wg"))?;
            let wd = getf(base, &format!("L{i}.wd"))?;
            staged.insert(
                format!("L{i}.wu"),
                Tensor::f32(vec![d, ff], sparsity::gather_cols(wu, d, ff, &cperm)),
            );
            staged.insert(
                format!("L{i}.wg"),
                Tensor::f32(vec![d, ff], sparsity::gather_cols(wg, d, ff, &cperm)),
            );
            staged.insert(
                format!("L{i}.wd"),
                Tensor::f32(vec![ff, d], sparsity::gather_rows(wd, d, &cperm)),
            );
            perms.insert(
                format!("L{i}.chan_perm"),
                Tensor::i32(vec![ff], cperm.iter().map(|&x| x as i32).collect()),
            );
        }
        for p in base_counts.keys() {
            let c = if is_mha(p) { sel.heads.len() } else { sel.channels.len() };
            if c == 0 {
                continue;
            }
            let name = format!("L{i}.{p}");
            let w = staged
                .remove(&name)
                .ok_or_else(|| anyhow!("replan: missing staged {name:?}"))?;
            let rows = if is_mha(p) { c * hd } else { c };
            let (din, dout) = (w.shape[0], w.shape[1]);
            let wv = w.as_f32()?;
            if is_row_split(p) {
                staged.insert(
                    format!("{name}_t"),
                    Tensor::f32(vec![rows, dout], wv[..rows * dout].to_vec()),
                );
                staged.insert(
                    format!("{name}_f"),
                    Tensor::f32(vec![din - rows, dout], wv[rows * dout..].to_vec()),
                );
            } else {
                let all: Vec<usize> = (0..dout).collect();
                let tv = sparsity::gather_cols(wv, din, dout, &all[..rows]);
                let fv = sparsity::gather_cols(wv, din, dout, &all[rows..]);
                staged.insert(format!("{name}_t"), Tensor::f32(vec![din, rows], tv));
                staged.insert(format!("{name}_f"), Tensor::f32(vec![din, dout - rows], fv));
            }
        }
    }
    Ok((staged, perms))
}

/// Carry one optimizer-moment tensor across a selection change. Units are
/// keyed by *original* unit index: a unit in both selections copies its
/// block from its old slot (wherever the permutation had placed it),
/// dropped units' blocks are discarded, grown units start at zero.
/// `block` is the per-unit extent along the split axis (head_dim for head
/// units, 1 for channels); `dim` the other axis; `row_split` picks which
/// axis the units live on.
pub(super) fn remap_unit_moments(
    old_sel: &[usize],
    new_sel: &[usize],
    block: usize,
    dim: usize,
    row_split: bool,
    old: &[f32],
) -> Vec<f32> {
    let pos: HashMap<usize, usize> = old_sel.iter().enumerate().map(|(k, &u)| (u, k)).collect();
    if row_split {
        let stride = block * dim;
        let mut out = vec![0.0f32; new_sel.len() * stride];
        for (kn, u) in new_sel.iter().enumerate() {
            if let Some(&ko) = pos.get(u) {
                out[kn * stride..(kn + 1) * stride]
                    .copy_from_slice(&old[ko * stride..(ko + 1) * stride]);
            }
        }
        out
    } else {
        let (co, cn) = (old_sel.len() * block, new_sel.len() * block);
        let mut out = vec![0.0f32; dim * cn];
        for (kn, u) in new_sel.iter().enumerate() {
            if let Some(&ko) = pos.get(u) {
                for r in 0..dim {
                    out[r * cn + kn * block..r * cn + (kn + 1) * block]
                        .copy_from_slice(&old[r * co + ko * block..r * co + (ko + 1) * block]);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::builtin::builtin_meta;
    use crate::sparsity::strategy::LayerSelection;
    use crate::util::rng::Rng;

    fn random_base(mm: &ModelMeta, seed: u64) -> HashMap<String, Tensor> {
        let mut rng = Rng::seed(seed);
        mm.base_params
            .iter()
            .map(|s| {
                let data: Vec<f32> = (0..s.numel()).map(|_| rng.normal_f32()).collect();
                (s.name.clone(), Tensor::f32(s.shape.clone(), data))
            })
            .collect()
    }

    #[test]
    fn build_then_merge_roundtrips_bitwise() {
        let meta = builtin_meta();
        let mm = &meta.models["tiny"];
        let base = random_base(mm, 11);
        let counts: HashMap<String, usize> =
            [("wo".to_string(), 2), ("wd".to_string(), 5)].into_iter().collect();
        let sels: LayerSelections = (0..mm.dims.n_layers)
            .map(|i| LayerSelection {
                heads: vec![(i + 1) % mm.dims.n_heads, (i + 3) % mm.dims.n_heads],
                channels: vec![0, 7, 3, 11, 40],
            })
            .collect();
        let (pool, perms) = build_pool(mm, &counts, &sels, &base).unwrap();
        assert!(pool.contains_key("L0.wo_t"));
        assert_eq!(pool["L0.wo_t"].shape, vec![2 * mm.head_dim(), mm.dims.d_model]);
        assert_eq!(pool["L1.wd_t"].shape, vec![5, mm.dims.d_model]);
        let merged = merge_pool_to_base(mm, &pool, &perms).unwrap();
        for s in &mm.base_params {
            let a = base[&s.name].as_f32().unwrap();
            let b = merged[&s.name].as_f32().unwrap();
            let (ab, bb): (Vec<u32>, Vec<u32>) = (
                a.iter().map(|x| x.to_bits()).collect(),
                b.iter().map(|x| x.to_bits()).collect(),
            );
            assert_eq!(ab, bb, "{} did not round-trip", s.name);
        }
    }

    #[test]
    fn moment_remap_keys_by_original_unit() {
        // old selection [4, 1], new [1, 6]: unit 1 survives (old slot 1 ->
        // new slot 0), unit 4 is dropped, unit 6 grows in at zero.
        let old = vec![
            1.0, 2.0, // unit 4's row
            3.0, 4.0, // unit 1's row
        ];
        let out = remap_unit_moments(&[4, 1], &[1, 6], 1, 2, true, &old);
        assert_eq!(out, vec![3.0, 4.0, 0.0, 0.0]);
        // column-split layout, block 2: unit blocks move whole
        let old_c = vec![
            10.0, 11.0, 20.0, 21.0, // row 0: unit 4 cols, unit 1 cols
            12.0, 13.0, 22.0, 23.0, // row 1
        ];
        let out_c = remap_unit_moments(&[4, 1], &[1, 6], 2, 2, false, &old_c);
        assert_eq!(out_c, vec![20.0, 21.0, 0.0, 0.0, 22.0, 23.0, 0.0, 0.0]);
    }

    #[test]
    fn validation_rejects_unrepresentable_selections() {
        let meta = builtin_meta();
        let mm = &meta.models["tiny"];
        let full: LayerSelections = (0..mm.dims.n_layers)
            .map(|_| LayerSelection {
                heads: (0..mm.dims.n_heads).collect(),
                channels: vec![1],
            })
            .collect();
        assert!(validate_selections(mm, true, true, &full).is_err());
        let empty: LayerSelections = (0..mm.dims.n_layers)
            .map(|_| LayerSelection { heads: vec![], channels: vec![1] })
            .collect();
        assert!(validate_selections(mm, true, true, &empty).is_err());
        let ok: LayerSelections = (0..mm.dims.n_layers)
            .map(|_| LayerSelection { heads: vec![2], channels: vec![1, 5] })
            .collect();
        assert!(validate_selections(mm, true, true, &ok).is_ok());
        assert!(validate_selections(mm, false, true, &ok).is_err());
    }

    #[test]
    fn counts_follow_selection_sizes() {
        let counts: HashMap<String, usize> =
            [("wo".to_string(), 2), ("wd".to_string(), 5)].into_iter().collect();
        let sels = vec![
            LayerSelection { heads: vec![1], channels: vec![2, 3] },
            LayerSelection { heads: vec![0, 2, 3], channels: vec![4] },
        ];
        let per = counts_per_layer(&counts, &sels);
        assert_eq!(per[0]["wo"], 1);
        assert_eq!(per[0]["wd"], 2);
        assert_eq!(per[1]["wo"], 3);
        assert_eq!(per[1]["wd"], 1);
        let (mha, ffn) = structure_counts(&counts);
        assert_eq!((mha, ffn), (2, 5));
    }
}
