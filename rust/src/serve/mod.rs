//! Multi-adapter serving (paper §6.2, S-LoRA-style scenario).
//!
//! Public API: [`Engine`] — an N-worker pool over one shared
//! [`AdapterRegistry`]. Requests ([`GenRequest`]) carry per-request
//! sampling parameters and stream their tokens back as [`GenEvent`]s
//! over a [`ReplyStream`]; the batcher groups requests by adapter id
//! (adapter-affinity) so each worker iteration pays at most one adapter
//! switch — the scatter_add fast path S²FT makes cheap.
//!
//! The registry scales that lifecycle to thousands of registered
//! adapters: a bounded resident set with LRU spill to disk, lazy reload
//! on demand, and a per-adapter traffic EWMA that decides whether a
//! batch fuses its adapter into the worker weights (hot) or applies it
//! unfused at decode time (cold). See [`residency`]'s docs for the full
//! model.
//!
//! When the backend provides a paged decode session (native), workers
//! run **continuous batching**: requests join and leave the running
//! batch between individual decode steps, with K/V cache space drawn
//! from a shared block-paged pool ([`kvpool`]) instead of private
//! per-request buffers. Backends without one (PJRT artifact replay)
//! fall back to wave scheduling over full-sequence recompute. Either
//! way, generation is O(t) per token on the native path and Python
//! never appears anywhere. See `docs/serving.md` for the architecture
//! walk-through.

mod batcher;
mod engine;
/// Fixed-size-block paged KV-cache pool backing continuous batching.
pub mod kvpool;
mod metrics;
/// Bounded adapter residency: LRU spill, lazy load, traffic-driven
/// fuse policy.
pub mod residency;

pub use batcher::{AdapterBatcher, BatchPlan, Queued, SchedPolicy};
pub use engine::{
    Engine, EngineConfig, GenEvent, GenReply, GenRequest, ReplyStream, SamplingParams,
    BASE_ADAPTER,
};
pub use kvpool::{KvPool, KvPoolConfig, PoolExhausted, PoolUsage};
pub use metrics::{KvPoolGauge, ServeMetrics};
pub use residency::{
    AdapterLease, AdapterRegistry, AdapterTraffic, FusePolicy, ResidencyConfig, ResidencyStats,
    ADAPTER_EXT,
};

use anyhow::Result;

use crate::adapter::{AnyAdapter, S2ftAdapter, S2ftLayerDelta};
use crate::runtime::{open_backend_named, Executable, Executor, ModelMeta, Tensor};
use crate::train::GenModel;
use crate::util::rng::Rng;

/// `repro serve` options.
#[derive(Debug, Clone)]
pub struct DemoOpts {
    pub artifacts: String,
    /// `native` | `pjrt` | `auto` (same semantics as the other commands).
    pub backend: String,
    pub model: String,
    pub weights: Option<String>,
    pub adapters: usize,
    pub requests: usize,
    pub max_batch: usize,
    pub workers: usize,
    /// Resident-adapter budget (`0` = keep everything in memory); see
    /// `EngineConfig::max_resident`.
    pub max_resident: usize,
    /// Adapter preload/spill directory; see `EngineConfig::adapter_dir`.
    pub adapter_dir: Option<String>,
    /// Print the first request's tokens as they stream in.
    pub stream: bool,
}

/// Synthesize a random S²FT adapter matching `mm`'s geometry (one head +
/// ~3% of FFN channels per layer).
pub fn synthetic_adapter(mm: &ModelMeta, rng: &mut Rng) -> AnyAdapter {
    let (d, k, hd) = (mm.dims.d_model, mm.dims.d_ff, mm.head_dim());
    let layers = (0..mm.dims.n_layers)
        .map(|_| {
            let heads = rng.choose(mm.dims.n_heads, 1);
            let wo_rows = crate::sparsity::expand_head_perm(&heads, hd);
            let chans = rng.choose(k, (k / 32).max(1));
            S2ftLayerDelta {
                wo_delta: (0..wo_rows.len() * d).map(|_| rng.normal_f32() * 1e-3).collect(),
                wo_rows,
                wd_delta: (0..chans.len() * d).map(|_| rng.normal_f32() * 1e-3).collect(),
                wd_rows: chans,
            }
        })
        .collect();
    AnyAdapter::S2ft(S2ftAdapter { layers, d_model: d })
}

/// Self-contained multi-adapter serving demo (`repro serve`).
///
/// Spins an [`Engine`] pool, registers `adapters` synthetic S²FT
/// adapters at runtime, demonstrates fuse-mode by combining the first
/// two, and fires `requests` prompts round-robin across the adapters.
/// Reports throughput, latency percentiles, switch count and cost,
/// tokens streamed, adapter memory and registry residency counters.
pub fn demo(opts: DemoOpts) -> Result<()> {
    let mut cfg = EngineConfig::new()
        .workers(opts.workers)
        .max_batch(opts.max_batch)
        .window(std::time::Duration::from_millis(3))
        .max_resident(opts.max_resident);
    if let Some(dir) = &opts.adapter_dir {
        cfg = cfg.adapter_dir(dir);
    }
    let artifacts = opts.artifacts.clone();
    let backend = opts.backend.clone();
    let model_name = opts.model.clone();
    let weights = opts.weights.clone();
    let engine = Engine::spawn(cfg, move |wid| {
        let rt = open_backend_named(&backend, &artifacts)?;
        let params = match &weights {
            Some(dir) => crate::train::load_params(dir)?,
            None => {
                let init = rt.load(&format!("init_{model_name}"))?;
                let outs = init.run(&[Tensor::scalar_i32(9)])?;
                init.spec()
                    .outputs
                    .iter()
                    .map(|s| s.name.clone())
                    .zip(outs)
                    .collect()
            }
        };
        let snapshot = params.clone();
        let gm = GenModel::new(rt.as_ref(), &model_name, params)?;
        if wid == 0 {
            println!(
                "worker 0 up: model {model_name}, decode path = {}",
                if gm.has_decoder() { "kv-cached" } else { "full recompute" }
            );
        }
        Ok((gm, snapshot))
    });

    // runtime adapter lifecycle: register while the pool is already up
    let rt = open_backend_named(&opts.backend, &opts.artifacts)?;
    let mm = rt.artifacts().model(&opts.model)?.clone();
    let mut rng = Rng::seed(0x5EE);
    for a in 0..opts.adapters {
        engine.register(format!("adapter{a}"), synthetic_adapter(&mm, &mut rng));
    }
    if opts.adapters >= 2 {
        // fuse-mode: a merged adapter is just another registry entry
        engine.fuse("fused01", &[("adapter0", 0.5), ("adapter1", 0.5)])?;
    }
    let base_bytes: usize = 4 * mm.param_count;
    println!(
        "engine up: {} workers, {} adapters registered / {} resident ({:.1} KB resident, vs \
         {:.1} MB base weights/worker)",
        engine.workers(),
        engine.registry().len(),
        engine.store().len(),
        engine.store().total_bytes() as f64 / 1e3,
        base_bytes as f64 / 1e6
    );

    let world = crate::data::World::canonical();
    let mut prng = Rng::seed(0xDEE);
    let started = std::time::Instant::now();
    let mut streams = Vec::with_capacity(opts.requests);
    for i in 0..opts.requests {
        let task = &crate::data::COMMONSENSE[prng.below(8)];
        let ex = task.sample(&world, &mut prng, crate::data::Split::Test);
        let adapter = if opts.adapters == 0 {
            BASE_ADAPTER.to_string()
        } else if opts.adapters >= 2 && i % 8 == 7 {
            "fused01".to_string()
        } else {
            format!("adapter{}", i % opts.adapters)
        };
        let req = GenRequest::new(adapter, ex.prompt).max_new(8).seed(i as u64);
        if i == 0 && opts.stream {
            // stream the first request token-by-token
            let mut stream = engine.submit(req);
            print!("streamed reply: ");
            let mut reply = None;
            for ev in &mut stream {
                match ev {
                    GenEvent::Token { text, .. } => print!("{text}"),
                    GenEvent::Done(r) => reply = Some(r),
                    GenEvent::Error(e) => println!(" <error: {e}>"),
                }
            }
            if let Some(r) = reply {
                println!(
                    "  ({} tokens in {:.0} ms on worker {})",
                    r.tokens,
                    r.latency.as_secs_f64() * 1e3,
                    r.worker
                );
            }
            continue;
        }
        streams.push(engine.submit(req));
    }
    let mut ok = 0;
    for s in streams {
        if s.wait().is_ok() {
            ok += 1;
        }
    }
    let wall = started.elapsed();
    let m = engine.metrics();
    let served = m.requests;
    println!(
        "served {served}/{} requests ({ok} awaited) in {:.2}s ({:.1} req/s, {:.0} tok/s streamed)",
        opts.requests,
        wall.as_secs_f64(),
        served as f64 / wall.as_secs_f64(),
        m.tokens as f64 / wall.as_secs_f64()
    );
    println!(
        "batches {} (mean size {:.1}), adapter switches {} (mean {:.1} us), latency p50 \
         {:.0} ms / p99 {:.0} ms",
        m.batches,
        m.mean_batch_size(),
        m.switches,
        m.mean_switch_us(),
        m.percentile_ms(0.5),
        m.percentile_ms(0.99)
    );
    let r = &m.residency;
    println!(
        "residency: {} registered / {} resident, hit rate {:.2} ({} load(s), {} spill(s)), \
         batches {} fused / {} unfused",
        r.registered,
        r.resident,
        r.hit_rate(),
        r.loads,
        r.spills,
        r.fused_batches,
        r.unfused_batches
    );
    if m.kv_capacity_bytes() > 0 {
        println!(
            "kv pool: {:.1} KB peak of {:.1} KB capacity across workers, {} eviction(s)",
            m.kv_peak_bytes() as f64 / 1e3,
            m.kv_capacity_bytes() as f64 / 1e3,
            m.evictions
        );
    }
    engine.shutdown()
}
