"""Channel / head selection strategies for the S2FT family (Sec. 3.2, D).

Five strategies, each choosing which FFN channels (rows of wd) and MHA
heads (row blocks of wo) become trainable:

  r : S2FT-R  — uniform random (the paper's default / fair baseline)
  w : S2FT-W  — by weight magnitude  ||W_c||_2
  a : S2FT-A  — by activation magnitude ||A_c||_2 on a calibration batch
  s : S2FT-S  — by ||W_c||_2 * ||A_c||_2
  g : S2FT-G  — by gradient magnitude ||G_c||_2 on a calibration batch

``select_small=True`` picks the smallest-scoring units (the paper finds
smallest-activation channels hold the least task-specific knowledge and are
the best place to inject new skills — Table 4).

Scores are computed with jnp so the whole selection can run inside the AOT
``prepare`` executable when a calibration batch is an input; for random
selection we thread an explicit seed.
"""

from typing import Dict, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp


def topk_indices(scores: jnp.ndarray, s: int, smallest: bool) -> jnp.ndarray:
    """Indices of the s largest (or smallest) scores, ascending order."""
    key = -scores if not smallest else scores
    idx = jnp.argsort(key)[:s]
    return jnp.sort(idx).astype(jnp.int32)


def random_indices(rng: np.random.Generator, total: int, s: int) -> np.ndarray:
    return np.sort(rng.choice(total, size=s, replace=False)).astype(np.int32)


# --- score functions -------------------------------------------------------


def weight_score_ffn(wu, wg, wd) -> jnp.ndarray:
    """Per-channel weight magnitude across the coupled FFN structure."""
    return (
        jnp.linalg.norm(wu, axis=0)
        + jnp.linalg.norm(wg, axis=0)
        + jnp.linalg.norm(wd, axis=1)
    )


def weight_score_heads(wo, n_heads: int) -> jnp.ndarray:
    d = wo.shape[0]
    return jnp.linalg.norm(wo.reshape(n_heads, d // n_heads * wo.shape[1]), axis=1)


def activation_score(acts: jnp.ndarray) -> jnp.ndarray:
    """||A_c||_2 per channel; acts: (..., channels) calibration activations."""
    flat = acts.reshape(-1, acts.shape[-1])
    return jnp.linalg.norm(flat, axis=0)


def head_score_from_channels(chan_scores: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    return chan_scores.reshape(n_heads, -1).sum(axis=1)


def gradient_score(grad: jnp.ndarray, axis: int) -> jnp.ndarray:
    """||G_c||_2 per channel of a weight gradient (Galore-style: computed
    layerwise on the calibration batch and immediately discarded)."""
    other = tuple(i for i in range(grad.ndim) if i != axis)
    return jnp.sqrt((grad**2).sum(axis=other))


# --- end-to-end selection --------------------------------------------------


def select_ffn_channels(
    strategy: str,
    smallest: bool,
    s: int,
    wu,
    wg,
    wd,
    acts=None,
    grad_wd=None,
    rng: np.random.Generator | None = None,
) -> np.ndarray:
    """Pick s FFN channels of one layer according to ``strategy``."""
    k = wd.shape[0]
    if s >= k:
        return np.arange(k, dtype=np.int32)
    if strategy == "r":
        assert rng is not None
        return random_indices(rng, k, s)
    if strategy == "w":
        score = weight_score_ffn(wu, wg, wd)
    elif strategy == "a":
        assert acts is not None, "S2FT-A needs calibration activations"
        score = activation_score(acts)
    elif strategy == "s":
        assert acts is not None
        score = weight_score_ffn(wu, wg, wd) * activation_score(acts)
    elif strategy == "g":
        assert grad_wd is not None, "S2FT-G needs calibration gradients"
        score = gradient_score(grad_wd, axis=0)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return np.asarray(topk_indices(score, s, smallest))


def select_mha_heads(
    strategy: str,
    smallest: bool,
    s_heads: int,
    wo,
    n_heads: int,
    acts=None,
    grad_wo=None,
    rng: np.random.Generator | None = None,
) -> np.ndarray:
    """Pick s_heads attention heads of one layer according to ``strategy``."""
    if s_heads >= n_heads:
        return np.arange(n_heads, dtype=np.int32)
    if strategy == "r":
        assert rng is not None
        return random_indices(rng, n_heads, s_heads)
    if strategy == "w":
        score = weight_score_heads(wo, n_heads)
    elif strategy in ("a", "s"):
        assert acts is not None
        score = head_score_from_channels(activation_score(acts), n_heads)
        if strategy == "s":
            score = score * weight_score_heads(wo, n_heads)
    elif strategy == "g":
        assert grad_wo is not None
        score = head_score_from_channels(gradient_score(grad_wo, axis=0), n_heads)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return np.asarray(topk_indices(score, s_heads, smallest))


def budget_to_counts(fractions: Dict[str, float], d_ff: int, n_heads: int) -> Dict[str, int]:
    """Convert per-projection fractions into integer unit counts.

    wo budget is in heads (rounded, >=1 if fraction > 0); wd/wu/wg budgets
    are in channels; wq/wk/wv select heads like wo (used by the Fig 4
    component ablation).
    """
    counts = {}
    for proj, f in fractions.items():
        if proj in ("wo", "wq", "wk", "wv"):
            counts[proj] = max(1, round(f * n_heads)) if f > 0 else 0
        elif proj in ("wd", "wu", "wg"):
            counts[proj] = max(1, round(f * d_ff)) if f > 0 else 0
        else:
            raise ValueError(f"unknown projection {proj!r}")
    return counts
