//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! The interchange contract with the python build layer (`aot.py`):
//! HLO *text* files plus `meta.json` describing every artifact's exact
//! input/output tensor order, shapes and dtypes. This module is the only
//! place that touches the `xla` crate.

mod meta;
mod tensor;

pub use meta::{ArtifactMeta, Meta, MethodMeta, ModelMeta, NamedShape, TensorSpec};
pub use tensor::{Tensor, TensorData};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

/// Handle to the artifact directory + parsed meta.json (no PJRT needed).
#[derive(Clone)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub meta: Arc<Meta>,
}

impl Artifacts {
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?}; run `make artifacts`"))?;
        let meta = Meta::parse(&text)?;
        Ok(Self { dir, meta: Arc::new(meta) })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.meta
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in meta.json (rebuild artifacts?)"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.meta
            .models
            .get(name)
            .ok_or_else(|| anyhow!("model {name:?} not in meta.json"))
    }
}

/// PJRT CPU client + compiled-executable cache.
///
/// Compilation is lazy and cached per artifact name: experiment harnesses
/// freely re-request executables without paying XLA compile time twice.
pub struct Runtime {
    client: xla::PjRtClient,
    pub artifacts: Artifacts,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let artifacts = Artifacts::open(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(Self { client, artifacts, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by meta.json name.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.artifacts.artifact(name)?.clone();
        let path = self.artifacts.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(xerr)
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(xerr)
            .with_context(|| format!("XLA compile of {name}"))?;
        let exec = Arc::new(Executable { name: name.to_string(), exe, spec });
        self.cache.lock().unwrap().insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    /// Drop a compiled executable (frees XLA memory for big models).
    pub fn evict(&self, name: &str) {
        self.cache.lock().unwrap().remove(name);
    }
}

fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

/// A compiled artifact plus its interface description.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactMeta,
}

impl Executable {
    /// Execute with positional inputs (must match `spec.inputs` order).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape != s.shape {
                bail!(
                    "{}: input {:?} shape {:?} != expected {:?}",
                    self.name, s.name, t.shape, s.shape
                );
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals).map_err(xerr)?;
        let lit = result[0][0].to_literal_sync().map_err(xerr)?;
        // aot.py lowers with return_tuple=True: single tuple output.
        let parts = lit.to_tuple().map_err(xerr)?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        parts.into_iter().map(Tensor::from_literal).collect()
    }

    /// Execute with named inputs pulled from a tensor pool.
    pub fn run_named(
        &self,
        pool: &HashMap<String, Tensor>,
    ) -> Result<HashMap<String, Tensor>> {
        let mut args = Vec::with_capacity(self.spec.inputs.len());
        for s in &self.spec.inputs {
            let t = pool
                .get(&s.name)
                .ok_or_else(|| anyhow!("{}: missing input {:?}", self.name, s.name))?;
            args.push(t.clone());
        }
        let outs = self.run(&args)?;
        Ok(self
            .spec
            .outputs
            .iter()
            .map(|s| s.name.clone())
            .zip(outs)
            .collect())
    }

    /// Total bytes of all inputs (used for memory accounting in Fig 5).
    pub fn input_bytes(&self) -> usize {
        self.spec.inputs.iter().map(|s| s.numel() * 4).sum()
    }

    pub fn output_bytes(&self) -> usize {
        self.spec.outputs.iter().map(|s| s.numel() * 4).sum()
    }
}
