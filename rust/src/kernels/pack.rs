//! Panel packing for the register-tiled GEMM micro-kernel.
//!
//! Every GEMM shape in this module is reduced to the same canonical
//! micro-kernel operand layout before the hot loop runs:
//!
//! * the **A panel** holds one `MR`-row (or `MR`-column, for the
//!   transposed-A shapes) tile of the broadcast operand, laid out
//!   depth-major: `pa[step * MR + r]` is the value row `r` contributes at
//!   reduction step `step`. The micro-kernel reads `MR` consecutive
//!   floats per step and broadcasts each across a vector register.
//! * a **B panel** holds `NR` output columns of the streaming operand,
//!   also depth-major: `pb[step * NR + j]` is the value column `j`
//!   contributes at reduction step `step`. The micro-kernel loads `NR`
//!   consecutive floats per step as two 8-lane vectors.
//!
//! Edge tiles are zero-padded to the full `MR`/`NR` width so the
//! micro-kernel never branches on tile shape; the drivers simply do not
//! copy the padded lanes out. Padding never contaminates real outputs —
//! padded A rows and padded B columns only ever feed accumulator lanes
//! that are discarded.
//!
//! Packing is what makes the inner loop fast *and* keeps it honest: the
//! reduction still walks the depth axis in ascending order, one scalar
//! chain per output element, so the packed kernels stay bit-identical to
//! the naive references in [`super::reference`].

/// Rows per register tile — the broadcast operand width.
pub(crate) const MR: usize = 4;

/// Output columns per register tile — two 8-lane f32 vectors.
pub(crate) const NR: usize = 16;

/// Pack `nrows` rows of row-major `a` (row stride `stride`, starting at
/// `row0`, `depth` values per row) into the depth-major A-panel layout.
/// `buf` must hold `depth * MR` floats; rows past `nrows` are zeroed.
pub(crate) fn pack_a_rows(
    a: &[f32],
    stride: usize,
    row0: usize,
    nrows: usize,
    depth: usize,
    buf: &mut [f32],
) {
    debug_assert!(nrows >= 1 && nrows <= MR, "pack_a_rows: nrows {nrows}");
    debug_assert!(buf.len() >= depth * MR, "pack_a_rows: buf too small");
    if nrows < MR {
        buf[..depth * MR].fill(0.0);
    }
    for r in 0..nrows {
        let arow = &a[(row0 + r) * stride..][..depth];
        for (kk, &v) in arow.iter().enumerate() {
            buf[kk * MR + r] = v;
        }
    }
}

/// Pack `ncols` columns of row-major `a` (row stride `stride`, columns
/// `col0..`, `depth` rows) into the depth-major A-panel layout — the
/// transposed-A (`gemm_tn`/`gemm_tn_outcols`) counterpart of
/// [`pack_a_rows`]. `buf` must hold `depth * MR` floats; columns past
/// `ncols` are zeroed.
pub(crate) fn pack_a_cols(
    a: &[f32],
    stride: usize,
    col0: usize,
    ncols: usize,
    depth: usize,
    buf: &mut [f32],
) {
    debug_assert!(ncols >= 1 && ncols <= MR, "pack_a_cols: ncols {ncols}");
    debug_assert!(buf.len() >= depth * MR, "pack_a_cols: buf too small");
    if ncols < MR {
        buf[..depth * MR].fill(0.0);
    }
    for (r, dst) in buf.chunks_exact_mut(MR).enumerate().take(depth) {
        dst[..ncols].copy_from_slice(&a[r * stride + col0..][..ncols]);
    }
}

/// Pack all `cols` columns of row-major `b` (row stride `stride`,
/// `depth` rows) into consecutive `NR`-wide B panels. Panel `jp` covers
/// output columns `jp * NR ..`, occupies `depth * NR` floats, and is
/// zero-padded on the right edge.
pub(crate) fn pack_b_panels(b: &[f32], stride: usize, cols: usize, depth: usize) -> Vec<f32> {
    debug_assert!(cols >= 1 && depth >= 1, "pack_b_panels: degenerate shape");
    let npanels = cols.div_ceil(NR);
    let mut out = vec![0.0f32; npanels * depth * NR];
    for (jp, panel) in out.chunks_exact_mut(depth * NR).enumerate() {
        let j0 = jp * NR;
        let w = NR.min(cols - j0);
        for (kk, prow) in panel.chunks_exact_mut(NR).enumerate() {
            prow[..w].copy_from_slice(&b[kk * stride + j0..][..w]);
        }
    }
    out
}

/// Pack the transpose of row-major `b (nrows, depth)` into `NR`-wide B
/// panels of `Bᵀ (depth, nrows)` — the [`super::gemm_nt`] packer. Output
/// column `j` of panel `jp` streams row `jp * NR + j` of `b`, so the
/// micro-kernel's ascending-depth walk reproduces the naive row-dot
/// reduction order exactly.
pub(crate) fn pack_bt_panels(b: &[f32], nrows: usize, depth: usize) -> Vec<f32> {
    debug_assert!(nrows >= 1 && depth >= 1, "pack_bt_panels: degenerate shape");
    let npanels = nrows.div_ceil(NR);
    let mut out = vec![0.0f32; npanels * depth * NR];
    for (jp, panel) in out.chunks_exact_mut(depth * NR).enumerate() {
        let j0 = jp * NR;
        let w = NR.min(nrows - j0);
        for j in 0..w {
            let src = &b[(j0 + j) * depth..][..depth];
            for (kk, &v) in src.iter().enumerate() {
                panel[kk * NR + j] = v;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a_rows_depth_major_with_zero_padding() {
        // a = 2x3 row-major; pack both rows into an MR=4 panel
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut buf = vec![7.0f32; 3 * MR];
        pack_a_rows(&a, 3, 0, 2, 3, &mut buf);
        for kk in 0..3 {
            assert_eq!(buf[kk * MR], a[kk], "row 0 step {kk}");
            assert_eq!(buf[kk * MR + 1], a[3 + kk], "row 1 step {kk}");
            assert_eq!(buf[kk * MR + 2], 0.0, "padded row");
            assert_eq!(buf[kk * MR + 3], 0.0, "padded row");
        }
    }

    #[test]
    fn a_cols_match_a_rows_of_transpose() {
        // packing columns of a equals packing rows of aᵀ
        let (rows, cols) = (5usize, 3usize);
        let a: Vec<f32> = (0..rows * cols).map(|i| i as f32).collect();
        let at: Vec<f32> = (0..cols * rows).map(|i| a[(i % rows) * cols + i / rows]).collect();
        let mut by_cols = vec![0.0f32; rows * MR];
        let mut by_rows = vec![0.0f32; rows * MR];
        pack_a_cols(&a, cols, 1, 2, rows, &mut by_cols);
        pack_a_rows(&at, rows, 1, 2, rows, &mut by_rows);
        assert_eq!(by_cols, by_rows);
    }

    #[test]
    fn b_panels_cover_all_columns_padded() {
        let (depth, cols) = (2usize, NR + 3);
        let b: Vec<f32> = (0..depth * cols).map(|i| i as f32 + 1.0).collect();
        let packed = pack_b_panels(&b, cols, cols, depth);
        assert_eq!(packed.len(), 2 * depth * NR);
        for kk in 0..depth {
            for j in 0..cols {
                let (jp, jj) = (j / NR, j % NR);
                assert_eq!(packed[jp * depth * NR + kk * NR + jj], b[kk * cols + j]);
            }
            for jj in 3..NR {
                assert_eq!(packed[depth * NR + kk * NR + jj], 0.0, "right-edge padding");
            }
        }
    }

    #[test]
    fn bt_panels_transpose_b() {
        let (nrows, depth) = (3usize, 4usize);
        let b: Vec<f32> = (0..nrows * depth).map(|i| i as f32).collect();
        let packed = pack_bt_panels(&b, nrows, depth);
        assert_eq!(packed.len(), depth * NR);
        for kk in 0..depth {
            for j in 0..nrows {
                assert_eq!(packed[kk * NR + j], b[j * depth + kk], "bᵀ[{kk}][{j}]");
            }
        }
    }
}
