//! S²FT: Structured Sparse Fine-Tuning — Layer-3 rust coordinator.
//!
//! This crate is the runtime half of a three-layer stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas partial-backprop kernels.
//! * **L2** (`python/compile/model.py`): LLaMA-style model + every
//!   fine-tuning method (fullft/lora/dora/spft/lisa/galore/s2ft), AOT-lowered
//!   to HLO text by `python/compile/aot.py`.
//! * **L3** (this crate): loads the artifacts via PJRT ([`runtime`]), owns
//!   training ([`train`]), data generation ([`data`]), adapter lifecycle
//!   ([`adapter`]), multi-adapter serving ([`serve`]), the deep-linear
//!   theory simulator ([`theory`]) and the paper's experiment harnesses
//!   ([`experiments`]).
//!
//! Python never runs on the request path: `make artifacts` is build-time
//! only, and the `repro` binary is self-contained afterwards.

pub mod adapter;
pub mod config;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod runtime;
pub mod serve;
pub mod sparsity;
pub mod theory;
pub mod train;
pub mod util;

pub use runtime::{Artifacts, Runtime, Tensor};
