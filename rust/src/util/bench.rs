//! Minimal criterion-style bench harness (criterion is not vendored).
//!
//! Each `cargo bench` target is a `harness = false` binary that builds a
//! [`BenchSuite`], registers closures, and calls [`BenchSuite::bench`]. The
//! harness warms up, runs timed batches until a wall budget, and reports
//! median / p10 / p90 per-iteration times plus throughput.
//!
//! CI hooks: `S2FT_BENCH_BUDGET_MS` caps the per-bench wall budget (the
//! `bench-smoke` job sets a short one), [`BenchSuite::save_skipped`]
//! records a machine-readable skip marker instead of silently exiting
//! (so a missing artifact is distinguishable from a lost file), and
//! [`compare_bench`] diffs two result files for the regression gate.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub mean_ns: f64,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("median_ns", Json::num(self.median_ns)),
            ("p10_ns", Json::num(self.p10_ns)),
            ("p90_ns", Json::num(self.p90_ns)),
            ("mean_ns", Json::num(self.mean_ns)),
        ])
    }
}

pub struct BenchSuite {
    pub suite: String,
    warmup: Duration,
    budget: Duration,
    min_iters: usize,
    pub results: Vec<BenchResult>,
}

/// Wall-budget override from the environment (CI smoke runs).
fn env_budget() -> Option<Duration> {
    std::env::var("S2FT_BENCH_BUDGET_MS")
        .ok()?
        .parse::<u64>()
        .ok()
        .map(|ms| Duration::from_millis(ms.max(1)))
}

impl BenchSuite {
    pub fn new(suite: &str) -> Self {
        let mut s = Self {
            suite: suite.to_string(),
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 10,
            results: Vec::new(),
        };
        s.apply_env_budget();
        s
    }

    /// For expensive benchmarks (whole train steps).
    pub fn slow(mut self) -> Self {
        self.warmup = Duration::from_millis(0);
        self.budget = Duration::from_secs(4);
        self.min_iters = 3;
        self.apply_env_budget();
        self
    }

    /// Honor `S2FT_BENCH_BUDGET_MS` (CI smoke budget): cap the timed
    /// budget and shrink the warmup proportionally.
    fn apply_env_budget(&mut self) {
        if let Some(b) = env_budget() {
            self.budget = b;
            self.warmup = self.warmup.min(b / 4);
        }
    }

    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        // Warmup.
        let start = Instant::now();
        f();
        let first = start.elapsed();
        if first < self.warmup {
            let wstart = Instant::now();
            while wstart.elapsed() < self.warmup {
                f();
            }
        }
        // Timed samples.
        let mut samples_ns: Vec<f64> = Vec::new();
        let tstart = Instant::now();
        while (tstart.elapsed() < self.budget || samples_ns.len() < self.min_iters)
            && samples_ns.len() < 10_000
        {
            let s = Instant::now();
            f();
            samples_ns.push(s.elapsed().as_nanos() as f64);
            if first > self.budget && samples_ns.len() >= self.min_iters {
                break; // very slow case: stop at min_iters
            }
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len();
        let pct = |p: f64| samples_ns[((n as f64 - 1.0) * p) as usize];
        let res = BenchResult {
            name: name.to_string(),
            iters: n,
            median_ns: pct(0.5),
            p10_ns: pct(0.1),
            p90_ns: pct(0.9),
            mean_ns: samples_ns.iter().sum::<f64>() / n as f64,
        };
        println!(
            "{:<52} {:>12}  (p10 {:>10}, p90 {:>10}, n={})",
            format!("{}/{}", self.suite, name),
            fmt_ns(res.median_ns),
            fmt_ns(res.p10_ns),
            fmt_ns(res.p90_ns),
            n
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Write results JSON under results/bench_<suite>.json.
    pub fn save(&self) {
        let _ = std::fs::create_dir_all("results");
        let js = Json::Arr(self.results.iter().map(|r| r.to_json()).collect());
        let path = format!("results/bench_{}.json", self.suite);
        if std::fs::write(&path, js.to_string_pretty()).is_ok() {
            println!("saved {path}");
        }
    }

    /// A bench target that cannot run (missing backend/artifacts) must
    /// still leave a machine-readable record, so the CI artifact
    /// distinguishes "skipped" from "lost". Writes
    /// `results/bench_<suite>.json` with a `skipped` reason.
    pub fn save_skipped(suite: &str, reason: &str) {
        let _ = std::fs::create_dir_all("results");
        let js = Json::obj(vec![("suite", Json::str(suite)), ("skipped", Json::str(reason))]);
        let path = format!("results/bench_{suite}.json");
        if std::fs::write(&path, js.to_string_pretty()).is_ok() {
            eprintln!("skipping {suite} bench: {reason} (recorded in {path})");
        } else {
            eprintln!("skipping {suite} bench: {reason} (could not write {path})");
        }
    }
}

// ---------------------------------------------------------------------------
// Baseline comparison (the CI `compare-bench` gate)
// ---------------------------------------------------------------------------

/// One benchmark's current-vs-baseline ratio (`> 1` = slower than base).
#[derive(Debug, Clone)]
pub struct BenchDelta {
    pub name: String,
    pub baseline_ns: f64,
    pub current_ns: f64,
    pub ratio: f64,
}

/// Outcome of diffing a current bench JSON against a committed baseline.
#[derive(Debug, Default)]
pub struct BenchCompare {
    /// The current file is a skip record (reason), not results.
    pub skipped: Option<String>,
    /// Benchmarks present on both sides, with median ratios.
    pub deltas: Vec<BenchDelta>,
    /// Baseline entries missing from the current run.
    pub missing: Vec<String>,
    /// Current entries with no baseline yet.
    pub added: Vec<String>,
}

impl BenchCompare {
    /// Slowest relative entry, if any ran.
    pub fn worst(&self) -> Option<&BenchDelta> {
        self.deltas
            .iter()
            .max_by(|a, b| a.ratio.partial_cmp(&b.ratio).unwrap_or(std::cmp::Ordering::Equal))
    }
}

fn parse_results(j: &Json) -> Result<Vec<(String, f64)>> {
    j.as_arr()?
        .iter()
        .map(|e| Ok((e.get("name")?.as_str()?.to_string(), e.get("median_ns")?.as_f64()?)))
        .collect()
}

/// Diff two bench JSON documents (arrays of [`BenchResult`] objects, or a
/// `{"skipped": ...}` record on the current side). Median-time ratios are
/// matched by benchmark name; order does not matter.
pub fn compare_bench(current: &Json, baseline: &Json) -> Result<BenchCompare> {
    if let Some(reason) = current.opt("skipped") {
        return Ok(BenchCompare {
            skipped: Some(reason.as_str().unwrap_or("unknown").to_string()),
            ..BenchCompare::default()
        });
    }
    if baseline.opt("skipped").is_some() {
        bail!("baseline is a skip record — regenerate it with `make bench-baseline`");
    }
    let cur = parse_results(current)?;
    let base = parse_results(baseline)?;
    let cur_map: std::collections::BTreeMap<&str, f64> =
        cur.iter().map(|(n, m)| (n.as_str(), *m)).collect();
    let base_map: std::collections::BTreeMap<&str, f64> =
        base.iter().map(|(n, m)| (n.as_str(), *m)).collect();
    let mut out = BenchCompare::default();
    for (name, &base_ns) in &base_map {
        match cur_map.get(name) {
            Some(&cur_ns) if base_ns > 0.0 => out.deltas.push(BenchDelta {
                name: name.to_string(),
                baseline_ns: base_ns,
                current_ns: cur_ns,
                ratio: cur_ns / base_ns,
            }),
            Some(_) => {} // degenerate zero baseline: no ratio
            None => out.missing.push(name.to_string()),
        }
    }
    for name in cur_map.keys() {
        if !base_map.contains_key(name) {
            out.added.push(name.to_string());
        }
    }
    Ok(out)
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Opaque value sink preventing the optimizer from deleting benched work.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_samples() {
        let mut s = BenchSuite::new("selftest");
        s.budget = Duration::from_millis(30);
        s.warmup = Duration::from_millis(5);
        let r = s.bench("noop", || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 10);
        assert!(r.median_ns >= 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }

    fn results_json(entries: &[(&str, f64)]) -> Json {
        let rows = entries
            .iter()
            .map(|(n, m)| Json::obj(vec![("name", Json::str(*n)), ("median_ns", Json::num(*m))]))
            .collect();
        Json::Arr(rows)
    }

    #[test]
    fn compare_matches_by_name_and_ratios() {
        let base = results_json(&[("a", 100.0), ("b", 200.0), ("gone", 50.0)]);
        let cur = results_json(&[("b", 500.0), ("a", 100.0), ("new", 10.0)]);
        let cmp = compare_bench(&cur, &base).unwrap();
        assert!(cmp.skipped.is_none());
        assert_eq!(cmp.deltas.len(), 2);
        assert_eq!(cmp.missing, vec!["gone".to_string()]);
        assert_eq!(cmp.added, vec!["new".to_string()]);
        let worst = cmp.worst().unwrap();
        assert_eq!(worst.name, "b");
        assert!((worst.ratio - 2.5).abs() < 1e-9);
    }

    #[test]
    fn compare_detects_skip_records() {
        let cur = Json::obj(vec![
            ("suite", Json::str("fig5_training")),
            ("skipped", Json::str("no artifacts")),
        ]);
        let base = results_json(&[("a", 100.0)]);
        let cmp = compare_bench(&cur, &base).unwrap();
        assert_eq!(cmp.skipped.as_deref(), Some("no artifacts"));
        assert!(cmp.deltas.is_empty());
        // a skip record on the *baseline* side is a configuration error
        assert!(compare_bench(&base, &cur).is_err());
    }

    #[test]
    fn compare_roundtrips_through_serialized_results() {
        let mut s = BenchSuite::new("cmp_roundtrip");
        s.budget = Duration::from_millis(10);
        s.warmup = Duration::from_millis(1);
        s.bench("x", || {
            black_box(2 + 2);
        });
        let js = Json::Arr(s.results.iter().map(|r| r.to_json()).collect());
        let reparsed = Json::parse(&js.to_string_pretty()).unwrap();
        let cmp = compare_bench(&reparsed, &reparsed).unwrap();
        assert_eq!(cmp.deltas.len(), 1);
        assert!((cmp.deltas[0].ratio - 1.0).abs() < 1e-12);
    }
}
