//! S²FT: Structured Sparse Fine-Tuning — Layer-3 rust coordinator.
//!
//! This crate is the runtime half of a three-layer stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas partial-backprop kernels.
//! * **L2** (`python/compile/model.py`): LLaMA-style model + every
//!   fine-tuning method (fullft/lora/dora/spft/lisa/galore/s2ft), AOT-lowered
//!   to HLO text by `python/compile/aot.py`.
//! * **L3** (this crate): executes the model contract through a pluggable
//!   backend ([`runtime::Executor`]), owns training ([`train`]), data
//!   generation ([`data`]), adapter lifecycle ([`adapter`]), multi-adapter
//!   serving ([`serve`]), the deep-linear theory simulator ([`theory`]) and
//!   the paper's experiment harnesses ([`experiments`]).
//!
//! # Execution backends
//!
//! * [`runtime::NativeBackend`] (default): a pure-Rust interpreter of the
//!   contract — seeded init, LLaMA forward/eval, AdamW with S²FT partial
//!   backprop, greedy generation. Fully hermetic: `cargo build && cargo
//!   test` need no Python, no artifacts and no XLA toolchain.
//! * [`runtime::Runtime`] (cargo feature `pjrt`): loads the AOT HLO-text
//!   artifacts via PJRT. `make artifacts` is build-time only, and the
//!   `repro` binary is self-contained afterwards; python never runs on the
//!   request path.
//!
//! Backend selection is a single call — [`runtime::open_backend`] — and
//! everything above the [`runtime`] module is backend-agnostic.
//!
//! # Serving
//!
//! [`serve::Engine`] is the public serving API: an N-worker pool over a
//! shared thread-safe [`adapter::AdapterStore`], streamed token replies
//! ([`serve::ReplyStream`]), per-request sampling, and a runtime adapter
//! lifecycle (register/unregister/fuse/switch while serving — the paper
//! §6.2 decoupled modes). Generation uses the KV-cached incremental
//! decode path ([`runtime::DecodeSession`]) when the backend provides
//! one — O(t) per token, bit-identical to full recompute.
//!
//! # Compute kernels
//!
//! Every dense GEMM — native forward/backward, the linear-algebra
//! substrate, multi-adapter serving — routes through the shared
//! [`kernels`] subsystem: packed register-tiled micro-kernels with
//! runtime SIMD/scalar dispatch (AVX2 when detected; `S2FT_SIMD=0`
//! forces the portable tile), multi-threaded (scoped `std::thread`,
//! sized by `S2FT_THREADS` / `--threads`), and bit-identical across
//! thread counts *and* the dispatch boundary because only the output is
//! ever partitioned — never the reduction axis — and every accumulator
//! lane is one fixed-order scalar chain.
//!
//! Those invariants are machine-checked: the [`analyze`] module (exposed
//! as `repro analyze`) lints the tree for float-literal equality, fused
//! multiply-adds, missing `// SAFETY:` comments, nondeterminism sources
//! in bit-identical modules and bench-lane/baseline drift.

pub mod adapter;
pub mod analyze;
pub mod config;
pub mod data;
pub mod experiments;
pub mod kernels;
pub mod linalg;
pub mod runtime;
pub mod serve;
pub mod sparsity;
pub mod theory;
pub mod train;
pub mod util;

pub use runtime::{
    open_backend, Artifacts, DecodeSession, DecoderProvider, Executable, Executor,
    NativeBackend, Tensor,
};
#[cfg(feature = "pjrt")]
pub use runtime::Runtime;
