"""L1 correctness: Pallas kernels vs pure-jnp oracle (ref.py).

The hypothesis sweep drives shapes/tile sizes; assert_allclose against the
oracle is THE correctness signal for the kernel that every train artifact
embeds (s2ft-pallas variants).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import partial_update as pk
from compile.kernels import ref

RTOL = ATOL = 2e-4


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 97),
    k=st.integers(1, 97),
    n=st.integers(1, 97),
    tile=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, tile, seed):
    rng = np.random.default_rng(seed)
    x, w = rand(rng, m, k), rand(rng, k, n)
    got = pk.matmul(jnp.asarray(x), jnp.asarray(w), tm=tile, tn=tile, tk=tile)
    np.testing.assert_allclose(np.asarray(got), ref.matmul_ref(x, w),
                               rtol=RTOL, atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(2, 96),
    n=st.integers(1, 64),
    frac=st.floats(0.05, 0.95),
    seed=st.integers(0, 2**31 - 1),
)
def test_s2ft_linear_forward(m, k, n, frac, seed):
    rng = np.random.default_rng(seed)
    s = max(1, min(k - 1, int(frac * k)))
    x, w = rand(rng, m, k), rand(rng, k, n)
    wt, wf = jnp.asarray(w[:s]), jnp.asarray(w[s:])
    got = pk.s2ft_linear(jnp.asarray(x), wt, wf)
    want = ref.s2ft_linear_ref(jnp.asarray(x), wt, wf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=RTOL, atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(2, 48),
    k=st.integers(4, 80),
    n=st.integers(2, 48),
    frac=st.floats(0.1, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_s2ft_linear_partial_backprop(m, k, n, frac, seed):
    """The custom VJP computes dx and dw_t exactly (and nothing for w_f)."""
    rng = np.random.default_rng(seed)
    s = max(1, min(k - 1, int(frac * k)))
    x, w = rand(rng, m, k), rand(rng, k, n)
    dy = rand(rng, m, n)
    wt, wf = jnp.asarray(w[:s]), jnp.asarray(w[s:])
    xj = jnp.asarray(x)

    def f(x_, wt_, wf_):
        return (pk.s2ft_linear(x_, wt_, wf_) * jnp.asarray(dy)).sum()

    dx, dwt, dwf = jax.grad(f, argnums=(0, 1, 2))(xj, wt, wf)
    dx_r, dwt_r = ref.s2ft_linear_grads_ref(xj, wt, wf, jnp.asarray(dy))
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_r), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(dwt), np.asarray(dwt_r), rtol=RTOL, atol=ATOL)
    # partial backprop: the frozen slice receives an exactly-zero cotangent
    assert np.all(np.asarray(dwf) == 0.0)


def test_s2ft_linear_nd_shapes():
    rng = np.random.default_rng(0)
    x = rand(rng, 2, 5, 24)
    w = rand(rng, 24, 12)
    out = pk.s2ft_linear_nd(jnp.asarray(x), jnp.asarray(w[:7]), jnp.asarray(w[7:]))
    assert out.shape == (2, 5, 12)
    want = x.reshape(-1, 24) @ w
    np.testing.assert_allclose(np.asarray(out).reshape(-1, 12), want,
                               rtol=RTOL, atol=ATOL)


def test_matmul_rejects_bad_contraction():
    with pytest.raises(AssertionError):
        pk.matmul(jnp.zeros((3, 4)), jnp.zeros((5, 6)))


def test_vmem_estimate_positive_and_mxu_sized():
    # 128x128 f32 tiles: 3 resident + 2 double-buffered < 16MB VMEM
    b = pk.vmem_bytes(128, 128, 128)
    assert 0 < b < 16 * 2**20


def test_matmul_inside_jit():
    """Raw kernel composes with jit (autodiff goes through s2ft_linear's
    custom VJP — the accumulation grid itself is not transposable)."""
    rng = np.random.default_rng(3)
    x, w = rand(rng, 9, 17), rand(rng, 17, 5)

    @jax.jit
    def f(x_, w_):
        return pk.matmul(x_, w_)

    got = f(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), x @ w, rtol=RTOL, atol=ATOL)


def test_grad_via_custom_vjp_inside_jit():
    """jit(grad(s2ft_linear)) — the exact composition aot.py lowers."""
    rng = np.random.default_rng(4)
    x, w = rand(rng, 9, 17), rand(rng, 17, 5)
    wt, wf = jnp.asarray(w[:6]), jnp.asarray(w[6:])

    @jax.jit
    def g(x_, wt_):
        return jax.grad(lambda a, b: pk.s2ft_linear(a, b, wf).sum(),
                        argnums=(0, 1))(x_, wt_)

    dx, dwt = g(jnp.asarray(x), wt)
    np.testing.assert_allclose(np.asarray(dx), np.ones((9, 5)) @ w.T,
                               rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(dwt), x[:, :6].T @ np.ones((9, 5)),
                               rtol=RTOL, atol=ATOL)
