//! KV-cached incremental decode for the native interpreter.
//!
//! [`NativeDecodeSession`] steps the LLaMA-style model one token per row
//! at a time: each step embeds the new tokens, runs the per-layer
//! projections at batch size = #active rows, appends rotated K / V to
//! per-row caches and attends them through the single-query
//! [`crate::kernels::attn_decode`] kernel — O(t) work per generated
//! token versus the O(t²) full-sequence recompute of the `fwd` artifact.
//!
//! Bit-identity contract: every arithmetic step (embedding copy, RMSNorm,
//! GEMM reduction order, RoPE rotation, softmax max/exp/normalize order,
//! weighted-value accumulation, residual adds, SwiGLU) reproduces the
//! exact operation order of the full forward in `native/model.rs` for the
//! same prefix, so greedy decode through a session matches full recompute
//! bit-for-bit (asserted by the generation proptests). Only causal
//! attention mixes positions, and it only looks backward — a prefix's
//! activations never depend on what comes after it.

// s2ft-analyze: allow(nondet) reason="weight maps are keyed lookup only — never iterated — so HashMap order cannot reach the decoded tokens"
use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::kernels::{attn_decode, gemm, gemm_nt};
use crate::runtime::meta::{Meta, ModelMeta};
use crate::runtime::{DecodeSession, DecoderProvider, Tensor};

use super::model::{rms_norm_fwd, rope_tables, sigmoid};

/// [`DecoderProvider`] for [`super::NativeBackend`]: holds only the meta
/// handle, so opening a session is allocation of the caches plus borrows
/// of the caller's weight slices (no weight copies).
pub struct NativeDecoderProvider {
    pub(super) meta: Arc<Meta>,
}

impl DecoderProvider for NativeDecoderProvider {
    fn open_session<'p>(
        &self,
        model: &str,
        params: &'p HashMap<String, Tensor>,
        b: usize,
        t_max: usize,
    ) -> Result<Box<dyn DecodeSession + 'p>> {
        let mm = self
            .meta
            .models
            .get(model)
            .ok_or_else(|| anyhow!("model {model:?} not in meta"))?;
        Ok(Box::new(NativeDecodeSession::new(mm.clone(), params, b, t_max)?))
    }
}

/// One live decode: borrowed base-layout weights + owned KV caches.
///
/// Cache memory is `2 · n_layers · b · t_max · d_model · 4` bytes
/// (K and V, f32) — e.g. the builtin `small` model at b=8, t_max=64
/// caches 4·8·64·256·2·4 B = 4.2 MB.
pub struct NativeDecodeSession<'p> {
    mm: ModelMeta,
    w: HashMap<String, &'p [f32]>,
    b: usize,
    t_max: usize,
    pos: Vec<usize>,
    /// per layer: (b, t_max, d) rotated keys
    k_cache: Vec<Vec<f32>>,
    /// per layer: (b, t_max, d) values
    v_cache: Vec<Vec<f32>>,
    cos: Vec<f32>,
    sin: Vec<f32>,
}

impl<'p> NativeDecodeSession<'p> {
    fn new(
        mm: ModelMeta,
        params: &'p HashMap<String, Tensor>,
        b: usize,
        t_max: usize,
    ) -> Result<Self> {
        let mut w = HashMap::new();
        for s in &mm.base_params {
            let t = params
                .get(&s.name)
                .ok_or_else(|| anyhow!("decode: missing weight {:?}", s.name))?;
            if t.shape != s.shape {
                bail!(
                    "decode: weight {:?} shape {:?} != expected {:?}",
                    s.name,
                    t.shape,
                    s.shape
                );
            }
            w.insert(s.name.clone(), t.as_f32()?);
        }
        let d = mm.dims.d_model;
        let hd = mm.head_dim();
        let n_layers = mm.dims.n_layers;
        let (cos, sin) = rope_tables(t_max, hd, mm.dims.rope_theta);
        Ok(Self {
            w,
            b,
            t_max,
            pos: vec![0; b],
            k_cache: (0..n_layers).map(|_| vec![0.0; b * t_max * d]).collect(),
            v_cache: (0..n_layers).map(|_| vec![0.0; b * t_max * d]).collect(),
            cos,
            sin,
            mm,
        })
    }

    fn weight(&self, name: &str) -> &'p [f32] {
        self.w[name]
    }

    /// In-place RoPE on one `(heads·hd)` row at absolute position `pos`
    /// — same pair rotation as the full forward's `apply_rope`.
    fn rope_row(&self, x: &mut [f32], heads: usize, hd: usize, pos: usize) {
        let half = hd / 2;
        for hh in 0..heads {
            let off = hh * hd;
            for j in 0..half {
                let c = self.cos[pos * half + j];
                let s = self.sin[pos * half + j];
                let x1 = x[off + 2 * j];
                let x2 = x[off + 2 * j + 1];
                x[off + 2 * j] = x1 * c - x2 * s;
                x[off + 2 * j + 1] = x1 * s + x2 * c;
            }
        }
    }
}

impl DecodeSession for NativeDecodeSession<'_> {
    fn batch(&self) -> usize {
        self.b
    }

    fn max_seq(&self) -> usize {
        self.t_max
    }

    fn pos(&self, row: usize) -> usize {
        self.pos[row]
    }

    fn step(&mut self, tokens: &[Option<i32>]) -> Result<Vec<f32>> {
        let d = self.mm.dims.d_model;
        let heads = self.mm.dims.n_heads;
        let hd = d / heads;
        let ff = self.mm.dims.d_ff;
        let vocab = self.mm.dims.vocab;
        let eps = self.mm.dims.norm_eps as f32;
        let scale = 1.0 / (hd as f32).sqrt();
        if tokens.len() != self.b {
            bail!("decode: {} token slots != batch {}", tokens.len(), self.b);
        }

        // active rows, their cache rows and (post-append) positions
        let mut rows = Vec::new();
        let mut toks = Vec::new();
        for (r, t) in tokens.iter().enumerate() {
            if let Some(t) = *t {
                if self.pos[r] >= self.t_max {
                    bail!("decode: row {r} exceeded t_max {}", self.t_max);
                }
                rows.push(r);
                toks.push(t);
            }
        }
        let mut out = vec![0.0f32; self.b * vocab];
        let m = rows.len();
        if m == 0 {
            return Ok(out);
        }
        let qpos: Vec<usize> = rows.iter().map(|&r| self.pos[r]).collect();

        let embed = self.weight("embed");
        let mut h = vec![0.0f32; m * d];
        for (j, &tok) in toks.iter().enumerate() {
            let tok = tok as usize;
            if tok >= vocab {
                bail!("decode: token id {tok} out of vocab {vocab}");
            }
            h[j * d..(j + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
        }

        for i in 0..self.mm.dims.n_layers {
            let (x1, _) = rms_norm_fwd(&h, self.weight(&format!("L{i}.norm1")), m, d, eps);
            let mut q = gemm(&x1, self.weight(&format!("L{i}.wq")), m, d, d);
            let mut k = gemm(&x1, self.weight(&format!("L{i}.wk")), m, d, d);
            let v = gemm(&x1, self.weight(&format!("L{i}.wv")), m, d, d);
            for (j, (&r, &p)) in rows.iter().zip(&qpos).enumerate() {
                self.rope_row(&mut q[j * d..(j + 1) * d], heads, hd, p);
                self.rope_row(&mut k[j * d..(j + 1) * d], heads, hd, p);
                let off = (r * self.t_max + p) * d;
                self.k_cache[i][off..off + d].copy_from_slice(&k[j * d..(j + 1) * d]);
                self.v_cache[i][off..off + d].copy_from_slice(&v[j * d..(j + 1) * d]);
            }
            let attn = attn_decode(
                &q,
                &self.k_cache[i],
                &self.v_cache[i],
                &rows,
                &qpos,
                heads,
                hd,
                self.t_max,
                scale,
            );
            // h_mid = h + attn @ wo (residual add, same order as forward)
            let wo_out = gemm(&attn, self.weight(&format!("L{i}.wo")), m, d, d);
            for (hv, ov) in h.iter_mut().zip(&wo_out) {
                *hv += ov;
            }
            let (x2, _) = rms_norm_fwd(&h, self.weight(&format!("L{i}.norm2")), m, d, eps);
            let u = gemm(&x2, self.weight(&format!("L{i}.wu")), m, d, ff);
            let g = gemm(&x2, self.weight(&format!("L{i}.wg")), m, d, ff);
            let mut act = vec![0.0f32; m * ff];
            for j in 0..m * ff {
                act[j] = u[j] * g[j] * sigmoid(g[j]);
            }
            let wd_out = gemm(&act, self.weight(&format!("L{i}.wd")), m, ff, d);
            for (hv, ov) in h.iter_mut().zip(&wd_out) {
                *hv += ov;
            }
        }

        let (xf, _) = rms_norm_fwd(&h, self.weight("norm_f"), m, d, eps);
        let logits = gemm_nt(&xf, embed, m, d, vocab);
        for (j, &r) in rows.iter().enumerate() {
            out[r * vocab..(r + 1) * vocab].copy_from_slice(&logits[j * vocab..(j + 1) * vocab]);
            self.pos[r] += 1;
        }
        Ok(out)
    }
}
