//! Adapter lifecycle (paper §6.2): S²FT weight deltas decompose into
//! `ΔW = U Vᵀ` with `U` a column-selection matrix, so an adapter is just
//! `(row indices, dense delta rows)` per layer. This enables:
//!
//! * **extraction** — diff merged vs base weights at the selected rows,
//! * **switch** — fuse/unfuse via `scatter_add` (O(s·d), no GEMM; Fig 6a/b),
//! * **fusion** — weighted combination of adapters (Table 5),
//! * **parallelism** — batched multi-adapter serving on a single layer
//!   (Fig 6c), implemented in [`parallel`].

/// Batched multi-adapter serving on one shared layer (paper Fig 6c).
pub mod parallel;
mod persist;
mod store;

pub use persist::{load_adapter, save_adapter, PersistError};
pub use store::{AdapterSlot, AdapterStore, AnyAdapter};

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::linalg::Mat;
use crate::runtime::{MethodMeta, ModelMeta, Tensor};
use crate::sparsity;

/// Per-layer S²FT delta: element-level row indices + dense delta rows.
#[derive(Debug, Clone, Default)]
pub struct S2ftLayerDelta {
    /// row indices into wo (element level, head blocks) — may be empty
    pub wo_rows: Vec<usize>,
    /// (wo_rows.len(), d_model) row-major
    pub wo_delta: Vec<f32>,
    /// row indices into wd (channel level)
    pub wd_rows: Vec<usize>,
    /// (wd_rows.len(), d_model) row-major
    pub wd_delta: Vec<f32>,
}

/// A complete S²FT adapter: one [`S2ftLayerDelta`] per transformer layer
/// plus the model width the deltas were extracted against.
#[derive(Debug, Clone)]
pub struct S2ftAdapter {
    /// Per-layer deltas, index = layer number.
    pub layers: Vec<S2ftLayerDelta>,
    /// Model width `d` every delta row spans.
    pub d_model: usize,
}

impl S2ftAdapter {
    /// Extract from base + fine-tuned (merged) weights using the prepare
    /// permutations. Only the selected rows can differ; we assert that by
    /// construction of the trainer and store exactly those rows.
    pub fn extract(
        mm: &ModelMeta,
        method: &MethodMeta,
        perms: &HashMap<String, Tensor>,
        base: &HashMap<String, Tensor>,
        merged: &HashMap<String, Tensor>,
    ) -> Result<S2ftAdapter> {
        let d = mm.dims.d_model;
        let hd = mm.head_dim();
        let counts = s2ft_counts(mm, method);
        let mut layers = Vec::with_capacity(mm.dims.n_layers);
        for i in 0..mm.dims.n_layers {
            let mut delta = S2ftLayerDelta::default();
            if let (Some(heads), Some(perm)) =
                (counts.get("wo"), perms.get(&format!("L{i}.head_perm")))
            {
                let hperm: Vec<usize> = perm.as_i32()?.iter().map(|&x| x as usize).collect();
                let sel = sparsity::selected_units(&hperm, *heads);
                delta.wo_rows = sparsity::expand_head_perm(&sel, hd);
                delta.wo_delta = diff_rows(
                    base[&format!("L{i}.wo")].as_f32()?,
                    merged[&format!("L{i}.wo")].as_f32()?,
                    d,
                    &delta.wo_rows,
                );
            }
            if let (Some(chans), Some(perm)) =
                (counts.get("wd"), perms.get(&format!("L{i}.chan_perm")))
            {
                let cperm: Vec<usize> = perm.as_i32()?.iter().map(|&x| x as usize).collect();
                delta.wd_rows = sparsity::selected_units(&cperm, *chans);
                delta.wd_delta = diff_rows(
                    base[&format!("L{i}.wd")].as_f32()?,
                    merged[&format!("L{i}.wd")].as_f32()?,
                    d,
                    &delta.wd_rows,
                );
            }
            layers.push(delta);
        }
        Ok(S2ftAdapter { layers, d_model: d })
    }

    /// Fuse into base-layout weights in place (scatter_add — Fig 6a).
    pub fn apply(&self, params: &mut HashMap<String, Tensor>) -> Result<()> {
        for (i, l) in self.layers.iter().enumerate() {
            if !l.wo_rows.is_empty() {
                let w = params
                    .get_mut(&format!("L{i}.wo"))
                    .ok_or_else(|| anyhow!("missing L{i}.wo"))?;
                sparsity::scatter_add_rows(w.as_f32_mut()?, self.d_model, &l.wo_rows, &l.wo_delta);
            }
            if !l.wd_rows.is_empty() {
                let w = params
                    .get_mut(&format!("L{i}.wd"))
                    .ok_or_else(|| anyhow!("missing L{i}.wd"))?;
                sparsity::scatter_add_rows(w.as_f32_mut()?, self.d_model, &l.wd_rows, &l.wd_delta);
            }
        }
        Ok(())
    }

    /// Unfuse (scatter_sub) — the adapter-switch "unload" half.
    pub fn remove(&self, params: &mut HashMap<String, Tensor>) -> Result<()> {
        for (i, l) in self.layers.iter().enumerate() {
            if !l.wo_rows.is_empty() {
                let w = params.get_mut(&format!("L{i}.wo")).unwrap();
                sparsity::scatter_sub_rows(w.as_f32_mut()?, self.d_model, &l.wo_rows, &l.wo_delta);
            }
            if !l.wd_rows.is_empty() {
                let w = params.get_mut(&format!("L{i}.wd")).unwrap();
                sparsity::scatter_sub_rows(w.as_f32_mut()?, self.d_model, &l.wd_rows, &l.wd_delta);
            }
        }
        Ok(())
    }

    /// Weighted fusion of adapters (Table 5). Deltas are combined over the
    /// union of rows; overlapping rows interfere (the paper's point about
    /// overlapped vs non-overlapped selection).
    pub fn fuse(adapters: &[(&S2ftAdapter, f32)]) -> Result<S2ftAdapter> {
        let first = adapters.first().ok_or_else(|| anyhow!("no adapters"))?;
        let d = first.0.d_model;
        let n_layers = first.0.layers.len();
        let mut layers = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            let mut wo: HashMap<usize, Vec<f32>> = HashMap::new();
            let mut wd: HashMap<usize, Vec<f32>> = HashMap::new();
            for (a, w) in adapters {
                let l = &a.layers[i];
                accumulate(&mut wo, &l.wo_rows, &l.wo_delta, d, *w);
                accumulate(&mut wd, &l.wd_rows, &l.wd_delta, d, *w);
            }
            layers.push(S2ftLayerDelta {
                wo_rows: sorted_keys(&wo),
                wo_delta: flatten(&wo),
                wd_rows: sorted_keys(&wd),
                wd_delta: flatten(&wd),
            });
        }
        Ok(S2ftAdapter { layers, d_model: d })
    }

    /// Fraction of selected rows shared with another adapter (0 = fully
    /// non-overlapping, the Table 5 "non-overlap" regime).
    pub fn overlap_with(&self, other: &S2ftAdapter) -> f64 {
        let mut shared = 0usize;
        let mut total = 0usize;
        for (a, b) in self.layers.iter().zip(&other.layers) {
            let bs: std::collections::HashSet<_> = b.wd_rows.iter().collect();
            shared += a.wd_rows.iter().filter(|r| bs.contains(r)).count();
            total += a.wd_rows.len();
            let bo: std::collections::HashSet<_> = b.wo_rows.iter().collect();
            shared += a.wo_rows.iter().filter(|r| bo.contains(r)).count();
            total += a.wo_rows.len();
        }
        shared as f64 / total.max(1) as f64
    }

    /// In-memory size: 4 bytes per delta f32 + 8 per row index.
    pub fn bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| (l.wo_delta.len() + l.wd_delta.len()) * 4 + (l.wo_rows.len() + l.wd_rows.len()) * 8)
            .sum()
    }
}

fn accumulate(
    acc: &mut HashMap<usize, Vec<f32>>,
    rows: &[usize],
    delta: &[f32],
    d: usize,
    w: f32,
) {
    for (k, &r) in rows.iter().enumerate() {
        let entry = acc.entry(r).or_insert_with(|| vec![0.0; d]);
        for (dst, &src) in entry.iter_mut().zip(&delta[k * d..(k + 1) * d]) {
            *dst += w * src;
        }
    }
}

fn sorted_keys(m: &HashMap<usize, Vec<f32>>) -> Vec<usize> {
    let mut k: Vec<usize> = m.keys().copied().collect();
    k.sort_unstable();
    k
}

fn flatten(m: &HashMap<usize, Vec<f32>>) -> Vec<f32> {
    let mut out = Vec::with_capacity(m.len() * m.values().next().map_or(0, |v| v.len()));
    for k in sorted_keys(m) {
        out.extend_from_slice(&m[&k]);
    }
    out
}

fn diff_rows(base: &[f32], merged: &[f32], cols: usize, rows: &[usize]) -> Vec<f32> {
    let mut out = Vec::with_capacity(rows.len() * cols);
    for &r in rows {
        for j in 0..cols {
            out.push(merged[r * cols + j] - base[r * cols + j]);
        }
    }
    out
}

/// Per-projection trainable unit counts for an S²FT method (zero-count
/// projections dropped). Delegates to [`sparsity::budget_to_counts`].
pub fn s2ft_counts(mm: &ModelMeta, method: &MethodMeta) -> HashMap<String, usize> {
    sparsity::budget_to_counts(&method.s2ft_fractions, mm.dims.d_ff, mm.dims.n_heads)
        .into_iter()
        .filter(|(_, c)| *c > 0)
        .collect()
}

// ---------------------------------------------------------------------------
// LoRA adapters (baseline for Fig 6 / Table 5)
// ---------------------------------------------------------------------------

/// Per-layer LoRA factors for one target projection set (wo + wd).
#[derive(Debug, Clone)]
pub struct LoraLayerDelta {
    /// A factor of the wo projection's low-rank delta.
    pub wo_a: Mat,
    /// B factor of the wo projection's low-rank delta.
    pub wo_b: Mat,
    /// A factor of the wd projection's low-rank delta.
    pub wd_a: Mat,
    /// B factor of the wd projection's low-rank delta.
    pub wd_b: Mat,
}

/// A complete LoRA adapter (the Fig 6 / Table 5 baseline family).
#[derive(Debug, Clone)]
pub struct LoraAdapter {
    /// Per-layer A/B factors, index = layer number.
    pub layers: Vec<LoraLayerDelta>,
    /// `alpha / rank` multiplier applied to every ΔW = A·B.
    pub scale: f32,
}

impl LoraAdapter {
    /// Extract A/B factors from a lora/dora trainer pool.
    pub fn from_pool(
        mm: &ModelMeta,
        method: &MethodMeta,
        pool: impl Fn(&str) -> Result<Tensor>,
    ) -> Result<LoraAdapter> {
        let mut layers = Vec::new();
        for i in 0..mm.dims.n_layers {
            let get = |name: &str| -> Result<Mat> {
                let t = pool(name)?;
                Ok(Mat::from_vec(t.shape[0], t.shape[1], t.as_f32()?.to_vec()))
            };
            layers.push(LoraLayerDelta {
                wo_a: get(&format!("L{i}.wo.a"))?,
                wo_b: get(&format!("L{i}.wo.b"))?,
                wd_a: get(&format!("L{i}.wd.a"))?,
                wd_b: get(&format!("L{i}.wd.b"))?,
            });
        }
        Ok(LoraAdapter {
            layers,
            scale: (method.lora_alpha / method.rank.max(1) as f64) as f32,
        })
    }

    /// Fuse into base weights: requires the ΔW = scale·A·B GEMM per layer
    /// (the quadratic cost Fig 6a measures, vs S²FT's scatter_add).
    pub fn apply(&self, params: &mut HashMap<String, Tensor>) -> Result<()> {
        for (i, l) in self.layers.iter().enumerate() {
            for (name, a, b) in
                [("wo", &l.wo_a, &l.wo_b), ("wd", &l.wd_a, &l.wd_b)]
            {
                let dw = a.matmul(b).scale(self.scale);
                let w = params.get_mut(&format!("L{i}.{name}")).unwrap();
                let wd = w.as_f32_mut()?;
                for (dst, &src) in wd.iter_mut().zip(&dw.data) {
                    *dst += src;
                }
            }
        }
        Ok(())
    }

    /// In-memory size of the A/B factors (4 bytes per f32).
    pub fn bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                (l.wo_a.data.len() + l.wo_b.data.len() + l.wd_a.data.len() + l.wd_b.data.len()) * 4
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_adapter(rows: Vec<usize>, d: usize, val: f32) -> S2ftAdapter {
        let n = rows.len();
        S2ftAdapter {
            layers: vec![S2ftLayerDelta {
                wo_rows: vec![],
                wo_delta: vec![],
                wd_rows: rows,
                wd_delta: vec![val; n * d],
            }],
            d_model: d,
        }
    }

    #[test]
    fn apply_remove_roundtrip() {
        let d = 4;
        let mut params = HashMap::new();
        params.insert("L0.wo".to_string(), Tensor::zeros(vec![d, d]));
        params.insert("L0.wd".to_string(), Tensor::zeros(vec![6, d]));
        let a = tiny_adapter(vec![1, 4], d, 0.5);
        a.apply(&mut params).unwrap();
        assert_eq!(params["L0.wd"].as_f32().unwrap()[d], 0.5);
        assert_eq!(params["L0.wd"].as_f32().unwrap()[0], 0.0);
        a.remove(&mut params).unwrap();
        assert!(params["L0.wd"].as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn fusion_union_and_overlap() {
        let d = 3;
        let a = tiny_adapter(vec![0, 1], d, 1.0);
        let b = tiny_adapter(vec![1, 2], d, 1.0);
        let fused = S2ftAdapter::fuse(&[(&a, 0.5), (&b, 0.5)]).unwrap();
        assert_eq!(fused.layers[0].wd_rows, vec![0, 1, 2]);
        // overlapping row 1 got both halves, rows 0/2 got one half
        let delta = &fused.layers[0].wd_delta;
        assert_eq!(delta[0], 0.5); // row0
        assert_eq!(delta[d], 1.0); // row1 (0.5+0.5)
        assert_eq!(delta[2 * d], 0.5); // row2
        assert!((a.overlap_with(&b) - 0.5).abs() < 1e-9);
        assert_eq!(a.overlap_with(&a), 1.0);
    }

    #[test]
    fn counts_mirror_python() {
        // craft a minimal ModelMeta via parse
        let meta_text = r#"{
          "models": {"x": {"model": {"name":"x","d_model":8,"n_layers":1,"n_heads":4,"d_ff":10,"vocab":261,"seq_len":8},
            "param_count": 1, "methods": {"s2ft": {"method":"s2ft","s2ft_fractions":{"wo":0.25,"wd":0.1}}},
            "batches": [[1,8]], "base_params": []}},
          "artifacts": {}
        }"#;
        let meta = crate::runtime::Meta::parse(meta_text).unwrap();
        let mm = &meta.models["x"];
        let counts = s2ft_counts(mm, &mm.methods["s2ft"]);
        assert_eq!(counts["wo"], 1);
        assert_eq!(counts["wd"], 1);
    }
}
