//! Findings, allow-annotations and the rendered `repro analyze` report.

use std::fmt::Write as _;

use super::lexer::Comment;

/// Finding emitted for an annotation whose syntax could not be parsed.
/// Not suppressible.
pub const MALFORMED_ALLOW: &str = "malformed-allow";
/// Finding emitted for an allow-annotation that suppressed nothing.
/// Not suppressible — stale escape hatches must be deleted.
pub const STALE_ALLOW: &str = "stale-allow";

/// One lint violation, addressed as `path:line`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Lint name (`float-eq`, `safety-comment`, …).
    pub lint: String,
    /// Path relative to the package root (`src/…` or `benches/…`).
    pub path: String,
    /// 1-based line.
    pub line: usize,
    pub message: String,
}

impl Finding {
    pub fn new(lint: &str, path: &str, line: usize, message: String) -> Self {
        Self { lint: lint.to_string(), path: path.to_string(), line, message }
    }
}

/// A parsed per-file escape hatch. The annotation grammar is one plain
/// (non-doc) line comment of the form
///
/// ```text
/// <marker> allow(<lint>) reason="<non-empty justification>"
/// ```
///
/// where the marker is the literal project tag `s2ft-analyze:`. It
/// suppresses findings of that lint *in the same file* and is itself
/// listed in the report; an annotation that suppresses nothing becomes
/// a [`STALE_ALLOW`] finding.
#[derive(Debug, Clone)]
pub struct Allow {
    pub path: String,
    pub line: usize,
    pub lint: String,
    pub reason: String,
    /// Set once the allow suppressed at least one finding.
    pub used: bool,
}

/// Everything `repro analyze` learned about the tree. `findings` empty
/// means the gate passes.
#[derive(Debug, Default)]
pub struct Report {
    pub files_scanned: usize,
    /// Surviving violations, sorted by `(path, line, lint)`.
    pub findings: Vec<Finding>,
    /// Every escape hatch in effect, in scan order.
    pub allows: Vec<Allow>,
}

impl Report {
    /// True when the tree is clean and the gate should exit 0.
    pub fn ok(&self) -> bool {
        self.findings.is_empty()
    }

    /// Human-readable report: one `path:line: [lint] message` per
    /// finding, then the escape hatches in effect, then the verdict.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "repro analyze: {} file(s) scanned, {} finding(s), {} allow(s)",
            self.files_scanned,
            self.findings.len(),
            self.allows.len(),
        );
        for f in &self.findings {
            let _ = writeln!(s, "{}:{}: [{}] {}", f.path, f.line, f.lint, f.message);
        }
        if !self.allows.is_empty() {
            let _ = writeln!(s, "escape hatches in effect:");
            for a in &self.allows {
                let _ = writeln!(s, "  {}:{}: allow({}) — {}", a.path, a.line, a.lint, a.reason);
            }
        }
        if self.ok() {
            let _ = writeln!(s, "OK: all invariants hold");
        }
        s
    }
}

/// The project tag that introduces an allow-annotation. Built from
/// pieces so the analyzer's own sources never contain the literal
/// marker outside of string context.
fn marker() -> String {
    format!("{}{}", "s2ft-", "analyze:")
}

fn malformed(rel: &str, line: usize, message: String) -> Finding {
    Finding::new(MALFORMED_ALLOW, rel, line, message)
}

/// Parse every allow-annotation in `comments`. Only plain (non-doc)
/// comments participate — documentation *describing* the syntax can
/// never arm an escape hatch. Returns the allows plus
/// [`MALFORMED_ALLOW`] findings for annotations that carry the marker
/// but not the grammar.
pub fn parse_allows(
    rel: &str,
    comments: &[Comment],
    known: &[&str],
) -> (Vec<Allow>, Vec<Finding>) {
    let tag = marker();
    let mut allows = Vec::new();
    let mut bad = Vec::new();
    for cm in comments {
        if cm.doc {
            continue;
        }
        let t = cm.text.trim();
        let Some(rest) = t.strip_prefix(tag.as_str()) else { continue };
        let rest = rest.trim_start();
        let Some(inner) = rest.strip_prefix("allow(") else {
            let msg = format!("annotation must read `allow(<lint>) reason=\"…\"`, got `{rest}`");
            bad.push(malformed(rel, cm.line, msg));
            continue;
        };
        let Some(close) = inner.find(')') else {
            bad.push(malformed(rel, cm.line, "unclosed `allow(` in annotation".to_string()));
            continue;
        };
        let name = inner[..close].trim();
        if !known.contains(&name) {
            let msg = format!("unknown lint `{name}` (known: {})", known.join(", "));
            bad.push(malformed(rel, cm.line, msg));
            continue;
        }
        let tail = inner[close + 1..].trim_start();
        let Some(r) = tail.strip_prefix("reason=\"") else {
            let msg = format!("allow({name}) needs a reason=\"…\" justification");
            bad.push(malformed(rel, cm.line, msg));
            continue;
        };
        let Some(endq) = r.find('"') else {
            let msg = "unterminated reason string in annotation".to_string();
            bad.push(malformed(rel, cm.line, msg));
            continue;
        };
        let reason = r[..endq].trim().to_string();
        if reason.is_empty() {
            bad.push(malformed(rel, cm.line, format!("allow({name}) has an empty reason")));
            continue;
        }
        let lint = name.to_string();
        allows.push(Allow { path: rel.to_string(), line: cm.line, lint, reason, used: false });
    }
    (allows, bad)
}

/// Drop findings covered by a same-file allow of the same lint, marking
/// those allows used. Returns the survivors.
pub fn apply_allows(findings: Vec<Finding>, allows: &mut [Allow]) -> Vec<Finding> {
    let mut kept = Vec::new();
    for f in findings {
        let hit = allows.iter_mut().find(|a| a.lint == f.lint);
        match hit {
            Some(a) => a.used = true,
            None => kept.push(f),
        }
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyze::lexer::lex;

    const KNOWN: &[&str] = &["nondet", "bench-baseline"];

    fn fixture_comment(body: &str) -> String {
        // build the annotation without embedding the live marker in
        // this file's source
        format!("// {} {body}\nfn f() {{}}\n", marker())
    }

    #[test]
    fn parses_well_formed_allow() {
        let src = fixture_comment("allow(nondet) reason=\"keyed lookup only\"");
        let lx = lex(&src);
        let (allows, bad) = parse_allows("src/x.rs", &lx.comments, KNOWN);
        assert!(bad.is_empty(), "{bad:?}");
        assert_eq!(allows.len(), 1);
        assert_eq!(allows[0].lint, "nondet");
        assert_eq!(allows[0].reason, "keyed lookup only");
        assert_eq!(allows[0].line, 1);
        assert!(!allows[0].used);
    }

    #[test]
    fn rejects_unknown_lint_and_missing_reason() {
        for body in [
            "allow(spelling) reason=\"x\"",
            "allow(nondet)",
            "allow(nondet) reason=\"\"",
            "deny(nondet)",
            "allow(nondet reason=\"x\"",
        ] {
            let src = fixture_comment(body);
            let lx = lex(&src);
            let (allows, bad) = parse_allows("src/x.rs", &lx.comments, KNOWN);
            assert!(allows.is_empty(), "{body} should not parse");
            assert_eq!(bad.len(), 1, "{body} should be one malformed finding");
            assert_eq!(bad[0].lint, MALFORMED_ALLOW);
        }
    }

    #[test]
    fn doc_comments_never_arm_allows() {
        let src = format!("/// {} allow(nondet) reason=\"docs\"\nfn f() {{}}\n", marker());
        let lx = lex(&src);
        let (allows, bad) = parse_allows("src/x.rs", &lx.comments, KNOWN);
        assert!(allows.is_empty());
        assert!(bad.is_empty());
    }

    #[test]
    fn apply_allows_suppresses_and_marks_used() {
        let findings = vec![
            Finding::new("nondet", "src/x.rs", 3, "HashMap".into()),
            Finding::new("float-eq", "src/x.rs", 9, "== 0.0".into()),
        ];
        let allow = Allow {
            path: "src/x.rs".into(),
            line: 1,
            lint: "nondet".into(),
            reason: "r".into(),
            used: false,
        };
        let mut allows = vec![allow];
        let left = apply_allows(findings, &mut allows);
        assert_eq!(left.len(), 1);
        assert_eq!(left[0].lint, "float-eq");
        assert!(allows[0].used);
    }

    #[test]
    fn render_lists_findings_and_allows() {
        let allow = Allow {
            path: "src/d.rs".into(),
            line: 2,
            lint: "nondet".into(),
            reason: "why".into(),
            used: true,
        };
        let report = Report {
            files_scanned: 2,
            findings: vec![Finding::new("float-eq", "src/k.rs", 7, "bad".into())],
            allows: vec![allow],
        };
        let s = report.render();
        assert!(s.contains("src/k.rs:7: [float-eq] bad"));
        assert!(s.contains("allow(nondet)"));
        assert!(!s.contains("OK:"));
        assert!(!report.ok());
    }
}
