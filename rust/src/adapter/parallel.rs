//! Adapter parallelism on a single linear layer (paper Fig 6c):
//! serve a batch where every request uses a *different* adapter.
//!
//! Both paths share the base GEMM `Y = X @ W` (S-LoRA's decomposition);
//! they differ in the per-request delta:
//!
//!   LoRA : y_i += ((x_i @ A_i) @ B_i) * scale       -> r·(k+d) MACs
//!   S²FT : y_i += x_i[rows_i] @ D_i                 -> s·d MACs + gather
//!
//! At the paper's setting (s = 2r, k = d) the MAC counts match, but S²FT
//! does one fused pass over memory instead of two chained GEMVs — the
//! source of its measured advantage.

use crate::linalg::Mat;

/// Per-request LoRA factors for one layer.
pub struct LoraReqAdapter {
    pub a: Mat, // (k, r)
    pub b: Mat, // (r, d)
    pub scale: f32,
}

/// Per-request S²FT delta rows for one layer.
pub struct S2ftReqAdapter {
    pub rows: Vec<usize>,
    pub delta: Mat, // (s, d)
}

/// Shared base computation: Y = X @ W.
pub fn base_forward(x: &Mat, w: &Mat) -> Mat {
    x.matmul(w)
}

/// LoRA path: per-request low-rank correction on top of `y`.
pub fn lora_parallel(x: &Mat, y: &mut Mat, adapters: &[LoraReqAdapter]) {
    let k = x.cols;
    let d = y.cols;
    assert_eq!(adapters.len(), x.rows);
    for (i, ad) in adapters.iter().enumerate() {
        let r = ad.a.cols;
        let xi = x.row(i);
        // t = x_i @ A  (k x r)
        let mut t = vec![0.0f32; r];
        for kk in 0..k {
            let xv = xi[kk];
            if xv == 0.0 {
                continue;
            }
            let arow = ad.a.row(kk);
            for j in 0..r {
                t[j] += xv * arow[j];
            }
        }
        // y_i += (t @ B) * scale
        let yrow = &mut y.data[i * d..(i + 1) * d];
        for rr in 0..r {
            let tv = t[rr] * ad.scale;
            if tv == 0.0 {
                continue;
            }
            let brow = ad.b.row(rr);
            for j in 0..d {
                yrow[j] += tv * brow[j];
            }
        }
    }
}

/// S²FT path: gather the selected activations, apply the dense delta.
pub fn s2ft_parallel(x: &Mat, y: &mut Mat, adapters: &[S2ftReqAdapter]) {
    let d = y.cols;
    assert_eq!(adapters.len(), x.rows);
    for (i, ad) in adapters.iter().enumerate() {
        let xi = x.row(i);
        let yrow = &mut y.data[i * d..(i + 1) * d];
        for (s_idx, &row) in ad.rows.iter().enumerate() {
            let xv = xi[row]; // gather
            if xv == 0.0 {
                continue;
            }
            let drow = ad.delta.row(s_idx);
            for j in 0..d {
                yrow[j] += xv * drow[j];
            }
        }
    }
}

/// Exact dense reference: y_i = x_i @ (W + ΔW_i).
pub fn dense_reference(x: &Mat, w: &Mat, deltas: &[Mat]) -> Mat {
    let mut out = Mat::zeros(x.rows, w.cols);
    for i in 0..x.rows {
        let weff = w.add(&deltas[i]);
        let xi = Mat::from_vec(1, x.cols, x.row(i).to_vec());
        let yi = xi.matmul(&weff);
        out.data[i * w.cols..(i + 1) * w.cols].copy_from_slice(&yi.data);
    }
    out
}

impl LoraReqAdapter {
    pub fn dense_delta(&self, _k: usize) -> Mat {
        self.a.matmul(&self.b).scale(self.scale)
    }
}

impl S2ftReqAdapter {
    pub fn dense_delta(&self, k: usize) -> Mat {
        let d = self.delta.cols;
        let mut out = Mat::zeros(k, d);
        for (s_idx, &row) in self.rows.iter().enumerate() {
            out.data[row * d..(row + 1) * d].copy_from_slice(self.delta.row(s_idx));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn both_paths_match_dense_reference() {
        let mut rng = Rng::seed(0);
        let (n, k, d, r, s) = (4, 16, 12, 3, 5);
        let x = Mat::randn(n, k, &mut rng);
        let w = Mat::randn(k, d, &mut rng);

        let loras: Vec<LoraReqAdapter> = (0..n)
            .map(|_| LoraReqAdapter {
                a: Mat::randn(k, r, &mut rng),
                b: Mat::randn(r, d, &mut rng),
                scale: 0.5,
            })
            .collect();
        let mut y = base_forward(&x, &w);
        lora_parallel(&x, &mut y, &loras);
        let deltas: Vec<Mat> = loras.iter().map(|a| a.dense_delta(k)).collect();
        let want = dense_reference(&x, &w, &deltas);
        assert!(y.sub(&want).fro_norm() / want.fro_norm() < 1e-4);

        let s2fts: Vec<S2ftReqAdapter> = (0..n)
            .map(|_| S2ftReqAdapter {
                rows: rng.choose(k, s),
                delta: Mat::randn(s, d, &mut rng),
            })
            .collect();
        let mut y2 = base_forward(&x, &w);
        s2ft_parallel(&x, &mut y2, &s2fts);
        let deltas2: Vec<Mat> = s2fts.iter().map(|a| a.dense_delta(k)).collect();
        let want2 = dense_reference(&x, &w, &deltas2);
        assert!(y2.sub(&want2).fro_norm() / want2.fro_norm() < 1e-4);
    }
}
