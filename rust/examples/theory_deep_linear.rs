//! Deep-linear-network theory playground (paper §4 / App. F).
//!
//! Sweeps the fine-tuning-task shift and the LoRA rank / S²FT sparsity to
//! show where the generalization separation of Theorem 4.2 opens up, and
//! verifies both bounds numerically on every instance.
//!
//! Run: `cargo run --release --example theory_deep_linear`

use repro::theory::{compare, Config};

fn main() {
    let dims = vec![24, 64, 64, 48];
    println!("deep linear net {dims:?}, fine-tuning layer 2; OOD = pre-training task");
    println!(
        "{:>6} {:>4} {:>10} {:>10} {:>10} {:>12} {:>12} {:>8}",
        "shift", "r", "E_od(pre)", "od(LoRA)", "od(S2FT)", "LoRA-bound", "F.15-bound", "ok?"
    );
    let mut checks = 0;
    let mut held = 0;
    for shift in [0.5f32, 1.0, 2.0, 4.0] {
        for r in [1usize, 2, 4] {
            let cfg = Config {
                dims: dims.clone(),
                layer: 2,
                task_shift: shift,
                ood_noise: 0.3,
                shift_rank: 8,
                seed: 3,
            };
            let rep = compare(&cfg, r);
            let f15 = rep.od_pre + 3.0 * rep.proj_shift_sq;
            let ok = rep.od_s2ft <= f15 * 1.15 && rep.od_lora >= 0.3 * rep.label_shift_sq;
            checks += 1;
            held += ok as usize;
            println!(
                "{:>6.1} {:>4} {:>10.2} {:>10.2} {:>10.2} {:>12.2} {:>12.2} {:>8}",
                shift,
                r,
                rep.od_pre,
                rep.od_lora,
                rep.od_s2ft,
                rep.label_shift_sq,
                f15,
                if ok { "✓" } else { "✗" }
            );
        }
    }
    println!("\nbounds held on {held}/{checks} instances");
    println!("reading: LoRA's OOD risk tracks the label shift (forgetting);");
    println!("S²FT's stays pinned near E_od(pre) + the small projected-shift term.");
}
