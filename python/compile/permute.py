"""Coupled-structure discovery and co-permutation (paper Sec. 3.1-3.2).

A *coupled structure* is a pair (W1, W2) of weight sets connected by an
intermediate activation whose channel order is private to the pair, so both
sides can be co-permuted without changing the module output:

  MHA : W1 = (wq, wk, wv) columns grouped by head, W2 = wo rows grouped by
        head; the activation is softmax(QK^T)V. Head blocks are the unit.
  FFN : W1 = (wu, wg) columns, W2 = wd rows; the activation is
        U(x) * SiLU(G(x)). Single channels are the unit.

Weight convention throughout: y = x @ W with W shaped (d_in, d_out), so
"channel c of the FFN" is column c of wu/wg and row c of wd; "head h of the
MHA" is column block h of wq/wk/wv and row block h of wo.

``co_permute_*`` return permuted copies plus the permutation used
(trainable-first order); ``invert_permutation`` undoes it. The rust
``sparsity`` module mirrors these index conventions for adapter extraction.
"""

from typing import Dict, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


def trainable_first_permutation(selected: Sequence[int], total: int) -> np.ndarray:
    """Permutation placing ``selected`` (in given order) first, rest after.

    Returns ``perm`` such that new[i] = old[perm[i]].
    """
    selected = list(selected)
    sel_set = set(selected)
    assert len(sel_set) == len(selected), "duplicate selection"
    assert all(0 <= c < total for c in selected), "selection out of range"
    rest = [c for c in range(total) if c not in sel_set]
    return np.array(selected + rest, dtype=np.int32)


def invert_permutation(perm: np.ndarray) -> np.ndarray:
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm), dtype=perm.dtype)
    return inv


def expand_head_perm(head_perm: np.ndarray, head_dim: int) -> np.ndarray:
    """Expand a head-level permutation to element level (blocks of head_dim)."""
    base = head_perm.astype(np.int64) * head_dim
    return (base[:, None] + np.arange(head_dim)[None, :]).reshape(-1).astype(np.int32)


def co_permute_ffn(
    wu: jnp.ndarray, wg: jnp.ndarray, wd: jnp.ndarray, selected: Sequence[int]
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, np.ndarray]:
    """Co-permute the FFN coupled structure so selected channels lead.

    wu, wg: (d, k) — columns permuted; wd: (k, d) — rows permuted.
    The module output x -> (U(x)*SiLU(G(x))) @ D is invariant.
    """
    k = wd.shape[0]
    perm = trainable_first_permutation(selected, k)
    return wu[:, perm], wg[:, perm], wd[perm, :], perm


def co_permute_mha(
    wq: jnp.ndarray,
    wk: jnp.ndarray,
    wv: jnp.ndarray,
    wo: jnp.ndarray,
    selected_heads: Sequence[int],
    n_heads: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, np.ndarray]:
    """Co-permute the MHA coupled structure so selected heads lead.

    wq/wk/wv: (d, d) columns grouped by head (permuted);
    wo: (d, d) rows grouped by head (permuted). Attention is computed
    per-head, so reordering heads consistently preserves the output.
    """
    d = wo.shape[0]
    head_dim = d // n_heads
    hperm = trainable_first_permutation(selected_heads, n_heads)
    eperm = expand_head_perm(hperm, head_dim)
    return wq[:, eperm], wk[:, eperm], wv[:, eperm], wo[eperm, :], hperm


def coupled_structures(n_layers: int) -> Dict[str, dict]:
    """Static description of every coupled structure in the model.

    This is the dependency-graph result of paper Eq. (1)-(2) specialized to
    the LLaMA block; emitted into meta.json so the rust side can reason
    about adapters without re-deriving it.
    """
    out = {}
    for i in range(n_layers):
        out[f"L{i}.mha"] = {
            "w1": [f"L{i}.wq", f"L{i}.wk", f"L{i}.wv"],
            "w2": [f"L{i}.wo"],
            "unit": "head",
            "activation": "softmax(QK^T)V",
        }
        out[f"L{i}.ffn"] = {
            "w1": [f"L{i}.wu", f"L{i}.wg"],
            "w2": [f"L{i}.wd"],
            "unit": "channel",
            "activation": "U(x)*SiLU(G(x))",
        }
    return out
