//! Fixed-size-block paged KV-cache pool for continuous batching.
//!
//! Instead of every decode session owning private `(rows, t_max, d)`
//! K/V buffers — sized for the worst case whether or not a stream ever
//! reaches `t_max` — the engine's paged decode path draws cache space
//! from one shared [`KvPool`] per worker. The pool is a flat arena of
//! fixed-size **blocks** ([`KvPoolConfig::block_tokens`] token slots
//! each, covering K *and* V across every layer), handed out through a
//! free list. Each live stream holds a *block table*: the ordered list
//! of physical block ids backing its logical token positions, so
//! logical position `t` lives in block `table[t / block_tokens]` at
//! slot `t % block_tokens`.
//!
//! What that buys the engine:
//!
//! * **admit/retire mid-flight** — a stream's cache is allocated lazily
//!   block-by-block as it decodes and returned to the free list the
//!   moment it finishes, so short streams never pay for `t_max`;
//! * **backpressure** — [`KvPool::alloc`] fails with a typed
//!   [`PoolExhausted`] when the free list is empty, which the engine
//!   turns into deferred admission or eviction of the youngest stream;
//! * **accounting** — [`KvPool::usage`] reports exact capacity / used /
//!   peak bytes, surfaced through `ServeMetrics` the same way
//!   `ActivationMeter` reports training cache bytes.
//!
//! The pool stores *rotated* keys (RoPE applied at append time, same as
//! the contiguous session), so attention over a block table is pure
//! address translation — `kernels::attn_decode_paged` reproduces the
//! contiguous `attn_decode` bit-for-bit.

use std::fmt;

/// Sizing knobs for one worker's [`KvPool`] (see `EngineConfig`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPoolConfig {
    /// Token positions per block. Small blocks waste less tail space per
    /// stream but make longer block tables; 16 is a good default for the
    /// builtin models (`t_max` 32–128).
    pub block_tokens: usize,
    /// Total blocks in the pool. `0` = auto-size so that `max_batch`
    /// streams can all reach `t_max` (no eviction possible).
    pub blocks: usize,
}

impl Default for KvPoolConfig {
    fn default() -> Self {
        Self { block_tokens: 16, blocks: 0 }
    }
}

/// Typed allocation failure: the free list is empty.
///
/// Carries the pool shape so callers can distinguish *temporary*
/// exhaustion (other streams hold the blocks — defer or evict) from a
/// request that can *never* fit (`requested_blocks > capacity_blocks`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolExhausted {
    /// Blocks the failed reservation still needed.
    pub requested_blocks: usize,
    /// Blocks free at the time of the failure (always 0 for `alloc`).
    pub free_blocks: usize,
    /// Total blocks the pool was built with.
    pub capacity_blocks: usize,
}

impl fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "kv pool exhausted: {} block(s) requested, {} free of {}",
            self.requested_blocks, self.free_blocks, self.capacity_blocks
        )
    }
}

impl std::error::Error for PoolExhausted {}

/// Point-in-time pool accounting (all byte figures are exact).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolUsage {
    /// Total blocks the pool owns.
    pub capacity_blocks: usize,
    /// Blocks currently on the free list.
    pub free_blocks: usize,
    /// Token slots per block.
    pub block_tokens: usize,
    /// Bytes one block pins across K+V and every layer.
    pub block_bytes: usize,
    /// `capacity_blocks * block_bytes`.
    pub capacity_bytes: usize,
    /// Bytes held by allocated blocks right now.
    pub used_bytes: usize,
    /// High-water mark of `used_bytes` over the pool's lifetime.
    pub peak_bytes: usize,
}

/// The shared block arena: per-layer K and V slabs plus a LIFO free
/// list of block ids.
///
/// One block id spans *all* layers — block `b` owns slab
/// `[b·block_tokens·d, (b+1)·block_tokens·d)` in every layer's K and V
/// buffer — so a stream's block table is layer-independent and a block
/// costs `2 · n_layers · block_tokens · d · 4` bytes.
pub struct KvPool {
    n_layers: usize,
    d: usize,
    block_tokens: usize,
    capacity_blocks: usize,
    /// per layer: `(capacity_blocks · block_tokens, d)` rotated keys
    k: Vec<Vec<f32>>,
    /// per layer: `(capacity_blocks · block_tokens, d)` values
    v: Vec<Vec<f32>>,
    free: Vec<u32>,
    peak_used_blocks: usize,
}

impl KvPool {
    /// Build a pool of `blocks` blocks of `block_tokens` positions for a
    /// model with `n_layers` layers of width `d` (= heads · head_dim).
    pub fn new(n_layers: usize, d: usize, block_tokens: usize, blocks: usize) -> Self {
        assert!(block_tokens > 0, "kv pool: block_tokens must be > 0");
        assert!(blocks > 0, "kv pool: blocks must be > 0");
        assert!(blocks <= u32::MAX as usize, "kv pool: block count overflows id space");
        let slab = blocks * block_tokens * d;
        Self {
            n_layers,
            d,
            block_tokens,
            capacity_blocks: blocks,
            k: (0..n_layers).map(|_| vec![0.0; slab]).collect(),
            v: (0..n_layers).map(|_| vec![0.0; slab]).collect(),
            // LIFO: pop from the end; ids handed out low-first initially
            free: (0..blocks as u32).rev().collect(),
            peak_used_blocks: 0,
        }
    }

    /// Token positions per block.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Total token positions the pool can back (`blocks · block_tokens`).
    pub fn capacity_tokens(&self) -> usize {
        self.capacity_blocks * self.block_tokens
    }

    /// Bytes one block pins (K+V, all layers, f32).
    pub fn block_bytes(&self) -> usize {
        2 * self.n_layers * self.block_tokens * self.d * 4
    }

    /// Take one block off the free list.
    pub fn alloc(&mut self) -> Result<u32, PoolExhausted> {
        let Some(id) = self.free.pop() else {
            return Err(PoolExhausted {
                requested_blocks: 1,
                free_blocks: 0,
                capacity_blocks: self.capacity_blocks,
            });
        };
        let used = self.capacity_blocks - self.free.len();
        self.peak_used_blocks = self.peak_used_blocks.max(used);
        Ok(id)
    }

    /// Return a stream's blocks to the free list (stream retirement).
    pub fn release(&mut self, blocks: &[u32]) {
        for &b in blocks {
            debug_assert!((b as usize) < self.capacity_blocks, "release of foreign block {b}");
            self.free.push(b);
        }
    }

    /// Write one rotated-K / V row (`d` floats each) into `block` at
    /// token `slot` of `layer`.
    pub fn write(&mut self, layer: usize, block: u32, slot: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(slot < self.block_tokens);
        let off = (block as usize * self.block_tokens + slot) * self.d;
        self.k[layer][off..off + self.d].copy_from_slice(k_row);
        self.v[layer][off..off + self.d].copy_from_slice(v_row);
    }

    /// One layer's full K and V slabs, for `kernels::attn_decode_paged`.
    pub fn layer_kv(&self, layer: usize) -> (&[f32], &[f32]) {
        (&self.k[layer], &self.v[layer])
    }

    /// Exact accounting snapshot.
    pub fn usage(&self) -> PoolUsage {
        let bb = self.block_bytes();
        let used = self.capacity_blocks - self.free.len();
        PoolUsage {
            capacity_blocks: self.capacity_blocks,
            free_blocks: self.free.len(),
            block_tokens: self.block_tokens,
            block_bytes: bb,
            capacity_bytes: self.capacity_blocks * bb,
            used_bytes: used * bb,
            peak_bytes: self.peak_used_blocks * bb,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip_and_accounting() {
        let mut p = KvPool::new(2, 8, 4, 3);
        let bb = 2 * 2 * 4 * 8 * 4;
        assert_eq!(p.block_bytes(), bb);
        assert_eq!(p.capacity_tokens(), 12);
        let u0 = p.usage();
        assert_eq!(u0.used_bytes, 0);
        assert_eq!(u0.capacity_bytes, 3 * bb);
        assert_eq!(u0.free_blocks, 3);

        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.usage().used_bytes, 2 * bb);
        assert_eq!(p.usage().peak_bytes, 2 * bb);

        p.release(&[a]);
        assert_eq!(p.usage().used_bytes, bb);
        // peak is a high-water mark: it does not fall with releases
        assert_eq!(p.usage().peak_bytes, 2 * bb);

        let c = p.alloc().unwrap();
        let d = p.alloc().unwrap();
        assert_eq!(p.usage().free_blocks, 0);
        assert_eq!(p.usage().used_bytes, 3 * bb);
        p.release(&[b, c, d]);
        assert_eq!(p.usage().used_bytes, 0);
        assert_eq!(p.usage().free_blocks, 3);
    }

    #[test]
    fn exhaustion_is_a_typed_error() {
        let mut p = KvPool::new(1, 4, 2, 2);
        let a = p.alloc().unwrap();
        let _b = p.alloc().unwrap();
        let err = p.alloc().unwrap_err();
        assert_eq!(
            err,
            PoolExhausted { requested_blocks: 1, free_blocks: 0, capacity_blocks: 2 }
        );
        assert!(err.to_string().contains("kv pool exhausted"));
        // reclamation makes the same pool allocatable again
        p.release(&[a]);
        assert!(p.alloc().is_ok());
    }

    #[test]
    fn writes_land_in_the_addressed_slot_only() {
        let mut p = KvPool::new(2, 3, 2, 2);
        let b0 = p.alloc().unwrap();
        let b1 = p.alloc().unwrap();
        p.write(1, b1, 1, &[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        let (k, v) = p.layer_kv(1);
        let off = (b1 as usize * 2 + 1) * 3;
        assert_eq!(&k[off..off + 3], &[1.0, 2.0, 3.0]);
        assert_eq!(&v[off..off + 3], &[4.0, 5.0, 6.0]);
        // everything else (other slot, other block, other layer) untouched
        assert!(k.iter().take(off).all(|&x| x == 0.0));
        let (k0, v0) = p.layer_kv(0);
        assert!(k0.iter().chain(v0).all(|&x| x == 0.0));
        let _ = b0;
    }

    #[test]
    fn default_config_is_auto_sized() {
        let c = KvPoolConfig::default();
        assert_eq!(c.blocks, 0, "0 means auto-size from max_batch × t_max");
        assert!(c.block_tokens > 0);
    }
}
