//! Figure 6a/b: adapter-switch latency on a single linear layer.
//!
//! LoRA switch = ΔW GEMM (k×r @ r×d) + dense add — O(r·d·k), quadratic in
//! the layer dimension. S²FT switch = scatter_add over s rows — O(s·d),
//! near-constant in k. Sweep the base dimension as the paper does
//! (sparsity 32 vs rank 16). The "CPU / IO-bound" panel (6b) is modeled by
//! also reporting bytes touched per switch.

// s2ft-analyze: allow(bench-baseline) reason="paper-figure sweep, not a regression lane; medians depend on the sweep dims so no baseline is committed"
use repro::linalg::Mat;
use repro::sparsity::{scatter_add_rows, scatter_sub_rows};
use repro::util::bench::{black_box, BenchSuite};
use repro::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("fig6_switch");
    let rank = 16usize;
    let sparsity = 32usize;
    println!("Fig 6a/b: adapter switch on one (d x d) layer; LoRA r={rank}, S2FT s={sparsity}\n");

    for d in [512usize, 1024, 2048, 4096] {
        let mut rng = Rng::seed(d as u64);
        let mut w = Mat::randn(d, d, &mut rng);
        // LoRA factors
        let a = Mat::randn(d, rank, &mut rng);
        let b = Mat::randn(rank, d, &mut rng).scale(1e-3);
        // S2FT delta
        let rows = rng.choose(d, sparsity);
        let delta: Vec<f32> = (0..sparsity * d).map(|_| rng.normal_f32() * 1e-3).collect();

        suite.bench(&format!("lora_switch/d={d}"), || {
            // fuse: ΔW = A@B, W += ΔW ; unfuse: W -= ΔW
            let dw = a.matmul(&b);
            for (x, y) in w.data.iter_mut().zip(&dw.data) {
                *x += *y;
            }
            for (x, y) in w.data.iter_mut().zip(&dw.data) {
                *x -= *y;
            }
            black_box(w.data[0]);
        });

        suite.bench(&format!("s2ft_switch/d={d}"), || {
            scatter_add_rows(&mut w.data, d, &rows, &delta);
            scatter_sub_rows(&mut w.data, d, &rows, &delta);
            black_box(w.data[0]);
        });

        // IO model (Fig 6b): bytes written per switch
        let lora_bytes = 2 * d * d * 4;
        let s2ft_bytes = 2 * sparsity * d * 4;
        println!(
            "   d={d}: bytes touched per switch  lora {:>12}  s2ft {:>10}  ({}x less IO)",
            lora_bytes,
            s2ft_bytes,
            lora_bytes / s2ft_bytes
        );
    }
    println!("\nPaper shape: LoRA scales ~quadratically with d; S²FT stays near-constant.");
    suite.save();
}
