//! Serving-stack integration through the public `serve::Engine` API:
//! pool scheduling, streamed replies, per-request sampling and the
//! runtime adapter lifecycle (register/unregister/fuse/switch) with live
//! S²FT adapter switches mid-stream.
//!
//! Runs hermetically on the native backend (default features); the pjrt
//! module replays the core scenarios against real AOT artifacts when
//! they exist.

use std::collections::HashMap;
use std::time::Duration;

use repro::adapter::{AnyAdapter, S2ftAdapter, S2ftLayerDelta};
use repro::runtime::{Executable, Executor, NativeBackend, Tensor};
use repro::serve::{Engine, EngineConfig, GenEvent, GenRequest, BASE_ADAPTER};
use repro::train::{DecodeRequest, GenModel};
use repro::util::rng::Rng;

/// Synthetic tiny-model S²FT adapter deltas, deterministic per rng state.
fn tiny_adapter(rng: &mut Rng) -> AnyAdapter {
    let rt = NativeBackend::builtin();
    let mm = rt.artifacts().model("tiny").unwrap();
    let (d, hd) = (mm.dims.d_model, mm.head_dim());
    let layers = (0..mm.dims.n_layers)
        .map(|_| {
            let heads = rng.choose(mm.dims.n_heads, 1);
            let wo_rows = repro::sparsity::expand_head_perm(&heads, hd);
            S2ftLayerDelta {
                wo_delta: (0..wo_rows.len() * d).map(|_| rng.normal_f32() * 1e-3).collect(),
                wo_rows,
                wd_rows: rng.choose(mm.dims.d_ff, 2),
                wd_delta: (0..2 * d).map(|_| rng.normal_f32() * 1e-3).collect(),
            }
        })
        .collect();
    AnyAdapter::S2ft(S2ftAdapter { layers, d_model: d })
}

/// Spawn an engine whose workers are built by `make_backend` (runs
/// inside each worker thread, PJRT-compatible), with `n_adapters`
/// registered at runtime.
fn spawn_engine<F>(make_backend: F, n_adapters: usize, workers: usize, max_batch: usize) -> Engine
where
    F: Fn() -> anyhow::Result<Box<dyn Executor>> + Send + Sync + 'static,
{
    let cfg = EngineConfig::new()
        .workers(workers)
        .max_batch(max_batch)
        .window(Duration::from_millis(2));
    let engine = Engine::spawn(cfg, move |_wid| {
        let rt = make_backend()?;
        let init = rt.load("init_tiny")?;
        let outs = init.run(&[Tensor::scalar_i32(3)])?;
        let params: HashMap<String, Tensor> =
            init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect();
        let snapshot = params.clone();
        let gm = GenModel::new(rt.as_ref(), "tiny", params)?;
        Ok((gm, snapshot))
    });
    let mut rng = Rng::seed(77);
    for a in 0..n_adapters {
        engine.register(format!("a{a}"), tiny_adapter(&mut rng));
    }
    engine
}

fn engine_serves_all_requests_across_adapters(engine: Engine) {
    let mut streams = Vec::new();
    for i in 0..9 {
        streams.push(engine.submit(
            GenRequest::new(format!("a{}", i % 3), format!("q: item {i}?")).max_new(3),
        ));
    }
    let mut served = 0;
    for s in streams {
        let reply = s.wait().expect("reply");
        assert!(reply.batch_size >= 1 && reply.batch_size <= 2);
        served += 1;
    }
    assert_eq!(served, 9);
    let m = engine.metrics();
    assert_eq!(m.requests, 9);
    assert!(m.batches >= 5, "batcher should cap at max_batch=2: {}", m.batches);
    assert!(m.switches >= 3, "must have switched between 3 adapters");
    assert!(m.percentile_ms(0.5) > 0.0);
    assert_eq!(m.latencies_ms().len(), 9);
    engine.shutdown().unwrap();
}

fn engine_base_requests_use_pristine_weights(engine: Engine) {
    // adapter request then base request: worker must unfuse in between
    let r1 = engine.call(GenRequest::new("a0", "q: x?").max_new(2)).unwrap();
    let r2 = engine
        .call(GenRequest::new(BASE_ADAPTER, "q: x?").max_new(2))
        .unwrap();
    // both served; determinism of each path is covered elsewhere — here we
    // assert the engine survives the fuse/unfuse round trip
    assert!(r1.tokens <= 2 && r2.tokens <= 2);
    assert_eq!(r1.adapter, "a0");
    assert_eq!(r2.adapter, BASE_ADAPTER);
    let m = engine.metrics();
    assert_eq!(m.requests, 2);
    engine.shutdown().unwrap();
}

fn shutdown_drains_cleanly(engine: Engine) {
    let pending = engine.submit(GenRequest::new("a1", "q: last?").max_new(2));
    engine.shutdown().unwrap();
    // the queued request was served before shutdown completed
    assert!(pending.wait().is_ok());
}

/// Sequential calls on one worker make the switch count exact: every
/// adapter change is one store switch, repeats are free.
fn switch_count_matches_adapter_changes(engine: Engine) {
    for (i, adapter) in ["a0", "a1", "a1", "a0", "a2"].iter().enumerate() {
        engine
            .call(GenRequest::new(*adapter, format!("q: {i}?")).max_new(1))
            .unwrap();
    }
    let m = engine.metrics();
    assert_eq!(m.requests, 5);
    // a0 -> a1 (skip dup) -> a0 -> a2 = 4 switches
    assert_eq!(m.switches, 4, "switch count must match adapter changes");
    engine.shutdown().unwrap();
}

mod native {
    use super::*;

    fn native_engine(n_adapters: usize, workers: usize, max_batch: usize) -> Engine {
        spawn_engine(
            || Ok(Box::new(NativeBackend::builtin()) as Box<dyn Executor>),
            n_adapters,
            workers,
            max_batch,
        )
    }

    #[test]
    fn engine_serves_all_requests_across_adapters() {
        super::engine_serves_all_requests_across_adapters(native_engine(3, 1, 2));
    }

    #[test]
    fn engine_base_requests_use_pristine_weights() {
        super::engine_base_requests_use_pristine_weights(native_engine(1, 1, 4));
    }

    #[test]
    fn shutdown_drains_cleanly() {
        super::shutdown_drains_cleanly(native_engine(2, 1, 4));
    }

    #[test]
    fn switch_count_matches_adapter_changes() {
        super::switch_count_matches_adapter_changes(native_engine(3, 1, 4));
    }

    /// A multi-worker pool serves everything; every adapter participates
    /// under round-robin load (the paper's parallel-serve mode: different
    /// adapters fused on different workers concurrently).
    #[test]
    fn multi_worker_pool_serves_and_spreads_load() {
        let engine = native_engine(3, 3, 2);
        let mut streams = Vec::new();
        for i in 0..24 {
            streams.push(engine.submit(
                GenRequest::new(format!("a{}", i % 3), format!("q: item {i}?")).max_new(2),
            ));
        }
        let mut workers_seen = std::collections::HashSet::new();
        let mut adapters_seen = std::collections::HashSet::new();
        for s in streams {
            let r = s.wait().expect("reply");
            workers_seen.insert(r.worker);
            adapters_seen.insert(r.adapter);
        }
        let m = engine.metrics();
        assert_eq!(m.requests, 24);
        assert_eq!(adapters_seen.len(), 3);
        assert!(
            !workers_seen.is_empty() && workers_seen.iter().all(|&w| w < 3),
            "worker ids out of range: {workers_seen:?}"
        );
        engine.shutdown().unwrap();
    }

    /// Streamed replies: token events arrive in order, concatenate to the
    /// final text, and end with exactly one Done.
    #[test]
    fn streaming_events_compose_the_reply() {
        let engine = native_engine(1, 1, 4);
        let stream = engine.submit(GenRequest::new("a0", "q: stream?").max_new(6));
        let mut text = String::new();
        let mut tokens = 0usize;
        let mut reply = None;
        for ev in stream {
            match ev {
                GenEvent::Token { token, text: piece } => {
                    assert!((0..=260).contains(&token));
                    text.push_str(&piece);
                    tokens += 1;
                    assert!(reply.is_none(), "tokens after Done");
                }
                GenEvent::Done(r) => reply = Some(r),
                GenEvent::Error(e) => panic!("unexpected error: {e}"),
            }
        }
        let reply = reply.expect("missing Done event");
        assert_eq!(reply.tokens, tokens);
        assert_eq!(reply.text, text, "streamed pieces must compose the reply");
        engine.shutdown().unwrap();
    }

    /// Per-request sampling: temperature+seed are deterministic and a
    /// stop token truncates generation.
    #[test]
    fn per_request_sampling_params() {
        let engine = native_engine(1, 1, 4);
        let hot = |seed| {
            GenRequest::new("a0", "q: sample?")
                .max_new(6)
                .temperature(1.5)
                .top_k(8)
                .seed(seed)
        };
        let a = engine.call(hot(7)).unwrap();
        let b = engine.call(hot(7)).unwrap();
        assert_eq!(a.text, b.text, "same seed => same sample");

        // stop token: grab the first greedy token off the stream, then
        // ask the same (deterministic) request to stop on it
        let first = engine
            .submit(GenRequest::new("a0", "q: stop?").max_new(4))
            .find_map(|ev| match ev {
                GenEvent::Token { token, .. } => Some(token),
                _ => None,
            });
        if let Some(first) = first {
            let stopped = engine
                .call(GenRequest::new("a0", "q: stop?").max_new(4).stop(first))
                .unwrap();
            assert_eq!(stopped.tokens, 0, "stop token must halt before emitting it");
        }
        engine.shutdown().unwrap();
    }

    /// Runtime lifecycle: an unknown adapter fails only its own request
    /// (transactional switch), register makes it servable, fuse-mode
    /// creates a combined adapter, unregister removes it again.
    #[test]
    fn runtime_register_fuse_unregister() {
        let engine = native_engine(2, 1, 4);
        // unknown adapter: the request errors, the engine stays up
        let err = engine.call(GenRequest::new("newcomer", "q: ?").max_new(1));
        assert!(err.is_err());
        assert!(engine.call(GenRequest::new("a0", "q: ok?").max_new(1)).is_ok());

        // register at runtime
        let mut rng = Rng::seed(123);
        engine.register("newcomer", super::tiny_adapter(&mut rng));
        let r = engine
            .call(GenRequest::new("newcomer", "q: now?").max_new(1))
            .unwrap();
        assert_eq!(r.adapter, "newcomer");

        // fuse-mode: weighted combination is immediately servable
        engine.fuse("blend", &[("a0", 0.5), ("a1", 0.5)]).unwrap();
        assert!(engine.adapters().contains(&"blend".to_string()));
        assert!(engine.call(GenRequest::new("blend", "q: blend?").max_new(1)).is_ok());
        assert!(engine.fuse("bad", &[("missing", 1.0)]).is_err());

        // unregister: subsequent requests fail, the rest keep serving
        engine.unregister("newcomer").unwrap();
        assert!(engine.call(GenRequest::new("newcomer", "q: gone?").max_new(1)).is_err());
        assert!(engine.call(GenRequest::new("a1", "q: still?").max_new(1)).is_ok());
        engine.shutdown().unwrap();
    }

    /// Zero-window engines cut batches immediately and still serve
    /// correctly (the empty-window scheduling edge).
    #[test]
    fn zero_window_engine_serves() {
        let cfg = EngineConfig::new().workers(1).max_batch(4).window(Duration::ZERO);
        let engine = Engine::spawn(cfg, |_| {
            let rt = NativeBackend::builtin();
            let init = rt.load("init_tiny")?;
            let outs = init.run(&[Tensor::scalar_i32(3)])?;
            let params: HashMap<String, Tensor> =
                init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect();
            let snapshot = params.clone();
            let gm = GenModel::new(&rt, "tiny", params)?;
            Ok((gm, snapshot))
        });
        for i in 0..4 {
            let r = engine
                .call(GenRequest::new(BASE_ADAPTER, format!("q: {i}?")).max_new(1))
                .unwrap();
            assert_eq!(r.batch_size, 1);
        }
        assert_eq!(engine.metrics().requests, 4);
        engine.shutdown().unwrap();
    }

    fn builtin_gm(seed: i32) -> GenModel {
        let rt = NativeBackend::builtin();
        let init = rt.load("init_tiny").unwrap();
        let outs = init.run(&[Tensor::scalar_i32(seed)]).unwrap();
        let params: HashMap<String, Tensor> =
            init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect();
        GenModel::new(&rt, "tiny", params).unwrap()
    }

    /// Continuous batching must reproduce the reference full-recompute
    /// decode text-for-text: co-scheduled streams share a paged KV pool
    /// but each row's logits (and therefore its greedy tokens) are
    /// bit-identical to a solo contiguous decode.
    #[test]
    fn continuous_batching_matches_full_recompute_text() {
        let gm = builtin_gm(3);
        let prompts = ["q: is item 0 blue?", "q: sum 2 3?", "q: tiny?"];
        let reqs: Vec<DecodeRequest> =
            prompts.iter().map(|p| DecodeRequest::greedy(p.to_string(), 6)).collect();
        let want = gm.generate_full_recompute(&reqs, |_, _| {}).unwrap();

        // submit all three at once so they co-decode in one batch
        let engine = native_engine(1, 1, 4);
        let streams: Vec<_> = prompts
            .iter()
            .map(|p| engine.submit(GenRequest::new(BASE_ADAPTER, *p).max_new(6)))
            .collect();
        for ((s, want), p) in streams.into_iter().zip(&want).zip(&prompts) {
            let r = s.wait().expect("reply");
            assert_eq!(&r.text, want, "continuous batching diverged for {p:?}");
        }
        engine.shutdown().unwrap();
    }

    /// KV-pool backpressure: with a pool too small for two long streams,
    /// the youngest is evicted with **exactly one** terminal event, the
    /// oldest finishes normally, and the reclaimed blocks keep the
    /// engine serving. The prompts are long enough that the block demand
    /// crosses capacity during prefill, where no EOS can cut decoding
    /// short, so eviction is deterministic.
    #[test]
    fn eviction_delivers_one_terminal_event_and_engine_recovers() {
        let cfg = EngineConfig::new()
            .workers(1)
            .max_batch(2)
            .window(Duration::from_millis(100))
            .kv_block_tokens(4)
            .kv_blocks(9);
        let engine = Engine::spawn(cfg, |_| {
            let rt = NativeBackend::builtin();
            let init = rt.load("init_tiny")?;
            let outs = init.run(&[Tensor::scalar_i32(3)])?;
            let params: HashMap<String, Tensor> =
                init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect();
            let snapshot = params.clone();
            let gm = GenModel::new(&rt, "tiny", params)?;
            Ok((gm, snapshot))
        });
        // each stream needs ceil(32/4) = 8 blocks worst-case (fits the
        // 9-block pool alone); two in lockstep exceed 9 at position 16,
        // still inside the ~28-token prompts
        let long_a = "q: aaaaaaaaaaaaaaaaaaaaaaaaa?";
        let long_b = "q: bbbbbbbbbbbbbbbbbbbbbbbbb?";
        let a = engine.submit(GenRequest::new(BASE_ADAPTER, long_a).max_new(4));
        let b = engine.submit(GenRequest::new(BASE_ADAPTER, long_b).max_new(4));
        let ra = a.wait();
        assert!(ra.is_ok(), "oldest stream must survive eviction: {ra:?}");
        let mut terminals = 0usize;
        let mut err_text = String::new();
        for ev in b {
            match ev {
                GenEvent::Token { .. } => {}
                GenEvent::Done(_) => terminals += 1,
                GenEvent::Error(e) => {
                    terminals += 1;
                    err_text = e;
                }
            }
        }
        assert_eq!(terminals, 1, "evicted stream must see exactly one terminal event");
        assert!(err_text.contains("evicted"), "error must name the eviction: {err_text}");
        let m = engine.metrics();
        assert!(m.evictions >= 1, "eviction counter must move");
        // blocks were reclaimed: the pool serves fresh requests
        let r = engine
            .call(GenRequest::new(BASE_ADAPTER, "q: after?").max_new(2))
            .unwrap();
        assert!(r.tokens <= 2);
        engine.shutdown().unwrap();
    }

    /// Residency: an adapter that was spilled to disk and lazily
    /// reloaded must serve byte-identical text to an engine that never
    /// spilled it — exercised on the fused path (`hot_rps = 0`) and on
    /// the policy-unfused decode path (`hot_rps = ∞`). Each path is
    /// individually deterministic, so the texts must match exactly.
    #[test]
    fn spilled_and_reloaded_adapter_serves_identical_text() {
        let spawn = |cfg: EngineConfig| {
            let engine = Engine::spawn(cfg.workers(1).max_batch(2), |_| {
                let rt = NativeBackend::builtin();
                let init = rt.load("init_tiny")?;
                let outs = init.run(&[Tensor::scalar_i32(3)])?;
                let params: HashMap<String, Tensor> =
                    init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect();
                let snapshot = params.clone();
                Ok((GenModel::new(&rt, "tiny", params)?, snapshot))
            });
            let mut rng = Rng::seed(77);
            for a in 0..3 {
                engine.register(format!("a{a}"), tiny_adapter(&mut rng));
            }
            engine
        };
        // a0 -> a1 -> a0 -> a2 -> a0: with max_resident = 1 every change
        // spills the previous adapter and reloads the next from disk
        let serve = |engine: &Engine| -> Vec<String> {
            ["a0", "a1", "a0", "a2", "a0"]
                .iter()
                .enumerate()
                .map(|(i, a)| {
                    engine
                        .call(GenRequest::new(*a, format!("q: item {i}?")).max_new(4))
                        .unwrap()
                        .text
                })
                .collect()
        };
        for (tag, hot_rps) in [("fused", 0.0), ("unfused", f64::INFINITY)] {
            let dir = std::env::temp_dir()
                .join(format!("s2ft-serve-spill-{tag}-{}", std::process::id()));
            let _ = std::fs::remove_dir_all(&dir);

            let reference = spawn(EngineConfig::new().hot_rps(hot_rps));
            let want = serve(&reference);
            reference.shutdown().unwrap();

            let churn =
                spawn(EngineConfig::new().hot_rps(hot_rps).max_resident(1).adapter_dir(&dir));
            let got = serve(&churn);
            let r = churn.metrics().residency;
            assert!(r.spills >= 2, "{tag}: spill path not exercised: {r:?}");
            assert!(r.loads >= 2, "{tag}: reload path not exercised: {r:?}");
            assert!(r.registered == 3 && r.resident <= 2, "{tag}: budget ignored: {r:?}");
            if hot_rps == 0.0 {
                assert!(r.fused_batches >= 1 && r.unfused_batches == 0, "{tag}: {r:?}");
            } else {
                assert!(r.unfused_batches >= 1 && r.fused_batches == 0, "{tag}: {r:?}");
            }
            churn.shutdown().unwrap();
            assert_eq!(got, want, "{tag}: spilled+reloaded adapter text diverged");
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    /// The documented `ReplyStream::recv` contract: exactly one terminal
    /// event, then `None` forever.
    #[test]
    fn recv_returns_none_after_terminal() {
        let engine = native_engine(1, 1, 2);
        let s = engine.submit(GenRequest::new("a0", "q: done?").max_new(2));
        let mut terminals = 0usize;
        while let Some(ev) = s.recv() {
            if matches!(ev, GenEvent::Done(_) | GenEvent::Error(_)) {
                terminals += 1;
            }
        }
        assert_eq!(terminals, 1, "exactly one terminal event per stream");
        assert!(s.recv().is_none(), "recv after the terminal event must stay None");
        assert!(s.recv().is_none());
        engine.shutdown().unwrap();
    }

    /// Concurrent submits from several threads all complete across a
    /// 2-worker pool.
    #[test]
    fn concurrent_submits_complete() {
        let engine = std::sync::Arc::new(native_engine(2, 2, 4));
        let mut handles = Vec::new();
        for w in 0..4 {
            let e = engine.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = 0;
                for i in 0..3 {
                    let reply = e
                        .call(
                            GenRequest::new(format!("a{}", (w + i) % 2), format!("q: w{w} i{i}?"))
                                .max_new(1),
                        )
                        .expect("reply");
                    assert!(reply.batch_size >= 1);
                    got += 1;
                }
                got
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 12);
        let m = engine.metrics();
        assert_eq!(m.requests, 12);
        assert!(m.switches >= 1);
        std::sync::Arc::try_unwrap(engine)
            .ok()
            .expect("sole owner")
            .shutdown()
            .unwrap();
    }
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use repro::runtime::Runtime;

    fn artifacts_dir() -> Option<&'static str> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("meta.json").exists() {
            eprintln!("skipping pjrt serve test: no artifacts (run `make artifacts`)");
            return None;
        }
        // probe PJRT up front so the engine-thread builder cannot fail
        if let Err(e) = Runtime::new(dir) {
            eprintln!("skipping pjrt serve test: {e:#} (vendor the real xla crate)");
            return None;
        }
        Some(dir)
    }

    fn pjrt_engine(
        dir: &'static str,
        n_adapters: usize,
        workers: usize,
        max_batch: usize,
    ) -> Engine {
        spawn_engine(
            move || Ok(Box::new(Runtime::new(dir)?) as Box<dyn Executor>),
            n_adapters,
            workers,
            max_batch,
        )
    }

    #[test]
    fn engine_serves_all_requests_across_adapters() {
        let Some(dir) = artifacts_dir() else { return };
        super::engine_serves_all_requests_across_adapters(pjrt_engine(dir, 3, 1, 2));
    }

    #[test]
    fn engine_base_requests_use_pristine_weights() {
        let Some(dir) = artifacts_dir() else { return };
        super::engine_base_requests_use_pristine_weights(pjrt_engine(dir, 1, 1, 4));
    }

    #[test]
    fn shutdown_drains_cleanly() {
        let Some(dir) = artifacts_dir() else { return };
        super::shutdown_drains_cleanly(pjrt_engine(dir, 2, 1, 4));
    }
}
