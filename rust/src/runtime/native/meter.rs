//! Byte-accurate activation-memory accounting for the native train step.
//!
//! The paper's Fig. 5 memory claim is about *activations*, not just
//! parameter/optimizer state: S²FT's partial back-propagation only has to
//! cache the trainable slice of each activation, and caches nothing below
//! the shallowest trainable layer. [`ActivationMeter`] measures what the
//! interpreter actually holds:
//!
//! * [`ActivationMeter::retain_layer`] records the bytes a layer's forward
//!   cache keeps alive until the backward pass consumes it (the
//!   plan-sliced buffers, summed into [`ActivationMeter::cache_total`]);
//! * [`ActivationMeter::alloc`] / [`ActivationMeter::free`] track the
//!   transient working set (full-width buffers while a layer is being
//!   computed, gradient buffers in the backward walk), whose high-water
//!   mark is [`ActivationMeter::peak`].
//!
//! The numbers surface as the `act_bytes` / `act_peak_bytes` outputs of
//! the native `train_M_m_BxT` executable and flow through
//! `TrainMetrics::to_json` into `repro experiment fig5`, next to the
//! analytic state-bytes figure.
//!
//! Replan safety: a meter is constructed fresh per train-step call from
//! the step's `CachePlan` walk — it holds no plan-derived state across
//! calls, so a mid-run selection replan (plan-epoch bump, see
//! `runtime::native::TrainPlans`) needs no meter invalidation; the next
//! step's measurement reflects the new plan automatically.
//!
//! Accounting scope: this is an *activation* meter. `cache_total` /
//! `per_layer` are exact (actual buffer lengths of everything the cache
//! holds). The peak covers every named O(N·d)-and-larger activation or
//! activation-gradient buffer in the forward and backward passes. It
//! deliberately excludes (a) weight-gradient accumulators — they are
//! parameter-scale, bounded by the method's trainable parameters, and
//! belong to the analytic `state_bytes` side of the Fig 5 story — and
//! (b) the unnamed GEMM temporaries inside `dx1`/`dx2` accumulation
//! chains, RoPE cos/sin tables, and O(N)/O(d) norm scratch (at most
//! about one `N·d` buffer of undercount).

/// Live/peak byte accounting for one forward+backward pass.
#[derive(Debug, Clone, Default)]
pub struct ActivationMeter {
    /// Bytes currently live (retained cache + transients).
    live: u64,
    /// High-water mark of `live` over the pass.
    pub peak: u64,
    /// Total bytes the forward cache retained for the backward pass.
    pub cache_total: u64,
    /// Retained cache bytes per layer (index = layer).
    pub per_layer: Vec<u64>,
}

impl ActivationMeter {
    pub fn new(n_layers: usize) -> Self {
        Self { live: 0, peak: 0, cache_total: 0, per_layer: vec![0; n_layers] }
    }

    /// Account `bytes` of freshly allocated buffer space.
    pub fn alloc(&mut self, bytes: u64) {
        self.live += bytes;
        self.peak = self.peak.max(self.live);
    }

    /// Account `bytes` of released buffer space.
    pub fn free(&mut self, bytes: u64) {
        self.live = self.live.saturating_sub(bytes);
    }

    /// Mark `bytes` of the currently-live working set as retained by the
    /// forward cache of `layer` (they stay live until the backward pass
    /// frees them with [`ActivationMeter::free`]).
    pub fn retain_layer(&mut self, layer: usize, bytes: u64) {
        if layer < self.per_layer.len() {
            self.per_layer[layer] = bytes;
        }
        self.cache_total += bytes;
    }

    /// Retained bytes not attributed to a specific layer (final norm /
    /// head buffers).
    pub fn retain_final(&mut self, bytes: u64) {
        self.cache_total += bytes;
    }

    /// Bytes currently live (tests / diagnostics).
    pub fn live_bytes(&self) -> u64 {
        self.live
    }
}

/// Bytes of an f32 buffer (the meter's unit of account).
pub fn f32_bytes(len: usize) -> u64 {
    (len * 4) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_high_water_mark() {
        let mut m = ActivationMeter::new(2);
        m.alloc(100);
        m.alloc(50);
        assert_eq!(m.peak, 150);
        m.free(120);
        assert_eq!(m.live_bytes(), 30);
        m.alloc(10);
        assert_eq!(m.peak, 150, "peak must not decrease");
    }

    #[test]
    fn retained_layers_sum_into_cache_total() {
        let mut m = ActivationMeter::new(3);
        m.alloc(400);
        m.retain_layer(0, 100);
        m.retain_layer(2, 50);
        m.retain_final(8);
        assert_eq!(m.cache_total, 158);
        assert_eq!(m.per_layer, vec![100, 0, 50]);
        // out-of-range layers still count toward the total
        m.retain_layer(9, 7);
        assert_eq!(m.cache_total, 165);
    }

    #[test]
    fn free_saturates_at_zero() {
        let mut m = ActivationMeter::new(0);
        m.alloc(10);
        m.free(25);
        assert_eq!(m.live_bytes(), 0);
    }
}
