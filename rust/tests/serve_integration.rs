//! Serving-stack integration: router + batcher + engine with live S²FT
//! adapter switches mid-stream.
//!
//! Runs hermetically on the native backend (default features); the pjrt
//! module replays the same scenarios against real AOT artifacts when they
//! exist.

use std::collections::HashMap;
use std::time::Duration;

use repro::adapter::{AdapterStore, AnyAdapter, S2ftAdapter, S2ftLayerDelta};
use repro::runtime::{Executable, Executor, NativeBackend, Tensor};
use repro::serve::{Router, ServeRequest};
use repro::train::GenModel;
use repro::util::rng::Rng;

/// Spawn a router whose engine is built by `make_backend` (runs inside the
/// engine thread, PJRT-compatible).
fn spawn_router<F>(make_backend: F, n_adapters: usize, max_batch: usize) -> Router
where
    F: FnOnce() -> anyhow::Result<Box<dyn Executor>> + Send + 'static,
{
    Router::spawn(max_batch, Duration::from_millis(2), move || {
        let rt = make_backend()?;
        let init = rt.load("init_tiny")?;
        let outs = init.run(&[Tensor::scalar_i32(3)])?;
        let params: HashMap<String, Tensor> =
            init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect();
        let mm = rt.artifacts().model("tiny")?;
        let (d, hd) = (mm.dims.d_model, mm.head_dim());
        let mut store = AdapterStore::new();
        let mut rng = Rng::seed(77);
        for a in 0..n_adapters {
            let layers = (0..mm.dims.n_layers)
                .map(|_| {
                    let heads = rng.choose(mm.dims.n_heads, 1);
                    let wo_rows = repro::sparsity::expand_head_perm(&heads, hd);
                    S2ftLayerDelta {
                        wo_delta: (0..wo_rows.len() * d)
                            .map(|_| rng.normal_f32() * 1e-3)
                            .collect(),
                        wo_rows,
                        wd_rows: rng.choose(mm.dims.d_ff, 2),
                        wd_delta: (0..2 * d).map(|_| rng.normal_f32() * 1e-3).collect(),
                    }
                })
                .collect();
            store.insert(format!("a{a}"), AnyAdapter::S2ft(S2ftAdapter { layers, d_model: d }));
        }
        let snapshot = params.clone();
        let gm = GenModel::new(rt.as_ref(), "tiny", params)?;
        Ok((gm, store, snapshot))
    })
}

fn router_serves_all_requests_across_adapters(router: Router) {
    let mut rx = Vec::new();
    for i in 0..9 {
        rx.push(router.submit(ServeRequest {
            adapter: format!("a{}", i % 3),
            prompt: format!("q: item {i}?"),
            max_new: 3,
        }));
    }
    let mut served = 0;
    for r in rx {
        let reply = r.recv().expect("reply");
        assert!(reply.batch_size >= 1 && reply.batch_size <= 2);
        served += 1;
    }
    assert_eq!(served, 9);
    let m = router.metrics();
    assert_eq!(m.requests, 9);
    assert!(m.batches >= 5, "batcher should cap at max_batch=2: {}", m.batches);
    assert!(m.switches >= 3, "must have switched between 3 adapters");
    assert!(m.percentile_ms(0.5) > 0.0);
    assert_eq!(m.latencies_ms.len(), 9);
    router.shutdown().unwrap();
}

fn router_base_requests_use_pristine_weights(router: Router) {
    // adapter request then base request: engine must unfuse in between
    let r1 = router
        .call(ServeRequest { adapter: "a0".into(), prompt: "q: x?".into(), max_new: 2 })
        .unwrap();
    let r2 = router
        .call(ServeRequest { adapter: "base".into(), prompt: "q: x?".into(), max_new: 2 })
        .unwrap();
    // both served; determinism of each path is covered elsewhere — here we
    // assert the engine survives the fuse/unfuse round trip
    assert!(r1.text.len() <= 2 && r2.text.len() <= 2);
    let m = router.metrics();
    assert_eq!(m.requests, 2);
    router.shutdown().unwrap();
}

fn shutdown_drains_cleanly(router: Router) {
    let pending = router.submit(ServeRequest {
        adapter: "a1".into(),
        prompt: "q: last?".into(),
        max_new: 2,
    });
    router.shutdown().unwrap();
    // the queued request was served before shutdown completed
    assert!(pending.recv().is_ok());
}

/// Sequential calls make the switch count exact: every adapter change is
/// one store switch, repeats are free.
fn switch_count_matches_adapter_changes(router: Router) {
    for (i, adapter) in ["a0", "a1", "a1", "a0", "a2"].iter().enumerate() {
        router
            .call(ServeRequest {
                adapter: adapter.to_string(),
                prompt: format!("q: {i}?"),
                max_new: 1,
            })
            .unwrap();
    }
    let m = router.metrics();
    assert_eq!(m.requests, 5);
    // a0 -> a1 (skip dup) -> a0 -> a2 = 4 switches
    assert_eq!(m.switches, 4, "switch count must match adapter changes");
    router.shutdown().unwrap();
}

mod native {
    use super::*;

    fn native_router(n_adapters: usize, max_batch: usize) -> Router {
        spawn_router(
            || Ok(Box::new(NativeBackend::builtin()) as Box<dyn Executor>),
            n_adapters,
            max_batch,
        )
    }

    #[test]
    fn router_serves_all_requests_across_adapters() {
        super::router_serves_all_requests_across_adapters(native_router(3, 2));
    }

    #[test]
    fn router_base_requests_use_pristine_weights() {
        super::router_base_requests_use_pristine_weights(native_router(1, 4));
    }

    #[test]
    fn shutdown_drains_cleanly() {
        super::shutdown_drains_cleanly(native_router(2, 4));
    }

    #[test]
    fn switch_count_matches_adapter_changes() {
        super::switch_count_matches_adapter_changes(native_router(3, 4));
    }

    /// Concurrent submits from several threads all complete (the router
    /// side is just channel sends; the single engine thread serializes).
    #[test]
    fn concurrent_submits_complete() {
        let router = std::sync::Arc::new(native_router(2, 4));
        let mut handles = Vec::new();
        for w in 0..4 {
            let r = router.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = 0;
                for i in 0..3 {
                    let reply = r
                        .call(ServeRequest {
                            adapter: format!("a{}", (w + i) % 2),
                            prompt: format!("q: w{w} i{i}?"),
                            max_new: 1,
                        })
                        .expect("reply");
                    assert!(reply.batch_size >= 1);
                    got += 1;
                }
                got
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 12);
        let m = router.metrics();
        assert_eq!(m.requests, 12);
        assert!(m.switches >= 1);
        std::sync::Arc::try_unwrap(router)
            .ok()
            .expect("sole owner")
            .shutdown()
            .unwrap();
    }
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use repro::runtime::Runtime;

    fn artifacts_dir() -> Option<&'static str> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("meta.json").exists() {
            eprintln!("skipping pjrt serve test: no artifacts (run `make artifacts`)");
            return None;
        }
        // probe PJRT up front so the engine-thread builder cannot fail
        if let Err(e) = Runtime::new(dir) {
            eprintln!("skipping pjrt serve test: {e:#} (vendor the real xla crate)");
            return None;
        }
        Some(dir)
    }

    fn pjrt_router(dir: &'static str, n_adapters: usize, max_batch: usize) -> Router {
        spawn_router(
            move || Ok(Box::new(Runtime::new(dir)?) as Box<dyn Executor>),
            n_adapters,
            max_batch,
        )
    }

    #[test]
    fn router_serves_all_requests_across_adapters() {
        let Some(dir) = artifacts_dir() else { return };
        super::router_serves_all_requests_across_adapters(pjrt_router(dir, 3, 2));
    }

    #[test]
    fn router_base_requests_use_pristine_weights() {
        let Some(dir) = artifacts_dir() else { return };
        super::router_base_requests_use_pristine_weights(pjrt_router(dir, 1, 4));
    }

    #[test]
    fn shutdown_drains_cleanly() {
        let Some(dir) = artifacts_dir() else { return };
        super::shutdown_drains_cleanly(pjrt_router(dir, 2, 4));
    }
}
