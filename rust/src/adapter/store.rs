//! Adapter store + per-worker fused-state slot.
//!
//! [`AdapterStore`] holds many fine-tuned adapters behind interior
//! mutability (`RwLock` map of `Arc`-shared adapters), so one store can
//! be shared by every worker of a [`crate::serve::Engine`] pool and
//! mutated at runtime — register/unregister while requests are in
//! flight, the S-LoRA-style scenario from paper §6.2.
//!
//! Which adapter is *fused* into a given set of live weights is
//! per-worker state, tracked by [`AdapterSlot`]: each pool worker owns
//! its weights and one slot, and drives the four-step switch (unfuse
//! old, unload, load, fuse new). Because the slot keeps an `Arc` to the
//! active adapter, unfusing still works even after the adapter has been
//! unregistered from the store mid-flight.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::{anyhow, bail, Result};

use crate::runtime::Tensor;

use super::{LoraAdapter, S2ftAdapter};

/// An adapter of either supported family, as stored in an
/// [`AdapterStore`] or [`crate::serve::AdapterRegistry`].
pub enum AnyAdapter {
    /// S²FT structured-sparse delta: exact fuse/unfuse via scatter-add.
    S2ft(S2ftAdapter),
    /// Low-rank delta: fused via a ΔW GEMM, unfused by snapshot restore.
    Lora(LoraAdapter),
}

impl AnyAdapter {
    /// Parameter memory of this adapter in bytes (f32 deltas + row ids).
    pub fn bytes(&self) -> usize {
        match self {
            AnyAdapter::S2ft(a) => a.bytes(),
            AnyAdapter::Lora(a) => a.bytes(),
        }
    }

    /// Check that fusing into `params` cannot fail halfway: every
    /// referenced tensor exists, row indices are in bounds and delta
    /// buffers have the right length. Called *before* any mutation so
    /// [`AdapterSlot::switch_to`] stays transactional.
    pub fn validate(&self, params: &HashMap<String, Tensor>) -> Result<()> {
        match self {
            AnyAdapter::S2ft(a) => {
                for (i, l) in a.layers.iter().enumerate() {
                    for (proj, rows, delta) in [
                        ("wo", &l.wo_rows, &l.wo_delta),
                        ("wd", &l.wd_rows, &l.wd_delta),
                    ] {
                        if rows.is_empty() {
                            continue;
                        }
                        let name = format!("L{i}.{proj}");
                        let w = params
                            .get(&name)
                            .ok_or_else(|| anyhow!("adapter references missing {name:?}"))?;
                        w.as_f32()?;
                        if w.shape.len() != 2 || w.shape[1] != a.d_model {
                            bail!(
                                "adapter d_model {} incompatible with {name:?} shape {:?}",
                                a.d_model,
                                w.shape
                            );
                        }
                        if let Some(&r) = rows.iter().max() {
                            if r >= w.shape[0] {
                                bail!(
                                    "adapter row {r} out of bounds for {name:?} ({} rows)",
                                    w.shape[0]
                                );
                            }
                        }
                        if delta.len() != rows.len() * a.d_model {
                            bail!(
                                "adapter delta length {} != {} rows x d_model {}",
                                delta.len(),
                                rows.len(),
                                a.d_model
                            );
                        }
                    }
                }
                Ok(())
            }
            AnyAdapter::Lora(a) => {
                for (i, l) in a.layers.iter().enumerate() {
                    for (proj, fa, fb) in
                        [("wo", &l.wo_a, &l.wo_b), ("wd", &l.wd_a, &l.wd_b)]
                    {
                        let name = format!("L{i}.{proj}");
                        let w = params
                            .get(&name)
                            .ok_or_else(|| anyhow!("adapter references missing {name:?}"))?;
                        w.as_f32()?;
                        if fa.cols != fb.rows {
                            bail!(
                                "adapter {name}: A ({}, {}) incompatible with B ({}, {})",
                                fa.rows,
                                fa.cols,
                                fb.rows,
                                fb.cols
                            );
                        }
                        if w.shape != [fa.rows, fb.cols] {
                            bail!(
                                "adapter ΔW ({}, {}) does not match {name:?} shape {:?}",
                                fa.rows,
                                fb.cols,
                                w.shape
                            );
                        }
                    }
                }
                Ok(())
            }
        }
    }

    fn fuse(&self, params: &mut HashMap<String, Tensor>) -> Result<()> {
        match self {
            AnyAdapter::S2ft(a) => a.apply(params),
            AnyAdapter::Lora(a) => a.apply(params),
        }
    }

    fn unfuse(
        &self,
        params: &mut HashMap<String, Tensor>,
        base_snapshot: &HashMap<String, Tensor>,
    ) -> Result<()> {
        match self {
            AnyAdapter::S2ft(a) => a.remove(params),
            AnyAdapter::Lora(_) => {
                // LoRA cannot be unfused exactly (ΔW is dense); restore the
                // touched projections from the pristine snapshot instead.
                for (k, v) in base_snapshot {
                    if k.ends_with(".wo") || k.ends_with(".wd") {
                        params.insert(k.clone(), v.clone());
                    }
                }
                Ok(())
            }
        }
    }
}

/// Thread-safe adapter registry, shared across an engine pool.
#[derive(Default)]
pub struct AdapterStore {
    adapters: RwLock<HashMap<String, Arc<AnyAdapter>>>,
    switches: AtomicUsize,
}

impl AdapterStore {
    /// Empty store; equivalent to `AdapterStore::default()`.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or replace) an adapter. `&self`: safe while serving.
    pub fn insert(&self, id: impl Into<String>, adapter: AnyAdapter) {
        self.insert_arc(id, Arc::new(adapter));
    }

    /// [`insert`](Self::insert) behind an existing shared handle, so a
    /// caller (e.g. [`crate::serve::AdapterRegistry`]) can keep `Arc`
    /// identity between its own tracking and the store.
    pub fn insert_arc(&self, id: impl Into<String>, adapter: Arc<AnyAdapter>) {
        self.adapters.write().unwrap().insert(id.into(), adapter);
    }

    /// Unregister an adapter. Workers that still have it fused keep their
    /// own `Arc` and unfuse normally on their next switch.
    pub fn remove(&self, id: &str) -> Result<()> {
        self.adapters
            .write()
            .unwrap()
            .remove(id)
            .map(|_| ())
            .ok_or_else(|| anyhow!("adapter {id:?} not in store"))
    }

    /// Shared handle to the adapter registered under `id`, if any.
    pub fn get(&self, id: &str) -> Option<Arc<AnyAdapter>> {
        self.adapters.read().unwrap().get(id).cloned()
    }

    /// Registered adapter ids, sorted.
    pub fn ids(&self) -> Vec<String> {
        let mut v: Vec<String> = self.adapters.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Number of registered adapters.
    pub fn len(&self) -> usize {
        self.adapters.read().unwrap().len()
    }

    /// True when no adapter is registered.
    pub fn is_empty(&self) -> bool {
        self.adapters.read().unwrap().is_empty()
    }

    /// Sum of [`AnyAdapter::bytes`] over every registered adapter.
    pub fn total_bytes(&self) -> usize {
        self.adapters.read().unwrap().values().map(|a| a.bytes()).sum()
    }

    /// Total switches performed across all slots sharing this store.
    pub fn switches(&self) -> usize {
        self.switches.load(Ordering::Relaxed)
    }

    fn note_switch(&self) {
        self.switches.fetch_add(1, Ordering::Relaxed);
    }
}

/// Per-worker fused-adapter state: which adapter is currently merged into
/// *this worker's* live weights, and the transactional switch between
/// them (S²FT switch cost is two scatter_adds over s·d elements per
/// layer; LoRA pays a ΔW GEMM — the Fig 6a comparison).
#[derive(Default)]
pub struct AdapterSlot {
    active: Option<(String, Arc<AnyAdapter>)>,
}

impl AdapterSlot {
    /// Empty slot (no adapter fused).
    pub fn new() -> Self {
        Self::default()
    }

    /// Id currently fused into this slot's weights (if any).
    pub fn active(&self) -> Option<&str> {
        self.active.as_ref().map(|(id, _)| id.as_str())
    }

    /// Switch the live weights to `id` (no-op if the exact same adapter
    /// is already active — compared by `Arc` identity, so re-`register`ing
    /// an id with new weights takes effect on the next batch).
    ///
    /// Transactional: the new adapter is looked up and validated against
    /// the weight pool *before* the current one is unfused, so a missing
    /// or incompatible adapter returns an error with the previous adapter
    /// still fused and `active` unchanged. If fusing still fails after
    /// validation, the previous adapter is re-fused before returning.
    pub fn switch_to(
        &mut self,
        store: &AdapterStore,
        id: &str,
        params: &mut HashMap<String, Tensor>,
        base_snapshot: &HashMap<String, Tensor>,
    ) -> Result<()> {
        let next = store
            .get(id)
            .ok_or_else(|| anyhow!("adapter {id:?} not in store"))?;
        if self.switch_to_handle(id, next, params, base_snapshot)? {
            store.note_switch();
        }
        Ok(())
    }

    /// [`switch_to`](Self::switch_to) with a pre-resolved adapter handle
    /// instead of a store lookup — the entry point used by the serve
    /// residency layer, where the adapter comes from a pinned
    /// [`crate::serve::AdapterLease`] rather than an [`AdapterStore`].
    /// Same transactional contract; returns `true` when weights actually
    /// changed (`false` for the Arc-identity no-op), so the caller owns
    /// switch accounting.
    pub fn switch_to_handle(
        &mut self,
        id: &str,
        next: Arc<AnyAdapter>,
        params: &mut HashMap<String, Tensor>,
        base_snapshot: &HashMap<String, Tensor>,
    ) -> Result<bool> {
        if let Some((aid, cur)) = &self.active {
            if aid == id && Arc::ptr_eq(cur, &next) {
                return Ok(false);
            }
        }
        next.validate(params)?;
        let prev = self.active.take();
        if let Some((_, a)) = &prev {
            a.unfuse(params, base_snapshot)?;
        }
        match next.fuse(params) {
            Ok(()) => {
                self.active = Some((id.to_string(), next));
                Ok(true)
            }
            Err(e) => {
                if let Some((pid, a)) = prev {
                    if a.fuse(params).is_ok() {
                        self.active = Some((pid, a));
                    }
                }
                Err(e)
            }
        }
    }

    /// Unfuse whatever is active, restoring pristine base weights.
    pub fn deactivate(
        &mut self,
        params: &mut HashMap<String, Tensor>,
        base_snapshot: &HashMap<String, Tensor>,
    ) -> Result<()> {
        if let Some((_, a)) = self.active.take() {
            a.unfuse(params, base_snapshot)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::S2ftLayerDelta;

    fn adapter(val: f32) -> AnyAdapter {
        AnyAdapter::S2ft(S2ftAdapter {
            layers: vec![S2ftLayerDelta {
                wo_rows: vec![],
                wo_delta: vec![],
                wd_rows: vec![0],
                wd_delta: vec![val; 4],
            }],
            d_model: 4,
        })
    }

    fn base() -> HashMap<String, Tensor> {
        let mut p = HashMap::new();
        p.insert("L0.wo".to_string(), Tensor::zeros(vec![4, 4]));
        p.insert("L0.wd".to_string(), Tensor::zeros(vec![4, 4]));
        p
    }

    #[test]
    fn switch_sequence_restores_weights() {
        let snapshot = base();
        let mut params = base();
        let store = AdapterStore::new();
        let mut slot = AdapterSlot::new();
        store.insert("a", adapter(1.0));
        store.insert("b", adapter(2.0));

        slot.switch_to(&store, "a", &mut params, &snapshot).unwrap();
        assert_eq!(params["L0.wd"].as_f32().unwrap()[0], 1.0);
        slot.switch_to(&store, "b", &mut params, &snapshot).unwrap();
        assert_eq!(params["L0.wd"].as_f32().unwrap()[0], 2.0);
        assert_eq!(store.switches(), 2);
        // switching to the active id is free
        slot.switch_to(&store, "b", &mut params, &snapshot).unwrap();
        assert_eq!(store.switches(), 2);
        slot.deactivate(&mut params, &snapshot).unwrap();
        assert_eq!(params["L0.wd"].as_f32().unwrap()[0], 0.0);
        assert!(slot.active().is_none());
    }

    #[test]
    fn missing_adapter_errors() {
        let snapshot = base();
        let mut params = base();
        let store = AdapterStore::new();
        let mut slot = AdapterSlot::new();
        assert!(slot.switch_to(&store, "nope", &mut params, &snapshot).is_err());
    }

    /// Regression: a failed switch must leave the previous adapter fused
    /// and `active` pointing at it — not stale, not cleared.
    #[test]
    fn failed_switch_is_transactional() {
        let snapshot = base();
        let mut params = base();
        let store = AdapterStore::new();
        let mut slot = AdapterSlot::new();
        store.insert("a", adapter(1.0));
        // references L1.wd which the pool doesn't have
        store.insert(
            "bad",
            AnyAdapter::S2ft(S2ftAdapter {
                layers: vec![
                    S2ftLayerDelta {
                        wd_rows: vec![0],
                        wd_delta: vec![9.0; 4],
                        ..Default::default()
                    },
                    S2ftLayerDelta {
                        wd_rows: vec![0],
                        wd_delta: vec![9.0; 4],
                        ..Default::default()
                    },
                ],
                d_model: 4,
            }),
        );
        // also an out-of-bounds row variant
        store.insert(
            "oob",
            AnyAdapter::S2ft(S2ftAdapter {
                layers: vec![S2ftLayerDelta {
                    wd_rows: vec![99],
                    wd_delta: vec![9.0; 4],
                    ..Default::default()
                }],
                d_model: 4,
            }),
        );

        slot.switch_to(&store, "a", &mut params, &snapshot).unwrap();
        for bad in ["missing-id", "bad", "oob"] {
            let err = slot.switch_to(&store, bad, &mut params, &snapshot);
            assert!(err.is_err(), "{bad} must fail");
            assert_eq!(slot.active(), Some("a"), "{bad}: active id rolled back");
            assert_eq!(
                params["L0.wd"].as_f32().unwrap()[0],
                1.0,
                "{bad}: previous adapter must stay fused"
            );
        }
        assert_eq!(store.switches(), 1, "failed switches must not count");
        // the engine is still fully operational after the failures
        store.insert("b", adapter(2.0));
        slot.switch_to(&store, "b", &mut params, &snapshot).unwrap();
        assert_eq!(params["L0.wd"].as_f32().unwrap()[0], 2.0);
    }

    /// Re-registering an id with new weights must take effect on the next
    /// switch even for a worker already fused on that id (Arc identity,
    /// not id string, decides the no-op fast path).
    #[test]
    fn reregistered_adapter_replaces_fused_version() {
        let snapshot = base();
        let mut params = base();
        let store = AdapterStore::new();
        let mut slot = AdapterSlot::new();
        store.insert("a", adapter(1.0));
        slot.switch_to(&store, "a", &mut params, &snapshot).unwrap();
        assert_eq!(params["L0.wd"].as_f32().unwrap()[0], 1.0);
        // same id, same version: free
        slot.switch_to(&store, "a", &mut params, &snapshot).unwrap();
        assert_eq!(store.switches(), 1);
        // replace the adapter under the same id while fused
        store.insert("a", adapter(5.0));
        slot.switch_to(&store, "a", &mut params, &snapshot).unwrap();
        assert_eq!(
            params["L0.wd"].as_f32().unwrap()[0],
            5.0,
            "v2 weights must be fused after re-register (v1 unfused first)"
        );
        assert_eq!(store.switches(), 2);
    }

    /// Unregistering an adapter that is fused elsewhere: the slot keeps
    /// its Arc and can still unfuse cleanly.
    #[test]
    fn unregister_while_fused_still_unfuses() {
        let snapshot = base();
        let mut params = base();
        let store = AdapterStore::new();
        let mut slot = AdapterSlot::new();
        store.insert("a", adapter(1.0));
        slot.switch_to(&store, "a", &mut params, &snapshot).unwrap();
        store.remove("a").unwrap();
        assert!(store.is_empty());
        assert!(store.remove("a").is_err(), "double-unregister errors");
        slot.deactivate(&mut params, &snapshot).unwrap();
        assert_eq!(params["L0.wd"].as_f32().unwrap()[0], 0.0);
    }
}
