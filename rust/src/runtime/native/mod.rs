//! Native backend: a pure-Rust interpreter of the artifact contract.
//!
//! Instead of compiling AOT HLO, [`NativeBackend`] recognizes artifact
//! *names* (`init_M`, `fwd_M_BxT`, `eval_M_BxT`, `prepare_M_m_BxT`,
//! `train_M_m_BxT`, `merge_M_m`) and executes the corresponding model
//! semantics directly on [`Tensor`]s: seeded init, LLaMA-style
//! forward/eval, an AdamW train step with S²FT partial backprop, and the
//! method-layout merge. Supported methods: `fullft` and `s2ft` (selection
//! strategies R and W); the remaining baselines exist only as AOT
//! artifacts under the `pjrt` feature.
//!
//! The train step's backward is *plan-truncated* (paper §4): a cache plan
//! derived from the gradient plan slices `act`/`attn` down to the
//! trainable channels at forward time, retains nothing below the
//! shallowest trainable layer, and the backward walk stops there, skipping
//! every dX-only GEMM no surviving gradient reads. An [`ActivationMeter`]
//! measures the retained cache and live peak byte-accurately; the numbers
//! surface as the native train executables' `act_bytes` /
//! `act_peak_bytes` outputs. `S2FT_FULL_BACKWARD=1` forces the
//! cache-everything, walk-to-zero reference (bit-identical trainable
//! gradients, proptest-enforced).
//!
//! Specs are synthesized on demand from the model/method layout sections,
//! so any (batch, seq) shape works — there is no artifact enumeration
//! step and no files on disk.

pub mod builtin;
mod decode;
pub mod meter;
mod model;

pub use decode::NativeDecodeSession;
pub use meter::ActivationMeter;
pub use model::set_full_backward_override;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use super::meta::{ArtifactMeta, Meta, MethodMeta, ModelMeta, TensorSpec};
use super::{check_inputs, Artifacts, Executable, Executor, Tensor};

/// Pure-Rust execution backend (hermetic: no Python, no XLA, no files).
pub struct NativeBackend {
    artifacts: Artifacts,
    cache: Mutex<HashMap<String, Arc<dyn Executable>>>,
}

impl NativeBackend {
    /// Backend over the builtin model set (tiny/small/base).
    pub fn builtin() -> Self {
        Self::with_artifacts(Artifacts::from_meta(builtin::builtin_meta()))
    }

    /// Backend over an explicit meta (e.g. parsed from an artifact
    /// directory's meta.json — the native interpreter then runs at the
    /// exact AOT shapes).
    pub fn with_artifacts(artifacts: Artifacts) -> Self {
        Self { artifacts, cache: Mutex::new(HashMap::new()) }
    }

    /// Backend over a custom in-memory meta.
    pub fn with_meta(meta: Meta) -> Self {
        Self::with_artifacts(Artifacts::from_meta(meta))
    }
}

impl Executor for NativeBackend {
    fn artifacts(&self) -> &Artifacts {
        &self.artifacts
    }

    fn load(&self, name: &str) -> Result<Arc<dyn Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let kind = Kind::parse(name)
            .with_context(|| format!("native backend cannot interpret artifact {name:?}"))?;
        let spec = spec_for(&self.artifacts, name, &kind)?;
        let exec: Arc<dyn Executable> = Arc::new(NativeExecutable {
            name: name.to_string(),
            spec,
            kind,
            meta: self.artifacts.meta.clone(),
            plans: Mutex::new(None),
        });
        self.cache.lock().unwrap().insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    fn evict(&self, name: &str) {
        self.cache.lock().unwrap().remove(name);
    }

    fn platform(&self) -> String {
        "native".to_string()
    }

    fn load_train_variant(
        &self,
        model: &str,
        tag: &str,
        base_method: &str,
        counts_per_layer: &[HashMap<String, usize>],
        b: usize,
        t: usize,
    ) -> Result<Arc<dyn Executable>> {
        let mm = self.artifacts.model(model)?;
        let base_meth = native_method(mm, base_method)?;
        if base_meth.method != "s2ft" {
            bail!("method {base_method:?} has no unit-count layout to vary");
        }
        let variant = builtin::s2ft_method_variant(mm, base_meth, counts_per_layer);
        let mut meta = (*self.artifacts.meta).clone();
        meta.models
            .get_mut(model)
            .ok_or_else(|| anyhow!("model {model:?} not in meta"))?
            .methods
            .insert(tag.to_string(), variant);
        let meta = Arc::new(meta);
        let name = format!("train_{model}_{tag}_{b}x{t}");
        let kind = Kind::Train { model: model.to_string(), method: tag.to_string(), b, t };
        let spec = synthesize_spec(&meta.models[model], &kind);
        let exec: Arc<dyn Executable> = Arc::new(NativeExecutable {
            name: name.clone(),
            spec,
            kind,
            meta,
            plans: Mutex::new(None),
        });
        // Always overwrite: the cache entry exists only so `evict` works
        // uniformly; serving a stale layout from it would be a bug.
        self.cache.lock().unwrap().insert(name, exec.clone());
        Ok(exec)
    }

    fn decoder(&self) -> Option<Arc<dyn super::DecoderProvider>> {
        Some(Arc::new(decode::NativeDecoderProvider {
            meta: self.artifacts.meta.clone(),
        }))
    }
}

/// The artifact families the native interpreter understands.
#[derive(Debug, Clone)]
enum Kind {
    Init { model: String },
    Fwd { model: String, b: usize, t: usize },
    Eval { model: String, b: usize, t: usize },
    Prepare { model: String, method: String, b: usize, t: usize },
    Train { model: String, method: String, b: usize, t: usize },
    Merge { model: String, method: String },
    /// Gradient-magnitude unit scores over a probe batch in base layout —
    /// the measurement dynamic selection strategies replan from.
    GradNorm { model: String, b: usize, t: usize },
}

fn parse_bt(s: &str) -> Option<(usize, usize)> {
    let (b, t) = s.split_once('x')?;
    Some((b.parse().ok()?, t.parse().ok()?))
}

impl Kind {
    fn parse(name: &str) -> Result<Kind> {
        let parts: Vec<&str> = name.split('_').collect();
        let kind = match parts.as_slice() {
            ["init", m] => Kind::Init { model: m.to_string() },
            ["fwd", m, bt] => {
                let (b, t) = parse_bt(bt).context("bad BxT suffix")?;
                Kind::Fwd { model: m.to_string(), b, t }
            }
            ["eval", m, bt] => {
                let (b, t) = parse_bt(bt).context("bad BxT suffix")?;
                Kind::Eval { model: m.to_string(), b, t }
            }
            ["prepare", m, meth, bt] => {
                let (b, t) = parse_bt(bt).context("bad BxT suffix")?;
                Kind::Prepare { model: m.to_string(), method: meth.to_string(), b, t }
            }
            ["train", m, meth, bt] => {
                let (b, t) = parse_bt(bt).context("bad BxT suffix")?;
                Kind::Train { model: m.to_string(), method: meth.to_string(), b, t }
            }
            ["merge", m, meth] => {
                Kind::Merge { model: m.to_string(), method: meth.to_string() }
            }
            ["gradnorm", m, bt] => {
                let (b, t) = parse_bt(bt).context("bad BxT suffix")?;
                Kind::GradNorm { model: m.to_string(), b, t }
            }
            _ => bail!("unrecognized artifact name shape"),
        };
        Ok(kind)
    }

    fn model(&self) -> &str {
        match self {
            Kind::Init { model }
            | Kind::Fwd { model, .. }
            | Kind::Eval { model, .. }
            | Kind::Prepare { model, .. }
            | Kind::Train { model, .. }
            | Kind::Merge { model, .. }
            | Kind::GradNorm { model, .. } => model,
        }
    }

    fn method(&self) -> Option<&str> {
        match self {
            Kind::Prepare { method, .. }
            | Kind::Train { method, .. }
            | Kind::Merge { method, .. } => Some(method),
            _ => None,
        }
    }
}

/// Check this method is natively executable and fetch its meta.
fn native_method<'m>(mm: &'m ModelMeta, tag: &str) -> Result<&'m MethodMeta> {
    let method = mm.method(tag)?;
    match method.method.as_str() {
        "fullft" => Ok(method),
        "s2ft" => {
            if !matches!(method.selection.as_str(), "r" | "w") {
                bail!(
                    "native backend supports s2ft selection strategies R and W \
                     (got {:?}); use the pjrt backend for A/S/G",
                    method.selection
                );
            }
            Ok(method)
        }
        other => bail!(
            "method {other:?} is only available through AOT artifacts \
             (--features pjrt); the native backend implements fullft and s2ft"
        ),
    }
}

/// Resolve an artifact spec: prefer an explicit meta.json entry, else
/// synthesize one from the model/method layout sections.
fn spec_for(artifacts: &Artifacts, name: &str, kind: &Kind) -> Result<ArtifactMeta> {
    if let Ok(spec) = artifacts.artifact(name) {
        return Ok(spec.clone());
    }
    let mm = artifacts.model(kind.model())?;
    if let Some(tag) = kind.method() {
        native_method(mm, tag)?;
    }
    Ok(synthesize_spec(mm, kind))
}

fn ts(name: &str, shape: Vec<usize>, dtype: &str) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape, dtype: dtype.to_string() }
}

fn section(shapes: &[super::NamedShape], dtype: &str) -> Vec<TensorSpec> {
    shapes.iter().map(|s| ts(&s.name, s.shape.clone(), dtype)).collect()
}

fn batch_specs(b: usize, t: usize) -> Vec<TensorSpec> {
    vec![
        ts("tokens", vec![b, t], "i32"),
        ts("targets", vec![b, t], "i32"),
        ts("loss_mask", vec![b, t], "f32"),
    ]
}

/// Build the interface description `aot.py` would have recorded for this
/// artifact (names, shapes, dtypes, exact ordering).
fn synthesize_spec(mm: &ModelMeta, kind: &Kind) -> ArtifactMeta {
    let base = section(&mm.base_params, "f32");
    let (inputs, outputs) = match kind {
        Kind::Init { .. } => (vec![ts("seed", vec![], "i32")], base),
        Kind::Fwd { b, t, .. } => {
            let mut inputs = base;
            inputs.push(ts("tokens", vec![*b, *t], "i32"));
            (inputs, vec![ts("logits", vec![*b, *t, mm.dims.vocab], "f32")])
        }
        Kind::Eval { b, t, .. } => {
            let mut inputs = base;
            inputs.extend(batch_specs(*b, *t));
            (inputs, vec![ts("loss", vec![], "f32"), ts("ncorrect", vec![], "f32")])
        }
        Kind::Prepare { method, b, t, .. } => {
            let m = &mm.methods[method.as_str()];
            let mut inputs = base;
            inputs.push(ts("seed", vec![], "i32"));
            inputs.extend(batch_specs(*b, *t));
            let mut outputs = section(&m.trainable, "f32");
            outputs.extend(section(&m.frozen, "f32"));
            outputs.extend(section(&m.perms, "i32"));
            (inputs, outputs)
        }
        Kind::Train { method, b, t, .. } => {
            let m = &mm.methods[method.as_str()];
            let mut inputs = section(&m.trainable, "f32");
            inputs.extend(section(&m.frozen, "f32"));
            for o in &m.opt {
                inputs.push(ts(&format!("m.{}", o.name), o.shape.clone(), "f32"));
            }
            for o in &m.opt {
                inputs.push(ts(&format!("v.{}", o.name), o.shape.clone(), "f32"));
            }
            inputs.push(ts("step", vec![], "f32"));
            inputs.extend(batch_specs(*b, *t));
            inputs.extend(section(&m.aux, "f32"));
            let mut outputs = Vec::new();
            for s in &m.trainable {
                outputs.push(ts(&format!("new.{}", s.name), s.shape.clone(), "f32"));
            }
            for o in &m.opt {
                outputs.push(ts(&format!("new_m.{}", o.name), o.shape.clone(), "f32"));
            }
            for o in &m.opt {
                outputs.push(ts(&format!("new_v.{}", o.name), o.shape.clone(), "f32"));
            }
            // measured activation memory (native-only outputs; AOT specs
            // from meta.json simply omit them and the trainer copes)
            outputs.push(ts("act_bytes", vec![], "i32"));
            outputs.push(ts("act_peak_bytes", vec![], "i32"));
            outputs.push(ts("loss", vec![], "f32"));
            (inputs, outputs)
        }
        Kind::Merge { method, .. } => {
            let m = &mm.methods[method.as_str()];
            let mut inputs = section(&m.trainable, "f32");
            inputs.extend(section(&m.frozen, "f32"));
            inputs.extend(section(&m.perms, "i32"));
            (inputs, base)
        }
        Kind::GradNorm { b, t, .. } => {
            let mut inputs = base;
            inputs.extend(batch_specs(*b, *t));
            let l = mm.dims.n_layers;
            (
                inputs,
                vec![
                    ts("chan_grad_norms", vec![l, mm.dims.d_ff], "f32"),
                    ts("head_grad_norms", vec![l, mm.dims.n_heads], "f32"),
                ],
            )
        }
    };
    ArtifactMeta { file: "<native>".to_string(), inputs, outputs }
}

/// One interpreted artifact.
struct NativeExecutable {
    name: String,
    spec: ArtifactMeta,
    kind: Kind,
    meta: Arc<Meta>,
    /// Train-kind only: the plan bundle (gradient plan + cache-retention
    /// plans), derived once from the method layout on first use. Plan
    /// invalidation is by *plan epoch*: a replanning trainer evicts this
    /// executable and loads a fresh one, so stale plans can never survive
    /// a selection change.
    plans: Mutex<Option<Arc<model::TrainPlans>>>,
}

impl Executable for NativeExecutable {
    fn name(&self) -> &str {
        &self.name
    }

    fn spec(&self) -> &ArtifactMeta {
        &self.spec
    }

    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        check_inputs(&self.name, &self.spec, inputs)?;
        let named: HashMap<&str, &Tensor> = self
            .spec
            .inputs
            .iter()
            .map(|s| s.name.as_str())
            .zip(inputs)
            .collect();
        let mm = self
            .meta
            .models
            .get(self.kind.model())
            .ok_or_else(|| anyhow!("model {:?} disappeared from meta", self.kind.model()))?;
        let mut out = match &self.kind {
            Kind::Init { .. } => {
                let seed = named["seed"].as_i32()?[0];
                model::init_params(mm, seed)
            }
            Kind::Fwd { b, t, .. } => {
                let logits = model::forward_logits(mm, &named, named["tokens"], *b, *t)?;
                HashMap::from([("logits".to_string(), logits)])
            }
            Kind::Eval { b, t, .. } => {
                let (loss, ncorrect) = model::eval_batch(mm, &named, *b, *t)?;
                HashMap::from([
                    ("loss".to_string(), Tensor::scalar_f32(loss)),
                    ("ncorrect".to_string(), Tensor::scalar_f32(ncorrect)),
                ])
            }
            Kind::Prepare { method, .. } => {
                let meth = native_method(mm, method)?;
                model::prepare(mm, meth, &named)?
            }
            Kind::Train { method, b, t, .. } => {
                let meth = native_method(mm, method)?;
                let plans = {
                    let mut guard = self.plans.lock().unwrap();
                    match guard.as_ref() {
                        Some(p) => p.clone(),
                        None => {
                            let p = Arc::new(model::TrainPlans::new(mm, meth));
                            *guard = Some(p.clone());
                            p
                        }
                    }
                };
                model::train_step(mm, meth, &plans, &named, *b, *t)?
            }
            Kind::Merge { method, .. } => {
                let meth = native_method(mm, method)?;
                model::merge(mm, meth, &named)?
            }
            Kind::GradNorm { b, t, .. } => model::grad_unit_norms(mm, &named, *b, *t)?,
        };
        self.spec
            .outputs
            .iter()
            .map(|s| {
                out.remove(&s.name)
                    .ok_or_else(|| anyhow!("{}: missing output {:?}", self.name, s.name))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parsing() {
        assert!(matches!(Kind::parse("init_tiny"), Ok(Kind::Init { .. })));
        let k = Kind::parse("train_tiny_s2ft-pallas_2x32").unwrap();
        match k {
            Kind::Train { ref model, ref method, b, t } => {
                assert_eq!(model, "tiny");
                assert_eq!(method, "s2ft-pallas");
                assert_eq!((b, t), (2, 32));
            }
            other => panic!("wrong kind {other:?}"),
        }
        assert!(Kind::parse("bogus").is_err());
        assert!(Kind::parse("fwd_tiny_2y32").is_err());
    }

    #[test]
    fn load_caches_and_evicts() {
        let be = NativeBackend::builtin();
        let a = be.load("init_tiny").unwrap();
        let b = be.load("init_tiny").unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        be.evict("init_tiny");
        let c = be.load("init_tiny").unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
    }

    #[test]
    fn unsupported_method_is_rejected_with_hint() {
        let be = NativeBackend::builtin();
        let err = be.load("train_tiny_lora_2x32").unwrap_err();
        assert!(format!("{err:#}").contains("method"), "{err:#}");
    }

    #[test]
    fn synthesized_train_spec_orders_sections() {
        let be = NativeBackend::builtin();
        let exe = be.load("train_tiny_s2ft_2x32").unwrap();
        let spec = exe.spec();
        let names: Vec<&str> = spec.inputs.iter().map(|s| s.name.as_str()).collect();
        assert!(names.contains(&"L0.wo_t"));
        assert!(names.contains(&"m.L0.wd_t"));
        assert!(names.contains(&"step"));
        let out_names: Vec<&str> = spec.outputs.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(*out_names.last().unwrap(), "loss");
        assert!(out_names.contains(&"new.L1.wo_t"));
    }
}
