//! Deep-linear-network theory simulator (paper §4 + App. F).
//!
//! Implements the exact setting of F.2 with population (n→∞) risks and
//! Σx = I: pre-trained L-layer linear net, fine-tune layer ℓ with either
//! the min-norm LoRA solution (rank r, Lemma F.9) or the min-norm S²FT
//! solution (sparsity s, Lemma F.12), then evaluate in-distribution and
//! out-of-distribution excess risks to check Theorem 4.2 / F.8.

use crate::linalg::{svd, Mat};
use crate::util::rng::Rng;

/// Problem instance: pre-trained net + ID/OOD regression targets.
pub struct DeepLinear {
    /// layer weights W_1..W_L (W\[l\]: (d_l, d_{l-1}))
    pub weights: Vec<Mat>,
    pub dims: Vec<usize>,
    /// in-distribution coefficient matrix (q, p)
    pub b_id: Mat,
    /// out-of-distribution coefficient matrix (q, p)
    pub b_od: Mat,
}

pub struct Config {
    pub dims: Vec<usize>, // d_0..d_L
    /// ℓ (1-based) — which layer gets fine-tuned
    pub layer: usize,
    /// magnitude of the fine-tuning-task displacement B_id − W_pre
    /// (realizable through layer ℓ). This is what fine-tuning chases and
    /// what a forgetful method drags the model away from W_pre by.
    pub task_shift: f32,
    /// magnitude of the residual OOD mismatch B_od − W_pre. The theorem's
    /// regime (paper §4.1) is "the pre-trained model is already good OOD":
    /// keep this small relative to task_shift.
    pub ood_noise: f32,
    /// rank of the realizable in-distribution residual (keeps
    /// `rank(Σf) >= s, r` as Theorem F.8 requires)
    pub shift_rank: usize,
    pub seed: u64,
}

impl DeepLinear {
    pub fn generate(cfg: &Config) -> DeepLinear {
        let mut rng = Rng::seed(cfg.seed);
        let l = cfg.dims.len() - 1;
        let weights: Vec<Mat> = (0..l)
            .map(|i| {
                // near-orthogonal init keeps condition numbers mild (F.6)
                Mat::randn(cfg.dims[i + 1], cfg.dims[i], &mut rng)
                    .scale(1.0 / (cfg.dims[i] as f32).sqrt())
            })
            .collect();
        let w_pre = product(&weights, 0, l);
        // Realizable in-distribution shift through the frozen outer factors
        // (Thm F.8 premise: B_id = W̄_{ℓ+1} B̃ W̲_{ℓ-1}): perturb layer ℓ.
        let above = product(&weights, cfg.layer, l); // W̄_{ℓ+1}
        let below = product(&weights, 0, cfg.layer - 1); // W̲_{ℓ-1}
        let (dl, dl1) = (cfg.dims[cfg.layer], cfg.dims[cfg.layer - 1]);
        let tilt = low_rank(dl, dl1, cfg.shift_rank, &mut rng)
            .scale(cfg.task_shift / (dl1 as f32).sqrt());
        let b_id = w_pre.add(&above.matmul(&tilt).matmul(&below));
        // OOD target stays close to the PRE-TRAINED map (the paper's
        // forgetting regime): B_od = W_pre + small generic mismatch, so the
        // label shift B_od − B_id ≈ −(B_id − W_pre) is dominated by the
        // fine-tuning displacement.
        let q = *cfg.dims.last().unwrap();
        let p = cfg.dims[0];
        let noise = low_rank(q, p, cfg.shift_rank, &mut rng)
            .scale(cfg.ood_noise / (p as f32).sqrt());
        let b_od = w_pre.add(&noise);
        DeepLinear { weights, dims: cfg.dims.clone(), b_id, b_od }
    }

    pub fn w_pre(&self) -> Mat {
        product(&self.weights, 0, self.weights.len())
    }

    /// W̄_{ℓ+1}: product of layers above ℓ (identity if ℓ = L).
    pub fn above(&self, layer: usize) -> Mat {
        product(&self.weights, layer, self.weights.len())
    }

    /// W̲_{ℓ-1}: product of layers below ℓ (identity if ℓ = 1).
    pub fn below(&self, layer: usize) -> Mat {
        product(&self.weights, 0, layer - 1)
    }

    /// Excess risk of the map `f` under target B (Σx = I, n→∞):
    /// E‖(B - f) x‖² = ‖B - f‖_F².
    pub fn excess_risk(&self, f: &Mat, b: &Mat) -> f64 {
        let d = b.sub(f).fro_norm() as f64;
        d * d
    }

    /// Fine-tuned map given a layer-ℓ update Δ.
    pub fn finetuned(&self, layer: usize, delta: &Mat) -> Mat {
        let mid = self.weights[layer - 1].add(delta);
        self.above(layer).matmul(&mid).matmul(&self.below(layer))
    }

    /// Population min-norm LoRA update of rank r (Lemma F.9, Σx = I):
    /// Δ = W̄† SVD_r(W̄ W̄† D W̲ᵀ A†) A†, where D = B_id - W_pre and
    /// A = (W̲ W̲ᵀ)^{1/2}.
    pub fn lora_update(&self, layer: usize, r: usize) -> Mat {
        let above = self.above(layer);
        let below = self.below(layer);
        let d = self.b_id.sub(&self.w_pre());
        let a2 = below.matmul(&below.t());
        let a = sqrt_psd(&a2);
        let a_pinv = a.pinv();
        let above_pinv = above.pinv();
        let proj = above.matmul(&above_pinv); // Φ'Φ'^T
        let m = proj.matmul(&d).matmul(&below.t()).matmul(&a_pinv);
        let m_r = m.svd_truncate(r);
        above_pinv.matmul(&m_r).matmul(&a_pinv)
    }

    /// Population min-norm S²FT update on channel set S (Lemma F.12):
    /// Δ = U_S (W̄ U_S)† D W̲ᵀ (A²)†  restricted to the selected rows.
    pub fn s2ft_update(&self, layer: usize, channels: &[usize]) -> Mat {
        let above = self.above(layer);
        let below = self.below(layer);
        let d = self.b_id.sub(&self.w_pre());
        let a2 = below.matmul(&below.t());
        // W̄ U_S: selected columns of `above`
        let dl = self.dims[layer];
        let au = gather_cols_mat(&above, channels);
        let au_pinv = au.pinv();
        let v = au_pinv.matmul(&d).matmul(&below.t()).matmul(&a2.pinv()); // (s, d_{l-1})
        // Δ = U_S v
        let mut delta = Mat::zeros(dl, self.dims[layer - 1]);
        for (k, &c) in channels.iter().enumerate() {
            delta.data[c * delta.cols..(c + 1) * delta.cols].copy_from_slice(v.row(k));
        }
        delta
    }
}

/// Risk report for one (r, s) comparison.
#[derive(Debug, Clone)]
pub struct RiskReport {
    pub id_pre: f64,
    pub od_pre: f64,
    pub id_lora: f64,
    pub od_lora: f64,
    pub id_s2ft: f64,
    pub od_s2ft: f64,
    /// ‖(B_od − B_id)‖_F² — the Thm 4.2 LoRA lower bound
    pub label_shift_sq: f64,
    /// ‖Φ″_S Φ″_Sᵀ (B_od − B_id)‖_F² — the Assumption 4.1/F.5 projection
    /// (ε² · E_od(pre) in the paper's notation). Theorem F.15's bound is
    /// E_od(S²FT) ≤ E_od(pre) + 3·this (covariate terms vanish for Σx = I
    /// and full-column-rank W̲).
    pub proj_shift_sq: f64,
}

/// Run the Theorem 4.2 comparison: LoRA rank r vs S²FT with
/// s = ⌊r (d_ℓ + d_{ℓ-1}) / d_{ℓ-1}⌋ random channels (parameter-matched).
pub fn compare(cfg: &Config, r: usize) -> RiskReport {
    let net = DeepLinear::generate(cfg);
    let layer = cfg.layer;
    let dl = cfg.dims[layer];
    let dl1 = cfg.dims[layer - 1];
    let s = ((r * (dl + dl1)) / dl1).clamp(1, dl);
    let mut rng = Rng::seed(cfg.seed ^ 0xC0FFEE);
    let channels = rng.choose(dl, s);

    let w_pre = net.w_pre();
    let lora = net.finetuned(layer, &net.lora_update(layer, r));
    let s2ft = net.finetuned(layer, &net.s2ft_update(layer, &channels));
    let shift_mat = net.b_od.sub(&net.b_id);
    let shift = shift_mat.fro_norm() as f64;
    // Φ″_S = orthonormal basis of span(W̄_{ℓ+1} U_S)
    let au = gather_cols_mat(&net.above(layer), &channels);
    let dec = svd(&au);
    let tol = dec.s.first().copied().unwrap_or(0.0) * 1e-4;
    let k = dec.s.iter().filter(|&&sv| sv > tol).count();
    let mut proj = 0.0f64;
    for col in 0..k {
        // ‖u_colᵀ · shift‖² accumulated over the basis
        for j in 0..shift_mat.cols {
            let mut dot = 0.0f64;
            for i in 0..shift_mat.rows {
                dot += dec.u[(i, col)] as f64 * shift_mat[(i, j)] as f64;
            }
            proj += dot * dot;
        }
    }
    RiskReport {
        id_pre: net.excess_risk(&w_pre, &net.b_id),
        od_pre: net.excess_risk(&w_pre, &net.b_od),
        id_lora: net.excess_risk(&lora, &net.b_id),
        od_lora: net.excess_risk(&lora, &net.b_od),
        id_s2ft: net.excess_risk(&s2ft, &net.b_id),
        od_s2ft: net.excess_risk(&s2ft, &net.b_od),
        label_shift_sq: shift * shift,
        proj_shift_sq: proj,
    }
}

fn product(ws: &[Mat], from: usize, to: usize) -> Mat {
    // W_to ... W_{from+1}: ws[from..to] composed left-to-right
    let dims_in = if from == 0 { ws[0].cols } else { ws[from - 1].rows };
    let mut acc = Mat::eye(if from < to { ws[from].cols } else { dims_in });
    for w in &ws[from..to] {
        acc = w.matmul(&acc);
    }
    acc
}

fn low_rank(rows: usize, cols: usize, r: usize, rng: &mut Rng) -> Mat {
    let u = Mat::randn(rows, r.max(1), rng);
    let v = Mat::randn(r.max(1), cols, rng);
    u.matmul(&v)
}

fn gather_cols_mat(m: &Mat, cols: &[usize]) -> Mat {
    let mut out = Mat::zeros(m.rows, cols.len());
    for i in 0..m.rows {
        for (k, &c) in cols.iter().enumerate() {
            out[(i, k)] = m[(i, c)];
        }
    }
    out
}

/// Symmetric PSD square root via eigendecomposition (through Jacobi SVD of
/// the symmetric matrix: A = U S Uᵀ up to sign, so √A = U √S Uᵀ).
fn sqrt_psd(a: &Mat) -> Mat {
    let dec = svd(a);
    let k = dec.s.len();
    let mut sq = Mat::zeros(k, k);
    for i in 0..k {
        sq[(i, i)] = dec.s[i].max(0.0).sqrt();
    }
    // For symmetric PSD A, U and V coincide (up to null-space signs).
    dec.u.matmul(&sq).matmul(&dec.u.t())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> Config {
        // d0 ≤ hidden dims => W̲ has full column rank and the covariate
        // slack terms in Thm F.15 vanish; s/q = 4/48 keeps the selected
        // output span small (Assumption 4.1's regime).
        Config {
            dims: vec![24, 64, 64, 48],
            layer: 2,
            task_shift: 2.0,
            ood_noise: 0.3,
            shift_rank: 8,
            seed: 7,
        }
    }

    #[test]
    fn sqrt_psd_squares_back() {
        let mut rng = Rng::seed(0);
        let b = Mat::randn(6, 6, &mut rng);
        let a = b.matmul(&b.t());
        let s = sqrt_psd(&a);
        let back = s.matmul(&s);
        assert!(back.sub(&a).fro_norm() / a.fro_norm() < 1e-3);
    }

    #[test]
    fn finetuning_reduces_id_risk() {
        let c = cfg();
        let rep = compare(&c, 2);
        assert!(rep.id_lora < rep.id_pre * 0.9, "{rep:?}");
        assert!(rep.id_s2ft < rep.id_pre * 0.95, "{rep:?}");
    }

    #[test]
    fn theorem_4_2_ood_separation() {
        // Forgetting regime: the OOD task is (close to) the pre-training
        // task, fine-tuning pulls the model toward B_id. S²FT keeps OOD
        // risk near the pre-trained model (up to the Assumption-4.1
        // projection term); LoRA's is lower-bounded by the label shift.
        let rep = compare(&cfg(), 2);
        // LoRA lower bound from Thm 4.2 (slack for finite dims / r-rank fit)
        assert!(
            rep.od_lora > 0.3 * rep.label_shift_sq,
            "lora OOD {} vs bound {}",
            rep.od_lora,
            rep.label_shift_sq
        );
        // Theorem F.15 upper bound with its own ε-projection term
        // (Σx = I, full-column-rank W̲ => covariate terms vanish):
        let bound = rep.od_pre + 3.0 * rep.proj_shift_sq;
        assert!(
            rep.od_s2ft <= bound * 1.15,
            "s2ft OOD {} vs F.15 bound {}",
            rep.od_s2ft,
            bound
        );
        // and the method separation is large in this regime
        assert!(rep.od_s2ft * 1.5 < rep.od_lora, "{rep:?}");
    }

    #[test]
    fn projection_term_scales_with_selection_size() {
        // ε² E_od(pre) (= proj_shift_sq) grows with s/q: more selected
        // channels -> more of the label shift lands in the touched span.
        let net_cfg = cfg();
        let net = DeepLinear::generate(&net_cfg);
        let shift = net.b_od.sub(&net.b_id);
        let total = (shift.fro_norm() as f64).powi(2);
        let small = compare(&net_cfg, 1).proj_shift_sq;
        let large = compare(&net_cfg, 8).proj_shift_sq;
        assert!(small < large, "{small} !< {large}");
        assert!(large <= total * 1.01);
    }

    #[test]
    fn s2ft_update_touches_only_selected_rows() {
        let c = cfg();
        let net = DeepLinear::generate(&c);
        let delta = net.s2ft_update(2, &[1, 3]);
        for i in 0..delta.rows {
            let nz = delta.row(i).iter().any(|&x| x != 0.0);
            assert_eq!(nz, i == 1 || i == 3, "row {i}");
        }
    }

    #[test]
    fn lora_update_has_rank_r() {
        let c = cfg();
        let net = DeepLinear::generate(&c);
        let delta = net.lora_update(2, 3);
        let sv = crate::linalg::svd(&delta).s;
        let big = sv.iter().filter(|&&s| s > sv[0] * 1e-3).count();
        assert!(big <= 3, "rank {big}");
    }
}
