//! Selection-strategy comparison (`repro experiment selection`): fine-tune
//! the same task stream under each pluggable selection strategy — static
//! S²FT, iterative drop/grow, and grad-norm warmup — and compare final
//! eval loss, trainable-parameter budget, measured activation bytes, and
//! replan activity. Not a paper figure: it exercises the dynamic
//! re-selection pipeline (plan-epoch bumps, optimizer-moment carry-over)
//! end-to-end on the existing task suite.

use anyhow::Result;

use crate::data::{finetune_examples, Tokenizer};
use crate::runtime::open_backend;
use crate::sparsity::strategy;
use crate::train::{eval_loss, GenModel, Trainer};
use crate::util::json::Json;

use super::common::{batch_at, pretrained_cached, save_result};

pub fn run_selection(artifacts: &str, quick: bool) -> Result<()> {
    let rt = open_backend(artifacts)?;
    if rt.platform() != "native" {
        // the gradient probe and method-layout variants are native-only
        println!("selection: requires the native backend (gradnorm probe); skipping");
        return Ok(());
    }
    let (model, pre_steps, ft_steps, replan_every, warmup, n_eval) = if quick {
        ("tiny", 30, 24, 8, 8, 24)
    } else {
        ("small", 800, 180, 30, 60, 96)
    };
    let base = pretrained_cached(&rt, model, pre_steps, 42)?;
    let mm = rt.artifacts().model(model)?;
    let (b, t) = mm.default_batch();
    let method = mm.method("s2ft")?.clone();
    let tk = Tokenizer;
    let train_examples = finetune_examples("commonsense", 2000, 61);
    let eval_examples = finetune_examples("commonsense", n_eval, 62);

    let specs = [
        ("static".to_string(), 0usize),
        ("dropgrow".to_string(), replan_every),
        (format!("warmup:{warmup}"), replan_every),
    ];
    println!("\n=== Selection strategies: {model}, {ft_steps} steps, replan every {replan_every}");
    println!(
        "{:<12}{:>11}{:>12}{:>12}{:>9}{:>7}",
        "Strategy", "eval loss", "trainable", "act bytes", "replans", "shape"
    );
    let mut records = Vec::new();
    for (spec, every) in &specs {
        let strat = strategy::for_name(spec, &method.selection, method.select_small)?;
        let label = strat.name().to_string();
        let mut trainer =
            Trainer::with_strategy(&rt, model, "s2ft", &base, 77, strat, *every, b, t)?;
        for step in 0..ft_steps {
            let batch = batch_at(&tk, &train_examples, step * b, b, t);
            trainer.maybe_replan(&rt, &batch)?;
            trainer.train_step(&batch)?;
        }
        let trainable = trainer.trainable_params();
        let act_bytes = trainer.activation_bytes().unwrap_or(0);
        let (replans, shape_replans) =
            (trainer.metrics.replans, trainer.metrics.shape_changing_replans);
        let gm = GenModel::new(&rt, model, trainer.merged_params(&rt)?)?;
        let loss = eval_loss(&gm, &eval_examples)?;
        println!(
            "{:<12}{:>11.4}{:>12}{:>12}{:>9}{:>7}",
            label, loss, trainable, act_bytes, replans, shape_replans
        );
        records.push(Json::obj(vec![
            ("strategy", Json::str(label)),
            ("spec", Json::str(spec.clone())),
            ("eval_loss", Json::num(loss as f64)),
            ("trainable_params", Json::num(trainable as f64)),
            ("act_bytes", Json::num(act_bytes as f64)),
            ("replans", Json::num(replans as f64)),
            ("shape_changing_replans", Json::num(shape_replans as f64)),
        ]));
    }
    save_result("selection", &Json::Arr(records));
    Ok(())
}
