//! Minimal criterion-style bench harness (criterion is not vendored).
//!
//! Each `cargo bench` target is a `harness = false` binary that builds a
//! [`BenchSuite`], registers closures, and calls [`BenchSuite::run`]. The
//! harness warms up, runs timed batches until a wall budget, and reports
//! median / p10 / p90 per-iteration times plus throughput.

use std::time::{Duration, Instant};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub mean_ns: f64,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("iters", Json::num(self.iters as f64)),
            ("median_ns", Json::num(self.median_ns)),
            ("p10_ns", Json::num(self.p10_ns)),
            ("p90_ns", Json::num(self.p90_ns)),
            ("mean_ns", Json::num(self.mean_ns)),
        ])
    }
}

pub struct BenchSuite {
    pub suite: String,
    warmup: Duration,
    budget: Duration,
    min_iters: usize,
    pub results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new(suite: &str) -> Self {
        Self {
            suite: suite.to_string(),
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 10,
            results: Vec::new(),
        }
    }

    /// For expensive benchmarks (whole train steps).
    pub fn slow(mut self) -> Self {
        self.warmup = Duration::from_millis(0);
        self.budget = Duration::from_secs(4);
        self.min_iters = 3;
        self
    }

    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        // Warmup.
        let start = Instant::now();
        f();
        let first = start.elapsed();
        if first < self.warmup {
            let wstart = Instant::now();
            while wstart.elapsed() < self.warmup {
                f();
            }
        }
        // Timed samples.
        let mut samples_ns: Vec<f64> = Vec::new();
        let tstart = Instant::now();
        while (tstart.elapsed() < self.budget || samples_ns.len() < self.min_iters)
            && samples_ns.len() < 10_000
        {
            let s = Instant::now();
            f();
            samples_ns.push(s.elapsed().as_nanos() as f64);
            if first > self.budget && samples_ns.len() >= self.min_iters {
                break; // very slow case: stop at min_iters
            }
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len();
        let pct = |p: f64| samples_ns[((n as f64 - 1.0) * p) as usize];
        let res = BenchResult {
            name: name.to_string(),
            iters: n,
            median_ns: pct(0.5),
            p10_ns: pct(0.1),
            p90_ns: pct(0.9),
            mean_ns: samples_ns.iter().sum::<f64>() / n as f64,
        };
        println!(
            "{:<52} {:>12}  (p10 {:>10}, p90 {:>10}, n={})",
            format!("{}/{}", self.suite, name),
            fmt_ns(res.median_ns),
            fmt_ns(res.p10_ns),
            fmt_ns(res.p90_ns),
            n
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Write results JSON under results/bench_<suite>.json.
    pub fn save(&self) {
        let _ = std::fs::create_dir_all("results");
        let js = Json::Arr(self.results.iter().map(|r| r.to_json()).collect());
        let path = format!("results/bench_{}.json", self.suite);
        if std::fs::write(&path, js.to_string_pretty()).is_ok() {
            println!("saved {path}");
        }
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Opaque value sink preventing the optimizer from deleting benched work.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_samples() {
        let mut s = BenchSuite::new("selftest");
        s.budget = Duration::from_millis(30);
        s.warmup = Duration::from_millis(5);
        let r = s.bench("noop", || {
            black_box(1 + 1);
        });
        assert!(r.iters >= 10);
        assert!(r.median_ns >= 0.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
