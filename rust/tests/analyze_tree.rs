//! The static-analysis gate, self-applied: `repro analyze` must pass on
//! this very tree. This is the same check CI runs via the subcommand;
//! having it in `cargo test` means a violation fails the ordinary test
//! suite too, with the full report in the failure message.

use std::path::PathBuf;

use repro::analyze::{run, AnalyzeConfig};

#[test]
fn analyze_passes_on_this_tree() {
    let cfg = AnalyzeConfig { root: PathBuf::from(env!("CARGO_MANIFEST_DIR")) };
    let report = run(&cfg).expect("analyze must complete");
    assert!(report.findings.is_empty(), "tree must be lint-clean:\n{}", report.render());
    // sanity: the walk really covered the tree (src/ + benches/)
    assert!(report.files_scanned > 40, "only {} files scanned", report.files_scanned);
    // every escape hatch in the tree is live, justified and accounted
    // for: the decode.rs weight-map allow plus the three diagnostic
    // bench targets without committed baselines
    assert_eq!(report.allows.len(), 4, "allows: {:#?}", report.allows);
    for a in &report.allows {
        assert!(a.used, "stale allow would be a finding: {a:?}");
        assert!(!a.reason.is_empty());
    }
}
