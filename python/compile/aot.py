"""AOT pipeline: lower every (model, method) step to HLO text + meta.json.

Interchange format is HLO *text* (NOT ``lowered.compiler_ir("hlo")`` protos
or ``.serialize()``): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the rust ``xla``
0.1.6 crate links) rejects; the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Artifacts (all under ``artifacts/``), per model ``M`` with default batch
``BxT`` and method ``m``:

  init_M.hlo.txt               (seed:i32) -> base params
  fwd_M_BxT.hlo.txt            (base..., tokens) -> logits
  eval_M_BxT.hlo.txt           (base..., tokens, targets, mask) -> (loss, ncorrect)
  prepare_M_m_BxT.hlo.txt      (base..., seed, calib tok/tgt/mask) -> (trn..., frz..., perms...)
  train_M_m_BxT.hlo.txt        (trn..., frz..., m..., v..., step, tok, tgt, mask, aux...)
                               -> (trn..., m..., v..., loss)
  merge_M_m.hlo.txt            (trn..., frz..., perms...) -> base params

``meta.json`` records every artifact's exact input/output tensor order,
shapes and dtypes plus the per-method layouts, so the rust coordinator is
fully self-describing (python never runs on the request path).

Usage: python -m compile.aot --out ../artifacts [--models tiny,small]
       [--methods s2ft,lora] [--fig5] [--sweeps BxT,BxT]
"""

import argparse
import json
import os
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .configs import MODELS, ModelConfig, MethodConfig, default_methods, config_dict
from . import model as M
from .permute import coupled_structures

F32, I32 = "f32", "i32"

# Default (batch, seq) per model; seq is capped by cfg.seq_len (RoPE tables).
DEFAULT_BATCH = {"tiny": (2, 32), "small": (8, 64), "base": (4, 128)}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is REQUIRED: the default printer elides big
    # constant tensors (RoPE tables, causal masks) as "...", which the text
    # parser then reads back as garbage — silently corrupting numerics.
    return comp.as_hlo_text(True)


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def named(d: Dict[str, tuple], dtype=jnp.float32) -> List[Tuple[str, object]]:
    return [(k, spec(v, dtype)) for k, v in sorted(d.items())]


class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.meta: Dict[str, dict] = {"models": {}, "artifacts": {}}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, in_specs: List[Tuple[str, object]],
             out_names: List[str]):
        """Lower fn(*specs) and write HLO text + record the interface."""
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        # keep_unused=True: the recorded interface must match the compiled
        # parameter list exactly (e.g. calib inputs are unused under S2FT-R
        # and would otherwise be DCE'd, shifting every later argument).
        lowered = jax.jit(fn, keep_unused=True).lower(*[s for _, s in in_specs])
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *[s for _, s in in_specs])
        flat, _ = jax.tree_util.tree_flatten(outs)
        assert len(flat) == len(out_names), (name, len(flat), len(out_names))
        self.meta["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                [n, list(s.shape), _dt(s.dtype)] for n, s in in_specs
            ],
            "outputs": [
                [n, list(s.shape), _dt(s.dtype)] for n, s in zip(out_names, flat)
            ],
        }
        print(f"  wrote {name}.hlo.txt ({len(text)//1024}KB, "
              f"{len(in_specs)} in / {len(out_names)} out)")

    def save_meta(self):
        path = os.path.join(self.out_dir, "meta.json")
        with open(path, "w") as f:
            json.dump(self.meta, f, indent=1)
        print(f"  wrote meta.json ({os.path.getsize(path)//1024}KB)")


def _dt(dtype) -> str:
    s = jnp.dtype(dtype).name
    return {"float32": F32, "int32": I32}[s]


def emit_model(em: Emitter, cfg: ModelConfig, methods: Dict[str, MethodConfig],
               batches: List[Tuple[int, int]]):
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.2f}M params, "
          f"batches {batches}, methods {list(methods)}")
    base_shapes = M.param_shapes(cfg)
    base_in = named(base_shapes)
    base_names = [n for n, _ in base_in]

    mm: dict = {
        **config_dict(cfg, methods),
        "batches": [list(b) for b in batches],
        "base_params": [[k, list(v)] for k, v in sorted(base_shapes.items())],
        "coupled": coupled_structures(cfg.n_layers),
    }
    em.meta["models"][cfg.name] = mm

    # init
    em.emit(
        f"init_{cfg.name}",
        lambda seed: tuple(
            M.init_params(cfg, jax.random.fold_in(jax.random.PRNGKey(7),
                                                  jnp.asarray(seed, jnp.uint32)))[k]
            for k in base_names
        ),
        [("seed", spec((), jnp.int32))],
        base_names,
    )

    for (B, T) in batches:
        bt = f"{B}x{T}"
        tok = ("tokens", spec((B, T), jnp.int32))
        tgt = ("targets", spec((B, T), jnp.int32))
        msk = ("loss_mask", spec((B, T), jnp.float32))

        def fwd_fn(*args):
            base = dict(zip(base_names, args[: len(base_names)]))
            return (M.forward_base(cfg, base, args[-1]),)

        em.emit(f"fwd_{cfg.name}_{bt}", fwd_fn, base_in + [tok], ["logits"])

        def eval_fn(*args):
            base = dict(zip(base_names, args[: len(base_names)]))
            tokens, targets, mask = args[-3], args[-2], args[-1]
            logits = M.forward_base(cfg, base, tokens)
            loss = M.ce_loss(logits, targets, mask)
            pred = jnp.argmax(logits, axis=-1)
            ncorrect = ((pred == targets) * mask).sum()
            return (loss, ncorrect)

        em.emit(f"eval_{cfg.name}_{bt}", eval_fn, base_in + [tok, tgt, msk],
                ["loss", "ncorrect"])

    for mname, mcfg in methods.items():
        emit_method(em, cfg, mname, mcfg, batches, base_in, base_names)


def emit_method(em: Emitter, cfg: ModelConfig, mname: str, mcfg: MethodConfig,
                batches, base_in, base_names):
    trn_s, frz_s, perm_s, aux_s = M.method_layout(cfg, mcfg)
    opt_s = M.opt_state_shapes(cfg, mcfg)
    trn_in, frz_in = named(trn_s), named(frz_s)
    perm_in = named(perm_s, jnp.int32)
    trn_names = [n for n, _ in trn_in]
    frz_names = [n for n, _ in frz_in]
    perm_names = [n for n, _ in perm_in]
    opt_in = named(opt_s)
    opt_names = [n for n, _ in opt_in]
    aux_in = [
        (k, spec(v, jnp.float32)) for k, v in sorted(aux_s.items())
    ]
    aux_names = [n for n, _ in aux_in]

    em.meta["models"][cfg.name]["methods"][mname].update({
        "trainable": [[k, list(v)] for k, v in sorted(trn_s.items())],
        "frozen": [[k, list(v)] for k, v in sorted(frz_s.items())],
        "perms": [[k, list(v)] for k, v in sorted(perm_s.items())],
        "aux": [[k, list(v)] for k, v in sorted(aux_s.items())],
        "opt": [[k, list(v)] for k, v in sorted(opt_s.items())],
        "trainable_params": sum(
            int(jnp.prod(jnp.array(v or (1,)))) for v in trn_s.values()
        ),
    })

    # merge (batch-independent)
    def merge_fn(*args):
        i = 0
        trn = dict(zip(trn_names, args[i : i + len(trn_names)])); i += len(trn_names)
        frz = dict(zip(frz_names, args[i : i + len(frz_names)])); i += len(frz_names)
        perms = dict(zip(perm_names, args[i : i + len(perm_names)]))
        merged = M.merge_method(cfg, mcfg, trn, frz, perms)
        return tuple(merged[k] for k in base_names)

    em.emit(f"merge_{cfg.name}_{mname}", merge_fn, trn_in + frz_in + perm_in,
            base_names)

    for (B, T) in batches:
        bt = f"{B}x{T}"
        tok = ("tokens", spec((B, T), jnp.int32))
        tgt = ("targets", spec((B, T), jnp.int32))
        msk = ("loss_mask", spec((B, T), jnp.float32))

        def prep_fn(*args):
            base = dict(zip(base_names, args[: len(base_names)]))
            seed, tokens, targets, mask = args[-4:]
            trn, frz, perms = M.prepare_method(cfg, mcfg, base, seed, tokens,
                                               targets, mask)
            return tuple(
                [trn[k] for k in trn_names]
                + [frz[k] for k in frz_names]
                + [perms[k] for k in perm_names]
            )

        em.emit(
            f"prepare_{cfg.name}_{mname}_{bt}",
            prep_fn,
            base_in + [("seed", spec((), jnp.int32)), tok, tgt, msk],
            trn_names + frz_names + perm_names,
        )

        def train_fn(*args):
            i = 0
            trn = dict(zip(trn_names, args[i : i + len(trn_names)])); i += len(trn_names)
            frz = dict(zip(frz_names, args[i : i + len(frz_names)])); i += len(frz_names)
            om = dict(zip(opt_names, args[i : i + len(opt_names)])); i += len(opt_names)
            ov = dict(zip(opt_names, args[i : i + len(opt_names)])); i += len(opt_names)
            step, tokens, targets, mask = args[i : i + 4]; i += 4
            aux = dict(zip(aux_names, args[i:]))
            nt, nm, nv, loss = M.train_step(cfg, mcfg, trn, frz, om, ov, step,
                                            tokens, targets, mask, aux)
            return tuple(
                [nt[k] for k in trn_names]
                + [nm[k] for k in opt_names]
                + [nv[k] for k in opt_names]
                + [loss]
            )

        em.emit(
            f"train_{cfg.name}_{mname}_{bt}",
            train_fn,
            trn_in + frz_in
            + [(f"m.{n}", s) for n, s in opt_in]
            + [(f"v.{n}", s) for n, s in opt_in]
            + [("step", spec((), jnp.float32)), tok, tgt, msk]
            + aux_in,
            [f"new.{n}" for n in trn_names]
            + [f"new_m.{n}" for n in opt_names]
            + [f"new_v.{n}" for n in opt_names]
            + ["loss"],
        )


def experiment_extras(cfg: ModelConfig) -> Dict[str, MethodConfig]:
    """Extra method variants for the paper's sweeps (model 'small'):

    * fig2 — SpFT/LoRA at trainable ratios p ~ {10%, 1%, 0.1%}
    * fig4 — S2FT with the whole budget on a single projection type
    * tab4 — S2FT selection strategies {W,A,S,G} x {Large,Small}
    """
    d, k = cfg.d_model, cfg.d_ff
    linear_params = cfg.n_layers * (4 * d * d + 3 * d * k)
    per_rank = cfg.n_layers * (2 * d + k + d)  # lora params per unit rank on (wo, wd)
    out: Dict[str, MethodConfig] = {}
    # fig2 ratio sweep
    for tag, ratio in (("p10", 0.10), ("p1", 0.01), ("p01", 0.001)):
        out[f"spft-{tag}"] = MethodConfig("spft", spft_ratio=ratio)
        r = max(1, round(ratio * linear_params / per_rank))
        out[f"lora-{tag}"] = MethodConfig("lora", rank=r)
    # fig4 single-component budgets (parameter-matched to the default s2ft)
    budget = 16 * per_rank / cfg.n_layers  # params per layer (lora r=16 equiv)
    comp_params = {"wq": d * d, "wk": d * d, "wv": d * d, "wo": d * d,
                   "wu": d * k, "wg": d * k, "wd": k * d}
    for proj, size in comp_params.items():
        out[f"s2ft-{proj[1]}only"] = MethodConfig(
            "s2ft", s2ft_fractions={proj: round(budget / size, 4)})
    # tab4 selection strategies
    frac = default_methods(cfg)["s2ft"].s2ft_fractions
    for strat in "wasg":
        for small in (True, False):
            tag = f"s2ft-{strat}{'S' if small else 'L'}"
            out[tag] = MethodConfig("s2ft", s2ft_fractions=frac, selection=strat,
                                    select_small=small)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="tiny,small,base")
    ap.add_argument("--methods", default=None,
                    help="comma list; default = all for tiny/small, core for base")
    ap.add_argument("--sweeps", default=None,
                    help="extra BxT batches, e.g. 1x128,4x256 (applied to all models)")
    ap.add_argument("--fig5", action="store_true",
                    help="emit the Fig5 efficiency sweep for model 'base'")
    ap.add_argument("--extras", action="store_true",
                    help="emit the fig2/fig4/tab4 method variants for model 'small'")
    args = ap.parse_args()

    em = Emitter(args.out)
    core = ["fullft", "lora", "s2ft"]
    for mn in args.models.split(","):
        cfg = MODELS[mn]
        methods = default_methods(cfg)
        if args.methods:
            methods = {k: v for k, v in methods.items() if k in args.methods.split(",")}
        elif mn == "base":
            methods = {k: v for k, v in methods.items() if k in core}
        if args.extras and mn == "small":
            methods.update(experiment_extras(cfg))
        batches = [DEFAULT_BATCH[mn]]
        if args.sweeps:
            batches += [tuple(map(int, s.split("x"))) for s in args.sweeps.split(",")]
        if args.fig5 and mn == "base":
            # seq capped at 256 on this single-core testbed; the latency /
            # memory scaling shape is already visible at 2 x 2 shapes.
            for b in (1, 4):
                for t in (128, 256):
                    if (b, t) not in batches:
                        batches.append((b, t))
        emit_model(em, cfg, methods, batches)
    em.save_meta()


if __name__ == "__main__":
    main()
