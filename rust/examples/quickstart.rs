//! Quickstart: the full S²FT lifecycle end-to-end on the `small` model.
//!
//!   1. pre-train the base LM on the synthetic corpus (full FT),
//!   2. fine-tune with S²FT on the arithmetic suite (partial backprop),
//!   3. merge, extract the adapter, evaluate ID + OOD accuracy,
//!   4. demonstrate fuse/unfuse via scatter_add.
//!
//! Run: `cargo run --release --example quickstart` (hermetic on the native
//! backend; add `--features pjrt` + artifacts for PJRT execution).
//! Set QUICKSTART_STEPS to shrink/grow the budget.

use anyhow::Result;

use repro::adapter::S2ftAdapter;
use repro::data::{finetune_examples, ARITHMETIC, COMMONSENSE};
use repro::experiments::common::{evaluate_suite, finetune, pretrain};
use repro::runtime::{open_backend, Executor};
use repro::train::GenModel;

fn main() -> Result<()> {
    let steps: usize = std::env::var("QUICKSTART_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);
    let rt = open_backend("artifacts")?;
    println!("backend: {}", rt.platform());

    // 1. pre-train
    println!("\n[1/4] pre-training `small` for {steps} steps on the synthetic corpus");
    let base = pretrain(rt.as_ref(), "small", steps, 42, true)?;

    // 2. S²FT fine-tune
    println!("\n[2/4] S²FT fine-tuning on the arithmetic mixture ({steps} steps)");
    let examples = finetune_examples("arithmetic", 2000, 7);
    let trainer = finetune(rt.as_ref(), "small", "s2ft", &base, &examples, steps, 11)?;
    println!(
        "  tail loss {:.4}, {:.1} ms/step, trainable state only {:.2} MB of {:.2} MB",
        trainer.metrics.tail_loss(10),
        trainer.metrics.ms_per_step(),
        trainer.opt_bytes() as f64 / 2e6, // m+v => /2 for one copy
        trainer.state_bytes() as f64 / 1e6,
    );

    // 3. merge + evaluate
    println!("\n[3/4] merging and evaluating");
    let merged = trainer.merged_params(rt.as_ref())?;
    let model = GenModel::new(rt.as_ref(), "small", merged.clone())?;
    let (rows, avg) = evaluate_suite(&model, &ARITHMETIC, 16, 1)?;
    for (name, acc) in &rows {
        println!("  {name:>10}: {acc:5.1}%");
    }
    println!("  arithmetic avg: {avg:.1}%");
    let (_, cs_avg) = evaluate_suite(&model, &COMMONSENSE, 16, 1)?;
    println!("  commonsense (far-OOD retention): {cs_avg:.1}%");

    // 4. adapter extraction + switch
    println!("\n[4/4] adapter lifecycle");
    let mm = rt.artifacts().model("small")?;
    let method = mm.method("s2ft")?;
    let adapter = S2ftAdapter::extract(mm, method, &trainer.perms, &base, &merged)?;
    println!(
        "  extracted adapter: {:.1} KB (vs {:.1} MB full model) across {} layers",
        adapter.bytes() as f64 / 1e3,
        mm.param_count as f64 * 4.0 / 1e6,
        adapter.layers.len()
    );
    let mut live = base.clone();
    let t0 = std::time::Instant::now();
    adapter.apply(&mut live)?;
    let fuse_us = t0.elapsed().as_micros();
    let t1 = std::time::Instant::now();
    adapter.remove(&mut live)?;
    println!(
        "  fuse {} µs / unfuse {} µs (scatter_add over selected rows only)",
        fuse_us,
        t1.elapsed().as_micros()
    );
    for (k, v) in &live {
        let a = v.as_f32()?;
        let b = base[k].as_f32()?;
        let max_diff = a
            .iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        // float add-then-subtract is not bitwise identity; 1e-6 abs is
        // exact restoration at f32 precision for these magnitudes
        assert!(max_diff < 1e-6, "unfuse drifted on {k}: {max_diff}");
    }
    println!("  base weights restored after unfuse (f32-exact) ✓");
    println!("\nquickstart complete.");
    Ok(())
}
