//! Dynamic batcher with adapter affinity.
//!
//! Groups queued requests by adapter id, emitting batches of at most
//! `max_batch`. Among groups it serves the *largest* group first
//! (throughput) but never starves: groups older than `max_wait` get
//! priority (bounded latency / backpressure).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Queued<T> {
    pub adapter: String,
    pub enqueued: Instant,
    pub payload: T,
}

#[derive(Debug)]
pub struct BatchPlan<T> {
    pub adapter: String,
    pub items: Vec<Queued<T>>,
}

pub struct AdapterBatcher<T> {
    queue: VecDeque<Queued<T>>,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl<T> AdapterBatcher<T> {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        Self { queue: VecDeque::new(), max_batch, max_wait }
    }

    pub fn push(&mut self, adapter: impl Into<String>, payload: T) {
        self.queue.push_back(Queued {
            adapter: adapter.into(),
            enqueued: Instant::now(),
            payload,
        });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Pick the adapter to serve next; None if the queue is empty.
    fn pick_adapter(&self) -> Option<String> {
        // starvation guard: oldest overdue request wins
        if let Some(overdue) = self
            .queue
            .iter()
            .filter(|q| q.enqueued.elapsed() >= self.max_wait)
            .min_by_key(|q| q.enqueued)
        {
            return Some(overdue.adapter.clone());
        }
        // otherwise the largest group (throughput-optimal switch amortization)
        let mut counts: std::collections::HashMap<&str, usize> = Default::default();
        for q in &self.queue {
            *counts.entry(q.adapter.as_str()).or_default() += 1;
        }
        counts
            .into_iter()
            .max_by_key(|(_, c)| *c)
            .map(|(a, _)| a.to_string())
    }

    /// Remove and return the next batch (same adapter, FIFO within group).
    pub fn next_batch(&mut self) -> Option<BatchPlan<T>> {
        let adapter = self.pick_adapter()?;
        let mut items = Vec::with_capacity(self.max_batch);
        let mut rest = VecDeque::with_capacity(self.queue.len());
        for q in self.queue.drain(..) {
            if q.adapter == adapter && items.len() < self.max_batch {
                items.push(q);
            } else {
                rest.push_back(q);
            }
        }
        self.queue = rest;
        Some(BatchPlan { adapter, items })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_group_by_adapter_and_cap() {
        let mut b = AdapterBatcher::new(2, Duration::from_secs(60));
        b.push("a", 1);
        b.push("b", 2);
        b.push("a", 3);
        b.push("a", 4);
        let p = b.next_batch().unwrap();
        assert_eq!(p.adapter, "a");
        assert_eq!(p.items.len(), 2); // capped at max_batch
        assert_eq!(p.items[0].payload, 1);
        assert_eq!(p.items[1].payload, 3);
        assert_eq!(b.len(), 2);
        let p2 = b.next_batch().unwrap();
        // remaining 'a' (1 item) vs 'b' (1 item): either is fine, but FIFO
        // grouping must preserve payload order within the adapter.
        assert!(p2.items.len() == 1);
    }

    #[test]
    fn starvation_guard_prioritizes_old_requests() {
        let mut b = AdapterBatcher::new(4, Duration::from_millis(0)); // everything overdue
        b.push("old", 1);
        std::thread::sleep(Duration::from_millis(2));
        b.push("big", 2);
        b.push("big", 3);
        b.push("big", 4);
        let p = b.next_batch().unwrap();
        assert_eq!(p.adapter, "old"); // despite "big" being larger
    }

    #[test]
    fn largest_group_wins_when_fresh() {
        let mut b = AdapterBatcher::new(4, Duration::from_secs(60));
        b.push("a", 1);
        b.push("b", 2);
        b.push("b", 3);
        let p = b.next_batch().unwrap();
        assert_eq!(p.adapter, "b");
    }

    #[test]
    fn empty_queue_returns_none() {
        let mut b: AdapterBatcher<u32> = AdapterBatcher::new(4, Duration::from_secs(1));
        assert!(b.next_batch().is_none());
    }

    /// Affinity: a batch only ever contains one adapter, and skipped
    /// requests keep their FIFO slot for the next round.
    #[test]
    fn affinity_never_mixes_adapters() {
        let mut b = AdapterBatcher::new(8, Duration::from_secs(60));
        for i in 0..12 {
            b.push(format!("a{}", i % 3), i);
        }
        while let Some(plan) = b.next_batch() {
            assert!(plan.items.iter().all(|q| q.adapter == plan.adapter));
            assert!(
                plan.items.windows(2).all(|w| w[0].payload < w[1].payload),
                "FIFO order broken within {:?}",
                plan.adapter
            );
        }
    }

    /// Windowing: once the wait budget expires, age dominates group size —
    /// and within the overdue set, the *oldest* adapter is served first.
    #[test]
    fn windowing_prefers_oldest_once_overdue() {
        let mut b = AdapterBatcher::new(8, Duration::from_millis(1));
        b.push("first", 0);
        std::thread::sleep(Duration::from_millis(3));
        b.push("second", 1);
        b.push("big", 2);
        b.push("big", 3);
        b.push("big", 4);
        std::thread::sleep(Duration::from_millis(3)); // all overdue now
        let p1 = b.next_batch().unwrap();
        assert_eq!(p1.adapter, "first");
        let p2 = b.next_batch().unwrap();
        assert_eq!(p2.adapter, "second");
        let p3 = b.next_batch().unwrap();
        assert_eq!(p3.adapter, "big");
        assert_eq!(p3.items.len(), 3);
    }
}
