//! Byte-level tokenizer: 256 raw bytes + 5 specials (vocab 261, matching
//! `ModelConfig.vocab` on the python side).

pub const PAD: i32 = 256;
pub const BOS: i32 = 257;
pub const EOS: i32 = 258;
pub const SEP: i32 = 259;
pub const UNK: i32 = 260;
pub const VOCAB: usize = 261;

#[derive(Debug, Clone, Default)]
pub struct Tokenizer;

impl Tokenizer {
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32).collect()
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .filter(|&&t| (0..256).contains(&t))
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Decode stopping at the first EOS/PAD.
    pub fn decode_until_eos(&self, tokens: &[i32]) -> String {
        let end = tokens
            .iter()
            .position(|&t| t == EOS || t == PAD)
            .unwrap_or(tokens.len());
        self.decode(&tokens[..end])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let tk = Tokenizer;
        let toks = tk.encode("7 + 5 = 12");
        assert_eq!(tk.decode(&toks), "7 + 5 = 12");
        assert!(toks.iter().all(|&t| t < 256));
    }

    #[test]
    fn decode_skips_specials_and_stops_at_eos() {
        let tk = Tokenizer;
        let mut toks = tk.encode("ab");
        toks.push(EOS);
        toks.extend(tk.encode("junk"));
        assert_eq!(tk.decode_until_eos(&toks), "ab");
        let with_specials = vec![BOS, 104, 105, SEP];
        assert_eq!(tk.decode(&with_specials), "hi");
    }
}
