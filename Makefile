# S²FT reproduction — top-level driver.
#
#   make build      release build (native backend, hermetic: no Python/XLA)
#   make test       full hermetic test suite (default features)
#   make test-pjrt  compile-check the PJRT feature path as well
#   make artifacts  AOT-lower the JAX models to HLO text (needs python+jax)
#   make fmt lint   formatting / clippy gates (same as CI)

CARGO ?= cargo
MANIFEST = rust/Cargo.toml

.PHONY: build test test-pjrt artifacts artifacts-fig5 fmt lint clean

build:
	$(CARGO) build --release --manifest-path $(MANIFEST)

test:
	$(CARGO) test -q --manifest-path $(MANIFEST)

test-pjrt:
	$(CARGO) test -q --manifest-path $(MANIFEST) --features pjrt

fmt:
	$(CARGO) fmt --check --manifest-path $(MANIFEST)

lint:
	$(CARGO) clippy --manifest-path $(MANIFEST) --all-targets -- -D warnings

# Build-time only: lower every (model, method) to HLO text + meta.json.
# Requires a python environment with jax installed; the rust side never
# needs python at runtime (and the native backend never needs artifacts).
artifacts:
	cd python && python -m compile.aot --out ../rust/artifacts

artifacts-fig5:
	cd python && python -m compile.aot --out ../rust/artifacts --fig5 --extras

clean:
	$(CARGO) clean --manifest-path $(MANIFEST)
