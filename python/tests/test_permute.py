"""Permutation invariance of coupled structures (paper Sec. 3.1)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import permute as P
from compile import model as M
from compile.configs import MODELS


def test_trainable_first_permutation_basic():
    perm = P.trainable_first_permutation([3, 1], 5)
    assert perm.tolist() == [3, 1, 0, 2, 4]
    inv = P.invert_permutation(perm)
    assert np.array_equal(perm[inv], np.arange(5))
    assert np.array_equal(inv[perm], np.arange(5))


@settings(max_examples=25, deadline=None)
@given(total=st.integers(2, 64), seed=st.integers(0, 10**6))
def test_permutation_roundtrip(total, seed):
    rng = np.random.default_rng(seed)
    s = int(rng.integers(1, total))
    selected = rng.choice(total, s, replace=False).tolist()
    perm = P.trainable_first_permutation(selected, total)
    assert sorted(perm.tolist()) == list(range(total))
    assert perm[:s].tolist() == selected
    inv = P.invert_permutation(perm)
    x = rng.standard_normal(total)
    np.testing.assert_array_equal(x[perm][inv], x)


def test_expand_head_perm():
    e = P.expand_head_perm(np.array([2, 0, 1], np.int32), 2)
    assert e.tolist() == [4, 5, 0, 1, 2, 3]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_ffn_co_permutation_invariance(seed):
    """U(x)*SiLU(G(x)) @ D is invariant under channel co-permutation."""
    rng = np.random.default_rng(seed)
    d, k, n = 8, 12, 6
    wu = rng.standard_normal((d, k)).astype(np.float32)
    wg = rng.standard_normal((d, k)).astype(np.float32)
    wd = rng.standard_normal((k, d)).astype(np.float32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    s = int(rng.integers(1, k))
    selected = rng.choice(k, s, replace=False).tolist()
    wu2, wg2, wd2, perm = P.co_permute_ffn(jnp.asarray(wu), jnp.asarray(wg),
                                           jnp.asarray(wd), selected)

    def ffn(wu_, wg_, wd_):
        act = (x @ np.asarray(wu_)) * jax.nn.silu(x @ np.asarray(wg_))
        return np.asarray(act) @ np.asarray(wd_)

    np.testing.assert_allclose(ffn(wu, wg, wd), ffn(wu2, wg2, wd2),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_mha_co_permutation_invariance_full_model(seed):
    """Whole-model check: permuting heads+channels of every layer leaves
    the logits unchanged (the property S2FT's prepare step relies on)."""
    cfg = MODELS["tiny"]
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    base = M.init_params(cfg, key)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)
    ref_logits = M.forward_base(cfg, base, tokens)

    permuted = dict(base)
    for i in range(cfg.n_layers):
        heads = rng.permutation(cfg.n_heads)[: cfg.n_heads // 2].tolist()
        wq, wk, wv, wo, _ = P.co_permute_mha(
            base[f"L{i}.wq"], base[f"L{i}.wk"], base[f"L{i}.wv"],
            base[f"L{i}.wo"], heads, cfg.n_heads,
        )
        permuted.update({f"L{i}.wq": wq, f"L{i}.wk": wk, f"L{i}.wv": wv,
                         f"L{i}.wo": wo})
        chans = rng.permutation(cfg.d_ff)[: cfg.d_ff // 3].tolist()
        wu, wg, wd, _ = P.co_permute_ffn(
            permuted[f"L{i}.wu"], permuted[f"L{i}.wg"], permuted[f"L{i}.wd"], chans
        )
        permuted.update({f"L{i}.wu": wu, f"L{i}.wg": wg, f"L{i}.wd": wd})
    got = M.forward_base(cfg, permuted, tokens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref_logits),
                               rtol=2e-4, atol=2e-4)


def test_coupled_structures_inventory():
    c = P.coupled_structures(3)
    assert len(c) == 6
    assert c["L1.mha"]["w2"] == ["L1.wo"]
    assert c["L2.ffn"]["w1"] == ["L2.wu", "L2.wg"]
