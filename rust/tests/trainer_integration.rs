//! Trainer-lifecycle integration tests against the real tiny-model
//! artifacts: prepare -> train -> merge -> eval -> adapter extraction,
//! for every fine-tuning method. These are the rust mirror of the python
//! `test_aot.py` checks, exercising the exact production code path.

use std::collections::HashMap;

use repro::adapter::{load_adapter, save_adapter, S2ftAdapter};
use repro::data::{lm_batch, pretrain_corpus, Tokenizer};
use repro::runtime::{Runtime, Tensor};
use repro::train::{load_params, save_params, GenModel, Trainer};
use repro::util::rng::Rng;

fn runtime() -> Runtime {
    Runtime::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")).expect("run `make artifacts`")
}

fn base_params(rt: &Runtime) -> HashMap<String, Tensor> {
    let init = rt.load("init_tiny").unwrap();
    let outs = init.run(&[Tensor::scalar_i32(7)]).unwrap();
    init.spec.outputs.iter().map(|s| s.name.clone()).zip(outs).collect()
}

fn train_n(rt: &Runtime, method: &str, steps: usize) -> (Trainer, HashMap<String, Tensor>) {
    let base = base_params(rt);
    let (b, t) = rt.artifacts.model("tiny").unwrap().default_batch();
    let tk = Tokenizer;
    let corpus = pretrain_corpus(1, 50_000);
    let mut rng = Rng::seed(9);
    let calib = lm_batch(&tk, &corpus, &mut rng, b, t);
    let mut trainer = Trainer::new(rt, "tiny", method, &base, 5, &calib).unwrap();
    for _ in 0..steps {
        let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
        trainer.train_step(&batch).unwrap();
    }
    (trainer, base)
}

#[test]
fn every_method_reduces_lm_loss() {
    let rt = runtime();
    for method in ["fullft", "lora", "dora", "spft", "lisa", "galore", "s2ft"] {
        let (trainer, _) = train_n(&rt, method, 8);
        let first = trainer.metrics.losses[0];
        let last = trainer.metrics.last_loss();
        assert!(
            last < first,
            "{method}: loss did not decrease ({first} -> {last})"
        );
        assert!(last.is_finite(), "{method}: non-finite loss");
        // free compiled executables between methods (memory hygiene)
        let (b, t) = rt.artifacts.model("tiny").unwrap().default_batch();
        rt.evict(&format!("train_tiny_{method}_{b}x{t}"));
    }
}

#[test]
fn s2ft_pallas_matches_native_trajectory() {
    let rt = runtime();
    let (native, _) = train_n(&rt, "s2ft", 4);
    let (pallas, _) = train_n(&rt, "s2ft-pallas", 4);
    for (a, b) in native.metrics.losses.iter().zip(&pallas.metrics.losses) {
        assert!(
            (a - b).abs() < 1e-4,
            "pallas trajectory diverged: {:?} vs {:?}",
            native.metrics.losses,
            pallas.metrics.losses
        );
    }
}

#[test]
fn merge_changes_only_selected_rows_for_s2ft() {
    let rt = runtime();
    let (trainer, base) = train_n(&rt, "s2ft", 4);
    let merged = trainer.merged_params(&rt).unwrap();
    let mm = rt.artifacts.model("tiny").unwrap();
    let method = mm.method("s2ft").unwrap();
    // adapter extraction + application reproduces the merged weights
    let adapter = S2ftAdapter::extract(mm, method, &trainer.perms, &base, &merged).unwrap();
    let mut rebuilt = base.clone();
    adapter.apply(&mut rebuilt).unwrap();
    for (k, v) in &merged {
        let a = v.as_f32().unwrap();
        let b = rebuilt[k].as_f32().unwrap();
        let max = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(max < 1e-5, "{k}: adapter apply drifted by {max}");
    }
    // frozen tensors (embed, norms, non-target projections) are untouched
    for k in ["embed", "norm_f", "L0.wq", "L0.norm1"] {
        assert_eq!(
            merged[k].as_f32().unwrap(),
            base[k].as_f32().unwrap(),
            "{k} must stay frozen under s2ft"
        );
    }
}

#[test]
fn adapter_persists_through_disk() {
    let rt = runtime();
    let (trainer, base) = train_n(&rt, "s2ft", 3);
    let merged = trainer.merged_params(&rt).unwrap();
    let mm = rt.artifacts.model("tiny").unwrap();
    let method = mm.method("s2ft").unwrap();
    let adapter = S2ftAdapter::extract(mm, method, &trainer.perms, &base, &merged).unwrap();

    let dir = std::env::temp_dir().join(format!("adapter_it_{}", std::process::id()));
    let path = dir.join("a.s2ft");
    save_adapter(&path, &adapter).unwrap();
    let loaded = load_adapter(&path).unwrap();
    let mut p1 = base.clone();
    adapter.apply(&mut p1).unwrap();
    let mut p2 = base.clone();
    loaded.apply(&mut p2).unwrap();
    for (k, v) in &p1 {
        assert_eq!(v.as_f32().unwrap(), p2[k].as_f32().unwrap(), "{k}");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let rt = runtime();
    let (trainer, _) = train_n(&rt, "fullft", 4);
    let merged = trainer.merged_params(&rt).unwrap();
    let dir = std::env::temp_dir().join(format!("ckpt_it_{}", std::process::id()));
    save_params(&dir, &merged).unwrap();
    let loaded = load_params(&dir).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();

    let (b, t) = rt.artifacts.model("tiny").unwrap().default_batch();
    let tk = Tokenizer;
    let corpus = pretrain_corpus(1, 50_000);
    let mut rng = Rng::seed(11);
    let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
    let m1 = GenModel::new(&rt, "tiny", merged).unwrap();
    let m2 = GenModel::new(&rt, "tiny", loaded).unwrap();
    let (l1, _) = m1.eval_batch(&batch).unwrap();
    let (l2, _) = m2.eval_batch(&batch).unwrap();
    assert!((l1 - l2).abs() < 1e-6, "{l1} vs {l2}");
}

#[test]
fn generate_is_deterministic_and_bounded() {
    let rt = runtime();
    let base = base_params(&rt);
    let model = GenModel::new(&rt, "tiny", base).unwrap();
    let prompts = vec!["q: 1 + 1 =".to_string(), "hello".to_string()];
    let a = model.generate(&prompts, 5).unwrap();
    let b = model.generate(&prompts, 5).unwrap();
    assert_eq!(a, b, "greedy decode must be deterministic");
    assert!(a.iter().all(|s| s.len() <= 5));
}

#[test]
fn opt_state_sizes_reflect_method_memory_story() {
    let rt = runtime();
    let (full, _) = train_n(&rt, "fullft", 1);
    let (s2ft, _) = train_n(&rt, "s2ft", 1);
    let (lora, _) = train_n(&rt, "lora", 1);
    // the paper's Fig 5 memory structure, enforced as an invariant:
    assert!(s2ft.opt_bytes() * 3 < full.opt_bytes(), "s2ft opt state must be far smaller");
    assert!(lora.opt_bytes() * 3 < full.opt_bytes());
    // total live state: frozen is shared, so the gap is smaller but real
    assert!(s2ft.state_bytes() < full.state_bytes());
}
