"""Config invariants: parameter matching across methods and sweep variants."""

import numpy as np
import pytest

from compile.configs import MODELS, MethodConfig, default_methods
from compile import model as M
from compile.aot import experiment_extras


@pytest.mark.parametrize("name", list(MODELS))
def test_model_dims_consistent(name):
    cfg = MODELS[name]
    assert cfg.d_model % cfg.n_heads == 0
    assert cfg.head_dim * cfg.n_heads == cfg.d_model
    assert cfg.param_count() > 0
    shapes = M.param_shapes(cfg)
    total = sum(int(np.prod(s)) for s in shapes.values())
    assert total == cfg.param_count()


@pytest.mark.parametrize("name", list(MODELS))
def test_s2ft_budget_parameter_matched_to_lora(name):
    """The paper keeps S2FT's trainable count comparable to LoRA's."""
    cfg = MODELS[name]
    methods = default_methods(cfg)
    counts = {}
    for tag in ("lora", "s2ft"):
        trn, _, _, _ = M.method_layout(cfg, methods[tag])
        counts[tag] = sum(int(np.prod(s)) for s in trn.values())
    ratio = counts["s2ft"] / counts["lora"]
    assert 0.5 < ratio < 2.0, counts


def test_method_tags_unique():
    cfg = MODELS["small"]
    methods = dict(default_methods(cfg))
    methods.update(experiment_extras(cfg))
    assert len(methods) == len(set(methods))
    # every extra variant produces a valid layout
    for tag, mc in methods.items():
        trn, frz, perms, aux = M.method_layout(cfg, mc)
        assert trn, tag
        total = sum(int(np.prod(s)) for s in trn.values())
        assert total > 0, tag


def test_fig2_ratio_sweep_spans_decades():
    cfg = MODELS["small"]
    extras = experiment_extras(cfg)
    linear = cfg.n_layers * (4 * cfg.d_model**2 + 3 * cfg.d_model * cfg.d_ff)

    def tensor_ratio(tag):
        trn, _, _, _ = M.method_layout(cfg, extras[tag])
        return sum(int(np.prod(s)) for s in trn.values()) / linear

    # LoRA's ranks track the requested decades
    l10, l1, l01 = (tensor_ratio(f"lora-{t}") for t in ("p10", "p1", "p01"))
    assert 0.05 < l10 < 0.2
    assert 0.005 < l1 < 0.02
    assert l01 < 0.005
    # SpFT's *effective* ratio is the bernoulli mask density (the delta
    # tensors are full-size — unstructured sparsity cannot shrink its
    # storage, which is exactly the paper's efficiency complaint)
    assert extras["spft-p10"].spft_ratio == pytest.approx(0.10)
    assert extras["spft-p1"].spft_ratio == pytest.approx(0.01)
    assert extras["spft-p01"].spft_ratio == pytest.approx(0.001)
    assert tensor_ratio("spft-p10") == pytest.approx(1.0)


def test_fig4_components_parameter_matched():
    cfg = MODELS["small"]
    extras = experiment_extras(cfg)
    sizes = {}
    for proj in "qkvougd":
        tag = f"s2ft-{proj}only"
        trn, _, _, _ = M.method_layout(cfg, extras[tag])
        sizes[tag] = sum(int(np.prod(s)) for s in trn.values())
    lo, hi = min(sizes.values()), max(sizes.values())
    # head/channel rounding allows some slack but budgets stay comparable
    assert hi / lo < 2.5, sizes


def test_tab4_strategy_variants_cover_all():
    cfg = MODELS["small"]
    extras = experiment_extras(cfg)
    for strat in "wasg":
        for side in "SL":
            tag = f"s2ft-{strat}{side}"
            assert tag in extras, tag
            assert extras[tag].selection == strat
            assert extras[tag].select_small == (side == "S")


def test_method_tag_roundtrip():
    m = MethodConfig("s2ft", s2ft_fractions={"wo": 0.1, "wd": 0.1},
                     selection="a", select_small=True)
    assert m.tag() == "s2ft-aS"
    m2 = MethodConfig("s2ft", s2ft_fractions={"wd": 0.1}, use_pallas=True)
    assert "pallas" in m2.tag()
