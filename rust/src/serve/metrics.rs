//! Engine-wide serving metrics.

use super::residency::ResidencyStats;

/// Point-in-time KV-pool gauge for one worker, mirrored from
/// [`crate::serve::kvpool::PoolUsage`] whenever that worker finishes a
/// request or drains its running batch.
///
/// `used_bytes` is a gauge (last reported value), `peak_bytes` a
/// high-water mark merged across reports; both are exact byte figures,
/// the serving counterpart of training's `ActivationMeter`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KvPoolGauge {
    /// Total bytes the worker's pool owns.
    pub capacity_bytes: usize,
    /// Bytes pinned by live streams at the last report.
    pub used_bytes: usize,
    /// High-water mark of `used_bytes` over the worker's lifetime.
    pub peak_bytes: usize,
}

/// Counters + latency distribution for one [`super::Engine`].
///
/// Latencies are kept **sorted on insert** ([`ServeMetrics::record_latency_ms`]
/// does a binary-search insert), so percentile reads are O(1) index math
/// instead of the former clone-and-sort per call.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    /// Requests fully served (counted when the terminal reply is built,
    /// *before* its `Done` event is delivered).
    pub requests: usize,
    /// Admission waves: one per continuous-batching admission of ≥1
    /// stream, or one per wave on the legacy full-recompute path.
    pub batches: usize,
    /// Adapter switches that actually changed a worker's weights
    /// (re-activating the already-fused adapter is free and uncounted).
    pub switches: usize,
    /// Wall-clock nanoseconds spent inside adapter switches (fuse +
    /// unfuse), summed across workers; `switch_ns / switches` is the
    /// mean switch cost ([`ServeMetrics::mean_switch_us`]).
    pub switch_ns: u64,
    /// Total tokens generated (streamed) across all requests.
    pub tokens: usize,
    /// Streams terminated early to reclaim KV-pool blocks under
    /// backpressure (each also delivered exactly one `Error` event).
    pub evictions: usize,
    /// Adapter-residency counters mirrored from the engine's
    /// [`crate::serve::AdapterRegistry`] when the snapshot is taken.
    pub residency: ResidencyStats,
    latencies_ms: Vec<f64>,
    /// Per-worker KV-pool gauges, indexed by worker id.
    kv: Vec<KvPoolGauge>,
}

impl ServeMetrics {
    /// Record one request latency, keeping the vector sorted.
    pub fn record_latency_ms(&mut self, ms: f64) {
        let i = self.latencies_ms.partition_point(|&x| x < ms);
        self.latencies_ms.insert(i, ms);
    }

    /// All recorded latencies, ascending.
    pub fn latencies_ms(&self) -> &[f64] {
        &self.latencies_ms
    }

    /// Nearest-rank percentile (`p` in `[0, 1]`): the smallest recorded
    /// latency such that at least `p · n` samples are ≤ it.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        let n = self.latencies_ms.len();
        if n == 0 {
            return 0.0;
        }
        let rank = (p * n as f64).ceil() as usize;
        self.latencies_ms[rank.clamp(1, n) - 1]
    }

    /// Mean adapter-switch cost in microseconds (0 before any switch).
    pub fn mean_switch_us(&self) -> f64 {
        if self.switches == 0 {
            0.0
        } else {
            self.switch_ns as f64 / self.switches as f64 / 1e3
        }
    }

    /// Mean requests per batch (`requests / batches`), 0 when nothing
    /// has been served.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Merge a fresh pool gauge from `worker`: capacity and `used_bytes`
    /// overwrite (gauges), `peak_bytes` keeps the maximum ever reported.
    pub fn record_kv(&mut self, worker: usize, g: KvPoolGauge) {
        if self.kv.len() <= worker {
            self.kv.resize(worker + 1, KvPoolGauge::default());
        }
        let slot = &mut self.kv[worker];
        slot.capacity_bytes = g.capacity_bytes;
        slot.used_bytes = g.used_bytes;
        slot.peak_bytes = slot.peak_bytes.max(g.peak_bytes);
    }

    /// Total KV-pool capacity across workers (0 on the legacy path).
    pub fn kv_capacity_bytes(&self) -> usize {
        self.kv.iter().map(|g| g.capacity_bytes).sum()
    }

    /// KV bytes pinned by live streams at the last report, summed across
    /// workers.
    pub fn kv_used_bytes(&self) -> usize {
        self.kv.iter().map(|g| g.used_bytes).sum()
    }

    /// Sum of each worker's KV high-water mark (an upper bound on any
    /// instantaneous total, exact per worker).
    pub fn kv_peak_bytes(&self) -> usize {
        self.kv.iter().map(|g| g.peak_bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_handles_empty_and_stays_sorted() {
        let m = ServeMetrics::default();
        assert_eq!(m.percentile_ms(0.5), 0.0);
        assert_eq!(m.percentile_ms(0.99), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);

        let mut m = ServeMetrics {
            requests: 4,
            batches: 2,
            switches: 1,
            ..Default::default()
        };
        for ms in [40.0, 10.0, 30.0, 20.0] {
            m.record_latency_ms(ms);
        }
        assert_eq!(m.latencies_ms(), &[10.0, 20.0, 30.0, 40.0]);
        assert_eq!(m.percentile_ms(0.0), 10.0);
        assert_eq!(m.percentile_ms(1.0), 40.0);
        assert_eq!(m.percentile_ms(0.5), 20.0);
        assert_eq!(m.mean_batch_size(), 2.0);
    }

    #[test]
    fn mean_switch_cost_is_ns_over_switches() {
        let mut m = ServeMetrics::default();
        assert_eq!(m.mean_switch_us(), 0.0);
        m.switches = 4;
        m.switch_ns = 8_000;
        assert_eq!(m.mean_switch_us(), 2.0);
    }

    /// Nearest-rank must not truncate toward low ranks: p99 of 9 samples
    /// is the maximum (rank ceil(8.91) = 9), not sample 7 as the old
    /// `(n-1)·p` truncation produced.
    #[test]
    fn nearest_rank_indexing() {
        let mut m = ServeMetrics::default();
        for i in 1..=9 {
            m.record_latency_ms(i as f64);
        }
        assert_eq!(m.percentile_ms(0.99), 9.0);
        assert_eq!(m.percentile_ms(0.5), 5.0);
        assert_eq!(m.percentile_ms(0.11), 1.0);
        assert_eq!(m.percentile_ms(0.12), 2.0);
    }

    /// Gauges overwrite, peaks merge, and the summed accessors add
    /// across workers (sparse worker ids included).
    #[test]
    fn kv_gauges_merge_per_worker() {
        let mut m = ServeMetrics::default();
        assert_eq!(m.kv_capacity_bytes(), 0);
        m.record_kv(2, KvPoolGauge { capacity_bytes: 100, used_bytes: 60, peak_bytes: 60 });
        m.record_kv(0, KvPoolGauge { capacity_bytes: 100, used_bytes: 10, peak_bytes: 10 });
        // worker 2 drains: used falls, peak must not
        m.record_kv(2, KvPoolGauge { capacity_bytes: 100, used_bytes: 0, peak_bytes: 40 });
        assert_eq!(m.kv_capacity_bytes(), 200);
        assert_eq!(m.kv_used_bytes(), 10);
        assert_eq!(m.kv_peak_bytes(), 70);
    }
}
