//! Shared harness plumbing for the paper-reproduction experiments.

use std::collections::HashMap;

use anyhow::Result;

use crate::data::{supervised_batch, Batch, Example, Split, Task, Tokenizer, World};
use crate::runtime::{Executable, Executor, Tensor};
use crate::train::{task_accuracy, GenModel, Trainer};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Write an experiment result JSON under results/.
pub fn save_result(name: &str, value: &Json) {
    let _ = std::fs::create_dir_all("results");
    let path = format!("results/{name}.json");
    match std::fs::write(&path, value.to_string_pretty()) {
        Ok(()) => println!("saved {path}"),
        Err(e) => eprintln!("could not save {path}: {e}"),
    }
}

/// Initialize base params from the init artifact.
pub fn init_params(rt: &dyn Executor, model: &str, seed: i32) -> Result<HashMap<String, Tensor>> {
    let init = rt.load(&format!("init_{model}"))?;
    let outs = init.run(&[Tensor::scalar_i32(seed)])?;
    Ok(init
        .spec()
        .outputs
        .iter()
        .map(|s| s.name.clone())
        .zip(outs)
        .collect())
}

/// Pre-train `model` on the synthetic corpus for `steps` full-FT steps,
/// returning base-layout weights. This is the stand-in for the paper's
/// pre-trained LLaMA checkpoints (DESIGN.md §2).
pub fn pretrain(
    rt: &dyn Executor,
    model: &str,
    steps: usize,
    seed: u64,
    log: bool,
) -> Result<HashMap<String, Tensor>> {
    let base = init_params(rt, model, seed as i32)?;
    let (b, t) = rt.artifacts().model(model)?.default_batch();
    let tk = Tokenizer;
    let corpus = crate::data::pretrain_corpus(seed, 200_000);
    let mut rng = Rng::seed(seed ^ 0x9E37);
    let calib = crate::data::lm_batch(&tk, &corpus, &mut rng, b, t);
    let mut trainer = Trainer::new(rt, model, "fullft", &base, seed, &calib)?;
    for step in 0..steps {
        let batch = crate::data::lm_batch(&tk, &corpus, &mut rng, b, t);
        let loss = trainer.train_step(&batch)?;
        if log && (step % 25 == 0 || step + 1 == steps) {
            println!(
                "  pretrain[{model}] step {step:>4}  loss {loss:.4}  ({:.0} tok/s)",
                trainer.metrics.tokens_per_sec()
            );
        }
    }
    trainer.merged_params(rt)
}

/// Load the cached pre-trained checkpoint, or pre-train and cache it.
/// Every accuracy experiment shares this base model.
pub fn pretrained_cached(
    rt: &dyn Executor,
    model: &str,
    steps: usize,
    seed: u64,
) -> Result<HashMap<String, Tensor>> {
    let dir = format!("checkpoints/pretrain_{model}_{steps}_{seed}");
    if let Ok(params) = crate::train::load_params(&dir) {
        println!("  loaded pre-trained base from {dir}");
        return Ok(params);
    }
    println!("  pre-training {model} for {steps} steps (cached to {dir})...");
    let params = pretrain(rt, model, steps, seed, true)?;
    crate::train::save_params(&dir, &params)?;
    Ok(params)
}

/// Fine-tune `method` on a task example stream; returns the trainer.
pub fn finetune(
    rt: &dyn Executor,
    model: &str,
    method: &str,
    base: &HashMap<String, Tensor>,
    examples: &[Example],
    steps: usize,
    seed: u64,
) -> Result<Trainer> {
    let (b, t) = rt.artifacts().model(model)?.default_batch();
    let tk = Tokenizer;
    let calib = batch_at(&tk, examples, 0, b, t);
    let mut trainer = Trainer::new(rt, model, method, base, seed, &calib)?;
    for step in 0..steps {
        let batch = batch_at(&tk, examples, step * b, b, t);
        trainer.train_step(&batch)?;
    }
    Ok(trainer)
}

/// Cyclic mini-batch over an example list.
pub fn batch_at(tk: &Tokenizer, examples: &[Example], offset: usize, b: usize, t: usize) -> Batch {
    let chunk: Vec<Example> = (0..b)
        .map(|i| examples[(offset + i) % examples.len()].clone())
        .collect();
    supervised_batch(tk, &chunk, b, t)
}

/// Per-subtask test accuracy (the paper's table row), returning
/// `(name, accuracy%)` pairs plus the average.
pub fn evaluate_suite(
    model: &GenModel,
    tasks: &[Task],
    n_per_task: usize,
    seed: u64,
) -> Result<(Vec<(String, f64)>, f64)> {
    let world = World::canonical();
    let mut rows = Vec::with_capacity(tasks.len());
    let mut sum = 0.0;
    for task in tasks {
        let mut rng = Rng::seed(seed ^ fxhash(task.name));
        let examples = task.batch(&world, &mut rng, Split::Test, n_per_task);
        let acc = task_accuracy(model, &examples)? * 100.0;
        sum += acc;
        rows.push((task.name.to_string(), acc));
    }
    Ok((rows.clone(), sum / tasks.len() as f64))
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Render an accuracy table like the paper's (methods x subtasks + Avg).
pub fn print_table(title: &str, subtask_names: &[String], rows: &[(String, Vec<f64>, f64)]) {
    println!("\n=== {title} ===");
    print!("{:<14}", "Method");
    for n in subtask_names {
        print!("{:>11}", truncate(n, 10));
    }
    println!("{:>8}", "Avg");
    for (method, accs, avg) in rows {
        print!("{:<14}", method);
        for a in accs {
            print!("{:>11.1}", a);
        }
        println!("{:>8.1}", avg);
    }
}

fn truncate(s: &str, n: usize) -> String {
    s.chars().take(n).collect()
}

/// Rows -> results JSON.
pub fn table_json(subtasks: &[String], rows: &[(String, Vec<f64>, f64)]) -> Json {
    Json::obj(vec![
        ("subtasks", Json::arr_str(subtasks.to_vec())),
        (
            "rows",
            Json::Arr(
                rows.iter()
                    .map(|(m, accs, avg)| {
                        Json::obj(vec![
                            ("method", Json::str(m.clone())),
                            ("accs", Json::arr_f64(accs.iter().copied())),
                            ("avg", Json::num(*avg)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}
