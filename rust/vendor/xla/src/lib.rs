//! API-compatible stub of the `xla` PJRT crate.
//!
//! The real crate links `xla_extension` (a multi-GB native toolchain) and
//! cannot ship inside this repository. This stub keeps the `pjrt` cargo
//! feature *compilable* everywhere: every constructor returns a descriptive
//! runtime error, and callers (which already probe for artifacts before
//! touching PJRT) degrade gracefully. To run real PJRT execution, point the
//! `xla` dependency in `rust/Cargo.toml` at the actual crate.

use std::fmt;

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn stub<T>() -> Result<T, Error> {
    Err(Error(
        "xla stub: PJRT is unavailable in this build; vendor the real `xla` crate \
         (see rust/README.md) or use the native backend"
            .to_string(),
    ))
}

/// Scalar types a [`Literal`] can hold.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u8 {}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U32,
    F32,
    F64,
}

pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

pub struct Literal;

impl Literal {
    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal
    }

    pub fn vec1<T: NativeType>(_v: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        stub()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        stub()
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        stub()
    }

    pub fn ty(&self) -> Result<ElementType, Error> {
        stub()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        stub()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        stub()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        stub()
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        stub()
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        stub()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        stub()
    }
}
