//! Figure 5: training efficiency — peak memory and per-step latency for
//! Full FT / LoRA / S²FT on the `base` model across (batch, seq) shapes.
//!
//! Memory is reported three ways: analytic live-state bytes (params +
//! frozen + optimizer moments, exactly what the method layouts imply —
//! batch inputs never enter the pool, so this is stable across steps),
//! *measured* activation bytes (what the native backend's plan-driven
//! cache actually retained for the backward pass, plus its live peak),
//! and process peak-RSS. Latency is the measured train-step wall time.

use anyhow::Result;

use crate::data::{lm_batch, pretrain_corpus, Tokenizer};
use crate::runtime::{open_backend, Executor};
use crate::train::Trainer;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::common::{init_params, save_result};

const MODEL: &str = "base";

pub fn run_fig5(artifacts: &str, quick: bool) -> Result<()> {
    let rt = open_backend(artifacts)?;
    let mm = rt.artifacts().model(MODEL)?.clone();
    let steps = if quick { 3 } else { 8 };
    let base = init_params(&rt, MODEL, 1)?;
    let tk = Tokenizer;
    let corpus = pretrain_corpus(5, 400_000);

    // every (b, t) shape that has artifacts (default + `make artifacts-fig5`)
    let shapes: Vec<(usize, usize)> = mm.batches.clone();
    let all_methods = ["fullft", "lora", "s2ft"];
    let filter = std::env::var("REPRO_METHODS").ok();
    let methods: Vec<&str> = all_methods
        .iter()
        .copied()
        .filter(|m| filter.as_ref().map_or(true, |f| f.split(',').any(|x| x.trim() == *m)))
        .collect();

    println!("\n=== Figure 5: training efficiency on `{MODEL}` ({:.1}M params) ===", mm.param_count as f64 / 1e6);
    println!(
        "{:<8} {:>5} {:>5} {:>12} {:>12} {:>12} {:>10} {:>10} {:>10}",
        "method", "B", "T", "ms/step", "state MB", "opt MB", "act MB", "act pk MB", "tok/s"
    );
    let mut records = Vec::new();
    let mut baseline_ms: Option<f64> = None;
    let mut baseline_mb: Option<f64> = None;
    let mut baseline_act: Option<f64> = None;
    for &(b, t) in &shapes {
        for &method in &methods {
            let train_name = format!("train_{MODEL}_{method}_{b}x{t}");
            // probe the backend: pjrt needs the artifact built, native
            // interprets fullft/s2ft at any shape and rejects the rest
            if let Err(e) = rt.load(&train_name) {
                println!("  (skipping {method} at {b}x{t}: {e})");
                continue;
            }
            let mut rng = Rng::seed(7);
            let calib = lm_batch(&tk, &corpus, &mut rng, b, t);
            let mut trainer =
                Trainer::with_batch(&rt, MODEL, method, &base, 3, &calib, b, t)?;
            // warmup (compile + first-run allocations)
            let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
            trainer.train_step(&batch)?;
            trainer.metrics = crate::train::TrainMetrics::new();
            for _ in 0..steps {
                let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
                trainer.train_step(&batch)?;
            }
            let ms = trainer.metrics.ms_per_step();
            let state_mb = trainer.state_bytes() as f64 / 1e6;
            let opt_mb = trainer.opt_bytes() as f64 / 1e6;
            // measured activation cache (native backend; AOT reports none)
            let act_mb = trainer.activation_bytes().map(|v| v as f64 / 1e6);
            let act_pk_mb = trainer.activation_peak_bytes().map(|v| v as f64 / 1e6);
            let fmt_opt = |v: Option<f64>| match v {
                Some(v) => format!("{v:.1}"),
                None => "-".to_string(),
            };
            let tps = trainer.metrics.tokens_per_sec();
            println!(
                "{:<8} {:>5} {:>5} {:>12.1} {:>12.1} {:>12.1} {:>10} {:>10} {:>10.0}",
                method,
                b,
                t,
                ms,
                state_mb,
                opt_mb,
                fmt_opt(act_mb),
                fmt_opt(act_pk_mb),
                tps
            );
            if method == "fullft" && (b, t) == shapes[0] {
                baseline_ms = Some(ms);
                baseline_mb = Some(state_mb);
                baseline_act = act_mb;
            }
            records.push(Json::obj(vec![
                ("method", Json::str(method)),
                ("batch", Json::num(b as f64)),
                ("seq", Json::num(t as f64)),
                ("ms_per_step", Json::num(ms)),
                ("state_mb", Json::num(state_mb)),
                ("opt_mb", Json::num(opt_mb)),
                (
                    "act_mb",
                    act_mb.map(Json::num).unwrap_or(Json::Null),
                ),
                (
                    "act_peak_mb",
                    act_pk_mb.map(Json::num).unwrap_or(Json::Null),
                ),
                ("tokens_per_sec", Json::num(tps)),
                (
                    "peak_rss_mb",
                    Json::num(crate::util::peak_rss_bytes().unwrap_or(0) as f64 / 1e6),
                ),
            ]));
            // free the compiled executable before the next big one
            rt.evict(&train_name);
        }
    }
    if let (Some(bms), Some(bmb)) = (baseline_ms, baseline_mb) {
        // summary ratios vs full FT at the default shape
        println!("\nRatios vs Full FT (default shape): paper reports 1.5-2.7x latency, 1.4-3.0x memory.");
        for r in &records {
            let m = r.get("method").unwrap().as_str().unwrap();
            if m != "fullft"
                && r.get("batch").unwrap().as_usize().unwrap() == shapes[0].0
                && r.get("seq").unwrap().as_usize().unwrap() == shapes[0].1
            {
                let ra = r.get("act_mb").ok().and_then(|v| v.as_f64().ok());
                let act_ratio = match (baseline_act, ra) {
                    (Some(base), Some(act)) if act > 0.0 => {
                        format!(", measured act {:.2}x smaller", base / act)
                    }
                    _ => String::new(),
                };
                println!(
                    "  {m}: latency {:.2}x faster, state {:.2}x smaller{act_ratio}",
                    bms / r.get("ms_per_step").unwrap().as_f64().unwrap(),
                    bmb / r.get("state_mb").unwrap().as_f64().unwrap(),
                );
            }
        }
    }
    // merge with prior chunked invocations (keyed by method/batch/seq)
    let mut merged: Vec<Json> = Vec::new();
    if let Ok(prev) = std::fs::read_to_string("results/fig5.json") {
        if let Ok(Json::Arr(prows)) = Json::parse(&prev) {
            for pr in prows {
                let key = |r: &Json| {
                    (
                        r.get("method").ok().and_then(|v| v.as_str().ok().map(String::from)),
                        r.get("batch").ok().and_then(|v| v.as_usize().ok()),
                        r.get("seq").ok().and_then(|v| v.as_usize().ok()),
                    )
                };
                if !records.iter().any(|r| key(r) == key(&pr)) {
                    merged.push(pr);
                }
            }
        }
    }
    merged.extend(records);
    save_result("fig5", &Json::Arr(merged));
    Ok(())
}
