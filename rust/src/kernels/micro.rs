//! The register-tiled micro-kernel and its SIMD/scalar runtime dispatch.
//!
//! One tile computes an `MR × NR` block of output elements from packed
//! panels ([`super::pack`]): for every reduction step it broadcasts `MR`
//! A values and multiplies them against one `NR`-wide B row, keeping all
//! `MR * NR` accumulators live in registers across the whole depth loop.
//!
//! # The dispatch contract
//!
//! Both paths — the portable tile (written so LLVM autovectorizes the
//! fixed-width inner loops) and the `std::arch` AVX2 tile — compute every
//! accumulator lane as **one scalar chain in ascending reduction order,
//! rounding the product and the sum separately** (`mul` then `add`, never
//! a fused multiply-add). Each lane is an independent output element, so
//! the two paths are bit-identical to each other *and* to the naive
//! triple-loop references for every input, and the runtime dispatch
//! decision can never change results.
//!
//! Dispatch order: the `S2FT_SIMD` environment variable (`0` / `off` /
//! `scalar` / `false` forces the portable tile; read once per process),
//! then [`simd_supported`] (compiled on `x86_64` and AVX2 detected at
//! runtime). Non-`x86_64` targets always take the portable tile.

use std::sync::OnceLock;

use super::pack::{MR, NR};

/// True when a `std::arch` micro-kernel is compiled in **and** the CPU
/// supports it at runtime (AVX2 on `x86_64`).
#[cfg(target_arch = "x86_64")]
pub fn simd_supported() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

/// True when a `std::arch` micro-kernel is compiled in **and** the CPU
/// supports it at runtime (AVX2 on `x86_64`).
#[cfg(not(target_arch = "x86_64"))]
pub fn simd_supported() -> bool {
    false
}

/// The process-wide dispatch decision: [`simd_supported`] unless the
/// `S2FT_SIMD` environment variable disables it (`0`, `off`, `scalar`,
/// `false`; read once per process). The explicit `*_with_dispatch` kernel
/// entry points bypass this for per-call control (tests, benches, the CI
/// scalar lane).
pub fn simd_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        let forced_off = std::env::var("S2FT_SIMD")
            .map(|v| matches!(v.trim(), "0" | "off" | "scalar" | "false"))
            .unwrap_or(false);
        simd_supported() && !forced_off
    })
}

/// Compute one packed tile into `acc` through the selected path. `pa` is
/// a `depth * MR` A panel, `pb` a `depth * NR` B panel; `acc[r][j]`
/// receives `sum_step pa[step * MR + r] * pb[step * NR + j]`, every lane
/// accumulated from `+0.0` in ascending `step` order. `simd: true` falls
/// back to the portable tile when the CPU lacks the feature.
#[inline]
pub(crate) fn tile(pa: &[f32], pb: &[f32], acc: &mut [[f32; NR]; MR], simd: bool) {
    #[cfg(target_arch = "x86_64")]
    if simd && simd_supported() {
        // SAFETY: AVX2 support was verified at runtime just above.
        unsafe { tile_avx2(pa, pb, acc) };
        return;
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = simd;
    tile_scalar(pa, pb, acc);
}

/// Portable tile: fixed-width (`NR`) inner loops over a local accumulator
/// array, written so LLVM autovectorizes them; the per-lane operation
/// sequence (mul, then add, ascending step) is exactly the AVX2 tile's.
#[inline]
pub(crate) fn tile_scalar(pa: &[f32], pb: &[f32], acc: &mut [[f32; NR]; MR]) {
    debug_assert_eq!(pa.len() / MR, pb.len() / NR, "tile: panel depth mismatch");
    let mut c = [[0.0f32; NR]; MR];
    for (av, bv) in pa.chunks_exact(MR).zip(pb.chunks_exact(NR)) {
        for (cr, &a) in c.iter_mut().zip(av) {
            for (cc, &b) in cr.iter_mut().zip(bv) {
                *cc += a * b;
            }
        }
    }
    *acc = c;
}

/// AVX2 tile: two 8-lane vectors per row of the register block, explicit
/// `mul` + `add` (never `fmadd` — the fused rounding would diverge from
/// the scalar tile and the naive references).
///
/// # Safety
/// The caller must ensure the CPU supports AVX2.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
// SAFETY: `#[target_feature]` makes every call unsafe; the only caller is
// the dispatch in `tile`, which runs this after `simd_supported()`
// confirms AVX2 at runtime.
unsafe fn tile_avx2(pa: &[f32], pb: &[f32], acc: &mut [[f32; NR]; MR]) {
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_setzero_ps,
        _mm256_storeu_ps,
    };
    let depth = pa.len() / MR;
    debug_assert_eq!(depth, pb.len() / NR, "tile: panel depth mismatch");
    let pa = pa.as_ptr();
    let pb = pb.as_ptr();
    let mut c = [[_mm256_setzero_ps(); 2]; MR];
    for step in 0..depth {
        let b0 = _mm256_loadu_ps(pb.add(step * NR));
        let b1 = _mm256_loadu_ps(pb.add(step * NR + 8));
        for (r, cr) in c.iter_mut().enumerate() {
            let a = _mm256_set1_ps(*pa.add(step * MR + r));
            cr[0] = _mm256_add_ps(cr[0], _mm256_mul_ps(a, b0));
            cr[1] = _mm256_add_ps(cr[1], _mm256_mul_ps(a, b1));
        }
    }
    for (cr, arow) in c.iter().zip(acc.iter_mut()) {
        _mm256_storeu_ps(arow.as_mut_ptr(), cr[0]);
        _mm256_storeu_ps(arow.as_mut_ptr().add(8), cr[1]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_tile(pa: &[f32], pb: &[f32]) -> [[f32; NR]; MR] {
        let depth = pa.len() / MR;
        let mut acc = [[0.0f32; NR]; MR];
        for step in 0..depth {
            for (r, arow) in acc.iter_mut().enumerate() {
                for (j, cc) in arow.iter_mut().enumerate() {
                    *cc += pa[step * MR + r] * pb[step * NR + j];
                }
            }
        }
        acc
    }

    #[test]
    fn both_paths_match_the_naive_tile_bitwise() {
        let depth = 9;
        let pa: Vec<f32> = (0..depth * MR).map(|i| (i as f32).sin()).collect();
        let pb: Vec<f32> = (0..depth * NR).map(|i| (i as f32 * 0.7).cos()).collect();
        let want = naive_tile(&pa, &pb);
        for simd in [false, true] {
            let mut acc = [[f32::NAN; NR]; MR];
            tile(&pa, &pb, &mut acc, simd);
            for (ar, wr) in acc.iter().zip(&want) {
                for (a, w) in ar.iter().zip(wr) {
                    assert_eq!(a.to_bits(), w.to_bits(), "simd={simd}");
                }
            }
        }
    }

    #[test]
    fn zero_depth_tile_clears_the_accumulator() {
        for simd in [false, true] {
            let mut acc = [[f32::NAN; NR]; MR];
            tile(&[], &[], &mut acc, simd);
            assert!(acc.iter().all(|r| r.iter().all(|v| v.to_bits() == 0)), "simd={simd}");
        }
    }

    #[test]
    fn dispatch_env_probe_is_consistent() {
        // simd_enabled() may be on or off depending on the machine/env,
        // but it must never claim SIMD without hardware support.
        assert!(!simd_enabled() || simd_supported());
    }
}
