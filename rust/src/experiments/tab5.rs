//! Table 5: adapter fusion. Train commonsense + arithmetic adapters, fuse
//! with equal weights, and measure the degradation on both suites —
//! comparing LoRA fusion vs S²FT fusion with overlapped vs non-overlapped
//! channel selections.

use std::collections::HashMap;

use anyhow::Result;

use crate::adapter::S2ftAdapter;
use crate::data::{finetune_examples, ARITHMETIC, COMMONSENSE};
use crate::runtime::{open_backend, Executor, Tensor};
use crate::train::GenModel;
use crate::util::json::Json;

use super::common::{evaluate_suite, finetune, pretrained_cached, save_result};

const MODEL: &str = "small";

pub fn run_tab5(artifacts: &str, quick: bool) -> Result<()> {
    let rt = open_backend(artifacts)?;
    let (pre_steps, ft_steps, n_eval) = if quick { (60, 30, 8) } else { (800, 180, 20) };
    let base = pretrained_cached(&rt, MODEL, pre_steps, 42)?;
    let mm = rt.artifacts().model(MODEL)?.clone();
    let method = mm.method("s2ft")?.clone();

    let cs_examples = finetune_examples("commonsense", 2000, 41);
    let ar_examples = finetune_examples("arithmetic", 2000, 43);

    let eval_both = |params: HashMap<String, Tensor>| -> Result<(f64, f64)> {
        let model = GenModel::new(&rt, MODEL, params)?;
        let (_, cs) = evaluate_suite(&model, &COMMONSENSE, n_eval, 0x7AB5)?;
        let (_, ar) = evaluate_suite(&model, &ARITHMETIC, n_eval, 0x7AB5)?;
        Ok((cs, ar))
    };

    println!("\n=== Table 5: adapter fusion (avg acc %, rows = eval suite) ===");
    let mut records = Vec::new();
    let emit = |label: &str, cs: f64, ar: f64, records: &mut Vec<Json>| {
        println!("{:<28} commonsense {:>5.1}   arithmetic {:>5.1}", label, cs, ar);
        records.push(Json::obj(vec![
            ("setting", Json::str(label)),
            ("commonsense", Json::num(cs)),
            ("arithmetic", Json::num(ar)),
        ]));
    };

    // --- S2FT: same selection seed => overlapped channels -----------------
    println!("tab5: training S2FT adapters (overlap: same selection seed)...");
    let t_cs = finetune(&rt, MODEL, "s2ft", &base, &cs_examples, ft_steps, 51)?;
    let t_ar_overlap = finetune(&rt, MODEL, "s2ft", &base, &ar_examples, ft_steps, 51)?;
    // --- different selection seed => (mostly) non-overlapping channels ----
    println!("tab5: training S2FT arithmetic adapter (non-overlap seed)...");
    let t_ar_disjoint = finetune(&rt, MODEL, "s2ft", &base, &ar_examples, ft_steps, 52)?;

    let a_cs = S2ftAdapter::extract(&mm, &method, &t_cs.perms, &base, &t_cs.merged_params(&rt)?)?;
    let a_ar_o = S2ftAdapter::extract(
        &mm, &method, &t_ar_overlap.perms, &base, &t_ar_overlap.merged_params(&rt)?,
    )?;
    let a_ar_d = S2ftAdapter::extract(
        &mm, &method, &t_ar_disjoint.perms, &base, &t_ar_disjoint.merged_params(&rt)?,
    )?;
    println!(
        "  channel overlap: same-seed {:.0}%, diff-seed {:.0}%",
        a_cs.overlap_with(&a_ar_o) * 100.0,
        a_cs.overlap_with(&a_ar_d) * 100.0
    );

    // individual adapters
    let (cs1, ar1) = eval_both(apply(&base, &a_cs)?)?;
    emit("S2FT commonsense adapter", cs1, ar1, &mut records);
    let (cs2, ar2) = eval_both(apply(&base, &a_ar_d)?)?;
    emit("S2FT arithmetic adapter", cs2, ar2, &mut records);

    // fused variants
    let fused_o = S2ftAdapter::fuse(&[(&a_cs, 0.5), (&a_ar_o, 0.5)])?;
    let (cso, aro) = eval_both(apply(&base, &fused_o)?)?;
    emit("S2FT fused (overlap)", cso, aro, &mut records);
    let fused_d = S2ftAdapter::fuse(&[(&a_cs, 0.5), (&a_ar_d, 0.5)])?;
    let (csd, ard) = eval_both(apply(&base, &fused_d)?)?;
    emit("S2FT fused (non-overlap)", csd, ard, &mut records);

    // --- LoRA baseline -----------------------------------------------------
    if mm.methods.get("lora").is_none() {
        println!("tab5: skipping LoRA baseline (method not available on this backend)");
        save_result("tab5", &Json::Arr(records));
        return Ok(());
    }
    println!("tab5: training LoRA adapters...");
    let l_cs = finetune(&rt, MODEL, "lora", &base, &cs_examples, ft_steps, 53)?;
    let l_ar = finetune(&rt, MODEL, "lora", &base, &ar_examples, ft_steps, 54)?;
    let m_cs = l_cs.merged_params(&rt)?;
    let m_ar = l_ar.merged_params(&rt)?;
    let (lcs1, lar1) = eval_both(m_cs.clone())?;
    emit("LoRA commonsense adapter", lcs1, lar1, &mut records);
    let (lcs2, lar2) = eval_both(m_ar.clone())?;
    emit("LoRA arithmetic adapter", lcs2, lar2, &mut records);
    // weighted ΔW fusion
    let mut fused = base.clone();
    for (k, v) in fused.iter_mut() {
        let b = base[k].as_f32()?;
        let c = m_cs[k].as_f32()?;
        let a = m_ar[k].as_f32()?;
        let out = v.as_f32_mut()?;
        for i in 0..out.len() {
            out[i] = b[i] + 0.5 * (c[i] - b[i]) + 0.5 * (a[i] - b[i]);
        }
    }
    let (lcsf, larf) = eval_both(fused)?;
    emit("LoRA fused", lcsf, larf, &mut records);

    println!("\nExpected shape (paper): fusion degrades both; S2FT non-overlap degrades least.");
    save_result("tab5", &Json::Arr(records));
    Ok(())
}

fn apply(base: &HashMap<String, Tensor>, adapter: &S2ftAdapter) -> Result<HashMap<String, Tensor>> {
    let mut p = base.clone();
    adapter.apply(&mut p)?;
    Ok(p)
}
