//! Adapter residency for thousand-adapter multi-tenant serving.
//!
//! An [`AdapterRegistry`] tracks every adapter a deployment knows about
//! — far more than fit in memory at once — and keeps only a bounded
//! *resident set* of decoded weights, the S-LoRA-style scenario of
//! paper §6.2 scaled out: S²FT deltas are small (s·d floats per layer),
//! so a thousand registered adapters are cheap on disk and a few dozen
//! resident ones serve the working set.
//!
//! Three cooperating mechanisms:
//!
//! * **Residency (LRU + pinning).** Every acquire stamps the entry with
//!   a monotone tick. When the resident set exceeds
//!   [`ResidencyConfig::max_resident`], the coldest unpinned entry is
//!   spilled: written to [`ResidencyConfig::spill_dir`] in the
//!   [`crate::adapter::save_adapter`] format if its weights are not
//!   already on disk (`dirty`), then dropped from memory. In-flight
//!   work pins entries via [`AdapterLease`] (RAII — dropping the lease
//!   unpins), so a batch can never have its weights spilled from under
//!   it. LoRA adapters have no persist format and are never spilled;
//!   they can push the resident set over budget, which is tolerated
//!   rather than violating correctness.
//! * **Lazy load.** Acquiring a non-resident adapter decodes it from
//!   its on-disk copy under the registry lock (loads serialize; S²FT
//!   payloads are kilobytes, so a load costs about as much as a fuse).
//! * **Traffic-driven fuse policy.** [`AdapterRegistry::note_batch`]
//!   feeds per-adapter EWMA requests/sec; [`AdapterRegistry::fuse_policy`]
//!   answers [`FusePolicy::Fused`] for hot adapters (scatter-add the
//!   delta into the worker's weights — cheapest when many consecutive
//!   batches reuse it) and [`FusePolicy::Unfused`] for cold ones (apply
//!   the delta at decode time via gather + GEMV,
//!   [`crate::runtime::PagedDecodeSession::set_unfused_adapter`], so a
//!   one-off request pays no fuse/unfuse round trip). With the default
//!   `hot_rps = 0` every adapter is considered hot, preserving the
//!   bit-tested fused path.
//!
//! The registry wraps the engine's [`AdapterStore`] and mirrors the
//! resident set into it, so existing store-based introspection
//! (`len()`, `total_bytes()`) keeps reporting the in-memory state.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::adapter::{load_adapter, save_adapter, AdapterStore, AnyAdapter};

/// File extension for persisted adapters; [`AdapterRegistry::register_dir`]
/// scans for `*.s2ft` and uses the file stem as the adapter id.
pub const ADAPTER_EXT: &str = "s2ft";

/// Residency and fuse-policy knobs for an [`AdapterRegistry`].
#[derive(Debug, Clone)]
pub struct ResidencyConfig {
    /// Resident-set budget; `0` means unbounded (nothing ever spills).
    pub max_resident: usize,
    /// Where dirty adapters are written when spilled. `None` makes
    /// never-persisted adapters unspillable (they stay resident).
    pub spill_dir: Option<PathBuf>,
    /// EWMA requests/sec at or above which an adapter is fused.
    /// `0` (default) fuses unconditionally; `f64::INFINITY` forces the
    /// unfused path for every adapter.
    pub hot_rps: f64,
    /// Smoothing factor for the per-adapter rate EWMA in `(0, 1]`;
    /// higher reacts faster to traffic shifts.
    pub ewma_alpha: f64,
}

impl Default for ResidencyConfig {
    fn default() -> Self {
        Self { max_resident: 0, spill_dir: None, hot_rps: 0.0, ewma_alpha: 0.3 }
    }
}

/// How a worker should apply an adapter to serve a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FusePolicy {
    /// Scatter-add the delta into the live weights (hot path).
    Fused,
    /// Leave base weights untouched; apply the delta per decode step
    /// (cold path — no fuse/unfuse round trip).
    Unfused,
}

/// Residency counters, exposed through
/// [`crate::serve::ServeMetrics::residency`] and the `repro serve`
/// report. Counter fields are cumulative; `registered` / `resident` are
/// point-in-time gauges filled by [`AdapterRegistry::stats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResidencyStats {
    /// Adapters the registry knows about (resident or on disk).
    pub registered: usize,
    /// Adapters currently decoded in memory.
    pub resident: usize,
    /// Acquires served from the resident set.
    pub hits: usize,
    /// Acquires that found the adapter non-resident.
    pub misses: usize,
    /// Successful lazy loads from disk (one per miss that recovered).
    pub loads: usize,
    /// Adapters evicted from the resident set (written to disk first
    /// when dirty).
    pub spills: usize,
    /// Batches served with the adapter fused into worker weights.
    pub fused_batches: usize,
    /// Batches served with the adapter applied unfused at decode time.
    pub unfused_batches: usize,
}

impl ResidencyStats {
    /// Fraction of acquires served without touching disk (1.0 when no
    /// acquire has happened yet).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Cumulative request/token counters and the traffic EWMA for one
/// registered adapter ([`AdapterRegistry::traffic`]).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AdapterTraffic {
    /// Requests served under this adapter.
    pub requests: u64,
    /// Tokens generated under this adapter.
    pub tokens: u64,
    /// Smoothed requests/sec (see [`ResidencyConfig::ewma_alpha`]);
    /// 0 until a second batch establishes an interval.
    pub ewma_rps: f64,
}

/// Per-adapter registry entry: at most one of memory/disk may be
/// missing, never both.
struct Entry {
    resident: Option<Arc<AnyAdapter>>,
    disk: Option<PathBuf>,
    /// Resident weights differ from (or lack) an on-disk copy, so a
    /// spill must write before dropping.
    dirty: bool,
    pins: usize,
    last_used: u64,
    traffic: AdapterTraffic,
    last_batch: Option<Instant>,
}

#[derive(Default)]
struct Inner {
    entries: HashMap<String, Entry>,
    /// Monotone LRU clock, bumped per acquire/insert.
    tick: u64,
    stats: ResidencyStats,
}

/// Bounded-residency adapter registry: the serving tier's source of
/// truth for which adapters exist, which are in memory, and how hot
/// each one is. See the module docs for the full model.
pub struct AdapterRegistry {
    store: AdapterStore,
    cfg: ResidencyConfig,
    inner: Mutex<Inner>,
}

impl AdapterRegistry {
    /// Empty registry with the given residency policy.
    pub fn new(cfg: ResidencyConfig) -> Self {
        Self { store: AdapterStore::new(), cfg, inner: Mutex::new(Inner::default()) }
    }

    /// The backing [`AdapterStore`] mirroring the resident set (shared
    /// introspection surface: `len()`, `total_bytes()`, ...).
    pub fn store(&self) -> &AdapterStore {
        &self.store
    }

    /// The policy this registry was built with.
    pub fn config(&self) -> &ResidencyConfig {
        &self.cfg
    }

    /// Register `adapter` with its weights resident (the classic
    /// runtime-registration path). The entry starts dirty: it has no
    /// on-disk copy until a spill writes one. Replaces any previous
    /// entry under `id` and may spill a colder adapter to stay within
    /// budget.
    pub fn insert_resident(&self, id: impl Into<String>, adapter: AnyAdapter) {
        let id = id.into();
        let handle = Arc::new(adapter);
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        inner.entries.insert(
            id.clone(),
            Entry {
                resident: Some(handle.clone()),
                disk: None,
                dirty: true,
                pins: 0,
                last_used: tick,
                traffic: AdapterTraffic::default(),
                last_batch: None,
            },
        );
        self.store.insert_arc(id, handle);
        self.evict_to_budget(&mut inner);
    }

    /// Register an adapter by its on-disk file without decoding it; the
    /// weights load lazily on first [`acquire`](Self::acquire).
    /// Replaces any previous entry under `id`.
    pub fn register_on_disk(&self, id: impl Into<String>, path: impl Into<PathBuf>) {
        let id = id.into();
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let prev = inner.entries.insert(
            id.clone(),
            Entry {
                resident: None,
                disk: Some(path.into()),
                dirty: false,
                pins: 0,
                last_used: tick,
                traffic: AdapterTraffic::default(),
                last_batch: None,
            },
        );
        if prev.and_then(|e| e.resident).is_some() {
            let _ = self.store.remove(&id);
        }
    }

    /// Register every `*.s2ft` file under `dir` (id = file stem, lazy
    /// load), in sorted order. Returns how many were registered.
    pub fn register_dir(&self, dir: impl AsRef<Path>) -> Result<usize> {
        let dir = dir.as_ref();
        let mut paths = Vec::new();
        for e in
            std::fs::read_dir(dir).with_context(|| format!("read adapter dir {}", dir.display()))?
        {
            let p = e?.path();
            if p.extension().and_then(|s| s.to_str()) == Some(ADAPTER_EXT) {
                paths.push(p);
            }
        }
        paths.sort();
        let mut n = 0;
        for p in paths {
            let Some(stem) = p.file_stem().and_then(|s| s.to_str()) else { continue };
            self.register_on_disk(stem.to_string(), p.clone());
            n += 1;
        }
        Ok(n)
    }

    /// Forget `id` entirely (memory and registry; any on-disk file is
    /// left alone). In-flight leases keep their `Arc` and stay valid.
    pub fn remove(&self, id: &str) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let e = inner
            .entries
            .remove(id)
            .ok_or_else(|| anyhow!("adapter {id:?} not registered"))?;
        if e.resident.is_some() {
            let _ = self.store.remove(id);
        }
        Ok(())
    }

    /// Pin `id`'s weights in memory and return a lease on them, lazily
    /// loading from disk on a residency miss. The entry cannot be
    /// spilled while the lease lives; drop it when the batch is done.
    pub fn acquire(&self, id: &str) -> Result<AdapterLease<'_>> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let entry = inner
            .entries
            .get_mut(id)
            .ok_or_else(|| anyhow!("adapter {id:?} not registered"))?;
        if let Some(a) = entry.resident.clone() {
            entry.pins += 1;
            entry.last_used = tick;
            inner.stats.hits += 1;
            return Ok(AdapterLease { registry: self, id: id.to_string(), adapter: a });
        }
        let path = entry
            .disk
            .clone()
            .ok_or_else(|| anyhow!("adapter {id:?} has neither resident weights nor a disk copy"))?;
        inner.stats.misses += 1;
        let loaded = Arc::new(AnyAdapter::S2ft(
            load_adapter(&path)
                .with_context(|| format!("lazy-load adapter {id:?} from {}", path.display()))?,
        ));
        inner.stats.loads += 1;
        let entry = inner.entries.get_mut(id).unwrap();
        entry.resident = Some(loaded.clone());
        entry.dirty = false;
        entry.pins += 1;
        entry.last_used = tick;
        self.store.insert_arc(id, loaded.clone());
        self.evict_to_budget(&mut inner);
        Ok(AdapterLease { registry: self, id: id.to_string(), adapter: loaded })
    }

    /// Record a served batch for `id`: bumps the cumulative counters,
    /// updates the rate EWMA from the inter-batch interval, and tallies
    /// which application path (`unfused`) the batch used.
    pub fn note_batch(&self, id: &str, requests: usize, tokens: usize, unfused: bool) {
        self.note_batch_at(id, requests, tokens, unfused, Instant::now());
    }

    pub(crate) fn note_batch_at(
        &self,
        id: &str,
        requests: usize,
        tokens: usize,
        unfused: bool,
        now: Instant,
    ) {
        let mut inner = self.inner.lock().unwrap();
        if unfused {
            inner.stats.unfused_batches += 1;
        } else {
            inner.stats.fused_batches += 1;
        }
        let Some(e) = inner.entries.get_mut(id) else { return };
        e.traffic.requests += requests as u64;
        e.traffic.tokens += tokens as u64;
        if let Some(last) = e.last_batch {
            let dt = now.duration_since(last).as_secs_f64().max(1e-6);
            let inst = requests as f64 / dt;
            let a = self.cfg.ewma_alpha.clamp(0.0, 1.0);
            e.traffic.ewma_rps = a * inst + (1.0 - a) * e.traffic.ewma_rps;
        }
        e.last_batch = Some(now);
    }

    /// Decide how a worker should apply `id` for the next batch. Hot
    /// (effective rate ≥ [`ResidencyConfig::hot_rps`]) → fuse; cold →
    /// apply unfused. The effective rate is the EWMA capped by
    /// `1 / seconds-since-last-batch`, so an adapter that stops getting
    /// traffic cools down even though its EWMA is stale.
    pub fn fuse_policy(&self, id: &str) -> FusePolicy {
        self.fuse_policy_at(id, Instant::now())
    }

    pub(crate) fn fuse_policy_at(&self, id: &str, now: Instant) -> FusePolicy {
        if self.cfg.hot_rps <= 0.0 {
            return FusePolicy::Fused;
        }
        let inner = self.inner.lock().unwrap();
        let Some(e) = inner.entries.get(id) else { return FusePolicy::Unfused };
        let Some(last) = e.last_batch else { return FusePolicy::Unfused };
        let dt = now.duration_since(last).as_secs_f64().max(1e-6);
        let effective = e.traffic.ewma_rps.min(1.0 / dt);
        if effective >= self.cfg.hot_rps {
            FusePolicy::Fused
        } else {
            FusePolicy::Unfused
        }
    }

    /// Traffic counters for `id`, if registered.
    pub fn traffic(&self, id: &str) -> Option<AdapterTraffic> {
        self.inner.lock().unwrap().entries.get(id).map(|e| e.traffic)
    }

    /// Whether `id`'s weights are currently decoded in memory.
    pub fn is_resident(&self, id: &str) -> bool {
        self.inner
            .lock()
            .unwrap()
            .entries
            .get(id)
            .is_some_and(|e| e.resident.is_some())
    }

    /// Every registered adapter id (resident or not), sorted.
    pub fn ids(&self) -> Vec<String> {
        let mut v: Vec<String> = self.inner.lock().unwrap().entries.keys().cloned().collect();
        v.sort();
        v
    }

    /// Number of registered adapters (resident or not).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// True when nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().entries.is_empty()
    }

    /// Point-in-time snapshot of the counters plus the current
    /// registered/resident gauges.
    pub fn stats(&self) -> ResidencyStats {
        let inner = self.inner.lock().unwrap();
        let mut s = inner.stats;
        s.registered = inner.entries.len();
        s.resident = inner.entries.values().filter(|e| e.resident.is_some()).count();
        s
    }

    /// Can this entry leave the resident set right now? Clean entries
    /// need an on-disk copy to fall back to; dirty ones need a spill
    /// dir to write to and must be S²FT (LoRA has no persist format).
    fn spillable(&self, e: &Entry) -> bool {
        if e.dirty {
            matches!(e.resident.as_deref(), Some(AnyAdapter::S2ft(_)))
                && self.cfg.spill_dir.is_some()
        } else {
            e.disk.is_some()
        }
    }

    /// Spill coldest unpinned spillable entries until the resident set
    /// fits the budget. When nothing qualifies (everything pinned or
    /// unspillable) the set is left over budget — correctness beats the
    /// cap. Spill write failures likewise stop eviction for this round.
    fn evict_to_budget(&self, inner: &mut Inner) {
        let cap = self.cfg.max_resident;
        if cap == 0 {
            return;
        }
        loop {
            let resident = inner.entries.values().filter(|e| e.resident.is_some()).count();
            if resident <= cap {
                return;
            }
            let victim = inner
                .entries
                .iter()
                .filter(|(_, e)| e.resident.is_some() && e.pins == 0 && self.spillable(e))
                .min_by_key(|(id, e)| (e.last_used, id.to_string()))
                .map(|(id, _)| id.clone());
            let Some(id) = victim else { return };
            if self.spill_locked(inner, &id).is_err() {
                return;
            }
        }
    }

    /// Drop `id`'s resident weights, writing them to the spill dir
    /// first when no on-disk copy exists yet.
    fn spill_locked(&self, inner: &mut Inner, id: &str) -> Result<()> {
        let e = inner.entries.get_mut(id).ok_or_else(|| anyhow!("adapter {id:?} vanished"))?;
        let Some(a) = e.resident.clone() else { return Ok(()) };
        if e.dirty {
            let dir = self
                .cfg
                .spill_dir
                .as_ref()
                .ok_or_else(|| anyhow!("no spill dir configured"))?;
            let AnyAdapter::S2ft(s) = a.as_ref() else {
                bail!("LoRA adapters cannot be spilled");
            };
            let path = dir.join(format!("{id}.{ADAPTER_EXT}"));
            save_adapter(&path, s).with_context(|| format!("spill adapter {id:?}"))?;
            e.disk = Some(path);
            e.dirty = false;
        }
        e.resident = None;
        let _ = self.store.remove(id);
        inner.stats.spills += 1;
        Ok(())
    }
}

/// RAII pin on one resident adapter: holds the shared weight handle and
/// keeps the entry unspillable until dropped.
pub struct AdapterLease<'r> {
    registry: &'r AdapterRegistry,
    id: String,
    adapter: Arc<AnyAdapter>,
}

impl AdapterLease<'_> {
    /// The leased adapter's id.
    pub fn id(&self) -> &str {
        &self.id
    }

    /// Shared handle to the leased weights (valid past the lease — the
    /// `Arc` keeps them alive — but no longer pinned once it drops).
    pub fn handle(&self) -> Arc<AnyAdapter> {
        self.adapter.clone()
    }
}

impl Drop for AdapterLease<'_> {
    fn drop(&mut self) {
        let mut inner = self.registry.inner.lock().unwrap();
        if let Some(e) = inner.entries.get_mut(&self.id) {
            e.pins = e.pins.saturating_sub(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::{LoraAdapter, S2ftAdapter, S2ftLayerDelta};
    use std::time::Duration;

    fn s2ft(seed: u32, d: usize) -> AnyAdapter {
        AnyAdapter::S2ft(S2ftAdapter {
            layers: vec![S2ftLayerDelta {
                wo_rows: vec![0, 2],
                wo_delta: (0..2 * d).map(|j| (seed * 1000 + j as u32) as f32 * 1e-3).collect(),
                wd_rows: vec![1],
                wd_delta: (0..d).map(|j| (seed * 7 + j as u32) as f32 * 1e-2).collect(),
            }],
            d_model: d,
        })
    }

    fn same_weights(a: &AnyAdapter, b: &AnyAdapter) -> bool {
        let (AnyAdapter::S2ft(a), AnyAdapter::S2ft(b)) = (a, b) else {
            return false;
        };
        a.d_model == b.d_model
            && a.layers.len() == b.layers.len()
            && a.layers.iter().zip(&b.layers).all(|(x, y)| {
                x.wo_rows == y.wo_rows
                    && x.wd_rows == y.wd_rows
                    && x.wo_delta.iter().zip(&y.wo_delta).all(|(p, q)| p.to_bits() == q.to_bits())
                    && x.wd_delta.iter().zip(&y.wd_delta).all(|(p, q)| p.to_bits() == q.to_bits())
            })
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("s2ft-residency-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn lru_spill_and_lazy_reload_are_lossless() {
        let dir = temp_dir("lru");
        let reg = AdapterRegistry::new(ResidencyConfig {
            max_resident: 2,
            spill_dir: Some(dir.clone()),
            ..Default::default()
        });
        let original = s2ft(1, 8);
        let keep = match &original {
            AnyAdapter::S2ft(a) => a.clone(),
            _ => unreachable!(),
        };
        reg.insert_resident("a", original);
        reg.insert_resident("b", s2ft(2, 8));
        reg.insert_resident("c", s2ft(3, 8));
        // cap 2: "a" (coldest) spilled to disk, still registered
        assert!(!reg.is_resident("a"));
        assert!(reg.is_resident("b") && reg.is_resident("c"));
        assert_eq!(reg.ids(), vec!["a", "b", "c"]);
        assert_eq!(reg.store().len(), 2, "store mirrors the resident set");
        let s = reg.stats();
        assert_eq!((s.registered, s.resident, s.spills), (3, 2, 1));

        // lazy reload on acquire: bitwise-identical weights, "b" (now
        // coldest) spilled to make room
        let lease = reg.acquire("a").unwrap();
        assert!(same_weights(&lease.handle(), &AnyAdapter::S2ft(keep)));
        assert!(!reg.is_resident("b"));
        let s = reg.stats();
        assert_eq!((s.hits, s.misses, s.loads, s.spills), (0, 1, 1, 2));
        drop(lease);

        // resident acquire is a hit and touches no disk state
        let _l2 = reg.acquire("a").unwrap();
        let s = reg.stats();
        assert_eq!((s.hits, s.misses, s.loads), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pinned_entries_never_spill() {
        let dir = temp_dir("pin");
        let reg = AdapterRegistry::new(ResidencyConfig {
            max_resident: 1,
            spill_dir: Some(dir.clone()),
            ..Default::default()
        });
        reg.insert_resident("a", s2ft(1, 4));
        let lease = reg.acquire("a").unwrap();
        // "a" is pinned and colder, so the budget falls on "b"
        reg.insert_resident("b", s2ft(2, 4));
        assert!(reg.is_resident("a"), "pinned entry must stay resident");
        assert!(!reg.is_resident("b"));
        drop(lease);
        // unpinned now: acquiring "b" reloads it and spills "a"
        let _b = reg.acquire("b").unwrap();
        assert!(!reg.is_resident("a"));
        assert!(reg.is_resident("b"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unspillable_adapters_tolerate_over_budget() {
        let dir = temp_dir("lora");
        let reg = AdapterRegistry::new(ResidencyConfig {
            max_resident: 1,
            spill_dir: Some(dir.clone()),
            ..Default::default()
        });
        reg.insert_resident("lora", AnyAdapter::Lora(LoraAdapter { layers: vec![], scale: 1.0 }));
        reg.insert_resident("s", s2ft(1, 4));
        // the S²FT adapter is the only spill candidate
        assert!(reg.is_resident("lora"));
        assert!(!reg.is_resident("s"));
        // with nothing spillable left, the set stays over budget
        let pin = reg.acquire("s").unwrap();
        assert!(reg.is_resident("lora") && reg.is_resident("s"));
        assert_eq!(reg.stats().resident, 2);
        drop(pin);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ewma_traffic_drives_fuse_policy() {
        let reg = AdapterRegistry::new(ResidencyConfig {
            hot_rps: 2.0,
            ewma_alpha: 1.0,
            ..Default::default()
        });
        reg.insert_resident("a", s2ft(1, 4));
        let t0 = Instant::now();
        // unknown interval yet -> cold
        reg.note_batch_at("a", 4, 16, false, t0);
        assert_eq!(reg.fuse_policy_at("a", t0 + Duration::from_millis(1)), FusePolicy::Unfused);
        // 4 requests in 100 ms = 40 rps -> hot
        reg.note_batch_at("a", 4, 16, false, t0 + Duration::from_millis(100));
        assert_eq!(
            reg.fuse_policy_at("a", t0 + Duration::from_millis(200)),
            FusePolicy::Fused
        );
        // stale EWMA is capped by 1/dt: ten idle seconds cool it down
        assert_eq!(
            reg.fuse_policy_at("a", t0 + Duration::from_secs(10)),
            FusePolicy::Unfused
        );
        let t = reg.traffic("a").unwrap();
        assert_eq!((t.requests, t.tokens), (8, 32));
        assert!((t.ewma_rps - 40.0).abs() < 1e-6);

        // hot_rps = 0 disables the policy entirely (always fused)
        let always = AdapterRegistry::new(ResidencyConfig::default());
        assert_eq!(always.fuse_policy_at("anything", t0), FusePolicy::Fused);
        // fused/unfused batch tallies land in the stats
        reg.note_batch_at("a", 1, 2, true, t0 + Duration::from_millis(300));
        let s = reg.stats();
        assert_eq!((s.fused_batches, s.unfused_batches), (2, 1));
    }

    #[test]
    fn register_dir_scans_and_lazily_loads() {
        let dir = temp_dir("scan");
        let AnyAdapter::S2ft(a1) = s2ft(1, 8) else { unreachable!() };
        let AnyAdapter::S2ft(a2) = s2ft(2, 8) else { unreachable!() };
        save_adapter(dir.join("alpha.s2ft"), &a1).unwrap();
        save_adapter(dir.join("beta.s2ft"), &a2).unwrap();
        std::fs::write(dir.join("notes.txt"), "not an adapter").unwrap();

        let reg = AdapterRegistry::new(ResidencyConfig::default());
        assert_eq!(reg.register_dir(&dir).unwrap(), 2);
        assert_eq!(reg.ids(), vec!["alpha", "beta"]);
        assert!(!reg.is_resident("alpha"), "registration must not decode");
        let lease = reg.acquire("alpha").unwrap();
        assert!(same_weights(&lease.handle(), &AnyAdapter::S2ft(a1)));
        assert_eq!(reg.stats().loads, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn acquire_and_remove_error_paths() {
        let reg = AdapterRegistry::new(ResidencyConfig::default());
        assert!(reg.acquire("ghost").is_err());
        assert!(reg.remove("ghost").is_err());
        reg.register_on_disk("broken", "/nonexistent/path.s2ft");
        assert!(reg.acquire("broken").is_err(), "load failure surfaces to the caller");
        assert!(!reg.is_resident("broken"), "failed load leaves the entry non-resident");
        reg.remove("broken").unwrap();
        assert!(reg.is_empty());
    }
}
