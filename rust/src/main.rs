//! `repro` — the S²FT launcher CLI (clap is not vendored; parsing is
//! hand-rolled). Subcommands:
//!
//!   repro info  [--artifacts DIR]
//!   repro pretrain --model M --steps N [--seed S] [--save DIR]
//!   repro train --config FILE | --model M --method T [--data SUITE]
//!               [--steps N] [--seed S] [--save DIR] [--init-from DIR]
//!   repro eval  --model M --weights DIR [--suite SUITE]
//!   repro serve --model M [--weights DIR] [--requests N] [--adapters K]
//!               [--workers W] [--max-batch B] [--max-resident R]
//!               [--adapter-dir DIR] [--stream]
//!   repro experiment <id> [--quick]
//!   repro analyze [--root DIR]

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context, Result};

use repro::config::TrainConfig;
use repro::data::{self, Tokenizer};
use repro::experiments;
use repro::runtime::{open_backend_named, Executor};
use repro::train::{self, GenModel, Trainer};
use repro::util::rng::Rng;

/// Resolve the execution backend from `--backend native|pjrt|auto` (auto:
/// PJRT when built with the feature and artifacts exist, else native).
fn backend_for(args: &Args) -> Result<Box<dyn Executor>> {
    backend_for_dir(args, args.get_or("artifacts", "artifacts"))
}

/// Same, but with an explicit artifact directory (config-file runs).
fn backend_for_dir(args: &Args, dir: &str) -> Result<Box<dyn Executor>> {
    open_backend_named(args.get("backend").unwrap_or("auto"), dir)
}

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
        return;
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    if let Some(n) = args.get("threads").and_then(|s| s.parse::<usize>().ok()) {
        // size the shared kernel worker pool (overrides S2FT_THREADS)
        repro::kernels::set_threads(n);
    }
    let result = match cmd.as_str() {
        "info" => cmd_info(&args),
        "pretrain" => cmd_pretrain(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "adapter" => cmd_adapter(&args),
        "experiment" => cmd_experiment(&args),
        "bench-compare" => cmd_bench_compare(&args),
        "analyze" => cmd_analyze(&args),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => Err(anyhow!("unknown command {other:?}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    println!(
        "repro — S²FT: Structured Sparse Fine-Tuning (rust+JAX+Pallas reproduction)

USAGE:
  repro info  [--artifacts DIR]
  repro pretrain --model M [--steps N] [--seed S] [--save DIR]
  repro train (--config FILE | --model M --method TAG) [--data SUITE]
              [--steps N] [--seed S] [--save DIR] [--init-from DIR]
              [--strategy static|dropgrow|warmup[:W]] [--replan-every K]
  repro eval  --model M --weights DIR [--suite commonsense|arithmetic|instruct]
  repro serve --model M [--weights DIR] [--adapters K] [--requests N]
              [--workers W] [--max-batch B] [--max-resident R]
              [--adapter-dir DIR] [--stream]
  repro adapter extract|apply|info [--model M --method T --base DIR --ft DIR
              --adapter FILE --out PATH]
  repro experiment fig2|tab1|tab2|tab3|fig4|tab4|fig5|tab5|thm42|selection|all
              [--quick]
  repro bench-compare [--current FILE] [--baseline FILE] [--warn R] [--fail R]
  repro analyze [--root DIR]

Methods: fullft lora dora spft lisa galore s2ft s2ft-pallas (+ experiment
variants, see `repro info`). Artifacts default to ./artifacts.

train --strategy routes s2ft unit selection through a pluggable
SelectionStrategy (static = the prepare artifact's selection, bit-exact;
dropgrow = drop lowest-magnitude / regrow highest-gradient units;
warmup:W = dense-ish warmup, then commit top-gradient units at step W).
--replan-every K sets the re-selection cadence; optimizer moments follow
surviving units across replans. `repro experiment selection` compares
the strategies end-to-end.

serve scales to many more adapters than fit in memory: --max-resident R
caps the decoded resident set (default 0 = unbounded, LRU spill past R)
and --adapter-dir DIR preloads every *.s2ft file in DIR (lazy) and
receives spilled adapters; the registry report prints hit rate, loads,
spills and fused/unfused batch counts.

Every command accepts --threads N to size the shared GEMM kernel worker
pool (default: S2FT_THREADS env, else all cores; 0 resets to that
fallback). S2FT_SIMD=0 forces the portable scalar micro-kernel tile
(results are bit-identical either way). bench-compare diffs a
bench JSON against a committed baseline and exits non-zero past --fail
(default 2.0x median; --warn 1.3x prints warnings only).

analyze is the static-analysis gate: it lints src/ and benches/ for the
project's bit-identity invariants (float-literal equality, mul_add,
missing SAFETY comments, nondeterminism sources, bench/baseline drift,
undocumented pub items in the serving API) and exits non-zero on any
finding. --root points at the package dir (auto-detected: ./rust or .).

Backends (--backend native|pjrt|auto): the native pure-rust interpreter
runs fullft + s2ft with no artifacts, python or XLA; pjrt (cargo feature)
executes the full AOT method set from ./artifacts. auto prefers pjrt when
available, else native."
    );
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = backend_for(args)?;
    println!("platform: {}", rt.platform());
    let meta = rt.artifacts().meta.clone();
    let mut models: Vec<_> = meta.models.iter().collect();
    models.sort_by_key(|(k, _)| k.clone());
    for (name, m) in models {
        println!(
            "model {name}: d={} L={} h={} ff={} vocab={} ({:.2}M params), batches {:?}",
            m.dims.d_model,
            m.dims.n_layers,
            m.dims.n_heads,
            m.dims.d_ff,
            m.dims.vocab,
            m.param_count as f64 / 1e6,
            m.batches
        );
        let mut tags: Vec<_> = m.methods.keys().collect();
        tags.sort();
        for tag in tags {
            let mm = &m.methods[tag];
            println!(
                "   {tag:<14} trainable {:>9} params ({:.2}%)",
                mm.trainable_params,
                100.0 * mm.trainable_params as f64 / m.param_count as f64
            );
        }
    }
    match meta.artifacts.len() {
        0 => println!("artifacts: none (native interpreter, specs synthesized on demand)"),
        n => println!("artifacts: {n}"),
    }
    Ok(())
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let model = args.get("model").context("--model required")?;
    let rt = backend_for(args)?;
    let steps = args.usize_or("steps", 400);
    let seed = args.u64_or("seed", 42);
    let params = experiments::common::pretrain(rt.as_ref(), model, steps, seed, true)?;
    if let Some(dir) = args.get("save") {
        train::save_params(dir, &params)?;
        println!("saved base weights to {dir}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = if let Some(path) = args.get("config") {
        TrainConfig::load(path)?
    } else {
        TrainConfig {
            model: args.get("model").context("--model or --config required")?.into(),
            method: args.get("method").context("--method required")?.into(),
            data: args.get_or("data", "corpus").into(),
            steps: args.usize_or("steps", 300),
            seed: args.u64_or("seed", 42),
            log_every: args.usize_or("log-every", 10),
            artifacts: args.get_or("artifacts", "artifacts").into(),
            save_to: args.get("save").map(String::from),
            init_from: args.get("init-from").map(String::from),
            notes: String::new(),
        }
    };
    let rt = backend_for_dir(args, &cfg.artifacts)?;
    let base = match &cfg.init_from {
        Some(dir) => train::load_params(dir)?,
        None => experiments::common::init_params(rt.as_ref(), &cfg.model, cfg.seed as i32)?,
    };
    let (b, t) = rt.artifacts().model(&cfg.model)?.default_batch();
    let tk = Tokenizer;
    println!(
        "train: model={} method={} data={} steps={} ({}x{} per step)",
        cfg.model, cfg.method, cfg.data, cfg.steps, b, t
    );

    // --strategy static|dropgrow|warmup[:W] routes selection through a
    // pluggable SelectionStrategy; --replan-every K lets it re-select
    // mid-run (see docs/training.md "Selection strategies").
    let strategy_flag = args.get("strategy").map(str::to_string);
    let replan_every = args.usize_or("replan-every", 0);
    let make_trainer = |calib: &data::Batch| -> Result<Trainer> {
        match &strategy_flag {
            Some(spec) => {
                let mm = rt.artifacts().model(&cfg.model)?;
                let m = mm.method(&cfg.method)?;
                let strat =
                    repro::sparsity::strategy::for_name(spec, &m.selection, m.select_small)?;
                Trainer::with_strategy(
                    rt.as_ref(),
                    &cfg.model,
                    &cfg.method,
                    &base,
                    cfg.seed,
                    strat,
                    replan_every,
                    b,
                    t,
                )
            }
            None => Trainer::new(rt.as_ref(), &cfg.model, &cfg.method, &base, cfg.seed, calib),
        }
    };

    let mut trainer: Trainer;
    if cfg.data == "corpus" {
        let corpus = data::pretrain_corpus(cfg.seed, 400_000);
        let mut rng = Rng::seed(cfg.seed ^ 1);
        let calib = data::lm_batch(&tk, &corpus, &mut rng, b, t);
        trainer = make_trainer(&calib)?;
        for step in 0..cfg.steps {
            let batch = data::lm_batch(&tk, &corpus, &mut rng, b, t);
            trainer.maybe_replan(rt.as_ref(), &batch)?;
            let loss = trainer.train_step(&batch)?;
            if step % cfg.log_every == 0 || step + 1 == cfg.steps {
                println!(
                    "step {step:>5}  loss {loss:.4}  {:.0} tok/s  peak-rss {:.0} MB",
                    trainer.metrics.tokens_per_sec(),
                    repro::util::peak_rss_bytes().unwrap_or(0) as f64 / 1e6
                );
            }
        }
    } else {
        let examples = data::finetune_examples(&cfg.data, 4000, cfg.seed ^ 2);
        let calib = experiments::common::batch_at(&tk, &examples, 0, b, t);
        trainer = make_trainer(&calib)?;
        for step in 0..cfg.steps {
            let batch = experiments::common::batch_at(&tk, &examples, step * b, b, t);
            trainer.maybe_replan(rt.as_ref(), &batch)?;
            let loss = trainer.train_step(&batch)?;
            if step % cfg.log_every == 0 || step + 1 == cfg.steps {
                println!(
                    "step {step:>5}  loss {loss:.4}  {:.0} tok/s",
                    trainer.metrics.tokens_per_sec()
                );
            }
        }
    }
    let act = match (trainer.activation_bytes(), trainer.activation_peak_bytes()) {
        (Some(c), Some(p)) => {
            format!(", act {:.1} MB (peak {:.1} MB)", c as f64 / 1e6, p as f64 / 1e6)
        }
        _ => String::new(),
    };
    println!(
        "done: {} steps, tail loss {:.4}, {:.1} ms/step, state {:.1} MB (opt {:.1} MB){act}",
        trainer.metrics.steps(),
        trainer.metrics.tail_loss(10),
        trainer.metrics.ms_per_step(),
        trainer.state_bytes() as f64 / 1e6,
        trainer.opt_bytes() as f64 / 1e6,
    );
    if trainer.metrics.replans > 0 {
        println!(
            "replans: {} committed ({} shape-changing), trainable now {} params",
            trainer.metrics.replans,
            trainer.metrics.shape_changing_replans,
            trainer.trainable_params()
        );
    }
    if let Some(dir) = &cfg.save_to {
        let merged = trainer.merged_params(rt.as_ref())?;
        train::save_params(dir, &merged)?;
        if !trainer.perms.is_empty() {
            // selection permutations enable later adapter extraction
            train::save_params(format!("{dir}/perms"), &trainer.perms)?;
        }
        println!("saved merged weights to {dir}");
    }
    experiments::common::save_result(
        &format!("train_{}_{}", cfg.model, cfg.method),
        &trainer.metrics.to_json(),
    );
    Ok(())
}

/// Adapter lifecycle from the command line:
///   repro adapter extract --model M --method T --base DIR --ft DIR --out FILE
///   repro adapter apply   --base DIR --adapter FILE --out DIR
///   repro adapter info    --adapter FILE
fn cmd_adapter(args: &Args) -> Result<()> {
    let sub = args.positional.first().context("adapter subcommand required")?;
    match sub.as_str() {
        "extract" => {
            let rt = backend_for(args)?;
            let model = args.get("model").context("--model required")?;
            let method = args.get_or("method", "s2ft");
            let base = train::load_params(args.get("base").context("--base required")?)?;
            let ft_dir = args.get("ft").context("--ft required")?;
            let ft = train::load_params(ft_dir)?;
            let perms = train::load_params(format!("{ft_dir}/perms"))
                .context("fine-tuned checkpoint has no perms/ (was it trained with s2ft + --save?)")?;
            let mm = rt.artifacts().model(model)?;
            let mmeta = mm.method(method)?;
            let adapter = repro::adapter::S2ftAdapter::extract(mm, mmeta, &perms, &base, &ft)?;
            let out = args.get_or("out", "adapter.s2ft");
            repro::adapter::save_adapter(out, &adapter)?;
            println!(
                "extracted adapter -> {out} ({:.1} KB, {} layers)",
                adapter.bytes() as f64 / 1e3,
                adapter.layers.len()
            );
            Ok(())
        }
        "apply" => {
            let mut base = train::load_params(args.get("base").context("--base required")?)?;
            let adapter =
                repro::adapter::load_adapter(args.get("adapter").context("--adapter required")?)?;
            adapter.apply(&mut base)?;
            let out = args.get("out").context("--out required")?;
            train::save_params(out, &base)?;
            println!("fused adapter into {out}");
            Ok(())
        }
        "info" => {
            let adapter =
                repro::adapter::load_adapter(args.get("adapter").context("--adapter required")?)?;
            println!(
                "adapter: d_model={} layers={} bytes={}",
                adapter.d_model,
                adapter.layers.len(),
                adapter.bytes()
            );
            for (i, l) in adapter.layers.iter().enumerate() {
                println!(
                    "  L{i}: wo rows {:?}, wd rows {:?}",
                    l.wo_rows.len(),
                    l.wd_rows.len()
                );
            }
            Ok(())
        }
        other => Err(anyhow!("unknown adapter subcommand {other:?}")),
    }
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model = args.get("model").context("--model required")?;
    let weights = args.get("weights").context("--weights required")?;
    let suite_name = args.get_or("suite", "commonsense");
    let rt = backend_for(args)?;
    let params = train::load_params(weights)?;
    let gm = GenModel::new(rt.as_ref(), model, params)?;
    let tasks = data::suite(suite_name).ok_or_else(|| anyhow!("unknown suite {suite_name:?}"))?;
    let (rows, avg) =
        experiments::common::evaluate_suite(&gm, tasks, args.usize_or("n", 32), 0xE7A1)?;
    for (name, acc) in &rows {
        println!("{name:>12}: {acc:5.1}%");
    }
    println!("{:>12}: {avg:5.1}%", "Avg");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    repro::serve::demo(repro::serve::DemoOpts {
        artifacts: args.get_or("artifacts", "artifacts").to_string(),
        backend: args.get_or("backend", "auto").to_string(),
        model: args.get_or("model", "small").to_string(),
        weights: args.get("weights").map(String::from),
        adapters: args.usize_or("adapters", 4),
        requests: args.usize_or("requests", 32),
        max_batch: args.usize_or("max-batch", 8),
        workers: args.usize_or("workers", 2),
        max_resident: args.usize_or("max-resident", 0),
        adapter_dir: args.get("adapter-dir").map(String::from),
        stream: args.has("stream"),
    })
}

/// CI regression gate: diff a bench JSON against the committed baseline.
/// Exits non-zero when any median regresses past `--fail` (default 2.0x);
/// ratios past `--warn` (default 1.3x) only print, keeping the gate
/// robust to shared-runner noise.
fn cmd_bench_compare(args: &Args) -> Result<()> {
    let cur_path = args.get_or("current", "rust/results/bench_kernels.json");
    let base_path = args.get_or("baseline", "rust/benches/baseline/kernels.json");
    let warn: f64 = args.get("warn").and_then(|s| s.parse().ok()).unwrap_or(1.3);
    let fail: f64 = args.get("fail").and_then(|s| s.parse().ok()).unwrap_or(2.0);
    let cur = repro::util::json::Json::parse(
        &std::fs::read_to_string(cur_path).with_context(|| format!("reading {cur_path}"))?,
    )?;
    let base = repro::util::json::Json::parse(
        &std::fs::read_to_string(base_path).with_context(|| format!("reading {base_path}"))?,
    )?;
    let cmp = repro::util::bench::compare_bench(&cur, &base)?;
    if let Some(reason) = &cmp.skipped {
        println!("bench-compare: current run was skipped ({reason}); nothing to gate");
        return Ok(());
    }
    println!("bench-compare: {cur_path} vs {base_path} (warn >{warn}x, fail >{fail}x)\n");
    let mut warned = 0usize;
    let mut failed = 0usize;
    for d in &cmp.deltas {
        let flag = if d.ratio > fail {
            failed += 1;
            "FAIL"
        } else if d.ratio > warn {
            warned += 1;
            "warn"
        } else {
            "  ok"
        };
        println!(
            "  {flag} {:<48} {:>10} -> {:>10}  ({:.2}x)",
            d.name,
            repro::util::bench::fmt_ns(d.baseline_ns),
            repro::util::bench::fmt_ns(d.current_ns),
            d.ratio
        );
    }
    for name in &cmp.missing {
        println!("  FAIL {name:<48} missing from current run");
    }
    for name in &cmp.added {
        println!("   new {name:<48} (no baseline yet — run `make bench-baseline`)");
    }
    if warned > 0 {
        println!("\n{warned} benchmark(s) in the {warn}x..{fail}x noise band — not failing");
    }
    if failed > 0 {
        bail!("{failed} benchmark(s) regressed past {fail}x median vs baseline");
    }
    // a gate that compared nothing proves nothing: renamed/lost benchmarks
    // must fail until the committed baseline is regenerated
    if !cmp.missing.is_empty() {
        bail!(
            "{} baseline benchmark(s) missing from the current run — \
             if renames are intended, refresh with `make bench-baseline`",
            cmp.missing.len()
        );
    }
    if cmp.deltas.is_empty() {
        bail!("no overlapping benchmarks between {cur_path} and {base_path}");
    }
    println!("\nbaseline comparison passed ({} benchmarks)", cmp.deltas.len());
    Ok(())
}

/// Static-analysis gate: lint the package for bit-identity invariant
/// violations (see the `repro::analyze` module docs) and exit non-zero
/// on any finding.
fn cmd_analyze(args: &Args) -> Result<()> {
    let cfg = match args.get("root") {
        Some(root) => repro::analyze::AnalyzeConfig { root: root.into() },
        None => repro::analyze::AnalyzeConfig::discover()?,
    };
    let report = repro::analyze::run(&cfg)?;
    print!("{}", report.render());
    if !report.ok() {
        bail!("{} invariant violation(s)", report.findings.len());
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .context("experiment id required (fig2|tab1|...|thm42|all)")?;
    let quick = args.has("quick");
    if quick {
        println!("(quick mode: reduced steps/evals — shapes only)");
    }
    experiments::run(id, args.get_or("artifacts", "artifacts"), quick)
}

#[cfg(test)]
mod tests {
    use super::Args;

    #[test]
    fn arg_parsing() {
        // real CLI shape: positionals precede flags (repro experiment fig2 --quick)
        let argv: Vec<String> = ["pos1", "--model", "tiny", "--steps", "5", "--quick"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = Args::parse(&argv);
        assert_eq!(a.get("model"), Some("tiny"));
        assert!(a.has("quick"));
        assert_eq!(a.usize_or("steps", 0), 5);
        assert_eq!(a.positional, vec!["pos1"]);
        assert_eq!(a.get_or("missing", "d"), "d");
    }
}
