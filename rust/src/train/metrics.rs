//! Training metrics: loss curve, throughput, wall time.

use std::time::Duration;

use crate::util::json::Json;

#[derive(Debug, Clone, Default)]
pub struct TrainMetrics {
    pub losses: Vec<f32>,
    pub total_tokens: usize,
    pub total_time: Duration,
}

impl TrainMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_step(&mut self, loss: f32, tokens: usize, elapsed: Duration) {
        self.losses.push(loss);
        self.total_tokens += tokens;
        self.total_time += elapsed;
    }

    pub fn steps(&self) -> usize {
        self.losses.len()
    }

    pub fn last_loss(&self) -> f32 {
        self.losses.last().copied().unwrap_or(f32::NAN)
    }

    /// Mean loss over the final `k` steps (smoothed curve endpoint).
    pub fn tail_loss(&self, k: usize) -> f32 {
        if self.losses.is_empty() {
            return f32::NAN;
        }
        let k = k.min(self.losses.len());
        let tail = &self.losses[self.losses.len() - k..];
        tail.iter().sum::<f32>() / k as f32
    }

    pub fn tokens_per_sec(&self) -> f64 {
        if self.total_time.is_zero() {
            return 0.0;
        }
        self.total_tokens as f64 / self.total_time.as_secs_f64()
    }

    pub fn ms_per_step(&self) -> f64 {
        if self.losses.is_empty() {
            return 0.0;
        }
        self.total_time.as_secs_f64() * 1e3 / self.losses.len() as f64
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("steps", Json::num(self.steps() as f64)),
            ("last_loss", Json::num(self.last_loss() as f64)),
            ("tail_loss", Json::num(self.tail_loss(10) as f64)),
            ("tokens_per_sec", Json::num(self.tokens_per_sec())),
            ("ms_per_step", Json::num(self.ms_per_step())),
            (
                "loss_curve",
                Json::arr_f64(self.losses.iter().map(|&l| l as f64)),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_accumulate() {
        let mut m = TrainMetrics::new();
        m.record_step(2.0, 100, Duration::from_millis(10));
        m.record_step(1.0, 100, Duration::from_millis(10));
        assert_eq!(m.steps(), 2);
        assert_eq!(m.last_loss(), 1.0);
        assert_eq!(m.tail_loss(2), 1.5);
        assert!(m.tokens_per_sec() > 0.0);
        assert!((m.ms_per_step() - 10.0).abs() < 1.0);
    }
}
