//! `repro analyze` — the project's static-analysis gate.
//!
//! Scans `src/**` and `benches/**` of the rust package with a
//! comment/string-aware tokenizer ([`lex`]) and enforces the invariants
//! earlier PRs established by hand as deny-by-default lints (see
//! [`KNOWN_LINTS`] and the pass docs in `lints.rs`): no float-literal
//! equality or fused multiply-adds in bit-identical kernel code, a
//! `// SAFETY:` comment on every `unsafe`, no nondeterminism sources in
//! the deterministic modules, a bench lane ↔ committed baseline
//! bijection so no perf lane escapes the CI regression gate, and
//! rustdoc on every `pub` item of the serving and adapter APIs
//! (`src/serve/`, `src/adapter/`).
//!
//! Escape hatch: one plain line comment per file per lint, of the form
//! documented on [`Allow`], suppresses that lint for the file and is
//! listed in the report. Malformed or unused annotations are themselves
//! findings, so the hatch cannot rot silently.
//!
//! The subsystem is dependency-free and pure stable Rust: [`run`] walks
//! the tree, lexes each file once, applies the passes and returns a
//! [`Report`]; the `repro analyze` subcommand renders it and exits
//! nonzero on any finding.

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

mod lexer;
mod lints;
mod report;

pub use lexer::{lex, Comment, Lexed, Tok, TokKind};
pub use lints::KNOWN_LINTS;
pub use report::{Allow, Finding, Report};

/// Where to scan. `root` is the package root: the directory holding
/// `src/` and (usually) `benches/`.
#[derive(Debug, Clone)]
pub struct AnalyzeConfig {
    pub root: PathBuf,
}

impl AnalyzeConfig {
    /// Locate the package root from the current directory: `rust/` when
    /// run from the repo root, else `.` when run inside the package.
    pub fn discover() -> Result<Self> {
        for cand in ["rust", "."] {
            let root = PathBuf::from(cand);
            if root.join("src").is_dir() {
                return Ok(Self { root });
            }
        }
        bail!("no rust package root found (run from the repo root or pass --root)")
    }
}

/// Analyze a single in-memory file the way [`run`] does, minus the
/// tree-wide passes (bench↔baseline pairing, stale-allow detection).
/// Returns the surviving findings and the parsed allows.
pub fn analyze_source(rel: &str, src: &str) -> (Vec<Finding>, Vec<Allow>) {
    let lx = lexer::lex(src);
    let (mut allows, mut findings) = report::parse_allows(rel, &lx.comments, lints::KNOWN_LINTS);
    let raw = lints::lint_file(rel, &lx);
    findings.extend(report::apply_allows(raw, &mut allows));
    (findings, allows)
}

/// Walk the tree under `cfg.root`, run every lint pass and the
/// bench↔baseline cross-check, and return the full [`Report`] with
/// findings sorted by `(path, line, lint)`.
pub fn run(cfg: &AnalyzeConfig) -> Result<Report> {
    let root = &cfg.root;
    let src_root = root.join("src");
    if !src_root.is_dir() {
        bail!("{} has no src/ directory; pass --root <package dir>", root.display());
    }
    let mut files = Vec::new();
    collect_rs(&src_root, &mut files)?;
    let bench_root = root.join("benches");
    if bench_root.is_dir() {
        collect_rs(&bench_root, &mut files)?;
    }

    let mut findings = Vec::new();
    // per scanned file: (relative path, its allows); bench targets also
    // record (index into per_file, stem, lane patterns) for the pairing
    // pass, which must run after every file's allows are parsed
    let mut per_file: Vec<(String, Vec<Allow>)> = Vec::new();
    let mut bench_info: Vec<(usize, String, Vec<(String, usize)>)> = Vec::new();
    for path in &files {
        let rel = rel_path(root, path);
        let src = fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
        let lx = lexer::lex(&src);
        let (mut allows, bad) = report::parse_allows(&rel, &lx.comments, lints::KNOWN_LINTS);
        findings.extend(bad);
        let raw = lints::lint_file(&rel, &lx);
        findings.extend(report::apply_allows(raw, &mut allows));
        if rel.starts_with("benches/") {
            let (pats, bad) = lints::bench_patterns(&rel, &lx);
            findings.extend(report::apply_allows(bad, &mut allows));
            bench_info.push((per_file.len(), stem_of(path), pats));
        }
        per_file.push((rel, allows));
    }

    // bench target ↔ committed baseline bijection
    let baseline_dir = bench_root.join("baseline");
    let mut paired = BTreeSet::new();
    for (idx, stem, pats) in &bench_info {
        paired.insert(stem.clone());
        let json_rel = format!("benches/baseline/{stem}.json");
        let json_path = baseline_dir.join(format!("{stem}.json"));
        let mut baseline = None;
        if json_path.is_file() {
            let text = fs::read_to_string(&json_path)
                .with_context(|| format!("read {}", json_path.display()))?;
            match Json::parse(&text) {
                Ok(j) => baseline = Some(j),
                Err(err) => {
                    let msg = format!("unreadable baseline: {err}");
                    findings.push(Finding::new(lints::BENCH_BASELINE, &json_rel, 1, msg));
                    continue;
                }
            }
        }
        let (rel, allows) = &mut per_file[*idx];
        let raw = lints::check_bench_lanes(rel, stem, pats, baseline.as_ref(), &json_rel);
        findings.extend(report::apply_allows(raw, allows));
    }

    // committed baselines no bench target registers lanes for
    if baseline_dir.is_dir() {
        let mut jsons = Vec::new();
        for e in fs::read_dir(&baseline_dir).context("read baseline dir")? {
            jsons.push(e?.path());
        }
        jsons.sort();
        for p in jsons {
            if p.extension().and_then(|s| s.to_str()) != Some("json") {
                continue;
            }
            let stem = stem_of(&p);
            if !paired.contains(&stem) {
                let rel = format!("benches/baseline/{stem}.json");
                let msg = "baseline has no bench target registering matching lanes".to_string();
                findings.push(Finding::new(lints::BENCH_BASELINE, &rel, 1, msg));
            }
        }
    }

    // an allow that suppressed nothing is itself a finding
    let files_scanned = per_file.len();
    let mut all_allows = Vec::new();
    for (_, allows) in per_file {
        for a in allows {
            if !a.used {
                let msg = format!("allow({}) suppresses nothing; delete it", a.lint);
                findings.push(Finding::new(report::STALE_ALLOW, &a.path, a.line, msg));
            }
            all_allows.push(a);
        }
    }

    findings.sort_by(|x, y| (&x.path, x.line, &x.lint).cmp(&(&y.path, y.line, &y.lint)));
    Ok(Report { files_scanned, findings, allows: all_allows })
}

/// Recursively collect `.rs` files under `dir` in sorted order, so the
/// report (and therefore CI output) is stable across filesystems.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries = Vec::new();
    for e in fs::read_dir(dir).with_context(|| format!("read dir {}", dir.display()))? {
        entries.push(e?.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().and_then(|s| s.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.to_string_lossy().replace('\\', "/")
}

fn stem_of(p: &Path) -> String {
    let stem = p.file_stem().and_then(|s| s.to_str());
    stem.unwrap_or("").to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analyze_source_applies_allows() {
        // the marker below sits inside a string literal, so the
        // analyzer never reads it as a live annotation when scanning
        // this file itself
        let src = "// s2ft-analyze: allow(float-eq) reason=\"legacy compare\"\n\
                   pub fn f(x: f32) -> bool { x == 0.0 }\n";
        let (findings, allows) = analyze_source("src/kernels/gemm.rs", src);
        assert!(findings.is_empty(), "{findings:?}");
        assert_eq!(allows.len(), 1);
        assert!(allows[0].used);
    }

    #[test]
    fn analyze_source_reports_without_allow() {
        let src = "pub fn f(x: f32) -> bool { x == 0.0 }\n";
        let (findings, allows) = analyze_source("src/kernels/gemm.rs", src);
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].lint, lints::FLOAT_EQ);
        assert!(allows.is_empty());
    }

    #[test]
    fn run_flags_stale_allows_and_orphan_baselines() {
        let dir = std::env::temp_dir().join(format!("s2ft-analyze-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(dir.join("src")).unwrap();
        fs::create_dir_all(dir.join("benches/baseline")).unwrap();
        let lib = "// s2ft-analyze: allow(fma) reason=\"never used\"\npub fn f() {}\n";
        fs::write(dir.join("src/lib.rs"), lib).unwrap();
        fs::write(dir.join("benches/baseline/ghost.json"), "[]").unwrap();

        let report = run(&AnalyzeConfig { root: dir.clone() }).unwrap();
        let _ = fs::remove_dir_all(&dir);

        assert_eq!(report.files_scanned, 1);
        let got: Vec<&str> = report.findings.iter().map(|f| f.lint.as_str()).collect();
        assert_eq!(got, vec![lints::BENCH_BASELINE, report::STALE_ALLOW]);
        assert_eq!(report.findings[0].path, "benches/baseline/ghost.json");
        assert_eq!(report.allows.len(), 1);
        assert!(!report.allows[0].used);
        assert!(!report.ok());
    }
}
