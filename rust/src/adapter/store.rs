//! Adapter store: holds many fine-tuned adapters in memory, tracks which
//! one is fused into the live weights, and implements the four-step
//! switch (unfuse old, unload, load, fuse new) from paper §6.2.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::runtime::Tensor;

use super::{LoraAdapter, S2ftAdapter};

pub enum AnyAdapter {
    S2ft(S2ftAdapter),
    Lora(LoraAdapter),
}

impl AnyAdapter {
    pub fn bytes(&self) -> usize {
        match self {
            AnyAdapter::S2ft(a) => a.bytes(),
            AnyAdapter::Lora(a) => a.bytes(),
        }
    }
}

#[derive(Default)]
pub struct AdapterStore {
    adapters: HashMap<String, AnyAdapter>,
    /// id currently fused into the live weights (if any)
    active: Option<String>,
    pub switches: usize,
}

impl AdapterStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, id: impl Into<String>, adapter: AnyAdapter) {
        self.adapters.insert(id.into(), adapter);
    }

    pub fn len(&self) -> usize {
        self.adapters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.adapters.is_empty()
    }

    pub fn active(&self) -> Option<&str> {
        self.active.as_deref()
    }

    pub fn total_bytes(&self) -> usize {
        self.adapters.values().map(|a| a.bytes()).sum()
    }

    /// Switch the live weights to `id` (no-op if already active).
    ///
    /// S²FT switch cost is two scatter_adds over s·d elements per layer;
    /// a LoRA switch costs a ΔW GEMM per target — the Fig 6a comparison.
    /// LoRA adapters cannot be *unfused* exactly here (we'd have to keep
    /// ΔW around), so the store snapshots base weights for them.
    pub fn switch_to(
        &mut self,
        id: &str,
        params: &mut HashMap<String, Tensor>,
        base_snapshot: &HashMap<String, Tensor>,
    ) -> Result<()> {
        if self.active.as_deref() == Some(id) {
            return Ok(());
        }
        // unfuse current
        if let Some(cur) = self.active.take() {
            match self.adapters.get(&cur) {
                Some(AnyAdapter::S2ft(a)) => a.remove(params)?,
                Some(AnyAdapter::Lora(_)) => {
                    // restore touched weights from the snapshot
                    for (k, v) in base_snapshot {
                        if k.ends_with(".wo") || k.ends_with(".wd") {
                            params.insert(k.clone(), v.clone());
                        }
                    }
                }
                None => {}
            }
        }
        let adapter = self
            .adapters
            .get(id)
            .ok_or_else(|| anyhow!("adapter {id:?} not in store"))?;
        match adapter {
            AnyAdapter::S2ft(a) => a.apply(params)?,
            AnyAdapter::Lora(a) => a.apply(params)?,
        }
        self.active = Some(id.to_string());
        self.switches += 1;
        Ok(())
    }

    /// Unfuse whatever is active, restoring pristine base weights.
    pub fn deactivate(
        &mut self,
        params: &mut HashMap<String, Tensor>,
        base_snapshot: &HashMap<String, Tensor>,
    ) -> Result<()> {
        if let Some(cur) = self.active.take() {
            match self.adapters.get(&cur) {
                Some(AnyAdapter::S2ft(a)) => a.remove(params)?,
                Some(AnyAdapter::Lora(_)) => {
                    for (k, v) in base_snapshot {
                        if k.ends_with(".wo") || k.ends_with(".wd") {
                            params.insert(k.clone(), v.clone());
                        }
                    }
                }
                None => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adapter::S2ftLayerDelta;

    fn adapter(val: f32) -> AnyAdapter {
        AnyAdapter::S2ft(S2ftAdapter {
            layers: vec![S2ftLayerDelta {
                wo_rows: vec![],
                wo_delta: vec![],
                wd_rows: vec![0],
                wd_delta: vec![val; 4],
            }],
            d_model: 4,
        })
    }

    fn base() -> HashMap<String, Tensor> {
        let mut p = HashMap::new();
        p.insert("L0.wo".to_string(), Tensor::zeros(vec![4, 4]));
        p.insert("L0.wd".to_string(), Tensor::zeros(vec![4, 4]));
        p
    }

    #[test]
    fn switch_sequence_restores_weights() {
        let snapshot = base();
        let mut params = base();
        let mut store = AdapterStore::new();
        store.insert("a", adapter(1.0));
        store.insert("b", adapter(2.0));

        store.switch_to("a", &mut params, &snapshot).unwrap();
        assert_eq!(params["L0.wd"].as_f32().unwrap()[0], 1.0);
        store.switch_to("b", &mut params, &snapshot).unwrap();
        assert_eq!(params["L0.wd"].as_f32().unwrap()[0], 2.0);
        assert_eq!(store.switches, 2);
        // switching to the active id is free
        store.switch_to("b", &mut params, &snapshot).unwrap();
        assert_eq!(store.switches, 2);
        store.deactivate(&mut params, &snapshot).unwrap();
        assert_eq!(params["L0.wd"].as_f32().unwrap()[0], 0.0);
        assert!(store.active().is_none());
    }

    #[test]
    fn missing_adapter_errors() {
        let snapshot = base();
        let mut params = base();
        let mut store = AdapterStore::new();
        assert!(store.switch_to("nope", &mut params, &snapshot).is_err());
    }
}
