//! Shared compute-kernel subsystem: every dense GEMM in the crate routes
//! through here.
//!
//! S²FT's efficiency claim (paper §3.3) is "select sparsely, compute
//! densely": the trainable slice is carved out *before* the dW GEMM, and
//! the remaining work is a plain dense matmul. That only pays off if the
//! dense matmuls themselves are engineered, so this module provides
//! packed, register-tiled, multi-threaded implementations of the four
//! GEMM shapes the codebase needs:
//!
//! * [`gemm`] — `C = A (m,k) @ B (k,n)`, the forward projections;
//! * [`gemm_nt`] — `C = A (m,k) @ Bᵀ` with `B (n,k)`, logits + dX;
//! * [`gemm_tn`] — `C = A[:, :lim]ᵀ @ B`, the row-split partial-gradient
//!   kernel (S²FT `wo`/`wd` backprop slices activation channels first);
//! * [`gemm_tn_outcols`] — `C = Aᵀ @ B[:, :lim]`, the column-split
//!   partial-gradient kernel (trainable head/channel columns);
//!
//! plus [`slice_cols`] (the cache-time activation slice: retaining
//! `A[:, :lim]` at forward time makes the later `gemm_tn` over the slice
//! bit-identical to the `lim`-limited GEMM over the full buffer),
//! [`gemv_acc`] (fused `y += scale·(x @ W)` for the per-request
//! adapter deltas) and the causal-attention pair
//! [`causal_attn_fwd`]/[`causal_attn_bwd`] used by the native model
//! interpreter.
//!
//! # Threading model
//!
//! Kernels run on `std::thread::scope` workers — no persistent pool, no
//! dependencies. The worker count comes from, in priority order:
//! [`set_threads`] (the CLI `--threads` flag), the `S2FT_THREADS`
//! environment variable, then [`std::thread::available_parallelism`].
//! Small problems (below [`MIN_PAR_WORK`] multiply-adds) stay on the
//! calling thread to avoid spawn overhead.
//!
//! # The micro-kernel pipeline
//!
//! The GEMMs are packed, register-tiled drivers: the streaming operand is
//! packed once into `NR`-wide column panels (`kernels/pack.rs`), each
//! worker packs `MR`-row tiles of the broadcast operand, and the
//! micro-kernel tile (`kernels/micro.rs`) computes `MR × NR` output
//! blocks with all accumulators in registers. The tile
//! has two implementations — a portable autovectorizing loop and a
//! `std::arch` AVX2 path — selected at runtime ([`simd_enabled`]:
//! `S2FT_SIMD=0|off|scalar|false` forces the portable path, otherwise
//! AVX2 is used when detected). `*_with_dispatch` kernel variants pin the
//! decision per call for tests, benches and the CI scalar lane.
//!
//! # Determinism
//!
//! Parallelism only ever partitions the *output* — never the reduction
//! axis — and both tile paths round every product and sum separately (no
//! FMA contraction) in the same ascending reduction order, so every
//! output element is one fixed scalar chain. Results are **bit-identical
//! to the naive triple loop** in [`reference`] for *every* input —
//! signed zeros, subnormals, infinities and NaNs included — and
//! independent of both thread count and the SIMD/scalar dispatch
//! decision (asserted by the proptests in `tests/proptests.rs`). This
//! keeps the JAX-reference numeric tests meaningful under any machine
//! configuration. The historical `av == 0.0` skip fast paths were
//! removed for violating exactly this contract (they matched `-0.0` and
//! dropped `0·±inf` / `0·NaN` products); `repro analyze` now machine
//! checks this module for float-literal equality, `mul_add` contraction
//! and nondeterminism sources so the bug class cannot return.
//!
//! The [`reference`] module holds naive triple-loop oracles used by tests
//! and benches.

mod attn;
mod gemm;
mod micro;
mod pack;
pub mod reference;

pub use attn::{attn_decode, attn_decode_paged, causal_attn_bwd, causal_attn_bwd_with_threads};
pub use attn::AttnDims;
pub use attn::{causal_attn_fwd, causal_attn_fwd_with_threads};
pub use gemm::{gemm, gemm_nt, gemm_nt_with_dispatch, gemm_nt_with_threads, gemm_tn};
pub use gemm::{gemm_tn_outcols, gemm_tn_outcols_with_dispatch, gemm_tn_outcols_with_threads};
pub use gemm::{gemm_tn_with_dispatch, gemm_tn_with_threads, gemm_with_dispatch};
pub use gemm::{gemm_with_threads, gemv_acc, slice_cols};
pub use micro::{simd_enabled, simd_supported};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Problems smaller than this many multiply-adds run on the calling
/// thread: at ~1 GFLOP/s-per-core worst case this is tens of
/// microseconds, the same order as a thread spawn.
pub const MIN_PAR_WORK: usize = 1 << 16;

/// `0` means "not overridden" — fall back to the environment.
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override the kernel worker count for this process (the CLI `--threads`
/// flag lands here). Takes precedence over `S2FT_THREADS`. Passing `0`
/// clears the override and resets to the environment fallback
/// (`S2FT_THREADS`, else available parallelism) — it does not mean "one
/// thread".
pub fn set_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::Relaxed);
}

/// Worker count kernels use by default: [`set_threads`] override, else
/// `S2FT_THREADS`, else available parallelism (read once per process).
pub fn configured_threads() -> usize {
    let o = THREAD_OVERRIDE.load(Ordering::Relaxed);
    if o > 0 {
        return o;
    }
    static ENV: OnceLock<usize> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var("S2FT_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, |n| n.get()))
    })
}

/// Split `out` into contiguous whole-row chunks and run `f(first_row,
/// chunk)` on scoped worker threads — the single partitioning primitive
/// behind every kernel. `work` is a multiply-add estimate; below
/// [`MIN_PAR_WORK`] (or with one thread / one row) `f` runs inline.
pub(crate) fn for_each_row_chunk(
    out: &mut [f32],
    row_len: usize,
    threads: usize,
    work: usize,
    f: impl Fn(usize, &mut [f32]) + Sync,
) {
    if out.is_empty() || row_len == 0 {
        return;
    }
    let rows = out.len() / row_len;
    let t = threads.min(rows);
    if t <= 1 || work < MIN_PAR_WORK {
        f(0, out);
        return;
    }
    let per = rows.div_ceil(t);
    std::thread::scope(|s| {
        for (ci, chunk) in out.chunks_mut(per * row_len).enumerate() {
            let f = &f;
            s.spawn(move || f(ci * per, chunk));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// `THREAD_OVERRIDE` is process-global state: every test that writes
    /// it (or asserts on [`configured_threads`]) takes this lock so a
    /// concurrently running sibling can't observe a half-finished
    /// override.
    static THREADS_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn configured_threads_positive() {
        let _guard = THREADS_LOCK.lock().unwrap();
        assert!(configured_threads() >= 1);
    }

    #[test]
    fn set_threads_overrides() {
        // last-wins semantics through the atomic, serialized against
        // sibling tests that read the global
        let _guard = THREADS_LOCK.lock().unwrap();
        set_threads(3);
        assert_eq!(configured_threads(), 3);
        set_threads(1);
        assert_eq!(configured_threads(), 1);
        // 0 clears the override: back to the environment fallback, which
        // is always at least one worker
        set_threads(0);
        assert!(configured_threads() >= 1);
    }

    #[test]
    fn row_chunks_cover_all_rows_once() {
        for threads in [1usize, 2, 3, 5, 16] {
            let rows = 13;
            let cols = 4;
            let mut out = vec![0.0f32; rows * cols];
            // force the parallel path with a huge work estimate
            for_each_row_chunk(&mut out, cols, threads, usize::MAX, |row0, chunk| {
                for (r, row) in chunk.chunks_mut(cols).enumerate() {
                    for v in row.iter_mut() {
                        *v += (row0 + r) as f32 + 1.0;
                    }
                }
            });
            let want: Vec<f32> = (0..rows).flat_map(|r| vec![r as f32 + 1.0; cols]).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn row_chunks_empty_is_noop() {
        let mut out: Vec<f32> = vec![];
        for_each_row_chunk(&mut out, 4, 8, usize::MAX, |_, _| panic!("called on empty"));
    }
}
