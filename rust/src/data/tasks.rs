//! Task suites mirroring the paper's evaluation structure (DESIGN.md §2):
//!
//! * `commonsense` — 8 subtasks (BoolQ/PIQA/SIQA/HellaSwag/WinoGrande/
//!   ARC-e/ARC-c/OBQA analogues) over the synthetic world.
//! * `arithmetic` — 7 subtasks (MultiArith/GSM8K/AddSub/AQuA/SingleEq/
//!   SVAMP/MAWPS analogues); the fine-tuning set (Math10K analogue) draws
//!   from GSM8K+AQuA+MAWPS only, so MultiArith/AddSub/SingleEq/SVAMP are
//!   near-OOD exactly as in the paper's App. C.
//! * `instruct` — 8 MT-Bench-like categories.
//!
//! Train/test disjointness: entity-based questions split by entity index
//! parity; numeric questions split by operand parity. A model can only be
//! correct on test items via the *rule*, not memorization.

use super::world::{World, GOALS};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

#[derive(Debug, Clone)]
pub struct Example {
    pub prompt: String,
    pub answer: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Difficulty {
    Easy,
    Hard,
}

/// A named subtask generator.
pub struct Task {
    pub name: &'static str,
    pub difficulty: Difficulty,
    gen: fn(&World, &mut Rng, Split) -> Example,
}

impl Task {
    pub fn sample(&self, world: &World, rng: &mut Rng, split: Split) -> Example {
        (self.gen)(world, rng, split)
    }

    pub fn batch(&self, world: &World, rng: &mut Rng, split: Split, n: usize) -> Vec<Example> {
        (0..n).map(|_| self.sample(world, rng, split)).collect()
    }
}

/// Pick an entity index respecting the split (even=train, odd=test).
fn split_entity(world: &World, rng: &mut Rng, split: Split) -> usize {
    let n = world.entities.len();
    let base = rng.below(n / 2) * 2;
    match split {
        Split::Train => base,
        Split::Test => base + 1,
    }
}

/// Pick a small operand respecting the split (even=train, odd=test).
fn split_num(rng: &mut Rng, split: Split, lo: i64, hi: i64) -> i64 {
    let v = rng.range(lo, hi);
    let v = v - (v & 1);
    match split {
        Split::Train => v,
        Split::Test => v + 1,
    }
}

// ---------------------------------------------------------------------------
// Commonsense suite
// ---------------------------------------------------------------------------

fn boolq(w: &World, rng: &mut Rng, split: Split) -> Example {
    let e = &w.entities[split_entity(w, rng, split)];
    let truth = rng.bool(0.5);
    let color = if truth {
        e.color
    } else {
        super::world::COLORS[(super::world::COLORS.iter().position(|c| *c == e.color).unwrap()
            + 1 + rng.below(4))
            % super::world::COLORS.len()]
    };
    Example {
        prompt: format!("q: is {} {}?", e.name, color),
        answer: (if truth { "yes" } else { "no" }).into(),
    }
}

fn piqa(w: &World, rng: &mut Rng, split: Split) -> Example {
    let (goal, kind) = GOALS[rng.below(GOALS.len())];
    // candidates: one entity of the right kind, one wrong
    let right: Vec<usize> = (0..w.entities.len())
        .filter(|&i| w.entities[i].kind == kind && matches_split(i, split))
        .collect();
    let wrong: Vec<usize> = (0..w.entities.len())
        .filter(|&i| w.entities[i].kind != kind && matches_split(i, split))
        .collect();
    if right.is_empty() || wrong.is_empty() {
        return piqa(w, rng, flip(split)); // degenerate world corner
    }
    let r = right[rng.below(right.len())];
    let wr = wrong[rng.below(wrong.len())];
    let r_first = rng.bool(0.5);
    let (a, b) = if r_first { (r, wr) } else { (wr, r) };
    Example {
        prompt: format!(
            "q: to {} pick {} or {}?",
            goal, w.entities[a].name, w.entities[b].name
        ),
        answer: w.entities[r].name.clone(),
    }
}

fn siqa(w: &World, rng: &mut Rng, split: Split) -> Example {
    // social-interaction analogue: who lives with whom (same place)
    let i = split_entity(w, rng, split);
    let e = &w.entities[i];
    Example {
        prompt: format!("q: where does {} live?", e.name),
        answer: e.place.to_string(),
    }
}

fn hellaswag(w: &World, rng: &mut Rng, split: Split) -> Example {
    // continuation: "X is a bird. X can ..." -> ability completion
    let e = &w.entities[split_entity(w, rng, split)];
    Example {
        prompt: format!("q: {} is a {}. {} can", e.name, e.kind, e.name),
        answer: World::ability_of(e.kind).to_string(),
    }
}

fn winogrande(w: &World, rng: &mut Rng, split: Split) -> Example {
    // pronoun resolution by size: "the big one" among two entities
    let mut i = split_entity(w, rng, split);
    let mut j = split_entity(w, rng, split);
    let mut guard = 0;
    while (w.entities[j].size == w.entities[i].size || j == i) && guard < 64 {
        j = split_entity(w, rng, split);
        guard += 1;
    }
    if w.entities[i].size == w.entities[j].size {
        i = 0;
        j = 1;
    }
    let big_first = size_rank(w.entities[i].size) > size_rank(w.entities[j].size);
    let bigger = if big_first { i } else { j };
    Example {
        prompt: format!(
            "q: {} is {} and {} is {}. which is bigger?",
            w.entities[i].name, w.entities[i].size, w.entities[j].name, w.entities[j].size
        ),
        answer: w.entities[bigger].name.clone(),
    }
}

fn arc_easy(w: &World, rng: &mut Rng, split: Split) -> Example {
    let e = &w.entities[split_entity(w, rng, split)];
    Example {
        prompt: format!("q: what kind is {}?", e.name),
        answer: e.kind.to_string(),
    }
}

fn arc_challenge(w: &World, rng: &mut Rng, split: Split) -> Example {
    // two-hop: entity -> kind -> ability
    let e = &w.entities[split_entity(w, rng, split)];
    Example {
        prompt: format!("q: what can {} do?", e.name),
        answer: World::ability_of(e.kind).to_string(),
    }
}

fn obqa(_w: &World, rng: &mut Rng, split: Split) -> Example {
    // open-book: goal -> needed kind (rule recall)
    let _ = split;
    let (goal, kind) = GOALS[rng.below(GOALS.len())];
    Example {
        prompt: format!("q: what kind do you need to {}?", goal),
        answer: kind.to_string(),
    }
}

fn size_rank(s: &str) -> usize {
    match s {
        "small" => 0,
        "big" => 1,
        _ => 2,
    }
}

fn matches_split(i: usize, split: Split) -> bool {
    (i % 2 == 0) == (split == Split::Train)
}

fn flip(s: Split) -> Split {
    match s {
        Split::Train => Split::Test,
        Split::Test => Split::Train,
    }
}

pub const COMMONSENSE: [Task; 8] = [
    Task { name: "BoolQ", difficulty: Difficulty::Easy, gen: boolq },
    Task { name: "PIQA", difficulty: Difficulty::Easy, gen: piqa },
    Task { name: "SIQA", difficulty: Difficulty::Easy, gen: siqa },
    Task { name: "HellaSwag", difficulty: Difficulty::Easy, gen: hellaswag },
    Task { name: "Wino", difficulty: Difficulty::Hard, gen: winogrande },
    Task { name: "ARC-e", difficulty: Difficulty::Easy, gen: arc_easy },
    Task { name: "ARC-c", difficulty: Difficulty::Hard, gen: arc_challenge },
    Task { name: "OBQA", difficulty: Difficulty::Easy, gen: obqa },
];

// ---------------------------------------------------------------------------
// Arithmetic suite
// ---------------------------------------------------------------------------

fn multiarith(_w: &World, rng: &mut Rng, split: Split) -> Example {
    let a = split_num(rng, split, 2, 10);
    let b = rng.range(2, 10);
    let c = rng.range(2, 6);
    Example {
        prompt: format!("q: ({} + {}) * {} =", a, b, c),
        answer: ((a + b) * c).to_string(),
    }
}

fn gsm8k(_w: &World, rng: &mut Rng, split: Split) -> Example {
    // two-step word problem
    let a = split_num(rng, split, 4, 20);
    let b = rng.range(2, a.max(3));
    let c = rng.range(2, 8);
    Example {
        prompt: format!(
            "q: sam has {} nuts, eats {} and finds {} more. how many nuts?",
            a, b, c
        ),
        answer: (a - b + c).to_string(),
    }
}

fn addsub(_w: &World, rng: &mut Rng, split: Split) -> Example {
    let a = split_num(rng, split, 2, 50);
    let b = rng.range(1, a.max(2));
    if rng.bool(0.5) {
        Example { prompt: format!("q: {} + {} =", a, b), answer: (a + b).to_string() }
    } else {
        Example { prompt: format!("q: {} - {} =", a, b), answer: (a - b).to_string() }
    }
}

fn aqua(_w: &World, rng: &mut Rng, split: Split) -> Example {
    // multiple choice
    let a = split_num(rng, split, 2, 20);
    let b = rng.range(2, 20);
    let sum = a + b;
    let correct = rng.below(3);
    let opts: Vec<i64> = (0..3)
        .map(|i| if i == correct { sum } else { sum + 1 + i as i64 })
        .collect();
    Example {
        prompt: format!(
            "q: {} + {} = ? (a) {} (b) {} (c) {}",
            a, b, opts[0], opts[1], opts[2]
        ),
        answer: ["a", "b", "c"][correct].to_string(),
    }
}

fn singleeq(_w: &World, rng: &mut Rng, split: Split) -> Example {
    let x = split_num(rng, split, 1, 30);
    let a = rng.range(1, 30);
    Example { prompt: format!("q: x + {} = {}. x =", a, x + a), answer: x.to_string() }
}

fn svamp(_w: &World, rng: &mut Rng, split: Split) -> Example {
    // reworded add/sub word problem (structure variation)
    let a = split_num(rng, split, 2, 40);
    let b = rng.range(1, a.max(2));
    Example {
        prompt: format!("q: there were {} cups. {} broke. cups left =", a, b),
        answer: (a - b).to_string(),
    }
}

fn mawps(_w: &World, rng: &mut Rng, split: Split) -> Example {
    let a = split_num(rng, split, 2, 12);
    let b = rng.range(2, 12);
    Example { prompt: format!("q: {} * {} =", a, b), answer: (a * b).to_string() }
}

/// Order matters: `ARITH_FT` below indexes into this list.
pub const ARITHMETIC: [Task; 7] = [
    Task { name: "MultiArith", difficulty: Difficulty::Easy, gen: multiarith },
    Task { name: "GSM8K", difficulty: Difficulty::Hard, gen: gsm8k },
    Task { name: "AddSub", difficulty: Difficulty::Easy, gen: addsub },
    Task { name: "AQuA", difficulty: Difficulty::Hard, gen: aqua },
    Task { name: "SingleEq", difficulty: Difficulty::Easy, gen: singleeq },
    Task { name: "SVAMP", difficulty: Difficulty::Hard, gen: svamp },
    Task { name: "MAWPS", difficulty: Difficulty::Easy, gen: mawps },
];

/// The Math10K-analogue fine-tuning mixture: GSM8K + AQuA + MAWPS
/// (indices into [`ARITHMETIC`]); the other four tasks are near-OOD.
pub const ARITH_FT: [usize; 3] = [1, 3, 6];

// ---------------------------------------------------------------------------
// Instruction-following suite (MT-Bench-like categories)
// ---------------------------------------------------------------------------

fn inst_writing(w: &World, rng: &mut Rng, split: Split) -> Example {
    let e = &w.entities[split_entity(w, rng, split)];
    Example {
        prompt: format!("write {} in caps:", e.name),
        answer: e.name.to_uppercase(),
    }
}

fn inst_roleplay(w: &World, rng: &mut Rng, split: Split) -> Example {
    let e = &w.entities[split_entity(w, rng, split)];
    Example {
        prompt: format!("you are {}. say your color:", e.name),
        answer: e.color.to_string(),
    }
}

fn inst_reasoning(_w: &World, rng: &mut Rng, split: Split) -> Example {
    let a = split_num(rng, split, 1, 40);
    let b = rng.range(1, 40);
    Example {
        prompt: format!("which is larger, {} or {}?", a, b),
        answer: a.max(b).to_string(),
    }
}

fn inst_code(w: &World, rng: &mut Rng, split: Split) -> Example {
    let e = &w.entities[split_entity(w, rng, split)];
    Example {
        prompt: format!("print('{}') outputs:", e.name),
        answer: e.name.clone(),
    }
}

fn inst_math(_w: &World, rng: &mut Rng, split: Split) -> Example {
    let a = split_num(rng, split, 1, 20);
    let b = rng.range(1, 20);
    Example { prompt: format!("{} + {} =", a, b), answer: (a + b).to_string() }
}

fn inst_extraction(w: &World, rng: &mut Rng, split: Split) -> Example {
    let e = &w.entities[split_entity(w, rng, split)];
    Example {
        prompt: format!(
            "record: name={} color={} place={}. extract color:",
            e.name, e.color, e.place
        ),
        answer: e.color.to_string(),
    }
}

fn inst_stem(w: &World, rng: &mut Rng, split: Split) -> Example {
    arc_challenge(w, rng, split)
}

fn inst_humanities(w: &World, rng: &mut Rng, split: Split) -> Example {
    siqa(w, rng, split)
}

pub const INSTRUCT: [Task; 8] = [
    Task { name: "Writing", difficulty: Difficulty::Easy, gen: inst_writing },
    Task { name: "Roleplay", difficulty: Difficulty::Easy, gen: inst_roleplay },
    Task { name: "Reasoning", difficulty: Difficulty::Hard, gen: inst_reasoning },
    Task { name: "Code", difficulty: Difficulty::Easy, gen: inst_code },
    Task { name: "Math", difficulty: Difficulty::Hard, gen: inst_math },
    Task { name: "Extraction", difficulty: Difficulty::Easy, gen: inst_extraction },
    Task { name: "STEM", difficulty: Difficulty::Hard, gen: inst_stem },
    Task { name: "Humanities", difficulty: Difficulty::Easy, gen: inst_humanities },
];

/// Look up a suite by name.
pub fn suite(name: &str) -> Option<&'static [Task]> {
    match name {
        "commonsense" => Some(&COMMONSENSE),
        "arithmetic" => Some(&ARITHMETIC),
        "instruct" => Some(&INSTRUCT),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_consistent_examples() {
        let w = World::canonical();
        let mut rng = Rng::seed(1);
        for task in COMMONSENSE.iter().chain(&ARITHMETIC).chain(&INSTRUCT) {
            for split in [Split::Train, Split::Test] {
                for _ in 0..20 {
                    let ex = task.sample(&w, &mut rng, split);
                    assert!(!ex.prompt.is_empty(), "{}", task.name);
                    assert!(!ex.answer.is_empty(), "{}", task.name);
                    assert!(ex.answer.len() <= 12, "{}: {:?}", task.name, ex.answer);
                }
            }
        }
    }

    #[test]
    fn splits_are_disjoint_for_entity_tasks() {
        let w = World::canonical();
        let mut rng = Rng::seed(2);
        // arc_easy asks about an entity; train and test entities must differ
        let train: std::collections::HashSet<String> = (0..200)
            .map(|_| arc_easy(&w, &mut rng, Split::Train).prompt)
            .collect();
        let test: std::collections::HashSet<String> = (0..200)
            .map(|_| arc_easy(&w, &mut rng, Split::Test).prompt)
            .collect();
        assert!(train.is_disjoint(&test));
    }

    #[test]
    fn arithmetic_answers_are_correct() {
        let w = World::canonical();
        let mut rng = Rng::seed(3);
        for _ in 0..100 {
            let ex = multiarith(&w, &mut rng, Split::Train);
            // parse "(a + b) * c ="
            let nums: Vec<i64> = ex
                .prompt
                .split(|c: char| !c.is_ascii_digit())
                .filter(|s| !s.is_empty())
                .map(|s| s.parse().unwrap())
                .collect();
            assert_eq!(
                ((nums[0] + nums[1]) * nums[2]).to_string(),
                ex.answer
            );
        }
    }

    #[test]
    fn math_ft_mixture_indices_valid() {
        for &i in &ARITH_FT {
            assert!(i < ARITHMETIC.len());
        }
        assert_eq!(ARITHMETIC[ARITH_FT[0]].name, "GSM8K");
    }

    #[test]
    fn suite_lookup() {
        assert_eq!(suite("commonsense").unwrap().len(), 8);
        assert_eq!(suite("arithmetic").unwrap().len(), 7);
        assert_eq!(suite("instruct").unwrap().len(), 8);
        assert!(suite("nope").is_none());
    }
}
