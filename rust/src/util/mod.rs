//! In-crate substrates for the offline environment: JSON, deterministic
//! RNG, a criterion-style bench harness, and process memory introspection.

pub mod bench;
pub mod json;
pub mod rng;

/// Peak resident set size of this process in bytes (VmHWM from
/// /proc/self/status). Used by the Fig 5 memory-efficiency harness.
pub fn peak_rss_bytes() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Current resident set size in bytes (VmRSS).
pub fn current_rss_bytes() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: u64 = rest.trim().trim_end_matches(" kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    #[test]
    fn rss_readable() {
        assert!(super::current_rss_bytes().unwrap() > 0);
        assert!(super::peak_rss_bytes().unwrap() >= super::current_rss_bytes().unwrap());
    }
}
