//! The Trainer: prepare -> step* -> merge lifecycle for one fine-tuning run.

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

use crate::data::Batch;
use crate::runtime::{Executable, Executor, Tensor};
use crate::util::rng::Rng;

use super::metrics::TrainMetrics;

/// Read a scalar byte-count output (i32 from the native backend, but be
/// liberal in what we accept from other executables).
fn scalar_bytes(t: &Tensor) -> Option<u64> {
    if let Ok(v) = t.as_i32() {
        v.first().map(|&x| x.max(0) as u64)
    } else if let Ok(v) = t.as_f32() {
        v.first().map(|&x| x.max(0.0) as u64)
    } else {
        None
    }
}

/// One fine-tuning run of `method` on `model`, at the artifact batch
/// shape `(b, t)`. Holds the method-layout state (trainable, frozen,
/// optimizer moments, permutations) as host tensors between steps.
pub struct Trainer {
    pub model: String,
    pub method: String,
    pub b: usize,
    pub t: usize,
    train_exe: std::sync::Arc<dyn Executable>,
    /// tensor pool holding trainable + frozen + m.* + v.* (+aux names)
    pool: HashMap<String, Tensor>,
    /// perm outputs of prepare (s2ft only)
    pub perms: HashMap<String, Tensor>,
    pub step: usize,
    pub metrics: TrainMetrics,
    n_layers: usize,
    rng: Rng,
    /// LISA freezes layers randomly per step; others leave aux constant.
    is_lisa: bool,
    is_galore: bool,
}

impl Trainer {
    /// Prepare a run from base-layout params. `calib` drives selection
    /// strategies A/S/G (any train batch works; unused under R/W).
    pub fn new(
        rt: &dyn Executor,
        model: &str,
        method: &str,
        base_params: &HashMap<String, Tensor>,
        seed: u64,
        calib: &Batch,
    ) -> Result<Self> {
        let mm = rt.artifacts().model(model)?;
        let (b, t) = mm.default_batch();
        Self::with_batch(rt, model, method, base_params, seed, calib, b, t)
    }

    /// Same but at an explicit artifact batch shape (Fig 5 sweeps).
    #[allow(clippy::too_many_arguments)]
    pub fn with_batch(
        rt: &dyn Executor,
        model: &str,
        method: &str,
        base_params: &HashMap<String, Tensor>,
        seed: u64,
        calib: &Batch,
        b: usize,
        t: usize,
    ) -> Result<Self> {
        let mm = rt.artifacts().model(model)?;
        let method_meta = mm.method(method)?.clone();
        let n_layers = mm.dims.n_layers;

        // prepare: (base..., seed, calib) -> (trainable..., frozen..., perms...)
        let prep = rt
            .load(&format!("prepare_{model}_{method}_{b}x{t}"))
            .with_context(|| format!("prepare artifact for {model}/{method} at {b}x{t}"))?;
        let mut pin = base_params.clone();
        pin.insert("seed".into(), Tensor::scalar_i32(seed as i32));
        pin.insert("tokens".into(), calib.tokens.clone());
        pin.insert("targets".into(), calib.targets.clone());
        pin.insert("loss_mask".into(), calib.loss_mask.clone());
        let prepared = prep.run_named(&pin)?;

        let mut pool: HashMap<String, Tensor> = HashMap::new();
        let mut perms: HashMap<String, Tensor> = HashMap::new();
        let perm_names: std::collections::HashSet<&str> =
            method_meta.perms.iter().map(|p| p.name.as_str()).collect();
        for (name, tensor) in prepared {
            if perm_names.contains(name.as_str()) {
                perms.insert(name, tensor);
            } else {
                pool.insert(name, tensor);
            }
        }
        // zero optimizer moments
        for o in &method_meta.opt {
            pool.insert(format!("m.{}", o.name), Tensor::zeros(o.shape.clone()));
            pool.insert(format!("v.{}", o.name), Tensor::zeros(o.shape.clone()));
        }
        // aux defaults
        for a in &method_meta.aux {
            pool.insert(a.name.clone(), Tensor::ones(a.shape.clone()));
        }

        let train_exe = rt.load(&format!("train_{model}_{method}_{b}x{t}"))?;
        Ok(Self {
            model: model.to_string(),
            method: method.to_string(),
            b,
            t,
            train_exe,
            pool,
            perms,
            step: 0,
            metrics: TrainMetrics::new(),
            n_layers,
            rng: Rng::seed(seed ^ 0x5113),
            is_lisa: method_meta.method == "lisa",
            is_galore: method_meta.method == "galore",
        })
    }

    /// Run one optimizer step; returns the loss.
    ///
    /// Per-step inputs (batch tensors, the step counter, LISA's layer
    /// mask) travel in a transient overlay, never the persistent pool —
    /// so [`Trainer::state_bytes`] reports live *state* only and is
    /// identical before and after a step.
    pub fn train_step(&mut self, batch: &Batch) -> Result<f32> {
        let started = std::time::Instant::now();
        let mut inputs: HashMap<String, Tensor> = HashMap::new();
        // 0-based step count: executables bias-correct at t = step + 1
        inputs.insert("step".into(), Tensor::scalar_f32(self.step as f32));
        inputs.insert("tokens".into(), batch.tokens.clone());
        inputs.insert("targets".into(), batch.targets.clone());
        inputs.insert("loss_mask".into(), batch.loss_mask.clone());
        if self.is_lisa {
            // LISA: sample 1/4 of the blocks active this step (+ embeddings).
            let active = (self.n_layers / 4).max(1);
            let chosen = self.rng.choose(self.n_layers, active);
            let mut mask = vec![0.0f32; self.n_layers + 1];
            for c in chosen {
                mask[c] = 1.0;
            }
            mask[self.n_layers] = 1.0;
            inputs.insert("layer_mask".into(), Tensor::f32(vec![self.n_layers + 1], mask));
        }
        if self.is_galore {
            // fixed projection: constant seed for the whole run
            inputs.insert("proj_seed".into(), Tensor::scalar_f32(1.0));
        }
        let out = self.train_exe.run_named_with(&self.pool, &inputs)?;
        let mut loss: Option<f32> = None;
        let mut act_bytes: Option<u64> = None;
        let mut act_peak: Option<u64> = None;
        for (name, tensor) in out {
            if name == "loss" {
                loss = Some(tensor.scalar_value_f32()?);
            } else if name == "act_bytes" {
                act_bytes = scalar_bytes(&tensor);
            } else if name == "act_peak_bytes" {
                act_peak = scalar_bytes(&tensor);
            } else if let Some(rest) = name.strip_prefix("new_m.") {
                self.pool.insert(format!("m.{rest}"), tensor);
            } else if let Some(rest) = name.strip_prefix("new_v.") {
                self.pool.insert(format!("v.{rest}"), tensor);
            } else if let Some(rest) = name.strip_prefix("new.") {
                self.pool.insert(rest.to_string(), tensor);
            }
        }
        // A train executable that emits no "loss" is malformed: recording
        // NaN would silently poison the metrics.
        let loss = loss.ok_or_else(|| {
            anyhow!(
                "train executable {:?} emitted no \"loss\" output",
                self.train_exe.name()
            )
        })?;
        self.step += 1;
        let tokens = batch.tokens.numel();
        self.metrics.record_step(loss, tokens, started.elapsed());
        if let (Some(cache), Some(peak)) = (act_bytes, act_peak) {
            self.metrics.record_activation(cache, peak);
        }
        Ok(loss)
    }

    /// Merge back into base layout (for eval / serving / adapter diffing).
    pub fn merged_params(&self, rt: &dyn Executor) -> Result<HashMap<String, Tensor>> {
        let merge = rt.load(&format!("merge_{}_{}", self.model, self.method))?;
        let mut pin = self.pool.clone();
        for (k, v) in &self.perms {
            pin.insert(k.clone(), v.clone());
        }
        merge.run_named(&pin)
    }

    /// Bytes of live training state (trainable+frozen+opt), the Fig 5
    /// analytic memory number. Per-step batch inputs never enter the
    /// pool, so this is stable across [`Trainer::train_step`] calls.
    pub fn state_bytes(&self) -> usize {
        self.pool.values().map(|t| t.bytes()).sum()
    }

    /// Measured activation-cache bytes of the last step (native backend
    /// train executables report them; `None` on AOT artifacts).
    pub fn activation_bytes(&self) -> Option<u64> {
        self.metrics.act_cache_bytes
    }

    /// Measured peak live activation bytes of the last step.
    pub fn activation_peak_bytes(&self) -> Option<u64> {
        self.metrics.act_peak_bytes
    }

    /// Bytes of optimizer state only.
    pub fn opt_bytes(&self) -> usize {
        self.pool
            .iter()
            .filter(|(k, _)| k.starts_with("m.") || k.starts_with("v."))
            .map(|(_, t)| t.bytes())
            .sum()
    }

    /// Read a state tensor (tests / diagnostics).
    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.pool.get(name).ok_or_else(|| anyhow!("no tensor {name:?} in trainer pool"))
    }
}
