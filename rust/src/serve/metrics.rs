//! Engine-wide serving metrics.

/// Counters + latency distribution for one [`super::Engine`].
///
/// Latencies are kept **sorted on insert** ([`ServeMetrics::record_latency_ms`]
/// does a binary-search insert), so percentile reads are O(1) index math
/// instead of the former clone-and-sort per call.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub requests: usize,
    pub batches: usize,
    pub switches: usize,
    /// Total tokens generated (streamed) across all requests.
    pub tokens: usize,
    latencies_ms: Vec<f64>,
}

impl ServeMetrics {
    /// Record one request latency, keeping the vector sorted.
    pub fn record_latency_ms(&mut self, ms: f64) {
        let i = self.latencies_ms.partition_point(|&x| x < ms);
        self.latencies_ms.insert(i, ms);
    }

    /// All recorded latencies, ascending.
    pub fn latencies_ms(&self) -> &[f64] {
        &self.latencies_ms
    }

    /// Nearest-rank percentile (`p` in `[0, 1]`): the smallest recorded
    /// latency such that at least `p · n` samples are ≤ it.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        let n = self.latencies_ms.len();
        if n == 0 {
            return 0.0;
        }
        let rank = (p * n as f64).ceil() as usize;
        self.latencies_ms[rank.clamp(1, n) - 1]
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_handles_empty_and_stays_sorted() {
        let m = ServeMetrics::default();
        assert_eq!(m.percentile_ms(0.5), 0.0);
        assert_eq!(m.percentile_ms(0.99), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);

        let mut m = ServeMetrics {
            requests: 4,
            batches: 2,
            switches: 1,
            ..Default::default()
        };
        for ms in [40.0, 10.0, 30.0, 20.0] {
            m.record_latency_ms(ms);
        }
        assert_eq!(m.latencies_ms(), &[10.0, 20.0, 30.0, 40.0]);
        assert_eq!(m.percentile_ms(0.0), 10.0);
        assert_eq!(m.percentile_ms(1.0), 40.0);
        assert_eq!(m.percentile_ms(0.5), 20.0);
        assert_eq!(m.mean_batch_size(), 2.0);
    }

    /// Nearest-rank must not truncate toward low ranks: p99 of 9 samples
    /// is the maximum (rank ceil(8.91) = 9), not sample 7 as the old
    /// `(n-1)·p` truncation produced.
    #[test]
    fn nearest_rank_indexing() {
        let mut m = ServeMetrics::default();
        for i in 1..=9 {
            m.record_latency_ms(i as f64);
        }
        assert_eq!(m.percentile_ms(0.99), 9.0);
        assert_eq!(m.percentile_ms(0.5), 5.0);
        assert_eq!(m.percentile_ms(0.11), 1.0);
        assert_eq!(m.percentile_ms(0.12), 2.0);
    }
}
