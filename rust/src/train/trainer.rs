//! The Trainer: prepare -> step* -> merge lifecycle for one fine-tuning run.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::data::Batch;
use crate::runtime::native::builtin::{is_mha, is_row_split};
use crate::runtime::{Executable, Executor, Tensor};
use crate::sparsity::strategy::{LayerSelections, SelectionCtx, SelectionStrategy};
use crate::util::rng::Rng;

use super::metrics::TrainMetrics;
use super::replan;

/// State of a dynamic selection run (None for classic prepare-artifact
/// runs): the strategy, its committed selection, and the bookkeeping
/// needed to rebuild the plan pipeline when the selection changes.
struct DynSelection {
    strategy: Box<dyn SelectionStrategy>,
    /// Replan cadence in steps (strategy-interpreted; 0 = never).
    replan_every: usize,
    /// The committed per-layer selection the current pool was built from.
    selections: LayerSelections,
    /// The base method's budgeted projections and their static counts.
    base_counts: HashMap<String, usize>,
    mha_count: usize,
    ffn_count: usize,
    seed: u64,
    /// Bumped on every committed replan; plan-derived executable state is
    /// keyed to it (evict + reload, never mutated in place).
    plan_epoch: usize,
}

/// Read a scalar byte-count output (i32 from the native backend, but be
/// liberal in what we accept from other executables).
fn scalar_bytes(t: &Tensor) -> Option<u64> {
    if let Ok(v) = t.as_i32() {
        v.first().map(|&x| x.max(0) as u64)
    } else if let Ok(v) = t.as_f32() {
        v.first().map(|&x| x.max(0.0) as u64)
    } else {
        None
    }
}

/// One fine-tuning run of `method` on `model`, at the artifact batch
/// shape `(b, t)`. Holds the method-layout state (trainable, frozen,
/// optimizer moments, permutations) as host tensors between steps.
pub struct Trainer {
    pub model: String,
    pub method: String,
    pub b: usize,
    pub t: usize,
    train_exe: Arc<dyn Executable>,
    /// tensor pool holding trainable + frozen + m.* + v.* (+aux names)
    pool: HashMap<String, Tensor>,
    /// perm outputs of prepare (s2ft only)
    pub perms: HashMap<String, Tensor>,
    pub step: usize,
    pub metrics: TrainMetrics,
    n_layers: usize,
    rng: Rng,
    /// LISA freezes layers randomly per step; others leave aux constant.
    is_lisa: bool,
    is_galore: bool,
    /// Dynamic selection state ([`Trainer::with_strategy`] runs only).
    dyn_sel: Option<DynSelection>,
}

impl Trainer {
    /// Prepare a run from base-layout params. `calib` drives selection
    /// strategies A/S/G (any train batch works; unused under R/W).
    pub fn new(
        rt: &dyn Executor,
        model: &str,
        method: &str,
        base_params: &HashMap<String, Tensor>,
        seed: u64,
        calib: &Batch,
    ) -> Result<Self> {
        let mm = rt.artifacts().model(model)?;
        let (b, t) = mm.default_batch();
        Self::with_batch(rt, model, method, base_params, seed, calib, b, t)
    }

    /// Same but at an explicit artifact batch shape (Fig 5 sweeps).
    #[allow(clippy::too_many_arguments)]
    pub fn with_batch(
        rt: &dyn Executor,
        model: &str,
        method: &str,
        base_params: &HashMap<String, Tensor>,
        seed: u64,
        calib: &Batch,
        b: usize,
        t: usize,
    ) -> Result<Self> {
        let mm = rt.artifacts().model(model)?;
        let method_meta = mm.method(method)?.clone();
        let n_layers = mm.dims.n_layers;

        // prepare: (base..., seed, calib) -> (trainable..., frozen..., perms...)
        let prep = rt
            .load(&format!("prepare_{model}_{method}_{b}x{t}"))
            .with_context(|| format!("prepare artifact for {model}/{method} at {b}x{t}"))?;
        let mut pin = base_params.clone();
        pin.insert("seed".into(), Tensor::scalar_i32(seed as i32));
        pin.insert("tokens".into(), calib.tokens.clone());
        pin.insert("targets".into(), calib.targets.clone());
        pin.insert("loss_mask".into(), calib.loss_mask.clone());
        let prepared = prep.run_named(&pin)?;

        let mut pool: HashMap<String, Tensor> = HashMap::new();
        let mut perms: HashMap<String, Tensor> = HashMap::new();
        let perm_names: std::collections::HashSet<&str> =
            method_meta.perms.iter().map(|p| p.name.as_str()).collect();
        for (name, tensor) in prepared {
            if perm_names.contains(name.as_str()) {
                perms.insert(name, tensor);
            } else {
                pool.insert(name, tensor);
            }
        }
        // zero optimizer moments
        for o in &method_meta.opt {
            pool.insert(format!("m.{}", o.name), Tensor::zeros(o.shape.clone()));
            pool.insert(format!("v.{}", o.name), Tensor::zeros(o.shape.clone()));
        }
        // aux defaults
        for a in &method_meta.aux {
            pool.insert(a.name.clone(), Tensor::ones(a.shape.clone()));
        }

        let train_exe = rt.load(&format!("train_{model}_{method}_{b}x{t}"))?;
        Ok(Self {
            model: model.to_string(),
            method: method.to_string(),
            b,
            t,
            train_exe,
            pool,
            perms,
            step: 0,
            metrics: TrainMetrics::new(),
            n_layers,
            rng: Rng::seed(seed ^ 0x5113),
            is_lisa: method_meta.method == "lisa",
            is_galore: method_meta.method == "galore",
            dyn_sel: None,
        })
    }

    /// Prepare a run whose selection is owned by a
    /// [`SelectionStrategy`] instead of the prepare artifact. The
    /// strategy's step-0 selection is committed host-side (for
    /// [`crate::sparsity::strategy::StaticS2ft`] this reproduces the
    /// prepare artifact's pool bit-for-bit); call
    /// [`Trainer::maybe_replan`] before each step to let the strategy
    /// re-select mid-run.
    #[allow(clippy::too_many_arguments)]
    pub fn with_strategy(
        rt: &dyn Executor,
        model: &str,
        method: &str,
        base_params: &HashMap<String, Tensor>,
        seed: u64,
        mut strategy: Box<dyn SelectionStrategy>,
        replan_every: usize,
        b: usize,
        t: usize,
    ) -> Result<Self> {
        let mm = rt.artifacts().model(model)?;
        let method_meta = mm.method(method)?.clone();
        if method_meta.method != "s2ft" {
            bail!(
                "selection strategies drive unit-level (head/channel) budgets; \
                 method {method:?} is {:?}, not s2ft",
                method_meta.method
            );
        }
        let n_layers = mm.dims.n_layers;
        let base_counts = crate::adapter::s2ft_counts(mm, &method_meta);
        let (mha_count, ffn_count) = replan::structure_counts(&base_counts);

        let scores = replan::unit_scores(mm, base_params)?;
        let ctx = SelectionCtx {
            step: 0,
            n_layers,
            n_heads: mm.dims.n_heads,
            d_ff: mm.dims.d_ff,
            mha_count,
            ffn_count,
            seed,
            scores: &scores,
            current: None,
        };
        let selections = strategy.select(&ctx)?.ok_or_else(|| {
            anyhow!("strategy {:?} produced no initial selection", strategy.name())
        })?;
        replan::validate_selections(mm, mha_count > 0, ffn_count > 0, &selections)?;

        let (mut pool, perms) = replan::build_pool(mm, &base_counts, &selections, base_params)?;
        // zero optimizer moments, one pair per trainable (`_t`) split
        let trainable: Vec<(String, Vec<usize>)> = pool
            .iter()
            .filter(|(k, _)| k.ends_with("_t"))
            .map(|(k, v)| (k.clone(), v.shape.clone()))
            .collect();
        for (name, shape) in trainable {
            pool.insert(format!("m.{name}"), Tensor::zeros(shape.clone()));
            pool.insert(format!("v.{name}"), Tensor::zeros(shape));
        }

        let counts = replan::counts_per_layer(&base_counts, &selections);
        let train_exe = if counts.iter().all(|c| *c == base_counts) {
            rt.load(&format!("train_{model}_{method}_{b}x{t}"))?
        } else {
            rt.load_train_variant(model, &format!("{method}-v0"), method, &counts, b, t)?
        };

        Ok(Self {
            model: model.to_string(),
            method: method.to_string(),
            b,
            t,
            train_exe,
            pool,
            perms,
            step: 0,
            metrics: TrainMetrics::new(),
            n_layers,
            rng: Rng::seed(seed ^ 0x5113),
            is_lisa: false,
            is_galore: false,
            dyn_sel: Some(DynSelection {
                strategy,
                replan_every,
                selections,
                base_counts,
                mha_count,
                ffn_count,
                seed,
                plan_epoch: 0,
            }),
        })
    }

    /// Give the selection strategy a chance to re-select before the next
    /// step. Returns `true` when a replan was committed: the pool was
    /// merged back to base layout, re-permuted and re-split at the new
    /// selection, optimizer moments were carried over keyed by original
    /// unit index (survivors keep their blocks, grown units start at
    /// zero), and the executable's plan-derived caches were invalidated
    /// by a plan-epoch bump (evict + reload). `probe` feeds the gradient
    /// probe for strategies that score by gradient magnitude; any train
    /// batch at the run's `(b, t)` shape works.
    pub fn maybe_replan(&mut self, rt: &dyn Executor, probe: &Batch) -> Result<bool> {
        let (due, needs_grad) = match &self.dyn_sel {
            Some(ds) => (
                ds.strategy.replan_due(self.step, ds.replan_every),
                ds.strategy.needs_grad_scores(self.step),
            ),
            None => return Ok(false),
        };
        if !due {
            return Ok(false);
        }
        let mm = rt.artifacts().model(&self.model)?;
        let base = replan::merge_pool_to_base(mm, &self.pool, &self.perms)?;
        let mut scores = replan::unit_scores(mm, &base)?;
        if needs_grad {
            let gn = rt.load(&format!("gradnorm_{}_{}x{}", self.model, self.b, self.t))?;
            let mut pin = base.clone();
            pin.insert("tokens".into(), probe.tokens.clone());
            pin.insert("targets".into(), probe.targets.clone());
            pin.insert("loss_mask".into(), probe.loss_mask.clone());
            let out = gn.run_named(&pin)?;
            let grab = |name: &str| -> Result<Vec<Vec<f32>>> {
                replan::score_rows(
                    out.get(name)
                        .ok_or_else(|| anyhow!("gradnorm probe emitted no {name:?}"))?,
                )
            };
            scores.head_grad = Some(grab("head_grad_norms")?);
            scores.chan_grad = Some(grab("chan_grad_norms")?);
        }

        let ds = self.dyn_sel.as_mut().expect("checked above");
        let ctx = SelectionCtx {
            step: self.step,
            n_layers: self.n_layers,
            n_heads: mm.dims.n_heads,
            d_ff: mm.dims.d_ff,
            mha_count: ds.mha_count,
            ffn_count: ds.ffn_count,
            seed: ds.seed,
            scores: &scores,
            current: Some(&ds.selections),
        };
        let new_sel = match ds.strategy.select(&ctx)? {
            Some(s) => s,
            None => return Ok(false),
        };
        replan::validate_selections(mm, ds.mha_count > 0, ds.ffn_count > 0, &new_sel)?;

        let old_sel = std::mem::replace(&mut ds.selections, new_sel.clone());
        let new_counts = replan::counts_per_layer(&ds.base_counts, &new_sel);
        let shape_changed = replan::counts_per_layer(&ds.base_counts, &old_sel) != new_counts;

        // rebuild the weight pool at the new selection ...
        let (mut new_pool, new_perms) = replan::build_pool(mm, &ds.base_counts, &new_sel, &base)?;
        // ... and carry the optimizer moments across, keyed by original
        // unit index (never by permuted position).
        let hd = mm.head_dim();
        for p in ds.base_counts.keys() {
            for i in 0..self.n_layers {
                let name = format!("L{i}.{p}");
                let (old_units, new_units, block) = if is_mha(p) {
                    (&old_sel[i].heads, &new_sel[i].heads, hd)
                } else {
                    (&old_sel[i].channels, &new_sel[i].channels, 1)
                };
                let shape = new_pool
                    .get(&format!("{name}_t"))
                    .ok_or_else(|| anyhow!("replan: missing rebuilt {name}_t"))?
                    .shape
                    .clone();
                let dim = if is_row_split(p) { shape[1] } else { shape[0] };
                for kind in ["m", "v"] {
                    let key = format!("{kind}.{name}_t");
                    let old_t = self
                        .pool
                        .get(&key)
                        .ok_or_else(|| anyhow!("replan: missing moment {key:?}"))?;
                    let data = replan::remap_unit_moments(
                        old_units,
                        new_units,
                        block,
                        dim,
                        is_row_split(p),
                        old_t.as_f32()?,
                    );
                    new_pool.insert(key, Tensor::f32(shape.clone(), data));
                }
            }
        }

        // plan-epoch bump: plan-derived executable state (GradPlan /
        // CachePlans) is never patched in place — evict and reload.
        ds.plan_epoch += 1;
        rt.evict(self.train_exe.name());
        let standard = format!("train_{}_{}_{}x{}", self.model, self.method, self.b, self.t);
        self.train_exe = if new_counts.iter().all(|c| *c == ds.base_counts) {
            rt.evict(&standard);
            rt.load(&standard)?
        } else {
            let tag = format!("{}-v{}", self.method, ds.plan_epoch);
            rt.load_train_variant(&self.model, &tag, &self.method, &new_counts, self.b, self.t)?
        };
        self.pool = new_pool;
        self.perms = new_perms;
        self.metrics.record_replan(shape_changed);
        Ok(true)
    }

    /// Run one optimizer step; returns the loss.
    ///
    /// Per-step inputs (batch tensors, the step counter, LISA's layer
    /// mask) travel in a transient overlay, never the persistent pool —
    /// so [`Trainer::state_bytes`] reports live *state* only and is
    /// identical before and after a step.
    pub fn train_step(&mut self, batch: &Batch) -> Result<f32> {
        let started = std::time::Instant::now();
        let mut inputs: HashMap<String, Tensor> = HashMap::new();
        // 0-based step count: executables bias-correct at t = step + 1
        inputs.insert("step".into(), Tensor::scalar_f32(self.step as f32));
        inputs.insert("tokens".into(), batch.tokens.clone());
        inputs.insert("targets".into(), batch.targets.clone());
        inputs.insert("loss_mask".into(), batch.loss_mask.clone());
        if self.is_lisa {
            // LISA: sample 1/4 of the blocks active this step (+ embeddings).
            let active = (self.n_layers / 4).max(1);
            let chosen = self.rng.choose(self.n_layers, active);
            let mut mask = vec![0.0f32; self.n_layers + 1];
            for c in chosen {
                mask[c] = 1.0;
            }
            mask[self.n_layers] = 1.0;
            inputs.insert("layer_mask".into(), Tensor::f32(vec![self.n_layers + 1], mask));
        }
        if self.is_galore {
            // fixed projection: constant seed for the whole run
            inputs.insert("proj_seed".into(), Tensor::scalar_f32(1.0));
        }
        let out = self.train_exe.run_named_with(&self.pool, &inputs)?;
        let mut loss: Option<f32> = None;
        let mut act_bytes: Option<u64> = None;
        let mut act_peak: Option<u64> = None;
        for (name, tensor) in out {
            if name == "loss" {
                loss = Some(tensor.scalar_value_f32()?);
            } else if name == "act_bytes" {
                act_bytes = scalar_bytes(&tensor);
            } else if name == "act_peak_bytes" {
                act_peak = scalar_bytes(&tensor);
            } else if let Some(rest) = name.strip_prefix("new_m.") {
                self.pool.insert(format!("m.{rest}"), tensor);
            } else if let Some(rest) = name.strip_prefix("new_v.") {
                self.pool.insert(format!("v.{rest}"), tensor);
            } else if let Some(rest) = name.strip_prefix("new.") {
                self.pool.insert(rest.to_string(), tensor);
            }
        }
        // A train executable that emits no "loss" is malformed: recording
        // NaN would silently poison the metrics.
        let loss = loss.ok_or_else(|| {
            anyhow!(
                "train executable {:?} emitted no \"loss\" output",
                self.train_exe.name()
            )
        })?;
        self.step += 1;
        let tokens = batch.tokens.numel();
        self.metrics.record_step(loss, tokens, started.elapsed());
        if let (Some(cache), Some(peak)) = (act_bytes, act_peak) {
            self.metrics.record_activation(cache, peak);
        }
        Ok(loss)
    }

    /// Merge back into base layout (for eval / serving / adapter diffing).
    ///
    /// Dynamic-selection runs merge host-side: the merge artifact's spec
    /// is fixed to the base method's split shapes, which a replanned
    /// layout variant no longer matches. The host merge performs the
    /// same pure gathers, so for an unreplanned run the two paths agree
    /// bit-for-bit.
    pub fn merged_params(&self, rt: &dyn Executor) -> Result<HashMap<String, Tensor>> {
        if self.dyn_sel.is_some() {
            let mm = rt.artifacts().model(&self.model)?;
            return replan::merge_pool_to_base(mm, &self.pool, &self.perms);
        }
        let merge = rt.load(&format!("merge_{}_{}", self.model, self.method))?;
        let mut pin = self.pool.clone();
        for (k, v) in &self.perms {
            pin.insert(k.clone(), v.clone());
        }
        merge.run_named(&pin)
    }

    /// Trainable parameter count of the *current* layout, measured from
    /// the optimizer-moment mirror (which tracks the trainable set
    /// exactly). Varies across replans for shape-changing strategies.
    pub fn trainable_params(&self) -> usize {
        self.pool
            .iter()
            .filter(|(k, _)| k.starts_with("m."))
            .map(|(_, t)| t.numel())
            .sum()
    }

    /// The committed per-layer selections of a dynamic run (`None` for
    /// prepare-artifact runs).
    pub fn selections(&self) -> Option<&LayerSelections> {
        self.dyn_sel.as_ref().map(|d| &d.selections)
    }

    /// Plan epoch: number of committed replans so far (0 for static and
    /// prepare-artifact runs).
    pub fn plan_epoch(&self) -> usize {
        self.dyn_sel.as_ref().map_or(0, |d| d.plan_epoch)
    }

    /// Bytes of live training state (trainable+frozen+opt), the Fig 5
    /// analytic memory number. Per-step batch inputs never enter the
    /// pool, so this is stable across [`Trainer::train_step`] calls.
    pub fn state_bytes(&self) -> usize {
        self.pool.values().map(|t| t.bytes()).sum()
    }

    /// Measured activation-cache bytes of the last step (native backend
    /// train executables report them; `None` on AOT artifacts).
    pub fn activation_bytes(&self) -> Option<u64> {
        self.metrics.act_cache_bytes
    }

    /// Measured peak live activation bytes of the last step.
    pub fn activation_peak_bytes(&self) -> Option<u64> {
        self.metrics.act_peak_bytes
    }

    /// Bytes of optimizer state only.
    pub fn opt_bytes(&self) -> usize {
        self.pool
            .iter()
            .filter(|(k, _)| k.starts_with("m.") || k.starts_with("v."))
            .map(|(_, t)| t.bytes())
            .sum()
    }

    /// Read a state tensor (tests / diagnostics).
    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.pool.get(name).ok_or_else(|| anyhow!("no tensor {name:?} in trainer pool"))
    }
}
