//! Training coordinator: drives the per-method AOT train-step executables,
//! owns optimizer state, evaluation (loss + greedy-decode accuracy) and
//! checkpoints.

mod checkpoint;
mod eval;
mod metrics;
mod replan;
mod trainer;

pub use checkpoint::{load_params, save_params};
pub use eval::{eval_loss, task_accuracy, DecodeRequest, GenModel, TokenSampler};
pub use metrics::TrainMetrics;
pub use trainer::Trainer;
