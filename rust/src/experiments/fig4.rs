//! Figure 4: which Transformer component should carry the fine-tuning
//! budget? One S²FT run per projection (Q/K/V/O/U/G/D), parameter-matched.

use anyhow::Result;

use crate::data::{finetune_examples, COMMONSENSE};
use crate::runtime::{open_backend, Executor};
use crate::train::GenModel;

use super::common::{evaluate_suite, finetune, pretrained_cached, save_result};
use crate::util::json::Json;

const MODEL: &str = "small";

pub fn run_fig4(artifacts: &str, quick: bool) -> Result<()> {
    let rt = open_backend(artifacts)?;
    let (pre_steps, ft_steps, n_eval) = if quick { (60, 30, 8) } else { (800, 150, 16) };
    let base = pretrained_cached(&rt, MODEL, pre_steps, 42)?;
    let examples = finetune_examples("commonsense", 2000, 19);

    let components = [
        ("Query", "s2ft-qonly"),
        ("Key", "s2ft-konly"),
        ("Value", "s2ft-vonly"),
        ("Output", "s2ft-oonly"),
        ("Up", "s2ft-uonly"),
        ("Gate", "s2ft-gonly"),
        ("Down", "s2ft-donly"),
    ];
    println!("\n=== Figure 4: component ablation (commonsense avg acc %) ===");
    let filter = std::env::var("REPRO_METHODS").ok();
    let mut records = Vec::new();
    for (label, tag) in components {
        if filter.as_ref().is_some_and(|f| !f.split(',').any(|x| x.trim() == tag)) {
            continue;
        }
        if rt.artifacts().model(MODEL)?.methods.get(tag).is_none() {
            println!("  (skipping {label}: {tag} not built)");
            continue;
        }
        let trainer = finetune(&rt, MODEL, tag, &base, &examples, ft_steps, 23)?;
        let model = GenModel::new(&rt, MODEL, trainer.merged_params(&rt)?)?;
        let (_, avg) = evaluate_suite(&model, &COMMONSENSE, n_eval, 0xF4)?;
        println!("{label:>8}: {avg:5.1}%   (train loss {:.3})", trainer.metrics.tail_loss(10));
        records.push(Json::obj(vec![
            ("component", Json::str(label)),
            ("avg_acc", Json::num(avg)),
            ("train_loss", Json::num(trainer.metrics.tail_loss(10) as f64)),
        ]));
    }
    println!("Expected shape (paper): Output/Down > Query/Key/Value/Up/Gate.");
    // merge chunked invocations (keyed by component)
    let mut merged: Vec<Json> = Vec::new();
    if let Ok(prev) = std::fs::read_to_string("results/fig4.json") {
        if let Ok(Json::Arr(prows)) = Json::parse(&prev) {
            for pr in prows {
                let name = pr.get("component").ok().and_then(|v| v.as_str().ok().map(String::from));
                if let Some(name) = name {
                    let dup = records.iter().any(|r: &Json| {
                        r.get("component").ok().and_then(|v| v.as_str().ok())
                            == Some(name.as_str())
                    });
                    if !dup {
                        merged.push(pr);
                    }
                }
            }
        }
    }
    merged.extend(records);
    save_result("fig4", &Json::Arr(merged));
    Ok(())
}
