//! Multi-adapter serving (paper §6.2): engine pool + dynamic batcher
//! serving requests across many S²FT adapters with adapter-affinity
//! batching, scatter_add switches and KV-cached incremental decode.
//!
//! Run: `cargo run --release --example multi_adapter_serving`
//! Env: ADAPTERS (default 6), REQUESTS (default 48), MAX_BATCH (default 8),
//!      WORKERS (default 2)

use anyhow::Result;

fn env(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let adapters = env("ADAPTERS", 6);
    let requests = env("REQUESTS", 48);
    let max_batch = env("MAX_BATCH", 8);
    let workers = env("WORKERS", 2);
    println!(
        "multi-adapter serving demo: {adapters} adapters, {requests} requests, \
         max batch {max_batch}, {workers} workers"
    );
    repro::serve::demo(repro::serve::DemoOpts {
        artifacts: "artifacts".into(),
        backend: "auto".into(),
        model: "small".into(),
        weights: None,
        adapters,
        requests,
        max_batch,
        workers,
        stream: true,
    })
}
