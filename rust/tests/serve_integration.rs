//! Serving-stack integration: router + batcher + engine over the real
//! tiny model, with live S²FT adapter switches mid-stream.

use std::collections::HashMap;
use std::time::Duration;

use repro::adapter::{AdapterStore, AnyAdapter, S2ftAdapter, S2ftLayerDelta};
use repro::runtime::{Runtime, Tensor};
use repro::serve::{Router, ServeRequest};
use repro::train::GenModel;
use repro::util::rng::Rng;

fn spawn_router(n_adapters: usize, max_batch: usize) -> Router {
    Router::spawn(max_batch, Duration::from_millis(2), move || {
        let rt = Runtime::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))?;
        let init = rt.load("init_tiny")?;
        let outs = init.run(&[Tensor::scalar_i32(3)])?;
        let params: HashMap<String, Tensor> =
            init.spec.outputs.iter().map(|s| s.name.clone()).zip(outs).collect();
        let mm = rt.artifacts.model("tiny")?;
        let (d, hd) = (mm.dims.d_model, mm.head_dim());
        let mut store = AdapterStore::new();
        let mut rng = Rng::seed(77);
        for a in 0..n_adapters {
            let layers = (0..mm.dims.n_layers)
                .map(|_| {
                    let heads = rng.choose(mm.dims.n_heads, 1);
                    let wo_rows = repro::sparsity::expand_head_perm(&heads, hd);
                    S2ftLayerDelta {
                        wo_delta: (0..wo_rows.len() * d).map(|_| rng.normal_f32() * 1e-3).collect(),
                        wo_rows,
                        wd_rows: rng.choose(mm.dims.d_ff, 2),
                        wd_delta: (0..2 * d).map(|_| rng.normal_f32() * 1e-3).collect(),
                    }
                })
                .collect();
            store.insert(format!("a{a}"), AnyAdapter::S2ft(S2ftAdapter { layers, d_model: d }));
        }
        let snapshot = params.clone();
        let gm = GenModel::new(&rt, "tiny", params)?;
        Ok((gm, store, snapshot))
    })
}

#[test]
fn router_serves_all_requests_across_adapters() {
    let router = spawn_router(3, 2);
    let mut rx = Vec::new();
    for i in 0..9 {
        rx.push(router.submit(ServeRequest {
            adapter: format!("a{}", i % 3),
            prompt: format!("q: item {i}?"),
            max_new: 3,
        }));
    }
    let mut served = 0;
    for r in rx {
        let reply = r.recv().expect("reply");
        assert!(reply.batch_size >= 1 && reply.batch_size <= 2);
        served += 1;
    }
    assert_eq!(served, 9);
    let m = router.metrics();
    assert_eq!(m.requests, 9);
    assert!(m.batches >= 5, "batcher should cap at max_batch=2: {}", m.batches);
    assert!(m.switches >= 3, "must have switched between 3 adapters");
    assert!(m.percentile_ms(0.5) > 0.0);
    router.shutdown().unwrap();
}

#[test]
fn router_base_requests_use_pristine_weights() {
    let router = spawn_router(1, 4);
    // adapter request then base request: engine must unfuse in between
    let r1 = router.call(ServeRequest {
        adapter: "a0".into(),
        prompt: "q: x?".into(),
        max_new: 2,
    }).unwrap();
    let r2 = router.call(ServeRequest {
        adapter: "base".into(),
        prompt: "q: x?".into(),
        max_new: 2,
    }).unwrap();
    // both served; determinism of each path is covered elsewhere — here we
    // assert the engine survives the fuse/unfuse round trip
    assert!(r1.text.len() <= 2 && r2.text.len() <= 2);
    let m = router.metrics();
    assert_eq!(m.requests, 2);
    router.shutdown().unwrap();
}

#[test]
fn shutdown_drains_cleanly() {
    let router = spawn_router(2, 4);
    let pending = router.submit(ServeRequest {
        adapter: "a1".into(),
        prompt: "q: last?".into(),
        max_new: 2,
    });
    router.shutdown().unwrap();
    // the queued request was served before shutdown completed
    assert!(pending.recv().is_ok());
}
