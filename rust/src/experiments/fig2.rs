//! Figure 2: memorization vs generalization of SpFT / LoRA / Full FT at
//! trainable-parameter ratios p ∈ {10%, 1%, 0.1%}.
//!
//! Protocol (App. C analogue): fine-tune the pre-trained small model on
//! the Math10K-analogue mixture, then report
//!   * final training loss (memorization),
//!   * easy-math accuracy (near-OOD: MultiArith/AddSub/SingleEq/MAWPS),
//!   * hard-math accuracy (GSM8K/AQuA/SVAMP),
//!   * commonsense accuracy (far OOD).

use anyhow::Result;

use crate::data::{finetune_examples, Difficulty, Split, Tokenizer, World, ARITHMETIC, COMMONSENSE};
use crate::runtime::{open_backend, Executor};
use crate::train::{task_accuracy, GenModel};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::common::{finetune, pretrained_cached, print_table, save_result, table_json};

const MODEL: &str = "small";

pub fn run_fig2(artifacts: &str, quick: bool) -> Result<()> {
    let rt = open_backend(artifacts)?;
    let (pre_steps, ft_steps, n_eval) = if quick { (60, 30, 8) } else { (800, 150, 12) };
    let base = pretrained_cached(&rt, MODEL, pre_steps, 42)?;
    let examples = finetune_examples("arithmetic", 2000, 7);

    let methods = [
        ("FullFT", "fullft"),
        ("SpFT p=10%", "spft-p10"),
        ("SpFT p=1%", "spft-p1"),
        ("SpFT p=.1%", "spft-p01"),
        ("LoRA p=10%", "lora-p10"),
        ("LoRA p=1%", "lora-p1"),
        ("LoRA p=.1%", "lora-p01"),
    ];

    let world = World::canonical();
    let subtasks = vec![
        "TrainLoss".to_string(),
        "EasyMath".to_string(),
        "HardMath".to_string(),
        "Commonsense".to_string(),
    ];
    let filter = std::env::var("REPRO_METHODS").ok();
    let mut rows = Vec::new();
    for (label, tag) in methods {
        if filter.as_ref().is_some_and(|f| !f.split(',').any(|x| x.trim() == tag)) {
            continue;
        }
        if rt.artifacts().model(MODEL)?.methods.get(tag).is_none() {
            println!("  (skipping {label}: artifact variant {tag} not built — `make artifacts`)");
            continue;
        }
        println!("fig2: fine-tuning {label} ({tag}) for {ft_steps} steps...");
        let trainer = finetune(&rt, MODEL, tag, &base, &examples, ft_steps, 11)?;
        let train_loss = trainer.metrics.tail_loss(10) as f64;
        let merged = trainer.merged_params(&rt)?;
        let model = GenModel::new(&rt, MODEL, merged)?;

        let acc_of = |tasks: &[&crate::data::Task]| -> Result<f64> {
            let mut sum = 0.0;
            for t in tasks {
                let mut rng = Rng::seed(0xF162 ^ t.name.len() as u64);
                let ex = t.batch(&world, &mut rng, Split::Test, n_eval);
                sum += task_accuracy(&model, &ex)? * 100.0;
            }
            Ok(sum / tasks.len() as f64)
        };
        let easy: Vec<&crate::data::Task> =
            ARITHMETIC.iter().filter(|t| t.difficulty == Difficulty::Easy).collect();
        let hard: Vec<&crate::data::Task> =
            ARITHMETIC.iter().filter(|t| t.difficulty == Difficulty::Hard).collect();
        let cs: Vec<&crate::data::Task> = COMMONSENSE.iter().collect();
        let vals = vec![train_loss, acc_of(&easy)?, acc_of(&hard)?, acc_of(&cs)?];
        let avg = (vals[1] + vals[2] + vals[3]) / 3.0;
        rows.push((label.to_string(), vals, avg));
        let _ = Tokenizer; // (tokenizer lives inside helpers)
    }
    // merge rows from earlier chunked invocations
    let mut merged: Vec<(String, Vec<f64>, f64)> = Vec::new();
    if let Ok(prev) = std::fs::read_to_string("results/fig2.json") {
        if let Ok(js) = crate::util::json::Json::parse(&prev) {
            if let Some(prows) = js.opt("rows").and_then(|r| r.as_arr().ok()) {
                for pr in prows {
                    if let (Ok(m), Ok(avg)) = (
                        pr.get("method").and_then(|v| v.as_str().map(String::from)),
                        pr.get("avg").and_then(|v| v.as_f64()),
                    ) {
                        let accs: Vec<f64> = pr
                            .get("accs")
                            .ok()
                            .and_then(|v| v.as_arr().ok())
                            .map(|a| a.iter().filter_map(|x| x.as_f64().ok()).collect())
                            .unwrap_or_default();
                        if !rows.iter().any(|(n, _, _)| *n == m) {
                            merged.push((m, accs, avg));
                        }
                    }
                }
            }
        }
    }
    merged.extend(rows);
    let order: Vec<&str> = methods.iter().map(|(l, _)| *l).collect();
    merged.sort_by_key(|(n, _, _)| order.iter().position(|o| o == n).unwrap_or(usize::MAX));
    print_table(
        "Figure 2: memorization (train loss ↓) vs generalization (acc % ↑)",
        &subtasks,
        &merged,
    );
    println!("\nExpected shape (paper): SpFT ≥ FullFT ≥ LoRA on far-OOD; loss ↑ as p ↓.");
    save_result("fig2", &table_json(&subtasks, &merged));
    Ok(())
}

// Silence unused-import lint when quick paths skip branches.
#[allow(unused)]
fn _t(_: Json) {}
