//! Batch construction: task examples / corpus text -> (tokens, targets,
//! loss_mask) tensors shaped for a given artifact batch (B, T).

use super::tasks::Example;
use super::tokenizer::{Tokenizer, BOS, EOS, PAD, SEP};
use crate::runtime::Tensor;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Tensor,
    pub targets: Tensor,
    pub loss_mask: Tensor,
}

impl Batch {
    /// Number of loss-bearing tokens.
    pub fn answer_tokens(&self) -> usize {
        self.loss_mask
            .as_f32()
            .map(|m| m.iter().filter(|&&x| x > 0.0).count())
            .unwrap_or(0)
    }
}

/// Layout of one supervised row: `BOS prompt SEP answer EOS PAD...`
/// Loss is applied only where the *target* is an answer token (or EOS),
/// i.e. supervised positions are SEP..answer_end-1 in input coordinates.
pub fn encode_example(tk: &Tokenizer, ex: &Example, t: usize) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
    let mut seq = vec![BOS];
    seq.extend(tk.encode(&ex.prompt));
    seq.push(SEP);
    let ans_start = seq.len();
    seq.extend(tk.encode(&ex.answer));
    seq.push(EOS);
    seq.truncate(t + 1);
    // inputs are seq[..-1], targets are seq[1..]
    let mut tokens: Vec<i32> = seq[..seq.len() - 1].to_vec();
    let mut targets: Vec<i32> = seq[1..].to_vec();
    let mut mask = vec![0.0f32; tokens.len()];
    for (i, m) in mask.iter_mut().enumerate() {
        // target position i supervises seq[i+1]
        if i + 1 >= ans_start {
            *m = 1.0;
        }
    }
    while tokens.len() < t {
        tokens.push(PAD);
        targets.push(PAD);
        mask.push(0.0);
    }
    (tokens, targets, mask)
}

/// Build a supervised batch from examples (padding rows repeat the last
/// example with zero loss-mask so accuracy counting is unaffected).
pub fn supervised_batch(tk: &Tokenizer, examples: &[Example], b: usize, t: usize) -> Batch {
    assert!(!examples.is_empty());
    let mut tokens = Vec::with_capacity(b * t);
    let mut targets = Vec::with_capacity(b * t);
    let mut mask = Vec::with_capacity(b * t);
    for i in 0..b {
        let (tok, tgt, m) = if i < examples.len() {
            encode_example(tk, &examples[i], t)
        } else {
            let (tok, tgt, _) = encode_example(tk, examples.last().unwrap(), t);
            (tok, tgt, vec![0.0; t])
        };
        tokens.extend(tok);
        targets.extend(tgt);
        mask.extend(m);
    }
    Batch {
        tokens: Tensor::i32(vec![b, t], tokens),
        targets: Tensor::i32(vec![b, t], targets),
        loss_mask: Tensor::f32(vec![b, t], mask),
    }
}

/// Language-model batch over corpus text: contiguous byte windows with
/// loss over every position.
pub fn lm_batch(tk: &Tokenizer, corpus: &str, rng: &mut Rng, b: usize, t: usize) -> Batch {
    let bytes = tk.encode(corpus);
    assert!(bytes.len() > t + 1, "corpus shorter than one window");
    let mut tokens = Vec::with_capacity(b * t);
    let mut targets = Vec::with_capacity(b * t);
    for _ in 0..b {
        let start = rng.below(bytes.len() - t - 1);
        tokens.extend(&bytes[start..start + t]);
        targets.extend(&bytes[start + 1..start + t + 1]);
    }
    Batch {
        tokens: Tensor::i32(vec![b, t], tokens),
        targets: Tensor::i32(vec![b, t], targets),
        loss_mask: Tensor::f32(vec![b, t], vec![1.0; b * t]),
    }
}

/// Prompt-only row for generation: `BOS prompt SEP PAD...`; returns the
/// position of the first generated token (index of SEP in inputs + 1).
pub fn encode_prompt(tk: &Tokenizer, prompt: &str, t: usize) -> (Vec<i32>, usize) {
    let mut seq = vec![BOS];
    seq.extend(tk.encode(prompt));
    seq.push(SEP);
    seq.truncate(t);
    let gen_pos = seq.len();
    let mut tokens = seq;
    while tokens.len() < t {
        tokens.push(PAD);
    }
    (tokens, gen_pos)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_example_layout() {
        let tk = Tokenizer;
        let ex = Example { prompt: "q: 1+1 =".into(), answer: "2".into() };
        let (tok, tgt, mask) = encode_example(&tk, &ex, 24);
        assert_eq!(tok.len(), 24);
        assert_eq!(tok[0], BOS);
        let sep_pos = tok.iter().position(|&t| t == SEP).unwrap();
        // the answer token '2' is the target at sep position
        assert_eq!(tgt[sep_pos], b'2' as i32);
        assert_eq!(mask[sep_pos], 1.0);
        assert_eq!(tgt[sep_pos + 1], EOS);
        assert_eq!(mask[sep_pos + 1], 1.0);
        // prompt positions carry no loss
        assert!(mask[..sep_pos].iter().all(|&m| m == 0.0));
    }

    #[test]
    fn supervised_batch_pads_rows() {
        let tk = Tokenizer;
        let ex = Example { prompt: "p".into(), answer: "a".into() };
        let b = supervised_batch(&tk, &[ex], 3, 16);
        assert_eq!(b.tokens.shape, vec![3, 16]);
        // only the real row carries loss
        let m = b.loss_mask.as_f32().unwrap();
        assert!(m[..16].iter().any(|&x| x > 0.0));
        assert!(m[16..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn lm_batch_shifts_by_one() {
        let tk = Tokenizer;
        let corpus = "abcdefghijklmnopqrstuvwxyz".repeat(4);
        let mut rng = Rng::seed(0);
        let b = lm_batch(&tk, &corpus, &mut rng, 2, 8);
        let tok = b.tokens.as_i32().unwrap();
        let tgt = b.targets.as_i32().unwrap();
        for row in 0..2 {
            for i in 0..7 {
                assert_eq!(tok[row * 8 + i + 1], tgt[row * 8 + i]);
            }
        }
    }

    #[test]
    fn encode_prompt_gen_pos() {
        let tk = Tokenizer;
        let (tok, pos) = encode_prompt(&tk, "hi", 8);
        assert_eq!(tok[0], BOS);
        assert_eq!(tok[3], SEP);
        assert_eq!(pos, 4);
        assert_eq!(tok[4], PAD);
    }
}
