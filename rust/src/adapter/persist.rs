//! Adapter persistence: compact on-disk format for S²FT adapters.
//!
//! An S²FT adapter is tiny (s·d floats + row ids per layer), so thousands
//! can live on disk next to one base checkpoint — the storage story of
//! paper §6.2. Format: little-endian binary with a JSON header.
//!
//! layout: "S2FT" magic | u32 header_len | header json | per-layer blobs
//! (wo_rows u32s, wo_delta f32s, wd_rows u32s, wd_delta f32s).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::{S2ftAdapter, S2ftLayerDelta};

const MAGIC: &[u8; 4] = b"S2FT";

pub fn save_adapter(path: impl AsRef<Path>, adapter: &S2ftAdapter) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let header = Json::obj(vec![
        ("version", Json::num(1.0)),
        ("d_model", Json::num(adapter.d_model as f64)),
        ("n_layers", Json::num(adapter.layers.len() as f64)),
        (
            "layer_shapes",
            Json::Arr(
                adapter
                    .layers
                    .iter()
                    .map(|l| {
                        Json::Arr(vec![
                            Json::num(l.wo_rows.len() as f64),
                            Json::num(l.wd_rows.len() as f64),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
    .to_string();
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(header.len() as u32).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for l in &adapter.layers {
        for &r in &l.wo_rows {
            f.write_all(&(r as u32).to_le_bytes())?;
        }
        for &v in &l.wo_delta {
            f.write_all(&v.to_le_bytes())?;
        }
        for &r in &l.wd_rows {
            f.write_all(&(r as u32).to_le_bytes())?;
        }
        for &v in &l.wd_delta {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn load_adapter(path: impl AsRef<Path>) -> Result<S2ftAdapter> {
    let mut bytes = Vec::new();
    std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?
        .read_to_end(&mut bytes)?;
    if bytes.len() < 8 || &bytes[..4] != MAGIC {
        bail!("not an S2FT adapter file");
    }
    let hlen = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
    let header = Json::parse(std::str::from_utf8(&bytes[8..8 + hlen])?)?;
    if header.num_or("version", 0.0) as u32 != 1 {
        bail!("unsupported adapter version");
    }
    let d = header.get("d_model")?.as_usize()?;
    let shapes = header.get("layer_shapes")?.as_arr()?;
    let mut off = 8 + hlen;
    let mut layers = Vec::with_capacity(shapes.len());
    let mut take_u32s = |bytes: &[u8], off: &mut usize, n: usize| -> Result<Vec<usize>> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            if *off + 4 > bytes.len() {
                bail!("truncated adapter file");
            }
            out.push(u32::from_le_bytes(bytes[*off..*off + 4].try_into().unwrap()) as usize);
            *off += 4;
        }
        Ok(out)
    };
    let take_f32s = |bytes: &[u8], off: &mut usize, n: usize| -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            if *off + 4 > bytes.len() {
                bail!("truncated adapter file");
            }
            out.push(f32::from_le_bytes(bytes[*off..*off + 4].try_into().unwrap()));
            *off += 4;
        }
        Ok(out)
    };
    for s in shapes {
        let a = s.as_arr()?;
        let (n_wo, n_wd) = (a[0].as_usize()?, a[1].as_usize()?);
        let wo_rows = take_u32s(&bytes, &mut off, n_wo)?;
        let wo_delta = take_f32s(&bytes, &mut off, n_wo * d)?;
        let wd_rows = take_u32s(&bytes, &mut off, n_wd)?;
        let wd_delta = take_f32s(&bytes, &mut off, n_wd * d)?;
        layers.push(S2ftLayerDelta { wo_rows, wo_delta, wd_rows, wd_delta });
    }
    if off != bytes.len() {
        bail!("trailing bytes in adapter file");
    }
    Ok(S2ftAdapter { layers, d_model: d })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(seed: u64) -> S2ftAdapter {
        let mut rng = Rng::seed(seed);
        let d = 16;
        let layers = (0..3)
            .map(|_| {
                let s = 1 + rng.below(3);
                let c = 1 + rng.below(4);
                S2ftLayerDelta {
                    wo_rows: rng.choose(d, s),
                    wo_delta: (0..s * d).map(|_| rng.normal_f32()).collect(),
                    wd_rows: rng.choose(24, c),
                    wd_delta: (0..c * d).map(|_| rng.normal_f32()).collect(),
                }
            })
            .collect();
        S2ftAdapter { layers, d_model: d }
    }

    #[test]
    fn roundtrip_exact() {
        let dir = std::env::temp_dir().join(format!("adapter_{}", std::process::id()));
        let path = dir.join("a.s2ft");
        let a = sample(1);
        save_adapter(&path, &a).unwrap();
        let b = load_adapter(&path).unwrap();
        assert_eq!(a.d_model, b.d_model);
        assert_eq!(a.layers.len(), b.layers.len());
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.wo_rows, y.wo_rows);
            assert_eq!(x.wo_delta, y.wo_delta);
            assert_eq!(x.wd_rows, y.wd_rows);
            assert_eq!(x.wd_delta, y.wd_delta);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("adapter_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.s2ft");
        std::fs::write(&path, b"NOPE1234").unwrap();
        assert!(load_adapter(&path).is_err());
        // truncated real file
        let a = sample(2);
        save_adapter(&path, &a).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        assert!(load_adapter(&path).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
