//! The deny-by-default lint passes behind `repro analyze`.
//!
//! Each pass consumes the token/comment streams from
//! [`super::lexer::lex`] and emits [`Finding`]s. Scoping is by path
//! relative to the package root:
//!
//! * [`FLOAT_EQ`] / [`FMA`] — non-test code in `src/kernels/` and
//!   `src/runtime/native/`: no float-literal equality (`== 0.0` /
//!   `!= 0.0`, the PR 5 zero-skip bug class) and no fused multiply-add
//!   (`mul_add`, `_mm*_fmadd_*`), both of which break the documented
//!   bit-identity-to-naive-reference contract.
//! * [`SAFETY`] — everywhere: each `unsafe` block or fn must be
//!   immediately preceded by a `// SAFETY:` comment (a rustdoc
//!   `# Safety` section above an `unsafe fn`'s attributes also counts).
//! * [`NONDET`] — non-test code in the modules documented as
//!   bit-identical (`src/kernels/`, `src/linalg/`,
//!   `src/runtime/native/decode.rs`): no wall-clock reads (`Instant`,
//!   `SystemTime`), no `thread::current()` identity, no
//!   `HashMap`/`HashSet` (iteration order is randomized per process).
//! * [`BENCH_BASELINE`] — every lane registered via `.bench("…")` in
//!   `benches/*.rs` must match an entry in the committed
//!   `benches/baseline/<target>.json` and vice versa, so no perf lane
//!   silently escapes the CI regression gate.
//! * [`PUB_DOC`] — non-test code in `src/serve/`, `src/adapter/` and
//!   `src/sparsity/`: every `pub` item (fn, struct, enum, trait, const,
//!   …) must carry a rustdoc comment, so the serving, adapter and
//!   selection-strategy APIs documented in `docs/serving.md` /
//!   `docs/training.md` cannot grow undocumented surface. `pub use`
//!   re-exports, `pub(crate)`-style restricted visibility and struct
//!   fields are exempt.

use super::lexer::{Comment, Lexed, Tok, TokKind};
use super::report::Finding;
use crate::util::json::Json;

/// Float-literal equality in bit-identical kernel code.
pub const FLOAT_EQ: &str = "float-eq";
/// Fused multiply-add in bit-identical kernel code.
pub const FMA: &str = "fma";
/// `unsafe` without an adjacent `// SAFETY:` proof.
pub const SAFETY: &str = "safety-comment";
/// Nondeterminism source in a bit-identical module.
pub const NONDET: &str = "nondet";
/// Bench lane without a committed baseline entry (or vice versa).
pub const BENCH_BASELINE: &str = "bench-baseline";
/// Undocumented `pub` item in the serving, adapter or sparsity API.
pub const PUB_DOC: &str = "pub-doc";

/// Every suppressible lint, for allow-annotation validation.
pub const KNOWN_LINTS: &[&str] = &[FLOAT_EQ, FMA, SAFETY, NONDET, BENCH_BASELINE, PUB_DOC];

const FLOAT_EQ_WHY: &str = "float-literal equality in bit-identical code \
                            (matches -0.0; compare bits or restructure)";
const FMA_WHY: &str = "fuses multiply-add rounding; kernels must round the product \
                       and the sum separately to match the naive reference";
const HASH_WHY: &str = "iteration order is randomized per process; use BTreeMap/Vec \
                        or justify a keyed-lookup-only allow";

fn float_scope(rel: &str) -> bool {
    rel.starts_with("src/kernels/") || rel.starts_with("src/runtime/native/")
}

fn pub_doc_scope(rel: &str) -> bool {
    rel.starts_with("src/serve/")
        || rel.starts_with("src/adapter/")
        || rel.starts_with("src/sparsity/")
}

fn nondet_scope(rel: &str) -> bool {
    rel.starts_with("src/kernels/")
        || rel.starts_with("src/linalg/")
        || rel == "src/runtime/native/decode.rs"
}

fn tok_is(t: Option<&Tok>, k: TokKind, s: &str) -> bool {
    t.is_some_and(|t| t.kind == k && t.text == s)
}

/// Line ranges covered by `#[cfg(test)]` / `#[test]` items, so the
/// kernel lints only police shipping code. An attribute followed by `;`
/// before any `{` (e.g. `#[cfg(test)] use …;`) spans just itself.
fn test_ranges(toks: &[Tok]) -> Vec<(usize, usize)> {
    let punct = |i: usize, s: &str| tok_is(toks.get(i), TokKind::Punct, s);
    let ident = |i: usize, s: &str| tok_is(toks.get(i), TokKind::Ident, s);
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if !(punct(i, "#") && punct(i + 1, "[")) {
            i += 1;
            continue;
        }
        let after = if ident(i + 2, "test") && punct(i + 3, "]") {
            Some(i + 4)
        } else if ident(i + 2, "cfg")
            && punct(i + 3, "(")
            && ident(i + 4, "test")
            && punct(i + 5, ")")
            && punct(i + 6, "]")
        {
            Some(i + 7)
        } else {
            None
        };
        let Some(mut j) = after else {
            i += 2;
            continue;
        };
        // skip to the item's opening brace; a `;` first means a
        // braceless item (use/decl) — cover only up to that line
        while j < toks.len() && !(punct(j, "{") || punct(j, ";")) {
            j += 1;
        }
        if j >= toks.len() || toks[j].text == ";" {
            out.push((toks[i].line, toks.get(j).map_or(toks[i].line, |t| t.line)));
            i = j;
            continue;
        }
        let start_line = toks[i].line;
        let mut depth = 0i64;
        let mut k = j;
        while k < toks.len() {
            if punct(k, "{") {
                depth += 1;
            } else if punct(k, "}") {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            k += 1;
        }
        let end = k.min(toks.len() - 1);
        out.push((start_line, toks[end].line));
        i = k + 1;
    }
    out
}

fn in_ranges(line: usize, ranges: &[(usize, usize)]) -> bool {
    ranges.iter().any(|&(s, e)| s <= line && line <= e)
}

/// Run every per-file lint pass that applies to `rel`.
pub fn lint_file(rel: &str, lx: &Lexed) -> Vec<Finding> {
    let mut out = Vec::new();
    let tests = test_ranges(&lx.tokens);
    if float_scope(rel) {
        float_eq_pass(rel, lx, &tests, &mut out);
        fma_pass(rel, lx, &tests, &mut out);
    }
    if nondet_scope(rel) {
        nondet_pass(rel, lx, &tests, &mut out);
    }
    if pub_doc_scope(rel) {
        pub_doc_pass(rel, lx, &tests, &mut out);
    }
    safety_pass(rel, lx, &mut out);
    out
}

/// Item keywords that make a `pub` token the start of a documentable
/// API item (as opposed to a struct field or a visibility qualifier).
const ITEM_KINDS: &[&str] =
    &["fn", "struct", "enum", "union", "trait", "mod", "type", "static", "use"];

/// Classify the tokens after a `pub`: `Some(kind)` for a real item,
/// `None` for struct fields (`pub name: T`). `const` is tentative so
/// `pub const fn` classifies as `fn`; `unsafe`/`async`/`extern` (and an
/// ABI string) are modifiers to scan through.
fn pub_item_kind(toks: &[Tok], i: usize) -> Option<String> {
    let mut kind: Option<String> = None;
    for t in toks.iter().skip(i + 1).take(6) {
        if t.kind == TokKind::Str {
            continue; // `extern "C" fn`
        }
        if t.kind != TokKind::Ident {
            break; // `:` of a field, `<` of a type, …
        }
        match t.text.as_str() {
            k if ITEM_KINDS.contains(&k) => {
                kind = Some(k.to_string());
                break;
            }
            "const" => kind = Some("const".to_string()),
            "unsafe" | "async" | "extern" => {}
            _ => break, // field or binding name
        }
    }
    kind
}

/// First line of the item a `pub` at token index `i` belongs to: walks
/// backward over any `#[…]` attribute groups so a doc comment above
/// `#[derive(…)]` still counts as adjacent.
fn attr_anchor_line(toks: &[Tok], mut i: usize) -> usize {
    let mut anchor = toks[i].line;
    while i > 0 {
        let mut j = i - 1;
        if !tok_is(toks.get(j), TokKind::Punct, "]") {
            break;
        }
        let mut depth = 1i64;
        while j > 0 && depth > 0 {
            j -= 1;
            if tok_is(toks.get(j), TokKind::Punct, "]") {
                depth += 1;
            } else if tok_is(toks.get(j), TokKind::Punct, "[") {
                depth -= 1;
            }
        }
        if depth != 0 || j == 0 || !tok_is(toks.get(j - 1), TokKind::Punct, "#") {
            break;
        }
        i = j - 1;
        anchor = toks[i].line;
    }
    anchor
}

/// Undocumented `pub` items in the serving API. A rustdoc comment must
/// end on the line directly above the item (attributes included) or on
/// the item's own line.
fn pub_doc_pass(rel: &str, lx: &Lexed, tests: &[(usize, usize)], out: &mut Vec<Finding>) {
    let toks = &lx.tokens;
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || t.text != "pub" || in_ranges(t.line, tests) {
            continue;
        }
        if punct_open(toks, i + 1) {
            continue; // pub(crate) / pub(super) — not public API
        }
        let Some(kind) = pub_item_kind(toks, i) else {
            continue; // struct field
        };
        if kind == "use" {
            continue; // re-export; the origin item carries the docs
        }
        let anchor = attr_anchor_line(toks, i);
        let covered = lx
            .comments
            .iter()
            .any(|cm| cm.doc && cm.end_line <= anchor && anchor - cm.end_line <= 1);
        if !covered {
            let msg = format!(
                "`pub {kind}` without a rustdoc comment — the serving/adapter/sparsity \
                 API (src/serve/, src/adapter/, src/sparsity/) is documented surface; \
                 see docs/serving.md and docs/training.md"
            );
            out.push(Finding::new(PUB_DOC, rel, t.line, msg));
        }
    }
}

/// `== 0.0` / `!= 0.0` against any float literal: the PR 5 bug class
/// (`-0.0` compares equal to `0.0`, so zero-skip fast paths silently
/// change results for signed zeros and non-finite operands).
fn float_eq_pass(rel: &str, lx: &Lexed, tests: &[(usize, usize)], out: &mut Vec<Finding>) {
    let toks = &lx.tokens;
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Punct || (t.text != "==" && t.text != "!=") {
            continue;
        }
        if in_ranges(t.line, tests) {
            continue;
        }
        let prev_float = i > 0 && toks[i - 1].kind == TokKind::Float;
        // look through unary minus and parens on the right-hand side
        let mut j = i + 1;
        while j < toks.len() && (tok_is(toks.get(j), TokKind::Punct, "-") || punct_open(toks, j)) {
            j += 1;
        }
        let next_float = toks.get(j).is_some_and(|t| t.kind == TokKind::Float);
        if prev_float || next_float {
            let lhs = i.checked_sub(1).map(|p| toks[p].text.clone()).unwrap_or_default();
            let rhs = toks.get(j).map(|p| p.text.clone()).unwrap_or_default();
            let msg = format!("`{lhs} {} {rhs}` — {FLOAT_EQ_WHY}", t.text);
            out.push(Finding::new(FLOAT_EQ, rel, t.line, msg));
        }
    }
}

fn punct_open(toks: &[Tok], j: usize) -> bool {
    tok_is(toks.get(j), TokKind::Punct, "(")
}

/// `mul_add` / `_mm*_fmadd_*` / `fmaf`: fused rounding diverges from
/// the separately-rounded naive reference.
fn fma_pass(rel: &str, lx: &Lexed, tests: &[(usize, usize)], out: &mut Vec<Finding>) {
    for t in &lx.tokens {
        if t.kind != TokKind::Ident || in_ranges(t.line, tests) {
            continue;
        }
        if t.text == "mul_add" || t.text == "fmaf" || t.text.contains("fmadd") {
            let msg = format!("`{}` {FMA_WHY}", t.text);
            out.push(Finding::new(FMA, rel, t.line, msg));
        }
    }
}

/// Wall clocks, thread identity and randomized-iteration containers in
/// modules whose outputs are asserted bit-identical across runs.
fn nondet_pass(rel: &str, lx: &Lexed, tests: &[(usize, usize)], out: &mut Vec<Finding>) {
    let toks = &lx.tokens;
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || in_ranges(t.line, tests) {
            continue;
        }
        let name = t.text.as_str();
        let thread_current = name == "thread"
            && tok_is(toks.get(i + 1), TokKind::Punct, "::")
            && tok_is(toks.get(i + 2), TokKind::Ident, "current");
        let msg = if thread_current {
            Some("`thread::current()` identity is nondeterministic across runs".to_string())
        } else if name == "HashMap" || name == "HashSet" {
            Some(format!("`{name}` {HASH_WHY}"))
        } else if name == "Instant" || name == "SystemTime" {
            Some(format!("wall-clock source `{name}` in a bit-identical module"))
        } else {
            None
        };
        if let Some(message) = msg {
            out.push(Finding::new(NONDET, rel, t.line, message));
        }
    }
}

/// Every `unsafe` token needs a `// SAFETY:` comment ending within the
/// 3 lines above it (same line allowed), or — for `unsafe fn` whose doc
/// block sits above `#[target_feature]`-style attributes — a rustdoc
/// `# Safety` section ending within 10 lines above.
fn safety_pass(rel: &str, lx: &Lexed, out: &mut Vec<Finding>) {
    for t in &lx.tokens {
        if t.kind != TokKind::Ident || t.text != "unsafe" {
            continue;
        }
        let covered = lx.comments.iter().any(|cm| covers_unsafe(cm, t.line));
        if !covered {
            let msg = "`unsafe` without an adjacent `// SAFETY:` comment".to_string();
            out.push(Finding::new(SAFETY, rel, t.line, msg));
        }
    }
}

fn covers_unsafe(cm: &Comment, unsafe_line: usize) -> bool {
    if cm.end_line > unsafe_line {
        return false;
    }
    let gap = unsafe_line - cm.end_line;
    if cm.text.contains("SAFETY:") && gap <= 3 {
        return true;
    }
    cm.doc && cm.text.contains("# Safety") && gap <= 10
}

// ---------------------------------------------------------------------------
// bench-baseline
// ---------------------------------------------------------------------------

/// Lane-name patterns registered by a bench target: each `.bench("…")`
/// call site, with `format!` placeholders widened to `*` globs.
/// Returns `(patterns, findings)` — a call whose lane name is not a
/// literal within reach is itself a finding (it could never be checked
/// against the baseline).
pub fn bench_patterns(rel: &str, lx: &Lexed) -> (Vec<(String, usize)>, Vec<Finding>) {
    let toks = &lx.tokens;
    let mut pats = Vec::new();
    let mut bad = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || t.text != "bench" {
            continue;
        }
        if i == 0 || toks[i - 1].text != "." || !punct_open(toks, i + 1) {
            continue;
        }
        // the lane name is the first string literal in the argument
        // head: covers `.bench("x", …)` and `.bench(&format!("x{y}"), …)`
        let hi = toks.len().min(i + 8);
        let lit = toks[i + 2..hi].iter().find(|t| t.kind == TokKind::Str);
        match lit {
            Some(s) => pats.push((lane_pattern(&s.text), s.line)),
            None => {
                let msg = "lane name is not a string literal; the baseline cannot be checked";
                bad.push(Finding::new(BENCH_BASELINE, rel, t.line, msg.to_string()));
            }
        }
    }
    (pats, bad)
}

/// Convert a `format!` template to a glob: `{…}` placeholders become
/// `*`, `{{`/`}}` become literal braces.
fn lane_pattern(fmt: &str) -> String {
    let b: Vec<char> = fmt.chars().collect();
    let mut out = String::new();
    let mut i = 0usize;
    while i < b.len() {
        match b[i] {
            '{' if b.get(i + 1) == Some(&'{') => {
                out.push('{');
                i += 2;
            }
            '}' if b.get(i + 1) == Some(&'}') => {
                out.push('}');
                i += 2;
            }
            '{' => {
                while i < b.len() && b[i] != '}' {
                    i += 1;
                }
                i += 1;
                out.push('*');
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    out
}

/// `/`-segmented glob match: segments must pair up exactly, `*` within
/// a segment matches any run of characters.
fn glob_match(pat: &str, name: &str) -> bool {
    let ps: Vec<&str> = pat.split('/').collect();
    let ns: Vec<&str> = name.split('/').collect();
    ps.len() == ns.len() && ps.iter().zip(&ns).all(|(p, n)| seg_match(p, n))
}

fn seg_match(pat: &str, s: &str) -> bool {
    let p: Vec<char> = pat.chars().collect();
    let t: Vec<char> = s.chars().collect();
    let (mut pi, mut ti) = (0usize, 0usize);
    let (mut star, mut mark) = (usize::MAX, 0usize);
    while ti < t.len() {
        if pi < p.len() && p[pi] == '*' {
            star = pi;
            mark = ti;
            pi += 1;
        } else if pi < p.len() && p[pi] == t[ti] {
            pi += 1;
            ti += 1;
        } else if star != usize::MAX {
            pi = star + 1;
            mark += 1;
            ti = mark;
        } else {
            return false;
        }
    }
    while pi < p.len() && p[pi] == '*' {
        pi += 1;
    }
    pi == p.len()
}

/// Cross-check a bench target's registered lane patterns against its
/// committed baseline. `baseline` is `None` when
/// `benches/baseline/<stem>.json` does not exist.
pub fn check_bench_lanes(
    bench_rel: &str,
    stem: &str,
    patterns: &[(String, usize)],
    baseline: Option<&Json>,
    json_rel: &str,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let Some(json) = baseline else {
        let line = patterns.first().map_or(1, |p| p.1);
        let n_lanes = patterns.len();
        let msg = format!("registers {n_lanes} lane(s) but {json_rel} is missing; gate or allow");
        out.push(Finding::new(BENCH_BASELINE, bench_rel, line, msg));
        return out;
    };
    if json.opt("skipped").is_some() {
        let msg = format!("baseline for `{stem}` is a skip record; regenerate from a real run");
        out.push(Finding::new(BENCH_BASELINE, json_rel, 1, msg));
        return out;
    }
    let entries = match json.as_arr() {
        Ok(a) => a,
        Err(err) => {
            let msg = format!("malformed baseline: {err}");
            out.push(Finding::new(BENCH_BASELINE, json_rel, 1, msg));
            return out;
        }
    };
    let mut names = Vec::new();
    for e in entries {
        match e.get("name").and_then(|v| v.as_str().map(str::to_string)) {
            Ok(name) => names.push(name),
            Err(err) => {
                let msg = format!("malformed baseline entry: {err}");
                out.push(Finding::new(BENCH_BASELINE, json_rel, 1, msg));
                return out;
            }
        }
    }
    for (pat, line) in patterns {
        if !names.iter().any(|n| glob_match(pat, n)) {
            let msg = format!("lane `{pat}` has no entry in {json_rel}; refresh the baseline");
            out.push(Finding::new(BENCH_BASELINE, bench_rel, *line, msg));
        }
    }
    for name in &names {
        if !patterns.iter().any(|(pat, _)| glob_match(pat, name)) {
            let msg = format!("baseline entry `{name}` matches no lane registered in {bench_rel}");
            out.push(Finding::new(BENCH_BASELINE, json_rel, 1, msg));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyze::lexer::lex;

    fn findings(rel: &str, src: &str) -> Vec<Finding> {
        lint_file(rel, &lex(src))
    }

    fn lints(rel: &str, src: &str) -> Vec<String> {
        findings(rel, src).into_iter().map(|f| f.lint).collect()
    }

    // -- float-eq -----------------------------------------------------------

    #[test]
    fn float_eq_catches_the_pr5_zero_skip() {
        // the exact bug class PR 5 removed: a zero-skip fast path inside
        // a kernel loop
        let src = "pub fn dot(a: &[f32], b: &[f32]) -> f32 {\n\
                   let mut s = 0.0f32;\n\
                   for (i, &av) in a.iter().enumerate() {\n\
                   if av == 0.0 { continue; }\n\
                   s += av * b[i];\n\
                   }\n\
                   s\n\
                   }\n";
        let f = findings("src/kernels/gemm.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].lint, FLOAT_EQ);
        assert_eq!(f[0].line, 4);
        assert!(f[0].message.contains("av == 0.0"), "{}", f[0].message);
    }

    #[test]
    fn float_eq_catches_reversed_negated_and_ne_forms() {
        let cases = ["0.0 == x", "x != 0.0", "x == -0.0", "x == (0.0)", "x != 1.5e3"];
        for expr in cases {
            let src = format!("pub fn f(x: f32) -> bool {{ {expr} }}\n");
            let got = lints("src/runtime/native/model.rs", &src);
            assert_eq!(got, vec![FLOAT_EQ], "{expr}");
        }
    }

    #[test]
    fn float_eq_ignores_tests_comments_strings_and_other_modules() {
        // inside #[cfg(test)]
        let test_mod = "#[cfg(test)]\nmod tests {\n fn f(x: f32) -> bool { x == 0.0 }\n}\n";
        assert!(lints("src/kernels/gemm.rs", test_mod).is_empty());
        // in a comment or string
        let commented = "// old code: x == 0.0\nconst S: &str = \"x == 0.0\";\n";
        assert!(lints("src/kernels/gemm.rs", commented).is_empty());
        // out of scope
        let live = "pub fn f(x: f32) -> bool { x == 0.0 }\n";
        assert!(lints("src/util/json.rs", live).is_empty());
        // int comparisons and bit comparisons stay legal
        let ok = "pub fn f(x: f32, n: usize) -> bool { n == 0 && x.to_bits() == 0 }\n";
        assert!(lints("src/kernels/gemm.rs", ok).is_empty());
    }

    // -- fma ----------------------------------------------------------------

    #[test]
    fn fma_catches_mul_add_and_intrinsics() {
        let src = "pub fn f(a: f32, b: f32, c: f32) -> f32 { a.mul_add(b, c) }\n";
        assert_eq!(lints("src/kernels/micro.rs", src), vec![FMA]);
        let simd = "unsafe fn t() { let v = _mm256_fmadd_ps(a, b, c); }\n";
        let got = lints("src/kernels/micro.rs", simd);
        // the fixture's unsafe also lacks a SAFETY comment
        assert!(got.contains(&FMA.to_string()), "{got:?}");
    }

    // -- safety-comment -----------------------------------------------------

    #[test]
    fn safety_requires_adjacent_comment() {
        let bad = "pub fn f(p: *const f32) -> f32 { unsafe { *p } }\n";
        let f = findings("src/kernels/pack.rs", bad);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].lint, SAFETY);

        let good = "pub fn f(p: *const f32) -> f32 {\n\
                    // SAFETY: caller guarantees p is valid\n\
                    unsafe { *p }\n\
                    }\n";
        assert!(findings("src/kernels/pack.rs", good).is_empty());

        // doc `# Safety` above attributes covers an unsafe fn
        let doc = "/// # Safety\n\
                   /// caller must prove avx2\n\
                   #[target_feature(enable = \"avx2\")]\n\
                   unsafe fn t() {}\n";
        assert!(findings("src/kernels/micro.rs", doc).is_empty());

        // a SAFETY comment too far above does not count
        let far = "// SAFETY: stale\n\nfn pad() {}\n\nfn pad2() {}\n\n\
                   pub fn f(p: *const f32) -> f32 { unsafe { *p } }\n";
        assert_eq!(lints("src/kernels/pack.rs", far), vec![SAFETY]);
    }

    // -- nondet -------------------------------------------------------------

    #[test]
    fn nondet_catches_clocks_maps_and_thread_identity() {
        let cases = [
            ("use std::time::Instant;\n", "Instant"),
            ("use std::time::SystemTime;\n", "SystemTime"),
            ("use std::collections::HashMap;\n", "HashMap"),
            ("fn f() { let s = std::collections::HashSet::new(); }\n", "HashSet"),
            ("fn f() { let id = std::thread::current().id(); }\n", "current"),
        ];
        for (src, what) in cases {
            assert_eq!(lints("src/kernels/gemm.rs", src), vec![NONDET], "{what}");
        }
        // `thread::spawn` is fine — only `current` is identity
        let spawn = "fn f() { std::thread::spawn(|| {}); }\n";
        assert!(lints("src/kernels/gemm.rs", spawn).is_empty());
        // decode.rs is in scope, the rest of runtime/native is not
        let map = "use std::collections::HashMap;\n";
        assert_eq!(lints("src/runtime/native/decode.rs", map), vec![NONDET]);
        assert!(lints("src/runtime/native/model.rs", map).is_empty());
    }

    // -- pub-doc ------------------------------------------------------------

    #[test]
    fn pub_doc_requires_rustdoc_in_serve() {
        let bad = "pub fn serve() {}\n";
        assert_eq!(lints("src/serve/engine.rs", bad), vec![PUB_DOC]);
        // the adapter API is documented surface too
        assert_eq!(lints("src/adapter/store.rs", bad), vec![PUB_DOC]);
        // the same source is fine outside src/serve/ and src/adapter/
        assert!(lints("src/train/eval.rs", bad).is_empty());
        let good = "/// Serves forever.\npub fn serve() {}\n";
        assert!(findings("src/serve/engine.rs", good).is_empty());
        // plain `//` comments are not rustdoc
        let plain = "// serves forever\npub fn serve() {}\n";
        assert_eq!(lints("src/serve/engine.rs", plain), vec![PUB_DOC]);
    }

    #[test]
    fn pub_doc_sees_through_attributes() {
        let derived = "/// A gauge.\n\
                       #[derive(Debug, Clone, Copy, Default)]\n\
                       pub struct G {\n    pub x: usize,\n}\n";
        // the struct doc covers through the derive; the bare pub field
        // is a field, not an item, so it is exempt
        assert!(findings("src/serve/metrics.rs", derived).is_empty());
        let bare = "#[derive(Debug)]\npub struct G;\n";
        let f = findings("src/serve/metrics.rs", bare);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].lint, PUB_DOC);
        assert_eq!(f[0].line, 2);
        assert!(f[0].message.contains("pub struct"), "{}", f[0].message);
    }

    #[test]
    fn pub_doc_skips_reexports_restricted_visibility_and_tests() {
        let skip = "/// Module docs live on the origin items.\n\
                    pub use engine::Engine;\n\
                    pub(crate) fn helper() {}\n\
                    pub(super) struct S;\n\
                    #[cfg(test)]\nmod tests {\n    pub fn fixture() {}\n}\n";
        assert!(findings("src/serve/mod.rs", skip).is_empty());
    }

    #[test]
    fn pub_doc_classifies_const_items_and_const_fns() {
        let item = "pub const BLOCK: usize = 16;\n";
        let f = findings("src/serve/kvpool.rs", item);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("pub const"), "{}", f[0].message);
        let cfn = "pub const fn block() -> usize { 16 }\n";
        let f = findings("src/serve/kvpool.rs", cfn);
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("pub fn"), "{}", f[0].message);
        let modified = "/// ABI shim.\npub unsafe extern \"C\" fn shim() {}\n";
        // documented, and the unsafe carries a doc (not a SAFETY comment,
        // so the safety lint still fires — filter to pub-doc here)
        let pd =
            findings("src/serve/kvpool.rs", modified).iter().filter(|f| f.lint == PUB_DOC).count();
        assert_eq!(pd, 0);
    }

    // -- test-region detection ----------------------------------------------

    #[test]
    fn cfg_test_use_without_braces_spans_one_line() {
        // `#[cfg(test)] use …;` must not swallow the rest of the file
        let src = "#[cfg(test)]\nuse crate::oracle;\n\
                   pub fn f(x: f32) -> bool { x == 0.0 }\n";
        assert_eq!(lints("src/kernels/gemm.rs", src), vec![FLOAT_EQ]);
    }

    // -- bench-baseline -----------------------------------------------------

    fn arr(names: &[&str]) -> Json {
        let mut rows = Vec::new();
        for n in names {
            rows.push(Json::obj(vec![("name", Json::str(*n)), ("median_ns", Json::num(1.0))]));
        }
        Json::Arr(rows)
    }

    fn check(pats: &[(String, usize)], baseline: Option<&Json>) -> Vec<Finding> {
        check_bench_lanes("benches/k.rs", "k", pats, baseline, "benches/baseline/k.json")
    }

    #[test]
    fn bench_patterns_read_literals_and_format_templates() {
        let src = "fn main() {\n\
                   let mut s = BenchSuite::new(\"kernels\");\n\
                   s.bench(\"gemm_naive/tiny\", || {});\n\
                   for t in [1, 4] {\n\
                   s.bench(&format!(\"gemm/{name}/threads={t}\"), || {});\n\
                   }\n\
                   }\n";
        let (pats, bad) = bench_patterns("benches/kernels.rs", &lex(src));
        assert!(bad.is_empty(), "{bad:?}");
        let names: Vec<&str> = pats.iter().map(|(p, _)| p.as_str()).collect();
        assert_eq!(names, vec!["gemm_naive/tiny", "gemm/*/threads=*"]);
    }

    #[test]
    fn bench_lanes_match_both_directions() {
        let pats = vec![("gemm/*/threads=*".to_string(), 5), ("attn/base".to_string(), 9)];
        let ok = arr(&["gemm/tiny/threads=1", "gemm/base/threads=4", "attn/base"]);
        let f = check(&pats, Some(&ok));
        assert!(f.is_empty(), "{f:?}");

        // an orphan baseline entry is a finding…
        let extra = arr(&["gemm/tiny/threads=1", "attn/base", "gemv/acc"]);
        let f = check(&pats, Some(&extra));
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("gemv/acc"), "{}", f[0].message);

        // …and so is a lane with no baseline entry
        let missing = arr(&["attn/base"]);
        let f = check(&pats, Some(&missing));
        assert_eq!(f.len(), 1, "{f:?}");
        assert!(f[0].message.contains("gemm/*/threads=*"), "{}", f[0].message);

        // a missing baseline file flags the bench target itself
        let f = check(&pats, None);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].path, "benches/k.rs");
        assert_eq!(f[0].line, 5);
    }

    #[test]
    fn skip_record_baselines_are_findings() {
        let skip = Json::obj(vec![("suite", Json::str("k")), ("skipped", Json::str("no env"))]);
        let pats = vec![("x/y".to_string(), 3)];
        let f = check(&pats, Some(&skip));
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("skip record"), "{}", f[0].message);
    }

    #[test]
    fn glob_segments_must_pair_exactly() {
        assert!(glob_match("gemm/*", "gemm/tiny"));
        assert!(!glob_match("gemm/*", "gemm/tiny/threads=1"));
        assert!(glob_match("a/*/c=*", "a/b/c=12"));
        assert!(!glob_match("a/*/c=*", "a/b/d=12"));
        assert!(glob_match("lit", "lit"));
        assert!(!glob_match("lit", "li"));
        assert_eq!(lane_pattern("a{x}/b{{c}}/{y}"), "a*/b{c}/*");
    }
}
