"""L2: LLaMA-style transformer + every fine-tuning method in the paper.

Build-time JAX only — this module is lowered by ``aot.py`` to HLO text and
never imported at runtime. The rust coordinator sees, per (model, method):

  prepare : (base params..., seed, calib tokens/targets/mask)
            -> (trainable..., frozen..., perms...)
  train   : (trainable..., frozen..., m..., v..., step, tokens, targets,
             loss_mask, aux...) -> (new trainable..., new m..., new v..., loss)
  merge   : (trainable..., frozen..., perms...) -> (base params...)
  forward : (base params..., tokens) -> logits          [shared, base layout]
  init    : (seed,) -> (base params...)                  [random init]

All dict-of-arrays interfaces are flattened in sorted-key order; meta.json
(written by aot.py) records names/shapes/dtypes so rust is self-describing.

Methods: fullft, lora, dora, spft (unstructured masked deltas), lisa
(per-step layer freezing), galore (low-rank gradient projection + projected
optimizer state), s2ft (the paper: trainable-first co-permutation + partial
back-propagation; optional Pallas hot path).
"""

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig, MethodConfig
from . import selection as sel
from .kernels.partial_update import s2ft_col_linear, s2ft_linear_nd, s2ft_row_linear

Params = Dict[str, jnp.ndarray]

# Projections whose trainable slice is a row block (axis 0) vs column block.
ROW_SPLIT = ("wo", "wd")
MHA_PROJS = ("wq", "wk", "wv", "wo")
FFN_PROJS = ("wu", "wg", "wd")


# --------------------------------------------------------------------------
# Base model
# --------------------------------------------------------------------------


def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    """Ordered (sorted-key) base parameter layout."""
    d, k, v = cfg.d_model, cfg.d_ff, cfg.vocab
    shapes: Dict[str, Tuple[int, ...]] = {"embed": (v, d), "norm_f": (d,)}
    for i in range(cfg.n_layers):
        shapes[f"L{i}.wq"] = (d, d)
        shapes[f"L{i}.wk"] = (d, d)
        shapes[f"L{i}.wv"] = (d, d)
        shapes[f"L{i}.wo"] = (d, d)
        shapes[f"L{i}.wu"] = (d, k)
        shapes[f"L{i}.wg"] = (d, k)
        shapes[f"L{i}.wd"] = (k, d)
        shapes[f"L{i}.norm1"] = (d,)
        shapes[f"L{i}.norm2"] = (d,)
    return dict(sorted(shapes.items()))


def init_params(cfg: ModelConfig, key) -> Params:
    """Scaled-gaussian init (GPT-2 style; residual projections down-scaled)."""
    shapes = param_shapes(cfg)
    params: Params = {}
    keys = jax.random.split(key, len(shapes))
    resid_scale = 1.0 / np.sqrt(2 * cfg.n_layers)
    for (name, shape), k in zip(shapes.items(), keys):
        if name.endswith(("norm1", "norm2", "norm_f")):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            std = 0.02
            if name.endswith(("wo", "wd")):
                std *= resid_scale
            params[name] = std * jax.random.normal(k, shape, jnp.float32)
    return params


def rms_norm(x, g, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return g * x * jax.lax.rsqrt(var + eps)


def rope_tables(cfg: ModelConfig, t: int):
    hd = cfg.head_dim
    pos = np.arange(t)[:, None]
    freqs = cfg.rope_theta ** (-np.arange(0, hd, 2) / hd)[None, :]
    ang = pos * freqs  # (T, hd/2)
    return jnp.asarray(np.cos(ang), jnp.float32), jnp.asarray(np.sin(ang), jnp.float32)


def apply_rope(x, cos, sin):
    """x: (B, T, h, hd) — rotate (even, odd) pairs."""
    x1, x2 = x[..., 0::2], x[..., 1::2]
    c, s = cos[None, :, None, :], sin[None, :, None, :]
    r1 = x1 * c - x2 * s
    r2 = x1 * s + x2 * c
    return jnp.stack([r1, r2], axis=-1).reshape(x.shape)


def _attention(cfg: ModelConfig, q, k, v):
    """q/k/v: (B, T, d) -> (B, T, d), causal with RoPE."""
    B, T, d = q.shape
    h, hd = cfg.n_heads, cfg.head_dim
    cos, sin = rope_tables(cfg, T)
    q = apply_rope(q.reshape(B, T, h, hd), cos, sin)
    k = apply_rope(k.reshape(B, T, h, hd), cos, sin)
    v = v.reshape(B, T, h, hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    mask = np.tril(np.ones((T, T), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out.reshape(B, T, d)


def forward_intermediates(cfg: ModelConfig, linear, weights: Params, tokens):
    """Shared forward skeleton.

    ``linear(name, x)`` resolves a projection application — this is the
    method-injection point (lora path, s2ft concat/pallas, plain matmul).
    ``weights`` only needs embed/norm tensors. Returns logits plus the
    coupled-structure intermediate activations used by selection A/S/G.
    """
    inter: Dict[str, jnp.ndarray] = {}
    h = weights["embed"][tokens]
    for i in range(cfg.n_layers):
        x = rms_norm(h, weights[f"L{i}.norm1"], cfg.norm_eps)
        q = linear(f"L{i}.wq", x)
        k = linear(f"L{i}.wk", x)
        v = linear(f"L{i}.wv", x)
        a = _attention(cfg, q, k, v)
        inter[f"L{i}.mha_act"] = a
        h = h + linear(f"L{i}.wo", a)
        x = rms_norm(h, weights[f"L{i}.norm2"], cfg.norm_eps)
        u = linear(f"L{i}.wu", x)
        g = linear(f"L{i}.wg", x)
        act = u * jax.nn.silu(g)
        inter[f"L{i}.ffn_act"] = act
        h = h + linear(f"L{i}.wd", act)
    h = rms_norm(h, weights["norm_f"], cfg.norm_eps)
    logits = h @ weights["embed"].T
    return logits, inter


def forward_base(cfg: ModelConfig, weights: Params, tokens):
    """Forward in base layout (serving path after adapter merge)."""
    linear = lambda name, x: x @ weights[name]
    return forward_intermediates(cfg, linear, weights, tokens)[0]


def ce_loss(logits, targets, loss_mask):
    """Masked next-token cross entropy (mean over unmasked positions)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -(ll * loss_mask).sum() / jnp.maximum(loss_mask.sum(), 1.0)


# --------------------------------------------------------------------------
# Method layouts: which tensors are trainable / frozen / perms / aux
# --------------------------------------------------------------------------


def s2ft_counts(cfg: ModelConfig, m: MethodConfig) -> Dict[str, int]:
    counts = sel.budget_to_counts(m.s2ft_fractions, cfg.d_ff, cfg.n_heads)
    heads = {c for p, c in counts.items() if p in MHA_PROJS and c > 0}
    chans = {c for p, c in counts.items() if p in FFN_PROJS and c > 0}
    if len(heads) > 1 or len(chans) > 1:
        raise ValueError("budgets must agree within a coupled structure")
    return {p: c for p, c in counts.items() if c > 0}


def method_layout(cfg: ModelConfig, m: MethodConfig):
    """Return (trainable, frozen, perm, aux) shape dicts for a method."""
    base = param_shapes(cfg)
    hd = cfg.head_dim
    trn: Dict[str, tuple] = {}
    frz: Dict[str, tuple] = {}
    perms: Dict[str, tuple] = {}
    aux: Dict[str, tuple] = {}
    if m.method in ("fullft", "lisa", "galore"):
        trn = dict(base)
        if m.method == "lisa":
            aux["layer_mask"] = (cfg.n_layers + 1,)
        if m.method == "galore":
            aux["proj_seed"] = ()
    elif m.method in ("lora", "dora"):
        frz = dict(base)
        for i in range(cfg.n_layers):
            for p in m.lora_targets:
                din, dout = base[f"L{i}.{p}"]
                trn[f"L{i}.{p}.a"] = (din, m.rank)
                trn[f"L{i}.{p}.b"] = (m.rank, dout)
                if m.method == "dora":
                    trn[f"L{i}.{p}.m"] = (dout,)
    elif m.method == "spft":
        frz = dict(base)
        for i in range(cfg.n_layers):
            for p in MHA_PROJS + FFN_PROJS:
                shape = base[f"L{i}.{p}"]
                trn[f"L{i}.{p}.delta"] = shape
                frz[f"L{i}.{p}.mask"] = shape
    elif m.method == "s2ft":
        frz = dict(base)
        counts = s2ft_counts(cfg, m)
        for i in range(cfg.n_layers):
            for p, c in counts.items():
                del frz[f"L{i}.{p}"]
                din, dout = base[f"L{i}.{p}"]
                rows = c * hd if p in MHA_PROJS else c
                if p in ROW_SPLIT:
                    trn[f"L{i}.{p}_t"] = (rows, dout)
                    frz[f"L{i}.{p}_f"] = (din - rows, dout)
                else:
                    trn[f"L{i}.{p}_t"] = (din, rows)
                    frz[f"L{i}.{p}_f"] = (din, dout - rows)
            if any(p in counts for p in MHA_PROJS):
                perms[f"L{i}.head_perm"] = (cfg.n_heads,)
            if any(p in counts for p in FFN_PROJS):
                perms[f"L{i}.chan_perm"] = (cfg.d_ff,)
    else:
        raise ValueError(f"unknown method {m.method!r}")
    return (
        dict(sorted(trn.items())),
        dict(sorted(frz.items())),
        dict(sorted(perms.items())),
        dict(sorted(aux.items())),
    )


# --------------------------------------------------------------------------
# Method forward
# --------------------------------------------------------------------------


def make_linear(cfg: ModelConfig, m: MethodConfig, trainable: Params, frozen: Params):
    """Build the ``linear(name, x)`` resolver for a method."""
    scale = m.lora_alpha / m.rank

    def linear(name, x):
        if m.method in ("fullft", "lisa", "galore"):
            return x @ trainable[name]
        if m.method in ("lora", "dora"):
            w = frozen[name]
            if f"{name}.a" not in trainable:
                return x @ w
            a, b = trainable[f"{name}.a"], trainable[f"{name}.b"]
            if m.method == "lora":
                return x @ w + scale * ((x @ a) @ b)
            w_eff = w + scale * (a @ b)
            col_norm = jnp.linalg.norm(w_eff, axis=0, keepdims=True)
            w_eff = trainable[f"{name}.m"][None, :] * w_eff / (col_norm + 1e-6)
            return x @ w_eff
        if m.method == "spft":
            w = frozen[name]
            if f"{name}.delta" in trainable:
                w = w + frozen[f"{name}.mask"] * trainable[f"{name}.delta"]
            return x @ w
        if m.method == "s2ft":
            if f"{name}_t" not in trainable:
                return x @ frozen[name]
            wt, wf = trainable[f"{name}_t"], frozen[f"{name}_f"]
            proj = name.split(".")[-1]
            # Partial back-propagation (paper §3.3): the custom VJPs slice
            # the activation/cotangent BEFORE the dW GEMM so the weight
            # gradient covers only the trainable block. Plain concat would
            # make XLA compute the full dW and slice afterwards.
            if proj in ROW_SPLIT:
                if m.use_pallas:
                    return s2ft_linear_nd(x, wt, wf)
                return s2ft_row_linear(x, wt, wf)
            return s2ft_col_linear(x, wt, wf)
        raise ValueError(m.method)

    return linear


def forward_method(cfg: ModelConfig, m: MethodConfig, trainable, frozen, tokens):
    getw = {**frozen, **trainable}  # embed / norms resolve from either
    linear = make_linear(cfg, m, trainable, frozen)
    return forward_intermediates(cfg, linear, getw, tokens)[0]


# --------------------------------------------------------------------------
# Prepare: base layout -> method layout (its own AOT executable)
# --------------------------------------------------------------------------


def prepare_method(cfg: ModelConfig, m: MethodConfig, base: Params, seed,
                   calib_tokens, calib_targets, calib_mask):
    """Split base params into (trainable, frozen, perms) for a method.

    ``seed`` is a scalar int32 (random selection / masks / lora init);
    calibration inputs drive selection strategies A/S/G and are DCE'd
    otherwise.
    """
    key = jax.random.PRNGKey(0)
    key = jax.random.fold_in(key, jnp.asarray(seed, jnp.uint32))
    trn: Params = {}
    frz: Params = {}
    perms: Params = {}
    if m.method in ("fullft", "lisa", "galore"):
        trn = dict(base)
    elif m.method in ("lora", "dora"):
        frz = dict(base)
        ks = jax.random.split(key, cfg.n_layers * len(m.lora_targets))
        idx = 0
        for i in range(cfg.n_layers):
            for p in m.lora_targets:
                din, dout = base[f"L{i}.{p}"].shape
                trn[f"L{i}.{p}.a"] = 0.02 * jax.random.normal(ks[idx], (din, m.rank))
                trn[f"L{i}.{p}.b"] = jnp.zeros((m.rank, dout), jnp.float32)
                if m.method == "dora":
                    trn[f"L{i}.{p}.m"] = jnp.linalg.norm(base[f"L{i}.{p}"], axis=0)
                idx += 1
    elif m.method == "spft":
        frz = dict(base)
        names = [f"L{i}.{p}" for i in range(cfg.n_layers) for p in MHA_PROJS + FFN_PROJS]
        ks = jax.random.split(key, len(names))
        for name, k in zip(names, ks):
            shape = base[name].shape
            frz[f"{name}.mask"] = jax.random.bernoulli(k, m.spft_ratio, shape).astype(
                jnp.float32
            )
            trn[f"{name}.delta"] = jnp.zeros(shape, jnp.float32)
    elif m.method == "s2ft":
        frz = dict(base)
        counts = s2ft_counts(cfg, m)
        mha_count = next((c for p, c in counts.items() if p in MHA_PROJS), 0)
        ffn_count = next((c for p, c in counts.items() if p in FFN_PROJS), 0)
        inter: Dict[str, jnp.ndarray] = {}
        grads: Params = {}
        if m.selection in ("a", "s"):
            linear = lambda name, x: x @ base[name]
            _, inter = forward_intermediates(cfg, linear, base, calib_tokens)
        if m.selection == "g":
            gnames = [f"L{i}.{p}" for i in range(cfg.n_layers) for p in ("wo", "wd")]

            def loss_of(sub: Params):
                w = {**base, **sub}
                linear = lambda name, x: x @ w[name]
                logits, _ = forward_intermediates(cfg, linear, w, calib_tokens)
                return ce_loss(logits, calib_targets, calib_mask)

            grads = jax.grad(loss_of)({n: base[n] for n in gnames})
        ks = jax.random.split(key, cfg.n_layers * 2)
        for i in range(cfg.n_layers):
            head_perm = chan_perm = None
            if mha_count > 0:
                head_perm = _select_perm_mha(cfg, m, base, i, mha_count, inter, grads,
                                             ks[2 * i])
                perms[f"L{i}.head_perm"] = head_perm
            if ffn_count > 0:
                chan_perm = _select_perm_ffn(cfg, m, base, i, ffn_count, inter, grads,
                                             ks[2 * i + 1])
                perms[f"L{i}.chan_perm"] = chan_perm
            _split_layer(cfg, m, base, i, counts, head_perm, chan_perm, trn, frz)
    else:
        raise ValueError(m.method)
    return (
        dict(sorted(trn.items())),
        dict(sorted(frz.items())),
        dict(sorted(perms.items())),
    )


def _select_perm_mha(cfg, m, base, i, count, inter, grads, key):
    n_heads = cfg.n_heads
    if m.selection == "r":
        idx = jnp.sort(jax.random.permutation(key, n_heads)[:count])
    else:
        if m.selection == "w":
            score = sel.weight_score_heads(base[f"L{i}.wo"], n_heads)
        elif m.selection in ("a", "s"):
            score = sel.head_score_from_channels(
                sel.activation_score(inter[f"L{i}.mha_act"]), n_heads
            )
            if m.selection == "s":
                score = score * sel.weight_score_heads(base[f"L{i}.wo"], n_heads)
        else:  # g
            score = sel.head_score_from_channels(
                sel.gradient_score(grads[f"L{i}.wo"], axis=0), n_heads
            )
        idx = sel.topk_indices(score, count, m.select_small)
    rest = _complement(idx, n_heads)
    return jnp.concatenate([idx, rest]).astype(jnp.int32)


def _select_perm_ffn(cfg, m, base, i, count, inter, grads, key):
    k = cfg.d_ff
    if m.selection == "r":
        idx = jnp.sort(jax.random.permutation(key, k)[:count])
    else:
        if m.selection == "w":
            score = sel.weight_score_ffn(base[f"L{i}.wu"], base[f"L{i}.wg"],
                                         base[f"L{i}.wd"])
        elif m.selection in ("a", "s"):
            score = sel.activation_score(inter[f"L{i}.ffn_act"])
            if m.selection == "s":
                score = score * sel.weight_score_ffn(
                    base[f"L{i}.wu"], base[f"L{i}.wg"], base[f"L{i}.wd"]
                )
        else:  # g
            score = sel.gradient_score(grads[f"L{i}.wd"], axis=0)
        idx = sel.topk_indices(score, count, m.select_small)
    rest = _complement(idx, k)
    return jnp.concatenate([idx, rest]).astype(jnp.int32)


def _complement(idx, total):
    """Indices of [0, total) not in idx, ascending (XLA-friendly)."""
    marker = jnp.zeros((total,), jnp.int32).at[idx].set(1)
    order = jnp.argsort(marker, stable=True)  # zeros (unselected) first
    rest = order[: total - idx.shape[0]]
    return jnp.sort(rest).astype(jnp.int32)


def _split_layer(cfg, m, base, i, counts, head_perm, chan_perm, trn, frz):
    """Co-permute layer i and split target projections into (_t, _f)."""
    hd = cfg.head_dim
    if head_perm is not None:
        eperm = (head_perm[:, None] * hd + jnp.arange(hd)[None, :]).reshape(-1)
        mats = {
            "wq": base[f"L{i}.wq"][:, eperm],
            "wk": base[f"L{i}.wk"][:, eperm],
            "wv": base[f"L{i}.wv"][:, eperm],
            "wo": base[f"L{i}.wo"][eperm, :],
        }
        for p in MHA_PROJS:
            _stash(f"L{i}.{p}", p, mats[p], counts.get(p, 0) * hd, trn, frz)
    if chan_perm is not None:
        mats = {
            "wu": base[f"L{i}.wu"][:, chan_perm],
            "wg": base[f"L{i}.wg"][:, chan_perm],
            "wd": base[f"L{i}.wd"][chan_perm, :],
        }
        for p in FFN_PROJS:
            _stash(f"L{i}.{p}", p, mats[p], counts.get(p, 0), trn, frz)


def _stash(name, p, w, rows, trn, frz):
    if rows == 0:
        frz[name] = w
        return
    del frz[name]
    if p in ROW_SPLIT:
        trn[f"{name}_t"] = w[:rows]
        frz[f"{name}_f"] = w[rows:]
    else:
        trn[f"{name}_t"] = w[:, :rows]
        frz[f"{name}_f"] = w[:, rows:]


# --------------------------------------------------------------------------
# Merge: method layout -> base layout
# --------------------------------------------------------------------------


def merge_method(cfg: ModelConfig, m: MethodConfig, trainable: Params,
                 frozen: Params, perms: Params) -> Params:
    scale = m.lora_alpha / m.rank
    base = param_shapes(cfg)
    out: Params = {}
    if m.method in ("fullft", "lisa", "galore"):
        return {k: trainable[k] for k in base}
    if m.method in ("lora", "dora"):
        for name in base:
            w = frozen[name]
            if f"{name}.a" in trainable:
                w_eff = w + scale * (trainable[f"{name}.a"] @ trainable[f"{name}.b"])
                if m.method == "dora":
                    col_norm = jnp.linalg.norm(w_eff, axis=0, keepdims=True)
                    w_eff = trainable[f"{name}.m"][None, :] * w_eff / (col_norm + 1e-6)
                w = w_eff
            out[name] = w
        return out
    if m.method == "spft":
        for name in base:
            w = frozen[name]
            if f"{name}.delta" in trainable:
                w = w + frozen[f"{name}.mask"] * trainable[f"{name}.delta"]
            out[name] = w
        return out
    if m.method == "s2ft":
        hd = cfg.head_dim
        for name in base:
            if name in frozen:
                out[name] = frozen[name]
        for i in range(cfg.n_layers):
            head_perm = perms.get(f"L{i}.head_perm")
            chan_perm = perms.get(f"L{i}.chan_perm")
            if head_perm is not None:
                eperm = (head_perm[:, None] * hd + jnp.arange(hd)[None, :]).reshape(-1)
                inv = jnp.argsort(eperm)
                for p in MHA_PROJS:
                    w = _unsplit(f"L{i}.{p}", p, trainable, frozen)
                    out[f"L{i}.{p}"] = w[inv, :] if p in ROW_SPLIT else w[:, inv]
            if chan_perm is not None:
                inv = jnp.argsort(chan_perm)
                for p in FFN_PROJS:
                    w = _unsplit(f"L{i}.{p}", p, trainable, frozen)
                    out[f"L{i}.{p}"] = w[inv, :] if p in ROW_SPLIT else w[:, inv]
        return {k: out[k] for k in base}
    raise ValueError(m.method)


def _unsplit(name, p, trainable, frozen):
    if f"{name}_t" in trainable:
        axis = 0 if p in ROW_SPLIT else 1
        return jnp.concatenate([trainable[f"{name}_t"], frozen[f"{name}_f"]], axis=axis)
    return frozen[name]


# --------------------------------------------------------------------------
# AdamW train step with method-specific gradient transforms
# --------------------------------------------------------------------------


def _galore_proj(key, din, r):
    """Fixed JL-style projection, regenerated in-graph from the seed."""
    return jax.random.normal(key, (din, r), jnp.float32) / np.sqrt(r)


def opt_state_shapes(cfg: ModelConfig, m: MethodConfig) -> Dict[str, tuple]:
    """Adam m/v shapes: trainable shapes, except galore's projected space."""
    trn, _, _, _ = method_layout(cfg, m)
    if m.method != "galore":
        return trn
    out = {}
    for name, shape in trn.items():
        if len(shape) == 2 and min(shape) > m.rank:
            out[name] = (m.rank, shape[1]) if shape[0] >= shape[1] else (shape[0], m.rank)
        else:
            out[name] = shape
    return out


def train_step(cfg: ModelConfig, m: MethodConfig, trainable: Params, frozen: Params,
               opt_m: Params, opt_v: Params, step, tokens, targets, loss_mask,
               aux: Params):
    """One AdamW step. Returns (new_trainable, new_m, new_v, loss)."""

    def loss_fn(tr):
        logits = forward_method(cfg, m, tr, frozen, tokens)
        return ce_loss(logits, targets, loss_mask)

    loss, grads = jax.value_and_grad(loss_fn)(trainable)

    if m.method == "lisa":
        lm = aux["layer_mask"]

        def mask_of(name):
            if name.startswith("L"):
                return lm[int(name[1 : name.index(".")])]
            return lm[cfg.n_layers]

        grads = {k: g * mask_of(k) for k, g in grads.items()}

    t = step + 1.0
    b1, b2, lr, eps, wd = m.beta1, m.beta2, m.lr, m.eps, m.weight_decay
    new_t, new_m, new_v = {}, {}, {}
    for name, g in grads.items():
        p, mm, vv = trainable[name], opt_m[name], opt_v[name]
        if m.method == "galore" and g.ndim == 2 and min(g.shape) > m.rank:
            pk = jax.random.fold_in(jax.random.PRNGKey(1), _stable_hash(name))
            pk = jax.random.fold_in(pk, jnp.asarray(aux["proj_seed"], jnp.uint32))
            if g.shape[0] >= g.shape[1]:
                proj = _galore_proj(pk, g.shape[0], m.rank)  # (din, r)
                gp = proj.T @ g
                mm, vv, upd_p = _adam(gp, mm, vv, b1, b2, eps, t)
                upd = proj @ upd_p
            else:
                proj = _galore_proj(pk, g.shape[1], m.rank)  # (dout, r)
                gp = g @ proj
                mm, vv, upd_p = _adam(gp, mm, vv, b1, b2, eps, t)
                upd = upd_p @ proj.T
        else:
            mm, vv, upd = _adam(g, mm, vv, b1, b2, eps, t)
        new_t[name] = p - lr * (upd + wd * p)
        new_m[name] = mm
        new_v[name] = vv
    return new_t, new_m, new_v, loss


def _adam(g, mm, vv, b1, b2, eps, t):
    mm = b1 * mm + (1 - b1) * g
    vv = b2 * vv + (1 - b2) * g * g
    mh = mm / (1 - b1**t)
    vh = vv / (1 - b2**t)
    return mm, vv, mh / (jnp.sqrt(vh) + eps)


def _stable_hash(name: str) -> int:
    h = 2166136261
    for ch in name.encode():
        h = ((h ^ ch) * 16777619) & 0x7FFFFFFF
    return h
