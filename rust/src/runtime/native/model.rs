//! Native numerics: the LLaMA-style model semantics interpreted directly
//! on host tensors — seeded init, plan-cached forward, masked
//! cross-entropy, truncated manual backprop with S²FT *partial* weight
//! gradients (paper §3.3/§4: the activation is sliced down to the
//! trainable channels when it is cached, nothing is cached below the
//! shallowest trainable layer, and the backward walk stops there), AdamW,
//! and the method-layout prepare/merge co-permutations (paper §3.1–3.2).
//!
//! Conventions match `python/compile/model.py` exactly: `y = x @ W` with
//! `W: (d_in, d_out)`; FFN channel `c` is column `c` of wu/wg and row `c`
//! of wd; MHA head `h` is column block `h` of wq/wk/wv and row block `h`
//! of wo; trainable-first co-permutation puts selected units first.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::kernels::{
    causal_attn_bwd, causal_attn_fwd, gemm, gemm_nt, gemm_tn, gemm_tn_outcols, slice_cols,
    AttnDims,
};
use crate::runtime::meta::{MethodMeta, ModelMeta};
use crate::runtime::Tensor;
use crate::sparsity;
use crate::util::rng::Rng;

use super::builtin::{is_mha, is_row_split, FFN_PROJS, MHA_PROJS};
use super::meter::{f32_bytes, ActivationMeter};

type Named<'a> = HashMap<&'a str, &'a Tensor>;
type WeightMap<'a> = HashMap<String, &'a [f32]>;

fn get<'a>(named: &Named<'a>, name: &str) -> Result<&'a Tensor> {
    named
        .get(name)
        .copied()
        .ok_or_else(|| anyhow!("native: missing input {name:?}"))
}

fn getf<'a>(named: &Named<'a>, name: &str) -> Result<&'a [f32]> {
    get(named, name)?.as_f32()
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Init
// ---------------------------------------------------------------------------

/// Seeded scaled-gaussian init (GPT-2 style; residual projections wo/wd
/// down-scaled by 1/sqrt(2L); norms start at one). Deterministic per
/// (seed, tensor name).
pub fn init_params(mm: &ModelMeta, seed: i32) -> HashMap<String, Tensor> {
    let resid_scale = 1.0 / ((2 * mm.dims.n_layers) as f32).sqrt();
    let root = Rng::seed(seed as u32 as u64 ^ 0x51F7_0000);
    let mut out = HashMap::new();
    for s in &mm.base_params {
        let n = s.numel();
        let data = if s.name.ends_with("norm1")
            || s.name.ends_with("norm2")
            || s.name.ends_with("norm_f")
        {
            vec![1.0f32; n]
        } else {
            let mut rng = root.fold(fxhash(&s.name));
            let mut std = 0.02f32;
            if s.name.ends_with(".wo") || s.name.ends_with(".wd") {
                std *= resid_scale;
            }
            (0..n).map(|_| rng.normal_f32() * std).collect()
        };
        out.insert(s.name.clone(), Tensor::f32(s.shape.clone(), data));
    }
    out
}

// ---------------------------------------------------------------------------
// Dense kernels — all GEMMs route through `crate::kernels` (packed,
// register-tiled, multi-threaded, bit-identical across thread counts
// and the SIMD/scalar dispatch boundary). The S²FT partial
// gradients use `gemm_tn`/`gemm_tn_outcols`, which slice the trainable
// rows/columns *before* the dW GEMM (paper §3.3).
// ---------------------------------------------------------------------------

fn add_assign(dst: &mut [f32], src: &[f32]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

// ---------------------------------------------------------------------------
// RMSNorm / RoPE / SiLU
// ---------------------------------------------------------------------------

/// y = g ⊙ x · rsqrt(mean(x²)+eps); returns (y, inv_rms per row).
pub(super) fn rms_norm_fwd(x: &[f32], g: &[f32], n: usize, d: usize, eps: f32) -> (Vec<f32>, Vec<f32>) {
    let mut y = vec![0.0f32; n * d];
    let mut inv = vec![0.0f32; n];
    for i in 0..n {
        let xr = &x[i * d..(i + 1) * d];
        let var = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (var + eps).sqrt();
        inv[i] = r;
        let yr = &mut y[i * d..(i + 1) * d];
        for j in 0..d {
            yr[j] = g[j] * xr[j] * r;
        }
    }
    (y, inv)
}

/// dx for rms_norm; accumulates dg into `dg` when provided (full FT).
fn rms_norm_bwd(
    x: &[f32],
    g: &[f32],
    inv: &[f32],
    dy: &[f32],
    n: usize,
    d: usize,
    mut dg: Option<&mut [f32]>,
) -> Vec<f32> {
    let mut dx = vec![0.0f32; n * d];
    for i in 0..n {
        let xr = &x[i * d..(i + 1) * d];
        let dyr = &dy[i * d..(i + 1) * d];
        let r = inv[i];
        let mut dot = 0.0f32;
        for j in 0..d {
            dot += dyr[j] * g[j] * xr[j];
        }
        let coef = r * r * r * dot / d as f32;
        let dxr = &mut dx[i * d..(i + 1) * d];
        for j in 0..d {
            dxr[j] = g[j] * dyr[j] * r - xr[j] * coef;
        }
        if let Some(dg) = dg.as_deref_mut() {
            for j in 0..d {
                dg[j] += dyr[j] * xr[j] * r;
            }
        }
    }
    dx
}

/// cos/sin tables, each (t, hd/2): angle = pos · theta^(−2j/hd).
pub(super) fn rope_tables(t: usize, hd: usize, theta: f64) -> (Vec<f32>, Vec<f32>) {
    let half = hd / 2;
    let mut cos = vec![0.0f32; t * half];
    let mut sin = vec![0.0f32; t * half];
    for pos in 0..t {
        for j in 0..half {
            let freq = theta.powf(-((2 * j) as f64) / hd as f64);
            let ang = pos as f64 * freq;
            cos[pos * half + j] = ang.cos() as f32;
            sin[pos * half + j] = ang.sin() as f32;
        }
    }
    (cos, sin)
}

/// Rotate (even, odd) pairs per head in place; `inverse` applies the
/// transpose rotation (the exact backward of RoPE).
#[allow(clippy::too_many_arguments)]
fn apply_rope(
    x: &mut [f32],
    b: usize,
    t: usize,
    heads: usize,
    hd: usize,
    cos: &[f32],
    sin: &[f32],
    inverse: bool,
) {
    let half = hd / 2;
    let d = heads * hd;
    for bi in 0..b {
        for tt in 0..t {
            let base = (bi * t + tt) * d;
            for hh in 0..heads {
                let off = base + hh * hd;
                for j in 0..half {
                    let c = cos[tt * half + j];
                    let s = if inverse {
                        -sin[tt * half + j]
                    } else {
                        sin[tt * half + j]
                    };
                    let x1 = x[off + 2 * j];
                    let x2 = x[off + 2 * j + 1];
                    x[off + 2 * j] = x1 * c - x2 * s;
                    x[off + 2 * j + 1] = x1 * s + x2 * c;
                }
            }
        }
    }
}

pub(super) fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

// ---------------------------------------------------------------------------
// Cache plan: which forward buffers the backward pass will actually read
// ---------------------------------------------------------------------------

/// Per-layer retention/backward plan (all false/0 = layer is below the
/// shallowest trainable layer; nothing is cached and the backward walk
/// never reaches it).
#[derive(Debug, Clone, Default)]
struct LayerPlan {
    /// `act` channels to retain — the trainable `wd` rows sit first under
    /// the co-permutation, so the cache keeps only `act[:, :act_ch]`.
    act_ch: usize,
    /// `attn` columns to retain — the trainable `wo` rows.
    attn_ch: usize,
    /// retain `x1` (the wq/wk/wv weight gradients read it in full)
    x1: bool,
    /// run the SiLU chain (retain `x2`, recompute `u`/`g` from it):
    /// needed for wu/wg gradients or to continue into `dx2`
    silu: bool,
    /// compute `dx2` → norm2 → `dh_mid` (retains `h_mid`/`inv2`)
    dx2: bool,
    /// run the attention backward (retains `qr`/`kr`/`v`/`probs`)
    attn_dx: bool,
    /// propagate `dh` into the layer below (retains `h_in`/`inv1`)
    dh_below: bool,
}

/// Plan for the whole pass, derived from the [`GradPlan`]: decides which
/// buffers [`forward`] retains and where [`backward`] stops walking.
struct CachePlan {
    /// Retain every buffer (incl. `u`/`g`/`xf`) and walk to layer 0 —
    /// full FT, or the `S2FT_FULL_BACKWARD` reference walk.
    retain_all: bool,
    /// Retain the final-norm buffers (`h_final`/`invf`) for backprop;
    /// false for inference-only forwards, which retain nothing.
    training: bool,
    /// Shallowest layer with any trainable units (`n_layers` when none):
    /// the backward walk stops here and no earlier layer caches anything.
    stop: usize,
    layers: Vec<LayerPlan>,
}

const LAYER_PROJS: [&str; 7] = ["wq", "wk", "wv", "wo", "wu", "wg", "wd"];

impl CachePlan {
    /// Forward-only: cache nothing anywhere.
    fn inference(n_layers: usize) -> CachePlan {
        CachePlan {
            retain_all: false,
            training: false,
            stop: n_layers,
            layers: vec![LayerPlan::default(); n_layers],
        }
    }

    /// Retain everything, walk every layer (full FT; also the reference
    /// behavior the partial plan is proptested bit-identical against).
    fn full_walk(mm: &ModelMeta) -> CachePlan {
        let lp = LayerPlan {
            act_ch: mm.dims.d_ff,
            attn_ch: mm.dims.d_model,
            x1: true,
            silu: true,
            dx2: true,
            attn_dx: true,
            dh_below: true,
        };
        CachePlan {
            retain_all: true,
            training: true,
            stop: 0,
            layers: vec![lp; mm.dims.n_layers],
        }
    }

    /// Derive the minimal retention plan for a gradient plan. The paper's
    /// partial back-propagation (§4): weight-gradient inputs are sliced to
    /// the trainable channels at cache time, dX chains run only where a
    /// gradient still has to flow, and the walk truncates at the
    /// shallowest trainable layer.
    fn training(plan: &GradPlan, mm: &ModelMeta, force_full_walk: bool) -> CachePlan {
        if plan.full || force_full_walk {
            return Self::full_walk(mm);
        }
        let l = mm.dims.n_layers;
        let any: Vec<bool> =
            (0..l).map(|i| LAYER_PROJS.iter().any(|p| plan.units(i, p) > 0)).collect();
        let stop = any.iter().position(|&a| a).unwrap_or(l);
        let layers = (0..l)
            .map(|i| {
                if i < stop {
                    return LayerPlan::default();
                }
                let u = |p: &str| plan.units(i, p);
                let below = i > stop; // a trainable layer exists strictly below
                let attn_projs = u("wq") > 0 || u("wk") > 0 || u("wv") > 0;
                let dx2 = below || u("wo") > 0 || attn_projs;
                LayerPlan {
                    act_ch: u("wd").min(mm.dims.d_ff),
                    attn_ch: u("wo").min(mm.dims.d_model),
                    x1: attn_projs,
                    silu: dx2 || u("wu") > 0 || u("wg") > 0,
                    dx2,
                    attn_dx: below || attn_projs,
                    dh_below: below,
                }
            })
            .collect();
        CachePlan { retain_all: false, training: true, stop, layers }
    }
}

/// In-process override for the full-walk reference switch:
/// 0 = unset (defer to the environment), 1 = forced off, 2 = forced on.
static FULL_WALK_OVERRIDE: std::sync::atomic::AtomicUsize =
    std::sync::atomic::AtomicUsize::new(0);

/// Force (or un-force, with `None`) the cache-everything walk-to-zero
/// reference backward without touching the process environment — the
/// hook tests and benches use, since `std::env::set_var` races with any
/// concurrent `getenv` on other threads.
pub fn set_full_backward_override(v: Option<bool>) {
    let enc = match v {
        None => 0,
        Some(false) => 1,
        Some(true) => 2,
    };
    FULL_WALK_OVERRIDE.store(enc, std::sync::atomic::Ordering::Relaxed);
}

/// `S2FT_FULL_BACKWARD=1` (or [`set_full_backward_override`]) forces the
/// pre-plan reference behavior: cache every buffer and walk every layer
/// down to 0 (weight gradients stay partial). Used by the
/// `fig5_training` truncated-vs-full bench lanes and the bit-identity
/// proptests.
fn force_full_walk() -> bool {
    match FULL_WALK_OVERRIDE.load(std::sync::atomic::Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => std::env::var("S2FT_FULL_BACKWARD")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false),
    }
}

// ---------------------------------------------------------------------------
// Forward (plan-cached)
// ---------------------------------------------------------------------------

#[derive(Default)]
struct LayerCache {
    h_in: Vec<f32>,
    inv1: Vec<f32>,
    x1: Vec<f32>,
    qr: Vec<f32>,
    kr: Vec<f32>,
    v: Vec<f32>,
    probs: Vec<f32>, // (b, heads, t, t)
    attn: Vec<f32>,  // head outputs pre-wo: (N, attn_ch) plan slice
    h_mid: Vec<f32>,
    inv2: Vec<f32>,
    x2: Vec<f32>,
    u: Vec<f32>, // retained only under `retain_all` (else recomputed)
    g: Vec<f32>,
    act: Vec<f32>, // (N, act_ch) plan slice
}

impl LayerCache {
    fn bytes(&self) -> u64 {
        f32_bytes(
            self.h_in.len()
                + self.inv1.len()
                + self.x1.len()
                + self.qr.len()
                + self.kr.len()
                + self.v.len()
                + self.probs.len()
                + self.attn.len()
                + self.h_mid.len()
                + self.inv2.len()
                + self.x2.len()
                + self.u.len()
                + self.g.len()
                + self.act.len(),
        )
    }
}

struct Cache {
    layers: Vec<LayerCache>,
    h_final: Vec<f32>,
    invf: Vec<f32>,
    xf: Vec<f32>, // retained only under `retain_all` (embed gradient)
    logits: Vec<f32>,
}

fn weight<'a>(w: &WeightMap<'a>, name: &str) -> Result<&'a [f32]> {
    w.get(name)
        .copied()
        .ok_or_else(|| anyhow!("native: missing weight {name:?}"))
}

/// Keep `v` in the cache if `cond`, else free it (metered).
fn keep(cond: bool, v: Vec<f32>, meter: &mut ActivationMeter) -> Vec<f32> {
    if cond {
        v
    } else {
        meter.free(f32_bytes(v.len()));
        Vec::new()
    }
}

/// Keep the first `ch` of `cols` columns of `v` (the cache-time slice);
/// `ch == cols` keeps the buffer whole without copying.
fn keep_sliced(
    ch: usize,
    rows: usize,
    cols: usize,
    v: Vec<f32>,
    meter: &mut ActivationMeter,
) -> Vec<f32> {
    if ch >= cols {
        return v;
    }
    let s = slice_cols(&v, rows, cols, ch);
    meter.alloc(f32_bytes(s.len()));
    meter.free(f32_bytes(v.len()));
    s
}

/// Cached forward pass in (possibly permuted) base layout. `cplan`
/// decides, per layer, which buffers survive into the returned [`Cache`];
/// `meter` tracks retained cache bytes and the live high-water mark.
fn forward(
    mm: &ModelMeta,
    w: &WeightMap,
    tokens: &[i32],
    b: usize,
    t: usize,
    cplan: &CachePlan,
    meter: &mut ActivationMeter,
) -> Result<Cache> {
    let d = mm.dims.d_model;
    let heads = mm.dims.n_heads;
    let hd = d / heads;
    let ff = mm.dims.d_ff;
    let vocab = mm.dims.vocab;
    let eps = mm.dims.norm_eps as f32;
    let n = b * t;
    if tokens.len() != n {
        bail!("native: tokens length {} != {b}x{t}", tokens.len());
    }

    let embed = weight(w, "embed")?;
    let mut h = vec![0.0f32; n * d];
    meter.alloc(f32_bytes(n * d));
    for (i, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        if tok >= vocab {
            bail!("native: token id {tok} out of vocab {vocab}");
        }
        h[i * d..(i + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
    }
    let (cos, sin) = rope_tables(t, hd, mm.dims.rope_theta);
    let scale = 1.0 / (hd as f32).sqrt();

    let mut layers = Vec::with_capacity(mm.dims.n_layers);
    for i in 0..mm.dims.n_layers {
        let lp = &cplan.layers[i];
        let ra = cplan.retain_all;
        let h_in = h;
        let (x1, inv1) =
            rms_norm_fwd(&h_in, weight(w, &format!("L{i}.norm1"))?, n, d, eps);
        meter.alloc(f32_bytes(x1.len() + inv1.len()));
        let mut qr = gemm(&x1, weight(w, &format!("L{i}.wq"))?, n, d, d);
        let mut kr = gemm(&x1, weight(w, &format!("L{i}.wk"))?, n, d, d);
        let v = gemm(&x1, weight(w, &format!("L{i}.wv"))?, n, d, d);
        meter.alloc(f32_bytes(3 * n * d));
        apply_rope(&mut qr, b, t, heads, hd, &cos, &sin, false);
        apply_rope(&mut kr, b, t, heads, hd, &cos, &sin, false);

        let (probs, attn) = causal_attn_fwd(&qr, &kr, &v, &AttnDims { b, t, heads, hd }, scale);
        meter.alloc(f32_bytes(probs.len() + attn.len()));

        let mut h_mid = h_in.clone();
        meter.alloc(f32_bytes(h_mid.len()));
        let wo_out = gemm(&attn, weight(w, &format!("L{i}.wo"))?, n, d, d);
        meter.alloc(f32_bytes(wo_out.len()));
        add_assign(&mut h_mid, &wo_out);
        meter.free(f32_bytes(wo_out.len()));
        drop(wo_out);
        let (x2, inv2) =
            rms_norm_fwd(&h_mid, weight(w, &format!("L{i}.norm2"))?, n, d, eps);
        meter.alloc(f32_bytes(x2.len() + inv2.len()));
        let u = gemm(&x2, weight(w, &format!("L{i}.wu"))?, n, d, ff);
        let g = gemm(&x2, weight(w, &format!("L{i}.wg"))?, n, d, ff);
        meter.alloc(f32_bytes(2 * n * ff));
        let mut act = vec![0.0f32; n * ff];
        meter.alloc(f32_bytes(act.len()));
        for j in 0..n * ff {
            act[j] = u[j] * g[j] * sigmoid(g[j]);
        }
        let mut h_out = h_mid.clone();
        meter.alloc(f32_bytes(h_out.len()));
        let wd_out = gemm(&act, weight(w, &format!("L{i}.wd"))?, n, ff, d);
        meter.alloc(f32_bytes(wd_out.len()));
        add_assign(&mut h_out, &wd_out);
        meter.free(f32_bytes(wd_out.len()));
        drop(wd_out);

        // Retention: move whole buffers the plan needs, slice `attn`/`act`
        // to the trainable channels, free the rest.
        let lc = LayerCache {
            h_in: keep(lp.dh_below, h_in, meter),
            inv1: keep(lp.dh_below, inv1, meter),
            x1: keep(lp.x1, x1, meter),
            qr: keep(lp.attn_dx, qr, meter),
            kr: keep(lp.attn_dx, kr, meter),
            v: keep(lp.attn_dx, v, meter),
            probs: keep(lp.attn_dx, probs, meter),
            attn: keep_sliced(lp.attn_ch, n, d, attn, meter),
            h_mid: keep(lp.dx2, h_mid, meter),
            inv2: keep(lp.dx2, inv2, meter),
            x2: keep(lp.silu, x2, meter),
            u: keep(ra, u, meter),
            g: keep(ra, g, meter),
            act: keep_sliced(lp.act_ch, n, ff, act, meter),
        };
        meter.retain_layer(i, lc.bytes());
        layers.push(lc);
        h = h_out;
    }

    let (xf, invf) = rms_norm_fwd(&h, weight(w, "norm_f")?, n, d, eps);
    meter.alloc(f32_bytes(xf.len() + invf.len()));
    let logits = gemm_nt(&xf, embed, n, d, vocab);
    meter.alloc(f32_bytes(logits.len()));
    let h_final = keep(cplan.training, h, meter);
    let invf = keep(cplan.training, invf, meter);
    let xf = keep(cplan.retain_all, xf, meter);
    meter.retain_final(f32_bytes(h_final.len() + invf.len() + xf.len()));
    Ok(Cache { layers, h_final, invf, xf, logits })
}

/// Loss-mask predicate: a position contributes only when its mask weight
/// is strictly positive. Written without float-literal equality (the
/// PR 5 bug class, rejected by `repro analyze` in this module): `-0.0`,
/// negatives and NaN all count as masked, mirroring the `mask[i] > 0.0`
/// guards on the loss and gradient accumulation below so the skip can
/// never disagree with them.
#[inline]
fn is_masked(m: f32) -> bool {
    m <= 0.0 || m.is_nan()
}

/// Masked mean cross-entropy + (optionally) dlogits, + masked ncorrect.
fn loss_ncorrect_grad(
    logits: &[f32],
    targets: &[i32],
    mask: &[f32],
    n: usize,
    vocab: usize,
    want_grad: bool,
) -> (f32, f32, Option<Vec<f32>>) {
    let msum: f32 = mask.iter().sum();
    let m = msum.max(1.0);
    let mut loss = 0.0f64;
    let mut ncorrect = 0.0f32;
    let mut dlogits = if want_grad {
        Some(vec![0.0f32; n * vocab])
    } else {
        None
    };
    for i in 0..n {
        let row = &logits[i * vocab..(i + 1) * vocab];
        let tgt = targets[i] as usize;
        let mut maxv = f32::NEG_INFINITY;
        let mut arg = 0usize;
        for (j, &x) in row.iter().enumerate() {
            if x > maxv {
                maxv = x;
                arg = j;
            }
        }
        if arg == tgt {
            ncorrect += mask[i];
        }
        if is_masked(mask[i]) && dlogits.is_none() {
            continue;
        }
        let lse: f32 = maxv + row.iter().map(|&x| (x - maxv).exp()).sum::<f32>().ln();
        if mask[i] > 0.0 {
            loss += (mask[i] * (lse - row[tgt])) as f64;
        }
        if let Some(dl) = dlogits.as_deref_mut() {
            if mask[i] > 0.0 {
                let coef = mask[i] / m;
                let drow = &mut dl[i * vocab..(i + 1) * vocab];
                for (j, &x) in row.iter().enumerate() {
                    drow[j] = coef * (x - lse).exp();
                }
                drow[tgt] -= coef;
            }
        }
    }
    ((loss / m as f64) as f32, ncorrect, dlogits)
}

// ---------------------------------------------------------------------------
// Public entry points: fwd / eval
// ---------------------------------------------------------------------------

fn base_weight_map<'a>(mm: &ModelMeta, named: &Named<'a>) -> Result<WeightMap<'a>> {
    let mut w = WeightMap::new();
    for s in &mm.base_params {
        w.insert(s.name.clone(), getf(named, &s.name)?);
    }
    Ok(w)
}

pub fn forward_logits(
    mm: &ModelMeta,
    named: &Named,
    tokens: &Tensor,
    b: usize,
    t: usize,
) -> Result<Tensor> {
    let w = base_weight_map(mm, named)?;
    let mut meter = ActivationMeter::new(mm.dims.n_layers);
    let cplan = CachePlan::inference(mm.dims.n_layers);
    let cache = forward(mm, &w, tokens.as_i32()?, b, t, &cplan, &mut meter)?;
    Ok(Tensor::f32(vec![b, t, mm.dims.vocab], cache.logits))
}

pub fn eval_batch(mm: &ModelMeta, named: &Named, b: usize, t: usize) -> Result<(f32, f32)> {
    let w = base_weight_map(mm, named)?;
    let tokens = get(named, "tokens")?.as_i32()?;
    let targets = get(named, "targets")?.as_i32()?;
    let mask = getf(named, "loss_mask")?;
    let mut meter = ActivationMeter::new(mm.dims.n_layers);
    let cplan = CachePlan::inference(mm.dims.n_layers);
    let cache = forward(mm, &w, tokens, b, t, &cplan, &mut meter)?;
    let (loss, ncorrect, _) =
        loss_ncorrect_grad(&cache.logits, targets, mask, b * t, mm.dims.vocab, false);
    Ok((loss, ncorrect))
}

// ---------------------------------------------------------------------------
// Gradient plan + backward
// ---------------------------------------------------------------------------

/// Which weight gradients to materialize.
struct GradPlan {
    /// full fine-tuning: every base tensor (incl. embed + norms)
    full: bool,
    /// s2ft: per layer, projection short-name -> trainable elements
    /// (rows for wo/wd, columns for the rest); absent = frozen.
    sel: Vec<HashMap<String, usize>>,
}

impl GradPlan {
    fn from_method(mm: &ModelMeta, meth: &MethodMeta) -> GradPlan {
        if meth.method == "fullft" {
            return GradPlan { full: true, sel: vec![] };
        }
        let mut sel = vec![HashMap::new(); mm.dims.n_layers];
        for s in &meth.trainable {
            // names look like "L{i}.{proj}_t"
            if let Some(rest) = s.name.strip_prefix('L') {
                if let Some((idx, tail)) = rest.split_once('.') {
                    if let (Ok(i), Some(proj)) =
                        (idx.parse::<usize>(), tail.strip_suffix("_t"))
                    {
                        let units = if is_row_split(proj) { s.shape[0] } else { s.shape[1] };
                        sel[i].insert(proj.to_string(), units);
                    }
                }
            }
        }
        GradPlan { full: false, sel }
    }

    fn units(&self, layer: usize, proj: &str) -> usize {
        if self.full {
            usize::MAX
        } else {
            self.sel.get(layer).and_then(|m| m.get(proj)).copied().unwrap_or(0)
        }
    }
}

/// Backward pass. Returns gradients keyed by *trainable tensor name*:
/// base names under full FT, `L{i}.{p}_t` slices under S²FT.
///
/// The walk is plan-truncated: it starts at the top layer and stops at
/// `cplan.stop` (the shallowest layer with any trainable units), skipping
/// every dX-only chain the plan marks unnecessary. Consumes the cache,
/// freeing each layer's buffers (and metering the release) as soon as
/// they have been read — trainable gradients are bit-identical to the
/// full walk because every skipped computation feeds only dX flows that
/// no surviving gradient reads, and every retained buffer is either whole
/// or a leading-channel slice consumed by the same `lim`-limited GEMM.
#[allow(clippy::too_many_arguments)]
fn backward(
    mm: &ModelMeta,
    w: &WeightMap,
    mut cache: Cache,
    dlogits: &[f32],
    tokens: &[i32],
    plan: &GradPlan,
    cplan: &CachePlan,
    meter: &mut ActivationMeter,
    b: usize,
    t: usize,
) -> Result<HashMap<String, Vec<f32>>> {
    let d = mm.dims.d_model;
    let heads = mm.dims.n_heads;
    let hd = d / heads;
    let ff = mm.dims.d_ff;
    let vocab = mm.dims.vocab;
    let n = b * t;
    let scale = 1.0 / (hd as f32).sqrt();
    let (cos, sin) = rope_tables(t, hd, mm.dims.rope_theta);
    let embed = weight(w, "embed")?;

    let mut grads: HashMap<String, Vec<f32>> = HashMap::new();

    // logits = xf @ embedᵀ (tied embedding)
    let dxf = gemm(dlogits, embed, n, vocab, d);
    meter.alloc(f32_bytes(dxf.len()));
    if plan.full {
        grads.insert("embed".to_string(), gemm_tn(dlogits, &cache.xf, n, vocab, d, vocab));
    }
    let mut dgf = plan.full.then(|| vec![0.0f32; d]);
    let mut dh = rms_norm_bwd(
        &cache.h_final,
        weight(w, "norm_f")?,
        &cache.invf,
        &dxf,
        n,
        d,
        dgf.as_deref_mut(),
    );
    meter.alloc(f32_bytes(dh.len()));
    meter.free(f32_bytes(dxf.len()));
    drop(dxf);
    if let Some(dgf) = dgf {
        grads.insert("norm_f".to_string(), dgf);
    }
    // the final-norm buffers are consumed; release them now
    meter.free(f32_bytes(cache.h_final.len() + cache.invf.len() + cache.xf.len()));
    cache.h_final = Vec::new();
    cache.invf = Vec::new();
    cache.xf = Vec::new();

    'walk: for i in (cplan.stop..mm.dims.n_layers).rev() {
        let lc = std::mem::take(&mut cache.layers[i]);
        // u/g (cached only under retain_all) are consumed and dropped
        // mid-iteration by the SiLU chain, so they are metered separately
        // from the rest of the layer cache (freed at iteration end).
        let ug_bytes = f32_bytes(lc.u.len() + lc.g.len());
        let lc_rest = lc.bytes() - ug_bytes;
        let lp = &cplan.layers[i];
        let ra = cplan.retain_all;

        // ---- FFN: h_out = h_mid + act @ wd -------------------------------
        let dffn = &dh; // gradient wrt (act @ wd)
        let wd_units = plan.units(i, "wd");
        if plan.full {
            grads.insert(format!("L{i}.wd"), gemm_tn(&lc.act, dffn, n, ff, d, ff));
        } else if wd_units > 0 {
            // partial backprop: the activation channels were sliced at
            // cache time (or at GEMM time under the full-walk reference)
            let ka = if ra { ff } else { lp.act_ch };
            grads.insert(
                format!("L{i}.wd_t"),
                gemm_tn(&lc.act, dffn, n, ka, d, wd_units),
            );
        }

        // ---- SiLU chain: everything upstream of the FFN entry ------------
        // du feeds the wu gradient and dx2; dgpre feeds the wg gradient
        // and dx2 (and is the only consumer of the recomputed u). At a
        // boundary layer with just one of wu/wg trainable, the other
        // half of the chain is dX-only work and is skipped.
        let need_du = lp.dx2 || plan.units(i, "wu") > 0;
        let need_dgpre = lp.dx2 || plan.units(i, "wg") > 0;
        let mut dh_mid_norm: Option<Vec<f32>> = None;
        if lp.silu {
            let (u, g) = if ra {
                (lc.u, lc.g) // cached under the full walk
            } else {
                // plan-sliced cache dropped u/g: recompute from the
                // retained x2 (same GEMM over the same inputs, so the
                // downstream gradients stay bit-identical)
                let u = if need_dgpre {
                    gemm(&lc.x2, weight(w, &format!("L{i}.wu"))?, n, d, ff)
                } else {
                    Vec::new()
                };
                let g = gemm(&lc.x2, weight(w, &format!("L{i}.wg"))?, n, d, ff);
                meter.alloc(f32_bytes(u.len() + g.len()));
                (u, g)
            };
            let dact = gemm_nt(dffn, weight(w, &format!("L{i}.wd"))?, n, d, ff);
            let mut du = if need_du { vec![0.0f32; n * ff] } else { Vec::new() };
            let mut dgpre = if need_dgpre { vec![0.0f32; n * ff] } else { Vec::new() };
            meter.alloc(f32_bytes(n * ff + du.len() + dgpre.len()));
            for j in 0..n * ff {
                let sg = sigmoid(g[j]);
                let sil = g[j] * sg;
                if need_du {
                    du[j] = dact[j] * sil;
                }
                if need_dgpre {
                    dgpre[j] = dact[j] * u[j] * sg * (1.0 + g[j] * (1.0 - sg));
                }
            }
            // frees the recomputed buffers, or (under retain_all) the
            // cached ones carved out of the layer-cache accounting above
            meter.free(f32_bytes(u.len() + g.len()));
            drop((u, g, dact));
            meter.free(f32_bytes(n * ff)); // dact
            for (proj, dproj) in [("wu", &du), ("wg", &dgpre)] {
                let units = plan.units(i, proj);
                if plan.full {
                    grads.insert(format!("L{i}.{proj}"), gemm_tn(&lc.x2, dproj, n, d, ff, d));
                } else if units > 0 {
                    grads.insert(
                        format!("L{i}.{proj}_t"),
                        gemm_tn_outcols(&lc.x2, dproj, n, d, ff, units),
                    );
                }
            }
            if lp.dx2 {
                let mut dx2 = gemm_nt(&du, weight(w, &format!("L{i}.wu"))?, n, ff, d);
                add_assign(&mut dx2, &gemm_nt(&dgpre, weight(w, &format!("L{i}.wg"))?, n, ff, d));
                meter.alloc(f32_bytes(dx2.len()));
                let mut dn2 = plan.full.then(|| vec![0.0f32; d]);
                dh_mid_norm = Some(rms_norm_bwd(
                    &lc.h_mid,
                    weight(w, &format!("L{i}.norm2"))?,
                    &lc.inv2,
                    &dx2,
                    n,
                    d,
                    dn2.as_deref_mut(),
                ));
                meter.free(f32_bytes(dx2.len()));
                meter.alloc(f32_bytes(n * d)); // dh_mid_norm
                if let Some(dn2) = dn2 {
                    grads.insert(format!("L{i}.norm2"), dn2);
                }
            }
            meter.free(f32_bytes(du.len() + dgpre.len()));
        }
        let Some(dh_mid_norm) = dh_mid_norm else {
            // Boundary layer with only FFN-entry projections trainable:
            // no gradient flows past h_mid, so the walk ends here.
            debug_assert_eq!(i, cplan.stop);
            meter.free(lc_rest + f32_bytes(dh.len()));
            break 'walk;
        };
        // residual path (take leaves `dh` empty so the post-loop embed
        // gradient read stays well-formed on the break paths)
        let mut dh_mid = std::mem::take(&mut dh);
        add_assign(&mut dh_mid, &dh_mid_norm);
        meter.free(f32_bytes(dh_mid_norm.len()));
        drop(dh_mid_norm);

        // ---- Attention: h_mid = h_in + attn @ wo -------------------------
        let wo_units = plan.units(i, "wo");
        if plan.full {
            grads.insert(format!("L{i}.wo"), gemm_tn(&lc.attn, &dh_mid, n, d, d, d));
        } else if wo_units > 0 {
            let ka = if ra { d } else { lp.attn_ch };
            grads.insert(
                format!("L{i}.wo_t"),
                gemm_tn(&lc.attn, &dh_mid, n, ka, d, wo_units),
            );
        }
        if !lp.attn_dx {
            // Boundary layer whose attention inputs are all frozen: the
            // dX GEMM through wo and the attention backward are skipped.
            debug_assert_eq!(i, cplan.stop);
            meter.free(lc_rest + f32_bytes(dh_mid.len()));
            break 'walk;
        }
        let da = gemm_nt(&dh_mid, weight(w, &format!("L{i}.wo"))?, n, d, d);
        meter.alloc(f32_bytes(da.len()));

        let (mut dqr, mut dkr, dv) = causal_attn_bwd(
            &lc.probs,
            &lc.qr,
            &lc.kr,
            &lc.v,
            &da,
            &AttnDims { b, t, heads, hd },
            scale,
        );
        meter.alloc(f32_bytes(3 * n * d));
        meter.free(f32_bytes(da.len()));
        drop(da);
        apply_rope(&mut dqr, b, t, heads, hd, &cos, &sin, true);
        apply_rope(&mut dkr, b, t, heads, hd, &cos, &sin, true);

        for (proj, dproj) in [("wq", &dqr), ("wk", &dkr), ("wv", &dv)] {
            let units = plan.units(i, proj);
            if plan.full {
                grads.insert(format!("L{i}.{proj}"), gemm_tn(&lc.x1, dproj, n, d, d, d));
            } else if units > 0 {
                grads.insert(
                    format!("L{i}.{proj}_t"),
                    gemm_tn_outcols(&lc.x1, dproj, n, d, d, units),
                );
            }
        }
        if !lp.dh_below {
            // Boundary layer: all gradients are in; nothing to push down.
            debug_assert_eq!(i, cplan.stop);
            meter.free(lc_rest + f32_bytes(dh_mid.len() + 3 * n * d));
            break 'walk;
        }
        let mut dx1 = gemm_nt(&dqr, weight(w, &format!("L{i}.wq"))?, n, d, d);
        add_assign(&mut dx1, &gemm_nt(&dkr, weight(w, &format!("L{i}.wk"))?, n, d, d));
        add_assign(&mut dx1, &gemm_nt(&dv, weight(w, &format!("L{i}.wv"))?, n, d, d));
        meter.alloc(f32_bytes(dx1.len()));
        meter.free(f32_bytes(3 * n * d)); // dqr, dkr, dv
        drop((dqr, dkr, dv));
        let mut dn1 = plan.full.then(|| vec![0.0f32; d]);
        let dh_in_norm = rms_norm_bwd(
            &lc.h_in,
            weight(w, &format!("L{i}.norm1"))?,
            &lc.inv1,
            &dx1,
            n,
            d,
            dn1.as_deref_mut(),
        );
        meter.free(f32_bytes(dx1.len()));
        drop(dx1);
        meter.alloc(f32_bytes(dh_in_norm.len()));
        if let Some(dn1) = dn1 {
            grads.insert(format!("L{i}.norm1"), dn1);
        }
        dh = dh_mid;
        add_assign(&mut dh, &dh_in_norm);
        meter.free(f32_bytes(dh_in_norm.len()));
        // the rest of this layer's cache is fully consumed
        meter.free(lc_rest);
    }

    if plan.full {
        // input-embedding gradient (tied with the output projection above)
        let de = grads.get_mut("embed").expect("embed grad allocated");
        for (idx, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            add_assign(&mut de[tok * d..(tok + 1) * d], &dh[idx * d..(idx + 1) * d]);
        }
    }
    Ok(grads)
}

// ---------------------------------------------------------------------------
// Train step
// ---------------------------------------------------------------------------

/// Build the effective (possibly permuted) base-layout weight map for a
/// method pool: full-FT reads trainable directly; S²FT concatenates the
/// `_t`/`_f` splits. Returns the owned concat storage + name resolution.
#[allow(clippy::type_complexity)]
fn effective_weights<'a>(
    mm: &ModelMeta,
    named: &Named<'a>,
) -> Result<(HashMap<String, Vec<f32>>, Vec<(String, Option<&'a [f32]>)>)> {
    let mut store: HashMap<String, Vec<f32>> = HashMap::new();
    let mut direct: Vec<(String, Option<&[f32]>)> = Vec::new();
    for s in &mm.base_params {
        let name = &s.name;
        let t_name = format!("{name}_t");
        let f_name = format!("{name}_f");
        if named.contains_key(t_name.as_str()) {
            let tt = get(named, &t_name)?;
            let ft = get(named, &f_name)?;
            let proj = name.rsplit('.').next().unwrap_or("");
            let buf = if is_row_split(proj) {
                let mut buf = Vec::with_capacity(s.numel());
                buf.extend_from_slice(tt.as_f32()?);
                buf.extend_from_slice(ft.as_f32()?);
                buf
            } else {
                // column concat: row r = t[r] ++ f[r]
                let (ct, cf) = (tt.shape[1], ft.shape[1]);
                let rows = tt.shape[0];
                let (tv, fv) = (tt.as_f32()?, ft.as_f32()?);
                let mut buf = Vec::with_capacity(rows * (ct + cf));
                for r in 0..rows {
                    buf.extend_from_slice(&tv[r * ct..(r + 1) * ct]);
                    buf.extend_from_slice(&fv[r * cf..(r + 1) * cf]);
                }
                buf
            };
            store.insert(name.clone(), buf);
            direct.push((name.clone(), None));
        } else {
            // base-named tensor lives in either trainable (fullft) or
            // frozen (s2ft untouched) — both arrive in `named`.
            direct.push((name.clone(), Some(getf(named, name)?)));
        }
    }
    Ok((store, direct))
}

/// The plan-derived state a train executable caches across steps: the
/// gradient plan plus both retention plans (partial, and the full-walk
/// reference the `S2FT_FULL_BACKWARD` switch selects). Plans derive from
/// the method layout's trainable *shapes* only, so they stay valid until
/// the selection — and hence the layout — changes; the replanning trainer
/// invalidates them by evicting and reloading the executable (a plan
/// epoch bump), never by mutating them in place.
pub struct TrainPlans {
    plan: GradPlan,
    partial: CachePlan,
    full: CachePlan,
}

impl TrainPlans {
    /// Derive the gradient plan and both cache-retention plans for a
    /// method layout.
    pub fn new(mm: &ModelMeta, meth: &MethodMeta) -> TrainPlans {
        let plan = GradPlan::from_method(mm, meth);
        let partial = CachePlan::training(&plan, mm, false);
        let full = CachePlan::training(&plan, mm, true);
        TrainPlans { plan, partial, full }
    }
}

/// One AdamW step in method layout. Outputs `new.*`, `new_m.*`, `new_v.*`
/// and `loss`, exactly like the AOT train artifacts. `plans` carries the
/// cached plan bundle for the *current* plan epoch (see [`TrainPlans`]).
pub fn train_step(
    mm: &ModelMeta,
    meth: &MethodMeta,
    plans: &TrainPlans,
    named: &Named,
    b: usize,
    t: usize,
) -> Result<HashMap<String, Tensor>> {
    let (store, direct) = effective_weights(mm, named)?;
    let mut w: WeightMap = WeightMap::new();
    for (name, slice) in &direct {
        match slice {
            Some(s) => w.insert(name.clone(), *s),
            None => w.insert(name.clone(), store[name].as_slice()),
        };
    }

    let tokens = get(named, "tokens")?.as_i32()?;
    let targets = get(named, "targets")?.as_i32()?;
    let mask = getf(named, "loss_mask")?;
    let step = getf(named, "step")?[0];
    // AdamW bias correction runs at t = step + 1 (the wire contract is a
    // 0-based step counter, matching the python `train_step`), so t starts
    // at 1 on the very first step. Reject anything that would make t < 1:
    // 1 - β^0 = 0 zeroes the corrections and the moment scaling divides
    // by it, turning the whole update to inf/NaN.
    let tt = (step + 1.0) as f64;
    if !tt.is_finite() || tt < 1.0 {
        bail!(
            "native: AdamW bias-correction step t = step+1 must be >= 1 \
             (got step = {step}; the trainer passes its 0-based step count)"
        );
    }

    let plan = &plans.plan;
    let cplan = if force_full_walk() { &plans.full } else { &plans.partial };
    let mut meter = ActivationMeter::new(mm.dims.n_layers);
    let mut cache = forward(mm, &w, tokens, b, t, cplan, &mut meter)?;
    let (loss, _, dlogits) =
        loss_ncorrect_grad(&cache.logits, targets, mask, b * t, mm.dims.vocab, true);
    let dlogits = dlogits.expect("gradient requested");
    meter.alloc(f32_bytes(dlogits.len()));
    // the backward pass never reads the logits: free them before it runs
    meter.free(f32_bytes(cache.logits.len()));
    cache.logits = Vec::new();
    let grads = backward(mm, &w, cache, &dlogits, tokens, plan, cplan, &mut meter, b, t)?;
    meter.free(f32_bytes(dlogits.len()));
    drop(dlogits);

    // AdamW (python `_adam` + decoupled weight decay), t = step + 1.
    let (b1, b2) = (meth.beta1 as f32, meth.beta2 as f32);
    let bc1 = (1.0 - meth.beta1.powf(tt)) as f32;
    let bc2 = (1.0 - meth.beta2.powf(tt)) as f32;
    let (lr, eps, wd) = (meth.lr as f32, meth.eps as f32, meth.weight_decay as f32);

    let mut out = HashMap::new();
    for s in &meth.trainable {
        let name = &s.name;
        let g = grads
            .get(name.as_str())
            .ok_or_else(|| anyhow!("native: no gradient computed for {name:?}"))?;
        let mut p = get(named, name)?.as_f32()?.to_vec();
        let mut om = getf(named, &format!("m.{name}"))?.to_vec();
        let mut ov = getf(named, &format!("v.{name}"))?.to_vec();
        for j in 0..p.len() {
            om[j] = b1 * om[j] + (1.0 - b1) * g[j];
            ov[j] = b2 * ov[j] + (1.0 - b2) * g[j] * g[j];
            let mh = om[j] / bc1;
            let vh = ov[j] / bc2;
            p[j] -= lr * (mh / (vh.sqrt() + eps) + wd * p[j]);
        }
        out.insert(format!("new.{name}"), Tensor::f32(s.shape.clone(), p));
        out.insert(format!("new_m.{name}"), Tensor::f32(s.shape.clone(), om));
        out.insert(format!("new_v.{name}"), Tensor::f32(s.shape.clone(), ov));
    }
    // Measured activation memory (Fig 5): bytes the plan-driven cache
    // retained across the forward/backward gap, and the live high-water
    // mark over the whole pass. i32 saturation keeps the wire dtype exact
    // (counts are exact below 2 GiB, far above any builtin shape).
    let clamp = |v: u64| v.min(i32::MAX as u64) as i32;
    out.insert("act_bytes".to_string(), Tensor::scalar_i32(clamp(meter.cache_total)));
    out.insert("act_peak_bytes".to_string(), Tensor::scalar_i32(clamp(meter.peak)));
    out.insert("loss".to_string(), Tensor::scalar_f32(loss));
    Ok(out)
}

/// Gradient-magnitude unit scores for dynamic selection strategies: one
/// full-plan forward/backward over a probe batch in *base* layout, then
/// the S²FT unit score formulas applied to the weight *gradients* instead
/// of the weights (dWo row-block norms per head; dWu col + dWg col + dWd
/// row norms per FFN channel). Outputs `head_grad_norms` `[L, n_heads]`
/// and `chan_grad_norms` `[L, d_ff]`.
pub fn grad_unit_norms(
    mm: &ModelMeta,
    named: &Named,
    b: usize,
    t: usize,
) -> Result<HashMap<String, Tensor>> {
    let w = base_weight_map(mm, named)?;
    let tokens = get(named, "tokens")?.as_i32()?;
    let targets = get(named, "targets")?.as_i32()?;
    let mask = getf(named, "loss_mask")?;

    let plan = GradPlan { full: true, sel: vec![] };
    let cplan = CachePlan::full_walk(mm);
    let mut meter = ActivationMeter::new(mm.dims.n_layers);
    let mut cache = forward(mm, &w, tokens, b, t, &cplan, &mut meter)?;
    let (_, _, dlogits) =
        loss_ncorrect_grad(&cache.logits, targets, mask, b * t, mm.dims.vocab, true);
    let dlogits = dlogits.expect("gradient requested");
    cache.logits = Vec::new();
    let grads = backward(mm, &w, cache, &dlogits, tokens, &plan, &cplan, &mut meter, b, t)?;

    let d = mm.dims.d_model;
    let hd = mm.head_dim();
    let ff = mm.dims.d_ff;
    let nh = mm.dims.n_heads;
    let l = mm.dims.n_layers;
    let gradf = |name: String| -> Result<&Vec<f32>> {
        grads.get(&name).ok_or_else(|| anyhow!("native: no gradient for {name:?}"))
    };
    let mut head = Vec::with_capacity(l * nh);
    let mut chan = Vec::with_capacity(l * ff);
    for i in 0..l {
        head.extend(sparsity::strategy::head_unit_scores(
            gradf(format!("L{i}.wo"))?,
            d,
            hd,
            nh,
        ));
        chan.extend(sparsity::strategy::chan_unit_scores(
            gradf(format!("L{i}.wu"))?,
            gradf(format!("L{i}.wg"))?,
            gradf(format!("L{i}.wd"))?,
            d,
            ff,
        ));
    }
    let mut out = HashMap::new();
    out.insert("head_grad_norms".to_string(), Tensor::f32(vec![l, nh], head));
    out.insert("chan_grad_norms".to_string(), Tensor::f32(vec![l, ff], chan));
    Ok(out)
}

// ---------------------------------------------------------------------------
// Prepare: base layout -> method layout (trainable-first co-permutation)
// ---------------------------------------------------------------------------

use crate::sparsity::{gather_cols as permute_cols, gather_rows as permute_rows};

/// Split base params into (trainable, frozen, perms) — the S²FT
/// trainable-first co-permutation, or a passthrough for full FT.
pub fn prepare(
    mm: &ModelMeta,
    meth: &MethodMeta,
    named: &Named,
) -> Result<HashMap<String, Tensor>> {
    if meth.method == "fullft" {
        let mut out = HashMap::new();
        for s in &mm.base_params {
            out.insert(s.name.clone(), get(named, &s.name)?.clone());
        }
        return Ok(out);
    }

    let d = mm.dims.d_model;
    let hd = mm.head_dim();
    let ff = mm.dims.d_ff;
    let seed = get(named, "seed")?.as_i32()?[0] as u32 as u64;
    let counts = crate::adapter::s2ft_counts(mm, meth);
    let mha_count = MHA_PROJS.iter().find_map(|p| counts.get(*p)).copied().unwrap_or(0);
    let ffn_count = FFN_PROJS.iter().find_map(|p| counts.get(*p)).copied().unwrap_or(0);

    let mut staged: HashMap<String, Tensor> = HashMap::new();
    for s in &mm.base_params {
        staged.insert(s.name.clone(), get(named, &s.name)?.clone());
    }
    let root = Rng::seed(seed ^ sparsity::strategy::SELECTION_STREAM);
    for i in 0..mm.dims.n_layers {
        if mha_count > 0 {
            let wo = getf(named, &format!("L{i}.wo"))?;
            let sel = sparsity::strategy::select_units(
                &meth.selection,
                meth.select_small,
                mm.dims.n_heads,
                mha_count,
                || sparsity::strategy::head_unit_scores(wo, d, hd, mm.dims.n_heads),
                &mut root.fold(2 * i as u64),
            )?;
            let hperm = sparsity::trainable_first_permutation(&sel, mm.dims.n_heads)?;
            let eperm = sparsity::expand_head_perm(&hperm, hd);
            for p in ["wq", "wk", "wv"] {
                let wsrc = getf(named, &format!("L{i}.{p}"))?;
                staged.insert(
                    format!("L{i}.{p}"),
                    Tensor::f32(vec![d, d], permute_cols(wsrc, d, d, &eperm)),
                );
            }
            staged.insert(
                format!("L{i}.wo"),
                Tensor::f32(vec![d, d], permute_rows(wo, d, &eperm)),
            );
            staged.insert(
                format!("L{i}.head_perm"),
                Tensor::i32(
                    vec![mm.dims.n_heads],
                    hperm.iter().map(|&x| x as i32).collect(),
                ),
            );
        }
        if ffn_count > 0 {
            let wu = getf(named, &format!("L{i}.wu"))?;
            let wg = getf(named, &format!("L{i}.wg"))?;
            let wd = getf(named, &format!("L{i}.wd"))?;
            let sel = sparsity::strategy::select_units(
                &meth.selection,
                meth.select_small,
                ff,
                ffn_count,
                || sparsity::strategy::chan_unit_scores(wu, wg, wd, d, ff),
                &mut root.fold(2 * i as u64 + 1),
            )?;
            let cperm = sparsity::trainable_first_permutation(&sel, ff)?;
            staged.insert(
                format!("L{i}.wu"),
                Tensor::f32(vec![d, ff], permute_cols(wu, d, ff, &cperm)),
            );
            staged.insert(
                format!("L{i}.wg"),
                Tensor::f32(vec![d, ff], permute_cols(wg, d, ff, &cperm)),
            );
            staged.insert(
                format!("L{i}.wd"),
                Tensor::f32(vec![ff, d], permute_rows(wd, d, &cperm)),
            );
            staged.insert(
                format!("L{i}.chan_perm"),
                Tensor::i32(vec![ff], cperm.iter().map(|&x| x as i32).collect()),
            );
        }
        // split the budgeted projections into (_t, _f)
        for (p, &c) in &counts {
            let name = format!("L{i}.{p}");
            let w = staged
                .remove(&name)
                .ok_or_else(|| anyhow!("native: missing staged {name:?}"))?;
            let rows = if is_mha(p) { c * hd } else { c };
            let (din, dout) = (w.shape[0], w.shape[1]);
            let wv = w.as_f32()?;
            if is_row_split(p) {
                staged.insert(
                    format!("{name}_t"),
                    Tensor::f32(vec![rows, dout], wv[..rows * dout].to_vec()),
                );
                staged.insert(
                    format!("{name}_f"),
                    Tensor::f32(vec![din - rows, dout], wv[rows * dout..].to_vec()),
                );
            } else {
                let all: Vec<usize> = (0..dout).collect();
                staged.insert(
                    format!("{name}_t"),
                    Tensor::f32(vec![din, rows], permute_cols(wv, din, dout, &all[..rows])),
                );
                staged.insert(
                    format!("{name}_f"),
                    Tensor::f32(vec![din, dout - rows], permute_cols(wv, din, dout, &all[rows..])),
                );
            }
        }
    }
    Ok(staged)
}

// ---------------------------------------------------------------------------
// Merge: method layout -> base layout
// ---------------------------------------------------------------------------

/// Invert the co-permutation and re-assemble base-layout weights. Pure
/// index gathers — frozen rows come back bit-identical.
pub fn merge(mm: &ModelMeta, meth: &MethodMeta, named: &Named) -> Result<HashMap<String, Tensor>> {
    let mut out = HashMap::new();
    if meth.method == "fullft" {
        for s in &mm.base_params {
            out.insert(s.name.clone(), get(named, &s.name)?.clone());
        }
        return Ok(out);
    }

    let hd = mm.head_dim();
    for s in &mm.base_params {
        if let Some(t) = named.get(s.name.as_str()) {
            out.insert(s.name.clone(), (*t).clone());
        }
    }
    let unsplit = |name: &str, proj: &str| -> Result<Tensor> {
        let t_name = format!("{name}_t");
        if !named.contains_key(t_name.as_str()) {
            return Ok(get(named, name)?.clone());
        }
        let tt = get(named, &t_name)?;
        let ft = get(named, &format!("{name}_f"))?;
        if is_row_split(proj) {
            let cols = tt.shape[1];
            let mut buf = tt.as_f32()?.to_vec();
            buf.extend_from_slice(ft.as_f32()?);
            Ok(Tensor::f32(vec![tt.shape[0] + ft.shape[0], cols], buf))
        } else {
            let rows = tt.shape[0];
            let (ct, cf) = (tt.shape[1], ft.shape[1]);
            let (tv, fv) = (tt.as_f32()?, ft.as_f32()?);
            let mut buf = Vec::with_capacity(rows * (ct + cf));
            for r in 0..rows {
                buf.extend_from_slice(&tv[r * ct..(r + 1) * ct]);
                buf.extend_from_slice(&fv[r * cf..(r + 1) * cf]);
            }
            Ok(Tensor::f32(vec![rows, ct + cf], buf))
        }
    };
    for i in 0..mm.dims.n_layers {
        if let Some(hp) = named.get(format!("L{i}.head_perm").as_str()) {
            let hperm: Vec<usize> = hp.as_i32()?.iter().map(|&x| x as usize).collect();
            let inv = sparsity::invert_permutation(&sparsity::expand_head_perm(&hperm, hd));
            for p in MHA_PROJS {
                let name = format!("L{i}.{p}");
                let w = unsplit(&name, p)?;
                let (rows, cols) = (w.shape[0], w.shape[1]);
                let data = if is_row_split(p) {
                    permute_rows(w.as_f32()?, cols, &inv)
                } else {
                    permute_cols(w.as_f32()?, rows, cols, &inv)
                };
                out.insert(name, Tensor::f32(vec![rows, cols], data));
            }
        }
        if let Some(cp) = named.get(format!("L{i}.chan_perm").as_str()) {
            let cperm: Vec<usize> = cp.as_i32()?.iter().map(|&x| x as usize).collect();
            let inv = sparsity::invert_permutation(&cperm);
            for p in FFN_PROJS {
                let name = format!("L{i}.{p}");
                let w = unsplit(&name, p)?;
                let (rows, cols) = (w.shape[0], w.shape[1]);
                let data = if is_row_split(p) {
                    permute_rows(w.as_f32()?, cols, &inv)
                } else {
                    permute_cols(w.as_f32()?, rows, cols, &inv)
                };
                out.insert(name, Tensor::f32(vec![rows, cols], data));
            }
        }
    }
    for s in &mm.base_params {
        if !out.contains_key(&s.name) {
            bail!("native merge: could not reassemble {:?}", s.name);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::{is_masked, loss_ncorrect_grad};

    #[test]
    fn is_masked_truth_table() {
        assert!(is_masked(0.0));
        assert!(is_masked(-0.0));
        assert!(is_masked(-1.0));
        assert!(is_masked(f32::NAN));
        assert!(!is_masked(1.0));
        assert!(!is_masked(0.5));
        assert!(!is_masked(f32::INFINITY));
    }

    /// A `-0.0` mask entry must behave exactly like `0.0`: the old
    /// `mask[i] == 0.0` compare got that right only by accident (float
    /// `==` matches both zeros); this pins the behaviour through
    /// `is_masked`, bitwise, on both the eval and the gradient path.
    #[test]
    fn negative_zero_mask_is_bit_identical_to_positive_zero() {
        let n = 3;
        let vocab = 4;
        let logits = vec![
            0.1, -0.7, 2.0, 0.3, // row 0 (kept)
            1.5, 0.2, -0.4, 0.9, // row 1 (masked)
            -2.0, 0.0, 0.25, 1.0, // row 2 (kept)
        ];
        let targets = vec![2, 0, 3];
        let pos = vec![1.0f32, 0.0, 1.0];
        let neg = vec![1.0f32, -0.0, 1.0];
        for want_grad in [false, true] {
            let (l0, c0, g0) = loss_ncorrect_grad(&logits, &targets, &pos, n, vocab, want_grad);
            let (l1, c1, g1) = loss_ncorrect_grad(&logits, &targets, &neg, n, vocab, want_grad);
            assert_eq!(l0.to_bits(), l1.to_bits());
            assert_eq!(c0.to_bits(), c1.to_bits());
            let b0: Option<Vec<u32>> = g0.map(|v| v.iter().map(|x| x.to_bits()).collect());
            let b1: Option<Vec<u32>> = g1.map(|v| v.iter().map(|x| x.to_bits()).collect());
            assert_eq!(b0.is_some(), want_grad);
            assert_eq!(b0, b1);
        }
    }
}
