//! Adapter persistence: compact on-disk format for S²FT adapters.
//!
//! An S²FT adapter is tiny (s·d floats + row ids per layer), so thousands
//! can live on disk next to one base checkpoint — the storage story of
//! paper §6.2 and the backing store of the serve residency manager
//! ([`crate::serve::AdapterRegistry`]).
//!
//! Format (little-endian binary with a JSON header):
//!
//! ```text
//! "S2FT" magic | u32 header_len | header json | payload
//! payload = per-layer blobs: wo_rows u32s, wo_delta f32s,
//!                            wd_rows u32s, wd_delta f32s
//! ```
//!
//! Version 2 (written by [`save_adapter`]) adds `payload_len` (exact
//! byte count after the header) and `checksum` (FNV-1a 64 over the
//! payload, hex string) to the header, so truncation and corruption are
//! detected *before* any weights are decoded. Version 1 files (no
//! length/checksum) remain readable; their per-field bounds checks are
//! the only integrity net. Every failure mode maps to a typed
//! [`PersistError`] (reachable through `anyhow`'s `downcast_ref`), so
//! callers like the residency manager can distinguish "not an adapter
//! file" from "bitrot" instead of receiving garbage weights.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::{S2ftAdapter, S2ftLayerDelta};

const MAGIC: &[u8; 4] = b"S2FT";
/// Format version written by [`save_adapter`].
const WRITE_VERSION: u32 = 2;

/// Typed failure modes of [`load_adapter`], reachable through
/// `anyhow::Error::downcast_ref::<PersistError>()`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PersistError {
    /// Missing magic or too short to hold one — not our format at all.
    NotAdapterFile,
    /// Magic matched but the header declares a version this build
    /// cannot read.
    UnsupportedVersion(u32),
    /// The JSON header is unreadable or missing required fields.
    MalformedHeader(String),
    /// The file ends before the declared payload does.
    Truncated {
        /// Bytes the header (v2) or blob layout (v1) requires.
        needed: usize,
        /// Bytes actually present.
        have: usize,
    },
    /// Extra bytes after the declared payload (v1: after the last blob).
    TrailingBytes(usize),
    /// The payload hash does not match the header's checksum (v2 only).
    ChecksumMismatch {
        /// Checksum recorded in the header.
        expected: u64,
        /// Checksum of the bytes actually on disk.
        computed: u64,
    },
}

impl std::fmt::Display for PersistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PersistError::NotAdapterFile => write!(f, "not an S2FT adapter file"),
            PersistError::UnsupportedVersion(v) => {
                write!(f, "unsupported adapter format version {v}")
            }
            PersistError::MalformedHeader(why) => write!(f, "malformed adapter header: {why}"),
            PersistError::Truncated { needed, have } => {
                write!(f, "truncated adapter file: need {needed} byte(s), have {have}")
            }
            PersistError::TrailingBytes(n) => {
                write!(f, "{n} trailing byte(s) after adapter payload")
            }
            PersistError::ChecksumMismatch { expected, computed } => write!(
                f,
                "adapter payload checksum mismatch: header {expected:#018x}, file {computed:#018x}"
            ),
        }
    }
}

impl std::error::Error for PersistError {}

/// FNV-1a 64-bit over `bytes` — dependency-free, deterministic, fast
/// enough for kilobyte-scale adapter payloads.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Serialize the per-layer blobs (rows as u32, deltas as f32, both
/// little-endian) — the byte stream both format versions share.
fn encode_payload(adapter: &S2ftAdapter) -> Vec<u8> {
    let bytes: usize = adapter
        .layers
        .iter()
        .map(|l| 4 * (l.wo_rows.len() + l.wo_delta.len() + l.wd_rows.len() + l.wd_delta.len()))
        .sum();
    let mut out = Vec::with_capacity(bytes);
    for l in &adapter.layers {
        for &r in &l.wo_rows {
            out.extend_from_slice(&(r as u32).to_le_bytes());
        }
        for &v in &l.wo_delta {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for &r in &l.wd_rows {
            out.extend_from_slice(&(r as u32).to_le_bytes());
        }
        for &v in &l.wd_delta {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

/// Write `adapter` to `path` in the current (v2) format: versioned
/// header with payload length + FNV-1a checksum, then the raw blobs.
/// Parent directories are created as needed.
pub fn save_adapter(path: impl AsRef<Path>, adapter: &S2ftAdapter) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let payload = encode_payload(adapter);
    let header = Json::obj(vec![
        ("version", Json::num(WRITE_VERSION as f64)),
        ("d_model", Json::num(adapter.d_model as f64)),
        ("n_layers", Json::num(adapter.layers.len() as f64)),
        (
            "layer_shapes",
            Json::Arr(
                adapter
                    .layers
                    .iter()
                    .map(|l| {
                        Json::Arr(vec![
                            Json::num(l.wo_rows.len() as f64),
                            Json::num(l.wd_rows.len() as f64),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("payload_len", Json::num(payload.len() as f64)),
        // hex string: a u64 cannot round-trip exactly through JSON's f64
        ("checksum", Json::str(format!("{:016x}", fnv1a64(&payload)))),
    ])
    .to_string();
    let mut f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(header.len() as u32).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    f.write_all(&payload)?;
    Ok(())
}

/// Read an adapter written by [`save_adapter`] (v2, length + checksum
/// validated before decoding) or by the pre-checksum v1 writer
/// (bounds-checked per field). Corrupt, truncated or foreign files
/// return a typed [`PersistError`] instead of garbage weights.
pub fn load_adapter(path: impl AsRef<Path>) -> Result<S2ftAdapter> {
    let mut bytes = Vec::new();
    std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?
        .read_to_end(&mut bytes)?;
    decode_adapter(&bytes).with_context(|| format!("loading {:?}", path.as_ref()))
}

fn decode_adapter(bytes: &[u8]) -> Result<S2ftAdapter> {
    if bytes.len() < 8 || &bytes[..4] != MAGIC {
        return Err(PersistError::NotAdapterFile.into());
    }
    let hlen = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
    if bytes.len() < 8 + hlen {
        return Err(PersistError::Truncated { needed: 8 + hlen, have: bytes.len() }.into());
    }
    let htext = std::str::from_utf8(&bytes[8..8 + hlen])
        .map_err(|e| PersistError::MalformedHeader(e.to_string()))?;
    let header =
        Json::parse(htext).map_err(|e| PersistError::MalformedHeader(format!("{e:#}")))?;
    let version = header.num_or("version", 0.0) as u32;
    if version == 0 || version > WRITE_VERSION {
        return Err(PersistError::UnsupportedVersion(version).into());
    }
    let payload = &bytes[8 + hlen..];
    if version >= 2 {
        // integrity first: length, then checksum, before any decoding
        let declared = header
            .get("payload_len")
            .and_then(|j| j.as_usize())
            .map_err(|_| PersistError::MalformedHeader("missing payload_len".into()))?;
        match payload.len() {
            have if have < declared => {
                return Err(
                    PersistError::Truncated { needed: 8 + hlen + declared, have: bytes.len() }
                        .into(),
                );
            }
            have if have > declared => {
                return Err(PersistError::TrailingBytes(payload.len() - declared).into());
            }
            _ => {}
        }
        let expected = header
            .get("checksum")
            .ok()
            .and_then(|j| j.as_str().ok())
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .ok_or_else(|| PersistError::MalformedHeader("missing checksum".into()))?;
        let computed = fnv1a64(payload);
        if computed != expected {
            return Err(PersistError::ChecksumMismatch { expected, computed }.into());
        }
    }
    let d = header
        .get("d_model")
        .and_then(|j| j.as_usize())
        .map_err(|_| PersistError::MalformedHeader("missing d_model".into()))?;
    let shapes = header
        .get("layer_shapes")
        .and_then(|j| j.as_arr().map(|a| a.to_vec()))
        .map_err(|_| PersistError::MalformedHeader("missing layer_shapes".into()))?;
    let mut off = 0usize;
    let mut layers = Vec::with_capacity(shapes.len());
    let take_u32s = |off: &mut usize, n: usize| -> Result<Vec<usize>> {
        if *off + 4 * n > payload.len() {
            return Err(PersistError::Truncated {
                needed: 8 + hlen + *off + 4 * n,
                have: bytes.len(),
            }
            .into());
        }
        let out = (0..n)
            .map(|k| {
                let at = *off + 4 * k;
                u32::from_le_bytes(payload[at..at + 4].try_into().unwrap()) as usize
            })
            .collect();
        *off += 4 * n;
        Ok(out)
    };
    let take_f32s = |off: &mut usize, n: usize| -> Result<Vec<f32>> {
        if *off + 4 * n > payload.len() {
            return Err(PersistError::Truncated {
                needed: 8 + hlen + *off + 4 * n,
                have: bytes.len(),
            }
            .into());
        }
        let out = (0..n)
            .map(|k| {
                let at = *off + 4 * k;
                f32::from_le_bytes(payload[at..at + 4].try_into().unwrap())
            })
            .collect();
        *off += 4 * n;
        Ok(out)
    };
    for s in &shapes {
        let a = s
            .as_arr()
            .map_err(|_| PersistError::MalformedHeader("bad layer_shapes entry".into()))?;
        if a.len() != 2 {
            return Err(PersistError::MalformedHeader("bad layer_shapes entry".into()).into());
        }
        let (n_wo, n_wd) = (
            a[0].as_usize()
                .map_err(|_| PersistError::MalformedHeader("bad layer_shapes entry".into()))?,
            a[1].as_usize()
                .map_err(|_| PersistError::MalformedHeader("bad layer_shapes entry".into()))?,
        );
        let wo_rows = take_u32s(&mut off, n_wo)?;
        let wo_delta = take_f32s(&mut off, n_wo * d)?;
        let wd_rows = take_u32s(&mut off, n_wd)?;
        let wd_delta = take_f32s(&mut off, n_wd * d)?;
        layers.push(S2ftLayerDelta { wo_rows, wo_delta, wd_rows, wd_delta });
    }
    if off != payload.len() {
        return Err(PersistError::TrailingBytes(payload.len() - off).into());
    }
    Ok(S2ftAdapter { layers, d_model: d })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample(seed: u64) -> S2ftAdapter {
        let mut rng = Rng::seed(seed);
        let d = 16;
        let layers = (0..3)
            .map(|_| {
                let s = 1 + rng.below(3);
                let c = 1 + rng.below(4);
                S2ftLayerDelta {
                    wo_rows: rng.choose(d, s),
                    wo_delta: (0..s * d).map(|_| rng.normal_f32()).collect(),
                    wd_rows: rng.choose(24, c),
                    wd_delta: (0..c * d).map(|_| rng.normal_f32()).collect(),
                }
            })
            .collect();
        S2ftAdapter { layers, d_model: d }
    }

    fn assert_same(a: &S2ftAdapter, b: &S2ftAdapter) {
        assert_eq!(a.d_model, b.d_model);
        assert_eq!(a.layers.len(), b.layers.len());
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.wo_rows, y.wo_rows);
            assert_eq!(x.wo_delta, y.wo_delta);
            assert_eq!(x.wd_rows, y.wd_rows);
            assert_eq!(x.wd_delta, y.wd_delta);
        }
    }

    /// Replicate the pre-checksum v1 writer byte-for-byte, so the
    /// backward-compat path is pinned against real old files.
    fn save_v1(path: &std::path::Path, adapter: &S2ftAdapter) {
        let header = Json::obj(vec![
            ("version", Json::num(1.0)),
            ("d_model", Json::num(adapter.d_model as f64)),
            ("n_layers", Json::num(adapter.layers.len() as f64)),
            (
                "layer_shapes",
                Json::Arr(
                    adapter
                        .layers
                        .iter()
                        .map(|l| {
                            Json::Arr(vec![
                                Json::num(l.wo_rows.len() as f64),
                                Json::num(l.wd_rows.len() as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
        .to_string();
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.extend_from_slice(&encode_payload(adapter));
        std::fs::write(path, out).unwrap();
    }

    fn kind(err: &anyhow::Error) -> PersistError {
        err.downcast_ref::<PersistError>()
            .unwrap_or_else(|| panic!("untyped persist error: {err:#}"))
            .clone()
    }

    #[test]
    fn roundtrip_exact() {
        let dir = std::env::temp_dir().join(format!("adapter_{}", std::process::id()));
        let path = dir.join("a.s2ft");
        let a = sample(1);
        save_adapter(&path, &a).unwrap();
        let b = load_adapter(&path).unwrap();
        assert_same(&a, &b);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reads_legacy_v1_files() {
        let dir = std::env::temp_dir().join(format!("adapter_v1_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("old.s2ft");
        let a = sample(7);
        save_v1(&path, &a);
        let b = load_adapter(&path).unwrap();
        assert_same(&a, &b);
        // v1 truncation is still caught by the per-field bounds checks
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let err = load_adapter(&path).unwrap_err();
        assert!(matches!(kind(&err), PersistError::Truncated { .. }), "{err:#}");
        // v1 trailing garbage is rejected too
        let mut grown = bytes.clone();
        grown.extend_from_slice(&[0u8; 3]);
        std::fs::write(&path, &grown).unwrap();
        let err = load_adapter(&path).unwrap_err();
        assert_eq!(kind(&err), PersistError::TrailingBytes(3));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn typed_errors_for_corruption() {
        let dir = std::env::temp_dir().join(format!("adapter_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.s2ft");

        // wrong magic
        std::fs::write(&path, b"NOPE1234").unwrap();
        let err = load_adapter(&path).unwrap_err();
        assert_eq!(kind(&err), PersistError::NotAdapterFile);

        // truncated payload: the v2 length check fires before decoding
        let a = sample(2);
        save_adapter(&path, &a).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let err = load_adapter(&path).unwrap_err();
        assert!(matches!(kind(&err), PersistError::Truncated { .. }), "{err:#}");

        // single flipped payload byte: checksum mismatch
        let mut flipped = bytes.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x40;
        std::fs::write(&path, &flipped).unwrap();
        let err = load_adapter(&path).unwrap_err();
        assert!(matches!(kind(&err), PersistError::ChecksumMismatch { .. }), "{err:#}");

        // trailing bytes beyond the declared payload
        let mut grown = bytes.clone();
        grown.push(0xAB);
        std::fs::write(&path, &grown).unwrap();
        let err = load_adapter(&path).unwrap_err();
        assert_eq!(kind(&err), PersistError::TrailingBytes(1));

        // future version
        let mut future = bytes.clone();
        // patch the header text in place: "version":2 -> "version":9
        let htext = String::from_utf8(bytes[8..].to_vec()).unwrap();
        let vpos = 8 + htext.find("\"version\":2").unwrap() + "\"version\":".len();
        future[vpos] = b'9';
        std::fs::write(&path, &future).unwrap();
        let err = load_adapter(&path).unwrap_err();
        assert_eq!(kind(&err), PersistError::UnsupportedVersion(9));

        // header declares itself longer than the file
        std::fs::write(&path, [MAGIC.as_slice(), 500u32.to_le_bytes().as_slice()].concat())
            .unwrap();
        let err = load_adapter(&path).unwrap_err();
        assert!(matches!(kind(&err), PersistError::Truncated { .. }), "{err:#}");

        // unparseable header json
        let mut badhdr = Vec::new();
        badhdr.extend_from_slice(MAGIC);
        badhdr.extend_from_slice(&3u32.to_le_bytes());
        badhdr.extend_from_slice(b"{{{");
        std::fs::write(&path, &badhdr).unwrap();
        let err = load_adapter(&path).unwrap_err();
        assert!(matches!(kind(&err), PersistError::MalformedHeader(_)), "{err:#}");

        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// The checksum is over the payload, so editing header whitespace or
    /// key order must not fail the integrity check (only payload bitrot
    /// does).
    #[test]
    fn checksum_covers_payload_only() {
        let dir = std::env::temp_dir().join(format!("adapter_hdr_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("h.s2ft");
        let a = sample(3);
        save_adapter(&path, &a).unwrap();
        let b = load_adapter(&path).unwrap();
        assert_same(&a, &b);
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325, "FNV offset basis");
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c, "FNV-1a reference vector");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
