//! Trainer-lifecycle integration tests: prepare -> train -> merge -> eval
//! -> adapter extraction, exercising the exact production code path.
//!
//! The native-backend tests are hermetic (default features) and cover the
//! methods the interpreter implements (fullft, s2ft) plus the paper's core
//! S²FT invariant: an optimizer step moves only the selected
//! trainable-first rows of wo/wd — every frozen row stays bit-identical.
//! The pjrt module re-runs the full method set against real AOT artifacts
//! when they exist.

use std::collections::HashMap;

use repro::adapter::{load_adapter, s2ft_counts, save_adapter, S2ftAdapter};
use repro::data::{lm_batch, pretrain_corpus, Tokenizer};
use repro::runtime::{Executable, Executor, NativeBackend, Tensor};
use repro::sparsity;
use repro::train::{load_params, save_params, GenModel, Trainer};
use repro::util::rng::Rng;

fn base_params(rt: &dyn Executor, seed: i32) -> HashMap<String, Tensor> {
    let init = rt.load("init_tiny").unwrap();
    let outs = init.run(&[Tensor::scalar_i32(seed)]).unwrap();
    init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect()
}

fn train_n(
    rt: &dyn Executor,
    method: &str,
    steps: usize,
) -> (Trainer, HashMap<String, Tensor>) {
    let base = base_params(rt, 7);
    let (b, t) = rt.artifacts().model("tiny").unwrap().default_batch();
    let tk = Tokenizer;
    let corpus = pretrain_corpus(1, 50_000);
    let mut rng = Rng::seed(9);
    let calib = lm_batch(&tk, &corpus, &mut rng, b, t);
    let mut trainer = Trainer::new(rt, "tiny", method, &base, 5, &calib).unwrap();
    for _ in 0..steps {
        let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
        trainer.train_step(&batch).unwrap();
    }
    (trainer, base)
}

fn methods_reduce_lm_loss(rt: &dyn Executor, methods: &[&str], steps: usize) {
    for &method in methods {
        let (trainer, _) = train_n(rt, method, steps);
        let first = trainer.metrics.losses[0];
        let last = trainer.metrics.last_loss();
        assert!(
            last < first,
            "{method}: loss did not decrease ({first} -> {last})"
        );
        assert!(last.is_finite(), "{method}: non-finite loss");
        // free cached executables between methods (memory hygiene)
        let (b, t) = rt.artifacts().model("tiny").unwrap().default_batch();
        rt.evict(&format!("train_tiny_{method}_{b}x{t}"));
    }
}

fn merge_changes_only_selected_rows_for_s2ft(rt: &dyn Executor) {
    let (trainer, base) = train_n(rt, "s2ft", 2);
    let merged = trainer.merged_params(rt).unwrap();
    let mm = rt.artifacts().model("tiny").unwrap();
    let method = mm.method("s2ft").unwrap();
    // adapter extraction + application reproduces the merged weights
    let adapter = S2ftAdapter::extract(mm, method, &trainer.perms, &base, &merged).unwrap();
    let mut rebuilt = base.clone();
    adapter.apply(&mut rebuilt).unwrap();
    for (k, v) in &merged {
        let a = v.as_f32().unwrap();
        let b = rebuilt[k].as_f32().unwrap();
        let max = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
        assert!(max < 1e-5, "{k}: adapter apply drifted by {max}");
    }
    // frozen tensors (embed, norms, non-target projections) are untouched
    for k in ["embed", "norm_f", "L0.wq", "L0.norm1"] {
        assert_eq!(
            merged[k].as_f32().unwrap(),
            base[k].as_f32().unwrap(),
            "{k} must stay frozen under s2ft"
        );
    }
}

fn adapter_persists_through_disk(rt: &dyn Executor) {
    let (trainer, base) = train_n(rt, "s2ft", 2);
    let merged = trainer.merged_params(rt).unwrap();
    let mm = rt.artifacts().model("tiny").unwrap();
    let method = mm.method("s2ft").unwrap();
    let adapter = S2ftAdapter::extract(mm, method, &trainer.perms, &base, &merged).unwrap();

    let dir = std::env::temp_dir().join(format!(
        "adapter_it_{}_{}",
        std::process::id(),
        rt.platform().replace('/', "-")
    ));
    let path = dir.join("a.s2ft");
    save_adapter(&path, &adapter).unwrap();
    let loaded = load_adapter(&path).unwrap();
    let mut p1 = base.clone();
    adapter.apply(&mut p1).unwrap();
    let mut p2 = base.clone();
    loaded.apply(&mut p2).unwrap();
    for (k, v) in &p1 {
        assert_eq!(v.as_f32().unwrap(), p2[k].as_f32().unwrap(), "{k}");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

fn checkpoint_roundtrip_preserves_eval(rt: &dyn Executor, method: &str) {
    let (trainer, _) = train_n(rt, method, 2);
    let merged = trainer.merged_params(rt).unwrap();
    let dir = std::env::temp_dir().join(format!(
        "ckpt_it_{}_{}",
        std::process::id(),
        rt.platform().replace('/', "-")
    ));
    save_params(&dir, &merged).unwrap();
    let loaded = load_params(&dir).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();

    let (b, t) = rt.artifacts().model("tiny").unwrap().default_batch();
    let tk = Tokenizer;
    let corpus = pretrain_corpus(1, 50_000);
    let mut rng = Rng::seed(11);
    let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
    let m1 = GenModel::new(rt, "tiny", merged).unwrap();
    let m2 = GenModel::new(rt, "tiny", loaded).unwrap();
    let (l1, _) = m1.eval_batch(&batch).unwrap();
    let (l2, _) = m2.eval_batch(&batch).unwrap();
    assert!((l1 - l2).abs() < 1e-6, "{l1} vs {l2}");
}

fn generate_is_deterministic_and_bounded(rt: &dyn Executor) {
    let base = base_params(rt, 7);
    let model = GenModel::new(rt, "tiny", base).unwrap();
    let prompts = vec!["q: 1 + 1 =".to_string(), "hello".to_string()];
    let a = model.generate(&prompts, 5).unwrap();
    let b = model.generate(&prompts, 5).unwrap();
    assert_eq!(a, b, "greedy decode must be deterministic");
    assert!(a.iter().all(|s| s.len() <= 5));
}

/// Tentpole regression: routing the static S²FT selection through the
/// `SelectionStrategy` trait ([`Trainer::with_strategy`] + host-side pool
/// build) is bit-identical to the pre-refactor prepare-artifact path —
/// same selection stream, same permutations, same per-step losses, same
/// measured act_bytes, same merged weights.
fn static_strategy_matches_prepare_path_bitwise(rt: &dyn Executor) {
    use repro::sparsity::strategy;

    let base = base_params(rt, 7);
    let mm = rt.artifacts().model("tiny").unwrap();
    let meth = mm.method("s2ft").unwrap().clone();
    let (b, t) = mm.default_batch();
    let n_layers = mm.dims.n_layers;
    let tk = Tokenizer;
    let corpus = pretrain_corpus(1, 50_000);
    let mut rng = Rng::seed(9);
    let calib = lm_batch(&tk, &corpus, &mut rng, b, t);
    let batches: Vec<_> = (0..4).map(|_| lm_batch(&tk, &corpus, &mut rng, b, t)).collect();

    let mut classic = Trainer::new(rt, "tiny", "s2ft", &base, 5, &calib).unwrap();
    let strat = strategy::for_name("static", &meth.selection, meth.select_small).unwrap();
    let mut routed =
        Trainer::with_strategy(rt, "tiny", "s2ft", &base, 5, strat, 0, b, t).unwrap();

    // identical permutations => identical selection stream
    for i in 0..n_layers {
        for name in [format!("L{i}.head_perm"), format!("L{i}.chan_perm")] {
            assert_eq!(
                classic.perms[&name].as_i32().unwrap(),
                routed.perms[&name].as_i32().unwrap(),
                "{name} differs between prepare path and StaticS2ft"
            );
        }
    }
    for batch in &batches {
        let l1 = classic.train_step(batch).unwrap();
        let l2 = routed.train_step(batch).unwrap();
        assert_eq!(l1.to_bits(), l2.to_bits(), "loss trajectory drifted");
    }
    assert_eq!(classic.activation_bytes(), routed.activation_bytes());
    assert_eq!(classic.trainable_params(), routed.trainable_params());
    // trainable weights + moments (the updated state) bit-identical
    for i in 0..n_layers {
        for p in ["wo", "wd"] {
            for key in
                [format!("L{i}.{p}_t"), format!("m.L{i}.{p}_t"), format!("v.L{i}.{p}_t")]
            {
                let a = classic.tensor(&key).unwrap().as_f32().unwrap();
                let b = routed.tensor(&key).unwrap().as_f32().unwrap();
                assert!(
                    a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{key} drifted between prepare path and StaticS2ft"
                );
            }
        }
    }
    // merged params bit-identical (merge artifact vs host merge)
    let m1 = classic.merged_params(rt).unwrap();
    let m2 = routed.merged_params(rt).unwrap();
    for (k, v) in &m1 {
        let a = v.as_f32().unwrap();
        let b = m2[k].as_f32().unwrap();
        assert!(
            a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits()),
            "merged {k} drifted between merge artifact and host merge"
        );
    }
}

fn opt_state_sizes_reflect_method_memory_story(rt: &dyn Executor) {
    let (full, _) = train_n(rt, "fullft", 1);
    let (s2ft, _) = train_n(rt, "s2ft", 1);
    // the paper's Fig 5 memory structure, enforced as an invariant:
    assert!(
        s2ft.opt_bytes() * 3 < full.opt_bytes(),
        "s2ft opt state must be far smaller"
    );
    assert!(s2ft.state_bytes() < full.state_bytes());
}

// --- native backend (hermetic) ---------------------------------------------

mod native {
    use super::*;

    fn backend() -> NativeBackend {
        NativeBackend::builtin()
    }

    #[test]
    fn native_methods_reduce_lm_loss() {
        methods_reduce_lm_loss(&backend(), &["fullft", "s2ft"], 6);
    }

    #[test]
    fn merge_changes_only_selected_rows_for_s2ft() {
        super::merge_changes_only_selected_rows_for_s2ft(&backend());
    }

    #[test]
    fn adapter_persists_through_disk() {
        super::adapter_persists_through_disk(&backend());
    }

    #[test]
    fn checkpoint_roundtrip_preserves_eval() {
        super::checkpoint_roundtrip_preserves_eval(&backend(), "fullft");
    }

    #[test]
    fn generate_is_deterministic_and_bounded() {
        super::generate_is_deterministic_and_bounded(&backend());
    }

    #[test]
    fn opt_state_sizes_reflect_method_memory_story() {
        super::opt_state_sizes_reflect_method_memory_story(&backend());
    }

    #[test]
    fn static_strategy_matches_prepare_path_bitwise() {
        super::static_strategy_matches_prepare_path_bitwise(&backend());
    }

    /// A shape-changing strategy (grad-norm warmup commits a narrower
    /// layout than its dense-ish start) swaps in a method-layout variant
    /// executable and keeps training: the end-to-end dynamic path.
    #[test]
    fn warmup_strategy_commits_and_keeps_training() {
        use repro::data::{lm_batch, pretrain_corpus};
        use repro::sparsity::strategy;

        let rt = backend();
        let base = super::base_params(&rt, 7);
        let mm = rt.artifacts().model("tiny").unwrap();
        let meth = mm.method("s2ft").unwrap().clone();
        let (b, t) = mm.default_batch();
        let tk = Tokenizer;
        let corpus = pretrain_corpus(1, 50_000);
        let mut rng = Rng::seed(9);

        let strat = strategy::for_name("warmup:2", &meth.selection, meth.select_small).unwrap();
        let mut tr = Trainer::with_strategy(&rt, "tiny", "s2ft", &base, 5, strat, 0, b, t).unwrap();
        let warm_trainable = tr.trainable_params();
        for _ in 0..5 {
            let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
            tr.maybe_replan(&rt, &batch).unwrap();
            tr.train_step(&batch).unwrap();
        }
        assert_eq!(tr.metrics.replans, 1, "warmup must commit exactly once");
        assert_eq!(tr.metrics.shape_changing_replans, 1);
        assert_eq!(tr.plan_epoch(), 1);
        // warmup starts dense-ish (total-1 units) and commits the base
        // method's budget => trainable count must shrink
        assert!(
            tr.trainable_params() < warm_trainable,
            "commit must shrink the trainable set ({warm_trainable} -> {})",
            tr.trainable_params()
        );
        assert!(tr.metrics.last_loss().is_finite());
        // post-commit selections carry the budgeted counts
        let sels = tr.selections().unwrap();
        let counts = s2ft_counts(mm, &meth);
        for s in sels {
            assert_eq!(s.heads.len(), counts.get("wo").copied().unwrap_or(0));
            assert_eq!(s.channels.len(), counts.get("wd").copied().unwrap_or(0));
        }
        // merged params stay base-shaped after the variant swap
        let merged = tr.merged_params(&rt).unwrap();
        for s in &mm.base_params {
            assert_eq!(merged[&s.name].shape, s.shape, "{} shape", s.name);
        }
    }

    /// Acceptance invariant (paper §3.3): one S²FT train step moves ONLY
    /// the selected trainable-first rows of wo/wd; every frozen row of the
    /// merged weights is *bit-identical* to the base weights, and eval
    /// loss at random init sits near ln(vocab).
    #[test]
    fn s2ft_partial_update_touches_only_selected_rows() {
        let rt = backend();
        let (trainer, base) = train_n(&rt, "s2ft", 1);
        let merged = trainer.merged_params(&rt).unwrap();
        let mm = rt.artifacts().model("tiny").unwrap();
        let method = mm.method("s2ft").unwrap();
        let counts = s2ft_counts(mm, method);
        let hd = mm.head_dim();
        let d = mm.dims.d_model;
        let mut changed_rows = 0usize;
        for i in 0..mm.dims.n_layers {
            // wo: selected heads -> element rows through the head perm
            let hp: Vec<usize> = trainer.perms[&format!("L{i}.head_perm")]
                .as_i32()
                .unwrap()
                .iter()
                .map(|&x| x as usize)
                .collect();
            let sel = sparsity::selected_units(&hp, counts["wo"]);
            let sel_rows: std::collections::HashSet<usize> =
                sparsity::expand_head_perm(&sel, hd).into_iter().collect();
            let wb = base[&format!("L{i}.wo")].as_f32().unwrap();
            let wm = merged[&format!("L{i}.wo")].as_f32().unwrap();
            for r in 0..d {
                let same_bits = wb[r * d..(r + 1) * d]
                    .iter()
                    .zip(&wm[r * d..(r + 1) * d])
                    .all(|(x, y)| x.to_bits() == y.to_bits());
                if sel_rows.contains(&r) {
                    if !same_bits {
                        changed_rows += 1;
                    }
                } else {
                    assert!(same_bits, "L{i}.wo frozen row {r} drifted");
                }
            }
            // wd: selected channels are rows directly
            let cp: Vec<usize> = trainer.perms[&format!("L{i}.chan_perm")]
                .as_i32()
                .unwrap()
                .iter()
                .map(|&x| x as usize)
                .collect();
            let sel_wd: std::collections::HashSet<usize> =
                sparsity::selected_units(&cp, counts["wd"]).into_iter().collect();
            let wb = base[&format!("L{i}.wd")].as_f32().unwrap();
            let wm = merged[&format!("L{i}.wd")].as_f32().unwrap();
            for r in 0..mm.dims.d_ff {
                let same_bits = wb[r * d..(r + 1) * d]
                    .iter()
                    .zip(&wm[r * d..(r + 1) * d])
                    .all(|(x, y)| x.to_bits() == y.to_bits());
                if sel_wd.contains(&r) {
                    if !same_bits {
                        changed_rows += 1;
                    }
                } else {
                    assert!(same_bits, "L{i}.wd frozen row {r} drifted");
                }
            }
        }
        assert!(changed_rows > 0, "no selected row moved — the step was a no-op");

        // random-init eval loss near ln(vocab)
        let (b, t) = mm.default_batch();
        let tk = Tokenizer;
        let corpus = pretrain_corpus(3, 50_000);
        let mut rng = Rng::seed(21);
        let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
        let gm = GenModel::new(&rt, "tiny", base).unwrap();
        let (loss, _) = gm.eval_batch(&batch).unwrap();
        let expect = (mm.dims.vocab as f32).ln();
        assert!(
            (loss - expect).abs() < 1.0,
            "random-init eval loss {loss} vs ln(vocab) {expect}"
        );
    }

    /// Regression: per-step inputs (tokens/targets/loss_mask/step — and
    /// LISA's layer_mask) must never leak into the persistent pool, so
    /// the Fig 5 analytic number `state_bytes()` is identical before and
    /// after a train step.
    #[test]
    fn state_bytes_identical_before_and_after_train_step() {
        let rt = backend();
        for method in ["fullft", "s2ft"] {
            let base = base_params(&rt, 7);
            let (b, t) = rt.artifacts().model("tiny").unwrap().default_batch();
            let tk = Tokenizer;
            let corpus = pretrain_corpus(1, 50_000);
            let mut rng = Rng::seed(9);
            let calib = lm_batch(&tk, &corpus, &mut rng, b, t);
            let mut trainer = Trainer::new(&rt, "tiny", method, &base, 5, &calib).unwrap();
            let before = trainer.state_bytes();
            let opt_before = trainer.opt_bytes();
            for _ in 0..2 {
                let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
                trainer.train_step(&batch).unwrap();
            }
            assert_eq!(
                before,
                trainer.state_bytes(),
                "{method}: state_bytes absorbed batch inputs"
            );
            assert_eq!(opt_before, trainer.opt_bytes(), "{method}: opt_bytes drifted");
        }
    }

    /// AdamW first step runs bias correction at t = 1 (not 0): the very
    /// first update and both moments must come out finite.
    #[test]
    fn first_train_step_is_finite() {
        let rt = backend();
        for method in ["fullft", "s2ft"] {
            let (trainer, _) = train_n(&rt, method, 1);
            assert!(trainer.metrics.last_loss().is_finite(), "{method}: loss");
            let mm = rt.artifacts().model("tiny").unwrap();
            for s in &mm.method(method).unwrap().trainable {
                for pre in ["", "m.", "v."] {
                    let t = trainer.tensor(&format!("{pre}{}", s.name)).unwrap();
                    assert!(
                        t.as_f32().unwrap().iter().all(|v| v.is_finite()),
                        "{method}: {pre}{} not finite after the first step",
                        s.name
                    );
                }
            }
        }
    }

    /// A step counter that would put the AdamW bias correction at t < 1
    /// is rejected instead of silently producing inf/NaN moments.
    #[test]
    fn negative_step_is_rejected() {
        let rt = backend();
        let base = base_params(&rt, 7);
        let (b, t) = rt.artifacts().model("tiny").unwrap().default_batch();
        let exe = rt.load(&format!("train_tiny_fullft_{b}x{t}")).unwrap();
        let mm = rt.artifacts().model("tiny").unwrap();
        let mut pool = base.clone();
        for o in &mm.method("fullft").unwrap().opt {
            pool.insert(format!("m.{}", o.name), Tensor::zeros(o.shape.clone()));
            pool.insert(format!("v.{}", o.name), Tensor::zeros(o.shape.clone()));
        }
        let tk = Tokenizer;
        let corpus = pretrain_corpus(1, 50_000);
        let mut rng = Rng::seed(4);
        let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
        pool.insert("tokens".to_string(), batch.tokens);
        pool.insert("targets".to_string(), batch.targets);
        pool.insert("loss_mask".to_string(), batch.loss_mask);
        pool.insert("step".to_string(), Tensor::scalar_f32(-1.0));
        let err = exe.run_named(&pool).unwrap_err();
        assert!(
            format!("{err:#}").contains("bias-correction"),
            "unexpected error: {err:#}"
        );
        // step = 0 (t = 1) is the valid first step
        pool.insert("step".to_string(), Tensor::scalar_f32(0.0));
        assert!(exe.run_named(&pool).is_ok());
    }

    /// Fig 5 measured-memory claim on the native backend: the plan-driven
    /// cache keeps S²FT's retained activation bytes at least 2x below
    /// full FT at the same shape, and the peak never exceeds full FT's.
    #[test]
    fn s2ft_activation_cache_at_least_2x_below_fullft() {
        let rt = backend();
        let (full, _) = train_n(&rt, "fullft", 1);
        let (s2ft, _) = train_n(&rt, "s2ft", 1);
        let (fa, sa) = (
            full.activation_bytes().expect("native reports act bytes"),
            s2ft.activation_bytes().expect("native reports act bytes"),
        );
        assert!(
            sa * 2 <= fa,
            "s2ft activation cache {sa} B not 2x below fullft {fa} B"
        );
        let (fp, sp) = (
            full.activation_peak_bytes().unwrap(),
            s2ft.activation_peak_bytes().unwrap(),
        );
        assert!(sp <= fp, "s2ft peak {sp} B above fullft peak {fp} B");
        assert!(sa <= sp && fa <= fp, "cache bytes cannot exceed live peak");
    }
}

// --- pjrt backend (full method set, requires artifacts) --------------------

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use repro::runtime::Runtime;

    fn runtime() -> Option<Runtime> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("meta.json").exists() {
            eprintln!("skipping pjrt test: no artifacts (run `make artifacts`)");
            return None;
        }
        match Runtime::new(dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping pjrt test: {e:#} (vendor the real xla crate)");
                None
            }
        }
    }

    #[test]
    fn every_method_reduces_lm_loss() {
        let Some(rt) = runtime() else { return };
        methods_reduce_lm_loss(
            &rt,
            &["fullft", "lora", "dora", "spft", "lisa", "galore", "s2ft"],
            8,
        );
    }

    #[test]
    fn s2ft_pallas_matches_native_trajectory() {
        let Some(rt) = runtime() else { return };
        let (plain, _) = train_n(&rt, "s2ft", 4);
        let (pallas, _) = train_n(&rt, "s2ft-pallas", 4);
        for (a, b) in plain.metrics.losses.iter().zip(&pallas.metrics.losses) {
            assert!(
                (a - b).abs() < 1e-4,
                "pallas trajectory diverged: {:?} vs {:?}",
                plain.metrics.losses,
                pallas.metrics.losses
            );
        }
    }

    #[test]
    fn merge_changes_only_selected_rows_for_s2ft() {
        let Some(rt) = runtime() else { return };
        super::merge_changes_only_selected_rows_for_s2ft(&rt);
    }

    #[test]
    fn adapter_persists_through_disk() {
        let Some(rt) = runtime() else { return };
        super::adapter_persists_through_disk(&rt);
    }

    #[test]
    fn checkpoint_roundtrip_preserves_eval() {
        let Some(rt) = runtime() else { return };
        super::checkpoint_roundtrip_preserves_eval(&rt, "fullft");
    }

    #[test]
    fn generate_is_deterministic_and_bounded() {
        let Some(rt) = runtime() else { return };
        super::generate_is_deterministic_and_bounded(&rt);
    }

    #[test]
    fn opt_state_sizes_reflect_method_memory_story() {
        let Some(rt) = runtime() else { return };
        super::opt_state_sizes_reflect_method_memory_story(&rt);
    }
}
