//! Property-based tests over coordinator invariants.
//!
//! The vendored crate set has no `proptest`, so these are seeded
//! generator sweeps (many random cases per property, deterministic seeds,
//! shrink-free but reproducible) — same invariants, zero dependencies.

use std::collections::HashMap;
use std::time::Duration;

use repro::adapter::{S2ftAdapter, S2ftLayerDelta};
use repro::data::batch::encode_example;
use repro::data::tokenizer::{Tokenizer, EOS, PAD, SEP};
use repro::data::{Example, Split, World, ARITHMETIC, COMMONSENSE, INSTRUCT};
use repro::kernels;
use repro::linalg::Mat;
use repro::runtime::{Executable, Executor, NativeBackend, Tensor};
use repro::serve::{AdapterBatcher, KvPoolConfig};
use repro::sparsity;
use repro::train::{DecodeRequest, GenModel};
use repro::util::rng::Rng;

const CASES: usize = 60;

/// Routing invariant: every queued request is emitted exactly once, in
/// FIFO order within its adapter group, with batches never exceeding cap.
#[test]
fn prop_batcher_conserves_requests() {
    for case in 0..CASES {
        let mut rng = Rng::seed(case as u64);
        let n = 1 + rng.below(64);
        let n_adapters = 1 + rng.below(6);
        let cap = 1 + rng.below(8);
        let mut b: AdapterBatcher<usize> = AdapterBatcher::new(cap, Duration::from_secs(60));
        let mut pushed: HashMap<String, Vec<usize>> = HashMap::new();
        for i in 0..n {
            let a = format!("a{}", rng.below(n_adapters));
            b.push(a.clone(), i);
            pushed.entry(a).or_default().push(i);
        }
        let mut drained: HashMap<String, Vec<usize>> = HashMap::new();
        let mut total = 0;
        while let Some(plan) = b.next_batch() {
            assert!(plan.items.len() <= cap, "case {case}: batch over cap");
            assert!(!plan.items.is_empty());
            total += plan.items.len();
            drained
                .entry(plan.adapter.clone())
                .or_default()
                .extend(plan.items.iter().map(|q| q.payload));
        }
        assert_eq!(total, n, "case {case}: lost/duplicated requests");
        for (a, seq) in &drained {
            assert_eq!(seq, &pushed[a], "case {case}: order broken for {a}");
        }
    }
}

/// Residency round-trip invariant: for random adapter geometries and
/// weights (including negative zeros and denormal-scale values), an
/// adapter pushed out of the resident set by LRU pressure and lazily
/// reloaded on acquire is bitwise-identical to the one registered —
/// spill→save→load must not perturb a single mantissa bit.
#[test]
fn prop_registry_spill_reload_bitwise_identical() {
    use repro::adapter::AnyAdapter;
    use repro::serve::{AdapterRegistry, ResidencyConfig};

    let dir = std::env::temp_dir()
        .join(format!("s2ft-prop-spill-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    for case in 0..16 {
        let mut rng = Rng::seed(0x5B11 + case as u64);
        let d = 2 + rng.below(14);
        let n_layers = 1 + rng.below(3);
        let mk = |rng: &mut Rng| {
            let layers = (0..n_layers)
                .map(|_| {
                    let (ko, kd) = (1 + rng.below(d), 1 + rng.below(4));
                    let wo_rows = rng.choose(d, ko);
                    let wd_rows = rng.choose(4 * d, kd);
                    S2ftLayerDelta {
                        wo_delta: (0..wo_rows.len() * d)
                            .map(|_| rng.normal_f32() * 1e-20)
                            .collect(),
                        wo_rows,
                        wd_delta: (0..wd_rows.len() * d).map(|_| -rng.normal_f32()).collect(),
                        wd_rows,
                    }
                })
                .collect();
            S2ftAdapter { layers, d_model: d }
        };
        let originals: Vec<S2ftAdapter> = (0..3).map(|_| mk(&mut rng)).collect();

        let reg = AdapterRegistry::new(ResidencyConfig {
            max_resident: 1,
            spill_dir: Some(dir.clone()),
            ..Default::default()
        });
        for (i, a) in originals.iter().enumerate() {
            reg.insert_resident(format!("c{case}-a{i}"), AnyAdapter::S2ft(a.clone()));
        }
        // registering 3 under budget 1 spilled the two coldest; acquiring
        // in random order churns every one of them through disk
        for _ in 0..6 {
            let i = rng.below(3);
            let lease = reg.acquire(&format!("c{case}-a{i}")).unwrap();
            let handle = lease.handle();
            let AnyAdapter::S2ft(got) = handle.as_ref() else {
                panic!("case {case}: adapter changed kind");
            };
            let want = &originals[i];
            assert_eq!(got.d_model, want.d_model, "case {case} adapter {i}");
            assert_eq!(got.layers.len(), want.layers.len(), "case {case} adapter {i}");
            for (lg, lw) in got.layers.iter().zip(&want.layers) {
                assert_eq!(lg.wo_rows, lw.wo_rows, "case {case} adapter {i}");
                assert_eq!(lg.wd_rows, lw.wd_rows, "case {case} adapter {i}");
                assert!(
                    bits_eq(&lg.wo_delta, &lw.wo_delta) && bits_eq(&lg.wd_delta, &lw.wd_delta),
                    "case {case} adapter {i}: reloaded delta bits diverged"
                );
            }
        }
        let s = reg.stats();
        assert!(s.spills >= 2 && s.loads >= 1, "case {case}: no churn happened: {s:?}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Permutation invariants: trainable-first + inverse compose to identity.
#[test]
fn prop_permutation_roundtrip() {
    for case in 0..CASES {
        let mut rng = Rng::seed(1000 + case as u64);
        let total = 2 + rng.below(128);
        let s = 1 + rng.below(total - 1);
        let sel = rng.choose(total, s);
        let perm = sparsity::trainable_first_permutation(&sel, total).unwrap();
        assert_eq!(&perm[..s], &sel[..]);
        let inv = sparsity::invert_permutation(&perm);
        for i in 0..total {
            assert_eq!(inv[perm[i]], i);
            assert_eq!(perm[inv[i]], i);
        }
        // expanded head perms partition the element range
        let hd = 1 + rng.below(8);
        let e = sparsity::expand_head_perm(&perm, hd);
        let mut sorted = e.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..total * hd).collect::<Vec<_>>());
    }
}

/// Permutation construction rejects duplicates and out-of-range ids, for
/// every position of the offending entry.
#[test]
fn prop_permutation_rejects_bad_input() {
    for case in 0..CASES {
        let mut rng = Rng::seed(1500 + case as u64);
        let total = 2 + rng.below(64);
        let s = 1 + rng.below(total);
        let good = rng.choose(total, s);
        assert!(sparsity::trainable_first_permutation(&good, total).is_ok());
        // out-of-range: corrupt one slot
        let mut oob = good.clone();
        let slot = rng.below(oob.len());
        oob[slot] = total + rng.below(5);
        assert!(
            sparsity::trainable_first_permutation(&oob, total).is_err(),
            "case {case}: accepted out-of-range {oob:?} (total {total})"
        );
        // duplicate: repeat an existing entry somewhere else
        if good.len() >= 2 {
            let mut dup = good.clone();
            let (a, b) = (rng.below(dup.len()), rng.below(dup.len()));
            if a != b {
                dup[a] = dup[b];
                assert!(
                    sparsity::trainable_first_permutation(&dup, total).is_err(),
                    "case {case}: accepted duplicate {dup:?}"
                );
            }
        }
    }
}

/// expand_head_perm has exact block structure: element k*hd + j of the
/// expansion is head_perm[k]*hd + j.
#[test]
fn prop_expand_head_perm_block_structure() {
    for case in 0..CASES {
        let mut rng = Rng::seed(1700 + case as u64);
        let heads = 1 + rng.below(16);
        let hd = 1 + rng.below(16);
        let mut perm: Vec<usize> = (0..heads).collect();
        rng.shuffle(&mut perm);
        let e = sparsity::expand_head_perm(&perm, hd);
        assert_eq!(e.len(), heads * hd);
        for (k, &h) in perm.iter().enumerate() {
            for j in 0..hd {
                assert_eq!(e[k * hd + j], h * hd + j, "case {case}: block ({k},{j})");
            }
        }
    }
}

/// budget_to_counts: positive fractions always yield >=1 unit, never more
/// than the structure size — including fractions above 1.0, which clamp
/// to the unit total instead of overflowing it; zero fractions yield zero.
#[test]
fn prop_budget_to_counts_bounds() {
    for case in 0..CASES {
        let mut rng = Rng::seed(1800 + case as u64);
        let d_ff = 1 + rng.below(512);
        let heads = 1 + rng.below(16);
        let mut fractions = HashMap::new();
        for p in ["wo", "wq", "wd", "wu"] {
            // mix zero, in-range, and over-budget (>1.0) fractions
            let f = if rng.bool(0.3) {
                0.0
            } else if rng.bool(0.25) {
                1.0 + rng.f64() * 9.0
            } else {
                rng.f64()
            };
            fractions.insert(p.to_string(), f);
        }
        let counts = sparsity::budget_to_counts(&fractions, d_ff, heads);
        for (p, &c) in &counts {
            let total = if p == "wo" || p == "wq" { heads } else { d_ff };
            let f = fractions[p];
            if f > 0.0 {
                assert!((1..=total).contains(&c), "case {case}: {p} f={f} c={c}");
                if f >= 1.0 {
                    assert_eq!(c, total, "case {case}: {p} f={f} must clamp to total");
                }
            } else {
                assert_eq!(c, 0, "case {case}: {p}");
            }
        }
    }
}

/// Scatter/gather rows+cols are exact inverses and touch nothing else.
#[test]
fn prop_scatter_gather_isolation() {
    for case in 0..CASES {
        let mut rng = Rng::seed(2000 + case as u64);
        let rows = 2 + rng.below(32);
        let cols = 1 + rng.below(32);
        let s = 1 + rng.below(rows - 1);
        let idx = rng.choose(rows, s);
        let mut w: Vec<f32> = (0..rows * cols).map(|_| rng.normal_f32()).collect();
        let orig = w.clone();
        let delta: Vec<f32> = (0..s * cols).map(|_| rng.normal_f32()).collect();
        sparsity::scatter_add_rows(&mut w, cols, &idx, &delta);
        // untouched rows identical
        for r in 0..rows {
            if !idx.contains(&r) {
                assert_eq!(&w[r * cols..(r + 1) * cols], &orig[r * cols..(r + 1) * cols]);
            }
        }
        assert_eq!(sparsity::gather_rows(&w, cols, &idx).len(), s * cols);
        sparsity::scatter_sub_rows(&mut w, cols, &idx, &delta);
        for (a, b) in w.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}

/// Adapter apply/remove is an exact involution on the weight pool, and
/// fusion of an adapter with weight 1.0 equals the adapter itself.
#[test]
fn prop_adapter_apply_remove_fuse() {
    for case in 0..CASES {
        let mut rng = Rng::seed(3000 + case as u64);
        let d = 4 + rng.below(24);
        let kf = 6 + rng.below(30);
        let n_layers = 1 + rng.below(3);
        let layers: Vec<S2ftLayerDelta> = (0..n_layers)
            .map(|_| {
                let s = 1 + rng.below(3);
                let c = 1 + rng.below(4);
                S2ftLayerDelta {
                    wo_rows: rng.choose(d, s),
                    wo_delta: (0..s * d).map(|_| rng.normal_f32()).collect(),
                    wd_rows: rng.choose(kf, c),
                    wd_delta: (0..c * d).map(|_| rng.normal_f32()).collect(),
                }
            })
            .collect();
        let adapter = S2ftAdapter { layers, d_model: d };
        let mut params: HashMap<String, Tensor> = HashMap::new();
        for i in 0..n_layers {
            params.insert(
                format!("L{i}.wo"),
                Tensor::f32(vec![d, d], (0..d * d).map(|x| x as f32).collect()),
            );
            params.insert(
                format!("L{i}.wd"),
                Tensor::f32(vec![kf, d], (0..kf * d).map(|x| x as f32 * 0.5).collect()),
            );
        }
        let orig = params.clone();
        adapter.apply(&mut params).unwrap();
        adapter.remove(&mut params).unwrap();
        for (k, v) in &params {
            let a = v.as_f32().unwrap();
            let b = orig[k].as_f32().unwrap();
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-4, "case {case}: {k} drifted");
            }
        }
        // fuse([(a, 1.0)]) == a (on the union representation)
        let fused = S2ftAdapter::fuse(&[(&adapter, 1.0)]).unwrap();
        let mut p1 = orig.clone();
        adapter.apply(&mut p1).unwrap();
        let mut p2 = orig.clone();
        fused.apply(&mut p2).unwrap();
        for (k, v) in &p1 {
            assert_eq!(v.as_f32().unwrap(), p2[k].as_f32().unwrap(), "case {case}: {k}");
        }
    }
}

/// Batch encoding invariants: loss mask covers exactly the answer+EOS
/// targets; decoding the supervised positions recovers the answer.
#[test]
fn prop_batch_encoding_supervises_answer() {
    let tk = Tokenizer;
    let world = World::canonical();
    for case in 0..CASES {
        let mut rng = Rng::seed(4000 + case as u64);
        let all: Vec<&repro::data::Task> =
            COMMONSENSE.iter().chain(&ARITHMETIC).chain(&INSTRUCT).collect();
        let task = all[rng.below(all.len())];
        let split = if rng.bool(0.5) { Split::Train } else { Split::Test };
        let ex = task.sample(&world, &mut rng, split);
        let t = 64;
        let (tokens, targets, mask) = encode_example(&tk, &ex, t);
        assert_eq!(tokens.len(), t);
        // supervised targets reconstruct answer + EOS
        let supervised: Vec<i32> = targets
            .iter()
            .zip(&mask)
            .filter(|(_, &m)| m > 0.0)
            .map(|(&t, _)| t)
            .collect();
        assert_eq!(*supervised.last().unwrap(), EOS, "case {case}");
        let decoded = tk.decode(&supervised[..supervised.len() - 1]);
        assert_eq!(decoded, ex.answer, "case {case}: {ex:?}");
        // no loss on SEP-or-earlier positions' inputs, none on padding
        for (i, &tok) in tokens.iter().enumerate() {
            if tok == PAD {
                assert_eq!(mask[i], 0.0);
            }
        }
        assert!(tokens.contains(&SEP));
    }
}

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32()).collect()
}

/// Adversarial *finite* values: signed zeros, subnormals and mixed
/// magnitudes — everything the historical zero-skip fast paths mishandled
/// short of NaN/inf. Finite-only outputs admit strict bit equality.
fn advv_finite(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n)
        .map(|_| match rng.below(8) {
            0 => 0.0,
            1 => -0.0,
            2 => 1.0e-40,                  // positive subnormal
            3 => -f32::MIN_POSITIVE / 2.0, // negative subnormal
            4 => 1.0e30,
            5 => -1.0e30,
            _ => rng.normal_f32(),
        })
        .collect()
}

/// [`advv_finite`] plus non-finite values — outputs may contain NaN, so
/// comparisons go through [`bits_eq_mod_nan`].
fn advv_full(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n)
        .map(|_| match rng.below(10) {
            0 => f32::INFINITY,
            1 => f32::NEG_INFINITY,
            2 => f32::NAN,
            3 => 0.0,
            4 => -0.0,
            5 => 1.0e-40,
            _ => rng.normal_f32(),
        })
        .collect()
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Bitwise equality except any-NaN == any-NaN: IEEE 754 leaves NaN
/// payload/sign propagation unspecified, and LLVM does not pin it across
/// differently compiled code, so non-finite properties assert *that* a
/// NaN surfaces rather than which payload.
fn bits_eq_mod_nan(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan()))
}

/// Every parallel GEMM kernel matches the naive triple-loop reference
/// elementwise (bit-exact: both sides accumulate each output in ascending
/// reduction order), at arbitrary shapes and thread counts.
#[test]
fn prop_gemm_kernels_match_naive_reference() {
    for case in 0..CASES {
        let mut rng = Rng::seed(7000 + case as u64);
        let m = 1 + rng.below(24);
        let k = 1 + rng.below(24);
        let n = 1 + rng.below(24);
        let threads = 1 + rng.below(5);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let bt = randv(&mut rng, n * k);
        assert!(
            bits_eq(
                &kernels::gemm_with_threads(&a, &b, m, k, n, threads),
                &kernels::reference::gemm(&a, &b, m, k, n),
            ),
            "case {case}: gemm {m}x{k}x{n} t={threads}"
        );
        assert!(
            bits_eq(
                &kernels::gemm_nt_with_threads(&a, &bt, m, k, n, threads),
                &kernels::reference::gemm_nt(&a, &bt, m, k, n),
            ),
            "case {case}: gemm_nt {m}x{k}x{n} t={threads}"
        );
    }
}

/// The S²FT partial-gradient kernels: for every `lim <= ka` (including
/// strict partial slices) the result equals the naive reference AND the
/// corresponding slice of the full-width gradient — i.e. slicing before
/// the GEMM loses nothing but the frozen rows/columns.
#[test]
fn prop_partial_gradient_kernels_slice_exactly() {
    for case in 0..CASES {
        let mut rng = Rng::seed(7500 + case as u64);
        let rows = 1 + rng.below(24);
        let ka = 2 + rng.below(24);
        let kb = 1 + rng.below(24);
        let threads = 1 + rng.below(5);
        let a = randv(&mut rng, rows * ka);
        let b = randv(&mut rng, rows * kb);
        let lim = 1 + rng.below(ka); // often a strict partial slice
        let part = kernels::gemm_tn_with_threads(&a, &b, rows, ka, kb, lim, threads);
        assert!(
            bits_eq(&part, &kernels::reference::gemm_tn(&a, &b, rows, ka, kb, lim)),
            "case {case}: gemm_tn lim={lim}/{ka}"
        );
        let full = kernels::gemm_tn_with_threads(&a, &b, rows, ka, kb, ka, threads);
        assert!(bits_eq(&part, &full[..lim * kb]), "case {case}: partial != slice of full");

        let limc = 1 + rng.below(kb);
        let partc = kernels::gemm_tn_outcols_with_threads(&a, &b, rows, ka, kb, limc, threads);
        assert!(
            bits_eq(&partc, &kernels::reference::gemm_tn_outcols(&a, &b, rows, ka, kb, limc)),
            "case {case}: gemm_tn_outcols lim={limc}/{kb}"
        );
        let fullc = kernels::gemm_tn_outcols_with_threads(&a, &b, rows, ka, kb, kb, threads);
        let sliced: Vec<f32> =
            (0..ka).flat_map(|i| fullc[i * kb..i * kb + limc].to_vec()).collect();
        assert!(bits_eq(&partc, &sliced), "case {case}: outcols partial != cols of full");
    }
}

/// `S2FT_THREADS=1` vs `N` bit-equality on shapes large enough to cross
/// the parallel threshold — the determinism contract the numeric tests
/// rely on (only the output is partitioned, never the reduction axis).
#[test]
fn prop_kernels_thread_count_bit_identical() {
    for case in 0..12 {
        let mut rng = Rng::seed(7900 + case as u64);
        let m = 33 + rng.below(31);
        let k = 33 + rng.below(31);
        let n = 33 + rng.below(31);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let bt = randv(&mut rng, n * k);
        let g1 = kernels::gemm_with_threads(&a, &b, m, k, n, 1);
        let nt1 = kernels::gemm_nt_with_threads(&a, &bt, m, k, n, 1);
        let tn1 = kernels::gemm_tn_with_threads(&a, &a, m, k, k, k, 1);
        let oc1 = kernels::gemm_tn_outcols_with_threads(&a, &a, m, k, k, k, 1);
        for threads in [2usize, 3, 4, 7] {
            assert!(
                bits_eq(&g1, &kernels::gemm_with_threads(&a, &b, m, k, n, threads)),
                "case {case}: gemm t={threads}"
            );
            assert!(
                bits_eq(&nt1, &kernels::gemm_nt_with_threads(&a, &bt, m, k, n, threads)),
                "case {case}: gemm_nt t={threads}"
            );
            assert!(
                bits_eq(&tn1, &kernels::gemm_tn_with_threads(&a, &a, m, k, k, k, threads)),
                "case {case}: gemm_tn t={threads}"
            );
            assert!(
                bits_eq(&oc1, &kernels::gemm_tn_outcols_with_threads(&a, &a, m, k, k, k, threads)),
                "case {case}: gemm_tn_outcols t={threads}"
            );
        }
    }
}

/// The repaired zero-skip contract on *finite* adversarial inputs: with
/// signed zeros, subnormals and mixed magnitudes in play, every kernel —
/// including `gemv_acc`, whose caller-owned accumulator is where the old
/// skip diverged on purely finite data — is strictly bit-identical to
/// its naive reference.
#[test]
fn prop_kernels_match_reference_on_adversarial_finite() {
    for case in 0..CASES {
        let mut rng = Rng::seed(8600 + case as u64);
        let m = 1 + rng.below(20);
        let k = 1 + rng.below(20);
        let n = 1 + rng.below(20);
        let threads = 1 + rng.below(5);
        let a = advv_finite(&mut rng, m * k);
        let b = advv_finite(&mut rng, k * n);
        let bt = advv_finite(&mut rng, n * k);
        assert!(
            bits_eq(
                &kernels::gemm_with_threads(&a, &b, m, k, n, threads),
                &kernels::reference::gemm(&a, &b, m, k, n),
            ),
            "case {case}: gemm {m}x{k}x{n}"
        );
        assert!(
            bits_eq(
                &kernels::gemm_nt_with_threads(&a, &bt, m, k, n, threads),
                &kernels::reference::gemm_nt(&a, &bt, m, k, n),
            ),
            "case {case}: gemm_nt {m}x{k}x{n}"
        );
        // A (m,k), B (m,n) in the transposed-A shapes
        let b2 = advv_finite(&mut rng, m * n);
        let lim = 1 + rng.below(k);
        assert!(
            bits_eq(
                &kernels::gemm_tn_with_threads(&a, &b2, m, k, n, lim, threads),
                &kernels::reference::gemm_tn(&a, &b2, m, k, n, lim),
            ),
            "case {case}: gemm_tn lim={lim}"
        );
        let limc = 1 + rng.below(n);
        assert!(
            bits_eq(
                &kernels::gemm_tn_outcols_with_threads(&a, &b2, m, k, n, limc, threads),
                &kernels::reference::gemm_tn_outcols(&a, &b2, m, k, n, limc),
            ),
            "case {case}: gemm_tn_outcols lim={limc}"
        );
        // gemv_acc: adversarial caller-owned y (may hold -0.0) and an
        // adversarial scale (0.0 / -0.0 among the candidates)
        let x = advv_finite(&mut rng, k);
        let w = advv_finite(&mut rng, k * n);
        let scale = match rng.below(4) {
            0 => 0.0,
            1 => -0.0,
            2 => -1.0,
            _ => rng.normal_f32(),
        };
        let y0 = advv_finite(&mut rng, n);
        let mut y_kernel = y0.clone();
        kernels::gemv_acc(&x, &w, n, scale, &mut y_kernel);
        let mut y_ref = y0;
        kernels::reference::gemv_acc(&x, &w, n, scale, &mut y_ref);
        assert!(bits_eq(&y_kernel, &y_ref), "case {case}: gemv_acc scale={scale}");
    }
}

/// Non-finite propagation: with ±inf and NaN in the inputs the kernels
/// must surface NaN exactly where the naive reference does (`0·inf` and
/// `0·NaN` products were silently dropped by the old zero-skips) and
/// match bitwise everywhere else.
#[test]
fn prop_kernels_match_reference_on_nonfinite() {
    for case in 0..CASES {
        let mut rng = Rng::seed(8700 + case as u64);
        let m = 1 + rng.below(16);
        let k = 1 + rng.below(16);
        let n = 1 + rng.below(16);
        let threads = 1 + rng.below(5);
        let a = advv_full(&mut rng, m * k);
        let b = advv_full(&mut rng, k * n);
        let bt = advv_full(&mut rng, n * k);
        assert!(
            bits_eq_mod_nan(
                &kernels::gemm_with_threads(&a, &b, m, k, n, threads),
                &kernels::reference::gemm(&a, &b, m, k, n),
            ),
            "case {case}: gemm {m}x{k}x{n}"
        );
        assert!(
            bits_eq_mod_nan(
                &kernels::gemm_nt_with_threads(&a, &bt, m, k, n, threads),
                &kernels::reference::gemm_nt(&a, &bt, m, k, n),
            ),
            "case {case}: gemm_nt {m}x{k}x{n}"
        );
        let b2 = advv_full(&mut rng, m * n);
        let lim = 1 + rng.below(k);
        assert!(
            bits_eq_mod_nan(
                &kernels::gemm_tn_with_threads(&a, &b2, m, k, n, lim, threads),
                &kernels::reference::gemm_tn(&a, &b2, m, k, n, lim),
            ),
            "case {case}: gemm_tn lim={lim}"
        );
        let limc = 1 + rng.below(n);
        assert!(
            bits_eq_mod_nan(
                &kernels::gemm_tn_outcols_with_threads(&a, &b2, m, k, n, limc, threads),
                &kernels::reference::gemm_tn_outcols(&a, &b2, m, k, n, limc),
            ),
            "case {case}: gemm_tn_outcols lim={limc}"
        );
        let x = advv_full(&mut rng, k);
        let w = advv_full(&mut rng, k * n);
        let y0 = advv_full(&mut rng, n);
        let mut y_kernel = y0.clone();
        kernels::gemv_acc(&x, &w, n, 1.0, &mut y_kernel);
        let mut y_ref = y0;
        kernels::reference::gemv_acc(&x, &w, n, 1.0, &mut y_ref);
        assert!(bits_eq_mod_nan(&y_kernel, &y_ref), "case {case}: gemv_acc");
    }
}

/// The dispatch boundary: forcing the SIMD tile and the portable tile via
/// `*_with_dispatch` yields strictly identical bits on adversarial finite
/// inputs — the runtime AVX2/scalar decision can never change results.
/// Shapes reach past one `NR`-wide panel and one `MR`-row tile so full
/// tiles, row remainders and right-edge panels all cross the boundary.
#[test]
fn prop_kernels_dispatch_boundary_bit_identical() {
    for case in 0..CASES {
        let mut rng = Rng::seed(8800 + case as u64);
        let m = 1 + rng.below(24);
        let k = 1 + rng.below(24);
        let n = 1 + rng.below(40);
        let threads = 1 + rng.below(4);
        let a = advv_finite(&mut rng, m * k);
        let b = advv_finite(&mut rng, k * n);
        let bt = advv_finite(&mut rng, n * k);
        assert!(
            bits_eq(
                &kernels::gemm_with_dispatch(&a, &b, m, k, n, threads, true),
                &kernels::gemm_with_dispatch(&a, &b, m, k, n, threads, false),
            ),
            "case {case}: gemm {m}x{k}x{n}"
        );
        assert!(
            bits_eq(
                &kernels::gemm_nt_with_dispatch(&a, &bt, m, k, n, threads, true),
                &kernels::gemm_nt_with_dispatch(&a, &bt, m, k, n, threads, false),
            ),
            "case {case}: gemm_nt {m}x{k}x{n}"
        );
        let b2 = advv_finite(&mut rng, m * n);
        let lim = 1 + rng.below(k);
        assert!(
            bits_eq(
                &kernels::gemm_tn_with_dispatch(&a, &b2, m, k, n, lim, threads, true),
                &kernels::gemm_tn_with_dispatch(&a, &b2, m, k, n, lim, threads, false),
            ),
            "case {case}: gemm_tn lim={lim}"
        );
        let limc = 1 + rng.below(n);
        assert!(
            bits_eq(
                &kernels::gemm_tn_outcols_with_dispatch(&a, &b2, m, k, n, limc, threads, true),
                &kernels::gemm_tn_outcols_with_dispatch(&a, &b2, m, k, n, limc, threads, false),
            ),
            "case {case}: gemm_tn_outcols lim={limc}"
        );
    }
}

/// Thread counts 1/2/4/8 on adversarial finite inputs, above the parallel
/// threshold: same code path on every worker, so equality is strict even
/// with signed zeros and subnormals in play.
#[test]
fn prop_kernels_thread_counts_bit_identical_on_adversarial() {
    for case in 0..8 {
        let mut rng = Rng::seed(8900 + case as u64);
        let m = 33 + rng.below(31);
        let k = 33 + rng.below(31);
        let n = 33 + rng.below(31);
        let a = advv_finite(&mut rng, m * k);
        let b = advv_finite(&mut rng, k * n);
        let bt = advv_finite(&mut rng, n * k);
        let g1 = kernels::gemm_with_threads(&a, &b, m, k, n, 1);
        let nt1 = kernels::gemm_nt_with_threads(&a, &bt, m, k, n, 1);
        let tn1 = kernels::gemm_tn_with_threads(&a, &a, m, k, k, k, 1);
        let oc1 = kernels::gemm_tn_outcols_with_threads(&a, &a, m, k, k, k, 1);
        for threads in [2usize, 4, 8] {
            assert!(
                bits_eq(&g1, &kernels::gemm_with_threads(&a, &b, m, k, n, threads)),
                "case {case}: gemm t={threads}"
            );
            assert!(
                bits_eq(&nt1, &kernels::gemm_nt_with_threads(&a, &bt, m, k, n, threads)),
                "case {case}: gemm_nt t={threads}"
            );
            assert!(
                bits_eq(&tn1, &kernels::gemm_tn_with_threads(&a, &a, m, k, k, k, threads)),
                "case {case}: gemm_tn t={threads}"
            );
            assert!(
                bits_eq(&oc1, &kernels::gemm_tn_outcols_with_threads(&a, &a, m, k, k, k, threads)),
                "case {case}: gemm_tn_outcols t={threads}"
            );
        }
    }
}

/// The causal-attention kernel pair is bit-identical across thread counts
/// and produces causal softmax rows.
#[test]
fn prop_attention_kernels_deterministic_and_causal() {
    for case in 0..10 {
        let mut rng = Rng::seed(8200 + case as u64);
        let dims = kernels::AttnDims {
            b: 2 + rng.below(3),
            t: 8 + rng.below(17),
            heads: 1 + rng.below(4),
            hd: 2 * (1 + rng.below(4)),
        };
        let d = dims.heads * dims.hd;
        let nel = dims.b * dims.t * d;
        let qr = randv(&mut rng, nel);
        let kr = randv(&mut rng, nel);
        let v = randv(&mut rng, nel);
        let da = randv(&mut rng, nel);
        let scale = 1.0 / (dims.hd as f32).sqrt();
        let (p1, a1) = kernels::causal_attn_fwd_with_threads(&qr, &kr, &v, &dims, scale, 1);
        let (dq1, dk1, dv1) =
            kernels::causal_attn_bwd_with_threads(&p1, &qr, &kr, &v, &da, &dims, scale, 1);
        for threads in [2usize, 3, 5] {
            let (pt, at) =
                kernels::causal_attn_fwd_with_threads(&qr, &kr, &v, &dims, scale, threads);
            assert!(bits_eq(&p1, &pt) && bits_eq(&a1, &at), "case {case}: fwd t={threads}");
            let (dqt, dkt, dvt) = kernels::causal_attn_bwd_with_threads(
                &p1,
                &qr,
                &kr,
                &v,
                &da,
                &dims,
                scale,
                threads,
            );
            assert!(
                bits_eq(&dq1, &dqt) && bits_eq(&dk1, &dkt) && bits_eq(&dv1, &dvt),
                "case {case}: bwd t={threads}"
            );
        }
        // causal structure: row tq is a softmax over keys 0..=tq, 0 after
        for bi in 0..dims.b {
            for hh in 0..dims.heads {
                for tq in 0..dims.t {
                    let row = &p1[((bi * dims.heads + hh) * dims.t + tq) * dims.t..][..dims.t];
                    let sum: f32 = row[..=tq].iter().sum();
                    assert!((sum - 1.0).abs() < 1e-4, "case {case}: sum {sum}");
                    assert!(row[tq + 1..].iter().all(|&p| p == 0.0), "case {case}: acausal");
                }
            }
        }
    }
}

/// linalg invariants: (A·B)ᵀ = Bᵀ·Aᵀ and ‖A‖_F² = Σ σᵢ².
#[test]
fn prop_linalg_identities() {
    for case in 0..30 {
        let mut rng = Rng::seed(5000 + case as u64);
        let m = 2 + rng.below(10);
        let k = 2 + rng.below(10);
        let n = 2 + rng.below(10);
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let ab_t = a.matmul(&b).t();
        let bt_at = b.t().matmul(&a.t());
        assert!(ab_t.sub(&bt_at).fro_norm() < 1e-4);
        let sv = repro::linalg::svd(&a).s;
        let fro2: f32 = sv.iter().map(|s| s * s).sum();
        let want = a.fro_norm() * a.fro_norm();
        assert!(
            (fro2 - want).abs() / want.max(1e-6) < 1e-3,
            "case {case}: {fro2} vs {want}"
        );
    }
}

/// Task-suite invariant: answers fit the decode budget and train/test
/// prompts for entity tasks never collide.
#[test]
fn prop_task_splits_disjoint() {
    let world = World::canonical();
    for (ti, task) in COMMONSENSE.iter().enumerate() {
        if task.name == "OBQA" {
            continue; // rule-recall task intentionally shares prompts
        }
        let mut rng = Rng::seed(6000 + ti as u64);
        let train: std::collections::HashSet<String> = (0..120)
            .map(|_| task.sample(&world, &mut rng, Split::Train))
            .map(|e: Example| e.prompt)
            .collect();
        let test: std::collections::HashSet<String> = (0..120)
            .map(|_| task.sample(&world, &mut rng, Split::Test).prompt)
            .collect();
        let inter: Vec<_> = train.intersection(&test).collect();
        assert!(
            inter.is_empty(),
            "{}: {} colliding prompts, e.g. {:?}",
            task.name,
            inter.len(),
            inter.first()
        );
    }
}

// ---------------------------------------------------------------------------
// KV-cached incremental decode vs full recompute
// ---------------------------------------------------------------------------

/// The serving hot-path contract: greedy (and seeded temperature)
/// generation through the KV-cached decode session is **bit-identical**
/// to full-sequence recompute through the `fwd` artifact — same texts,
/// same token streams, on random prompts over the builtin metas.
#[test]
fn prop_kv_cached_decode_matches_full_recompute() {
    for (model, cases) in [("tiny", 10usize), ("small", 2)] {
        let rt = NativeBackend::builtin();
        for case in 0..cases {
            let mut rng = Rng::seed(0xD3C0 + case as u64);
            let init = rt.load(&format!("init_{model}")).unwrap();
            let outs = init.run(&[Tensor::scalar_i32(case as i32)]).unwrap();
            let params: std::collections::HashMap<String, Tensor> =
                init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect();
            let gm = GenModel::new(&rt, model, params).unwrap();
            assert!(gm.has_decoder(), "native backend must provide a decoder");

            // random printable prompts of random lengths (some empty, some
            // long enough to near the window), random per-request params;
            // tiny sometimes spills into a second chunk, small stays at a
            // single short chunk to bound the full-recompute reference cost
            let (n_reqs, max_gen) = if model == "tiny" {
                (1 + rng.below(gm.b + 2), 9)
            } else {
                (1 + rng.below(3), 4)
            };
            let reqs: Vec<DecodeRequest> = (0..n_reqs)
                .map(|i| {
                    let len = rng.below(gm.t.min(24));
                    let prompt: String =
                        (0..len).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
                    let mut r = DecodeRequest::greedy(prompt, 1 + rng.below(max_gen));
                    if i % 3 == 2 {
                        r.temperature = 0.8;
                        r.top_k = 1 + rng.below(16);
                        r.seed = 0xBEEF + i as u64;
                    }
                    if i % 4 == 3 {
                        r.stop = Some(rng.below(256) as i32);
                    }
                    r
                })
                .collect();

            let mut cached_tokens: Vec<(usize, i32)> = Vec::new();
            let cached = gm
                .generate_stream(&reqs, |i, t| cached_tokens.push((i, t)))
                .unwrap();
            let mut full_tokens: Vec<(usize, i32)> = Vec::new();
            let full = gm
                .generate_full_recompute(&reqs, |i, t| full_tokens.push((i, t)))
                .unwrap();
            assert_eq!(
                cached, full,
                "{model} case {case}: decoded texts diverge between kv-cache and recompute"
            );
            assert_eq!(
                cached_tokens, full_tokens,
                "{model} case {case}: streamed token sequences diverge"
            );
        }
    }
}

/// Truncated + plan-sliced backward vs the cache-everything full walk
/// (`set_full_backward_override`, the in-process equivalent of
/// `S2FT_FULL_BACKWARD=1`): every trainable gradient, updated parameter
/// and optimizer moment must be *bit-identical* across random per-layer
/// S²FT selections, including the all-layers-trainable and
/// single-top-layer edge cases — and full FT must be unaffected.
///
/// Kept as one #[test] because the reference-walk override is process
/// global state: splitting it across tests would race under the
/// parallel test runner.
#[test]
fn prop_truncated_backward_bit_identical_to_full_walk() {
    use repro::data::{lm_batch, pretrain_corpus};
    use repro::runtime::native::builtin::{self, is_mha};
    use repro::runtime::native::set_full_backward_override;

    let tk = Tokenizer;
    let corpus = pretrain_corpus(2, 60_000);

    // one train step through the named method, with/without the full walk
    let step_outputs = |meta: repro::runtime::Meta,
                        tag: &str,
                        pool: &HashMap<String, Tensor>,
                        full_walk: bool|
     -> HashMap<String, Tensor> {
        set_full_backward_override(Some(full_walk));
        let nb = NativeBackend::with_meta(meta);
        let (b, t) = nb.artifacts().model("tiny").unwrap().default_batch();
        let exe = nb.load(&format!("train_tiny_{tag}_{b}x{t}")).unwrap();
        let out = exe.run_named(pool).unwrap();
        set_full_backward_override(None);
        out
    };

    let base_meta = builtin::builtin_meta();
    let mm = base_meta.models["tiny"].clone();
    let (b, t) = mm.default_batch();
    let nb = NativeBackend::with_meta(base_meta.clone());
    let init = nb.load("init_tiny").unwrap();
    let outs = init.run(&[Tensor::scalar_i32(3)]).unwrap();
    let base: HashMap<String, Tensor> =
        init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect();

    let projs = ["wq", "wk", "wv", "wo", "wu", "wg", "wd"];
    let l = mm.dims.n_layers;
    // per-layer unit budgets: random sweeps + forced edge cases
    //   case 0: every layer, every projection trainable (stop = 0, full cache widths)
    //   case 1: single top layer only (maximal truncation)
    //   case 2: single bottom layer only (boundary at layer 0)
    //   3..: random subsets/counts, lower layers often empty
    type LayerCounts = Vec<HashMap<String, usize>>;
    let mut cases: Vec<LayerCounts> = Vec::new();
    // (widths stay one unit below full so the `_f` complement is never a
    // zero-sized tensor, which the Tensor type cannot represent)
    cases.push(
        (0..l)
            .map(|_| {
                projs
                    .iter()
                    .map(|&p| {
                        let c = if is_mha(p) { mm.dims.n_heads - 1 } else { mm.dims.d_ff - 1 };
                        (p.to_string(), c)
                    })
                    .collect()
            })
            .collect(),
    );
    let top_only = |p: &str, c: usize| -> LayerCounts {
        let mut v = vec![HashMap::new(); l];
        v[l - 1].insert(p.to_string(), c);
        v
    };
    cases.push(top_only("wo", 1));
    // boundary layers where only half the SiLU chain carries a gradient
    // (exercises the du/dgpre need-gating)
    cases.push(top_only("wu", 3));
    cases.push(top_only("wg", 4));
    cases.push(top_only("wd", 2));
    {
        let mut v = vec![HashMap::new(); l];
        v[0].insert("wd".to_string(), 5);
        v[0].insert("wo".to_string(), 1);
        cases.push(v);
    }
    let mut rng = Rng::seed(0x51F7_CA5E);
    for _ in 0..5 {
        let mut v: LayerCounts = Vec::new();
        for _ in 0..l {
            let mut m = HashMap::new();
            for &p in &projs {
                if rng.below(3) == 0 {
                    let max = if is_mha(p) { mm.dims.n_heads } else { mm.dims.d_ff };
                    let c = 1 + rng.below(max - 1); // never full width (see above)
                    m.insert(p.to_string(), c);
                }
            }
            v.push(m);
        }
        if v.iter().all(|m| m.is_empty()) {
            v[l - 1].insert("wd".to_string(), 1);
        }
        cases.push(v);
    }

    let mut batch_rng = Rng::seed(77);
    for (case, counts) in cases.iter().enumerate() {
        let (trainable, frozen, perms) =
            builtin::s2ft_layout_per_layer(&mm.dims, &mm.base_params, counts);
        let mut meth = mm.methods["s2ft"].clone();
        meth.trainable_params = trainable.iter().map(|s| s.numel()).sum();
        meth.opt = trainable.clone();
        meth.trainable = trainable;
        meth.frozen = frozen;
        meth.perms = perms;
        let mut meta = base_meta.clone();
        meta.models.get_mut("tiny").unwrap().methods.insert("s2ftcase".to_string(), meth.clone());

        let mut pool = builtin::identity_split_pool(&base, &meth);
        let batch = lm_batch(&tk, &corpus, &mut batch_rng, b, t);
        pool.insert("step".to_string(), Tensor::scalar_f32(0.0));
        pool.insert("tokens".to_string(), batch.tokens);
        pool.insert("targets".to_string(), batch.targets);
        pool.insert("loss_mask".to_string(), batch.loss_mask);

        let truncated = step_outputs(meta.clone(), "s2ftcase", &pool, false);
        let full_walk = step_outputs(meta, "s2ftcase", &pool, true);
        assert_eq!(truncated.len(), full_walk.len(), "case {case}: output sets differ");
        for (name, tt) in &truncated {
            let ft = &full_walk[name];
            if name == "act_bytes" || name == "act_peak_bytes" {
                // the measured memory is exactly what is allowed to differ
                let (a, f) =
                    (tt.as_i32().unwrap()[0], ft.as_i32().unwrap()[0]);
                assert!(
                    a <= f,
                    "case {case}: truncated cache {a} larger than full walk {f}"
                );
                continue;
            }
            let (av, bv) = (tt.as_f32().unwrap(), ft.as_f32().unwrap());
            assert_eq!(av.len(), bv.len(), "case {case}: {name} length");
            assert!(
                av.iter().zip(bv).all(|(x, y)| x.to_bits() == y.to_bits()),
                "case {case}: {name} not bit-identical between truncated and full walk"
            );
        }
    }

    // full FT is unaffected by the reference-walk switch
    let mut pool: HashMap<String, Tensor> = base.clone();
    for o in &mm.methods["fullft"].opt {
        pool.insert(format!("m.{}", o.name), Tensor::zeros(o.shape.clone()));
        pool.insert(format!("v.{}", o.name), Tensor::zeros(o.shape.clone()));
    }
    let batch = lm_batch(&tk, &corpus, &mut batch_rng, b, t);
    pool.insert("step".to_string(), Tensor::scalar_f32(0.0));
    pool.insert("tokens".to_string(), batch.tokens);
    pool.insert("targets".to_string(), batch.targets);
    pool.insert("loss_mask".to_string(), batch.loss_mask);
    let a = step_outputs(base_meta.clone(), "fullft", &pool, false);
    let bo = step_outputs(base_meta, "fullft", &pool, true);
    for (name, tt) in &a {
        if name == "act_bytes" || name == "act_peak_bytes" {
            assert_eq!(
                tt.as_i32().unwrap()[0],
                bo[name].as_i32().unwrap()[0],
                "fullft retains everything either way"
            );
            continue;
        }
        let (av, bv) = (tt.as_f32().unwrap(), bo[name].as_f32().unwrap());
        assert!(
            av.iter().zip(bv).all(|(x, y)| x.to_bits() == y.to_bits()),
            "fullft {name} changed under the reference-walk switch"
        );
    }
}

/// Paged-KV bit-identity under random continuous-batching schedules:
/// streams admit into random rows, feed interleaved (some rows idle per
/// step via `None`), retire early and hand their rows to fresh streams —
/// across block sizes that tile the sequence evenly and unevenly. Every
/// stepped row's logits must equal a solo contiguous [`repro::runtime::
/// DecodeSession`] fed the same token sequence, bit for bit: the block
/// table is address translation, never arithmetic.
#[test]
fn prop_paged_decode_bit_identical_to_contiguous() {
    let rt = NativeBackend::builtin();
    let init = rt.load("init_tiny").unwrap();
    let outs = init.run(&[Tensor::scalar_i32(11)]).unwrap();
    let params: HashMap<String, Tensor> =
        init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect();
    let provider = rt.decoder().expect("native backend has a decoder");
    let t_max = 32usize;

    for case in 0..12usize {
        let mut rng = Rng::seed(9000 + case as u64);
        let bt = [1usize, 2, 3, 8][case % 4];
        let rows = 2 + case % 2;
        let cfg = KvPoolConfig { block_tokens: bt, blocks: 0 };
        let mut paged = provider
            .open_paged("tiny", &params, rows, t_max, cfg)
            .expect("open_paged")
            .expect("native supports paged sessions");
        // per row: the solo contiguous reference session of the stream
        // currently occupying it (admitted lazily, replaced on reuse)
        let mut refs: Vec<Option<Box<dyn repro::runtime::DecodeSession + '_>>> =
            (0..rows).map(|_| None).collect();

        for step in 0..60usize {
            // random lifecycle event ~every 4th step
            match rng.below(4) {
                0 => {
                    if let Some(row) = (0..rows).find(|&r| !paged.is_active(r)) {
                        paged.admit(row).unwrap();
                        refs[row] = Some(provider.open_session("tiny", &params, 1, t_max).unwrap());
                    }
                }
                1 if step > 6 => {
                    let row = rng.below(rows);
                    if paged.is_active(row) {
                        paged.retire(row);
                        refs[row] = None;
                    }
                }
                _ => {}
            }
            // feed a random subset of active, non-full rows
            let mut feed: Vec<Option<i32>> = vec![None; rows];
            let mut fed_rows = Vec::new();
            for r in 0..rows {
                if paged.is_active(r) && paged.pos(r) < t_max && rng.below(10) < 8 {
                    feed[r] = Some(rng.below(256) as i32);
                    fed_rows.push(r);
                }
            }
            if fed_rows.is_empty() {
                continue;
            }
            paged.reserve(&fed_rows).expect("auto-sized pool cannot exhaust");
            let got = paged.step(&feed).unwrap();
            let vocab = got.len() / rows;
            for &r in &fed_rows {
                let solo = refs[r].as_mut().expect("active row has a reference");
                let want = solo.step(&[feed[r]]).unwrap();
                assert_eq!(want.len(), vocab, "case {case} step {step}: vocab width");
                let g = &got[r * vocab..(r + 1) * vocab];
                assert!(
                    g.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "case {case} step {step} row {r} (bt={bt}): paged logits drifted"
                );
            }
        }
        // retiring everything must return the pool to empty
        for r in 0..rows {
            paged.retire(r);
        }
        assert_eq!(paged.pool_usage().used_bytes, 0, "case {case}: blocks leaked");
    }
}

/// Dynamic-replan identity (selection-strategy pipeline): a StaticS2ft
/// run with forced replan-every-K — the strategy re-commits the *same*
/// selection, so each replan merges the pool to base layout, rebuilds it,
/// carries every optimizer moment, and evicts/reloads the executable —
/// must be bit-identical to the same run with replanning disabled:
/// per-step losses, trainable weights, optimizer moments, measured
/// `act_bytes`, and the merged params all agree exactly.
#[test]
fn prop_static_replan_recommit_bit_identical() {
    use repro::data::{lm_batch, pretrain_corpus};
    use repro::sparsity::strategy;
    use repro::train::Trainer;

    let nb = NativeBackend::builtin();
    let mm = nb.artifacts().model("tiny").unwrap().clone();
    let meth = mm.method("s2ft").unwrap().clone();
    let (b, t) = mm.default_batch();
    let init = nb.load("init_tiny").unwrap();
    let outs = init.run(&[Tensor::scalar_i32(3)]).unwrap();
    let base: HashMap<String, Tensor> =
        init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect();
    let tk = Tokenizer;
    let corpus = pretrain_corpus(5, 60_000);

    for (case, &(seed, every, steps)) in
        [(5u64, 2usize, 5usize), (6, 3, 7), (7, 1, 4)].iter().enumerate()
    {
        // one pre-generated batch stream shared by both runs
        let mut rng = Rng::seed(31 + case as u64);
        let batches: Vec<_> = (0..steps).map(|_| lm_batch(&tk, &corpus, &mut rng, b, t)).collect();
        let run = |replan_every: usize| -> Trainer {
            let strat =
                strategy::for_name("static", &meth.selection, meth.select_small).unwrap();
            let mut tr =
                Trainer::with_strategy(&nb, "tiny", "s2ft", &base, seed, strat, replan_every, b, t)
                    .unwrap();
            for batch in &batches {
                tr.maybe_replan(&nb, batch).unwrap();
                tr.train_step(batch).unwrap();
            }
            tr
        };
        let plain = run(0);
        let replanned = run(every);
        assert_eq!(plain.metrics.replans, 0, "case {case}");
        assert!(
            replanned.metrics.replans > 0,
            "case {case}: every={every} never replanned in {steps} steps"
        );
        assert_eq!(
            replanned.metrics.shape_changing_replans, 0,
            "case {case}: identical re-commit must not change layout shapes"
        );
        // losses bit-identical step by step
        for (s, (a, r)) in plain.metrics.losses.iter().zip(&replanned.metrics.losses).enumerate() {
            assert_eq!(a.to_bits(), r.to_bits(), "case {case} step {s}: loss drifted");
        }
        // measured activation bytes identical (same plan after rebuild)
        assert_eq!(
            plain.activation_bytes(),
            replanned.activation_bytes(),
            "case {case}: act_bytes drifted"
        );
        // trainable weights + carried optimizer moments bit-identical
        for i in 0..mm.dims.n_layers {
            for p in ["wo", "wd"] {
                for key in [
                    format!("L{i}.{p}_t"),
                    format!("m.L{i}.{p}_t"),
                    format!("v.L{i}.{p}_t"),
                ] {
                    let a = plain.tensor(&key).unwrap().as_f32().unwrap();
                    let r = replanned.tensor(&key).unwrap().as_f32().unwrap();
                    assert!(
                        a.iter().zip(r).all(|(x, y)| x.to_bits() == y.to_bits()),
                        "case {case}: {key} drifted across re-commits"
                    );
                }
            }
        }
        // merged params bit-identical (host merge path both sides)
        let ma = plain.merged_params(&nb).unwrap();
        let mr = replanned.merged_params(&nb).unwrap();
        for (k, v) in &ma {
            let a = v.as_f32().unwrap();
            let r = mr[k].as_f32().unwrap();
            assert!(
                a.iter().zip(r).all(|(x, y)| x.to_bits() == y.to_bits()),
                "case {case}: merged {k} drifted"
            );
        }
    }
}
