//! Deterministic RNG (xoshiro256++ seeded via SplitMix64) — every data
//! generator, selection seed and experiment in the repo flows through this
//! so runs are exactly reproducible from a single u64 seed.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller sample
    spare: Option<f64>,
}

impl Rng {
    pub fn seed(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()], spare: None }
    }

    /// Derive an independent stream (like jax.random.fold_in).
    pub fn fold(&self, tag: u64) -> Rng {
        let mut h = 0xcbf29ce484222325u64;
        for v in self.s.iter().chain(std::iter::once(&tag)) {
            h ^= v;
            h = h.wrapping_mul(0x100000001b3);
        }
        Rng::seed(h)
    }

    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s0.wrapping_add(s3).rotate_left(23).wrapping_add(s0);
        let t = s1 << 17;
        let mut s = [s0, s1, s2, s3];
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        self.s = s;
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// k distinct indices from [0, n), ascending.
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        let mut out = idx[..k].to_vec();
        out.sort_unstable();
        out
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed(7);
        let mut b = Rng::seed(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fold_independent() {
        let base = Rng::seed(7);
        let mut a = base.fold(1);
        let mut b = base.fold(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::seed(1);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            let n = r.range(-5, 5);
            assert!((-5..5).contains(&n));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed(2);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn choose_distinct_sorted() {
        let mut r = Rng::seed(3);
        let c = r.choose(10, 4);
        assert_eq!(c.len(), 4);
        assert!(c.windows(2).all(|w| w[0] < w[1]));
    }
}
