//! The public serving API: an N-worker engine pool with streamed replies
//! and a runtime adapter lifecycle.
//!
//! ```text
//!            Engine::submit(GenRequest) ──► ReplyStream (GenEvent::Token…Done)
//!                     │
//!              Mutex<AdapterBatcher> + Condvar   (shared work queue,
//!                     │                           adapter-affinity scheduling)
//!        ┌────────────┼────────────┐
//!     worker 0     worker 1  …  worker N-1      (each: own GenModel weights
//!        │            │            │             + AdapterSlot fused state)
//!        └────────────┴────────────┘
//!              Arc<AdapterStore>                 (thread-safe registry:
//!                                                 register/unregister/fuse
//!                                                 while serving)
//! ```
//!
//! Each worker owns a full copy of the (merged, base-layout) weights and
//! a [`AdapterSlot`]; the [`AdapterStore`] is shared. A worker asks the
//! batcher for a batch *preferring its currently-fused adapter*, so under
//! steady multi-adapter load the pool converges to one adapter per worker
//! and switches only when the mix shifts — the paper §6.2 decoupling in
//! all three modes at once: **fuse** ([`Engine::fuse`] merges adapters
//! into a new servable one), **fast switch** (scatter_add per batch via
//! the slot) and **parallel serve** (different adapters live on different
//! workers concurrently).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::adapter::{AdapterSlot, AdapterStore, AnyAdapter, S2ftAdapter};
use crate::data::Tokenizer;
use crate::runtime::Tensor;
use crate::train::{DecodeRequest, GenModel};

use super::batcher::{AdapterBatcher, BatchPlan, Queued, SchedPolicy};
use super::metrics::ServeMetrics;

/// Reserved adapter id meaning "pristine base weights, nothing fused".
pub const BASE_ADAPTER: &str = "base";

/// Engine construction parameters (builder-style).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub workers: usize,
    pub max_batch: usize,
    /// How long a freshly-arrived request may wait for batch-mates.
    pub window: Duration,
    pub policy: SchedPolicy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            workers: 1,
            max_batch: 8,
            window: Duration::from_millis(2),
            policy: SchedPolicy::AdapterAffinity,
        }
    }
}

impl EngineConfig {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n.max(1);
        self
    }

    pub fn window(mut self, w: Duration) -> Self {
        self.window = w;
        self
    }

    pub fn policy(mut self, p: SchedPolicy) -> Self {
        self.policy = p;
        self
    }
}

/// Per-request sampling parameters (see [`DecodeRequest`]).
#[derive(Debug, Clone)]
pub struct SamplingParams {
    pub max_new: usize,
    pub temperature: f32,
    pub top_k: usize,
    pub stop: Option<i32>,
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { max_new: 8, temperature: 0.0, top_k: 0, stop: None, seed: 0 }
    }
}

/// One generation request routed to `adapter` (use [`BASE_ADAPTER`] for
/// the un-adapted base model).
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub adapter: String,
    pub prompt: String,
    pub params: SamplingParams,
}

impl GenRequest {
    pub fn new(adapter: impl Into<String>, prompt: impl Into<String>) -> Self {
        Self {
            adapter: adapter.into(),
            prompt: prompt.into(),
            params: SamplingParams::default(),
        }
    }

    pub fn max_new(mut self, n: usize) -> Self {
        self.params.max_new = n;
        self
    }

    pub fn temperature(mut self, t: f32) -> Self {
        self.params.temperature = t;
        self
    }

    pub fn top_k(mut self, k: usize) -> Self {
        self.params.top_k = k;
        self
    }

    pub fn stop(mut self, tok: i32) -> Self {
        self.params.stop = Some(tok);
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.params.seed = s;
        self
    }
}

/// Streamed reply events, in order: zero or more `Token`s, then exactly
/// one `Done` or `Error`.
#[derive(Debug, Clone)]
pub enum GenEvent {
    /// One generated token, as it was produced.
    Token { token: i32, text: String },
    /// Generation finished; the full reply.
    Done(GenReply),
    /// The request failed (unknown adapter, engine stopped, ...).
    Error(String),
}

#[derive(Debug, Clone)]
pub struct GenReply {
    pub text: String,
    /// Tokens generated for this request.
    pub tokens: usize,
    pub latency: Duration,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
    /// Pool worker that served it.
    pub worker: usize,
    pub adapter: String,
}

/// Receiver half of one request's event stream. Iterate for tokens, or
/// [`ReplyStream::wait`] for just the final reply.
pub struct ReplyStream {
    rx: Receiver<GenEvent>,
}

impl ReplyStream {
    /// Next event; `None` once the stream is finished (after
    /// `Done`/`Error`, or if the engine dropped the request).
    pub fn recv(&self) -> Option<GenEvent> {
        self.rx.recv().ok()
    }

    /// Drain the stream and return the final reply.
    pub fn wait(self) -> Result<GenReply> {
        for ev in self {
            match ev {
                GenEvent::Token { .. } => {}
                GenEvent::Done(reply) => return Ok(reply),
                GenEvent::Error(e) => bail!("{e}"),
            }
        }
        bail!("engine dropped the request")
    }
}

impl Iterator for ReplyStream {
    type Item = GenEvent;

    fn next(&mut self) -> Option<GenEvent> {
        self.rx.recv().ok()
    }
}

/// What [`Engine::spawn`]'s builder produces per worker: the worker's
/// own model (merged base-layout weights) plus a pristine snapshot of
/// those weights (used to unfuse adapters exactly).
pub type WorkerParts = (GenModel, HashMap<String, Tensor>);

type WorkerBuilder = dyn Fn(usize) -> Result<WorkerParts> + Send + Sync;

struct Job {
    prompt: String,
    params: SamplingParams,
    events: Sender<GenEvent>,
    t0: Instant,
}

struct QueueState {
    batcher: AdapterBatcher<Job>,
    open: bool,
}

struct Shared {
    cfg: EngineConfig,
    queue: Mutex<QueueState>,
    cv: Condvar,
    store: AdapterStore,
    metrics: Mutex<ServeMetrics>,
    live: AtomicUsize,
}

/// Multi-worker serving engine. See the module docs for the architecture.
pub struct Engine {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<Result<()>>>,
}

impl Engine {
    /// Spawn the pool. `builder(worker_id)` runs *inside* each worker
    /// thread and must construct that worker's model plus a pristine
    /// base-weight snapshot (used to unfuse adapters exactly). Backends
    /// with thread-local state (PJRT) are therefore supported: every
    /// worker builds its own.
    pub fn spawn<F>(cfg: EngineConfig, builder: F) -> Engine
    where
        F: Fn(usize) -> Result<WorkerParts> + Send + Sync + 'static,
    {
        let workers = cfg.workers;
        let max_wait = cfg.window.max(Duration::from_millis(1)) * 4;
        let batcher = AdapterBatcher::new(cfg.max_batch, max_wait).with_policy(cfg.policy);
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { batcher, open: true }),
            cv: Condvar::new(),
            store: AdapterStore::new(),
            metrics: Mutex::new(ServeMetrics::default()),
            live: AtomicUsize::new(workers),
            cfg,
        });
        let builder = Arc::new(builder);
        let handles = (0..workers)
            .map(|id| {
                let shared = shared.clone();
                let builder = builder.clone();
                std::thread::Builder::new()
                    .name(format!("s2ft-engine-{id}"))
                    .spawn(move || worker_main(id, shared, builder.as_ref()))
                    .expect("spawn engine worker")
            })
            .collect();
        Engine { shared, handles }
    }

    /// Submit a request; token events and the final reply arrive on the
    /// returned stream.
    pub fn submit(&self, req: GenRequest) -> ReplyStream {
        let (tx, rx) = channel();
        {
            // the open check shares the queue lock with the last-worker
            // drain, so a request can never be pushed after the drain ran
            // (it would hang forever with no worker left to fail it)
            let mut q = self.shared.queue.lock().unwrap();
            if !q.open {
                let _ = tx.send(GenEvent::Error("engine is shut down".into()));
                return ReplyStream { rx };
            }
            q.batcher.push(
                req.adapter,
                Job { prompt: req.prompt, params: req.params, events: tx, t0: Instant::now() },
            );
        }
        self.shared.cv.notify_all();
        ReplyStream { rx }
    }

    /// Convenience: submit and wait for the final reply.
    pub fn call(&self, req: GenRequest) -> Result<GenReply> {
        self.submit(req).wait()
    }

    // --- runtime adapter lifecycle (paper §6.2) -------------------------

    /// Register (or replace) an adapter while serving.
    pub fn register(&self, id: impl Into<String>, adapter: AnyAdapter) {
        self.shared.store.insert(id, adapter);
    }

    /// Unregister an adapter. In-flight batches already fused on it
    /// finish normally (workers hold their own handle).
    pub fn unregister(&self, id: &str) -> Result<()> {
        self.shared.store.remove(id)
    }

    /// Fuse-mode: weighted-combine registered S²FT adapters into a new
    /// adapter registered as `new_id`, servable immediately.
    pub fn fuse(&self, new_id: impl Into<String>, parts: &[(&str, f32)]) -> Result<()> {
        let handles: Vec<(Arc<AnyAdapter>, f32)> = parts
            .iter()
            .map(|(id, w)| {
                self.shared
                    .store
                    .get(id)
                    .map(|a| (a, *w))
                    .ok_or_else(|| anyhow!("adapter {id:?} not in store"))
            })
            .collect::<Result<_>>()?;
        let refs: Vec<(&S2ftAdapter, f32)> = handles
            .iter()
            .map(|(a, w)| match a.as_ref() {
                AnyAdapter::S2ft(s) => Ok((s, *w)),
                AnyAdapter::Lora(_) => Err(anyhow!("fuse supports S²FT adapters only")),
            })
            .collect::<Result<_>>()?;
        let fused = S2ftAdapter::fuse(&refs)?;
        self.shared.store.insert(new_id, AnyAdapter::S2ft(fused));
        Ok(())
    }

    /// The shared adapter registry.
    pub fn store(&self) -> &AdapterStore {
        &self.shared.store
    }

    /// Registered adapter ids, sorted.
    pub fn adapters(&self) -> Vec<String> {
        self.shared.store.ids()
    }

    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    pub fn metrics(&self) -> ServeMetrics {
        let mut m = self.shared.metrics.lock().unwrap().clone();
        m.switches = self.shared.store.switches();
        m
    }

    /// Stop accepting work, drain the queue, join every worker.
    pub fn shutdown(mut self) -> Result<()> {
        self.shutdown_inner()
    }

    fn shutdown_inner(&mut self) -> Result<()> {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.open = false;
        }
        self.shared.cv.notify_all();
        let mut first_err = None;
        for h in self.handles.drain(..) {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => first_err = first_err.or(Some(anyhow!("engine worker panicked"))),
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.shutdown_inner();
    }
}

fn worker_main(id: usize, shared: Arc<Shared>, builder: &WorkerBuilder) -> Result<()> {
    let res = (|| -> Result<()> {
        let (mut gm, snapshot) = builder(id)?;
        let mut slot = AdapterSlot::new();
        loop {
            let prefer = slot.active().map(String::from);
            let Some(plan) = next_plan(&shared, prefer.as_deref()) else {
                break;
            };
            serve_batch(id, &shared, &mut gm, &mut slot, &snapshot, plan);
        }
        Ok(())
    })();
    if shared.live.fetch_sub(1, Ordering::SeqCst) == 1 {
        // last worker out: nothing will ever drain the queue again
        let mut q = shared.queue.lock().unwrap();
        q.open = false;
        while let Some(plan) = q.batcher.next_batch() {
            for item in plan.items {
                let _ = item.payload.events.send(GenEvent::Error("engine stopped".into()));
            }
        }
    }
    res
}

/// Block until a batch is available (respecting the arrival window) or
/// the engine is closed and drained. `None` = exit. `prefer` is the
/// calling worker's currently-fused adapter (switch-free fast path).
fn next_plan(shared: &Shared, prefer: Option<&str>) -> Option<BatchPlan<Job>> {
    let mut q = shared.queue.lock().unwrap();
    loop {
        if q.batcher.is_empty() {
            if !q.open {
                return None;
            }
            q = shared.cv.wait(q).unwrap();
            continue;
        }
        let age = q.batcher.oldest_age();
        if !q.open || q.batcher.len() >= shared.cfg.max_batch || age >= shared.cfg.window {
            break;
        }
        let (qq, _) = shared.cv.wait_timeout(q, shared.cfg.window - age).unwrap();
        q = qq;
    }
    q.batcher.next_batch_preferring(prefer)
}

fn serve_batch(
    id: usize,
    shared: &Shared,
    gm: &mut GenModel,
    slot: &mut AdapterSlot,
    snapshot: &HashMap<String, Tensor>,
    plan: BatchPlan<Job>,
) {
    let fail_all = |items: Vec<Queued<Job>>, msg: String| {
        for item in items {
            let _ = item.payload.events.send(GenEvent::Error(msg.clone()));
        }
    };
    // adapter-affinity switch (at most one per batch; scatter_add for S²FT)
    let switched = if plan.adapter == BASE_ADAPTER {
        slot.deactivate(&mut gm.params, snapshot)
    } else {
        slot.switch_to(&shared.store, &plan.adapter, &mut gm.params, snapshot)
    };
    if let Err(e) = switched {
        // transactional switch: previous adapter still fused, the engine
        // keeps serving — only this batch fails
        return fail_all(plan.items, format!("adapter switch failed: {e:#}"));
    }

    let items = plan.items;
    let bs = items.len();
    let reqs: Vec<DecodeRequest> = items
        .iter()
        .map(|q| DecodeRequest {
            prompt: q.payload.prompt.clone(),
            max_new: q.payload.params.max_new,
            temperature: q.payload.params.temperature,
            top_k: q.payload.params.top_k,
            stop: q.payload.params.stop,
            seed: q.payload.params.seed,
        })
        .collect();
    let tk = Tokenizer;
    let mut counts = vec![0usize; bs];
    let texts = gm.generate_stream(&reqs, |i, tok| {
        counts[i] += 1;
        let _ = items[i]
            .payload
            .events
            .send(GenEvent::Token { token: tok, text: tk.decode(&[tok]) });
    });
    let texts = match texts {
        Ok(t) => t,
        Err(e) => return fail_all(items, format!("generation failed: {e:#}")),
    };
    {
        let mut m = shared.metrics.lock().unwrap();
        m.requests += bs;
        m.batches += 1;
        m.tokens += counts.iter().sum::<usize>();
        for item in &items {
            m.record_latency_ms(item.payload.t0.elapsed().as_secs_f64() * 1e3);
        }
    }
    for ((item, text), tokens) in items.into_iter().zip(texts).zip(counts) {
        let latency = item.payload.t0.elapsed();
        let _ = item.payload.events.send(GenEvent::Done(GenReply {
            text,
            tokens,
            latency,
            batch_size: bs,
            worker: id,
            adapter: item.adapter,
        }));
    }
}
