//! Host-side dense tensor type shared by every backend (the `pjrt` module
//! bridges it to `xla::Literal` when that feature is enabled).

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host-side dense tensor (f32 or i32, row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(shape: impl Into<Vec<usize>>, data: Vec<f32>) -> Self {
        let shape = shape.into();
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        Self { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: impl Into<Vec<usize>>, data: Vec<i32>) -> Self {
        let shape = shape.into();
        assert_eq!(shape.iter().product::<usize>().max(1), data.len());
        Self { shape, data: TensorData::I32(data) }
    }

    pub fn zeros(shape: impl Into<Vec<usize>>) -> Self {
        let shape = shape.into();
        let n = shape.iter().product::<usize>().max(1);
        Self { shape, data: TensorData::F32(vec![0.0; n]) }
    }

    pub fn ones(shape: impl Into<Vec<usize>>) -> Self {
        let shape = shape.into();
        let n = shape.iter().product::<usize>().max(1);
        Self { shape, data: TensorData::F32(vec![1.0; n]) }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self { shape: vec![], data: TensorData::F32(vec![v]) }
    }

    pub fn scalar_i32(v: i32) -> Self {
        Self { shape: vec![], data: TensorData::I32(vec![v]) }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn bytes(&self) -> usize {
        self.numel() * 4
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => bail!("tensor is f32, expected i32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn scalar_value_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("not a scalar: {:?}", self.shape);
        }
        Ok(v[0])
    }

    /// Squared L2 distance to another tensor (diagnostics / tests).
    pub fn l2_to(&self, other: &Tensor) -> Result<f32> {
        let a = self.as_f32()?;
        let b = other.as_f32()?;
        if a.len() != b.len() {
            bail!("size mismatch {} vs {}", a.len(), b.len());
        }
        Ok(a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum())
    }
}
