//! The four GEMM shapes, cache-blocked and output-partitioned.
//!
//! Each kernel keeps one accumulator per output element and walks the
//! reduction axis in ascending order, so the result is bit-identical to
//! the naive triple loop ([`super::reference`]) and independent of the
//! thread count. The `gemm` micro-kernel processes four A-rows per pass
//! over a B-row, cutting B memory traffic 4× while the four output rows
//! (4·n·4 bytes) stay resident in L1.

use super::{configured_threads, for_each_row_chunk};

/// `A (m,k) @ B (k,n)` with the configured worker count.
pub fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    gemm_with_threads(a, b, m, k, n, configured_threads())
}

/// `A (m,k) @ B (k,n)` on an explicit worker count (output rows are
/// partitioned; reduction order is fixed, so results do not depend on
/// `threads`).
pub fn gemm_with_threads(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k, "gemm: A shape");
    debug_assert_eq!(b.len(), k * n, "gemm: B shape");
    let mut out = vec![0.0f32; m * n];
    for_each_row_chunk(&mut out, n, threads, 2 * m * k * n, |row0, chunk| {
        gemm_rows(a, b, row0, k, n, chunk);
    });
    out
}

/// Rows `[row0, row0 + chunk_rows)` of `A @ B` into `out`.
fn gemm_rows(a: &[f32], b: &[f32], row0: usize, k: usize, n: usize, out: &mut [f32]) {
    let rows = out.len() / n;
    let mut r = 0;
    // 4-row micro-kernel: each B row is streamed once per quad.
    while r + 4 <= rows {
        let quad = &mut out[r * n..(r + 4) * n];
        let (o0, quad) = quad.split_at_mut(n);
        let (o1, quad) = quad.split_at_mut(n);
        let (o2, o3) = quad.split_at_mut(n);
        let a0 = &a[(row0 + r) * k..][..k];
        let a1 = &a[(row0 + r + 1) * k..][..k];
        let a2 = &a[(row0 + r + 2) * k..][..k];
        let a3 = &a[(row0 + r + 3) * k..][..k];
        let quads = a0.iter().zip(a1).zip(a2).zip(a3).enumerate();
        for (kk, (((&v0, &v1), &v2), &v3)) in quads {
            if v0 == 0.0 && v1 == 0.0 && v2 == 0.0 && v3 == 0.0 {
                continue; // fully-masked quad column (e.g. padded dlogits)
            }
            let br = &b[kk * n..][..n];
            for (j, &bv) in br.iter().enumerate() {
                o0[j] += v0 * bv;
                o1[j] += v1 * bv;
                o2[j] += v2 * bv;
                o3[j] += v3 * bv;
            }
        }
        r += 4;
    }
    // Remainder rows: plain ikj with a zero-skip.
    for rr in r..rows {
        let arow = &a[(row0 + rr) * k..][..k];
        let orow = &mut out[rr * n..][..n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let br = &b[kk * n..][..n];
            for (o, &bv) in orow.iter_mut().zip(br) {
                *o += av * bv;
            }
        }
    }
}

/// `A (m,k) @ Bᵀ` with `B (n,k)` — row-dot products.
pub fn gemm_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    gemm_nt_with_threads(a, b, m, k, n, configured_threads())
}

/// `A (m,k) @ Bᵀ` with `B (n,k)` on an explicit worker count.
pub fn gemm_nt_with_threads(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k, "gemm_nt: A shape");
    debug_assert_eq!(b.len(), n * k, "gemm_nt: B shape");
    let mut out = vec![0.0f32; m * n];
    for_each_row_chunk(&mut out, n, threads, 2 * m * k * n, |row0, chunk| {
        for (rr, orow) in chunk.chunks_mut(n).enumerate() {
            let arow = &a[(row0 + rr) * k..][..k];
            for (o, brow) in orow.iter_mut().zip(b.chunks(k.max(1))) {
                let mut s = 0.0f32;
                for (x, y) in arow.iter().zip(brow) {
                    s += x * y;
                }
                *o = s;
            }
        }
    });
    out
}

/// `A[:, :lim]ᵀ @ B` with `A (rows, ka)`, `B (rows, kb)` → `(lim, kb)`.
///
/// The S²FT row-split partial-backprop kernel: with `lim < ka` only the
/// trainable slice of the weight gradient is ever materialized — the
/// activation is sliced *before* the GEMM (paper §3.3).
pub fn gemm_tn(a: &[f32], b: &[f32], rows: usize, ka: usize, kb: usize, lim: usize) -> Vec<f32> {
    gemm_tn_with_threads(a, b, rows, ka, kb, lim, configured_threads())
}

/// [`gemm_tn`] on an explicit worker count (output rows partitioned).
#[allow(clippy::too_many_arguments)]
pub fn gemm_tn_with_threads(
    a: &[f32],
    b: &[f32],
    rows: usize,
    ka: usize,
    kb: usize,
    lim: usize,
    threads: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), rows * ka, "gemm_tn: A shape");
    debug_assert_eq!(b.len(), rows * kb, "gemm_tn: B shape");
    debug_assert!(lim <= ka, "gemm_tn: lim {lim} > ka {ka}");
    let mut out = vec![0.0f32; lim * kb];
    for_each_row_chunk(&mut out, kb, threads, 2 * rows * lim * kb, |i0, chunk| {
        let nlim = chunk.len() / kb;
        for r in 0..rows {
            let arow = &a[r * ka + i0..][..nlim];
            let brow = &b[r * kb..][..kb];
            for (ii, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let orow = &mut chunk[ii * kb..][..kb];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    });
    out
}

/// `Aᵀ @ B[:, :lim]` with `A (rows, ka)`, `B (rows, kb)` → `(ka, lim)` —
/// the column-split partial gradient (trainable head/channel columns).
pub fn gemm_tn_outcols(
    a: &[f32],
    b: &[f32],
    rows: usize,
    ka: usize,
    kb: usize,
    lim: usize,
) -> Vec<f32> {
    gemm_tn_outcols_with_threads(a, b, rows, ka, kb, lim, configured_threads())
}

/// [`gemm_tn_outcols`] on an explicit worker count.
#[allow(clippy::too_many_arguments)]
pub fn gemm_tn_outcols_with_threads(
    a: &[f32],
    b: &[f32],
    rows: usize,
    ka: usize,
    kb: usize,
    lim: usize,
    threads: usize,
) -> Vec<f32> {
    debug_assert_eq!(a.len(), rows * ka, "gemm_tn_outcols: A shape");
    debug_assert_eq!(b.len(), rows * kb, "gemm_tn_outcols: B shape");
    debug_assert!(lim <= kb, "gemm_tn_outcols: lim {lim} > kb {kb}");
    let mut out = vec![0.0f32; ka * lim];
    for_each_row_chunk(&mut out, lim, threads, 2 * rows * ka * lim, |i0, chunk| {
        let ni = chunk.len() / lim;
        for r in 0..rows {
            let arow = &a[r * ka + i0..][..ni];
            let brow = &b[r * kb..][..lim];
            for (ii, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let orow = &mut chunk[ii * lim..][..lim];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    });
    out
}

/// Sliced-cache copy: the first `lim` columns of each row of `A (rows,
/// cols)`, packed into a `(rows, lim)` buffer.
///
/// This is the cache-time half of the S²FT partial-gradient contract:
/// the trainable-first co-permutation puts the trainable channels first,
/// so retaining `A[:, :lim]` at forward time is enough to later compute
/// `gemm_tn(sliced, dY, rows, lim, kb, lim)` — bit-identical to
/// `gemm_tn(full, dY, rows, cols, kb, lim)`, but the frozen channels are
/// never held across the forward/backward gap.
pub fn slice_cols(a: &[f32], rows: usize, cols: usize, lim: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), rows * cols, "slice_cols: A shape");
    debug_assert!(lim <= cols, "slice_cols: lim {lim} > cols {cols}");
    let mut out = vec![0.0f32; rows * lim];
    for (r, orow) in out.chunks_exact_mut(lim.max(1)).enumerate() {
        orow.copy_from_slice(&a[r * cols..r * cols + lim]);
    }
    out
}

/// Fused GEMV accumulate: `y (n) += scale · (x (k) @ W (k,n))` on the
/// calling thread — the per-request adapter-delta shape (one activation
/// row against a small dense delta).
pub fn gemv_acc(x: &[f32], w: &[f32], n: usize, scale: f32, y: &mut [f32]) {
    debug_assert_eq!(y.len(), n, "gemv_acc: y shape");
    debug_assert_eq!(w.len(), x.len() * n, "gemv_acc: W shape");
    for (kk, &xv) in x.iter().enumerate() {
        let v = xv * scale;
        if v == 0.0 {
            continue;
        }
        let wrow = &w[kk * n..][..n];
        for (o, &wv) in y.iter_mut().zip(wrow) {
            *o += v * wv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::reference;
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn gemm_known_values() {
        // [1 2; 3 4] @ [1 1; 1 1] = [3 3; 7 7]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![1.0, 1.0, 1.0, 1.0];
        assert_eq!(gemm(&a, &b, 2, 2, 2), vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn gemm_quad_and_remainder_match_reference() {
        // rows chosen to exercise the 4-row micro-kernel plus a remainder
        let mut rng = Rng::seed(11);
        for (m, k, n) in [(1, 3, 2), (4, 5, 6), (6, 7, 3), (9, 4, 8), (12, 1, 1)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, k * n);
            assert_eq!(
                gemm_with_threads(&a, &b, m, k, n, 1),
                reference::gemm(&a, &b, m, k, n),
                "{m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn gemm_nt_matches_reference() {
        let mut rng = Rng::seed(12);
        for (m, k, n) in [(5, 4, 3), (8, 6, 7), (3, 1, 9)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, n * k);
            assert_eq!(
                gemm_nt_with_threads(&a, &b, m, k, n, 1),
                reference::gemm_nt(&a, &b, m, k, n)
            );
        }
    }

    #[test]
    fn gemm_tn_partial_equals_slice_of_full() {
        let mut rng = Rng::seed(13);
        let (rows, ka, kb) = (9, 7, 5);
        let a = randv(&mut rng, rows * ka);
        let b = randv(&mut rng, rows * kb);
        let full = gemm_tn(&a, &b, rows, ka, kb, ka);
        for lim in [0, 1, 3, ka] {
            let part = gemm_tn(&a, &b, rows, ka, kb, lim);
            assert_eq!(part, full[..lim * kb].to_vec(), "lim {lim}");
            assert_eq!(part, reference::gemm_tn(&a, &b, rows, ka, kb, lim));
        }
    }

    #[test]
    fn gemm_tn_outcols_partial_equals_cols_of_full() {
        let mut rng = Rng::seed(14);
        let (rows, ka, kb) = (8, 6, 7);
        let a = randv(&mut rng, rows * ka);
        let b = randv(&mut rng, rows * kb);
        let full = gemm_tn_outcols(&a, &b, rows, ka, kb, kb);
        for lim in [0, 2, 5, kb] {
            let part = gemm_tn_outcols(&a, &b, rows, ka, kb, lim);
            let want: Vec<f32> =
                (0..ka).flat_map(|i| full[i * kb..i * kb + lim].to_vec()).collect();
            assert_eq!(part, want, "lim {lim}");
            assert_eq!(part, reference::gemm_tn_outcols(&a, &b, rows, ka, kb, lim));
        }
    }

    #[test]
    fn slice_cols_keeps_leading_columns() {
        // (2,3) -> first 2 cols
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        assert_eq!(slice_cols(&a, 2, 3, 2), vec![1.0, 2.0, 4.0, 5.0]);
        assert_eq!(slice_cols(&a, 2, 3, 0), Vec::<f32>::new());
        assert_eq!(slice_cols(&a, 2, 3, 3), a);
    }

    #[test]
    fn gemm_tn_on_sliced_cache_is_bit_identical_to_gemm_time_slice() {
        // the cache-time slice contract: slicing A before the GEMM gives
        // the exact bits of the lim-limited GEMM over the full A
        let mut rng = Rng::seed(16);
        let (rows, ka, kb) = (11, 9, 6);
        let a = randv(&mut rng, rows * ka);
        let b = randv(&mut rng, rows * kb);
        for lim in [0usize, 1, 4, ka] {
            let at_gemm_time = gemm_tn(&a, &b, rows, ka, kb, lim);
            let sliced = slice_cols(&a, rows, ka, lim);
            let at_cache_time = gemm_tn(&sliced, &b, rows, lim, kb, lim);
            assert!(
                at_gemm_time
                    .iter()
                    .zip(&at_cache_time)
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "lim {lim}"
            );
        }
    }

    #[test]
    fn gemv_acc_accumulates_scaled() {
        let x = vec![1.0, 0.0, 2.0];
        let w = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // (3,2)
        let mut y = vec![10.0, 20.0];
        gemv_acc(&x, &w, 2, 0.5, &mut y);
        // y += 0.5 * [1*[1,2] + 2*[5,6]] = [5.5, 7.0]
        assert_eq!(y, vec![15.5, 27.0]);
    }

    #[test]
    fn thread_counts_are_bit_identical() {
        let mut rng = Rng::seed(15);
        let (m, k, n) = (33, 40, 37); // above MIN_PAR_WORK
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let bt = randv(&mut rng, n * k);
        let one = gemm_with_threads(&a, &b, m, k, n, 1);
        let one_nt = gemm_nt_with_threads(&a, &bt, m, k, n, 1);
        for t in [2usize, 3, 5, 8] {
            let many = gemm_with_threads(&a, &b, m, k, n, t);
            assert!(one.iter().zip(&many).all(|(x, y)| x.to_bits() == y.to_bits()), "t={t}");
            let many_nt = gemm_nt_with_threads(&a, &bt, m, k, n, t);
            assert!(one_nt.iter().zip(&many_nt).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
    }
}
