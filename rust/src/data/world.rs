//! The synthetic world: a fixed fact base shared by the pre-training
//! corpus and every downstream task suite.
//!
//! This replaces the paper's "pre-trained knowledge" (DESIGN.md §2): the
//! base model is pre-trained on statements generated from these facts, so
//! fine-tuning methods can *forget* them — which is exactly the axis the
//! paper's generalization experiments (Fig 2, Tables 1-3) measure.

use crate::util::rng::Rng;

pub const WORLD_SEED: u64 = 0x57_4F_52_4C_44; // "WORLD"

#[derive(Debug, Clone)]
pub struct Entity {
    pub name: String,
    pub color: &'static str,
    pub kind: &'static str,
    pub size: &'static str,
    pub place: &'static str,
}

pub const COLORS: [&str; 6] = ["red", "blue", "green", "gold", "gray", "pink"];
pub const KINDS: [&str; 6] = ["bird", "fish", "tool", "gem", "tree", "robot"];
pub const SIZES: [&str; 3] = ["small", "big", "huge"];
pub const PLACES: [&str; 5] = ["cave", "lake", "hill", "barn", "dome"];

/// kind -> ability (category-level rules, used by arc-style questions)
pub const ABILITIES: [(&str, &str); 6] = [
    ("bird", "fly"),
    ("fish", "swim"),
    ("tool", "cut"),
    ("gem", "shine"),
    ("tree", "grow"),
    ("robot", "compute"),
];

/// goal -> correct tool kind (piqa-style physical commonsense)
pub const GOALS: [(&str, &str); 5] = [
    ("cross the lake", "fish"),
    ("reach the sky", "bird"),
    ("split a log", "tool"),
    ("light the cave", "gem"),
    ("solve a puzzle", "robot"),
];

#[derive(Debug, Clone)]
pub struct World {
    pub entities: Vec<Entity>,
}

impl World {
    /// The canonical world: deterministic, identical for corpus + tasks.
    pub fn canonical() -> World {
        World::generate(WORLD_SEED, 40)
    }

    pub fn generate(seed: u64, n: usize) -> World {
        let mut rng = Rng::seed(seed);
        let consonants = ["b", "d", "f", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z"];
        let vowels = ["a", "e", "i", "o", "u"];
        let mut entities = Vec::with_capacity(n);
        let mut used = std::collections::HashSet::new();
        while entities.len() < n {
            let name = format!(
                "{}{}{}{}{}",
                rng.pick(&consonants),
                rng.pick(&vowels),
                rng.pick(&consonants),
                rng.pick(&vowels),
                rng.pick(&consonants),
            );
            if !used.insert(name.clone()) {
                continue;
            }
            entities.push(Entity {
                name,
                color: COLORS[rng.below(COLORS.len())],
                kind: KINDS[rng.below(KINDS.len())],
                size: SIZES[rng.below(SIZES.len())],
                place: PLACES[rng.below(PLACES.len())],
            });
        }
        World { entities }
    }

    pub fn ability_of(kind: &str) -> &'static str {
        ABILITIES.iter().find(|(k, _)| *k == kind).map(|(_, a)| *a).unwrap()
    }

    pub fn entity(&self, rng: &mut Rng) -> &Entity {
        &self.entities[rng.below(self.entities.len())]
    }

    /// All declarative fact statements (the pre-training corpus source).
    pub fn fact_statements(&self) -> Vec<String> {
        let mut out = Vec::new();
        for e in &self.entities {
            out.push(format!("{} is {}.", e.name, e.color));
            out.push(format!("{} is a {}.", e.name, e.kind));
            out.push(format!("{} is {}.", e.name, e.size));
            out.push(format!("{} lives in the {}.", e.name, e.place));
        }
        for (kind, ability) in ABILITIES {
            out.push(format!("every {} can {}.", kind, ability));
        }
        for (goal, kind) in GOALS {
            out.push(format!("to {} you need a {}.", goal, kind));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_world_is_stable() {
        let a = World::canonical();
        let b = World::canonical();
        assert_eq!(a.entities.len(), b.entities.len());
        for (x, y) in a.entities.iter().zip(&b.entities) {
            assert_eq!(x.name, y.name);
            assert_eq!(x.color, y.color);
        }
    }

    #[test]
    fn names_unique_and_pronounceable() {
        let w = World::canonical();
        let names: std::collections::HashSet<_> = w.entities.iter().map(|e| &e.name).collect();
        assert_eq!(names.len(), w.entities.len());
        assert!(w.entities.iter().all(|e| e.name.len() == 5));
    }

    #[test]
    fn fact_statements_cover_entities() {
        let w = World::canonical();
        let facts = w.fact_statements();
        assert!(facts.len() >= w.entities.len() * 4);
        assert!(facts.iter().any(|f| f.contains("can fly")));
    }
}
