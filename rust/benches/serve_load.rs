//! Open-loop serving load bench: Poisson arrivals against the
//! continuous-batching engine.
//!
//! Unlike `serve.rs` (closed-loop: submit a wave, wait, repeat), this
//! target models an *open* system — requests arrive on a Poisson clock
//! whether or not the engine has kept up — which is what exposes
//! queueing latency and KV-pool churn. Two lanes:
//!
//! * `load/tiny/poisson/streams=128/workers=4` — 128 in-flight streams
//!   across 4 workers with an auto-sized KV pool (no eviction), the
//!   headline throughput/latency datum.
//! * `load/tiny/churn/streams=64/kv_blocks=6` — a deliberately tiny
//!   6-block pool on one worker, so admission, reservation and eviction
//!   backpressure all cycle continuously.
//! * `load/tiny/zipf/adapters=1000/resident=32/affinity` — the
//!   thousand-adapter multi-tenant lane: 1000 adapters persisted on
//!   disk, a 32-adapter resident budget, and Zipf(s=1.1)-popular
//!   request traffic batched with adapter affinity (the registry's LRU
//!   spill, lazy load and resident-preferring scheduling all cycle).
//! * `load/tiny/zipf/adapters=1000/resident=32/switch_per_request` —
//!   the same registered set and traffic served with `max_batch = 1`
//!   FIFO scheduling, paying one adapter acquire+switch per request:
//!   the baseline the affinity lane must beat on throughput.
//!
//! Each lane prints p50/p99 request latency, aggregate tok/s and the
//! eviction/KV-peak counters after its timed runs; the Zipf lanes add
//! residency hit rate, load/spill counts and mean switch cost. Knobs:
//! `S2FT_BENCH_BUDGET_MS` shortens the wall budget (CI smoke);
//! `make bench-baseline` regenerates the committed regression baseline
//! from this target's JSON (see README "Benchmarks & baselines").

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use repro::adapter::{save_adapter, AnyAdapter};
use repro::runtime::{Executable, Executor, NativeBackend, Tensor};
use repro::serve::{synthetic_adapter, Engine, EngineConfig, GenRequest, SchedPolicy};
use repro::train::GenModel;
use repro::util::bench::BenchSuite;
use repro::util::rng::Rng;

fn tiny_params(rt: &NativeBackend) -> HashMap<String, Tensor> {
    let init = rt.load("init_tiny").unwrap();
    let outs = init.run(&[Tensor::scalar_i32(5)]).unwrap();
    init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect()
}

fn spawn_engine(cfg: EngineConfig, n_adapters: usize) -> Engine {
    let engine = Engine::spawn(cfg, |_wid| {
        let rt = NativeBackend::builtin();
        let params = tiny_params(&rt);
        let snapshot = params.clone();
        let gm = GenModel::new(&rt, "tiny", params)?;
        Ok((gm, snapshot))
    });
    let rt = NativeBackend::builtin();
    let mm = rt.artifacts().model("tiny").unwrap().clone();
    let mut rng = Rng::seed(0xBE17);
    for a in 0..n_adapters {
        engine.register(format!("a{a}"), synthetic_adapter(&mm, &mut rng));
    }
    engine
}

/// Submit `n` requests with exponential (Poisson-process) inter-arrival
/// gaps of mean `mean_gap_us`, then drain every stream. Evicted streams
/// on the tight-pool lane terminate with an error; the load generator
/// tolerates both outcomes.
fn open_loop(engine: &Engine, rng: &mut Rng, n: usize, n_adapters: usize, mean_gap_us: f64) {
    let streams: Vec<_> = (0..n)
        .map(|i| {
            let gap_us = -(1.0 - rng.f64()).ln() * mean_gap_us;
            std::thread::sleep(Duration::from_nanos((gap_us * 1e3) as u64));
            let max_new = [2usize, 4, 8][i % 3];
            let adapter = format!("a{}", i % n_adapters);
            engine.submit(GenRequest::new(adapter, format!("q: item {i}?")).max_new(max_new))
        })
        .collect();
    for s in streams {
        let _ = s.wait();
    }
}

fn report(engine: &Engine, wall: Duration) {
    let m = engine.metrics();
    println!(
        "  p50 {:.2} ms, p99 {:.2} ms, {:.0} tok/s, {} served, {} eviction(s), kv peak {:.1} KB",
        m.percentile_ms(0.5),
        m.percentile_ms(0.99),
        m.tokens as f64 / wall.as_secs_f64().max(1e-9),
        m.requests,
        m.evictions,
        m.kv_peak_bytes() as f64 / 1e3
    );
}

fn report_residency(engine: &Engine, wall: Duration) {
    report(engine, wall);
    let m = engine.metrics();
    let r = &m.residency;
    println!(
        "  residency: {} registered / {} resident, hit rate {:.3} ({} load(s), {} spill(s)); \
         {} switch(es) mean {:.1} us; {} fused / {} unfused batches",
        r.registered,
        r.resident,
        r.hit_rate(),
        r.loads,
        r.spills,
        m.switches,
        m.mean_switch_us(),
        r.fused_batches,
        r.unfused_batches
    );
}

/// Persist `n` synthetic tiny-model adapters (`a0000.s2ft` …) into `dir`
/// so the engines can register the full set lazily via `adapter_dir`.
fn write_adapter_dir(dir: &Path, n: usize) {
    let _ = std::fs::remove_dir_all(dir);
    std::fs::create_dir_all(dir).unwrap();
    let rt = NativeBackend::builtin();
    let mm = rt.artifacts().model("tiny").unwrap().clone();
    let mut rng = Rng::seed(0x21FF);
    for a in 0..n {
        let AnyAdapter::S2ft(ad) = synthetic_adapter(&mm, &mut rng) else { unreachable!() };
        save_adapter(dir.join(format!("a{a:04}.s2ft")), &ad).unwrap();
    }
}

/// Normalized Zipf(s) CDF over ranks `1..=n` (rank 0 is the hottest).
fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let mut cdf = Vec::with_capacity(n);
    let mut acc = 0.0;
    for r in 1..=n {
        acc += 1.0 / (r as f64).powf(s);
        cdf.push(acc);
    }
    for x in &mut cdf {
        *x /= acc;
    }
    cdf
}

/// Zipf-popular open loop: adapter ranks drawn from `cdf`, Poisson
/// inter-arrival gaps of mean `mean_gap_us`.
fn zipf_loop(engine: &Engine, rng: &mut Rng, cdf: &[f64], n: usize, mean_gap_us: f64) {
    let streams: Vec<_> = (0..n)
        .map(|i| {
            let gap_us = -(1.0 - rng.f64()).ln() * mean_gap_us;
            std::thread::sleep(Duration::from_nanos((gap_us * 1e3) as u64));
            let u = rng.f64();
            let a = cdf.partition_point(|&x| x < u).min(cdf.len() - 1);
            let max_new = [2usize, 4, 8][i % 3];
            engine
                .submit(GenRequest::new(format!("a{a:04}"), format!("q: item {i}?")).max_new(max_new))
        })
        .collect();
    for s in streams {
        let _ = s.wait();
    }
}

fn main() {
    let mut suite = BenchSuite::new("serve_load").slow();
    println!(
        "open-loop serving load (available parallelism {})\n",
        std::thread::available_parallelism().map_or(1, |n| n.get())
    );
    let mut rng = Rng::seed(0x10AD);

    // --- headline: 128 Poisson streams, 4 workers, ample pool -----------
    {
        let cfg = EngineConfig::new()
            .workers(4)
            .max_batch(8)
            .window(Duration::from_millis(1));
        let engine = spawn_engine(cfg, 4);
        let t0 = Instant::now();
        suite.bench("load/tiny/poisson/streams=128/workers=4", || {
            open_loop(&engine, &mut rng, 128, 4, 150.0);
        });
        report(&engine, t0.elapsed());
        engine.shutdown().unwrap();
    }

    // --- churn: one worker, 6-block pool, eviction backpressure ---------
    {
        let cfg = EngineConfig::new()
            .workers(1)
            .max_batch(4)
            .window(Duration::from_millis(1))
            .kv_block_tokens(4)
            .kv_blocks(6);
        let engine = spawn_engine(cfg, 2);
        let t0 = Instant::now();
        suite.bench("load/tiny/churn/streams=64/kv_blocks=6", || {
            open_loop(&engine, &mut rng, 64, 2, 100.0);
        });
        report(&engine, t0.elapsed());
        engine.shutdown().unwrap();
    }

    // --- thousand-adapter multi-tenancy: Zipf traffic, bounded residency -
    let dir = std::env::temp_dir().join(format!("s2ft-bench-adapters-{}", std::process::id()));
    write_adapter_dir(&dir, 1000);
    let cdf = zipf_cdf(1000, 1.1);

    // affinity-grouped: one fused batch per adapter group, workers prefer
    // resident adapters, cold tail spills and lazily reloads
    {
        let cfg = EngineConfig::new()
            .workers(2)
            .max_batch(8)
            .window(Duration::from_millis(1))
            .max_resident(32)
            .adapter_dir(&dir);
        let engine = spawn_engine(cfg, 0);
        let t0 = Instant::now();
        suite.bench("load/tiny/zipf/adapters=1000/resident=32/affinity", || {
            zipf_loop(&engine, &mut rng, &cdf, 96, 120.0);
        });
        report_residency(&engine, t0.elapsed());
        engine.shutdown().unwrap();
    }

    // switch-per-request baseline: same registered set and traffic, but
    // max_batch=1 FIFO forfeits grouping — one acquire+switch per request
    {
        let cfg = EngineConfig::new()
            .workers(2)
            .max_batch(1)
            .window(Duration::ZERO)
            .policy(SchedPolicy::Fifo)
            .max_resident(32)
            .adapter_dir(&dir);
        let engine = spawn_engine(cfg, 0);
        let t0 = Instant::now();
        suite.bench("load/tiny/zipf/adapters=1000/resident=32/switch_per_request", || {
            zipf_loop(&engine, &mut rng, &cdf, 96, 120.0);
        });
        report_residency(&engine, t0.elapsed());
        engine.shutdown().unwrap();
    }
    let _ = std::fs::remove_dir_all(&dir);

    suite.save();
}
