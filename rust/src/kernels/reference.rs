//! Naive triple-loop GEMM oracles.
//!
//! Deliberately unblocked, unskipping and single-threaded: these are the
//! ground truth the optimized kernels are proptested against
//! (elementwise, bit-exact — both sides accumulate each output element
//! in ascending reduction order, rounding every product and sum
//! separately) and the "before" side of the kernel micro-benchmarks.
//! The contract covers *all* inputs, non-finite values and signed zeros
//! included, so the oracles must never skip a term.

/// `A (m,k) @ B (k,n)`.
pub fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0f32;
            for kk in 0..k {
                s += a[i * k + kk] * b[kk * n + j];
            }
            out[i * n + j] = s;
        }
    }
    out
}

/// `A (m,k) @ Bᵀ` with `B (n,k)`.
pub fn gemm_nt(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0f32;
            for kk in 0..k {
                s += a[i * k + kk] * b[j * k + kk];
            }
            out[i * n + j] = s;
        }
    }
    out
}

/// `A[:, :lim]ᵀ @ B` with `A (rows, ka)`, `B (rows, kb)` → `(lim, kb)`.
pub fn gemm_tn(a: &[f32], b: &[f32], rows: usize, ka: usize, kb: usize, lim: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; lim * kb];
    for i in 0..lim {
        for j in 0..kb {
            let mut s = 0.0f32;
            for r in 0..rows {
                s += a[r * ka + i] * b[r * kb + j];
            }
            out[i * kb + j] = s;
        }
    }
    out
}

/// `y (n) += scale · (x (k) @ W (k,n))` into the caller's accumulator,
/// ascending `k`, scaling `x` before the product — the [`super::gemv_acc`]
/// oracle. Accumulating into caller-owned memory is part of the contract:
/// a `y` lane holding `-0.0` must flip to `+0.0` when a (possibly zero)
/// product is added.
pub fn gemv_acc(x: &[f32], w: &[f32], n: usize, scale: f32, y: &mut [f32]) {
    for (kk, &xv) in x.iter().enumerate() {
        let v = xv * scale;
        for j in 0..n {
            y[j] += v * w[kk * n + j];
        }
    }
}

/// `Aᵀ @ B[:, :lim]` with `A (rows, ka)`, `B (rows, kb)` → `(ka, lim)`.
pub fn gemm_tn_outcols(
    a: &[f32],
    b: &[f32],
    rows: usize,
    ka: usize,
    kb: usize,
    lim: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; ka * lim];
    for i in 0..ka {
        for j in 0..lim {
            let mut s = 0.0f32;
            for r in 0..rows {
                s += a[r * ka + i] * b[r * kb + j];
            }
            out[i * lim + j] = s;
        }
    }
    out
}
