//! Checkpointing: base-layout parameter dicts as raw little-endian f32
//! blobs plus an index.json (no external serialization deps).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::{Tensor, TensorData};
use crate::util::json::Json;

/// Save a named tensor pool to `dir/` (one .bin per tensor + index.json).
pub fn save_params(dir: impl AsRef<Path>, params: &HashMap<String, Tensor>) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let mut index = Vec::new();
    for (name, tensor) in params {
        let fname = format!("{}.bin", name.replace(['/', '.'], "_"));
        let path = dir.join(&fname);
        let mut f = std::fs::File::create(&path)?;
        match &tensor.data {
            TensorData::F32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            TensorData::I32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
        }
        index.push(Json::Arr(vec![
            Json::str(name.clone()),
            Json::str(fname),
            Json::Arr(tensor.shape.iter().map(|&d| Json::num(d as f64)).collect()),
            Json::str(match tensor.data {
                TensorData::F32(_) => "f32",
                TensorData::I32(_) => "i32",
            }),
        ]));
    }
    std::fs::write(dir.join("index.json"), Json::Arr(index).to_string_pretty())?;
    Ok(())
}

/// Load a tensor pool saved by [`save_params`].
pub fn load_params(dir: impl AsRef<Path>) -> Result<HashMap<String, Tensor>> {
    let dir = dir.as_ref();
    let text = std::fs::read_to_string(dir.join("index.json"))
        .with_context(|| format!("reading checkpoint index in {dir:?}"))?;
    let index = Json::parse(&text)?;
    let mut out = HashMap::new();
    for entry in index.as_arr()? {
        let e = entry.as_arr()?;
        let name = e[0].as_str()?.to_string();
        let fname = e[1].as_str()?;
        let shape: Vec<usize> = e[2].as_arr()?.iter().map(|v| v.as_usize().unwrap()).collect();
        let dtype = e[3].as_str()?;
        let mut bytes = Vec::new();
        std::fs::File::open(dir.join(fname))?.read_to_end(&mut bytes)?;
        let numel = shape.iter().product::<usize>().max(1);
        if bytes.len() != numel * 4 {
            bail!("checkpoint {name}: {} bytes, expected {}", bytes.len(), numel * 4);
        }
        let tensor = match dtype {
            "f32" => Tensor::f32(
                shape,
                bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            "i32" => Tensor::i32(
                shape,
                bytes.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            other => bail!("unknown dtype {other}"),
        };
        out.insert(name, tensor);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("ckpt_test_{}", std::process::id()));
        let mut params = HashMap::new();
        params.insert("L0.wq".to_string(), Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        params.insert("perm".to_string(), Tensor::i32(vec![4], vec![3, 1, 0, 2]));
        save_params(&dir, &params).unwrap();
        let loaded = load_params(&dir).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded["L0.wq"], params["L0.wq"]);
        assert_eq!(loaded["perm"], params["perm"]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn load_missing_dir_errors() {
        assert!(load_params("/nonexistent/nowhere").is_err());
    }
}
