//! L3 coordinator micro-benchmarks: the pure-rust hot paths that must
//! never bottleneck serving — batcher decisions, adapter store switches,
//! tokenizer, batch construction, JSON parse of meta.json.

// s2ft-analyze: allow(bench-baseline) reason="diagnostic micro-benchmarks; no committed baseline yet — promote to the regression gate once medians stabilize"
use std::collections::HashMap;
use std::time::Duration;

use repro::adapter::{AdapterSlot, AdapterStore, AnyAdapter, S2ftAdapter, S2ftLayerDelta};
use repro::data::{supervised_batch, Example, Tokenizer};
use repro::runtime::Tensor;
use repro::serve::AdapterBatcher;
use repro::util::bench::{black_box, BenchSuite};
use repro::util::json::Json;
use repro::util::rng::Rng;

fn main() {
    let mut suite = BenchSuite::new("coordinator");

    // batcher decision latency at queue depth 256 over 32 adapters
    suite.bench("batcher/decide_depth256", || {
        let mut b: AdapterBatcher<u32> = AdapterBatcher::new(8, Duration::from_millis(5));
        for i in 0..256u32 {
            b.push(format!("a{}", i % 32), i);
        }
        while b.next_batch().is_some() {}
        black_box(b.len());
    });

    // adapter switch through the store (small-model-like geometry)
    let d = 256usize;
    let n_layers = 4usize;
    let mut rng = Rng::seed(1);
    let mk_adapter = |rng: &mut Rng| {
        let layers = (0..n_layers)
            .map(|_| S2ftLayerDelta {
                wo_rows: rng.choose(d, 32),
                wo_delta: (0..32 * d).map(|_| rng.normal_f32()).collect(),
                wd_rows: rng.choose(704, 22),
                wd_delta: (0..22 * d).map(|_| rng.normal_f32()).collect(),
            })
            .collect();
        AnyAdapter::S2ft(S2ftAdapter { layers, d_model: d })
    };
    let store = AdapterStore::new();
    for i in 0..16 {
        store.insert(format!("a{i}"), mk_adapter(&mut rng));
    }
    let mut params: HashMap<String, Tensor> = HashMap::new();
    for i in 0..n_layers {
        params.insert(format!("L{i}.wo"), Tensor::zeros(vec![d, d]));
        params.insert(format!("L{i}.wd"), Tensor::zeros(vec![704, d]));
    }
    let snapshot = params.clone();
    let mut slot = AdapterSlot::new();
    let mut flip = 0usize;
    suite.bench("store/switch_16_adapters", || {
        flip += 1;
        slot.switch_to(&store, &format!("a{}", flip % 16), &mut params, &snapshot)
            .unwrap();
    });

    // tokenizer + batch building (the submit-side per-request cost)
    let tk = Tokenizer;
    let examples: Vec<Example> = (0..8)
        .map(|i| Example {
            prompt: format!("q: is entity{i} blue and big and living in the cave?"),
            answer: "yes".into(),
        })
        .collect();
    suite.bench("data/supervised_batch_8x64", || {
        black_box(supervised_batch(&tk, &examples, 8, 64));
    });

    // meta.json parse (startup cost)
    if let Ok(text) = std::fs::read_to_string("artifacts/meta.json") {
        suite.bench("json/parse_meta", || {
            black_box(Json::parse(&text).unwrap());
        });
    }

    suite.save();
}
