"""Model / method configurations shared by the AOT pipeline.

Every named config here corresponds to a family of HLO artifacts in
``artifacts/`` and is mirrored in ``meta.json`` so the rust coordinator is
fully self-describing at runtime (no python on the request path).
"""

from dataclasses import dataclass, field, asdict
from typing import Dict, List, Optional


@dataclass(frozen=True)
class ModelConfig:
    """LLaMA-style decoder-only transformer dimensions."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int = 261  # 256 bytes + PAD/BOS/EOS/SEP/UNK
    seq_len: int = 64
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        per_layer = 4 * self.d_model * self.d_model + 3 * self.d_model * self.d_ff
        norms = self.n_layers * 2 * self.d_model + self.d_model
        return self.vocab * self.d_model + self.n_layers * per_layer + norms


@dataclass(frozen=True)
class MethodConfig:
    """Fine-tuning method parameterization.

    ``method`` is one of: fullft, lora, dora, spft, lisa, galore, s2ft.
    For s2ft, ``s2ft_fractions`` maps projection name -> fraction of
    channels/heads trainable (the paper's default budget goes to ``wo`` and
    ``wd``); ``selection`` picks the strategy (r/w/a/s/g) and ``select_small``
    flips largest/smallest ranking (Table 4).
    """

    method: str
    # s2ft
    s2ft_fractions: Dict[str, float] = field(default_factory=dict)
    selection: str = "r"  # r | w | a | s | g
    select_small: bool = True
    use_pallas: bool = False
    # lora / dora / galore
    rank: int = 16
    lora_alpha: float = 32.0
    lora_targets: List[str] = field(default_factory=lambda: ["wo", "wd"])
    # spft
    spft_ratio: float = 0.01
    # optimizer
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0

    def tag(self) -> str:
        """Short unique tag used in artifact filenames."""
        t = self.method
        if self.method == "s2ft":
            if self.selection != "r":
                t += f"-{self.selection}{'S' if self.select_small else 'L'}"
            if self.use_pallas:
                t += "-pallas"
            # non-default projection budget (Fig 4 ablation)
            keys = sorted(self.s2ft_fractions)
            if keys and keys != ["wd", "wo"]:
                t += "-" + "".join(k[1] for k in keys)
        return t


MODELS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig("tiny", d_model=64, n_layers=2, n_heads=4, d_ff=176, seq_len=32),
    "small": ModelConfig("small", d_model=256, n_layers=4, n_heads=8, d_ff=704, seq_len=64),
    "base": ModelConfig("base", d_model=512, n_layers=6, n_heads=8, d_ff=1376, seq_len=128),
}

# Default per-method configs; experiments override via aot.py flags.
def default_methods(model: ModelConfig) -> Dict[str, MethodConfig]:
    # Parameter-matched budgets (paper keeps ~LoRA's trainable count):
    # lora rank 16 on (wo, wd) trains r*(d+d) + r*(k+d) params per layer.
    # s2ft fraction f trains f*d*d (wo rows) + f*k*d (wd rows) per layer.
    d, k = model.d_model, model.d_ff
    r = 16
    lora_params = r * (2 * d) + r * (k + d)
    f = lora_params / (d * d + k * d)
    frac = {"wo": round(f, 4), "wd": round(f, 4)}
    return {
        "fullft": MethodConfig("fullft", lr=2e-4),
        "lora": MethodConfig("lora", rank=r),
        "dora": MethodConfig("dora", rank=r),
        "spft": MethodConfig("spft", spft_ratio=round(f, 4)),
        "lisa": MethodConfig("lisa", lr=2e-4),
        "galore": MethodConfig("galore", rank=r, lr=2e-4),
        "s2ft": MethodConfig("s2ft", s2ft_fractions=frac),
        "s2ft-pallas": MethodConfig("s2ft", s2ft_fractions=frac, use_pallas=True),
    }


def config_dict(model: ModelConfig, methods: Dict[str, MethodConfig]) -> dict:
    return {
        "model": asdict(model),
        "param_count": model.param_count(),
        "methods": {k: asdict(v) for k, v in methods.items()},
    }
