//! Native numerics: the LLaMA-style model semantics interpreted directly
//! on host tensors — seeded init, cached forward, masked cross-entropy,
//! manual backprop with S²FT *partial* weight gradients (paper §3.3: the
//! activation is sliced before the dW GEMM, so frozen rows never get a
//! gradient, let alone an update), AdamW, and the method-layout
//! prepare/merge co-permutations (paper §3.1–3.2).
//!
//! Conventions match `python/compile/model.py` exactly: `y = x @ W` with
//! `W: (d_in, d_out)`; FFN channel `c` is column `c` of wu/wg and row `c`
//! of wd; MHA head `h` is column block `h` of wq/wk/wv and row block `h`
//! of wo; trainable-first co-permutation puts selected units first.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use crate::kernels::{
    causal_attn_bwd, causal_attn_fwd, gemm, gemm_nt, gemm_tn, gemm_tn_outcols, AttnDims,
};
use crate::runtime::meta::{MethodMeta, ModelMeta};
use crate::runtime::Tensor;
use crate::sparsity;
use crate::util::rng::Rng;

use super::builtin::{is_mha, is_row_split, FFN_PROJS, MHA_PROJS};

type Named<'a> = HashMap<&'a str, &'a Tensor>;
type WeightMap<'a> = HashMap<String, &'a [f32]>;

fn get<'a>(named: &Named<'a>, name: &str) -> Result<&'a Tensor> {
    named
        .get(name)
        .copied()
        .ok_or_else(|| anyhow!("native: missing input {name:?}"))
}

fn getf<'a>(named: &Named<'a>, name: &str) -> Result<&'a [f32]> {
    get(named, name)?.as_f32()
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Init
// ---------------------------------------------------------------------------

/// Seeded scaled-gaussian init (GPT-2 style; residual projections wo/wd
/// down-scaled by 1/sqrt(2L); norms start at one). Deterministic per
/// (seed, tensor name).
pub fn init_params(mm: &ModelMeta, seed: i32) -> HashMap<String, Tensor> {
    let resid_scale = 1.0 / ((2 * mm.dims.n_layers) as f32).sqrt();
    let root = Rng::seed(seed as u32 as u64 ^ 0x51F7_0000);
    let mut out = HashMap::new();
    for s in &mm.base_params {
        let n = s.numel();
        let data = if s.name.ends_with("norm1")
            || s.name.ends_with("norm2")
            || s.name.ends_with("norm_f")
        {
            vec![1.0f32; n]
        } else {
            let mut rng = root.fold(fxhash(&s.name));
            let mut std = 0.02f32;
            if s.name.ends_with(".wo") || s.name.ends_with(".wd") {
                std *= resid_scale;
            }
            (0..n).map(|_| rng.normal_f32() * std).collect()
        };
        out.insert(s.name.clone(), Tensor::f32(s.shape.clone(), data));
    }
    out
}

// ---------------------------------------------------------------------------
// Dense kernels — all GEMMs route through `crate::kernels` (cache-blocked,
// multi-threaded, bit-identical across thread counts). The S²FT partial
// gradients use `gemm_tn`/`gemm_tn_outcols`, which slice the trainable
// rows/columns *before* the dW GEMM (paper §3.3).
// ---------------------------------------------------------------------------

fn add_assign(dst: &mut [f32], src: &[f32]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

// ---------------------------------------------------------------------------
// RMSNorm / RoPE / SiLU
// ---------------------------------------------------------------------------

/// y = g ⊙ x · rsqrt(mean(x²)+eps); returns (y, inv_rms per row).
pub(super) fn rms_norm_fwd(x: &[f32], g: &[f32], n: usize, d: usize, eps: f32) -> (Vec<f32>, Vec<f32>) {
    let mut y = vec![0.0f32; n * d];
    let mut inv = vec![0.0f32; n];
    for i in 0..n {
        let xr = &x[i * d..(i + 1) * d];
        let var = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (var + eps).sqrt();
        inv[i] = r;
        let yr = &mut y[i * d..(i + 1) * d];
        for j in 0..d {
            yr[j] = g[j] * xr[j] * r;
        }
    }
    (y, inv)
}

/// dx for rms_norm; accumulates dg into `dg` when provided (full FT).
fn rms_norm_bwd(
    x: &[f32],
    g: &[f32],
    inv: &[f32],
    dy: &[f32],
    n: usize,
    d: usize,
    mut dg: Option<&mut [f32]>,
) -> Vec<f32> {
    let mut dx = vec![0.0f32; n * d];
    for i in 0..n {
        let xr = &x[i * d..(i + 1) * d];
        let dyr = &dy[i * d..(i + 1) * d];
        let r = inv[i];
        let mut dot = 0.0f32;
        for j in 0..d {
            dot += dyr[j] * g[j] * xr[j];
        }
        let coef = r * r * r * dot / d as f32;
        let dxr = &mut dx[i * d..(i + 1) * d];
        for j in 0..d {
            dxr[j] = g[j] * dyr[j] * r - xr[j] * coef;
        }
        if let Some(dg) = dg.as_deref_mut() {
            for j in 0..d {
                dg[j] += dyr[j] * xr[j] * r;
            }
        }
    }
    dx
}

/// cos/sin tables, each (t, hd/2): angle = pos · theta^(−2j/hd).
pub(super) fn rope_tables(t: usize, hd: usize, theta: f64) -> (Vec<f32>, Vec<f32>) {
    let half = hd / 2;
    let mut cos = vec![0.0f32; t * half];
    let mut sin = vec![0.0f32; t * half];
    for pos in 0..t {
        for j in 0..half {
            let freq = theta.powf(-((2 * j) as f64) / hd as f64);
            let ang = pos as f64 * freq;
            cos[pos * half + j] = ang.cos() as f32;
            sin[pos * half + j] = ang.sin() as f32;
        }
    }
    (cos, sin)
}

/// Rotate (even, odd) pairs per head in place; `inverse` applies the
/// transpose rotation (the exact backward of RoPE).
#[allow(clippy::too_many_arguments)]
fn apply_rope(
    x: &mut [f32],
    b: usize,
    t: usize,
    heads: usize,
    hd: usize,
    cos: &[f32],
    sin: &[f32],
    inverse: bool,
) {
    let half = hd / 2;
    let d = heads * hd;
    for bi in 0..b {
        for tt in 0..t {
            let base = (bi * t + tt) * d;
            for hh in 0..heads {
                let off = base + hh * hd;
                for j in 0..half {
                    let c = cos[tt * half + j];
                    let s = if inverse {
                        -sin[tt * half + j]
                    } else {
                        sin[tt * half + j]
                    };
                    let x1 = x[off + 2 * j];
                    let x2 = x[off + 2 * j + 1];
                    x[off + 2 * j] = x1 * c - x2 * s;
                    x[off + 2 * j + 1] = x1 * s + x2 * c;
                }
            }
        }
    }
}

pub(super) fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

// ---------------------------------------------------------------------------
// Forward (cached)
// ---------------------------------------------------------------------------

struct LayerCache {
    h_in: Vec<f32>,
    inv1: Vec<f32>,
    x1: Vec<f32>,
    qr: Vec<f32>,
    kr: Vec<f32>,
    v: Vec<f32>,
    probs: Vec<f32>, // (b, heads, t, t)
    attn: Vec<f32>,  // concatenated head outputs (N, d), pre-wo
    h_mid: Vec<f32>,
    inv2: Vec<f32>,
    x2: Vec<f32>,
    u: Vec<f32>,
    g: Vec<f32>,
    act: Vec<f32>,
}

struct Cache {
    layers: Vec<LayerCache>,
    h_final: Vec<f32>,
    invf: Vec<f32>,
    xf: Vec<f32>,
    logits: Vec<f32>,
}

fn weight<'a>(w: &WeightMap<'a>, name: &str) -> Result<&'a [f32]> {
    w.get(name)
        .copied()
        .ok_or_else(|| anyhow!("native: missing weight {name:?}"))
}

/// Full cached forward pass in (possibly permuted) base layout.
fn forward(mm: &ModelMeta, w: &WeightMap, tokens: &[i32], b: usize, t: usize) -> Result<Cache> {
    let d = mm.dims.d_model;
    let heads = mm.dims.n_heads;
    let hd = d / heads;
    let ff = mm.dims.d_ff;
    let vocab = mm.dims.vocab;
    let eps = mm.dims.norm_eps as f32;
    let n = b * t;
    if tokens.len() != n {
        bail!("native: tokens length {} != {b}x{t}", tokens.len());
    }

    let embed = weight(w, "embed")?;
    let mut h = vec![0.0f32; n * d];
    for (i, &tok) in tokens.iter().enumerate() {
        let tok = tok as usize;
        if tok >= vocab {
            bail!("native: token id {tok} out of vocab {vocab}");
        }
        h[i * d..(i + 1) * d].copy_from_slice(&embed[tok * d..(tok + 1) * d]);
    }
    let (cos, sin) = rope_tables(t, hd, mm.dims.rope_theta);
    let scale = 1.0 / (hd as f32).sqrt();

    let mut layers = Vec::with_capacity(mm.dims.n_layers);
    for i in 0..mm.dims.n_layers {
        let h_in = h;
        let (x1, inv1) =
            rms_norm_fwd(&h_in, weight(w, &format!("L{i}.norm1"))?, n, d, eps);
        let mut qr = gemm(&x1, weight(w, &format!("L{i}.wq"))?, n, d, d);
        let mut kr = gemm(&x1, weight(w, &format!("L{i}.wk"))?, n, d, d);
        let v = gemm(&x1, weight(w, &format!("L{i}.wv"))?, n, d, d);
        apply_rope(&mut qr, b, t, heads, hd, &cos, &sin, false);
        apply_rope(&mut kr, b, t, heads, hd, &cos, &sin, false);

        let (probs, attn) = causal_attn_fwd(&qr, &kr, &v, &AttnDims { b, t, heads, hd }, scale);

        let mut h_mid = h_in.clone();
        add_assign(&mut h_mid, &gemm(&attn, weight(w, &format!("L{i}.wo"))?, n, d, d));
        let (x2, inv2) =
            rms_norm_fwd(&h_mid, weight(w, &format!("L{i}.norm2"))?, n, d, eps);
        let u = gemm(&x2, weight(w, &format!("L{i}.wu"))?, n, d, ff);
        let g = gemm(&x2, weight(w, &format!("L{i}.wg"))?, n, d, ff);
        let mut act = vec![0.0f32; n * ff];
        for j in 0..n * ff {
            act[j] = u[j] * g[j] * sigmoid(g[j]);
        }
        let mut h_out = h_mid.clone();
        add_assign(&mut h_out, &gemm(&act, weight(w, &format!("L{i}.wd"))?, n, ff, d));

        layers.push(LayerCache {
            h_in,
            inv1,
            x1,
            qr,
            kr,
            v,
            probs,
            attn,
            h_mid,
            inv2,
            x2,
            u,
            g,
            act,
        });
        h = h_out;
    }

    let (xf, invf) = rms_norm_fwd(&h, weight(w, "norm_f")?, n, d, eps);
    let logits = gemm_nt(&xf, embed, n, d, vocab);
    Ok(Cache { layers, h_final: h, invf, xf, logits })
}

/// Masked mean cross-entropy + (optionally) dlogits, + masked ncorrect.
fn loss_ncorrect_grad(
    logits: &[f32],
    targets: &[i32],
    mask: &[f32],
    n: usize,
    vocab: usize,
    want_grad: bool,
) -> (f32, f32, Option<Vec<f32>>) {
    let msum: f32 = mask.iter().sum();
    let m = msum.max(1.0);
    let mut loss = 0.0f64;
    let mut ncorrect = 0.0f32;
    let mut dlogits = if want_grad {
        Some(vec![0.0f32; n * vocab])
    } else {
        None
    };
    for i in 0..n {
        let row = &logits[i * vocab..(i + 1) * vocab];
        let tgt = targets[i] as usize;
        let mut maxv = f32::NEG_INFINITY;
        let mut arg = 0usize;
        for (j, &x) in row.iter().enumerate() {
            if x > maxv {
                maxv = x;
                arg = j;
            }
        }
        if arg == tgt {
            ncorrect += mask[i];
        }
        if mask[i] == 0.0 && dlogits.is_none() {
            continue;
        }
        let lse: f32 = maxv + row.iter().map(|&x| (x - maxv).exp()).sum::<f32>().ln();
        if mask[i] > 0.0 {
            loss += (mask[i] * (lse - row[tgt])) as f64;
        }
        if let Some(dl) = dlogits.as_deref_mut() {
            if mask[i] > 0.0 {
                let coef = mask[i] / m;
                let drow = &mut dl[i * vocab..(i + 1) * vocab];
                for (j, &x) in row.iter().enumerate() {
                    drow[j] = coef * (x - lse).exp();
                }
                drow[tgt] -= coef;
            }
        }
    }
    ((loss / m as f64) as f32, ncorrect, dlogits)
}

// ---------------------------------------------------------------------------
// Public entry points: fwd / eval
// ---------------------------------------------------------------------------

fn base_weight_map<'a>(mm: &ModelMeta, named: &Named<'a>) -> Result<WeightMap<'a>> {
    let mut w = WeightMap::new();
    for s in &mm.base_params {
        w.insert(s.name.clone(), getf(named, &s.name)?);
    }
    Ok(w)
}

pub fn forward_logits(
    mm: &ModelMeta,
    named: &Named,
    tokens: &Tensor,
    b: usize,
    t: usize,
) -> Result<Tensor> {
    let w = base_weight_map(mm, named)?;
    let cache = forward(mm, &w, tokens.as_i32()?, b, t)?;
    Ok(Tensor::f32(vec![b, t, mm.dims.vocab], cache.logits))
}

pub fn eval_batch(mm: &ModelMeta, named: &Named, b: usize, t: usize) -> Result<(f32, f32)> {
    let w = base_weight_map(mm, named)?;
    let tokens = get(named, "tokens")?.as_i32()?;
    let targets = get(named, "targets")?.as_i32()?;
    let mask = getf(named, "loss_mask")?;
    let cache = forward(mm, &w, tokens, b, t)?;
    let (loss, ncorrect, _) =
        loss_ncorrect_grad(&cache.logits, targets, mask, b * t, mm.dims.vocab, false);
    Ok((loss, ncorrect))
}

// ---------------------------------------------------------------------------
// Gradient plan + backward
// ---------------------------------------------------------------------------

/// Which weight gradients to materialize.
struct GradPlan {
    /// full fine-tuning: every base tensor (incl. embed + norms)
    full: bool,
    /// s2ft: per layer, projection short-name -> trainable elements
    /// (rows for wo/wd, columns for the rest); absent = frozen.
    sel: Vec<HashMap<String, usize>>,
}

impl GradPlan {
    fn from_method(mm: &ModelMeta, meth: &MethodMeta) -> GradPlan {
        if meth.method == "fullft" {
            return GradPlan { full: true, sel: vec![] };
        }
        let mut sel = vec![HashMap::new(); mm.dims.n_layers];
        for s in &meth.trainable {
            // names look like "L{i}.{proj}_t"
            if let Some(rest) = s.name.strip_prefix('L') {
                if let Some((idx, tail)) = rest.split_once('.') {
                    if let (Ok(i), Some(proj)) =
                        (idx.parse::<usize>(), tail.strip_suffix("_t"))
                    {
                        let units = if is_row_split(proj) { s.shape[0] } else { s.shape[1] };
                        sel[i].insert(proj.to_string(), units);
                    }
                }
            }
        }
        GradPlan { full: false, sel }
    }

    fn units(&self, layer: usize, proj: &str) -> usize {
        if self.full {
            usize::MAX
        } else {
            self.sel.get(layer).and_then(|m| m.get(proj)).copied().unwrap_or(0)
        }
    }
}

/// Backward pass. Returns gradients keyed by *trainable tensor name*:
/// base names under full FT, `L{i}.{p}_t` slices under S²FT.
#[allow(clippy::too_many_arguments)]
fn backward(
    mm: &ModelMeta,
    w: &WeightMap,
    cache: &Cache,
    dlogits: &[f32],
    tokens: &[i32],
    plan: &GradPlan,
    b: usize,
    t: usize,
) -> Result<HashMap<String, Vec<f32>>> {
    let d = mm.dims.d_model;
    let heads = mm.dims.n_heads;
    let hd = d / heads;
    let ff = mm.dims.d_ff;
    let vocab = mm.dims.vocab;
    let n = b * t;
    let scale = 1.0 / (hd as f32).sqrt();
    let (cos, sin) = rope_tables(t, hd, mm.dims.rope_theta);
    let embed = weight(w, "embed")?;

    let mut grads: HashMap<String, Vec<f32>> = HashMap::new();

    // logits = xf @ embedᵀ (tied embedding)
    let dxf = gemm(dlogits, embed, n, vocab, d);
    if plan.full {
        grads.insert("embed".to_string(), gemm_tn(dlogits, &cache.xf, n, vocab, d, vocab));
    }
    let mut dgf = plan.full.then(|| vec![0.0f32; d]);
    let mut dh = rms_norm_bwd(
        &cache.h_final,
        weight(w, "norm_f")?,
        &cache.invf,
        &dxf,
        n,
        d,
        dgf.as_deref_mut(),
    );
    if let Some(dgf) = dgf {
        grads.insert("norm_f".to_string(), dgf);
    }

    for i in (0..mm.dims.n_layers).rev() {
        let lc = &cache.layers[i];

        // ---- FFN: h_out = h_mid + act @ wd -------------------------------
        let dffn = &dh; // gradient wrt (act @ wd)
        let wd_units = plan.units(i, "wd");
        if plan.full {
            grads.insert(format!("L{i}.wd"), gemm_tn(&lc.act, dffn, n, ff, d, ff));
        } else if wd_units > 0 {
            // partial backprop: slice activation channels BEFORE the GEMM
            grads.insert(
                format!("L{i}.wd_t"),
                gemm_tn(&lc.act, dffn, n, ff, d, wd_units),
            );
        }
        let dact = gemm_nt(dffn, weight(w, &format!("L{i}.wd"))?, n, d, ff);
        let mut du = vec![0.0f32; n * ff];
        let mut dgpre = vec![0.0f32; n * ff];
        for j in 0..n * ff {
            let sg = sigmoid(lc.g[j]);
            let sil = lc.g[j] * sg;
            du[j] = dact[j] * sil;
            dgpre[j] = dact[j] * lc.u[j] * sg * (1.0 + lc.g[j] * (1.0 - sg));
        }
        for (proj, dproj) in [("wu", &du), ("wg", &dgpre)] {
            let units = plan.units(i, proj);
            if plan.full {
                grads.insert(format!("L{i}.{proj}"), gemm_tn(&lc.x2, dproj, n, d, ff, d));
            } else if units > 0 {
                grads.insert(
                    format!("L{i}.{proj}_t"),
                    gemm_tn_outcols(&lc.x2, dproj, n, d, ff, units),
                );
            }
        }
        let mut dx2 = gemm_nt(&du, weight(w, &format!("L{i}.wu"))?, n, ff, d);
        add_assign(&mut dx2, &gemm_nt(&dgpre, weight(w, &format!("L{i}.wg"))?, n, ff, d));
        let mut dn2 = plan.full.then(|| vec![0.0f32; d]);
        let dh_mid_norm = rms_norm_bwd(
            &lc.h_mid,
            weight(w, &format!("L{i}.norm2"))?,
            &lc.inv2,
            &dx2,
            n,
            d,
            dn2.as_deref_mut(),
        );
        if let Some(dn2) = dn2 {
            grads.insert(format!("L{i}.norm2"), dn2);
        }
        let mut dh_mid = dh; // residual path
        add_assign(&mut dh_mid, &dh_mid_norm);

        // ---- Attention: h_mid = h_in + attn @ wo -------------------------
        let wo_units = plan.units(i, "wo");
        if plan.full {
            grads.insert(format!("L{i}.wo"), gemm_tn(&lc.attn, &dh_mid, n, d, d, d));
        } else if wo_units > 0 {
            grads.insert(
                format!("L{i}.wo_t"),
                gemm_tn(&lc.attn, &dh_mid, n, d, d, wo_units),
            );
        }
        let da = gemm_nt(&dh_mid, weight(w, &format!("L{i}.wo"))?, n, d, d);

        let (mut dqr, mut dkr, dv) = causal_attn_bwd(
            &lc.probs,
            &lc.qr,
            &lc.kr,
            &lc.v,
            &da,
            &AttnDims { b, t, heads, hd },
            scale,
        );
        apply_rope(&mut dqr, b, t, heads, hd, &cos, &sin, true);
        apply_rope(&mut dkr, b, t, heads, hd, &cos, &sin, true);

        for (proj, dproj) in [("wq", &dqr), ("wk", &dkr), ("wv", &dv)] {
            let units = plan.units(i, proj);
            if plan.full {
                grads.insert(format!("L{i}.{proj}"), gemm_tn(&lc.x1, dproj, n, d, d, d));
            } else if units > 0 {
                grads.insert(
                    format!("L{i}.{proj}_t"),
                    gemm_tn_outcols(&lc.x1, dproj, n, d, d, units),
                );
            }
        }
        let mut dx1 = gemm_nt(&dqr, weight(w, &format!("L{i}.wq"))?, n, d, d);
        add_assign(&mut dx1, &gemm_nt(&dkr, weight(w, &format!("L{i}.wk"))?, n, d, d));
        add_assign(&mut dx1, &gemm_nt(&dv, weight(w, &format!("L{i}.wv"))?, n, d, d));
        let mut dn1 = plan.full.then(|| vec![0.0f32; d]);
        let dh_in_norm = rms_norm_bwd(
            &lc.h_in,
            weight(w, &format!("L{i}.norm1"))?,
            &lc.inv1,
            &dx1,
            n,
            d,
            dn1.as_deref_mut(),
        );
        if let Some(dn1) = dn1 {
            grads.insert(format!("L{i}.norm1"), dn1);
        }
        dh = dh_mid;
        add_assign(&mut dh, &dh_in_norm);
    }

    if plan.full {
        // input-embedding gradient (tied with the output projection above)
        let de = grads.get_mut("embed").expect("embed grad allocated");
        for (idx, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            add_assign(&mut de[tok * d..(tok + 1) * d], &dh[idx * d..(idx + 1) * d]);
        }
    }
    Ok(grads)
}

// ---------------------------------------------------------------------------
// Train step
// ---------------------------------------------------------------------------

/// Build the effective (possibly permuted) base-layout weight map for a
/// method pool: full-FT reads trainable directly; S²FT concatenates the
/// `_t`/`_f` splits. Returns the owned concat storage + name resolution.
#[allow(clippy::type_complexity)]
fn effective_weights<'a>(
    mm: &ModelMeta,
    named: &Named<'a>,
) -> Result<(HashMap<String, Vec<f32>>, Vec<(String, Option<&'a [f32]>)>)> {
    let mut store: HashMap<String, Vec<f32>> = HashMap::new();
    let mut direct: Vec<(String, Option<&[f32]>)> = Vec::new();
    for s in &mm.base_params {
        let name = &s.name;
        let t_name = format!("{name}_t");
        let f_name = format!("{name}_f");
        if named.contains_key(t_name.as_str()) {
            let tt = get(named, &t_name)?;
            let ft = get(named, &f_name)?;
            let proj = name.rsplit('.').next().unwrap_or("");
            let buf = if is_row_split(proj) {
                let mut buf = Vec::with_capacity(s.numel());
                buf.extend_from_slice(tt.as_f32()?);
                buf.extend_from_slice(ft.as_f32()?);
                buf
            } else {
                // column concat: row r = t[r] ++ f[r]
                let (ct, cf) = (tt.shape[1], ft.shape[1]);
                let rows = tt.shape[0];
                let (tv, fv) = (tt.as_f32()?, ft.as_f32()?);
                let mut buf = Vec::with_capacity(rows * (ct + cf));
                for r in 0..rows {
                    buf.extend_from_slice(&tv[r * ct..(r + 1) * ct]);
                    buf.extend_from_slice(&fv[r * cf..(r + 1) * cf]);
                }
                buf
            };
            store.insert(name.clone(), buf);
            direct.push((name.clone(), None));
        } else {
            // base-named tensor lives in either trainable (fullft) or
            // frozen (s2ft untouched) — both arrive in `named`.
            direct.push((name.clone(), Some(getf(named, name)?)));
        }
    }
    Ok((store, direct))
}

/// One AdamW step in method layout. Outputs `new.*`, `new_m.*`, `new_v.*`
/// and `loss`, exactly like the AOT train artifacts.
pub fn train_step(
    mm: &ModelMeta,
    meth: &MethodMeta,
    named: &Named,
    b: usize,
    t: usize,
) -> Result<HashMap<String, Tensor>> {
    let (store, direct) = effective_weights(mm, named)?;
    let mut w: WeightMap = WeightMap::new();
    for (name, slice) in &direct {
        match slice {
            Some(s) => w.insert(name.clone(), *s),
            None => w.insert(name.clone(), store[name].as_slice()),
        };
    }

    let tokens = get(named, "tokens")?.as_i32()?;
    let targets = get(named, "targets")?.as_i32()?;
    let mask = getf(named, "loss_mask")?;
    let step = getf(named, "step")?[0];

    let cache = forward(mm, &w, tokens, b, t)?;
    let (loss, _, dlogits) =
        loss_ncorrect_grad(&cache.logits, targets, mask, b * t, mm.dims.vocab, true);
    let dlogits = dlogits.expect("gradient requested");
    let plan = GradPlan::from_method(mm, meth);
    let grads = backward(mm, &w, &cache, &dlogits, tokens, &plan, b, t)?;

    // AdamW (python `_adam` + decoupled weight decay), t = step + 1.
    let tt = (step + 1.0) as f64;
    let (b1, b2) = (meth.beta1 as f32, meth.beta2 as f32);
    let bc1 = (1.0 - meth.beta1.powf(tt)) as f32;
    let bc2 = (1.0 - meth.beta2.powf(tt)) as f32;
    let (lr, eps, wd) = (meth.lr as f32, meth.eps as f32, meth.weight_decay as f32);

    let mut out = HashMap::new();
    for s in &meth.trainable {
        let name = &s.name;
        let g = grads
            .get(name.as_str())
            .ok_or_else(|| anyhow!("native: no gradient computed for {name:?}"))?;
        let mut p = get(named, name)?.as_f32()?.to_vec();
        let mut om = getf(named, &format!("m.{name}"))?.to_vec();
        let mut ov = getf(named, &format!("v.{name}"))?.to_vec();
        for j in 0..p.len() {
            om[j] = b1 * om[j] + (1.0 - b1) * g[j];
            ov[j] = b2 * ov[j] + (1.0 - b2) * g[j] * g[j];
            let mh = om[j] / bc1;
            let vh = ov[j] / bc2;
            p[j] -= lr * (mh / (vh.sqrt() + eps) + wd * p[j]);
        }
        out.insert(format!("new.{name}"), Tensor::f32(s.shape.clone(), p));
        out.insert(format!("new_m.{name}"), Tensor::f32(s.shape.clone(), om));
        out.insert(format!("new_v.{name}"), Tensor::f32(s.shape.clone(), ov));
    }
    out.insert("loss".to_string(), Tensor::scalar_f32(loss));
    Ok(out)
}

// ---------------------------------------------------------------------------
// Prepare: base layout -> method layout (trainable-first co-permutation)
// ---------------------------------------------------------------------------

fn permute_rows(w: &[f32], cols: usize, perm: &[usize]) -> Vec<f32> {
    let mut out = Vec::with_capacity(perm.len() * cols);
    for &r in perm {
        out.extend_from_slice(&w[r * cols..(r + 1) * cols]);
    }
    out
}

fn permute_cols(w: &[f32], rows: usize, cols: usize, perm: &[usize]) -> Vec<f32> {
    let mut out = Vec::with_capacity(rows * perm.len());
    for r in 0..rows {
        let row = &w[r * cols..(r + 1) * cols];
        for &c in perm {
            out.push(row[c]);
        }
    }
    out
}

/// Unit selection for one coupled structure (strategies R and W).
fn select_units(
    meth: &MethodMeta,
    total: usize,
    count: usize,
    scores: impl Fn() -> Vec<f32>,
    rng: &mut Rng,
) -> Result<Vec<usize>> {
    if count >= total {
        return Ok((0..total).collect());
    }
    match meth.selection.as_str() {
        "r" => Ok(rng.choose(total, count)),
        "w" => {
            let sc = scores();
            let mut idx: Vec<usize> = (0..total).collect();
            idx.sort_by(|&a, &b| sc[a].partial_cmp(&sc[b]).unwrap_or(std::cmp::Ordering::Equal));
            if !meth.select_small {
                idx.reverse();
            }
            let mut sel = idx[..count].to_vec();
            sel.sort_unstable();
            Ok(sel)
        }
        other => bail!("native: unsupported selection strategy {other:?}"),
    }
}

/// Split base params into (trainable, frozen, perms) — the S²FT
/// trainable-first co-permutation, or a passthrough for full FT.
pub fn prepare(
    mm: &ModelMeta,
    meth: &MethodMeta,
    named: &Named,
) -> Result<HashMap<String, Tensor>> {
    if meth.method == "fullft" {
        let mut out = HashMap::new();
        for s in &mm.base_params {
            out.insert(s.name.clone(), get(named, &s.name)?.clone());
        }
        return Ok(out);
    }

    let d = mm.dims.d_model;
    let hd = mm.head_dim();
    let ff = mm.dims.d_ff;
    let seed = get(named, "seed")?.as_i32()?[0] as u32 as u64;
    let counts = crate::adapter::s2ft_counts(mm, meth);
    let mha_count = MHA_PROJS.iter().find_map(|p| counts.get(*p)).copied().unwrap_or(0);
    let ffn_count = FFN_PROJS.iter().find_map(|p| counts.get(*p)).copied().unwrap_or(0);

    let mut staged: HashMap<String, Tensor> = HashMap::new();
    for s in &mm.base_params {
        staged.insert(s.name.clone(), get(named, &s.name)?.clone());
    }
    let root = Rng::seed(seed ^ 0x52F7_1111);
    for i in 0..mm.dims.n_layers {
        if mha_count > 0 {
            let wo = getf(named, &format!("L{i}.wo"))?;
            let sel = select_units(
                meth,
                mm.dims.n_heads,
                mha_count,
                || {
                    (0..mm.dims.n_heads)
                        .map(|h| {
                            wo[h * hd * d..(h + 1) * hd * d]
                                .iter()
                                .map(|v| v * v)
                                .sum::<f32>()
                                .sqrt()
                        })
                        .collect()
                },
                &mut root.fold(2 * i as u64),
            )?;
            let hperm = sparsity::trainable_first_permutation(&sel, mm.dims.n_heads)?;
            let eperm = sparsity::expand_head_perm(&hperm, hd);
            for p in ["wq", "wk", "wv"] {
                let wsrc = getf(named, &format!("L{i}.{p}"))?;
                staged.insert(
                    format!("L{i}.{p}"),
                    Tensor::f32(vec![d, d], permute_cols(wsrc, d, d, &eperm)),
                );
            }
            staged.insert(
                format!("L{i}.wo"),
                Tensor::f32(vec![d, d], permute_rows(wo, d, &eperm)),
            );
            staged.insert(
                format!("L{i}.head_perm"),
                Tensor::i32(
                    vec![mm.dims.n_heads],
                    hperm.iter().map(|&x| x as i32).collect(),
                ),
            );
        }
        if ffn_count > 0 {
            let wu = getf(named, &format!("L{i}.wu"))?;
            let wg = getf(named, &format!("L{i}.wg"))?;
            let wd = getf(named, &format!("L{i}.wd"))?;
            let sel = select_units(
                meth,
                ff,
                ffn_count,
                || {
                    (0..ff)
                        .map(|c| {
                            let col = |w: &[f32]| {
                                (0..d).map(|r| w[r * ff + c] * w[r * ff + c]).sum::<f32>().sqrt()
                            };
                            let wd_row = wd[c * d..(c + 1) * d]
                                .iter()
                                .map(|v| v * v)
                                .sum::<f32>()
                                .sqrt();
                            col(wu) + col(wg) + wd_row
                        })
                        .collect()
                },
                &mut root.fold(2 * i as u64 + 1),
            )?;
            let cperm = sparsity::trainable_first_permutation(&sel, ff)?;
            staged.insert(
                format!("L{i}.wu"),
                Tensor::f32(vec![d, ff], permute_cols(wu, d, ff, &cperm)),
            );
            staged.insert(
                format!("L{i}.wg"),
                Tensor::f32(vec![d, ff], permute_cols(wg, d, ff, &cperm)),
            );
            staged.insert(
                format!("L{i}.wd"),
                Tensor::f32(vec![ff, d], permute_rows(wd, d, &cperm)),
            );
            staged.insert(
                format!("L{i}.chan_perm"),
                Tensor::i32(vec![ff], cperm.iter().map(|&x| x as i32).collect()),
            );
        }
        // split the budgeted projections into (_t, _f)
        for (p, &c) in &counts {
            let name = format!("L{i}.{p}");
            let w = staged
                .remove(&name)
                .ok_or_else(|| anyhow!("native: missing staged {name:?}"))?;
            let rows = if is_mha(p) { c * hd } else { c };
            let (din, dout) = (w.shape[0], w.shape[1]);
            let wv = w.as_f32()?;
            if is_row_split(p) {
                staged.insert(
                    format!("{name}_t"),
                    Tensor::f32(vec![rows, dout], wv[..rows * dout].to_vec()),
                );
                staged.insert(
                    format!("{name}_f"),
                    Tensor::f32(vec![din - rows, dout], wv[rows * dout..].to_vec()),
                );
            } else {
                let all: Vec<usize> = (0..dout).collect();
                staged.insert(
                    format!("{name}_t"),
                    Tensor::f32(vec![din, rows], permute_cols(wv, din, dout, &all[..rows])),
                );
                staged.insert(
                    format!("{name}_f"),
                    Tensor::f32(vec![din, dout - rows], permute_cols(wv, din, dout, &all[rows..])),
                );
            }
        }
    }
    Ok(staged)
}

// ---------------------------------------------------------------------------
// Merge: method layout -> base layout
// ---------------------------------------------------------------------------

/// Invert the co-permutation and re-assemble base-layout weights. Pure
/// index gathers — frozen rows come back bit-identical.
pub fn merge(mm: &ModelMeta, meth: &MethodMeta, named: &Named) -> Result<HashMap<String, Tensor>> {
    let mut out = HashMap::new();
    if meth.method == "fullft" {
        for s in &mm.base_params {
            out.insert(s.name.clone(), get(named, &s.name)?.clone());
        }
        return Ok(out);
    }

    let hd = mm.head_dim();
    for s in &mm.base_params {
        if let Some(t) = named.get(s.name.as_str()) {
            out.insert(s.name.clone(), (*t).clone());
        }
    }
    let unsplit = |name: &str, proj: &str| -> Result<Tensor> {
        let t_name = format!("{name}_t");
        if !named.contains_key(t_name.as_str()) {
            return Ok(get(named, name)?.clone());
        }
        let tt = get(named, &t_name)?;
        let ft = get(named, &format!("{name}_f"))?;
        if is_row_split(proj) {
            let cols = tt.shape[1];
            let mut buf = tt.as_f32()?.to_vec();
            buf.extend_from_slice(ft.as_f32()?);
            Ok(Tensor::f32(vec![tt.shape[0] + ft.shape[0], cols], buf))
        } else {
            let rows = tt.shape[0];
            let (ct, cf) = (tt.shape[1], ft.shape[1]);
            let (tv, fv) = (tt.as_f32()?, ft.as_f32()?);
            let mut buf = Vec::with_capacity(rows * (ct + cf));
            for r in 0..rows {
                buf.extend_from_slice(&tv[r * ct..(r + 1) * ct]);
                buf.extend_from_slice(&fv[r * cf..(r + 1) * cf]);
            }
            Ok(Tensor::f32(vec![rows, ct + cf], buf))
        }
    };
    for i in 0..mm.dims.n_layers {
        if let Some(hp) = named.get(format!("L{i}.head_perm").as_str()) {
            let hperm: Vec<usize> = hp.as_i32()?.iter().map(|&x| x as usize).collect();
            let inv = sparsity::invert_permutation(&sparsity::expand_head_perm(&hperm, hd));
            for p in MHA_PROJS {
                let name = format!("L{i}.{p}");
                let w = unsplit(&name, p)?;
                let (rows, cols) = (w.shape[0], w.shape[1]);
                let data = if is_row_split(p) {
                    permute_rows(w.as_f32()?, cols, &inv)
                } else {
                    permute_cols(w.as_f32()?, rows, cols, &inv)
                };
                out.insert(name, Tensor::f32(vec![rows, cols], data));
            }
        }
        if let Some(cp) = named.get(format!("L{i}.chan_perm").as_str()) {
            let cperm: Vec<usize> = cp.as_i32()?.iter().map(|&x| x as usize).collect();
            let inv = sparsity::invert_permutation(&cperm);
            for p in FFN_PROJS {
                let name = format!("L{i}.{p}");
                let w = unsplit(&name, p)?;
                let (rows, cols) = (w.shape[0], w.shape[1]);
                let data = if is_row_split(p) {
                    permute_rows(w.as_f32()?, cols, &inv)
                } else {
                    permute_cols(w.as_f32()?, rows, cols, &inv)
                };
                out.insert(name, Tensor::f32(vec![rows, cols], data));
            }
        }
    }
    for s in &mm.base_params {
        if !out.contains_key(&s.name) {
            bail!("native merge: could not reassemble {:?}", s.name);
        }
    }
    Ok(out)
}
