//! The public serving API: an N-worker engine pool with streamed replies,
//! continuous per-token batching and a runtime adapter lifecycle.
//!
//! ```text
//!            Engine::submit(GenRequest) ──► ReplyStream (GenEvent::Token…Done)
//!                     │
//!              Mutex<AdapterBatcher> + Condvar   (shared work queue,
//!                     │                           adapter-affinity scheduling)
//!        ┌────────────┼────────────┐
//!     worker 0     worker 1  …  worker N-1      (each: own GenModel weights
//!        │            │            │             + AdapterSlot fused state)
//!        │  ┌─────────┴──────────┐ │
//!        │  │ continuous run:    │ │            per worker, per run:
//!        │  │  admit ▸ step ▸    │ │             row slots over one paged
//!        │  │  readout ▸ retire  │ │             KvPool; streams join/leave
//!        │  └─────────┬──────────┘ │             between decode steps
//!        └────────────┴────────────┘
//!               AdapterRegistry                  (bounded resident set,
//!            (wraps AdapterStore)                 LRU spill + lazy load,
//!                                                 traffic-driven fuse policy)
//! ```
//!
//! Each worker owns a full copy of the (merged, base-layout) weights and
//! an [`AdapterSlot`]; the [`AdapterRegistry`] is shared and mirrors its
//! resident adapters into an [`AdapterStore`]. A worker asks the batcher
//! for work *preferring its currently-fused adapter* (and otherwise
//! favouring groups whose adapter is already resident), so under steady
//! multi-adapter load the pool converges to one adapter per worker and
//! switches only when the mix shifts — the paper §6.2 decoupling in
//! all three modes at once: **fuse** ([`Engine::fuse`] merges adapters
//! into a new servable one), **fast switch** (scatter_add per run via
//! the slot) and **parallel serve** (different adapters live on different
//! workers concurrently).
//!
//! # Adapter residency & fuse policy
//!
//! The registry scales the lifecycle to thousands of registered
//! adapters: at most `max_resident` stay decoded in memory, the rest
//! live on disk under `adapter_dir` (pre-registered lazily at spawn,
//! and the spill target for evicted residents). Before serving a plan
//! the worker *acquires* a pinned lease on the plan's adapter — lazily
//! loading it on a residency miss — and asks the registry's traffic
//! policy how to apply it: hot adapters (EWMA requests/sec ≥ `hot_rps`)
//! are fused into the worker weights via the slot, cold ones are
//! applied unfused at decode time
//! ([`PagedDecodeSession::set_unfused_adapter`]), skipping the
//! fuse/unfuse round trip. The two paths agree numerically (not
//! bitwise) and each is individually deterministic; `hot_rps = 0` (the
//! default) always fuses, preserving the bit-tested fused path.
//!
//! # Continuous batching
//!
//! On backends with a paged decode session (native), a worker run is a
//! per-token loop, not a wave: every tick admits queued requests into
//! free row slots, feeds one token per live stream through a single
//! batched decode step, reads out finished streams and returns their
//! row + KV blocks immediately. A short reply retires mid-run while its
//! long batch-mates keep decoding, and newly arrived requests for the
//! same adapter join without waiting for the batch to drain. K/V cache
//! memory comes from a per-run [`crate::serve::kvpool::KvPool`]; when
//! the pool runs dry the youngest stream is evicted with a terminal
//! [`GenEvent::Error`] ([`crate::serve::ServeMetrics::evictions`]
//! counts these). Backends without a paged session (PJRT artifact
//! replay) keep the wave path: one `generate_stream` call per batch.
//! Per-row logits are independent of co-scheduled rows (the kernels
//! partition strictly by row), so continuous co-scheduling cannot
//! change any stream's tokens — asserted bitwise by the serve tests.

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::adapter::{AdapterSlot, AdapterStore, AnyAdapter, S2ftAdapter};
use crate::data::batch::encode_prompt;
use crate::data::tokenizer::{EOS, PAD};
use crate::data::Tokenizer;
use crate::runtime::{PagedDecodeSession, Tensor};
use crate::train::{DecodeRequest, GenModel, TokenSampler};

use super::batcher::{AdapterBatcher, BatchPlan, Queued, SchedPolicy};
use super::kvpool::{KvPoolConfig, PoolUsage};
use super::metrics::{KvPoolGauge, ServeMetrics};
use super::residency::{AdapterLease, AdapterRegistry, FusePolicy, ResidencyConfig};

/// Reserved adapter id meaning "pristine base weights, nothing fused".
pub const BASE_ADAPTER: &str = "base";

/// Engine construction parameters (builder-style).
///
/// ```
/// use std::time::Duration;
/// use repro::serve::{EngineConfig, SchedPolicy};
///
/// let cfg = EngineConfig::new()
///     .workers(2)
///     .max_batch(16)              // row slots per worker
///     .window(Duration::from_millis(2))
///     .policy(SchedPolicy::AdapterAffinity)
///     .kv_block_tokens(16)        // paged-KV block granularity
///     .kv_blocks(0)               // 0 = auto-size (eviction-free)
///     .max_resident(64)           // resident-adapter budget (0 = unbounded)
///     .adapter_dir("/tmp/adapters")
///     .hot_rps(4.0);              // fuse adapters hotter than 4 req/s
/// assert_eq!(cfg.workers, 2);
/// assert_eq!(cfg.kv_block_tokens, 16);
/// assert_eq!(cfg.max_resident, 64);
/// ```
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads, each with its own weight copy.
    pub workers: usize,
    /// Row slots per worker: the most streams one worker co-decodes.
    pub max_batch: usize,
    /// How long a freshly-arrived request may wait for batch-mates.
    pub window: Duration,
    /// How the batcher picks the next adapter group.
    pub policy: SchedPolicy,
    /// Token positions per paged-KV block (continuous batching only).
    pub kv_block_tokens: usize,
    /// Blocks in each worker's KV pool; `0` auto-sizes so `max_batch`
    /// streams can all reach the model context length (no eviction).
    /// Smaller values cap cache memory and enable backpressure.
    pub kv_blocks: usize,
    /// Resident-adapter budget for the shared registry; `0` keeps every
    /// registered adapter in memory. Over budget, the least-recently-used
    /// unpinned adapter is spilled to `adapter_dir` (or simply dropped
    /// when a clean on-disk copy already exists).
    pub max_resident: usize,
    /// Directory of persisted adapters: every `*.s2ft` file in it is
    /// registered (lazily) at spawn, and evicted residents spill there.
    pub adapter_dir: Option<PathBuf>,
    /// EWMA requests/sec at or above which an adapter is fused into the
    /// worker weights; colder adapters are applied unfused at decode
    /// time. `0` (default) always fuses, `f64::INFINITY` never does.
    pub hot_rps: f64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            workers: 1,
            max_batch: 8,
            window: Duration::from_millis(2),
            policy: SchedPolicy::AdapterAffinity,
            kv_block_tokens: KvPoolConfig::default().block_tokens,
            kv_blocks: 0,
            max_resident: 0,
            adapter_dir: None,
            hot_rps: 0.0,
        }
    }
}

impl EngineConfig {
    /// Defaults: 1 worker, 8 row slots, 2 ms window, adapter affinity,
    /// auto-sized KV pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the worker-thread count (minimum 1).
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Set the per-worker row-slot count (minimum 1).
    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n.max(1);
        self
    }

    /// Set the batching window (how long a request may wait for mates).
    pub fn window(mut self, w: Duration) -> Self {
        self.window = w;
        self
    }

    /// Set the scheduling policy.
    pub fn policy(mut self, p: SchedPolicy) -> Self {
        self.policy = p;
        self
    }

    /// Set the paged-KV block size in token positions (minimum 1).
    pub fn kv_block_tokens(mut self, n: usize) -> Self {
        self.kv_block_tokens = n.max(1);
        self
    }

    /// Set the per-worker KV-pool block count (`0` = auto-size).
    pub fn kv_blocks(mut self, n: usize) -> Self {
        self.kv_blocks = n;
        self
    }

    /// Cap the registry's resident adapters (`0` = unbounded).
    pub fn max_resident(mut self, n: usize) -> Self {
        self.max_resident = n;
        self
    }

    /// Set the adapter preload/spill directory.
    pub fn adapter_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.adapter_dir = Some(dir.into());
        self
    }

    /// Set the fused-application traffic threshold in requests/sec.
    pub fn hot_rps(mut self, rps: f64) -> Self {
        self.hot_rps = rps;
        self
    }
}

/// Per-request sampling parameters (see [`DecodeRequest`]).
#[derive(Debug, Clone)]
pub struct SamplingParams {
    /// Maximum tokens to generate.
    pub max_new: usize,
    /// `<= 0.0` = greedy argmax; otherwise softmax temperature.
    pub temperature: f32,
    /// Restrict sampling to the k highest logits (`0` = whole vocab).
    pub top_k: usize,
    /// Extra stop token (EOS and PAD always stop).
    pub stop: Option<i32>,
    /// Seed for the per-request sampling stream.
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { max_new: 8, temperature: 0.0, top_k: 0, stop: None, seed: 0 }
    }
}

/// One generation request routed to `adapter` (use [`BASE_ADAPTER`] for
/// the un-adapted base model).
///
/// ```
/// use repro::serve::{GenRequest, BASE_ADAPTER};
///
/// let req = GenRequest::new(BASE_ADAPTER, "2+3=")
///     .max_new(4)
///     .temperature(0.8)
///     .top_k(16)
///     .stop(259)   // SEP
///     .seed(7);
/// assert_eq!(req.params.max_new, 4);
/// assert_eq!(req.params.stop, Some(259));
/// ```
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// Adapter id to serve this request with.
    pub adapter: String,
    /// The prompt text.
    pub prompt: String,
    /// Sampling parameters (builder methods below).
    pub params: SamplingParams,
}

impl GenRequest {
    /// A request with default (greedy, 8-token) sampling parameters.
    pub fn new(adapter: impl Into<String>, prompt: impl Into<String>) -> Self {
        Self {
            adapter: adapter.into(),
            prompt: prompt.into(),
            params: SamplingParams::default(),
        }
    }

    /// Cap the generated tokens.
    pub fn max_new(mut self, n: usize) -> Self {
        self.params.max_new = n;
        self
    }

    /// Set the sampling temperature (`<= 0.0` = greedy).
    pub fn temperature(mut self, t: f32) -> Self {
        self.params.temperature = t;
        self
    }

    /// Restrict sampling to the `k` highest logits (`0` = whole vocab).
    pub fn top_k(mut self, k: usize) -> Self {
        self.params.top_k = k;
        self
    }

    /// Add an extra stop token (EOS and PAD always stop).
    pub fn stop(mut self, tok: i32) -> Self {
        self.params.stop = Some(tok);
        self
    }

    /// Seed the per-request sampling stream.
    pub fn seed(mut self, s: u64) -> Self {
        self.params.seed = s;
        self
    }
}

/// Streamed reply events, in order: zero or more `Token`s, then exactly
/// one `Done` or `Error`.
#[derive(Debug, Clone)]
pub enum GenEvent {
    /// One generated token, as it was produced.
    Token {
        /// The token id.
        token: i32,
        /// Its decoded text.
        text: String,
    },
    /// Generation finished; the full reply.
    Done(GenReply),
    /// The request failed (unknown adapter, engine stopped, KV-pool
    /// eviction, ...). Terminal: nothing follows it.
    Error(String),
}

/// The completed reply delivered inside [`GenEvent::Done`].
#[derive(Debug, Clone)]
pub struct GenReply {
    /// Decoded reply text (up to but excluding EOS).
    pub text: String,
    /// Tokens generated for this request.
    pub tokens: usize,
    /// Submit-to-done wall time.
    pub latency: Duration,
    /// Live streams co-decoding when this request finished (wave size on
    /// the legacy path).
    pub batch_size: usize,
    /// Pool worker that served it.
    pub worker: usize,
    /// Adapter it was served with.
    pub adapter: String,
}

/// Receiver half of one request's event stream. Iterate for tokens, or
/// [`ReplyStream::wait`] for just the final reply.
pub struct ReplyStream {
    rx: Receiver<GenEvent>,
}

impl ReplyStream {
    /// Next event, blocking until one arrives.
    ///
    /// Returns `None` once the stream is finished: every stream delivers
    /// *exactly one* terminal event ([`GenEvent::Done`] or
    /// [`GenEvent::Error`] — including on shutdown, worker failure and
    /// KV-pool eviction), after which `recv` returns `None` forever. The
    /// only way to observe `None` without a prior terminal event is a
    /// worker death by panic, which [`Engine::shutdown`] reports.
    ///
    /// ```no_run
    /// use repro::serve::{GenEvent, ReplyStream};
    ///
    /// fn drain(stream: &ReplyStream) {
    ///     while let Some(ev) = stream.recv() {
    ///         match ev {
    ///             GenEvent::Token { text, .. } => print!("{text}"),
    ///             GenEvent::Done(r) => println!(" [{} tokens]", r.tokens),
    ///             GenEvent::Error(e) => eprintln!("failed: {e}"),
    ///         }
    ///     }
    ///     // recv() is now None forever: the terminal event was consumed.
    /// }
    /// ```
    pub fn recv(&self) -> Option<GenEvent> {
        self.rx.recv().ok()
    }

    /// Drain the stream and return the final reply (`Err` if the stream
    /// ended with [`GenEvent::Error`] or was dropped without a terminal
    /// event).
    ///
    /// ```no_run
    /// use repro::serve::{Engine, GenRequest};
    ///
    /// fn call(engine: &Engine) -> anyhow::Result<String> {
    ///     let reply = engine.submit(GenRequest::new("base", "2+3=")).wait()?;
    ///     Ok(reply.text)
    /// }
    /// ```
    pub fn wait(self) -> Result<GenReply> {
        for ev in self {
            match ev {
                GenEvent::Token { .. } => {}
                GenEvent::Done(reply) => return Ok(reply),
                GenEvent::Error(e) => bail!("{e}"),
            }
        }
        bail!("engine dropped the request")
    }
}

impl Iterator for ReplyStream {
    type Item = GenEvent;

    fn next(&mut self) -> Option<GenEvent> {
        self.rx.recv().ok()
    }
}

/// What [`Engine::spawn`]'s builder produces per worker: the worker's
/// own model (merged base-layout weights) plus a pristine snapshot of
/// those weights (used to unfuse adapters exactly).
pub type WorkerParts = (GenModel, HashMap<String, Tensor>);

type WorkerBuilder = dyn Fn(usize) -> Result<WorkerParts> + Send + Sync;

struct Job {
    prompt: String,
    params: SamplingParams,
    events: Sender<GenEvent>,
    t0: Instant,
}

struct QueueState {
    batcher: AdapterBatcher<Job>,
    open: bool,
}

struct Shared {
    cfg: EngineConfig,
    queue: Mutex<QueueState>,
    cv: Condvar,
    registry: AdapterRegistry,
    metrics: Mutex<ServeMetrics>,
    live: AtomicUsize,
}

/// Multi-worker serving engine. See the module docs for the architecture.
pub struct Engine {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<Result<()>>>,
}

impl Engine {
    /// Spawn the pool. `builder(worker_id)` runs *inside* each worker
    /// thread and must construct that worker's model plus a pristine
    /// base-weight snapshot (used to unfuse adapters exactly). Backends
    /// with thread-local state (PJRT) are therefore supported: every
    /// worker builds its own.
    pub fn spawn<F>(cfg: EngineConfig, builder: F) -> Engine
    where
        F: Fn(usize) -> Result<WorkerParts> + Send + Sync + 'static,
    {
        let workers = cfg.workers;
        let max_wait = cfg.window.max(Duration::from_millis(1)) * 4;
        let batcher = AdapterBatcher::new(cfg.max_batch, max_wait).with_policy(cfg.policy);
        let registry = AdapterRegistry::new(ResidencyConfig {
            max_resident: cfg.max_resident,
            spill_dir: cfg.adapter_dir.clone(),
            hot_rps: cfg.hot_rps,
            ..ResidencyConfig::default()
        });
        if let Some(dir) = &cfg.adapter_dir {
            // best-effort preload: a fresh spill dir simply starts empty
            let _ = std::fs::create_dir_all(dir);
            let _ = registry.register_dir(dir);
        }
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState { batcher, open: true }),
            cv: Condvar::new(),
            registry,
            metrics: Mutex::new(ServeMetrics::default()),
            live: AtomicUsize::new(workers),
            cfg,
        });
        let builder = Arc::new(builder);
        let handles = (0..workers)
            .map(|id| {
                let shared = shared.clone();
                let builder = builder.clone();
                std::thread::Builder::new()
                    .name(format!("s2ft-engine-{id}"))
                    .spawn(move || worker_main(id, shared, builder.as_ref()))
                    .expect("spawn engine worker")
            })
            .collect();
        Engine { shared, handles }
    }

    /// Submit a request; token events and the final reply arrive on the
    /// returned stream.
    ///
    /// ```
    /// use repro::runtime::{Executable, Executor, NativeBackend, Tensor};
    /// use repro::serve::{Engine, EngineConfig, GenRequest, BASE_ADAPTER};
    /// use repro::train::GenModel;
    ///
    /// let engine = Engine::spawn(EngineConfig::new().workers(1), |_| {
    ///     let rt = NativeBackend::builtin();
    ///     let init = rt.load("init_tiny")?;
    ///     let outs = init.run(&[Tensor::scalar_i32(1)])?;
    ///     let params: std::collections::HashMap<_, _> =
    ///         init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect();
    ///     let snapshot = params.clone();
    ///     Ok((GenModel::new(&rt, "tiny", params)?, snapshot))
    /// });
    /// let reply = engine.submit(GenRequest::new(BASE_ADAPTER, "2+3=").max_new(4)).wait()?;
    /// assert!(reply.tokens <= 4);
    /// engine.shutdown()?;
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn submit(&self, req: GenRequest) -> ReplyStream {
        let (tx, rx) = channel();
        {
            // the open check shares the queue lock with the last-worker
            // drain, so a request can never be pushed after the drain ran
            // (it would hang forever with no worker left to fail it)
            let mut q = self.shared.queue.lock().unwrap();
            if !q.open {
                let _ = tx.send(GenEvent::Error("engine is shut down".into()));
                return ReplyStream { rx };
            }
            q.batcher.push(
                req.adapter,
                Job { prompt: req.prompt, params: req.params, events: tx, t0: Instant::now() },
            );
        }
        self.shared.cv.notify_all();
        ReplyStream { rx }
    }

    /// Convenience: submit and wait for the final reply.
    pub fn call(&self, req: GenRequest) -> Result<GenReply> {
        self.submit(req).wait()
    }

    // --- runtime adapter lifecycle (paper §6.2) -------------------------

    /// Register (or replace) an adapter while serving. It enters the
    /// registry resident (and may spill a colder adapter past the
    /// residency budget).
    pub fn register(&self, id: impl Into<String>, adapter: AnyAdapter) {
        self.shared.registry.insert_resident(id, adapter);
    }

    /// Unregister an adapter (resident or spilled). In-flight batches
    /// already serving it finish normally (workers hold their own
    /// handle); any on-disk spill file is left alone.
    pub fn unregister(&self, id: &str) -> Result<()> {
        self.shared.registry.remove(id)
    }

    /// Fuse-mode: weighted-combine registered S²FT adapters into a new
    /// adapter registered as `new_id`, servable immediately. Sources are
    /// acquired through the registry, so spilled parts are lazily
    /// reloaded (and pinned) for the combination.
    pub fn fuse(&self, new_id: impl Into<String>, parts: &[(&str, f32)]) -> Result<()> {
        let leases: Vec<(AdapterLease<'_>, f32)> = parts
            .iter()
            .map(|(id, w)| self.shared.registry.acquire(id).map(|l| (l, *w)))
            .collect::<Result<_>>()?;
        let handles: Vec<(Arc<AnyAdapter>, f32)> =
            leases.iter().map(|(l, w)| (l.handle(), *w)).collect();
        let refs: Vec<(&S2ftAdapter, f32)> = handles
            .iter()
            .map(|(a, w)| match a.as_ref() {
                AnyAdapter::S2ft(s) => Ok((s, *w)),
                AnyAdapter::Lora(_) => Err(anyhow!("fuse supports S²FT adapters only")),
            })
            .collect::<Result<_>>()?;
        let fused = S2ftAdapter::fuse(&refs)?;
        self.shared.registry.insert_resident(new_id, AnyAdapter::S2ft(fused));
        Ok(())
    }

    /// The shared adapter store — the registry's resident mirror, kept
    /// for in-memory introspection (`len()`, `total_bytes()`, ...).
    pub fn store(&self) -> &AdapterStore {
        self.shared.registry.store()
    }

    /// The shared adapter residency registry (see
    /// [`crate::serve::AdapterRegistry`]).
    pub fn registry(&self) -> &AdapterRegistry {
        &self.shared.registry
    }

    /// Registered adapter ids (resident or spilled), sorted.
    pub fn adapters(&self) -> Vec<String> {
        self.shared.registry.ids()
    }

    /// Number of worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Snapshot of the engine-wide serving metrics (counters, latency
    /// percentiles, KV-pool gauges, adapter-residency counters).
    pub fn metrics(&self) -> ServeMetrics {
        let mut m = self.shared.metrics.lock().unwrap().clone();
        m.residency = self.shared.registry.stats();
        m
    }

    /// Stop accepting work, drain the queue, join every worker.
    ///
    /// Requests already queued or in flight are still served; anything
    /// the workers cannot drain is failed with a terminal
    /// [`GenEvent::Error`], so no [`ReplyStream`] is left hanging.
    /// Returns the first worker error, if any. Dropping the engine does
    /// the same, discarding the error.
    ///
    /// ```no_run
    /// use repro::serve::{Engine, GenRequest};
    ///
    /// fn serve_one(engine: Engine) -> anyhow::Result<()> {
    ///     let stream = engine.submit(GenRequest::new("base", "2+3="));
    ///     engine.shutdown()?;      // waits for the in-flight request
    ///     let reply = stream.wait()?;
    ///     println!("{}", reply.text);
    ///     Ok(())
    /// }
    /// ```
    pub fn shutdown(mut self) -> Result<()> {
        self.shutdown_inner()
    }

    fn shutdown_inner(&mut self) -> Result<()> {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.open = false;
        }
        self.shared.cv.notify_all();
        let mut first_err = None;
        for h in self.handles.drain(..) {
            match h.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => first_err = first_err.or(Some(anyhow!("engine worker panicked"))),
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.shutdown_inner();
    }
}

fn worker_main(id: usize, shared: Arc<Shared>, builder: &WorkerBuilder) -> Result<()> {
    let res = (|| -> Result<()> {
        let (mut gm, snapshot) = builder(id)?;
        let mut slot = AdapterSlot::new();
        loop {
            let prefer = slot.active().map(String::from);
            let Some(plan) = next_plan(&shared, prefer.as_deref()) else {
                break;
            };
            serve_plan(id, &shared, &mut gm, &mut slot, &snapshot, plan);
        }
        Ok(())
    })();
    if shared.live.fetch_sub(1, Ordering::SeqCst) == 1 {
        // last worker out: nothing will ever drain the queue again
        let mut q = shared.queue.lock().unwrap();
        q.open = false;
        while let Some(plan) = q.batcher.next_batch() {
            for item in plan.items {
                let _ = item.payload.events.send(GenEvent::Error("engine stopped".into()));
            }
        }
    }
    res
}

/// Block until a batch is available (respecting the arrival window) or
/// the engine is closed and drained. `None` = exit. `prefer` is the
/// calling worker's currently-fused adapter (switch-free fast path).
fn next_plan(shared: &Shared, prefer: Option<&str>) -> Option<BatchPlan<Job>> {
    let mut q = shared.queue.lock().unwrap();
    loop {
        if q.batcher.is_empty() {
            if !q.open {
                return None;
            }
            q = shared.cv.wait(q).unwrap();
            continue;
        }
        let age = q.batcher.oldest_age();
        if !q.open || q.batcher.len() >= shared.cfg.max_batch || age >= shared.cfg.window {
            break;
        }
        let (qq, _) = shared.cv.wait_timeout(q, shared.cfg.window - age).unwrap();
        q = qq;
    }
    // among equally-eligible groups, favour adapters that are already
    // resident: serving them costs no lazy load (and likely no spill)
    q.batcher.next_batch_preferring_where(prefer, |a| {
        a == BASE_ADAPTER || shared.registry.is_resident(a)
    })
}

fn fail_all(items: Vec<Queued<Job>>, msg: &str) {
    for item in items {
        let _ = item.payload.events.send(GenEvent::Error(msg.to_string()));
    }
}

/// Serve one scheduled plan: acquire a pinned lease on the adapter
/// (lazily loading it from disk on a residency miss), apply it fused or
/// unfused per the registry's traffic policy, then run either the
/// continuous paged path (native) or the legacy wave path (no paged
/// session available).
fn serve_plan(
    id: usize,
    shared: &Shared,
    gm: &mut GenModel,
    slot: &mut AdapterSlot,
    snapshot: &HashMap<String, Tensor>,
    plan: BatchPlan<Job>,
) {
    let lease = if plan.adapter == BASE_ADAPTER {
        None
    } else {
        match shared.registry.acquire(&plan.adapter) {
            Ok(l) => Some(l),
            // the registry is unchanged and the engine keeps serving —
            // only this batch fails
            Err(e) => return fail_all(plan.items, &format!("adapter switch failed: {e:#}")),
        }
    };
    // cold adapters skip the fuse/unfuse round trip and are applied at
    // decode time instead (continuous path only — the wave fallback
    // below late-fuses when no paged session materialises)
    let unfused = lease.as_ref().is_some_and(|l| {
        gm.has_decoder()
            && matches!(l.handle().as_ref(), AnyAdapter::S2ft(_))
            && shared.registry.fuse_policy(l.id()) == FusePolicy::Unfused
    });

    // adapter-affinity switch (at most one per run; scatter_add for S²FT)
    let switched = match &lease {
        Some(l) if !unfused => timed_switch(shared, slot, &plan.adapter, l, gm, snapshot),
        // base plans and unfused plans both serve from pristine weights
        _ => slot.deactivate(&mut gm.params, snapshot),
    };
    if let Err(e) = switched {
        // transactional switch: previous adapter still fused, the engine
        // keeps serving — only this batch fails
        return fail_all(plan.items, &format!("adapter switch failed: {e:#}"));
    }

    if gm.has_decoder() {
        let kvcfg = KvPoolConfig {
            block_tokens: shared.cfg.kv_block_tokens.max(1),
            blocks: shared.cfg.kv_blocks,
        };
        match gm.open_paged_session(shared.cfg.max_batch, kvcfg) {
            Ok(Some(mut sess)) => {
                if unfused {
                    let handle = lease.as_ref().expect("unfused implies a lease").handle();
                    if let Err(e) = sess.set_unfused_adapter(Some(handle)) {
                        return fail_all(plan.items, &format!("adapter switch failed: {e:#}"));
                    }
                }
                let (reqs, toks) =
                    continuous_run(id, shared, gm, sess.as_mut(), &plan.adapter, plan.items);
                if let Some(l) = &lease {
                    shared.registry.note_batch(l.id(), reqs, toks, unfused);
                }
                return;
            }
            Ok(None) => {
                // decoder without a paged path: the wave fallback serves
                // from the worker weights, so a policy-unfused adapter
                // must be fused after all
                if unfused {
                    let l = lease.as_ref().expect("unfused implies a lease");
                    if let Err(e) = timed_switch(shared, slot, &plan.adapter, l, gm, snapshot) {
                        return fail_all(plan.items, &format!("adapter switch failed: {e:#}"));
                    }
                }
            }
            Err(e) => {
                return fail_all(plan.items, &format!("paged decode unavailable: {e:#}"));
            }
        }
    }
    let (reqs, toks) = serve_wave(id, shared, gm, plan);
    if let Some(l) = &lease {
        shared.registry.note_batch(l.id(), reqs, toks, false);
    }
}

/// Fuse `lease`'s adapter into the worker weights through `slot`,
/// recording switch count and wall time in the metrics when the weights
/// actually changed (repeat activations are free and uncounted).
fn timed_switch(
    shared: &Shared,
    slot: &mut AdapterSlot,
    adapter: &str,
    lease: &AdapterLease<'_>,
    gm: &mut GenModel,
    snapshot: &HashMap<String, Tensor>,
) -> Result<()> {
    let t0 = Instant::now();
    if slot.switch_to_handle(adapter, lease.handle(), &mut gm.params, snapshot)? {
        let mut m = shared.metrics.lock().unwrap();
        m.switches += 1;
        m.switch_ns += t0.elapsed().as_nanos() as u64;
    }
    Ok(())
}

/// Legacy wave path: one `generate_stream` call over the whole batch
/// (the only path AOT/PJRT artifact backends can serve). Returns
/// `(requests served, tokens generated)` for traffic accounting.
fn serve_wave(id: usize, shared: &Shared, gm: &GenModel, plan: BatchPlan<Job>) -> (usize, usize) {
    let items = plan.items;
    let bs = items.len();
    let reqs: Vec<DecodeRequest> = items
        .iter()
        .map(|q| DecodeRequest {
            prompt: q.payload.prompt.clone(),
            max_new: q.payload.params.max_new,
            temperature: q.payload.params.temperature,
            top_k: q.payload.params.top_k,
            stop: q.payload.params.stop,
            seed: q.payload.params.seed,
        })
        .collect();
    let tk = Tokenizer;
    let mut counts = vec![0usize; bs];
    let texts = gm.generate_stream(&reqs, |i, tok| {
        counts[i] += 1;
        let _ = items[i]
            .payload
            .events
            .send(GenEvent::Token { token: tok, text: tk.decode(&[tok]) });
    });
    let texts = match texts {
        Ok(t) => t,
        Err(e) => {
            fail_all(items, &format!("generation failed: {e:#}"));
            return (0, 0);
        }
    };
    let total_tokens: usize = counts.iter().sum();
    {
        let mut m = shared.metrics.lock().unwrap();
        m.requests += bs;
        m.batches += 1;
        m.tokens += total_tokens;
        for item in &items {
            m.record_latency_ms(item.payload.t0.elapsed().as_secs_f64() * 1e3);
        }
    }
    for ((item, text), tokens) in items.into_iter().zip(texts).zip(counts) {
        let latency = item.payload.t0.elapsed();
        let _ = item.payload.events.send(GenEvent::Done(GenReply {
            text,
            tokens,
            latency,
            batch_size: bs,
            worker: id,
            adapter: item.adapter,
        }));
    }
    (bs, total_tokens)
}

/// One live stream inside a continuous run.
struct Stream {
    job: Job,
    adapter: String,
    /// Row slot in the paged session.
    row: usize,
    /// Encoded prompt (BOS + text + SEP, padded to t_max).
    toks: Vec<i32>,
    /// Prompt length actually fed (`gp.min(t_max - 1)`).
    plen: usize,
    /// Tokens fed so far = the session position after the last step.
    fed: usize,
    generated: Vec<i32>,
    sampler: TokenSampler,
    /// Last sampled token, to feed on the next tick.
    pending_tok: Option<i32>,
    /// Admission order; eviction picks the highest (youngest).
    seq: u64,
}

fn kv_gauge(u: &PoolUsage) -> KvPoolGauge {
    KvPoolGauge {
        capacity_bytes: u.capacity_bytes,
        used_bytes: u.used_bytes,
        peak_bytes: u.peak_bytes,
    }
}

/// The continuous-batching run loop (see the module docs): admit queued
/// requests into free rows, feed one token per live stream per batched
/// decode step, read out, retire finished streams and top up from the
/// queue until neither live streams nor same-adapter work remain.
///
/// Decode semantics are identical to `GenModel`'s wave driver per row —
/// same prompt encoding, same readout rules, same `TokenSampler` stream
/// — so for the same request the continuous path produces the same
/// tokens as `generate_stream`/`generate_full_recompute` (asserted by
/// the serve integration tests).
///
/// Returns `(requests served, tokens generated)` for traffic accounting
/// (evicted or failed streams are not counted as served).
fn continuous_run(
    id: usize,
    shared: &Shared,
    gm: &GenModel,
    sess: &mut dyn PagedDecodeSession,
    adapter: &str,
    items: Vec<Queued<Job>>,
) -> (usize, usize) {
    let tk = Tokenizer;
    let vocab = gm.vocab();
    let t_max = sess.max_seq();
    let rows_cap = sess.rows();
    let capacity_blocks = sess.pool_usage().capacity_blocks;
    let block_tokens = sess.pool_usage().block_tokens;

    let mut pending: VecDeque<Queued<Job>> = items.into();
    let mut streams: Vec<Stream> = Vec::new();
    // LIFO free list so row reuse is deterministic
    let mut free_rows: Vec<usize> = (0..rows_cap).rev().collect();
    let mut next_seq: u64 = 0;
    let (mut done_requests, mut done_tokens) = (0usize, 0usize);

    // exactly-one-terminal-event guarantee: every exit from this loop
    // either finishes, evicts or fails each stream it ever admitted
    loop {
        // --- admit pending requests into free rows -------------------
        let mut processed_any = false;
        while !pending.is_empty()
            && !free_rows.is_empty()
            && sess.pool_usage().free_blocks > 0
        {
            let item = pending.pop_front().expect("checked non-empty");
            let job = item.payload;
            let (toks, gp) = encode_prompt(&tk, &job.prompt, t_max);
            let plen = gp.min(t_max - 1);
            if job.params.max_new == 0 {
                // nothing to generate: reply immediately, no row consumed
                let latency = job.t0.elapsed();
                {
                    let mut m = shared.metrics.lock().unwrap();
                    m.requests += 1;
                    m.record_latency_ms(latency.as_secs_f64() * 1e3);
                }
                let _ = job.events.send(GenEvent::Done(GenReply {
                    text: tk.decode_until_eos(&[]),
                    tokens: 0,
                    latency,
                    batch_size: 1,
                    worker: id,
                    adapter: adapter.to_string(),
                }));
                done_requests += 1;
                processed_any = true;
                continue;
            }
            // hard refusal: a request that cannot fit even in an empty
            // pool would evict forever — fail it up front, typed message
            let worst = (plen + job.params.max_new).min(t_max);
            let needed = worst.div_ceil(block_tokens);
            if needed > capacity_blocks {
                let _ = job.events.send(GenEvent::Error(format!(
                    "kv pool cannot fit request: needs {needed} block(s) of {block_tokens} \
                     token(s), pool capacity {capacity_blocks} block(s)"
                )));
                continue;
            }
            let row = free_rows.pop().expect("checked non-empty");
            if let Err(e) = sess.admit(row) {
                free_rows.push(row);
                let _ = job.events.send(GenEvent::Error(format!("admission failed: {e:#}")));
                continue;
            }
            let sampler = TokenSampler::new(&DecodeRequest {
                prompt: String::new(),
                max_new: job.params.max_new,
                temperature: job.params.temperature,
                top_k: job.params.top_k,
                stop: job.params.stop,
                seed: job.params.seed,
            });
            streams.push(Stream {
                job,
                adapter: item.adapter,
                row,
                toks,
                plen,
                fed: 0,
                generated: Vec::new(),
                sampler,
                pending_tok: None,
                seq: next_seq,
            });
            next_seq += 1;
            processed_any = true;
        }
        if processed_any {
            // one admission wave = one batch for the metrics
            shared.metrics.lock().unwrap().batches += 1;
        }

        // --- refill / exit when idle ---------------------------------
        if streams.is_empty() {
            if !pending.is_empty() {
                // admission is blocked with no live streams to free
                // resources — can't make progress (defensive; admission
                // can only block on rows/blocks held by live streams)
                fail_all(pending.into(), "admission stalled with no live streams");
                break;
            }
            let more = take_from_queue(shared, adapter, rows_cap);
            if more.is_empty() {
                break;
            }
            pending.extend(more);
            continue;
        }

        // --- top up free rows from the queue without waiting ---------
        if !free_rows.is_empty() && pending.is_empty() {
            let more = take_from_queue(shared, adapter, free_rows.len());
            if !more.is_empty() {
                pending.extend(more);
                continue; // admit before stepping
            }
        }

        // --- reserve KV blocks, evicting the youngest under pressure -
        loop {
            let live_rows: Vec<usize> = streams.iter().map(|s| s.row).collect();
            match sess.reserve(&live_rows) {
                Ok(()) => break,
                Err(e) => {
                    let (yi, _) = streams
                        .iter()
                        .enumerate()
                        .max_by_key(|(_, s)| s.seq)
                        .expect("reserve failed with no live streams");
                    let s = streams.swap_remove(yi);
                    sess.retire(s.row);
                    free_rows.push(s.row);
                    {
                        let mut m = shared.metrics.lock().unwrap();
                        m.evictions += 1;
                        m.record_kv(id, kv_gauge(&sess.pool_usage()));
                    }
                    let _ = s.job.events.send(GenEvent::Error(format!(
                        "evicted under kv-pool backpressure: {e}"
                    )));
                    if streams.is_empty() {
                        break;
                    }
                }
            }
        }
        if streams.is_empty() {
            continue;
        }

        // --- one batched decode step over every live stream ----------
        let live = streams.len();
        let mut feed: Vec<Option<i32>> = vec![None; rows_cap];
        for s in &mut streams {
            let tok = if s.fed < s.plen {
                s.toks[s.fed]
            } else {
                s.pending_tok.take().expect("stream fed past prompt without a pending token")
            };
            feed[s.row] = Some(tok);
        }
        let lg = match sess.step(&feed) {
            Ok(lg) => lg,
            Err(e) => {
                let msg = format!("generation failed: {e:#}");
                for s in &streams {
                    sess.retire(s.row);
                }
                for s in streams {
                    let _ = s.job.events.send(GenEvent::Error(msg.clone()));
                }
                fail_all(pending.into(), &msg);
                return (done_requests, done_tokens);
            }
        };

        // --- readout: same per-row rules as the wave driver ----------
        let mut finished: Vec<usize> = Vec::new();
        for (si, s) in streams.iter_mut().enumerate() {
            s.fed += 1;
            if s.fed < s.plen {
                continue; // still prefilling
            }
            if s.generated.len() >= s.job.params.max_new || s.fed >= t_max {
                finished.push(si);
                continue;
            }
            let tok = s.sampler.sample(&lg[s.row * vocab..(s.row + 1) * vocab]);
            if tok == EOS || tok == PAD || s.job.params.stop == Some(tok) {
                finished.push(si);
                continue;
            }
            s.generated.push(tok);
            let _ = s
                .job
                .events
                .send(GenEvent::Token { token: tok, text: tk.decode(&[tok]) });
            s.pending_tok = Some(tok);
        }
        // highest index first keeps the remaining indices valid
        for &si in finished.iter().rev() {
            let s = streams.swap_remove(si);
            sess.retire(s.row);
            free_rows.push(s.row);
            let latency = s.job.t0.elapsed();
            let text = tk.decode_until_eos(&s.generated);
            {
                // metrics are updated before Done is delivered, so a
                // caller that observed Done always sees itself counted
                let mut m = shared.metrics.lock().unwrap();
                m.requests += 1;
                m.tokens += s.generated.len();
                m.record_latency_ms(latency.as_secs_f64() * 1e3);
                m.record_kv(id, kv_gauge(&sess.pool_usage()));
            }
            done_requests += 1;
            done_tokens += s.generated.len();
            let _ = s.job.events.send(GenEvent::Done(GenReply {
                text,
                tokens: s.generated.len(),
                latency,
                batch_size: live,
                worker: id,
                adapter: s.adapter,
            }));
        }
    }
    // final gauge: all streams retired, the pool reads fully free
    shared.metrics.lock().unwrap().record_kv(id, kv_gauge(&sess.pool_usage()));
    (done_requests, done_tokens)
}

/// Pull more same-adapter work for a running continuous batch. Empty
/// when the batcher's starvation guard says to yield (see
/// [`AdapterBatcher::take_matching`]).
fn take_from_queue(shared: &Shared, adapter: &str, max: usize) -> Vec<Queued<Job>> {
    let mut q = shared.queue.lock().unwrap();
    q.batcher.take_matching(adapter, max)
}
