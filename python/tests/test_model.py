"""L2 model + methods: layouts, forward equivalence, training, merge."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import MODELS, MethodConfig, default_methods

CFG = MODELS["tiny"]
METHODS = default_methods(CFG)


@pytest.fixture(scope="module")
def base():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch():
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (2, CFG.seq_len), 0, CFG.vocab).astype(jnp.int32)
    targets = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones(tokens.shape, jnp.float32)
    return tokens, targets, mask


def _prep(mc, base, batch):
    tokens, targets, mask = batch
    return M.prepare_method(CFG, mc, base, jnp.int32(42), tokens, targets, mask)


def test_param_shapes_sorted_and_counted():
    shapes = M.param_shapes(CFG)
    assert list(shapes) == sorted(shapes)
    total = sum(int(np.prod(s)) for s in shapes.values())
    assert total == CFG.param_count()


def test_forward_base_shape_and_finite(base, batch):
    logits = M.forward_base(CFG, base, batch[0])
    assert logits.shape == (2, CFG.seq_len, CFG.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_ce_loss_mask():
    logits = jnp.zeros((1, 4, 7))
    targets = jnp.zeros((1, 4), jnp.int32)
    full = M.ce_loss(logits, targets, jnp.ones((1, 4)))
    np.testing.assert_allclose(float(full), np.log(7.0), rtol=1e-5)
    # zero mask must not NaN
    z = M.ce_loss(logits, targets, jnp.zeros((1, 4)))
    assert float(z) == 0.0


@pytest.mark.parametrize("name", list(METHODS))
def test_layout_matches_prepare(name, base, batch):
    mc = METHODS[name]
    trn, frz, perms = _prep(mc, base, batch)
    lt, lf, lp, _ = M.method_layout(CFG, mc)
    assert sorted(trn) == sorted(lt)
    assert sorted(frz) == sorted(lf)
    assert sorted(perms) == sorted(lp)
    for k in trn:
        assert tuple(trn[k].shape) == tuple(lt[k]), k


@pytest.mark.parametrize("name", list(METHODS))
def test_forward_preserved_at_init(name, base, batch):
    """Every PEFT init is a no-op on the function computed (B=0 / delta=0 /
    permutation-invariance for s2ft)."""
    mc = METHODS[name]
    trn, frz, perms = _prep(mc, base, batch)
    want = M.forward_base(CFG, base, batch[0])
    got = M.forward_method(CFG, mc, trn, frz, batch[0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("name", list(METHODS))
def test_merge_roundtrip(name, base, batch):
    mc = METHODS[name]
    trn, frz, perms = _prep(mc, base, batch)
    merged = M.merge_method(CFG, mc, trn, frz, perms)
    for k in M.param_shapes(CFG):
        np.testing.assert_allclose(np.asarray(merged[k]), np.asarray(base[k]),
                                   rtol=2e-4, atol=2e-4, err_msg=f"{name}/{k}")


@pytest.mark.parametrize("name", list(METHODS))
def test_train_step_reduces_loss(name, base, batch):
    mc = METHODS[name]
    tokens, targets, mask = batch
    trn, frz, _ = _prep(mc, base, batch)
    oshapes = M.opt_state_shapes(CFG, mc)
    om = {k: jnp.zeros(v, jnp.float32) for k, v in oshapes.items()}
    ov = {k: jnp.zeros(v, jnp.float32) for k, v in oshapes.items()}
    _, _, _, aux_s = M.method_layout(CFG, mc)
    aux = {k: jnp.ones(v, jnp.float32) for k, v in aux_s.items()}

    fn = jax.jit(lambda tr, om_, ov_, s: M.train_step(
        CFG, mc, tr, frz, om_, ov_, s, tokens, targets, mask, aux))
    nt, nm, nv, loss0 = fn(trn, om, ov, jnp.float32(0))
    for i in range(4):
        nt, nm, nv, loss = fn(nt, nm, nv, jnp.float32(i + 1))
    assert float(loss) < float(loss0), name
    assert np.isfinite(float(loss))


def test_s2ft_updates_only_selected_rows(base, batch):
    """Core S2FT invariant: after merge, only rows/cols at selected indices
    differ from the base weights."""
    mc = METHODS["s2ft"]
    tokens, targets, mask = batch
    trn, frz, perms = _prep(mc, base, batch)
    oshapes = M.opt_state_shapes(CFG, mc)
    om = {k: jnp.zeros(v, jnp.float32) for k, v in oshapes.items()}
    ov = {k: jnp.zeros(v, jnp.float32) for k, v in oshapes.items()}
    nt, _, _, _ = M.train_step(CFG, mc, trn, frz, om, ov, jnp.float32(0),
                               tokens, targets, mask, {})
    merged = M.merge_method(CFG, mc, nt, frz, perms)
    counts = M.s2ft_counts(CFG, mc)
    hd = CFG.head_dim
    for i in range(CFG.n_layers):
        # FFN: only selected wd rows change
        chan_perm = np.asarray(perms[f"L{i}.chan_perm"])
        sel_rows = set(chan_perm[: counts["wd"]].tolist())
        diff = np.abs(np.asarray(merged[f"L{i}.wd"]) - np.asarray(base[f"L{i}.wd"]))
        changed = set(np.nonzero(diff.sum(axis=1) > 0)[0].tolist())
        assert changed <= sel_rows
        assert changed, "selected rows must actually receive updates"
        # MHA: only selected head row-blocks of wo change
        head_perm = np.asarray(perms[f"L{i}.head_perm"])
        sel_el = {h * hd + j for h in head_perm[: counts["wo"]] for j in range(hd)}
        diffo = np.abs(np.asarray(merged[f"L{i}.wo"]) - np.asarray(base[f"L{i}.wo"]))
        changedo = set(np.nonzero(diffo.sum(axis=1) > 0)[0].tolist())
        assert changedo <= sel_el
        # everything not in the coupled structures is bit-identical
        np.testing.assert_array_equal(np.asarray(merged[f"L{i}.norm1"]),
                                      np.asarray(base[f"L{i}.norm1"]))
    np.testing.assert_array_equal(np.asarray(merged["embed"]),
                                  np.asarray(base["embed"]))


def test_s2ft_pallas_matches_native(base, batch):
    """The Pallas hot path computes the identical training trajectory."""
    tokens, targets, mask = batch
    out = {}
    for name in ("s2ft", "s2ft-pallas"):
        mc = METHODS[name]
        trn, frz, _ = _prep(mc, base, batch)
        oshapes = M.opt_state_shapes(CFG, mc)
        om = {k: jnp.zeros(v, jnp.float32) for k, v in oshapes.items()}
        ov = {k: jnp.zeros(v, jnp.float32) for k, v in oshapes.items()}
        nt, _, _, loss = M.train_step(CFG, mc, trn, frz, om, ov, jnp.float32(0),
                                      tokens, targets, mask, {})
        out[name] = (nt, float(loss))
    assert abs(out["s2ft"][1] - out["s2ft-pallas"][1]) < 1e-5
    for k in out["s2ft"][0]:
        np.testing.assert_allclose(np.asarray(out["s2ft"][0][k]),
                                   np.asarray(out["s2ft-pallas"][0][k]),
                                   rtol=2e-4, atol=2e-5)


def test_selection_strategies_prepare(base, batch):
    """A/S/G selection runs in-graph from calibration data."""
    for strat in "wasg":
        mc = MethodConfig("s2ft", s2ft_fractions={"wo": 0.25, "wd": 0.1},
                          selection=strat)
        trn, frz, perms = _prep(mc, base, batch)
        p = np.asarray(perms["L0.chan_perm"])
        assert sorted(p.tolist()) == list(range(CFG.d_ff))


def test_fig4_single_component_budgets(base, batch):
    """Each projection type can carry the whole budget (Fig 4 ablation)."""
    for proj in ("wq", "wk", "wv", "wo", "wu", "wg", "wd"):
        mc = MethodConfig("s2ft", s2ft_fractions={proj: 0.25})
        trn, frz, perms = _prep(mc, base, batch)
        assert any(k.endswith(f"{proj}_t") for k in trn), proj
        want = M.forward_base(CFG, base, batch[0])
        got = M.forward_method(CFG, mc, trn, frz, batch[0])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


def test_lisa_mask_freezes_layers(base, batch):
    tokens, targets, mask = batch
    mc = METHODS["lisa"]
    trn, frz, _ = _prep(mc, base, batch)
    oshapes = M.opt_state_shapes(CFG, mc)
    om = {k: jnp.zeros(v, jnp.float32) for k, v in oshapes.items()}
    ov = {k: jnp.zeros(v, jnp.float32) for k, v in oshapes.items()}
    lm = np.ones(CFG.n_layers + 1, np.float32)
    lm[0] = 0.0  # freeze layer 0 this step
    nt, _, _, _ = M.train_step(CFG, mc, trn, frz, om, ov, jnp.float32(0),
                               tokens, targets, mask,
                               {"layer_mask": jnp.asarray(lm)})
    np.testing.assert_array_equal(np.asarray(nt["L0.wq"]), np.asarray(trn["L0.wq"]))
    assert not np.array_equal(np.asarray(nt["L1.wq"]), np.asarray(trn["L1.wq"]))


def test_galore_opt_state_is_projected():
    mc = METHODS["galore"]
    shapes = M.opt_state_shapes(CFG, mc)
    d = CFG.d_model
    assert shapes["L0.wq"] == (mc.rank, d)
    assert shapes["L0.norm1"] == (d,)
    full = sum(int(np.prod(s)) for s in M.param_shapes(CFG).values())
    proj = sum(int(np.prod(s)) for s in shapes.values())
    assert proj < full / 2  # the memory saving galore claims
