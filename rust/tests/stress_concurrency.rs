//! Concurrency stress tests — the primary workload for the CI
//! ThreadSanitizer lane (`make tsan`), also run under plain `cargo test`.
//!
//! Three shared-state surfaces are exercised:
//!
//! * the kernels thread pool: `set_threads` override churn racing
//!   concurrent GEMMs, which must stay bit-identical to the naive
//!   reference at every thread count;
//! * the serve engine: drop/shutdown with in-flight streaming requests
//!   across 4 workers (no hang, exactly one terminal event per stream,
//!   metrics consistent with what was served) and adapter
//!   register/fuse/unregister churn under concurrent submits;
//! * the shared `AdapterStore`: concurrent per-worker switch/deactivate
//!   churn that must restore base weights bitwise;
//! * the paged KV pool: typed exhaustion errors, block reclamation and
//!   exact byte accounting while the continuous-batching engine churns.
//!
//! `S2FT_STRESS_ITERS` scales the iteration counts down for the TSan
//! lane (shadow-memory slowdown is roughly an order of magnitude).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

use repro::adapter::{AdapterSlot, AdapterStore, AnyAdapter, S2ftAdapter, S2ftLayerDelta};
use repro::kernels::{self, reference};
use repro::runtime::{Executable, Executor, NativeBackend, Tensor};
use repro::serve::{Engine, EngineConfig, GenEvent, GenRequest, KvPool, PoolExhausted};
use repro::train::GenModel;
use repro::util::rng::Rng;

/// Iteration count, overridable via `S2FT_STRESS_ITERS` so the TSan CI
/// lane can stay inside its time budget.
fn stress_iters(default: usize) -> usize {
    let v = std::env::var("S2FT_STRESS_ITERS").ok();
    v.and_then(|s| s.parse().ok()).unwrap_or(default).max(1)
}

/// Run `f` on a fresh thread and panic if it does not finish in time —
/// a hang in a concurrency test must fail loudly, not stall the suite.
fn with_deadline<F>(secs: u64, name: &str, f: F)
where
    F: FnOnce() + Send + 'static,
{
    let (tx, rx) = mpsc::channel();
    let h = thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) => h.join().unwrap(),
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            // the worker panicked before signalling; surface its panic
            if let Err(p) = h.join() {
                std::panic::resume_unwind(p);
            }
            panic!("{name}: worker exited without completing");
        }
        Err(mpsc::RecvTimeoutError::Timeout) => panic!("{name}: deadline of {secs}s exceeded"),
    }
}

/// Synthetic tiny-model S²FT adapter deltas, deterministic per rng state.
fn tiny_adapter(rng: &mut Rng) -> AnyAdapter {
    let rt = NativeBackend::builtin();
    let mm = rt.artifacts().model("tiny").unwrap();
    let (d, hd) = (mm.dims.d_model, mm.head_dim());
    let layers = (0..mm.dims.n_layers)
        .map(|_| {
            let heads = rng.choose(mm.dims.n_heads, 1);
            let wo_rows = repro::sparsity::expand_head_perm(&heads, hd);
            S2ftLayerDelta {
                wo_delta: (0..wo_rows.len() * d).map(|_| rng.normal_f32() * 1e-3).collect(),
                wo_rows,
                wd_rows: rng.choose(mm.dims.d_ff, 2),
                wd_delta: (0..2 * d).map(|_| rng.normal_f32() * 1e-3).collect(),
            }
        })
        .collect();
    AnyAdapter::S2ft(S2ftAdapter { layers, d_model: d })
}

/// Native-backend engine with `n_adapters` registered, short batching
/// window to keep the stress tests brisk.
fn native_engine(n_adapters: usize, workers: usize, max_batch: usize) -> Engine {
    let cfg = EngineConfig::new()
        .workers(workers)
        .max_batch(max_batch)
        .window(Duration::from_millis(1));
    let engine = Engine::spawn(cfg, |_wid| {
        let rt = NativeBackend::builtin();
        let init = rt.load("init_tiny")?;
        let outs = init.run(&[Tensor::scalar_i32(3)])?;
        let params: HashMap<String, Tensor> =
            init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect();
        let snapshot = params.clone();
        let gm = GenModel::new(&rt, "tiny", params)?;
        Ok((gm, snapshot))
    });
    let mut rng = Rng::seed(0x57AE55);
    for a in 0..n_adapters {
        engine.register(format!("a{a}"), tiny_adapter(&mut rng));
    }
    engine
}

/// Kernels pool: `set_threads` churn racing concurrent GEMMs. The pool
/// size is a relaxed atomic read per call, so every GEMM sees *some*
/// thread count — and the bit-identity contract says the count must not
/// matter. 64³ multiply-adds exceeds the MIN_PAR_WORK threshold, so the
/// parallel path genuinely engages.
#[test]
fn set_threads_churn_keeps_gemm_bit_identical() {
    let iters = stress_iters(40);
    let (m, k, n) = (64usize, 64, 64);
    let mut rng = Rng::seed(0xC0FFEE);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
    let refr = reference::gemm(&a, &b, m, k, n);
    let want: Vec<u32> = refr.iter().map(|x| x.to_bits()).collect();
    with_deadline(120, "set_threads churn", move || {
        let stop = AtomicBool::new(false);
        thread::scope(|s| {
            let churn = s.spawn(|| {
                let mut t = 1usize;
                while !stop.load(Ordering::Relaxed) {
                    kernels::set_threads(t);
                    // 0 resets to the S2FT_THREADS / all-cores fallback
                    t = if t >= 4 { 0 } else { t + 1 };
                    thread::yield_now();
                }
                kernels::set_threads(0);
            });
            let mut workers = Vec::new();
            for _ in 0..4 {
                workers.push(s.spawn(|| {
                    for _ in 0..iters {
                        let got = kernels::gemm(&a, &b, m, k, n);
                        for (g, w) in got.iter().zip(&want) {
                            assert_eq!(g.to_bits(), *w, "GEMM drifted under thread churn");
                        }
                    }
                }));
            }
            for w in workers {
                w.join().unwrap();
            }
            stop.store(true, Ordering::Relaxed);
            churn.join().unwrap();
        });
    });
}

/// Dropping an engine with a full queue must not hang, and every stream
/// still ends in exactly one terminal event (`Done` for drained work,
/// `Error` if the pool gave up on it) — never zero, never two.
#[test]
fn engine_drop_with_inflight_streams_terminates_every_stream() {
    let iters = stress_iters(24);
    with_deadline(180, "engine drop with in-flight streams", move || {
        let engine = native_engine(3, 4, 4);
        let mut streams = Vec::new();
        for i in 0..iters {
            let id = format!("a{}", i % 3);
            streams.push(engine.submit(GenRequest::new(id, format!("q: {i}?")).max_new(4)));
        }
        drop(engine); // shutdown with the queue still full
        for s in streams {
            let mut terminals = 0usize;
            for ev in s {
                match ev {
                    GenEvent::Done(_) | GenEvent::Error(_) => terminals += 1,
                    GenEvent::Token { .. } => {}
                }
            }
            assert_eq!(terminals, 1, "every stream must end in exactly one terminal");
        }
    });
}

/// Explicit shutdown path: everything submitted before the drain is
/// served, and the metrics agree exactly with what the streams saw
/// (requests, latency samples). Metrics are updated before `Done` is
/// delivered, so this is race-free by construction.
#[test]
fn engine_shutdown_drains_and_metrics_count_every_served_request() {
    let iters = stress_iters(16);
    with_deadline(180, "engine shutdown drain", move || {
        let engine = native_engine(2, 4, 4);
        let mut streams = Vec::new();
        for i in 0..iters {
            let id = format!("a{}", i % 2);
            streams.push(engine.submit(GenRequest::new(id, format!("q: {i}?")).max_new(2)));
        }
        let mut done = 0usize;
        for s in streams {
            if s.wait().is_ok() {
                done += 1;
            }
        }
        assert_eq!(done, iters, "all submitted requests must serve");
        let m = engine.metrics();
        assert_eq!(m.requests, done, "metrics must count every served request");
        assert_eq!(m.latencies_ms().len(), done);
        assert!(m.batches >= 1 && m.batches <= done);
        engine.shutdown().unwrap();
    });
}

/// Runtime adapter lifecycle churn (register / fuse / unregister a hot
/// id) racing concurrent submits on stable ids: nothing is lost, the
/// stable ids never fail, and the served count matches the metrics.
#[test]
fn adapter_lifecycle_churn_under_concurrent_submits() {
    let iters = stress_iters(6);
    with_deadline(240, "adapter lifecycle churn", move || {
        let engine = Arc::new(native_engine(3, 4, 2));
        let stop = Arc::new(AtomicBool::new(false));
        let churn = {
            let engine = engine.clone();
            let stop = stop.clone();
            thread::spawn(move || {
                let mut rng = Rng::seed(0x5EED);
                while !stop.load(Ordering::Relaxed) {
                    engine.register("hot", tiny_adapter(&mut rng));
                    let _ = engine.fuse("blend", &[("a0", 0.5), ("hot", 0.5)]);
                    let _ = engine.unregister("hot");
                    thread::yield_now();
                }
            })
        };
        let mut submitters = Vec::new();
        for w in 0..4 {
            let engine = engine.clone();
            submitters.push(thread::spawn(move || {
                let mut done = 0usize;
                let mut errs = 0usize;
                for i in 0..iters {
                    let id = format!("a{}", (w + i) % 3);
                    match engine.call(GenRequest::new(id, "q?").max_new(1)) {
                        Ok(_) => done += 1,
                        Err(_) => errs += 1,
                    }
                }
                (done, errs)
            }));
        }
        let mut done = 0usize;
        let mut errs = 0usize;
        for h in submitters {
            let (d, e) = h.join().unwrap();
            done += d;
            errs += e;
        }
        stop.store(true, Ordering::Relaxed);
        churn.join().unwrap();
        assert_eq!(done + errs, 4 * iters, "no request may be lost");
        assert_eq!(errs, 0, "stable adapter ids must never fail to serve");
        let m = engine.metrics();
        assert_eq!(m.requests, done);
        Arc::try_unwrap(engine)
            .ok()
            .expect("sole owner")
            .shutdown()
            .unwrap();
    });
}

/// An empty freelist is a *typed* error carrying the exact shortfall —
/// never a panic — and released blocks are immediately allocatable
/// again (LIFO reclamation), with byte accounting restored to zero.
#[test]
fn kv_pool_exhaustion_is_typed_and_blocks_reclaim() {
    let mut pool = KvPool::new(2, 8, 4, 3);
    let b0 = pool.alloc().unwrap();
    let b1 = pool.alloc().unwrap();
    let b2 = pool.alloc().unwrap();
    let err = pool.alloc().unwrap_err();
    assert_eq!(
        err,
        PoolExhausted { requested_blocks: 1, free_blocks: 0, capacity_blocks: 3 }
    );
    assert!(err.to_string().contains("kv pool exhausted"), "{err}");
    let u = pool.usage();
    assert_eq!(u.used_bytes, 3 * u.block_bytes, "all capacity pinned at exhaustion");
    pool.release(&[b1]);
    let again = pool.alloc().expect("released block must be allocatable");
    assert_eq!(again, b1, "LIFO freelist reuses the reclaimed block first");
    pool.release(&[b0, b2, again]);
    let u = pool.usage();
    assert_eq!(u.free_blocks, 3);
    assert_eq!(u.used_bytes, 0, "full release must zero the byte gauge");
    assert_eq!(u.peak_bytes, u.capacity_bytes, "peak saw the full pool");
}

/// Engine-level KV accounting under churn: after a drained run the
/// pool gauges must read exactly zero used bytes, a peak that is a
/// whole number of blocks, and a capacity that is a whole number of
/// per-worker pools — no leaked blocks, no phantom bytes.
#[test]
fn kv_pool_accounting_is_exact_under_engine_churn() {
    let iters = stress_iters(24);
    with_deadline(180, "kv pool accounting churn", move || {
        let engine = native_engine(2, 2, 4);
        let mut streams = Vec::new();
        for i in 0..iters {
            let id = format!("a{}", i % 2);
            streams.push(engine.submit(GenRequest::new(id, format!("q: churn {i}?")).max_new(3)));
        }
        for s in streams {
            s.wait().expect("reply");
        }
        let m = engine.metrics();
        // reconstruct the exact per-worker pool geometry: default
        // 16-token blocks, max_batch=4 row slots, t_max from the model
        let rt = NativeBackend::builtin();
        let mm = rt.artifacts().model("tiny").unwrap();
        let (_, t_max) = mm.default_batch();
        let bt = 16usize; // EngineConfig::default().kv_block_tokens
        let block_bytes = 2 * mm.dims.n_layers * bt * mm.dims.d_model * 4;
        let per_worker = 4 * t_max.div_ceil(bt) * block_bytes;
        assert_eq!(m.kv_used_bytes(), 0, "drained engine must hold zero KV bytes");
        assert!(m.kv_peak_bytes() > 0, "serving must have pinned at least one block");
        assert_eq!(m.kv_peak_bytes() % block_bytes, 0, "peak must be whole blocks");
        assert!(m.kv_capacity_bytes() > 0 && m.kv_capacity_bytes() % per_worker == 0);
        assert!(m.kv_peak_bytes() <= m.kv_capacity_bytes());
        assert_eq!(m.evictions, 0, "the auto-sized pool never evicts");
        engine.shutdown().unwrap();
    });
}

/// Shared `AdapterStore` under concurrent per-worker switch churn: after
/// any switch sequence plus a deactivate, the live weights must equal
/// the pristine snapshot *bitwise*. Zero base weights make that exact:
/// `0 + v - v` is `+0.0` in every lane, so any drift is a real bug.
#[test]
fn adapter_store_churn_restores_base_weights_bitwise() {
    let iters = stress_iters(200);
    with_deadline(120, "adapter store churn", move || {
        let d = 8usize;
        let store = AdapterStore::new();
        let mut rng = Rng::seed(0xAB);
        for a in 0..4 {
            let wd_rows = rng.choose(d, 2);
            let wd_delta: Vec<f32> = (0..2 * d).map(|_| rng.normal_f32()).collect();
            let layer = S2ftLayerDelta { wo_rows: vec![], wo_delta: vec![], wd_rows, wd_delta };
            let adapter = AnyAdapter::S2ft(S2ftAdapter { layers: vec![layer], d_model: d });
            store.insert(format!("a{a}"), adapter);
        }
        let base = || {
            let mut p = HashMap::new();
            p.insert("L0.wo".to_string(), Tensor::zeros(vec![d, d]));
            p.insert("L0.wd".to_string(), Tensor::zeros(vec![d, d]));
            p
        };
        thread::scope(|s| {
            for w in 0..4 {
                let store = &store;
                let base = &base;
                s.spawn(move || {
                    let snapshot = base();
                    let mut params = base();
                    let mut slot = AdapterSlot::new();
                    for i in 0..iters {
                        let id = format!("a{}", (w + i) % 4);
                        slot.switch_to(store, &id, &mut params, &snapshot).unwrap();
                    }
                    slot.deactivate(&mut params, &snapshot).unwrap();
                    for name in ["L0.wo", "L0.wd"] {
                        let got = params[name].as_f32().unwrap();
                        let want = snapshot[name].as_f32().unwrap();
                        for (g, v) in got.iter().zip(want) {
                            assert_eq!(g.to_bits(), v.to_bits(), "{name} must restore bitwise");
                        }
                    }
                });
            }
        });
        assert!(store.switches() >= 4, "churn must actually switch");
    });
}
