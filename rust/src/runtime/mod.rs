//! Pluggable execution backends behind the [`Executor`] / [`Executable`]
//! traits.
//!
//! The interchange contract (defined by the python build layer `aot.py`)
//! is a set of named *artifacts* — `init_M`, `fwd_M_BxT`, `eval_M_BxT`,
//! `prepare_M_m_BxT`, `train_M_m_BxT`, `merge_M_m` — each with an exact
//! input/output tensor order recorded in `meta.json`. Two backends honor
//! that contract:
//!
//! * [`NativeBackend`] — a pure-Rust interpreter of the model contract
//!   (seeded init, LLaMA-style forward/eval, AdamW train step with S²FT
//!   partial backprop, merge). Hermetic: no Python, no artifacts, no XLA.
//!   This is the default, and the only backend unit/integration tests need.
//! * [`Runtime`] (cargo feature `pjrt`) — compiles the AOT HLO-text
//!   artifacts through the `xla` PJRT crate and executes them. Requires
//!   `make artifacts` and a real `xla` build (the vendored crate is a
//!   compile-only stub).
//!
//! Everything above this module ([`crate::train`], [`crate::serve`],
//! [`crate::experiments`]) is backend-agnostic: it sees only
//! `&dyn Executor` and `Arc<dyn Executable>`.

mod meta;
pub mod native;
#[cfg(feature = "pjrt")]
mod pjrt;
mod tensor;

pub use meta::{ArtifactMeta, Meta, MethodMeta, ModelDims, ModelMeta, NamedShape, TensorSpec};
pub use native::NativeBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;
pub use tensor::{Tensor, TensorData};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::serve::kvpool::{KvPoolConfig, PoolExhausted, PoolUsage};

/// Handle to the parsed meta.json plus (for artifact-backed backends) the
/// directory the HLO files live in. The native backend synthesizes its
/// meta in-process and uses a placeholder directory.
#[derive(Clone)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub meta: Arc<Meta>,
}

impl Artifacts {
    /// Open an artifact directory produced by `make artifacts`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?}; run `make artifacts`"))?;
        let meta = Meta::parse(&text)?;
        Ok(Self { dir, meta: Arc::new(meta) })
    }

    /// Wrap an in-memory meta (native backend — no files involved).
    pub fn from_meta(meta: Meta) -> Self {
        Self { dir: PathBuf::from("<native>"), meta: Arc::new(meta) }
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.meta
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in meta (rebuild artifacts?)"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.meta
            .models
            .get(name)
            .ok_or_else(|| anyhow!("model {name:?} not in meta"))
    }
}

/// One loaded artifact: a callable with a self-describing interface.
pub trait Executable: Send + Sync {
    /// Artifact name (`train_tiny_s2ft_2x32`, ...).
    fn name(&self) -> &str;

    /// Interface description: input/output names, shapes, dtypes.
    fn spec(&self) -> &ArtifactMeta;

    /// Execute with positional inputs (must match `spec().inputs` order).
    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Execute with named inputs pulled from a tensor pool.
    fn run_named(&self, pool: &HashMap<String, Tensor>) -> Result<HashMap<String, Tensor>> {
        self.run_named_with(pool, &HashMap::new())
    }

    /// Execute with named inputs pulled from `overlay` first, then `pool`.
    ///
    /// Callers with per-step inputs (batch tensors, step counters, layer
    /// masks) pass them in the overlay so the persistent pool holds
    /// *state only* — this is what keeps `Trainer::state_bytes()` an
    /// honest Fig 5 number instead of one that silently absorbs batch
    /// inputs after the first step.
    fn run_named_with(
        &self,
        pool: &HashMap<String, Tensor>,
        overlay: &HashMap<String, Tensor>,
    ) -> Result<HashMap<String, Tensor>> {
        let spec = self.spec();
        let mut args = Vec::with_capacity(spec.inputs.len());
        for s in &spec.inputs {
            let t = overlay
                .get(&s.name)
                .or_else(|| pool.get(&s.name))
                .ok_or_else(|| anyhow!("{}: missing input {:?}", self.name(), s.name))?;
            args.push(t.clone());
        }
        let outs = self.run(&args)?;
        Ok(self
            .spec()
            .outputs
            .iter()
            .map(|s| s.name.clone())
            .zip(outs)
            .collect())
    }

    /// Total bytes of all inputs at their declared dtypes (Fig 5 memory
    /// accounting).
    fn input_bytes(&self) -> usize {
        self.spec().inputs.iter().map(|s| s.numel() * s.dtype_bytes()).sum()
    }

    fn output_bytes(&self) -> usize {
        self.spec().outputs.iter().map(|s| s.numel() * s.dtype_bytes()).sum()
    }
}

/// Validate positional inputs against a spec (shared by all backends).
pub fn check_inputs(name: &str, spec: &ArtifactMeta, inputs: &[Tensor]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!("{name}: expected {} inputs, got {}", spec.inputs.len(), inputs.len());
    }
    for (t, s) in inputs.iter().zip(&spec.inputs) {
        if t.shape != s.shape {
            bail!(
                "{name}: input {:?} shape {:?} != expected {:?}",
                s.name, t.shape, s.shape
            );
        }
    }
    Ok(())
}

/// An execution backend: loads executables by artifact name and owns the
/// compiled/interpreted cache.
pub trait Executor: Send + Sync {
    /// The meta the backend serves (models, methods, artifact specs).
    fn artifacts(&self) -> &Artifacts;

    /// Compile (or fetch from cache) an executable by artifact name.
    fn load(&self, name: &str) -> Result<Arc<dyn Executable>>;

    /// Drop a cached executable (frees memory for big models).
    fn evict(&self, name: &str);

    /// Human-readable backend identifier.
    fn platform(&self) -> String;

    /// Build (and cache, under a synthetic train-artifact name derived
    /// from `tag`) a train executable for a *method-layout variant*: the
    /// base method's hyperparameters with an explicit per-layer unit-count
    /// budget, as committed mid-run by a dynamic selection strategy. The
    /// executable is always rebuilt fresh — never served from cache — so a
    /// reused tag can't resurrect a stale layout. Backends without
    /// replanning support (AOT artifact sets are fixed at build time)
    /// refuse.
    fn load_train_variant(
        &self,
        _model: &str,
        _tag: &str,
        _base_method: &str,
        _counts_per_layer: &[HashMap<String, usize>],
        _b: usize,
        _t: usize,
    ) -> Result<Arc<dyn Executable>> {
        bail!(
            "backend {:?} cannot build method-layout variants; dynamic \
             re-selection requires the native backend",
            self.platform()
        )
    }

    /// KV-cached incremental-decode provider, if the backend supports
    /// stepping a model one token at a time (the native interpreter
    /// does). `None` means callers must fall back to full-sequence
    /// recompute through the `fwd_M_BxT` artifact.
    fn decoder(&self) -> Option<Arc<dyn DecoderProvider>> {
        None
    }
}

/// An in-flight incremental decoding session over a fixed batch capacity
/// and maximum sequence length. Rows advance independently: each
/// [`DecodeSession::step`] consumes at most one token per row, appends
/// its key/value to that row's cache, and returns next-token logits —
/// O(t) work per generated token instead of the O(t²) full-sequence
/// recompute.
pub trait DecodeSession {
    /// Batch capacity (cache rows).
    fn batch(&self) -> usize;

    /// Maximum positions per row.
    fn max_seq(&self) -> usize;

    /// Cache length (= next position) for `row`.
    fn pos(&self, row: usize) -> usize;

    /// Feed `tokens[row]` at each `Some` row's next position and return
    /// logits as a `(batch, vocab)` row-major buffer. Rows passed `None`
    /// are untouched and their logits rows are zero/stale.
    fn step(&mut self, tokens: &[Option<i32>]) -> Result<Vec<f32>>;
}

/// A continuous-batching decode session over a shared block-paged KV
/// pool (see [`crate::serve::kvpool`]). Unlike [`DecodeSession`], whose
/// rows are bound for the whole session, paged rows are *slots*:
/// streams [`PagedDecodeSession::admit`] into a free row, draw cache
/// blocks lazily via [`PagedDecodeSession::reserve`], and
/// [`PagedDecodeSession::retire`] returns their blocks to the pool —
/// so the serve engine can admit and finish requests mid-flight while
/// every step stays one batched forward across all active rows.
///
/// Bit-identity contract: for the same per-row token schedule, logits
/// match [`DecodeSession`] (and full recompute) bit-for-bit — the block
/// table is address translation only.
pub trait PagedDecodeSession {
    /// Row-slot capacity (max concurrently-admitted streams).
    fn rows(&self) -> usize;

    /// Maximum positions per stream.
    fn max_seq(&self) -> usize;

    /// Cache length (= next position) for `row` (0 if not admitted).
    fn pos(&self, row: usize) -> usize;

    /// Whether `row` currently hosts an admitted stream.
    fn is_active(&self, row: usize) -> bool;

    /// Bind a fresh stream (position 0, empty block table) to a free
    /// row. Fails if the row is already occupied. Allocates nothing:
    /// blocks are drawn by [`PagedDecodeSession::reserve`].
    fn admit(&mut self, row: usize) -> Result<()>;

    /// Release `row`'s stream and return its blocks to the pool.
    /// No-op when the row is not admitted.
    fn retire(&mut self, row: usize);

    /// Ensure each listed row's block table covers its next position,
    /// allocating from the pool as needed. On
    /// [`crate::serve::kvpool::PoolExhausted`] no arithmetic state has
    /// been touched (tables may have grown — harmless), so the caller
    /// can evict a stream and retry. Must be called before
    /// [`PagedDecodeSession::step`] feeds those rows.
    fn reserve(&mut self, rows: &[usize]) -> std::result::Result<(), PoolExhausted>;

    /// Feed `tokens[row]` at each `Some` row's next position and return
    /// logits as a `(rows, vocab)` row-major buffer — same semantics as
    /// [`DecodeSession::step`]. Stepped rows must be admitted and
    /// reserved.
    fn step(&mut self, tokens: &[Option<i32>]) -> Result<Vec<f32>>;

    /// Attach (or clear with `None`) an adapter applied **unfused** at
    /// decode time: every subsequent [`PagedDecodeSession::step`] adds
    /// the adapter's per-row delta contribution on top of the *base*
    /// weights (gather selected activations, `gemv_acc` the dense delta
    /// rows) instead of requiring the weights to be mutated up front.
    /// This is the serve residency manager's cold-adapter path — the
    /// worker's fused weights stay pristine, so no unfuse is owed when
    /// the batch ends.
    ///
    /// Default implementation: clearing (`None`) succeeds, attaching
    /// fails — backends without the hook serve every adapter fused.
    fn set_unfused_adapter(&mut self, adapter: Option<Arc<crate::adapter::AnyAdapter>>) -> Result<()> {
        match adapter {
            None => Ok(()),
            Some(_) => bail!("this decode session cannot apply adapters unfused"),
        }
    }

    /// Exact pool accounting (capacity / used / peak bytes).
    fn pool_usage(&self) -> PoolUsage;
}

/// Factory for [`DecodeSession`]s. Split from [`Executor`] so a session
/// can borrow the caller's weight pool (`'p`) without tying it to the
/// backend's lifetime.
pub trait DecoderProvider: Send + Sync {
    /// Open a session over `params` (base-layout weights) for `model`,
    /// with `b` cache rows of `t_max` positions each.
    fn open_session<'p>(
        &self,
        model: &str,
        params: &'p HashMap<String, Tensor>,
        b: usize,
        t_max: usize,
    ) -> Result<Box<dyn DecodeSession + 'p>>;

    /// Open a paged continuous-batching session with `rows` stream
    /// slots backed by a KV pool sized by `cfg`. Default: unsupported
    /// (`Ok(None)`) — callers fall back to [`DecoderProvider::open_session`]
    /// wave scheduling.
    fn open_paged<'p>(
        &self,
        _model: &str,
        _params: &'p HashMap<String, Tensor>,
        _rows: usize,
        _t_max: usize,
        _cfg: KvPoolConfig,
    ) -> Result<Option<Box<dyn PagedDecodeSession + 'p>>> {
        Ok(None)
    }
}

/// Open the best available backend for `artifact_dir`:
///
/// * with the `pjrt` feature and a `meta.json` present, the PJRT runtime;
/// * with a `meta.json` but no PJRT, the native interpreter *at the
///   artifact shapes* (meta-driven);
/// * otherwise the native interpreter with its builtin model set
///   (tiny/small/base, mirroring `python/compile/configs.py`).
pub fn open_backend(artifact_dir: &str) -> Result<Box<dyn Executor>> {
    let has_meta = Path::new(artifact_dir).join("meta.json").exists();
    #[cfg(feature = "pjrt")]
    if has_meta {
        return Ok(Box::new(Runtime::new(artifact_dir)?));
    }
    if has_meta {
        return Ok(Box::new(NativeBackend::with_artifacts(Artifacts::open(artifact_dir)?)));
    }
    Ok(Box::new(NativeBackend::builtin()))
}

/// Resolve an explicit backend choice (the CLI `--backend` flag, shared
/// by every command and the serve engine's per-worker builders):
///
/// * `auto` — [`open_backend`] preference order;
/// * `native` — the pure-Rust interpreter (meta-driven when
///   `meta.json` exists, builtin models otherwise);
/// * `pjrt` — the AOT runtime; errors without the `pjrt` feature.
pub fn open_backend_named(backend: &str, artifact_dir: &str) -> Result<Box<dyn Executor>> {
    match backend {
        "auto" => open_backend(artifact_dir),
        "native" => {
            if Path::new(artifact_dir).join("meta.json").exists() {
                Ok(Box::new(NativeBackend::with_artifacts(Artifacts::open(artifact_dir)?)))
            } else {
                Ok(Box::new(NativeBackend::builtin()))
            }
        }
        #[cfg(feature = "pjrt")]
        "pjrt" => Ok(Box::new(Runtime::new(artifact_dir)?)),
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => Err(anyhow!(
            "this binary was built without PJRT; rebuild with `--features pjrt`"
        )),
        other => Err(anyhow!("unknown backend {other:?} (native|pjrt|auto)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Dummy {
        spec: ArtifactMeta,
    }

    impl Executable for Dummy {
        fn name(&self) -> &str {
            "dummy"
        }
        fn spec(&self) -> &ArtifactMeta {
            &self.spec
        }
        fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            check_inputs(self.name(), &self.spec, inputs)?;
            Ok(vec![Tensor::scalar_f32(0.0)])
        }
    }

    fn spec_of(inputs: Vec<(&str, Vec<usize>, &str)>) -> ArtifactMeta {
        ArtifactMeta {
            file: String::new(),
            inputs: inputs
                .into_iter()
                .map(|(n, shape, dt)| TensorSpec {
                    name: n.to_string(),
                    shape,
                    dtype: dt.to_string(),
                })
                .collect(),
            outputs: vec![TensorSpec {
                name: "out".to_string(),
                shape: vec![],
                dtype: "f32".to_string(),
            }],
        }
    }

    #[test]
    fn input_bytes_uses_per_dtype_sizes() {
        // f32 and i32 are both 4 bytes; f64 is 8; f16/bf16 are 2.
        let d = Dummy {
            spec: spec_of(vec![
                ("a", vec![2, 3], "f32"),
                ("b", vec![2, 3], "i32"),
                ("c", vec![5], "f64"),
                ("d", vec![8], "bf16"),
            ]),
        };
        assert_eq!(d.input_bytes(), 6 * 4 + 6 * 4 + 5 * 8 + 8 * 2);
        assert_eq!(d.output_bytes(), 4); // scalar f32
    }

    #[test]
    fn check_inputs_rejects_arity_and_shape() {
        let d = Dummy { spec: spec_of(vec![("a", vec![2, 2], "f32")]) };
        assert!(d.run(&[]).is_err());
        assert!(d.run(&[Tensor::zeros(vec![3, 2])]).is_err());
        assert!(d.run(&[Tensor::zeros(vec![2, 2])]).is_ok());
    }

    #[test]
    fn run_named_pulls_spec_order_and_names_outputs() {
        let d = Dummy {
            spec: spec_of(vec![("a", vec![1], "f32"), ("b", vec![1], "i32")]),
        };
        let mut pool = HashMap::new();
        pool.insert("a".to_string(), Tensor::f32(vec![1], vec![1.0]));
        pool.insert("b".to_string(), Tensor::i32(vec![1], vec![2]));
        let out = d.run_named(&pool).unwrap();
        assert!(out.contains_key("out"));
        pool.remove("b");
        assert!(d.run_named(&pool).is_err());
    }
}
