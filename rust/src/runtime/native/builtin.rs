//! Builtin model set for the native backend, mirroring
//! `python/compile/configs.py` (dims, parameter-matched S²FT budgets) and
//! the layout sections `aot.py` would emit into meta.json — so the rest of
//! the crate sees an identical self-describing contract whether or not
//! artifacts exist on disk.

use std::collections::HashMap;

use crate::runtime::meta::{Meta, MethodMeta, ModelDims, ModelMeta, NamedShape};
use crate::runtime::Tensor;
use crate::sparsity;

/// The native methods: fullft and s2ft (the paper's method). Other
/// baselines (lora/dora/spft/lisa/galore) exist only as AOT artifacts.
pub const NATIVE_METHODS: [&str; 2] = ["fullft", "s2ft"];

/// Builtin meta: tiny/small/base models with fullft + s2ft methods at the
/// default batch shapes.
pub fn builtin_meta() -> Meta {
    let mut models = HashMap::new();
    for (name, d, l, h, ff, seq, b, t) in [
        ("tiny", 64, 2, 4, 176, 32, 2, 32),
        ("small", 256, 4, 8, 704, 64, 8, 64),
        ("base", 512, 6, 8, 1376, 128, 4, 128),
    ] {
        let dims = ModelDims {
            name: name.to_string(),
            d_model: d,
            n_layers: l,
            n_heads: h,
            d_ff: ff,
            vocab: 261,
            seq_len: seq,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        };
        models.insert(name.to_string(), build_model(dims, (b, t)));
    }
    // Artifact specs are synthesized on demand by the backend (see
    // `native::spec_for`), so the artifacts table starts empty.
    Meta { models, artifacts: HashMap::new() }
}

fn build_model(dims: ModelDims, batch: (usize, usize)) -> ModelMeta {
    let base_params = base_shapes(&dims);
    let param_count: usize = base_params.iter().map(NamedShape::numel).sum();
    let mut methods = HashMap::new();
    methods.insert("fullft".to_string(), method_fullft(&base_params));
    methods.insert("s2ft".to_string(), method_s2ft(&dims, &base_params));
    ModelMeta { dims, param_count, methods, batches: vec![batch], base_params }
}

/// Ordered (sorted-name) base parameter layout — python `param_shapes`.
pub fn base_shapes(dims: &ModelDims) -> Vec<NamedShape> {
    let (d, k, v) = (dims.d_model, dims.d_ff, dims.vocab);
    let mut shapes: Vec<NamedShape> = vec![
        named("embed", vec![v, d]),
        named("norm_f", vec![d]),
    ];
    for i in 0..dims.n_layers {
        shapes.push(named(&format!("L{i}.wq"), vec![d, d]));
        shapes.push(named(&format!("L{i}.wk"), vec![d, d]));
        shapes.push(named(&format!("L{i}.wv"), vec![d, d]));
        shapes.push(named(&format!("L{i}.wo"), vec![d, d]));
        shapes.push(named(&format!("L{i}.wu"), vec![d, k]));
        shapes.push(named(&format!("L{i}.wg"), vec![d, k]));
        shapes.push(named(&format!("L{i}.wd"), vec![k, d]));
        shapes.push(named(&format!("L{i}.norm1"), vec![d]));
        shapes.push(named(&format!("L{i}.norm2"), vec![d]));
    }
    shapes.sort_by(|a, b| a.name.cmp(&b.name));
    shapes
}

fn named(name: &str, shape: Vec<usize>) -> NamedShape {
    NamedShape { name: name.to_string(), shape }
}

fn method_fullft(base: &[NamedShape]) -> MethodMeta {
    let trainable: Vec<NamedShape> = base.to_vec();
    MethodMeta {
        method: "fullft".to_string(),
        selection: "r".to_string(),
        select_small: true,
        rank: 0,
        lora_alpha: 0.0,
        lr: 2e-4,
        beta1: 0.9,
        beta2: 0.999,
        eps: 1e-8,
        weight_decay: 0.0,
        s2ft_fractions: HashMap::new(),
        trainable_params: trainable.iter().map(NamedShape::numel).sum(),
        opt: trainable.clone(),
        trainable,
        frozen: vec![],
        perms: vec![],
        aux: vec![],
    }
}

fn method_s2ft(dims: &ModelDims, base: &[NamedShape]) -> MethodMeta {
    // Parameter-matched budget (configs.py): fraction f such that S²FT on
    // (wo, wd) trains about as many params as LoRA rank 16 on (wo, wd).
    let (d, k, r) = (dims.d_model as f64, dims.d_ff as f64, 16.0);
    let lora_params = r * (2.0 * d) + r * (k + d);
    let f = lora_params / (d * d + k * d);
    let mut fractions = HashMap::new();
    fractions.insert("wo".to_string(), f);
    fractions.insert("wd".to_string(), f);

    let counts: HashMap<String, usize> =
        sparsity::budget_to_counts(&fractions, dims.d_ff, dims.n_heads)
            .into_iter()
            .filter(|(_, c)| *c > 0)
            .collect();
    let (trainable, frozen, perms) = s2ft_layout(dims, base, &counts);
    MethodMeta {
        method: "s2ft".to_string(),
        selection: "r".to_string(),
        select_small: true,
        rank: 0,
        lora_alpha: 0.0,
        lr: 1e-3,
        beta1: 0.9,
        beta2: 0.999,
        eps: 1e-8,
        weight_decay: 0.0,
        s2ft_fractions: fractions,
        trainable_params: trainable.iter().map(NamedShape::numel).sum(),
        opt: trainable.clone(),
        trainable,
        frozen,
        perms,
        aux: vec![],
    }
}

/// Projections whose trainable slice is a row block (axis 0); the rest
/// split on columns. Mirrors python `model.ROW_SPLIT`.
pub const ROW_SPLIT: [&str; 2] = ["wo", "wd"];
pub const MHA_PROJS: [&str; 4] = ["wq", "wk", "wv", "wo"];
pub const FFN_PROJS: [&str; 3] = ["wu", "wg", "wd"];

pub fn is_row_split(p: &str) -> bool {
    ROW_SPLIT.contains(&p)
}

pub fn is_mha(p: &str) -> bool {
    MHA_PROJS.contains(&p)
}

/// The s2ft (trainable, frozen, perms) shape sections for a unit-count
/// budget — python `method_layout`, s2ft arm. Uniform across layers.
pub fn s2ft_layout(
    dims: &ModelDims,
    base: &[NamedShape],
    counts: &HashMap<String, usize>,
) -> (Vec<NamedShape>, Vec<NamedShape>, Vec<NamedShape>) {
    let per_layer = vec![counts.clone(); dims.n_layers];
    s2ft_layout_per_layer(dims, base, &per_layer)
}

/// [`s2ft_layout`] with an explicit unit-count budget *per layer* —
/// layers with an empty map stay fully frozen. This is how tests and
/// benches build concentrated selections (e.g. top-layer-only) that
/// exercise the truncated backward walk; `aot.py` only ever emits the
/// uniform layout.
pub fn s2ft_layout_per_layer(
    dims: &ModelDims,
    base: &[NamedShape],
    counts_per_layer: &[HashMap<String, usize>],
) -> (Vec<NamedShape>, Vec<NamedShape>, Vec<NamedShape>) {
    let hd = dims.d_model / dims.n_heads;
    let base_shape = |name: &str| -> Vec<usize> {
        base.iter().find(|s| s.name == name).map(|s| s.shape.clone()).unwrap_or_default()
    };
    let mut trn: Vec<NamedShape> = Vec::new();
    let mut frz: Vec<NamedShape> = base.to_vec();
    let mut perms: Vec<NamedShape> = Vec::new();
    for (i, counts) in counts_per_layer.iter().enumerate().take(dims.n_layers) {
        let has_mha = counts.iter().any(|(p, &c)| c > 0 && is_mha(p));
        let has_ffn = counts.iter().any(|(p, &c)| c > 0 && !is_mha(p));
        for (p, &c) in counts {
            if c == 0 {
                continue;
            }
            let name = format!("L{i}.{p}");
            let shape = base_shape(&name);
            let (din, dout) = (shape[0], shape[1]);
            let rows = if is_mha(p) { c * hd } else { c };
            frz.retain(|s| s.name != name);
            if is_row_split(p) {
                trn.push(named(&format!("{name}_t"), vec![rows, dout]));
                frz.push(named(&format!("{name}_f"), vec![din - rows, dout]));
            } else {
                trn.push(named(&format!("{name}_t"), vec![din, rows]));
                frz.push(named(&format!("{name}_f"), vec![din, dout - rows]));
            }
        }
        if has_mha {
            perms.push(named(&format!("L{i}.head_perm"), vec![dims.n_heads]));
        }
        if has_ffn {
            perms.push(named(&format!("L{i}.chan_perm"), vec![dims.d_ff]));
        }
    }
    trn.sort_by(|a, b| a.name.cmp(&b.name));
    frz.sort_by(|a, b| a.name.cmp(&b.name));
    perms.sort_by(|a, b| a.name.cmp(&b.name));
    (trn, frz, perms)
}

/// A method-layout *variant* of an s2ft method: identical hyperparameters
/// and selection semantics, but an explicit per-layer unit-count budget —
/// the layout a dynamic selection strategy commits mid-run. The trainer
/// registers the result under a per-plan-epoch tag (via
/// `Executor::load_train_variant`) whenever a replan changes the
/// trainable shapes.
pub fn s2ft_method_variant(
    mm: &ModelMeta,
    base_meth: &MethodMeta,
    counts_per_layer: &[HashMap<String, usize>],
) -> MethodMeta {
    let (trainable, frozen, perms) =
        s2ft_layout_per_layer(&mm.dims, &mm.base_params, counts_per_layer);
    let mut meth = base_meth.clone();
    meth.trainable_params = trainable.iter().map(NamedShape::numel).sum();
    meth.opt = trainable.clone();
    meth.trainable = trainable;
    meth.frozen = frozen;
    meth.perms = perms;
    meth
}

/// Split base-layout weights at the *identity* selection (`_t` = the
/// leading rows/columns of each trainable tensor's base weight) for a
/// hand-built layout, and zero the optimizer moments — the
/// executable-level pool that tests and benches drive a `train_M_m_BxT`
/// executable with, bypassing `prepare` (which would also permute).
///
/// Panics on a malformed layout (trainable name without a base tensor);
/// this is test/bench support, not a production path.
pub fn identity_split_pool(
    base: &HashMap<String, Tensor>,
    meth: &MethodMeta,
) -> HashMap<String, Tensor> {
    let mut pool = base.clone();
    for s in &meth.trainable {
        let name = s.name.strip_suffix("_t").expect("trainable name ends in _t");
        let proj = name.rsplit('.').next().unwrap_or("");
        let w = pool.remove(name).expect("base tensor for split");
        let (din, dout) = (w.shape[0], w.shape[1]);
        let wv = w.as_f32().expect("f32 weight");
        if is_row_split(proj) {
            let rows = s.shape[0];
            pool.insert(
                format!("{name}_t"),
                Tensor::f32(vec![rows, dout], wv[..rows * dout].to_vec()),
            );
            pool.insert(
                format!("{name}_f"),
                Tensor::f32(vec![din - rows, dout], wv[rows * dout..].to_vec()),
            );
        } else {
            let cols = s.shape[1];
            let (mut tv, mut fv) = (Vec::new(), Vec::new());
            for r in 0..din {
                tv.extend_from_slice(&wv[r * dout..r * dout + cols]);
                fv.extend_from_slice(&wv[r * dout + cols..(r + 1) * dout]);
            }
            pool.insert(format!("{name}_t"), Tensor::f32(vec![din, cols], tv));
            pool.insert(format!("{name}_f"), Tensor::f32(vec![din, dout - cols], fv));
        }
    }
    for o in &meth.opt {
        pool.insert(format!("m.{}", o.name), Tensor::zeros(o.shape.clone()));
        pool.insert(format!("v.{}", o.name), Tensor::zeros(o.shape.clone()));
    }
    pool
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_models_well_formed() {
        let meta = builtin_meta();
        for name in ["tiny", "small", "base"] {
            let mm = &meta.models[name];
            assert_eq!(mm.dims.d_model % mm.dims.n_heads, 0, "{name}");
            assert_eq!(
                mm.param_count,
                mm.base_params.iter().map(NamedShape::numel).sum::<usize>()
            );
            for tag in NATIVE_METHODS {
                let m = &mm.methods[tag];
                assert!(m.trainable_params > 0, "{name}/{tag}");
                assert_eq!(m.opt.len(), m.trainable.len());
            }
        }
    }

    #[test]
    fn s2ft_budget_is_parameter_matched() {
        let meta = builtin_meta();
        let mm = &meta.models["small"];
        let s2ft = &mm.methods["s2ft"];
        let lora_params = {
            let (d, k, r) = (mm.dims.d_model, mm.dims.d_ff, 16);
            mm.dims.n_layers * (r * 2 * d + r * (k + d))
        };
        let ratio = s2ft.trainable_params as f64 / lora_params as f64;
        assert!((0.5..2.0).contains(&ratio), "budget mismatch: {ratio}");
        // trainable + frozen partitions the wo/wd projections exactly
        let d = mm.dims.d_model;
        for i in 0..mm.dims.n_layers {
            let t = s2ft
                .trainable
                .iter()
                .find(|s| s.name == format!("L{i}.wo_t"))
                .unwrap();
            let f = s2ft
                .frozen
                .iter()
                .find(|s| s.name == format!("L{i}.wo_f"))
                .unwrap();
            assert_eq!(t.shape[0] + f.shape[0], d);
            assert_eq!(t.shape[0] % mm.head_dim(), 0, "wo split must be head-aligned");
        }
    }
}
