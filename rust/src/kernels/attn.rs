//! Causal-attention kernels (scores → softmax → weighted values, and the
//! exact backward), shared by the native model interpreter.
//!
//! Layout matches `python/compile/model.py`: activations are `(b·t, d)`
//! row-major with head `h` occupying column block `h·hd..(h+1)·hd`, and
//! probabilities are `(b, heads, t, t)`. Parallelism partitions the
//! *batch* axis — every output buffer is contiguous per batch element, so
//! worker chunks are disjoint slices and the per-element accumulation
//! order never depends on the thread count (bit-identical results).

use super::{configured_threads, MIN_PAR_WORK};

/// Attention problem shape; `d_model = heads * hd`.
#[derive(Debug, Clone, Copy)]
pub struct AttnDims {
    pub b: usize,
    pub t: usize,
    pub heads: usize,
    pub hd: usize,
}

impl AttnDims {
    fn d(&self) -> usize {
        self.heads * self.hd
    }

    /// Multiply-add estimate for the parallel/serial decision.
    fn work(&self) -> usize {
        2 * self.b * self.heads * self.t * self.t * self.hd
    }
}

/// Forward causal attention over rotated Q/K and V, each `(b·t, d)`.
/// Returns `(probs (b,heads,t,t), attn (b·t, d))` — attn is the
/// concatenated head outputs, pre-`wo`.
pub fn causal_attn_fwd(
    qr: &[f32],
    kr: &[f32],
    v: &[f32],
    dims: &AttnDims,
    scale: f32,
) -> (Vec<f32>, Vec<f32>) {
    causal_attn_fwd_with_threads(qr, kr, v, dims, scale, configured_threads())
}

/// [`causal_attn_fwd`] on an explicit worker count.
pub fn causal_attn_fwd_with_threads(
    qr: &[f32],
    kr: &[f32],
    v: &[f32],
    dims: &AttnDims,
    scale: f32,
    threads: usize,
) -> (Vec<f32>, Vec<f32>) {
    let (b, t) = (dims.b, dims.t);
    let (p_unit, a_unit) = (dims.heads * t * t, t * dims.d());
    let mut probs = vec![0.0f32; b * p_unit];
    let mut attn = vec![0.0f32; b * a_unit];
    let nt = threads.min(b.max(1));
    if nt <= 1 || dims.work() < MIN_PAR_WORK {
        fwd_block(qr, kr, v, dims, scale, 0, &mut probs, &mut attn);
    } else {
        let per = b.div_ceil(nt);
        std::thread::scope(|s| {
            let chunks = probs.chunks_mut(per * p_unit).zip(attn.chunks_mut(per * a_unit));
            for (ci, (pc, ac)) in chunks.enumerate() {
                s.spawn(move || fwd_block(qr, kr, v, dims, scale, ci * per, pc, ac));
            }
        });
    }
    (probs, attn)
}

/// Forward for batches `[b0, b0 + probs.len()/p_unit)`; `probs`/`attn`
/// are the local output slices for exactly those batches.
#[allow(clippy::too_many_arguments)]
fn fwd_block(
    qr: &[f32],
    kr: &[f32],
    v: &[f32],
    dims: &AttnDims,
    scale: f32,
    b0: usize,
    probs: &mut [f32],
    attn: &mut [f32],
) {
    let (t, heads, hd, d) = (dims.t, dims.heads, dims.hd, dims.d());
    let nb = probs.len() / (heads * t * t);
    for lb in 0..nb {
        let bi = b0 + lb;
        for hh in 0..heads {
            for tq in 0..t {
                let qoff = (bi * t + tq) * d + hh * hd;
                let prow = &mut probs[((lb * heads + hh) * t + tq) * t..][..t];
                let mut maxv = f32::NEG_INFINITY;
                for (tk, p) in prow.iter_mut().enumerate().take(tq + 1) {
                    let koff = (bi * t + tk) * d + hh * hd;
                    let mut s = 0.0f32;
                    for j in 0..hd {
                        s += qr[qoff + j] * kr[koff + j];
                    }
                    let s = s * scale;
                    *p = s;
                    if s > maxv {
                        maxv = s;
                    }
                }
                let mut denom = 0.0f32;
                for p in prow.iter_mut().take(tq + 1) {
                    *p = (*p - maxv).exp();
                    denom += *p;
                }
                for p in prow.iter_mut().take(tq + 1) {
                    *p /= denom;
                }
                // no zero-probability skip: every term reaches the
                // accumulator so the row stays bit-identical to the
                // unskipped reduction (and mirrors `attn_decode` exactly)
                let aoff = (lb * t + tq) * d + hh * hd;
                for (tk, &p) in prow.iter().enumerate().take(tq + 1) {
                    let voff = (bi * t + tk) * d + hh * hd;
                    let arow = &mut attn[aoff..aoff + hd];
                    for (o, &vv) in arow.iter_mut().zip(&v[voff..voff + hd]) {
                        *o += p * vv;
                    }
                }
            }
        }
    }
}

/// Backward of [`causal_attn_fwd`]: given the cached probabilities and
/// `da = d(loss)/d(attn)`, produce `(dqr, dkr, dv)` (pre-RoPE-inverse).
pub fn causal_attn_bwd(
    probs: &[f32],
    qr: &[f32],
    kr: &[f32],
    v: &[f32],
    da: &[f32],
    dims: &AttnDims,
    scale: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    causal_attn_bwd_with_threads(probs, qr, kr, v, da, dims, scale, configured_threads())
}

/// [`causal_attn_bwd`] on an explicit worker count.
#[allow(clippy::too_many_arguments)]
pub fn causal_attn_bwd_with_threads(
    probs: &[f32],
    qr: &[f32],
    kr: &[f32],
    v: &[f32],
    da: &[f32],
    dims: &AttnDims,
    scale: f32,
    threads: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (b, t) = (dims.b, dims.t);
    let unit = t * dims.d();
    let mut dqr = vec![0.0f32; b * unit];
    let mut dkr = vec![0.0f32; b * unit];
    let mut dv = vec![0.0f32; b * unit];
    let nt = threads.min(b.max(1));
    if nt <= 1 || dims.work() < MIN_PAR_WORK {
        bwd_block(probs, qr, kr, v, da, dims, scale, 0, &mut dqr, &mut dkr, &mut dv);
    } else {
        let per = b.div_ceil(nt);
        std::thread::scope(|s| {
            let chunks = dqr
                .chunks_mut(per * unit)
                .zip(dkr.chunks_mut(per * unit).zip(dv.chunks_mut(per * unit)));
            for (ci, (qc, (kc, vc))) in chunks.enumerate() {
                s.spawn(move || {
                    bwd_block(probs, qr, kr, v, da, dims, scale, ci * per, qc, kc, vc);
                });
            }
        });
    }
    (dqr, dkr, dv)
}

/// Backward for batches `[b0, b0 + dqr.len()/unit)`; the three gradient
/// slices are local to exactly those batches.
#[allow(clippy::too_many_arguments)]
fn bwd_block(
    probs: &[f32],
    qr: &[f32],
    kr: &[f32],
    v: &[f32],
    da: &[f32],
    dims: &AttnDims,
    scale: f32,
    b0: usize,
    dqr: &mut [f32],
    dkr: &mut [f32],
    dv: &mut [f32],
) {
    let (t, heads, hd, d) = (dims.t, dims.heads, dims.hd, dims.d());
    let nb = dqr.len() / (t * d);
    for lb in 0..nb {
        let bi = b0 + lb;
        for hh in 0..heads {
            for tq in 0..t {
                let prow = &probs[((bi * heads + hh) * t + tq) * t..][..t];
                let doff = (bi * t + tq) * d + hh * hd;
                let ldoff = (lb * t + tq) * d + hh * hd;
                let mut dpro = vec![0.0f32; tq + 1];
                for (tk, dp) in dpro.iter_mut().enumerate() {
                    let voff = (bi * t + tk) * d + hh * hd;
                    let lvoff = (lb * t + tk) * d + hh * hd;
                    let mut s = 0.0f32;
                    for j in 0..hd {
                        s += da[doff + j] * v[voff + j];
                    }
                    *dp = s;
                    // unguarded: zero probabilities still contribute
                    // their (possibly signed-zero / NaN) products
                    let p = prow[tk];
                    let dvrow = &mut dv[lvoff..lvoff + hd];
                    for (o, &g) in dvrow.iter_mut().zip(&da[doff..doff + hd]) {
                        *o += p * g;
                    }
                }
                let dot: f32 = dpro.iter().zip(prow).map(|(dp, p)| dp * p).sum();
                for (tk, dp) in dpro.iter().enumerate() {
                    let ds = prow[tk] * (dp - dot) * scale;
                    let koff = (bi * t + tk) * d + hh * hd;
                    let lkoff = (lb * t + tk) * d + hh * hd;
                    // split accumulations: each element's own chain still
                    // walks tk ascending, so per-element order is intact
                    let qrow = &mut dqr[ldoff..ldoff + hd];
                    for (o, &kv) in qrow.iter_mut().zip(&kr[koff..koff + hd]) {
                        *o += ds * kv;
                    }
                    let krow = &mut dkr[lkoff..lkoff + hd];
                    for (o, &qv) in krow.iter_mut().zip(&qr[doff..doff + hd]) {
                        *o += ds * qv;
                    }
                }
            }
        }
    }
}

/// Single-query causal attention over a KV cache — the incremental-decode
/// counterpart of [`causal_attn_fwd`].
///
/// `q` holds one rotated query row per active request, `(m, d)` with the
/// usual head-blocked columns. `k_cache`/`v_cache` are `(cache_rows,
/// t_max, d)` ring-free caches; query `j` lives in cache row `rows[j]`
/// and sits at position `pos[j]`, with positions `0..=pos[j]` already
/// appended (including the current token). Returns the attended outputs
/// `(m, d)`.
///
/// Accumulation order per output element — score loop, running max,
/// exp/denominator pass, normalization, unskipped weighted-value sum —
/// exactly mirrors the `tq`-th query row of [`causal_attn_fwd`], so
/// greedy decode through this kernel is bit-identical to full-sequence
/// recompute. The value loops are plain elementwise zip chains, which
/// LLVM autovectorizes without reordering any per-element reduction.
#[allow(clippy::too_many_arguments)]
pub fn attn_decode(
    q: &[f32],
    k_cache: &[f32],
    v_cache: &[f32],
    rows: &[usize],
    pos: &[usize],
    heads: usize,
    hd: usize,
    t_max: usize,
    scale: f32,
) -> Vec<f32> {
    let d = heads * hd;
    let m = rows.len();
    debug_assert_eq!(q.len(), m * d);
    debug_assert_eq!(pos.len(), m);
    let mut out = vec![0.0f32; m * d];
    let work: usize = pos.iter().map(|&p| 2 * (p + 1) * d).sum();
    super::for_each_row_chunk(&mut out, d, configured_threads(), work, |row0, chunk| {
        for (lj, orow) in chunk.chunks_mut(d).enumerate() {
            let j = row0 + lj;
            let (bi, p) = (rows[j], pos[j]);
            let cbase = bi * t_max * d;
            // one score buffer per row, reused across heads (every entry
            // is rewritten by the score loop before it is read)
            let mut prow = vec![0.0f32; p + 1];
            for hh in 0..heads {
                let qh = &q[j * d + hh * hd..][..hd];
                let mut maxv = f32::NEG_INFINITY;
                for (tk, pr) in prow.iter_mut().enumerate() {
                    let kh = &k_cache[cbase + tk * d + hh * hd..][..hd];
                    let mut s = 0.0f32;
                    for (x, y) in qh.iter().zip(kh) {
                        s += x * y;
                    }
                    let s = s * scale;
                    *pr = s;
                    if s > maxv {
                        maxv = s;
                    }
                }
                let mut denom = 0.0f32;
                for pr in prow.iter_mut() {
                    *pr = (*pr - maxv).exp();
                    denom += *pr;
                }
                for pr in prow.iter_mut() {
                    *pr /= denom;
                }
                let oh = &mut orow[hh * hd..hh * hd + hd];
                for (tk, &pr) in prow.iter().enumerate() {
                    let vh = &v_cache[cbase + tk * d + hh * hd..][..hd];
                    for (o, &vv) in oh.iter_mut().zip(vh) {
                        *o += pr * vv;
                    }
                }
            }
        }
    });
    out
}

/// [`attn_decode`] over a block-paged KV pool instead of contiguous
/// per-row caches.
///
/// `k_pool`/`v_pool` are one layer's `(blocks · block_tokens, d)` slabs
/// from the serve KV pool; query `j`'s logical position `tk` lives at
/// physical row `tables[j][tk / block_tokens] · block_tokens +
/// tk % block_tokens`. `tables[j]` must cover positions `0..=pos[j]`.
///
/// Everything except that address translation — loop structure, score /
/// max / exp / normalize / weighted-value order — is byte-for-byte the
/// contiguous kernel, so paged decode stays bit-identical to the
/// contiguous session (asserted by `paged_matches_contiguous_bitwise`
/// and the generation proptests).
#[allow(clippy::too_many_arguments)]
pub fn attn_decode_paged(
    q: &[f32],
    k_pool: &[f32],
    v_pool: &[f32],
    tables: &[&[u32]],
    pos: &[usize],
    heads: usize,
    hd: usize,
    block_tokens: usize,
    scale: f32,
) -> Vec<f32> {
    let d = heads * hd;
    let m = tables.len();
    debug_assert_eq!(q.len(), m * d);
    debug_assert_eq!(pos.len(), m);
    let mut out = vec![0.0f32; m * d];
    let work: usize = pos.iter().map(|&p| 2 * (p + 1) * d).sum();
    super::for_each_row_chunk(&mut out, d, configured_threads(), work, |row0, chunk| {
        for (lj, orow) in chunk.chunks_mut(d).enumerate() {
            let j = row0 + lj;
            let (table, p) = (tables[j], pos[j]);
            debug_assert!(table.len() * block_tokens > p, "block table short of pos");
            // one score buffer per row, reused across heads (every entry
            // is rewritten by the score loop before it is read)
            let mut prow = vec![0.0f32; p + 1];
            for hh in 0..heads {
                let qh = &q[j * d + hh * hd..][..hd];
                let mut maxv = f32::NEG_INFINITY;
                for (tk, pr) in prow.iter_mut().enumerate() {
                    let phys =
                        table[tk / block_tokens] as usize * block_tokens + tk % block_tokens;
                    let kh = &k_pool[phys * d + hh * hd..][..hd];
                    let mut s = 0.0f32;
                    for (x, y) in qh.iter().zip(kh) {
                        s += x * y;
                    }
                    let s = s * scale;
                    *pr = s;
                    if s > maxv {
                        maxv = s;
                    }
                }
                let mut denom = 0.0f32;
                for pr in prow.iter_mut() {
                    *pr = (*pr - maxv).exp();
                    denom += *pr;
                }
                for pr in prow.iter_mut() {
                    *pr /= denom;
                }
                let oh = &mut orow[hh * hd..hh * hd + hd];
                for (tk, &pr) in prow.iter().enumerate() {
                    let phys =
                        table[tk / block_tokens] as usize * block_tokens + tk % block_tokens;
                    let vh = &v_pool[phys * d + hh * hd..][..hd];
                    for (o, &vv) in oh.iter_mut().zip(vh) {
                        *o += pr * vv;
                    }
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(dims: &AttnDims, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let n = dims.b * dims.t * dims.d();
        let mk = |rng: &mut Rng| (0..n).map(|_| rng.normal_f32()).collect::<Vec<f32>>();
        (mk(&mut rng), mk(&mut rng), mk(&mut rng))
    }

    #[test]
    fn fwd_probs_are_causal_softmax_rows() {
        let dims = AttnDims { b: 2, t: 6, heads: 2, hd: 4 };
        let (qr, kr, v) = setup(&dims, 1);
        let scale = 1.0 / (dims.hd as f32).sqrt();
        let (probs, attn) = causal_attn_fwd_with_threads(&qr, &kr, &v, &dims, scale, 1);
        assert_eq!(attn.len(), dims.b * dims.t * dims.d());
        for bi in 0..dims.b {
            for hh in 0..dims.heads {
                for tq in 0..dims.t {
                    let row = &probs[((bi * dims.heads + hh) * dims.t + tq) * dims.t..][..dims.t];
                    let sum: f32 = row[..=tq].iter().sum();
                    assert!((sum - 1.0).abs() < 1e-5, "row sums to {sum}");
                    for &p in &row[tq + 1..] {
                        assert_eq!(p, 0.0, "future position attended");
                    }
                }
            }
        }
    }

    /// Every query position computed through the single-query decode
    /// kernel must reproduce the corresponding row of the full forward
    /// bit-for-bit (the KV-cache decode correctness contract).
    #[test]
    fn decode_matches_full_forward_bitwise() {
        let dims = AttnDims { b: 3, t: 7, heads: 2, hd: 4 };
        let (qr, kr, v) = setup(&dims, 9);
        let scale = 1.0 / (dims.hd as f32).sqrt();
        let d = dims.d();
        let (_, attn) = causal_attn_fwd_with_threads(&qr, &kr, &v, &dims, scale, 1);
        // caches in (b, t_max, d) layout == the (b·t, d) activation layout
        for tq in 0..dims.t {
            let rows: Vec<usize> = (0..dims.b).collect();
            let pos = vec![tq; dims.b];
            let q: Vec<f32> = (0..dims.b)
                .flat_map(|bi| qr[(bi * dims.t + tq) * d..][..d].to_vec())
                .collect();
            let out = attn_decode(&q, &kr, &v, &rows, &pos, dims.heads, dims.hd, dims.t, scale);
            for bi in 0..dims.b {
                let want = &attn[(bi * dims.t + tq) * d..][..d];
                let got = &out[bi * d..][..d];
                assert!(
                    want.iter().zip(got).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "decode mismatch at b={bi} tq={tq}"
                );
            }
        }
    }

    /// Paged decode over a shuffled physical block layout must reproduce
    /// the contiguous decode kernel bit-for-bit: the block table is pure
    /// address translation, never arithmetic.
    #[test]
    fn paged_matches_contiguous_bitwise() {
        let dims = AttnDims { b: 3, t: 7, heads: 2, hd: 4 };
        let (qr, kr, v) = setup(&dims, 11);
        let scale = 1.0 / (dims.hd as f32).sqrt();
        let d = dims.d();
        for bt in [1usize, 2, 3, 7] {
            // scatter each row's cache into non-contiguous, interleaved
            // blocks: row bi's logical block g lives at physical block
            // (g * b + bi) — a worst-case fragmented layout
            let blocks_per_row = dims.t.div_ceil(bt);
            let nblocks = blocks_per_row * dims.b;
            let mut kp = vec![0.0f32; nblocks * bt * d];
            let mut vp = vec![0.0f32; nblocks * bt * d];
            let tables: Vec<Vec<u32>> = (0..dims.b)
                .map(|bi| (0..blocks_per_row).map(|g| (g * dims.b + bi) as u32).collect())
                .collect();
            for bi in 0..dims.b {
                for tk in 0..dims.t {
                    let phys = tables[bi][tk / bt] as usize * bt + tk % bt;
                    let src = (bi * dims.t + tk) * d;
                    kp[phys * d..phys * d + d].copy_from_slice(&kr[src..src + d]);
                    vp[phys * d..phys * d + d].copy_from_slice(&v[src..src + d]);
                }
            }
            for tq in 0..dims.t {
                let rows: Vec<usize> = (0..dims.b).collect();
                let pos = vec![tq; dims.b];
                let q: Vec<f32> = (0..dims.b)
                    .flat_map(|bi| qr[(bi * dims.t + tq) * d..][..d].to_vec())
                    .collect();
                let want =
                    attn_decode(&q, &kr, &v, &rows, &pos, dims.heads, dims.hd, dims.t, scale);
                let trefs: Vec<&[u32]> = tables.iter().map(|t| t.as_slice()).collect();
                let got = attn_decode_paged(
                    &q, &kp, &vp, &trefs, &pos, dims.heads, dims.hd, bt, scale,
                );
                assert!(
                    want.iter().zip(&got).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "paged decode drifted at bt={bt} tq={tq}"
                );
            }
        }
    }

    #[test]
    fn fwd_and_bwd_are_bit_identical_across_threads() {
        // large enough to cross MIN_PAR_WORK: 2*4*4*24*24*8 = 147456
        let dims = AttnDims { b: 4, t: 24, heads: 4, hd: 8 };
        let (qr, kr, v) = setup(&dims, 2);
        let scale = 1.0 / (dims.hd as f32).sqrt();
        let mut rng = Rng::seed(3);
        let da: Vec<f32> = (0..dims.b * dims.t * dims.d()).map(|_| rng.normal_f32()).collect();
        let (p1, a1) = causal_attn_fwd_with_threads(&qr, &kr, &v, &dims, scale, 1);
        let bwd1 = causal_attn_bwd_with_threads(&p1, &qr, &kr, &v, &da, &dims, scale, 1);
        for t in [2usize, 3, 4, 7] {
            let (pt, at) = causal_attn_fwd_with_threads(&qr, &kr, &v, &dims, scale, t);
            assert!(p1.iter().zip(&pt).all(|(x, y)| x.to_bits() == y.to_bits()), "probs t={t}");
            assert!(a1.iter().zip(&at).all(|(x, y)| x.to_bits() == y.to_bits()), "attn t={t}");
            let bwdt = causal_attn_bwd_with_threads(&p1, &qr, &kr, &v, &da, &dims, scale, t);
            for (one, many) in [(&bwd1.0, &bwdt.0), (&bwd1.1, &bwdt.1), (&bwd1.2, &bwdt.2)] {
                assert!(one.iter().zip(many.iter()).all(|(x, y)| x.to_bits() == y.to_bits()));
            }
        }
    }
}
