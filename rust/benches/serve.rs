//! Serving benches: KV-cached decode vs full recompute, and engine-pool
//! closed-loop burst throughput at 1/2/4 workers (the multi-worker
//! scaling datum the baseline gate tracks). Open-loop Poisson load with
//! KV-pool churn lives in `serve_load.rs`.
//!
//! `S2FT_BENCH_BUDGET_MS` shortens the wall budget (CI smoke);
//! `make bench-baseline` regenerates the committed regression baseline
//! from this target's JSON.

use std::collections::HashMap;
use std::time::Duration;

use repro::runtime::{Executable, Executor, NativeBackend, Tensor};
use repro::serve::{synthetic_adapter, Engine, EngineConfig, GenRequest};
use repro::train::{DecodeRequest, GenModel};
use repro::util::bench::{black_box, BenchSuite};
use repro::util::rng::Rng;

fn tiny_params(rt: &NativeBackend) -> HashMap<String, Tensor> {
    let init = rt.load("init_tiny").unwrap();
    let outs = init.run(&[Tensor::scalar_i32(5)]).unwrap();
    init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect()
}

fn spawn_engine(workers: usize, n_adapters: usize) -> Engine {
    let cfg = EngineConfig::new()
        .workers(workers)
        .max_batch(2)
        .window(Duration::from_millis(1));
    let engine = Engine::spawn(cfg, |_wid| {
        let rt = NativeBackend::builtin();
        let params = tiny_params(&rt);
        let snapshot = params.clone();
        let gm = GenModel::new(&rt, "tiny", params)?;
        Ok((gm, snapshot))
    });
    let rt = NativeBackend::builtin();
    let mm = rt.artifacts().model("tiny").unwrap().clone();
    let mut rng = Rng::seed(0xBE);
    for a in 0..n_adapters {
        engine.register(format!("a{a}"), synthetic_adapter(&mm, &mut rng));
    }
    engine
}

fn main() {
    let mut suite = BenchSuite::new("serve");
    println!(
        "serving benches (available parallelism {})\n",
        std::thread::available_parallelism().map_or(1, |n| n.get())
    );

    // --- decode hot path: O(t) cached step vs O(t²) full recompute ------
    let rt = NativeBackend::builtin();
    let gm = GenModel::new(&rt, "tiny", tiny_params(&rt)).unwrap();
    let reqs: Vec<DecodeRequest> = (0..4)
        .map(|i| DecodeRequest::greedy(format!("q: is item {i} blue and big?"), 16))
        .collect();
    suite.bench("decode/tiny/kv_cached_16tok", || {
        black_box(gm.generate_stream(&reqs, |_, _| {}).unwrap());
    });
    suite.bench("decode/tiny/full_recompute_16tok", || {
        black_box(gm.generate_full_recompute(&reqs, |_, _| {}).unwrap());
    });

    // --- engine pool: a 32-request burst across 4 adapters, served by
    // --- continuous batching (or legacy waves on decoder-less backends)
    for workers in [1usize, 2, 4] {
        let engine = spawn_engine(workers, 4);
        suite.bench(&format!("engine/tiny/burst32/workers={workers}"), || {
            let streams: Vec<_> = (0..32)
                .map(|i| {
                    engine.submit(
                        GenRequest::new(format!("a{}", i % 4), format!("q: item {i}?")).max_new(4),
                    )
                })
                .collect();
            for s in streams {
                s.wait().expect("reply");
            }
        });
        let m = engine.metrics();
        println!(
            "  workers={workers}: {} batches (mean size {:.1}), {} switches, {} tokens",
            m.batches,
            m.mean_batch_size(),
            m.switches,
            m.tokens
        );
        engine.shutdown().unwrap();
    }

    suite.save();
}
