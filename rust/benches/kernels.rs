//! Micro-benchmarks for the shared GEMM kernel subsystem.
//!
//! Covers each of the four GEMM shapes at the builtin tiny/small/base
//! model dimensions, single- vs multi-threaded (the acceptance shape:
//! `gemm/base` at 4 workers vs 1), the naive triple-loop reference as
//! the "before" datum, and the partial-backprop `lim` sweep showing the
//! paper's partial-gradient saving (§3.3): dW cost scales with the
//! trainable slice, not the full layer.
//!
//! Also covered: the SIMD/scalar dispatch boundary (`*/scalar` lanes pin
//! the portable tile via `*_with_dispatch`; setting `S2FT_SIMD=0` forces
//! it for the whole run, as the CI scalar matrix lane does) and the
//! KV-cached `attn_decode` hot path at base dims.
//!
//! `S2FT_BENCH_BUDGET_MS` shortens the wall budget (CI smoke);
//! `make bench-baseline` regenerates the committed regression baseline
//! from this target's JSON.

use repro::kernels::{attn_decode, gemm_nt_with_dispatch, gemm_nt_with_threads};
use repro::kernels::{gemm_tn_outcols_with_threads, gemm_tn_with_threads, gemm_with_dispatch};
use repro::kernels::{gemm_with_threads, reference, simd_enabled};
use repro::util::bench::{black_box, BenchSuite};
use repro::util::rng::Rng;

const PAR_THREADS: usize = 4;

fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32()).collect()
}

fn main() {
    let mut suite = BenchSuite::new("kernels");
    println!(
        "kernel micro-benches: threads 1 vs {PAR_THREADS} (available parallelism {}), \
         simd dispatch {}\n",
        std::thread::available_parallelism().map_or(1, |n| n.get()),
        if simd_enabled() { "on" } else { "off (scalar tile)" }
    );

    // (m, k, n) = (b·t, d_model, d_model) per builtin model — the
    // attention-projection GEMM shape that dominates the forward pass.
    for (name, m, k, n) in [
        ("tiny", 64usize, 64usize, 64usize),
        ("small", 512, 256, 256),
        ("base", 512, 512, 512),
    ] {
        let mut rng = Rng::seed(k as u64);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let bt = randv(&mut rng, n * k);
        let g = randv(&mut rng, m * k); // upstream gradient, (m, k)

        if name != "base" {
            // the naive "before" datum is too slow to repeat at base dims
            suite.bench(&format!("gemm_naive/{name}"), || {
                black_box(reference::gemm(&a, &b, m, k, n));
            });
        }
        suite.bench(&format!("gemm/{name}/threads=1"), || {
            black_box(gemm_with_threads(&a, &b, m, k, n, 1));
        });
        if name == "base" {
            // the dispatch boundary, pinned per call: the portable tile's
            // cost relative to the std::arch path (results are
            // bit-identical either way — only time may differ)
            suite.bench(&format!("gemm/{name}/threads=1/scalar"), || {
                black_box(gemm_with_dispatch(&a, &b, m, k, n, 1, false));
            });
            suite.bench(&format!("gemm_nt/{name}/threads=1/scalar"), || {
                black_box(gemm_nt_with_dispatch(&a, &bt, m, k, n, 1, false));
            });
        }
        suite.bench(&format!("gemm/{name}/threads={PAR_THREADS}"), || {
            black_box(gemm_with_threads(&a, &b, m, k, n, PAR_THREADS));
        });
        suite.bench(&format!("gemm_nt/{name}/threads=1"), || {
            black_box(gemm_nt_with_threads(&a, &bt, m, k, n, 1));
        });
        suite.bench(&format!("gemm_nt/{name}/threads={PAR_THREADS}"), || {
            black_box(gemm_nt_with_threads(&a, &bt, m, k, n, PAR_THREADS));
        });
        // full-width dW gradients (rows = m tokens, both operands (m, k))
        suite.bench(&format!("gemm_tn/{name}/threads=1"), || {
            black_box(gemm_tn_with_threads(&a, &g, m, k, k, k, 1));
        });
        suite.bench(&format!("gemm_tn/{name}/threads={PAR_THREADS}"), || {
            black_box(gemm_tn_with_threads(&a, &g, m, k, k, k, PAR_THREADS));
        });
        suite.bench(&format!("gemm_tn_outcols/{name}/threads=1"), || {
            black_box(gemm_tn_outcols_with_threads(&a, &g, m, k, k, k, 1));
        });
        suite.bench(&format!("gemm_tn_outcols/{name}/threads={PAR_THREADS}"), || {
            black_box(gemm_tn_outcols_with_threads(&a, &g, m, k, k, k, PAR_THREADS));
        });
    }

    // Partial-backprop sweep at the base FFN down-projection (wd): the
    // dW GEMM is (b·t=512, d_ff=1376)ᵀ-sliced @ (512, d=512). S²FT only
    // materializes `lim` trainable channel rows — cost is linear in lim.
    {
        let (rows, ka, kb) = (512usize, 1376usize, 512usize);
        let mut rng = Rng::seed(0x57EE);
        let act = randv(&mut rng, rows * ka);
        let dy = randv(&mut rng, rows * kb);
        for lim in [ka, ka / 4, ka / 16, ka / 64] {
            suite.bench(&format!("gemm_tn_partial/base_ffn/lim={lim}"), || {
                black_box(gemm_tn_with_threads(&act, &dy, rows, ka, kb, lim, 1));
            });
        }
    }

    // KV-cached decode attention at base-model dims: 16 active requests,
    // every cache at the last position of a 512-token window.
    {
        let (heads, hd, t_max, m) = (8usize, 64usize, 512usize, 16usize);
        let d = heads * hd;
        let mut rng = Rng::seed(0xDEC0);
        let q = randv(&mut rng, m * d);
        let k_cache = randv(&mut rng, m * t_max * d);
        let v_cache = randv(&mut rng, m * t_max * d);
        let rows: Vec<usize> = (0..m).collect();
        let pos = vec![t_max - 1; m];
        let scale = 1.0 / (hd as f32).sqrt();
        suite.bench("attn_decode/base", || {
            black_box(attn_decode(&q, &k_cache, &v_cache, &rows, &pos, heads, hd, t_max, scale));
        });
    }

    let median = |name: &str| {
        suite.results.iter().find(|r| r.name == name).map(|r| r.median_ns).unwrap_or(f64::NAN)
    };
    let speedup =
        median("gemm/base/threads=1") / median(&format!("gemm/base/threads={PAR_THREADS}"));
    println!(
        "\ngemm/base median speedup ({PAR_THREADS} threads vs 1): {speedup:.2}x \
         (acceptance target >= 2x on a >=4-core runner)"
    );
    let full = median("gemm_tn_partial/base_ffn/lim=1376");
    let part = median("gemm_tn_partial/base_ffn/lim=86");
    println!(
        "partial dW saving at lim=86/1376: {:.1}x less GEMM time (paper Fig 5 mechanism)",
        full / part
    );
    suite.save();
}
