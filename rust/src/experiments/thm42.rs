//! Theorem 4.2 numerical verification: OOD excess-risk separation between
//! min-norm LoRA and S²FT on deep linear networks.
//!
//! Prints, per label-shift magnitude: the pre-trained OOD risk, both
//! fine-tuned OOD risks, the LoRA lower bound ‖(B_o−B_i)Σ½‖_F² and the
//! S²FT upper-bound check E_o(S²FT) ≤ (1+3ε²)·E_o(pre).

use anyhow::Result;

use crate::theory::{compare, Config};
use crate::util::json::Json;

use super::common::save_result;

pub fn run_thm42(quick: bool) -> Result<()> {
    let dims = if quick { vec![24, 20, 20, 16] } else { vec![48, 40, 40, 32] };
    let rank = if quick { 2 } else { 4 };
    let shifts = if quick { vec![0.5, 2.0] } else { vec![0.25, 0.5, 1.0, 2.0, 4.0] };
    let seeds: Vec<u64> = if quick { vec![1, 2] } else { vec![1, 2, 3, 4, 5] };

    println!("\n=== Theorem 4.2: OOD excess risk, deep linear nets ===");
    println!(
        "dims {dims:?}, layer 2, rank r={rank}, s=⌊r(dl+dl-1)/dl-1⌋; OOD = pre-training task; sweep = FT-task shift; mean over {} seeds",
        seeds.len()
    );
    println!(
        "{:>8} {:>11} {:>11} {:>11} {:>12} {:>12} {:>10} {:>10}",
        "shift", "E_od(pre)", "E_od(LoRA)", "E_od(S2FT)", "LoRA bound", "F.15 bound", "E_id(LoRA)", "E_id(S2FT)"
    );
    let mut records = Vec::new();
    let mut lora_bound_ok = 0usize;
    let mut s2ft_bound_ok = 0usize;
    let mut s2ft_sep_ok = 0usize;
    let mut total = 0usize;
    for &shift in &shifts {
        let mut acc = [0.0f64; 7];
        for &seed in &seeds {
            let cfg = Config {
                dims: dims.clone(),
                layer: 2,
                task_shift: shift,
                ood_noise: 0.2,
                shift_rank: 2 * rank,
                seed,
            };
            let rep = compare(&cfg, rank);
            let f15_bound = rep.od_pre + 3.0 * rep.proj_shift_sq;
            acc[0] += rep.od_pre;
            acc[1] += rep.od_lora;
            acc[2] += rep.od_s2ft;
            acc[3] += rep.label_shift_sq;
            acc[4] += rep.id_lora;
            acc[5] += rep.id_s2ft;
            acc[6] += f15_bound;
            total += 1;
            // Thm 4.2 / F.15 checks (with slack for finite dims / f32):
            if rep.od_lora >= 0.3 * rep.label_shift_sq {
                lora_bound_ok += 1;
            }
            if rep.od_s2ft <= f15_bound * 1.15 {
                s2ft_bound_ok += 1;
            }
            if shift < 1.0 || rep.od_s2ft < rep.od_lora {
                s2ft_sep_ok += 1; // separation claimed for large shift
            }
        }
        let n = seeds.len() as f64;
        println!(
            "{:>8.2} {:>11.3} {:>11.3} {:>11.3} {:>12.3} {:>12.3} {:>10.3} {:>10.3}",
            shift,
            acc[0] / n,
            acc[1] / n,
            acc[2] / n,
            acc[3] / n,
            acc[6] / n,
            acc[4] / n,
            acc[5] / n
        );
        records.push(Json::obj(vec![
            ("task_shift", Json::num(shift as f64)),
            ("od_pre", Json::num(acc[0] / n)),
            ("od_lora", Json::num(acc[1] / n)),
            ("od_s2ft", Json::num(acc[2] / n)),
            ("lora_lower_bound", Json::num(acc[3] / n)),
            ("f15_upper_bound", Json::num(acc[6] / n)),
            ("id_lora", Json::num(acc[4] / n)),
            ("id_s2ft", Json::num(acc[5] / n)),
        ]));
    }
    println!(
        "\nLoRA lower bound E_od ≥ ‖ΔB‖² held {lora_bound_ok}/{total}; \
         S²FT upper bound E_od ≤ E_od(pre)+3‖Φ″ΔB‖² held {s2ft_bound_ok}/{total}; \
         S²FT < LoRA OOD under large shift {s2ft_sep_ok}/{total}"
    );
    save_result("thm42", &Json::Arr(records));
    Ok(())
}
