//! Request router + engine thread.
//!
//! The PJRT client is not `Send`, so the engine thread *builds* the model
//! itself (via the builder closure) and owns it for its whole life; the
//! router side only moves host data (prompts, replies) across channels.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::adapter::AdapterStore;
use crate::runtime::Tensor;
use crate::train::GenModel;

use super::batcher::AdapterBatcher;

#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub adapter: String,
    pub prompt: String,
    pub max_new: usize,
}

#[derive(Debug, Clone)]
pub struct ServeReply {
    pub text: String,
    pub latency: Duration,
    pub batch_size: usize,
}

#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub requests: usize,
    pub batches: usize,
    pub switches: usize,
    pub latencies_ms: Vec<f64>,
}

impl ServeMetrics {
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        let mut v = self.latencies_ms.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[((v.len() as f64 - 1.0) * p) as usize]
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

enum Envelope {
    Req(ServeRequest, Sender<ServeReply>, Instant),
    Shutdown,
}

/// Leader-side handle: submit prompts, collect replies, read metrics.
pub struct Router {
    tx: Sender<Envelope>,
    handle: Option<JoinHandle<Result<()>>>,
    metrics: Arc<Mutex<ServeMetrics>>,
}

impl Router {
    /// Spawn the engine thread. `builder` runs *inside* the engine thread
    /// and must construct the model + adapter store (the PJRT client is
    /// thread-local by construction).
    pub fn spawn<F>(max_batch: usize, window: Duration, builder: F) -> Router
    where
        F: FnOnce() -> Result<(GenModel, AdapterStore, HashMap<String, Tensor>)>
            + Send
            + 'static,
    {
        let (tx, rx) = channel::<Envelope>();
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let m2 = metrics.clone();
        let handle = std::thread::spawn(move || engine_loop(rx, max_batch, window, builder, m2));
        Router { tx, handle: Some(handle), metrics }
    }

    /// Submit a request; returns the reply receiver.
    pub fn submit(&self, req: ServeRequest) -> Receiver<ServeReply> {
        let (rtx, rrx) = channel();
        let _ = self.tx.send(Envelope::Req(req, rtx, Instant::now()));
        rrx
    }

    /// Convenience: submit and wait.
    pub fn call(&self, req: ServeRequest) -> Result<ServeReply> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow!("engine dropped the request"))
    }

    pub fn metrics(&self) -> ServeMetrics {
        self.metrics.lock().unwrap().clone()
    }

    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Envelope::Shutdown);
        if let Some(h) = self.handle.take() {
            h.join().map_err(|_| anyhow!("engine panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        let _ = self.tx.send(Envelope::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_handles_empty_and_sorts() {
        let m = ServeMetrics::default();
        assert_eq!(m.percentile_ms(0.5), 0.0);
        assert_eq!(m.percentile_ms(0.99), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);

        let m = ServeMetrics {
            requests: 4,
            batches: 2,
            switches: 1,
            latencies_ms: vec![40.0, 10.0, 30.0, 20.0],
        };
        assert_eq!(m.percentile_ms(0.0), 10.0);
        assert_eq!(m.percentile_ms(1.0), 40.0);
        assert_eq!(m.percentile_ms(0.5), 20.0);
        assert_eq!(m.mean_batch_size(), 2.0);
    }
}

type Pending = (Sender<ServeReply>, Instant, usize);

fn engine_loop<F>(
    rx: Receiver<Envelope>,
    max_batch: usize,
    window: Duration,
    builder: F,
    metrics: Arc<Mutex<ServeMetrics>>,
) -> Result<()>
where
    F: FnOnce() -> Result<(GenModel, AdapterStore, HashMap<String, Tensor>)>,
{
    let (mut model, mut store, base_snapshot) = builder()?;
    let mut batcher: AdapterBatcher<(String, usize, Pending)> =
        AdapterBatcher::new(max_batch, window.max(Duration::from_millis(1)) * 4);
    let mut open = true;
    while open || !batcher.is_empty() {
        // Drain the channel; block briefly when idle to batch arrivals.
        loop {
            let msg = if batcher.is_empty() && open {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        open = false;
                        break;
                    }
                }
            } else {
                match rx.recv_timeout(window) {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                Envelope::Req(req, reply_tx, t0) => {
                    batcher.push(
                        req.adapter.clone(),
                        (req.prompt, req.max_new, (reply_tx, t0, 0)),
                    );
                    if batcher.len() >= max_batch {
                        break;
                    }
                }
                Envelope::Shutdown => {
                    open = false;
                    break;
                }
            }
        }
        let Some(plan) = batcher.next_batch() else { continue };
        // adapter-affinity switch (cheap scatter_add for S²FT adapters)
        if !store.is_empty() && plan.adapter != "base" {
            store.switch_to(&plan.adapter, &mut model.params, &base_snapshot)?;
        } else if store.active().is_some() && plan.adapter == "base" {
            store.deactivate(&mut model.params, &base_snapshot)?;
        }
        let prompts: Vec<String> =
            plan.items.iter().map(|q| q.payload.0.clone()).collect();
        let max_new = plan.items.iter().map(|q| q.payload.1).max().unwrap_or(8);
        let texts = model.generate(&prompts, max_new)?;
        let bs = plan.items.len();
        {
            let mut m = metrics.lock().unwrap();
            m.requests += bs;
            m.batches += 1;
            m.switches = store.switches;
        }
        for (q, text) in plan.items.into_iter().zip(texts) {
            let (reply_tx, t0, _) = q.payload.2;
            let latency = t0.elapsed();
            metrics
                .lock()
                .unwrap()
                .latencies_ms
                .push(latency.as_secs_f64() * 1e3);
            let _ = reply_tx.send(ServeReply { text, latency, batch_size: bs });
        }
    }
    Ok(())
}
