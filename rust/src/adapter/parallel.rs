//! Adapter parallelism on a single linear layer (paper Fig 6c):
//! serve a batch where every request uses a *different* adapter.
//!
//! Both paths share the base GEMM `Y = X @ W` (S-LoRA's decomposition);
//! they differ in the per-request delta:
//!
//!   LoRA : y_i += ((x_i @ A_i) @ B_i) * scale       -> r·(k+d) MACs
//!   S²FT : y_i += x_i[rows_i] @ D_i                 -> s·d MACs + gather
//!
//! At the paper's setting (s = 2r, k = d) the MAC counts match, but S²FT
//! does one fused pass over memory instead of two chained GEMVs — the
//! source of its measured advantage.
//!
//! All dense math routes through [`crate::kernels`]: the base GEMM is the
//! blocked parallel kernel, and the per-request deltas are partitioned
//! across the worker pool by output row (requests are independent, so
//! results are bit-identical to the serial path).

use crate::kernels;
use crate::linalg::Mat;

/// Per-request LoRA factors for one layer.
pub struct LoraReqAdapter {
    pub a: Mat, // (k, r)
    pub b: Mat, // (r, d)
    pub scale: f32,
}

/// Per-request S²FT delta rows for one layer.
pub struct S2ftReqAdapter {
    pub rows: Vec<usize>,
    pub delta: Mat, // (s, d)
}

/// Shared base computation: Y = X @ W.
pub fn base_forward(x: &Mat, w: &Mat) -> Mat {
    x.matmul(w)
}

/// LoRA path: per-request low-rank correction on top of `y`.
pub fn lora_parallel(x: &Mat, y: &mut Mat, adapters: &[LoraReqAdapter]) {
    lora_parallel_with_threads(x, y, adapters, kernels::configured_threads())
}

/// [`lora_parallel`] on an explicit worker count (requests partitioned).
pub fn lora_parallel_with_threads(
    x: &Mat,
    y: &mut Mat,
    adapters: &[LoraReqAdapter],
    threads: usize,
) {
    let k = x.cols;
    let d = y.cols;
    assert_eq!(adapters.len(), x.rows);
    let r = adapters.first().map_or(0, |ad| ad.a.cols);
    let work = adapters.len() * r * (k + d);
    kernels::for_each_row_chunk(&mut y.data, d, threads, work, |row0, chunk| {
        for (i, yrow) in chunk.chunks_mut(d).enumerate() {
            let ad = &adapters[row0 + i];
            // t = x_i @ A (1 x r), then y_i += (t @ B) * scale
            let t = kernels::gemm_with_threads(x.row(row0 + i), &ad.a.data, 1, k, ad.a.cols, 1);
            kernels::gemv_acc(&t, &ad.b.data, d, ad.scale, yrow);
        }
    });
}

/// S²FT path: gather the selected activations, apply the dense delta.
pub fn s2ft_parallel(x: &Mat, y: &mut Mat, adapters: &[S2ftReqAdapter]) {
    s2ft_parallel_with_threads(x, y, adapters, kernels::configured_threads())
}

/// [`s2ft_parallel`] on an explicit worker count (requests partitioned).
pub fn s2ft_parallel_with_threads(
    x: &Mat,
    y: &mut Mat,
    adapters: &[S2ftReqAdapter],
    threads: usize,
) {
    let d = y.cols;
    assert_eq!(adapters.len(), x.rows);
    let s = adapters.first().map_or(0, |ad| ad.rows.len());
    let work = adapters.len() * s * d;
    kernels::for_each_row_chunk(&mut y.data, d, threads, work, |row0, chunk| {
        // one gather buffer per worker chunk — the delta path stays
        // allocation-free per request (the point of the Fig 6c comparison)
        let mut xs: Vec<f32> = Vec::new();
        for (i, yrow) in chunk.chunks_mut(d).enumerate() {
            let ad = &adapters[row0 + i];
            let xi = x.row(row0 + i);
            xs.clear();
            xs.extend(ad.rows.iter().map(|&row| xi[row])); // gather
            kernels::gemv_acc(&xs, &ad.delta.data, d, 1.0, yrow);
        }
    });
}

/// Exact dense reference: y_i = x_i @ (W + ΔW_i).
pub fn dense_reference(x: &Mat, w: &Mat, deltas: &[Mat]) -> Mat {
    let mut out = Mat::zeros(x.rows, w.cols);
    for i in 0..x.rows {
        let weff = w.add(&deltas[i]);
        let xi = Mat::from_vec(1, x.cols, x.row(i).to_vec());
        let yi = xi.matmul(&weff);
        out.data[i * w.cols..(i + 1) * w.cols].copy_from_slice(&yi.data);
    }
    out
}

impl LoraReqAdapter {
    /// Materialize the dense ΔW = scale·A·B (test/reference use only).
    pub fn dense_delta(&self, _k: usize) -> Mat {
        self.a.matmul(&self.b).scale(self.scale)
    }
}

impl S2ftReqAdapter {
    /// Scatter the delta rows into a dense (k, d) ΔW (test/reference use).
    pub fn dense_delta(&self, k: usize) -> Mat {
        let d = self.delta.cols;
        let mut out = Mat::zeros(k, d);
        for (s_idx, &row) in self.rows.iter().enumerate() {
            out.data[row * d..(row + 1) * d].copy_from_slice(self.delta.row(s_idx));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn both_paths_match_dense_reference() {
        let mut rng = Rng::seed(0);
        let (n, k, d, r, s) = (4, 16, 12, 3, 5);
        let x = Mat::randn(n, k, &mut rng);
        let w = Mat::randn(k, d, &mut rng);

        let loras: Vec<LoraReqAdapter> = (0..n)
            .map(|_| LoraReqAdapter {
                a: Mat::randn(k, r, &mut rng),
                b: Mat::randn(r, d, &mut rng),
                scale: 0.5,
            })
            .collect();
        let mut y = base_forward(&x, &w);
        lora_parallel(&x, &mut y, &loras);
        let deltas: Vec<Mat> = loras.iter().map(|a| a.dense_delta(k)).collect();
        let want = dense_reference(&x, &w, &deltas);
        assert!(y.sub(&want).fro_norm() / want.fro_norm() < 1e-4);

        let s2fts: Vec<S2ftReqAdapter> = (0..n)
            .map(|_| S2ftReqAdapter {
                rows: rng.choose(k, s),
                delta: Mat::randn(s, d, &mut rng),
            })
            .collect();
        let mut y2 = base_forward(&x, &w);
        s2ft_parallel(&x, &mut y2, &s2fts);
        let deltas2: Vec<Mat> = s2fts.iter().map(|a| a.dense_delta(k)).collect();
        let want2 = dense_reference(&x, &w, &deltas2);
        assert!(y2.sub(&want2).fro_norm() / want2.fro_norm() < 1e-4);
    }

    #[test]
    fn request_partitioning_is_bit_identical() {
        // sized above kernels::MIN_PAR_WORK so the scoped-thread path runs
        let mut rng = Rng::seed(9);
        let (n, k, d, r, s) = (33, 256, 256, 8, 16);
        let x = Mat::randn(n, k, &mut rng);
        let w = Mat::randn(k, d, &mut rng);
        let loras: Vec<LoraReqAdapter> = (0..n)
            .map(|_| LoraReqAdapter {
                a: Mat::randn(k, r, &mut rng),
                b: Mat::randn(r, d, &mut rng),
                scale: 2.0,
            })
            .collect();
        let s2fts: Vec<S2ftReqAdapter> = (0..n)
            .map(|_| S2ftReqAdapter {
                rows: rng.choose(k, s),
                delta: Mat::randn(s, d, &mut rng),
            })
            .collect();
        let base = base_forward(&x, &w);
        let (mut l1, mut s1) = (base.clone(), base.clone());
        lora_parallel_with_threads(&x, &mut l1, &loras, 1);
        s2ft_parallel_with_threads(&x, &mut s1, &s2fts, 1);
        for t in [2usize, 3, 8] {
            let (mut lt, mut st) = (base.clone(), base.clone());
            lora_parallel_with_threads(&x, &mut lt, &loras, t);
            s2ft_parallel_with_threads(&x, &mut st, &s2fts, t);
            assert!(l1.data.iter().zip(&lt.data).all(|(a, b)| a.to_bits() == b.to_bits()));
            assert!(s1.data.iter().zip(&st.data).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }
}
