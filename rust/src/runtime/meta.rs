//! meta.json schema — the contract emitted by `python/compile/aot.py`.
//!
//! Parsed with the in-crate JSON module (no serde in the vendored set).

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// `[name, shape, dtype]` triple describing one artifact input/output.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn parse(j: &Json) -> Result<Self> {
        let a = j.as_arr()?;
        Ok(Self {
            name: a[0].as_str()?.to_string(),
            shape: a[1].as_arr()?.iter().map(|v| v.as_usize().unwrap_or(0)).collect(),
            dtype: a[2].as_str()?.to_string(),
        })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    /// Bytes per element for this spec's dtype (unknown dtypes default to
    /// 4 so memory accounting degrades gracefully rather than panicking).
    pub fn dtype_bytes(&self) -> usize {
        match self.dtype.as_str() {
            "f64" | "i64" | "u64" | "float64" | "int64" => 8,
            "f32" | "i32" | "u32" | "float32" | "int32" => 4,
            "f16" | "bf16" | "i16" | "u16" | "float16" | "int16" => 2,
            "i8" | "u8" | "bool" | "pred" | "int8" | "uint8" => 1,
            _ => 4,
        }
    }
}

/// `[name, shape]` pair (method layout sections).
#[derive(Debug, Clone)]
pub struct NamedShape {
    pub name: String,
    pub shape: Vec<usize>,
}

impl NamedShape {
    fn parse(j: &Json) -> Result<Self> {
        let a = j.as_arr()?;
        Ok(Self {
            name: a[0].as_str()?.to_string(),
            shape: a[1].as_arr()?.iter().map(|v| v.as_usize().unwrap_or(0)).collect(),
        })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

fn parse_shapes(j: Option<&Json>) -> Result<Vec<NamedShape>> {
    match j {
        None => Ok(vec![]),
        Some(j) => j.as_arr()?.iter().map(NamedShape::parse).collect(),
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Debug, Clone)]
pub struct ModelDims {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
}

#[derive(Debug, Clone)]
pub struct MethodMeta {
    pub method: String,
    pub selection: String,
    pub select_small: bool,
    pub rank: usize,
    pub lora_alpha: f64,
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    pub s2ft_fractions: HashMap<String, f64>,
    pub trainable: Vec<NamedShape>,
    pub frozen: Vec<NamedShape>,
    pub perms: Vec<NamedShape>,
    pub aux: Vec<NamedShape>,
    pub opt: Vec<NamedShape>,
    pub trainable_params: usize,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub dims: ModelDims,
    pub param_count: usize,
    pub methods: HashMap<String, MethodMeta>,
    pub batches: Vec<(usize, usize)>,
    pub base_params: Vec<NamedShape>,
}

impl ModelMeta {
    /// Default (batch, seq) — first entry emitted by aot.py.
    pub fn default_batch(&self) -> (usize, usize) {
        self.batches[0]
    }

    pub fn head_dim(&self) -> usize {
        self.dims.d_model / self.dims.n_heads
    }

    pub fn method(&self, tag: &str) -> Result<&MethodMeta> {
        self.methods
            .get(tag)
            .with_context(|| format!("method {tag:?} not in meta for model {}", self.dims.name))
    }
}

#[derive(Debug, Clone)]
pub struct Meta {
    pub models: HashMap<String, ModelMeta>,
    pub artifacts: HashMap<String, ArtifactMeta>,
}

impl Meta {
    pub fn parse(text: &str) -> Result<Meta> {
        let root = Json::parse(text).context("meta.json parse")?;
        let mut models = HashMap::new();
        for (name, mj) in root.get("models")?.as_obj()? {
            models.insert(name.clone(), parse_model(mj).context(name.clone())?);
        }
        let mut artifacts = HashMap::new();
        for (name, aj) in root.get("artifacts")?.as_obj()? {
            let inputs = aj.get("inputs")?.as_arr()?.iter().map(TensorSpec::parse)
                .collect::<Result<_>>()?;
            let outputs = aj.get("outputs")?.as_arr()?.iter().map(TensorSpec::parse)
                .collect::<Result<_>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactMeta { file: aj.get("file")?.as_str()?.to_string(), inputs, outputs },
            );
        }
        Ok(Meta { models, artifacts })
    }
}

fn parse_model(mj: &Json) -> Result<ModelMeta> {
    let dj = mj.get("model")?;
    let dims = ModelDims {
        name: dj.get("name")?.as_str()?.to_string(),
        d_model: dj.get("d_model")?.as_usize()?,
        n_layers: dj.get("n_layers")?.as_usize()?,
        n_heads: dj.get("n_heads")?.as_usize()?,
        d_ff: dj.get("d_ff")?.as_usize()?,
        vocab: dj.get("vocab")?.as_usize()?,
        seq_len: dj.get("seq_len")?.as_usize()?,
        rope_theta: dj.num_or("rope_theta", 10000.0),
        norm_eps: dj.num_or("norm_eps", 1e-5),
    };
    let mut methods = HashMap::new();
    for (tag, j) in mj.get("methods")?.as_obj()? {
        let mut fractions = HashMap::new();
        if let Some(f) = j.opt("s2ft_fractions") {
            for (k, v) in f.as_obj()? {
                fractions.insert(k.clone(), v.as_f64()?);
            }
        }
        methods.insert(
            tag.clone(),
            MethodMeta {
                method: j.str_or("method", tag),
                selection: j.str_or("selection", "r"),
                select_small: j
                    .opt("select_small")
                    .and_then(|v| v.as_bool().ok())
                    .unwrap_or(true),
                rank: j.num_or("rank", 0.0) as usize,
                lora_alpha: j.num_or("lora_alpha", 0.0),
                lr: j.num_or("lr", 0.0),
                beta1: j.num_or("beta1", 0.9),
                beta2: j.num_or("beta2", 0.999),
                eps: j.num_or("eps", 1e-8),
                weight_decay: j.num_or("weight_decay", 0.0),
                s2ft_fractions: fractions,
                trainable: parse_shapes(j.opt("trainable"))?,
                frozen: parse_shapes(j.opt("frozen"))?,
                perms: parse_shapes(j.opt("perms"))?,
                aux: parse_shapes(j.opt("aux"))?,
                opt: parse_shapes(j.opt("opt"))?,
                trainable_params: j.num_or("trainable_params", 0.0) as usize,
            },
        );
    }
    let batches = mj
        .get("batches")?
        .as_arr()?
        .iter()
        .map(|b| {
            let a = b.as_arr()?;
            Ok((a[0].as_usize()?, a[1].as_usize()?))
        })
        .collect::<Result<_>>()?;
    Ok(ModelMeta {
        dims,
        param_count: mj.get("param_count")?.as_usize()?,
        methods,
        batches,
        base_params: parse_shapes(mj.opt("base_params"))?,
    })
}
