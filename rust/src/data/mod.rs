//! Data substrate: tokenizer, synthetic world, pre-training corpus and
//! the three task suites the experiments fine-tune/evaluate on.

pub mod batch;
pub mod tasks;
pub mod tokenizer;
pub mod world;

pub use batch::{lm_batch, supervised_batch, Batch};
pub use tasks::{
    suite, Difficulty, Example, Split, Task, ARITHMETIC, ARITH_FT, COMMONSENSE, INSTRUCT,
};
pub use tokenizer::Tokenizer;
pub use world::World;

use crate::util::rng::Rng;

/// Build the pre-training corpus: world facts + counting/arithmetic
/// statements, shuffled deterministically.
///
/// This is the "pre-trained knowledge" the paper's generalization
/// experiments measure forgetting against (DESIGN.md §2).
pub fn pretrain_corpus(seed: u64, approx_bytes: usize) -> String {
    let world = World::canonical();
    let mut rng = Rng::seed(seed);
    let mut statements = world.fact_statements();
    // arithmetic statements: sums/differences/products over small ints
    for a in 0..25i64 {
        for b in 0..25i64 {
            statements.push(format!("{} + {} = {}.", a, b, a + b));
            if a >= b {
                statements.push(format!("{} - {} = {}.", a, b, a - b));
            }
            if a < 13 && b < 13 {
                statements.push(format!("{} * {} = {}.", a, b, a * b));
            }
        }
    }
    let mut out = String::with_capacity(approx_bytes + 256);
    while out.len() < approx_bytes {
        out.push_str(statements[rng.below(statements.len())].as_str());
        out.push(' ');
    }
    out
}

/// Mixed fine-tuning set for a suite (train split), with the arithmetic
/// suite drawing only from the Math10K-analogue mixture.
pub fn finetune_examples(suite_name: &str, n: usize, seed: u64) -> Vec<Example> {
    let world = World::canonical();
    let mut rng = Rng::seed(seed);
    let tasks = suite(suite_name).unwrap_or(&COMMONSENSE);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let task = if suite_name == "arithmetic" {
            &tasks[ARITH_FT[rng.below(ARITH_FT.len())]]
        } else {
            &tasks[rng.below(tasks.len())]
        };
        out.push(task.sample(&world, &mut rng, Split::Train));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_has_requested_size_and_facts() {
        let c = pretrain_corpus(0, 10_000);
        assert!(c.len() >= 10_000);
        assert!(c.contains(" = "));
        assert!(c.contains("can"));
    }

    #[test]
    fn corpus_deterministic() {
        assert_eq!(pretrain_corpus(1, 2000), pretrain_corpus(1, 2000));
        assert_ne!(pretrain_corpus(1, 2000), pretrain_corpus(2, 2000));
    }

    #[test]
    fn finetune_arithmetic_only_uses_ft_mixture() {
        let ex = finetune_examples("arithmetic", 100, 3);
        assert_eq!(ex.len(), 100);
        // MultiArith prompts "q: (a + b) * c" never appear in the FT mixture
        assert!(ex.iter().all(|e| !e.prompt.starts_with("q: (")));
    }
}
