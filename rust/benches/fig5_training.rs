//! Figure 5 (bench form): end-to-end train-step latency per method on the
//! `small` model through whichever backend is available (native interprets
//! fullft + s2ft; the pjrt feature adds the full AOT method set). The
//! `repro experiment fig5` harness covers the `base`-model sweep with
//! memory accounting; this bench gives tight per-step latency
//! distributions for regressions.
//!
//! Two comparison axes ride along for the native backend:
//!
//! * truncated vs full walk — `train_step/s2ft` (plan-truncated backward,
//!   sliced activation cache) against `train_step/s2ft_fullwalk`
//!   (`S2FT_FULL_BACKWARD=1`: cache everything, walk to layer 0). The
//!   trainable gradients are bit-identical (proptest-enforced); only
//!   memory/latency differ. Measured activation-cache bytes print next to
//!   each lane — the paper's Fig 5 memory story.
//! * concentrated selection — `train_step/s2ft_top1[_fullwalk]` trains
//!   only the *top* layer's wo/wd: the truncated walk stops immediately
//!   below it and skips the other layers' backward entirely, which is
//!   where the paper's partial-backprop latency win shows up.

use std::collections::HashMap;

use repro::adapter::s2ft_counts;
use repro::data::{lm_batch, pretrain_corpus, Tokenizer};
use repro::runtime::native::builtin;
use repro::runtime::native::set_full_backward_override;
use repro::runtime::{open_backend, Executable, Executor, NativeBackend, Tensor};
use repro::sparsity::strategy::for_name;
use repro::train::Trainer;
use repro::util::bench::BenchSuite;
use repro::util::rng::Rng;

fn act_bytes_note(name: &str, tr: &Trainer) {
    if let (Some(c), Some(p)) = (tr.activation_bytes(), tr.activation_peak_bytes()) {
        println!(
            "    {name}: activation cache {:.2} MB, live peak {:.2} MB",
            c as f64 / 1e6,
            p as f64 / 1e6
        );
    }
}

fn main() {
    let rt = match open_backend("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            // leave a machine-readable record so CI can tell a skipped
            // bench apart from a lost artifact
            BenchSuite::save_skipped("fig5_training", &format!("{e:#}"));
            return;
        }
    };
    let model = "small";
    let mm = rt.artifacts().model(model).expect("small model meta").clone();
    let (b, t) = mm.default_batch();
    let init = rt.load(&format!("init_{model}")).expect("init artifact");
    let outs = init.run(&[Tensor::scalar_i32(1)]).expect("init run");
    let base: std::collections::HashMap<String, Tensor> = init
        .spec()
        .outputs
        .iter()
        .map(|s| s.name.clone())
        .zip(outs)
        .collect();

    let tk = Tokenizer;
    let corpus = pretrain_corpus(3, 200_000);
    let mut suite = BenchSuite::new("fig5_training").slow();
    println!(
        "Fig 5 (bench): one optimizer step, model=small {b}x{t}, backend {}\n",
        rt.platform()
    );
    set_full_backward_override(Some(false));
    for method in ["fullft", "lora", "dora", "spft", "lisa", "galore", "s2ft", "s2ft-pallas"] {
        if mm.methods.get(method).is_none() {
            continue;
        }
        let mut rng = Rng::seed(5);
        let calib = lm_batch(&tk, &corpus, &mut rng, b, t);
        let mut trainer = match Trainer::new(rt.as_ref(), model, method, &base, 3, &calib) {
            Ok(tr) => tr,
            Err(e) => {
                eprintln!("  {method}: {e:#}");
                continue;
            }
        };
        // compile + warm
        let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
        trainer.train_step(&batch).expect("warmup step");
        suite.bench(&format!("train_step/{method}"), || {
            let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
            trainer.train_step(&batch).expect("train step");
        });
        act_bytes_note(method, &trainer);
        // truncated-vs-full reference lane: identical gradients, but the
        // cache retains everything and the walk runs to layer 0
        if method == "s2ft" && rt.platform() == "native" {
            set_full_backward_override(Some(true));
            let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
            trainer.train_step(&batch).expect("full-walk warmup");
            suite.bench("train_step/s2ft_fullwalk", || {
                let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
                trainer.train_step(&batch).expect("full-walk step");
            });
            act_bytes_note("s2ft_fullwalk", &trainer);
            set_full_backward_override(Some(false));
        }
        rt.evict(&format!("train_{model}_{method}_{b}x{t}"));
    }

    // Replan overhead: a static strategy forced to re-commit the identical
    // selection every step, so each iteration pays the full
    // merge→rebuild→remap→reload cycle on top of one optimizer step. The
    // recommit is a bitwise identity (proptest-enforced); the delta over
    // `train_step/s2ft` is the cost of dynamic re-selection itself.
    if let Some(meth) = mm.methods.get("s2ft").filter(|_| rt.platform() == "native") {
        let strat = for_name("static", &meth.selection, meth.select_small).expect("static strategy");
        let mut trainer =
            Trainer::with_strategy(rt.as_ref(), model, "s2ft", &base, 3, strat, 1, b, t)
                .expect("strategy trainer");
        let mut rng = Rng::seed(5);
        let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
        trainer.train_step(&batch).expect("replan warmup step");
        suite.bench("train_step/s2ft_replan_recommit", || {
            let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
            let replanned = trainer.maybe_replan(rt.as_ref(), &batch).expect("replan");
            assert!(replanned, "replan_every=1 must replan each step");
            trainer.train_step(&batch).expect("replan train step");
        });
        act_bytes_note("s2ft_replan_recommit", &trainer);
        rt.evict(&format!("train_{model}_s2ft_{b}x{t}"));
    }

    // Concentrated selection: only the top layer's wo/wd train, so the
    // truncated walk never descends below it (native backend only — this
    // layout has no AOT artifact).
    if rt.platform() == "native" {
        let nb = NativeBackend::builtin();
        let mm = nb.artifacts().model(model).expect("model meta").clone();
        let uniform = &mm.methods["s2ft"];
        let top = mm.dims.n_layers - 1;
        // same unit budget as the uniform s2ft method, applied to the top
        // layer only (s2ft_counts speaks head/channel units, exactly what
        // s2ft_layout_per_layer expects)
        let mut counts_per_layer = vec![HashMap::new(); mm.dims.n_layers];
        counts_per_layer[top] = s2ft_counts(&mm, uniform);
        let (trainable, frozen, perms) = builtin::s2ft_layout_per_layer(
            &mm.dims,
            &mm.base_params,
            &counts_per_layer,
        );
        let mut meth = uniform.clone();
        meth.trainable_params = trainable.iter().map(|s| s.numel()).sum();
        meth.opt = trainable.clone();
        meth.trainable = trainable;
        meth.frozen = frozen;
        meth.perms = perms;
        let mut meta = builtin::builtin_meta();
        meta.models
            .get_mut(model)
            .expect("model")
            .methods
            .insert("s2fttop".to_string(), meth.clone());
        let nb = NativeBackend::with_meta(meta);
        let (b, t) = nb.artifacts().model(model).expect("model").default_batch();
        let exe = nb
            .load(&format!("train_{model}_s2fttop_{b}x{t}"))
            .expect("top-layer train executable");
        // weights from the builtin init (the outer backend may be driven
        // by a meta.json whose `small` differs from the builtin one)
        let init = nb.load(&format!("init_{model}")).expect("init");
        let outs = init.run(&[Tensor::scalar_i32(1)]).expect("init run");
        let nb_base: HashMap<String, Tensor> =
            init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect();
        let mut pool = builtin::identity_split_pool(&nb_base, &meth);
        pool.insert("step".to_string(), Tensor::scalar_f32(0.0));
        let mut rng = Rng::seed(5);
        for (name, full_walk) in [("s2ft_top1", false), ("s2ft_top1_fullwalk", true)] {
            set_full_backward_override(Some(full_walk));
            suite.bench(&format!("train_step/{name}"), || {
                // batch travels in the overlay: the timed lane measures
                // the step itself, not a whole-pool clone per iteration
                let batch = lm_batch(&tk, &corpus, &mut rng, b, t);
                let mut overlay = HashMap::new();
                overlay.insert("tokens".to_string(), batch.tokens);
                overlay.insert("targets".to_string(), batch.targets);
                overlay.insert("loss_mask".to_string(), batch.loss_mask);
                let out = exe.run_named_with(&pool, &overlay).expect("top-layer step");
                assert!(out.contains_key("loss"));
            });
        }
        set_full_backward_override(None);
        println!(
            "\n  top-layer selection: the truncated walk stops below L{top}; \
             the full walk still backprops {} layers",
            mm.dims.n_layers
        );
    }

    println!("\nPaper shape: s2ft < lora/dora < fullft in step latency; truncated");
    println!("s2ft activation cache well below fullft; top-layer truncation beats");
    println!("the full walk outright.");
    suite.save();
}
