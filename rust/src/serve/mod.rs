//! Multi-adapter serving coordinator (paper §6.2, S-LoRA-style scenario).
//!
//! Architecture: a leader **router** thread owns the request queue and the
//! dynamic batcher; a single **engine** thread owns the PJRT runtime, the
//! live merged weights and the [`AdapterStore`]. Requests are grouped by
//! adapter id (adapter-affinity batching) so each engine iteration pays at
//! most one adapter switch — the scatter_add fast path S²FT makes cheap.
//! Python never appears anywhere on this path.

mod batcher;
mod router;

pub use batcher::{AdapterBatcher, BatchPlan};
pub use router::{Router, ServeMetrics, ServeReply, ServeRequest};

use std::collections::HashMap;
use std::time::Duration;

use anyhow::Result;

use crate::adapter::{AdapterStore, AnyAdapter, S2ftAdapter, S2ftLayerDelta};
use crate::runtime::{open_backend, Executable, Executor, Tensor};
use crate::train::GenModel;
use crate::util::rng::Rng;

/// Self-contained multi-adapter serving demo (`repro serve`).
///
/// Loads (or randomly initializes) base weights, registers `n_adapters`
/// synthetic S²FT adapters, and fires `n_requests` prompts round-robin
/// across them through the router. Reports throughput, latency
/// percentiles, switch count and adapter memory.
pub fn demo(
    artifacts: &str,
    model: &str,
    weights: Option<&str>,
    n_adapters: usize,
    n_requests: usize,
    max_batch: usize,
) -> Result<()> {
    let artifacts = artifacts.to_string();
    let model_name = model.to_string();
    let weights = weights.map(String::from);
    let router = Router::spawn(max_batch, Duration::from_millis(3), move || {
        let rt = open_backend(&artifacts)?;
        let params = match &weights {
            Some(dir) => crate::train::load_params(dir)?,
            None => {
                let init = rt.load(&format!("init_{model_name}"))?;
                let outs = init.run(&[Tensor::scalar_i32(9)])?;
                init.spec()
                    .outputs
                    .iter()
                    .map(|s| s.name.clone())
                    .zip(outs)
                    .collect()
            }
        };
        let mm = rt.artifacts().model(&model_name)?;
        let (d, k, hd) = (mm.dims.d_model, mm.dims.d_ff, mm.head_dim());
        let n_layers = mm.dims.n_layers;
        let mut store = AdapterStore::new();
        let mut rng = Rng::seed(0x5EE);
        for a in 0..n_adapters {
            let layers = (0..n_layers)
                .map(|_| {
                    let heads = rng.choose(mm.dims.n_heads, 1);
                    let wo_rows = crate::sparsity::expand_head_perm(&heads, hd);
                    let chans = rng.choose(k, (k / 32).max(1));
                    S2ftLayerDelta {
                        wo_delta: (0..wo_rows.len() * d).map(|_| rng.normal_f32() * 1e-3).collect(),
                        wo_rows,
                        wd_delta: (0..chans.len() * d).map(|_| rng.normal_f32() * 1e-3).collect(),
                        wd_rows: chans,
                    }
                })
                .collect();
            store.insert(
                format!("adapter{a}"),
                AnyAdapter::S2ft(S2ftAdapter { layers, d_model: d }),
            );
        }
        println!(
            "engine up: {} adapters ({:.1} KB total, vs {:.1} MB base weights)",
            store.len(),
            store.total_bytes() as f64 / 1e3,
            params.values().map(Tensor::bytes).sum::<usize>() as f64 / 1e6
        );
        let snapshot: HashMap<String, Tensor> = params.clone();
        let gm = GenModel::new(rt.as_ref(), &model_name, params)?;
        Ok((gm, store, snapshot))
    });

    let world = crate::data::World::canonical();
    let mut rng = Rng::seed(0xDEE);
    let started = std::time::Instant::now();
    let mut receivers = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let task = &crate::data::COMMONSENSE[rng.below(8)];
        let ex = task.sample(&world, &mut rng, crate::data::Split::Test);
        receivers.push(router.submit(ServeRequest {
            adapter: format!("adapter{}", i % n_adapters.max(1)),
            prompt: ex.prompt,
            max_new: 8,
        }));
    }
    let mut ok = 0;
    for r in receivers {
        if r.recv().is_ok() {
            ok += 1;
        }
    }
    let wall = started.elapsed();
    let m = router.metrics();
    println!(
        "served {ok}/{n_requests} requests in {:.2}s ({:.1} req/s)",
        wall.as_secs_f64(),
        ok as f64 / wall.as_secs_f64()
    );
    println!(
        "batches {} (mean size {:.1}), adapter switches {}, latency p50 {:.0} ms / p99 {:.0} ms",
        m.batches,
        m.mean_batch_size(),
        m.switches,
        m.percentile_ms(0.5),
        m.percentile_ms(0.99)
    );
    router.shutdown()
}
