//! PJRT backend (cargo feature `pjrt`): compile AOT HLO-text artifacts
//! through the `xla` crate and execute them.
//!
//! This module is the only place that touches `xla`. Note the in-tree
//! `xla` dependency is a compile-only stub; execution requires vendoring
//! the real crate (see rust/README.md).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use super::{check_inputs, ArtifactMeta, Artifacts, Executable, Executor, Tensor, TensorData};

/// PJRT CPU client + compiled-executable cache.
///
/// Compilation is lazy and cached per artifact name: experiment harnesses
/// freely re-request executables without paying XLA compile time twice.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: Artifacts,
    cache: Mutex<HashMap<String, Arc<dyn Executable>>>,
}

impl Runtime {
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let artifacts = Artifacts::open(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(Self { client, artifacts, cache: Mutex::new(HashMap::new()) })
    }
}

impl Executor for Runtime {
    fn artifacts(&self) -> &Artifacts {
        &self.artifacts
    }

    fn load(&self, name: &str) -> Result<Arc<dyn Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.artifacts.artifact(name)?.clone();
        let path = self.artifacts.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(xerr)
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(xerr)
            .with_context(|| format!("XLA compile of {name}"))?;
        let exec: Arc<dyn Executable> =
            Arc::new(PjrtExecutable { name: name.to_string(), exe, spec });
        self.cache.lock().unwrap().insert(name.to_string(), exec.clone());
        Ok(exec)
    }

    fn evict(&self, name: &str) {
        self.cache.lock().unwrap().remove(name);
    }

    fn platform(&self) -> String {
        format!("pjrt/{}", self.client.platform_name())
    }
}

fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

/// A compiled artifact plus its interface description.
pub struct PjrtExecutable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactMeta,
}

impl Executable for PjrtExecutable {
    fn name(&self) -> &str {
        &self.name
    }

    fn spec(&self) -> &ArtifactMeta {
        &self.spec
    }

    fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        check_inputs(&self.name, &self.spec, inputs)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals).map_err(xerr)?;
        let lit = result[0][0].to_literal_sync().map_err(xerr)?;
        // aot.py lowers with return_tuple=True: single tuple output.
        let parts = lit.to_tuple().map_err(xerr)?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        parts.into_iter().map(from_literal).collect()
    }
}

fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    let lit = match &t.data {
        TensorData::F32(v) => {
            if t.shape.is_empty() {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::vec1(v)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape: {e}"))?
            }
        }
        TensorData::I32(v) => {
            if t.shape.is_empty() {
                xla::Literal::scalar(v[0])
            } else {
                xla::Literal::vec1(v)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape: {e}"))?
            }
        }
    };
    Ok(lit)
}

fn from_literal(lit: xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(|e| anyhow!("array_shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let ty = lit.ty().map_err(|e| anyhow!("ty: {e}"))?;
    match ty {
        xla::ElementType::F32 => {
            let v = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
            Ok(Tensor { shape: dims, data: TensorData::F32(v) })
        }
        xla::ElementType::S32 => {
            let v = lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e}"))?;
            Ok(Tensor { shape: dims, data: TensorData::I32(v) })
        }
        other => bail!("unsupported literal element type {other:?}"),
    }
}
