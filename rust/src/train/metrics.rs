//! Training metrics: loss curve, throughput, wall time.

use std::time::Duration;

use crate::util::json::Json;

#[derive(Debug, Clone, Default)]
pub struct TrainMetrics {
    pub losses: Vec<f32>,
    pub total_tokens: usize,
    pub total_time: Duration,
    /// Measured activation-cache bytes (forward buffers retained for the
    /// backward pass) of the most recent step, when the executable
    /// reports them (native backend).
    pub act_cache_bytes: Option<u64>,
    /// Measured peak live activation bytes of the most recent step.
    pub act_peak_bytes: Option<u64>,
    /// Mid-run selection replans committed (dynamic strategies): every
    /// count here was a pool rebuild + optimizer-moment remap + plan
    /// epoch bump.
    pub replans: usize,
    /// Replans that changed the trainable layout shapes (and therefore
    /// swapped in a method-layout variant executable), not just the
    /// selected unit ids.
    pub shape_changing_replans: usize,
}

impl TrainMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_step(&mut self, loss: f32, tokens: usize, elapsed: Duration) {
        self.losses.push(loss);
        self.total_tokens += tokens;
        self.total_time += elapsed;
    }

    /// Record the measured activation memory of a step.
    pub fn record_activation(&mut self, cache_bytes: u64, peak_bytes: u64) {
        self.act_cache_bytes = Some(cache_bytes);
        self.act_peak_bytes = Some(peak_bytes);
    }

    /// Record a committed mid-run replan (`shape_changed`: the trainable
    /// layout shapes differ from the previous plan epoch).
    pub fn record_replan(&mut self, shape_changed: bool) {
        self.replans += 1;
        if shape_changed {
            self.shape_changing_replans += 1;
        }
    }

    /// Steps whose recorded loss was not finite (divergence, masked-out
    /// batches); flagged in [`TrainMetrics::to_json`].
    pub fn non_finite_steps(&self) -> usize {
        self.losses.iter().filter(|l| !l.is_finite()).count()
    }

    pub fn steps(&self) -> usize {
        self.losses.len()
    }

    pub fn last_loss(&self) -> f32 {
        self.losses.last().copied().unwrap_or(f32::NAN)
    }

    /// Mean loss over the final `k` steps (smoothed curve endpoint).
    pub fn tail_loss(&self, k: usize) -> f32 {
        if self.losses.is_empty() {
            return f32::NAN;
        }
        let k = k.min(self.losses.len());
        let tail = &self.losses[self.losses.len() - k..];
        tail.iter().sum::<f32>() / k as f32
    }

    pub fn tokens_per_sec(&self) -> f64 {
        if self.total_time.is_zero() {
            return 0.0;
        }
        self.total_tokens as f64 / self.total_time.as_secs_f64()
    }

    pub fn ms_per_step(&self) -> f64 {
        if self.losses.is_empty() {
            return 0.0;
        }
        self.total_time.as_secs_f64() * 1e3 / self.losses.len() as f64
    }

    /// Serialize. Non-finite losses are never emitted as bare `NaN`/`inf`
    /// (invalid JSON): they become `null` in the curve, the scalar loss
    /// fields are nulled when non-finite, and a `non_finite_steps` count
    /// flags that it happened.
    pub fn to_json(&self) -> Json {
        let finite_or_null = |v: f32| {
            if v.is_finite() {
                Json::num(v as f64)
            } else {
                Json::Null
            }
        };
        let mut fields = vec![
            ("steps", Json::num(self.steps() as f64)),
            ("last_loss", finite_or_null(self.last_loss())),
            ("tail_loss", finite_or_null(self.tail_loss(10))),
            ("non_finite_steps", Json::num(self.non_finite_steps() as f64)),
            ("tokens_per_sec", Json::num(self.tokens_per_sec())),
            ("ms_per_step", Json::num(self.ms_per_step())),
            (
                "loss_curve",
                Json::Arr(self.losses.iter().map(|&l| finite_or_null(l)).collect()),
            ),
        ];
        if let Some(b) = self.act_cache_bytes {
            fields.push(("act_cache_bytes", Json::num(b as f64)));
        }
        if let Some(b) = self.act_peak_bytes {
            fields.push(("act_peak_bytes", Json::num(b as f64)));
        }
        if self.replans > 0 {
            fields.push(("replans", Json::num(self.replans as f64)));
            fields.push((
                "shape_changing_replans",
                Json::num(self.shape_changing_replans as f64),
            ));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_accumulate() {
        let mut m = TrainMetrics::new();
        m.record_step(2.0, 100, Duration::from_millis(10));
        m.record_step(1.0, 100, Duration::from_millis(10));
        assert_eq!(m.steps(), 2);
        assert_eq!(m.last_loss(), 1.0);
        assert_eq!(m.tail_loss(2), 1.5);
        assert!(m.tokens_per_sec() > 0.0);
        assert!((m.ms_per_step() - 10.0).abs() < 1.0);
    }

    #[test]
    fn to_json_never_emits_bare_nan() {
        let mut m = TrainMetrics::new();
        m.record_step(2.0, 100, Duration::from_millis(10));
        m.record_step(f32::NAN, 100, Duration::from_millis(10));
        m.record_step(f32::INFINITY, 100, Duration::from_millis(10));
        assert_eq!(m.non_finite_steps(), 2);
        let s = m.to_json().to_string_pretty();
        assert!(!s.contains("NaN") && !s.contains("inf"), "invalid JSON: {s}");
        assert!(s.contains("non_finite_steps"));
        // the curve keeps positional alignment via nulls
        assert!(s.contains("null"));
        // round-trips through the parser
        assert!(Json::parse(&s).is_ok());
    }

    #[test]
    fn activation_bytes_surface_in_json() {
        let mut m = TrainMetrics::new();
        m.record_step(1.0, 10, Duration::from_millis(1));
        assert!(m.to_json().get("act_cache_bytes").is_err());
        m.record_activation(1234, 5678);
        let j = m.to_json();
        assert_eq!(j.get("act_cache_bytes").unwrap().as_f64().unwrap(), 1234.0);
        assert_eq!(j.get("act_peak_bytes").unwrap().as_f64().unwrap(), 5678.0);
    }
}
