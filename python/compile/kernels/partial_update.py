"""L1 Pallas kernels: the S2FT partial back-propagation hot path.

The paper's efficiency contribution (Sec. 3.3) is that after co-permuting
the coupled structures, the trainable channels form a *contiguous leading
block* of the weight matrix, so both the forward GEMM and the
trainable-slice weight gradient are plain dense tiled matmuls — no sparse
ops anywhere. We express that as a single tiled Pallas matmul kernel used
three ways:

  forward :  y    = x @ [w_t; w_f]           full grid
  dx      :  dx   = dy @ W^T                 full grid
  dw_t    :  dw_t = x[:, :s]^T @ dy          grid restricted to s rows

TPU mapping (DESIGN.md §Hardware-Adaptation): the BlockSpec tiles are
MXU-shaped (up to 128x128); the dw_t grid covers ceil(s/Tm) instead of
ceil(K/Tm) row tiles, so backward compute and VMEM traffic scale with the
sparsity level exactly like the paper's CUDA implementation.

Kernels MUST run with interpret=True here: the CPU PJRT plugin cannot
execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes; clamped per-dimension (shapes are padded up to tile
# multiples so arbitrary problem sizes are supported).
TILE_M = 64
TILE_N = 64
TILE_K = 64


def _tile(dim: int, t: int) -> int:
    """Largest tile <= t; degenerate dims get a unit tile."""
    return max(1, min(dim, t))


def _pad_to(x, m_mult, n_mult):
    m, n = x.shape
    pm = (-m) % m_mult
    pn = (-n) % n_mult
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """Tiled matmul body accumulating into the revisited output tile.

    The output BlockSpec maps every k-step of the grid to the same (i, j)
    tile, so the tile stays resident in VMEM across the contraction loop
    (standard Pallas accumulation pattern — no scratch needed).
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("tm", "tn", "tk"))
def matmul(x, w, tm: int = TILE_M, tn: int = TILE_N, tk: int = TILE_K):
    """Tiled Pallas GEMM: (M, K) @ (K, N) -> (M, N), any f32 shapes.

    Shapes are zero-padded to tile multiples; padding contributes zeros to
    the accumulator, so the unpadded slice of the result is exact.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {w.shape}"
    tm, tn, tk = _tile(m, tm), _tile(n, tn), _tile(k, tk)
    xp = _pad_to(x.astype(jnp.float32), tm, tk)
    wp = _pad_to(w.astype(jnp.float32), tk, tn)
    mp, kp = xp.shape
    _, np_ = wp.shape
    grid = (mp // tm, np_ // tn, kp // tk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


# --------------------------------------------------------------------------
# S2FT partitioned linear layer with partial back-propagation.
# --------------------------------------------------------------------------


@jax.custom_vjp
def s2ft_linear(x, w_t, w_f):
    """y = x @ [w_t; w_f] with gradients only for (x, w_t).

    This is the two-line partial-backprop patch of paper Sec. 3.3 expressed
    as a custom VJP: the saved residual for the weight gradient is only the
    trainable slice of the activation, and the dw GEMM covers only the
    trainable rows.
    """
    return matmul(x, jnp.concatenate([w_t, w_f], axis=0))


def _s2ft_fwd(x, w_t, w_f):
    y = s2ft_linear(x, w_t, w_f)
    # Save only what partial backprop needs: the trainable activation slice
    # for dw_t, and both weight pieces for dx (`setup_context` analogue).
    s = w_t.shape[0]
    return y, (x[:, :s], w_t, w_f)


def _s2ft_bwd(res, dy):
    x_t, w_t, w_f = res
    w = jnp.concatenate([w_t, w_f], axis=0)
    dx = matmul(dy, w.T)
    dw_t = matmul(x_t.T, dy)  # grid restricted to s rows: the paper's saving
    return dx, dw_t, jnp.zeros_like(w_f)


s2ft_linear.defvjp(_s2ft_fwd, _s2ft_bwd)


def s2ft_linear_nd(x, w_t, w_f):
    """s2ft_linear for (..., K) activations (flattens leading dims)."""
    lead = x.shape[:-1]
    y = s2ft_linear(x.reshape(-1, x.shape[-1]), w_t, w_f)
    return y.reshape(*lead, y.shape[-1])


# --------------------------------------------------------------------------
# XLA-native partial back-propagation (no Pallas) — same contract.
#
# Why this exists: differentiating `x @ concat([w_t, w_f])` makes JAX emit
# the FULL weight-gradient GEMM and then slice out the trainable rows — XLA
# does not push the slice into the dot, so the paper's backward saving
# silently evaporates. These custom VJPs apply the slice *before* the dW
# GEMM (the §3.3 "two-line patch"), for both row-split (wo/wd) and
# column-split (wq/wk/wv/wu/wg) coupled structures.
# --------------------------------------------------------------------------


@jax.custom_vjp
def s2ft_row_linear(x, w_t, w_f):
    """y = x @ [w_t; w_f] (row split), grads only for (x, w_t). x: (..., K)."""
    return x @ jnp.concatenate([w_t, w_f], axis=0)


def _row_fwd(x, w_t, w_f):
    s = w_t.shape[0]
    return s2ft_row_linear(x, w_t, w_f), (x[..., :s], w_t, w_f)


def _row_bwd(res, dy):
    x_t, w_t, w_f = res
    w = jnp.concatenate([w_t, w_f], axis=0)
    dx = dy @ w.T
    # contract all leading dims: dw_t = x_tᵀ · dy over only the s rows
    xt2 = x_t.reshape(-1, x_t.shape[-1])
    dy2 = dy.reshape(-1, dy.shape[-1])
    dw_t = xt2.T @ dy2
    return dx, dw_t, jnp.zeros_like(w_f)


s2ft_row_linear.defvjp(_row_fwd, _row_bwd)


@jax.custom_vjp
def s2ft_col_linear(x, w_t, w_f):
    """y = x @ [w_t | w_f] (column split), grads only for (x, w_t)."""
    return x @ jnp.concatenate([w_t, w_f], axis=1)


def _col_fwd(x, w_t, w_f):
    return s2ft_col_linear(x, w_t, w_f), (x, w_t, w_f)


def _col_bwd(res, dy):
    x, w_t, w_f = res
    s = w_t.shape[1]
    w = jnp.concatenate([w_t, w_f], axis=1)
    dx = dy @ w.T
    x2 = x.reshape(-1, x.shape[-1])
    dy_t = dy[..., :s].reshape(-1, s)  # slice BEFORE the dW GEMM
    dw_t = x2.T @ dy_t
    return dx, dw_t, jnp.zeros_like(w_f)


s2ft_col_linear.defvjp(_col_fwd, _col_bwd)


def vmem_bytes(tm: int = TILE_M, tn: int = TILE_N, tk: int = TILE_K) -> int:
    """Estimated VMEM working set per grid step (x, w, out tiles, f32).

    Used by DESIGN.md / EXPERIMENTS.md §Perf for the TPU roofline estimate:
    3 tiles resident + 2x for double buffering of the streamed inputs.
    """
    return 4 * (tm * tk + tk * tn + tm * tn) + 4 * (tm * tk + tk * tn)
