//! Backend integration tests: init / forward / eval over the artifact
//! contract.
//!
//! Every test body is written against `&dyn Executor` and runs **twice**:
//! hermetically on the native backend (default feature set — no Python,
//! no artifacts, no XLA), and — under `--features pjrt` — against the
//! compiled AOT artifacts, skipping gracefully when `make artifacts` has
//! not been run.

use std::collections::HashMap;

use repro::runtime::{Executable, Executor, NativeBackend, Tensor};

fn init_pool(rt: &dyn Executor, seed: i32) -> HashMap<String, Tensor> {
    let init = rt.load("init_tiny").unwrap();
    let outs = init.run(&[Tensor::scalar_i32(seed)]).unwrap();
    init.spec().outputs.iter().map(|s| s.name.clone()).zip(outs).collect()
}

fn init_forward_eval_roundtrip(rt: &dyn Executor) {
    let init = rt.load("init_tiny").unwrap();
    let params = init.run(&[Tensor::scalar_i32(0)]).unwrap();
    assert_eq!(params.len(), init.spec().outputs.len());

    let mut pool: HashMap<String, Tensor> = init
        .spec()
        .outputs
        .iter()
        .map(|s| s.name.clone())
        .zip(params)
        .collect();
    let (b, t) = rt.artifacts().model("tiny").unwrap().default_batch();
    pool.insert("tokens".into(), Tensor::i32(vec![b, t], vec![1i32; b * t]));
    pool.insert("targets".into(), Tensor::i32(vec![b, t], vec![2i32; b * t]));
    pool.insert("loss_mask".into(), Tensor::f32(vec![b, t], vec![1.0; b * t]));

    let fwd = rt.load(&format!("fwd_tiny_{b}x{t}")).unwrap();
    let logits = fwd.run_named(&pool).unwrap();
    let lg = &logits["logits"];
    let vocab = rt.artifacts().model("tiny").unwrap().dims.vocab;
    assert_eq!(lg.shape, vec![b, t, vocab]);
    assert!(lg.as_f32().unwrap().iter().all(|x| x.is_finite()));

    let eval = rt.load(&format!("eval_tiny_{b}x{t}")).unwrap();
    let out = eval.run_named(&pool).unwrap();
    let loss = out["loss"].scalar_value_f32().unwrap();
    // Random init => loss near ln(vocab).
    let expect = (vocab as f32).ln();
    assert!(
        (loss - expect).abs() < 1.0,
        "loss {loss} too far from ln(vocab) {expect}"
    );
}

fn executable_rejects_bad_inputs(rt: &dyn Executor) {
    let init = rt.load("init_tiny").unwrap();
    // wrong arity
    assert!(init.run(&[]).is_err());
    // wrong shape
    let fwd_name = {
        let (b, t) = rt.artifacts().model("tiny").unwrap().default_batch();
        format!("fwd_tiny_{b}x{t}")
    };
    let fwd = rt.load(&fwd_name).unwrap();
    let bad: Vec<Tensor> =
        fwd.spec().inputs.iter().map(|_| Tensor::scalar_f32(0.0)).collect();
    assert!(fwd.run(&bad).is_err());
}

fn executable_cache_returns_same_instance(rt: &dyn Executor) {
    let a = rt.load("init_tiny").unwrap();
    let b = rt.load("init_tiny").unwrap();
    assert!(std::sync::Arc::ptr_eq(&a, &b));
    rt.evict("init_tiny");
    let c = rt.load("init_tiny").unwrap();
    assert!(!std::sync::Arc::ptr_eq(&a, &c));
}

fn init_is_deterministic_in_seed(rt: &dyn Executor) {
    let init = rt.load("init_tiny").unwrap();
    let p1 = init.run(&[Tensor::scalar_i32(3)]).unwrap();
    let p2 = init.run(&[Tensor::scalar_i32(3)]).unwrap();
    let p3 = init.run(&[Tensor::scalar_i32(4)]).unwrap();
    assert_eq!(p1, p2);
    // different seed differs somewhere
    let same = p1.iter().zip(&p3).all(|(a, b)| a == b);
    assert!(!same);
}

fn eval_ncorrect_counts_only_masked(rt: &dyn Executor) {
    let pool = init_pool(rt, 5);
    let (b, t) = rt.artifacts().model("tiny").unwrap().default_batch();
    let eval = rt.load(&format!("eval_tiny_{b}x{t}")).unwrap();
    let mut p = pool.clone();
    p.insert("tokens".into(), Tensor::i32(vec![b, t], vec![3i32; b * t]));
    p.insert("targets".into(), Tensor::i32(vec![b, t], vec![4i32; b * t]));
    // zero mask: loss must be finite and ncorrect exactly zero
    p.insert("loss_mask".into(), Tensor::f32(vec![b, t], vec![0.0; b * t]));
    let out = eval.run_named(&p).unwrap();
    assert_eq!(out["ncorrect"].scalar_value_f32().unwrap(), 0.0);
    assert!(out["loss"].scalar_value_f32().unwrap().is_finite());
}

// --- native backend (hermetic, default features) ---------------------------

mod native {
    use super::*;

    fn backend() -> NativeBackend {
        NativeBackend::builtin()
    }

    #[test]
    fn init_forward_eval_roundtrip() {
        super::init_forward_eval_roundtrip(&backend());
    }

    #[test]
    fn executable_rejects_bad_inputs() {
        super::executable_rejects_bad_inputs(&backend());
    }

    #[test]
    fn executable_cache_returns_same_instance() {
        super::executable_cache_returns_same_instance(&backend());
    }

    #[test]
    fn init_is_deterministic_in_seed() {
        super::init_is_deterministic_in_seed(&backend());
    }

    #[test]
    fn eval_ncorrect_counts_only_masked() {
        super::eval_ncorrect_counts_only_masked(&backend());
    }

    /// Greedy generation path: identical prompts in different batch slots
    /// decode identically (batch-invariant forward).
    #[test]
    fn forward_is_batch_position_invariant() {
        let rt = backend();
        let pool = init_pool(&rt, 9);
        let (b, t) = rt.artifacts().model("tiny").unwrap().default_batch();
        let fwd = rt.load(&format!("fwd_tiny_{b}x{t}")).unwrap();
        let row: Vec<i32> = (0..t as i32).map(|i| (i % 7) + 1).collect();
        let mut tokens = Vec::new();
        for _ in 0..b {
            tokens.extend(row.clone());
        }
        let mut p = pool.clone();
        p.insert("tokens".into(), Tensor::i32(vec![b, t], tokens));
        let out = fwd.run_named(&p).unwrap();
        let lg = out["logits"].as_f32().unwrap();
        let vocab = rt.artifacts().model("tiny").unwrap().dims.vocab;
        let per_row = t * vocab;
        for bi in 1..b {
            assert_eq!(
                &lg[..per_row],
                &lg[bi * per_row..(bi + 1) * per_row],
                "row {bi} diverged from row 0"
            );
        }
    }
}

// --- pjrt backend (requires `make artifacts` + a real xla build) -----------

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use repro::runtime::Runtime;

    fn runtime() -> Option<Runtime> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("meta.json").exists() {
            eprintln!("skipping pjrt test: no artifacts (run `make artifacts`)");
            return None;
        }
        match Runtime::new(dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping pjrt test: {e:#} (vendor the real xla crate)");
                None
            }
        }
    }

    #[test]
    fn init_forward_eval_roundtrip() {
        let Some(rt) = runtime() else { return };
        super::init_forward_eval_roundtrip(&rt);
    }

    #[test]
    fn executable_rejects_bad_inputs() {
        let Some(rt) = runtime() else { return };
        super::executable_rejects_bad_inputs(&rt);
    }

    #[test]
    fn executable_cache_returns_same_instance() {
        let Some(rt) = runtime() else { return };
        super::executable_cache_returns_same_instance(&rt);
    }

    #[test]
    fn init_is_deterministic_in_seed() {
        let Some(rt) = runtime() else { return };
        super::init_is_deterministic_in_seed(&rt);
    }
}
