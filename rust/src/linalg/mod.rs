//! Dense linear-algebra substrate (no external BLAS).
//!
//! Powers the theory simulator (min-norm LoRA/S²FT solutions need SVD and
//! pseudo-inverses), the adapter math (LoRA ΔW = A·B on the switch path)
//! and the Fig 6 single-layer serving benchmarks.

mod svd;

pub use svd::{svd, Svd};

use std::fmt;

/// Row-major dense f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len());
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    /// Standard-normal random matrix (deterministic given the rng).
    pub fn randn(rows: usize, cols: usize, rng: &mut crate::util::rng::Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal_f32()).collect();
        Self { rows, cols, data }
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// `self @ other` via the shared parallel kernel subsystem
    /// ([`crate::kernels::gemm`]).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul {self:?} @ {other:?}");
        Mat {
            rows: self.rows,
            cols: other.cols,
            data: crate::kernels::gemm(&self.data, &other.data, self.rows, self.cols, other.cols),
        }
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn scale(&self, s: f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn op_norm(&self) -> f32 {
        svd(self).s.first().copied().unwrap_or(0.0)
    }

    pub fn trace(&self) -> f32 {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).sum()
    }

    /// Moore–Penrose pseudo-inverse via SVD with relative threshold.
    pub fn pinv(&self) -> Mat {
        let Svd { u, s, vt } = svd(self);
        let tol = s.first().copied().unwrap_or(0.0) * 1e-5 * self.rows.max(self.cols) as f32;
        // A+ = V S+ U^T
        let mut sp = Mat::zeros(vt.rows, u.cols);
        for (i, &sv) in s.iter().enumerate() {
            if sv > tol {
                sp[(i, i)] = 1.0 / sv;
            }
        }
        vt.t().matmul(&sp).matmul(&u.t())
    }

    /// Best rank-r approximation (truncated SVD) — the LoRA min-norm
    /// update. Reconstruction `(U_r Σ_r) @ Vt_r` runs on the shared GEMM
    /// kernel.
    pub fn svd_truncate(&self, r: usize) -> Mat {
        let Svd { u, s, vt } = svd(self);
        let r = r.min(s.len());
        // gather the first r columns of U scaled by the singular values
        let mut us = Vec::with_capacity(self.rows * r);
        for i in 0..self.rows {
            for (k, &sv) in s.iter().enumerate().take(r) {
                us.push(u[(i, k)] * sv);
            }
        }
        let vtr = &vt.data[..r * self.cols];
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: crate::kernels::gemm(&us, vtr, self.rows, r, self.cols),
        }
    }

    /// Keep only the rows in `idx`, zeroing the rest (S²FT-style projector).
    pub fn keep_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for &i in idx {
            out.data[i * self.cols..(i + 1) * self.cols]
                .copy_from_slice(self.row(i));
        }
        out
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::seed(0);
        let a = Mat::randn(4, 6, &mut rng);
        let got = a.matmul(&Mat::eye(6));
        assert!(got.sub(&a).fro_norm() < 1e-6);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn pinv_of_full_rank_square_is_inverse() {
        let a = Mat::from_vec(2, 2, vec![4.0, 0.0, 0.0, 2.0]);
        let p = a.pinv();
        let prod = a.matmul(&p);
        assert!(prod.sub(&Mat::eye(2)).fro_norm() < 1e-4);
    }

    #[test]
    fn pinv_properties_rect() {
        let mut rng = Rng::seed(1);
        let a = Mat::randn(6, 3, &mut rng);
        let p = a.pinv();
        // A A+ A = A
        let apa = a.matmul(&p).matmul(&a);
        assert!(apa.sub(&a).fro_norm() / a.fro_norm() < 1e-3);
    }

    #[test]
    fn truncated_svd_rank() {
        let mut rng = Rng::seed(2);
        // build an exactly rank-2 matrix
        let u = Mat::randn(5, 2, &mut rng);
        let v = Mat::randn(2, 7, &mut rng);
        let a = u.matmul(&v);
        let a2 = a.svd_truncate(2);
        assert!(a2.sub(&a).fro_norm() / a.fro_norm() < 1e-3);
        let a1 = a.svd_truncate(1);
        assert!(a1.sub(&a).fro_norm() > 1e-3); // strictly worse
    }

    #[test]
    fn keep_rows_projector() {
        let a = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let k = a.keep_rows(&[1]);
        assert_eq!(k.data, vec![0., 0., 3., 4., 0., 0.]);
    }
}
