# S²FT reproduction — top-level driver.
#
#   make build          release build (native backend, hermetic: no Python/XLA)
#   make test           full hermetic test suite (default features)
#   make test-pjrt      compile-check the PJRT feature path as well
#   make artifacts      AOT-lower the JAX models to HLO text (needs python+jax)
#   make fmt lint doc   formatting / clippy / rustdoc gates (same as CI)
#   make bench          run every harness=false bench (JSON in rust/results/)
#   make bench-smoke    same with the short CI wall budget
#   make bench-smoke-scalar  smoke run with the portable tile forced
#                       (S2FT_SIMD=0 — the CI scalar matrix lane)
#   make bench-baseline regenerate the committed regression baselines
#   make bench-compare  gate kernels/serve/serve_load results vs baselines
#   make serve-smoke    engine-pool serving end-to-end (hermetic, native)
#   make analyze        static-analysis gate (bit-identity invariant lints)
#   make miri           nightly: UB-check the unsafe kernel modules
#   make tsan           nightly: ThreadSanitizer over the stress tests

CARGO ?= cargo
MANIFEST = rust/Cargo.toml

.PHONY: build test test-pjrt artifacts artifacts-fig5 fmt lint doc clean \
	bench bench-smoke bench-smoke-scalar bench-baseline bench-compare serve-smoke \
	analyze miri tsan

build:
	$(CARGO) build --release --manifest-path $(MANIFEST)

test:
	$(CARGO) test -q --manifest-path $(MANIFEST)

test-pjrt:
	$(CARGO) test -q --manifest-path $(MANIFEST) --features pjrt

fmt:
	$(CARGO) fmt --check --manifest-path $(MANIFEST)

lint:
	$(CARGO) clippy --manifest-path $(MANIFEST) --all-targets -- -D warnings

doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps --manifest-path $(MANIFEST)

# Bench binaries run with cwd = package root; JSON lands in rust/results/.
bench:
	$(CARGO) bench --manifest-path $(MANIFEST)

bench-smoke:
	S2FT_BENCH_BUDGET_MS=300 $(CARGO) bench --manifest-path $(MANIFEST)

bench-smoke-scalar:
	S2FT_BENCH_BUDGET_MS=300 S2FT_SIMD=0 $(CARGO) bench --manifest-path $(MANIFEST)

bench-baseline:
	$(CARGO) bench --manifest-path $(MANIFEST) --bench kernels
	$(CARGO) bench --manifest-path $(MANIFEST) --bench serve
	$(CARGO) bench --manifest-path $(MANIFEST) --bench serve_load
	$(CARGO) bench --manifest-path $(MANIFEST) --bench fig5_training
	cp rust/results/bench_kernels.json rust/benches/baseline/kernels.json
	cp rust/results/bench_serve.json rust/benches/baseline/serve.json
	cp rust/results/bench_serve_load.json rust/benches/baseline/serve_load.json
	cp rust/results/bench_fig5_training.json rust/benches/baseline/fig5_training.json
	@echo "baselines updated: rust/benches/baseline/{kernels,serve,serve_load,fig5_training}.json (commit them)"

bench-compare:
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench-compare \
	  --current rust/results/bench_kernels.json \
	  --baseline rust/benches/baseline/kernels.json
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench-compare \
	  --current rust/results/bench_serve.json \
	  --baseline rust/benches/baseline/serve.json
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench-compare \
	  --current rust/results/bench_serve_load.json \
	  --baseline rust/benches/baseline/serve_load.json --warn 1.5 --fail 3.0
	$(CARGO) run --release --manifest-path $(MANIFEST) -- bench-compare \
	  --current rust/results/bench_fig5_training.json \
	  --baseline rust/benches/baseline/fig5_training.json --warn 1.5 --fail 3.0

serve-smoke:
	$(CARGO) run --release --manifest-path $(MANIFEST) -- serve \
	  --backend native --model tiny --workers 2 --adapters 3 --requests 32 --stream

# Static-analysis gate: deny-by-default lints for the bit-identity
# invariants (float-literal equality, mul_add, SAFETY comments,
# nondeterminism sources, bench/baseline drift). Exits non-zero on any
# finding; same invocation as the CI step.
analyze:
	$(CARGO) run --release --manifest-path $(MANIFEST) -- analyze

# Dynamic lanes the linter cannot cover (both need a nightly toolchain:
# `rustup +nightly component add miri rust-src`).
miri:
	$(CARGO) +nightly miri test --manifest-path $(MANIFEST) --lib -- \
	  kernels::pack kernels::micro

tsan:
	RUSTFLAGS="-Zsanitizer=thread" S2FT_STRESS_ITERS=3 \
	  $(CARGO) +nightly test --manifest-path $(MANIFEST) \
	  -Zbuild-std --target x86_64-unknown-linux-gnu \
	  --release --test stress_concurrency

# Build-time only: lower every (model, method) to HLO text + meta.json.
# Requires a python environment with jax installed; the rust side never
# needs python at runtime (and the native backend never needs artifacts).
artifacts:
	cd python && python -m compile.aot --out ../rust/artifacts

artifacts-fig5:
	cd python && python -m compile.aot --out ../rust/artifacts --fig5 --extras

clean:
	$(CARGO) clean --manifest-path $(MANIFEST)
